"""Benchmark harness entry point: one section per paper table/figure plus the
framework-level benches.

  figure1   — semabench (coherence model + real threads)      [paper Fig. 1]
  serving   — TWA scheduler vs global rescan + QoS tenants    [paper §2 adapted]
  kernels   — Pallas kernels: oracle deltas + VMEM budgets
  roofline  — dry-run aggregation (per arch × shape × mesh)   [assignment]

    PYTHONPATH=src python -m benchmarks.run [--only figure1,kernels]
                                            [--json out.json]

`--json` writes per-section metrics (figure1 throughputs, serving
scans/skipped + per-tenant admission shares, kernel oracle deltas) so the
BENCH_*.json perf trajectory can accumulate across PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="figure1,serving,kernels,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-section metrics JSON to PATH")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: skip the K=128 megastep sweep and "
                         "shrink the paged-pool workload (flat wall time)")
    args = ap.parse_args(argv)
    if args.quick:
        import os

        os.environ["REPRO_BENCH_QUICK"] = "1"
    if args.json:  # fail fast, not after minutes of benchmarking
        with open(args.json, "a"):
            pass
    only = set(args.only.split(","))
    sections = []
    if "figure1" in only:
        from . import semabench

        sections.append(("figure1 / semabench", semabench.run))
    if "serving" in only:
        from . import serving_bench

        sections.append(("serving scheduler", serving_bench.run))
    if "kernels" in only:
        from . import kernel_bench

        sections.append(("pallas kernels", kernel_bench.run))
    if "roofline" in only:
        from . import roofline_table

        sections.append(("roofline / dry-run", roofline_table.run))

    failures = 0
    report: dict = {"sections": {}, "failures": []}
    for name, fn in sections:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        t0 = time.time()
        metrics: dict = {}
        try:
            print(fn(metrics))
            metrics["wall_s"] = round(time.time() - t0, 3)
            report["sections"][name] = metrics
            print(f"[{name}] ok in {time.time() - t0:.1f}s")
        except Exception as e:  # report and continue — partial results count
            failures += 1
            import traceback

            traceback.print_exc()
            print(f"[{name}] FAILED: {e}")
            report["failures"].append({"section": name, "error": repr(e)})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"\n[metrics] wrote {args.json}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
