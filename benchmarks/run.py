"""Benchmark harness entry point: one section per paper table/figure plus the
framework-level benches.

  figure1   — semabench (coherence model + real threads)      [paper Fig. 1]
  serving   — TWA scheduler vs global rescan                  [paper §2 adapted]
  kernels   — Pallas kernels: oracle deltas + VMEM budgets
  roofline  — dry-run aggregation (per arch × shape × mesh)   [assignment]

    PYTHONPATH=src python -m benchmarks.run [--only figure1,kernels]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="figure1,serving,kernels,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(","))
    sections = []
    if "figure1" in only:
        from . import semabench

        sections.append(("figure1 / semabench", semabench.run))
    if "serving" in only:
        from . import serving_bench

        sections.append(("serving scheduler", serving_bench.run))
    if "kernels" in only:
        from . import kernel_bench

        sections.append(("pallas kernels", kernel_bench.run))
    if "roofline" in only:
        from . import roofline_table

        sections.append(("roofline / dry-run", roofline_table.run))

    failures = 0
    for name, fn in sections:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            print(fn())
            print(f"[{name}] ok in {time.time() - t0:.1f}s")
        except Exception as e:  # report and continue — partial results count
            failures += 1
            import traceback

            traceback.print_exc()
            print(f"[{name}] FAILED: {e}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
