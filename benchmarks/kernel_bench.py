"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock is meaningless; what is reported instead:
  * correctness deltas vs the jnp oracles (allclose margins);
  * the *analytic* VMEM working set per BlockSpec configuration vs the
    16 MiB/core budget (the quantity that determines real TPU viability);
  * arithmetic intensity per kernel config (drives the §Roofline discussion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import decode_attention_ref, mha_ref, sema_batch_ref
from repro.kernels.sema_batch import sema_batch

VMEM_BUDGET = 16 * 2**20


def flash_vmem(block_q, block_k, hd, G):
    """fp32 scratch + bf16 tiles + double buffering of k/v blocks."""
    scratch = (G * block_q * hd + 2 * G * block_q) * 4
    tiles = (G * block_q * hd + 2 * 2 * block_k * hd) * 2  # q + 2×(k,v) dbuf
    probs = G * block_q * block_k * 4
    return scratch + tiles + probs


def run(metrics: dict | None = None) -> str:
    lines = ["== Pallas kernels (interpret-mode validation + VMEM budgets) =="]
    key = jax.random.PRNGKey(0)

    # flash attention configs: (name, S, H, KV, hd, bq, bk)
    for name, S, H, KV, hd, bq, bk in [
        ("qwen2-72b prefill tile", 512, 8, 1, 128, 256, 512),
        ("gemma3 local-window    ", 512, 4, 1, 256, 256, 256),
        ("musicgen               ", 512, 4, 4, 64, 512, 512),
    ]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, S, H, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, S, KV, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, S, KV, hd), jnp.bfloat16)
        out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk, interpret=True)
        ref = mha_ref(q, k, v)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
        G = H // KV
        vm = flash_vmem(bq, bk, hd, G)
        flops = 4 * S * S * hd  # per (b,kv-head)
        bytes_hbm = (S * G * hd + 2 * S * hd) * 2
        lines.append(
            f"flash {name} bq={bq} bk={bk}: err={err:.1e} "
            f"VMEM={vm / 2**20:.1f}MiB ({'OK' if vm < VMEM_BUDGET else 'OVER'}) "
            f"AI={flops / bytes_hbm:.0f} flop/B")
        if metrics is not None:
            metrics.setdefault("oracle_err", {})[f"flash/{name.strip()}"] = err

    # decode attention
    for name, C, H, KV, hd, bk in [
        ("72b decode shard  ", 2048, 64, 8, 128, 512),
        ("long-context shard", 2048, 4, 1, 256, 512),
    ]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, H, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (2, C, KV, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (2, C, KV, hd), jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(C)[None], (2, C)).astype(jnp.int32)
        qp = jnp.full((2,), C, jnp.int32)
        out = decode_attention(q, k, v, pos, qp, block_k=bk, interpret=True)
        ref = decode_attention_ref(q, k, v, pos, qp)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
        G = H // KV
        vm = (G * hd * 4 + 2 * G * 4 + 2 * 2 * bk * hd * 2 + G * bk * 4)
        ai = (4 * C * hd * G) / (2 * C * hd * 2)  # ≈ 2·G flop/B — memory-bound
        lines.append(
            f"decode {name} bk={bk}: err={err:.1e} VMEM={vm / 2**20:.2f}MiB "
            f"AI={ai:.1f} flop/B (memory-bound by design)")
        if metrics is not None:
            metrics.setdefault("oracle_err", {})[f"decode/{name.strip()}"] = err

    # sema_batch
    req = jax.random.bernoulli(key, 0.6, (2048,))
    out = sema_batch(jnp.uint32(0), jnp.uint32(64), jnp.zeros((1024,), jnp.uint32),
                     req, jnp.uint32(128), jnp.uint32(7), block_n=512, interpret=True)
    ref = sema_batch_ref(jnp.uint32(0), jnp.uint32(64), jnp.zeros((1024,), jnp.uint32),
                         req, jnp.uint32(128), jnp.uint32(7))
    exact = bool(np.array_equal(np.asarray(out[4]), np.asarray(ref["admitted"])))
    lines.append(f"sema_batch 2048 reqs × 1024 buckets: exact={exact} "
                 f"(tri-matmul rank + permutation one-hot poke)")
    if metrics is not None:
        metrics["sema_batch_exact"] = exact
    return "\n".join(lines)


if __name__ == "__main__":
    print(run())
