"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock is meaningless; what is reported instead:
  * correctness deltas vs the jnp oracles (allclose margins);
  * the *analytic* VMEM working set per BlockSpec configuration vs the
    16 MiB/core budget (the quantity that determines real TPU viability);
  * arithmetic intensity per kernel config (drives the §Roofline discussion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.admission.functional_qos import QoSState, make_qos, qos_take
from repro.core.functional import live_fifo_rank, live_fifo_rank_pairwise
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.qos_admission import qos_round_fused
from repro.kernels.ref import (
    decode_attention_ref,
    mha_ref,
    qos_round_ref,
    sema_batch_ref,
)
from repro.kernels.sema_batch import sema_batch

VMEM_BUDGET = 16 * 2**20


def qos_vmem(block_n, s_pad, u_pad, table):
    """Fused qos_round working set: row blocks, tenant state, crossings,
    the (Sp, T) permutation-poke compare, and the two tri matmuls."""
    rows = 3 * block_n * 4 + 2 * block_n * 4            # in + out row blocks
    tenant = (2 + 4 + 4 + 1) * s_pad * 4 + 6 * s_pad * 4  # state + scratch
    seq = 2 * table * 4
    crossings = s_pad * u_pad * 4 * 2                   # cross + key
    poke = s_pad * table * 4
    tri = block_n * block_n * 4 + s_pad * s_pad * 4
    return rows + tenant + seq + crossings + poke + tri


def _flops(fn, *args):
    return compat.cost_analysis(
        jax.jit(fn).lower(*args).compile()).get("flops", 0.0)


def flash_vmem(block_q, block_k, hd, G):
    """fp32 scratch + bf16 tiles + double buffering of k/v blocks."""
    scratch = (G * block_q * hd + 2 * G * block_q) * 4
    tiles = (G * block_q * hd + 2 * 2 * block_k * hd) * 2  # q + 2×(k,v) dbuf
    probs = G * block_q * block_k * 4
    return scratch + tiles + probs


def run(metrics: dict | None = None) -> str:
    lines = ["== Pallas kernels (interpret-mode validation + VMEM budgets) =="]
    key = jax.random.PRNGKey(0)

    # flash attention configs: (name, S, H, KV, hd, bq, bk)
    for name, S, H, KV, hd, bq, bk in [
        ("qwen2-72b prefill tile", 512, 8, 1, 128, 256, 512),
        ("gemma3 local-window    ", 512, 4, 1, 256, 256, 256),
        ("musicgen               ", 512, 4, 4, 64, 512, 512),
    ]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, S, H, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, S, KV, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, S, KV, hd), jnp.bfloat16)
        out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk, interpret=True)
        ref = mha_ref(q, k, v)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
        G = H // KV
        vm = flash_vmem(bq, bk, hd, G)
        flops = 4 * S * S * hd  # per (b,kv-head)
        bytes_hbm = (S * G * hd + 2 * S * hd) * 2
        lines.append(
            f"flash {name} bq={bq} bk={bk}: err={err:.1e} "
            f"VMEM={vm / 2**20:.1f}MiB ({'OK' if vm < VMEM_BUDGET else 'OVER'}) "
            f"AI={flops / bytes_hbm:.0f} flop/B")
        if metrics is not None:
            metrics.setdefault("oracle_err", {})[f"flash/{name.strip()}"] = err

    # decode attention
    for name, C, H, KV, hd, bk in [
        ("72b decode shard  ", 2048, 64, 8, 128, 512),
        ("long-context shard", 2048, 4, 1, 256, 512),
    ]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, H, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (2, C, KV, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (2, C, KV, hd), jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(C)[None], (2, C)).astype(jnp.int32)
        qp = jnp.full((2,), C, jnp.int32)
        out = decode_attention(q, k, v, pos, qp, block_k=bk, interpret=True)
        ref = decode_attention_ref(q, k, v, pos, qp)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
        G = H // KV
        vm = (G * hd * 4 + 2 * G * 4 + 2 * 2 * bk * hd * 2 + G * bk * 4)
        ai = (4 * C * hd * G) / (2 * C * hd * 2)  # ≈ 2·G flop/B — memory-bound
        lines.append(
            f"decode {name} bk={bk}: err={err:.1e} VMEM={vm / 2**20:.2f}MiB "
            f"AI={ai:.1f} flop/B (memory-bound by design)")
        if metrics is not None:
            metrics.setdefault("oracle_err", {})[f"decode/{name.strip()}"] = err

    # paged prefill (chunked-prefill kernel: in-pass pool writeback)
    from repro.kernels.paged_prefill import paged_prefill
    from repro.kernels.ref import paged_prefill_ref

    S, CT, H, KV, hd = 4, 32, 4, 2, 64
    NB, BS, MB = 64, 16, 8
    ks = jax.random.split(key, 5)
    qp_ = jax.random.normal(ks[0], (S, CT, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (S, CT, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (S, CT, KV, hd), jnp.float32)
    kpool = jax.random.normal(ks[3], (NB, BS, KV, hd), jnp.float32)
    vpool = jax.random.normal(ks[4], (NB, BS, KV, hd), jnp.float32)
    tbl = jnp.arange(S * MB, dtype=jnp.int32).reshape(S, MB) % NB
    offs = jnp.asarray([0, 24, 7, 40], jnp.int32)
    lens = jnp.asarray([32, 32, 17, 0], jnp.int32)
    out_k, kp2, vp2 = paged_prefill(qp_, kc, vc, kpool, vpool, tbl, offs,
                                    lens, interpret=True)
    out_r, kpr, vpr = paged_prefill_ref(qp_, kc, vc, kpool, vpool, tbl,
                                        offs, lens)
    pf_exact = bool(np.array_equal(np.asarray(out_k), np.asarray(out_r))
                    and np.array_equal(np.asarray(kp2), np.asarray(kpr))
                    and np.array_equal(np.asarray(vp2), np.asarray(vpr)))
    G = H // KV
    # q/chunk tiles + 2×(k,v) block dbuf + merge one-hot + scores + scratch
    vm_pf = (G * CT * hd * 4 + 2 * CT * hd * 4 + 2 * 2 * BS * hd * 4
             + BS * CT * 4 + G * CT * BS * 4
             + (G * CT * hd + 2 * G * CT) * 4)
    lines.append(
        f"paged_prefill S={S} CT={CT} BS={BS} (ragged offs, idle slot, "
        f"GQA {G}): bit-exact={pf_exact} incl. in-pass pool writeback; "
        f"VMEM={vm_pf / 2**20:.2f}MiB")
    if metrics is not None:
        metrics.setdefault("oracle_err", {})["paged_prefill/bitexact"] = \
            0.0 if pf_exact else 1.0

    # sema_batch
    req = jax.random.bernoulli(key, 0.6, (2048,))
    out = sema_batch(jnp.uint32(0), jnp.uint32(64), jnp.zeros((1024,), jnp.uint32),
                     req, jnp.uint32(128), jnp.uint32(7), block_n=512, interpret=True)
    ref = sema_batch_ref(jnp.uint32(0), jnp.uint32(64), jnp.zeros((1024,), jnp.uint32),
                         req, jnp.uint32(128), jnp.uint32(7))
    exact = bool(np.array_equal(np.asarray(out[4]), np.asarray(ref["admitted"])))
    lines.append(f"sema_batch 2048 reqs × 1024 buckets: exact={exact} "
                 f"(tri-matmul rank + permutation one-hot poke)")
    if metrics is not None:
        metrics["sema_batch_exact"] = exact

    # fused QoS admission round: kernel vs functional oracle (bit-exact)
    S, N, TBL, MU, BN = 8, 512, 512, 32, 128
    rng = np.random.default_rng(0)
    qs = make_qos(np.linspace(1, 4, S).astype(np.float32), table_size=TBL)
    ids = jnp.asarray(rng.integers(0, S, N), jnp.int32)
    qs, tk, _, _ = qos_take(qs, ids, jnp.ones(N, bool))
    alive = jnp.asarray(rng.random(N) > 0.15)
    dls = jnp.asarray(np.where(rng.random(N) > 0.4,
                               rng.uniform(0, 2, N), np.inf), jnp.float32)
    ref = qos_round_ref(qs, ids, tk, alive, dls, 1.0, 24, MU)
    ks, ka, ke, kl = qos_round_fused(qs, ids, tk, alive, dls, 1.0, 24,
                                     max_units=MU, block_n=BN, interpret=True)
    qexact = (
        np.array_equal(np.asarray(ka), np.asarray(ref["admitted"]))
        and np.array_equal(np.asarray(ke), np.asarray(ref["expired"]))
        and int(kl) == int(ref["leftover"])
        and all(np.array_equal(np.asarray(getattr(ks, f)),
                               np.asarray(getattr(ref["state"], f)))
                for f in QoSState._fields))
    s_pad, u_pad = 128, 128
    vm = qos_vmem(BN, s_pad, u_pad, TBL)
    lines.append(
        f"qos_round {N} rows × {S} tenants × {TBL} buckets: exact={qexact} "
        f"VMEM={vm / 2**20:.2f}MiB ({'OK' if vm < VMEM_BUDGET else 'OVER'}) "
        f"(2-phase grid: depth sweep → bit-descend stride alloc + "
        f"permutation poke → tri-rank admit)")
    if metrics is not None:
        metrics["qos_round_exact"] = qexact

    # reference-path asymptotics: blocked-prefix live rank vs the retained
    # O(N²) pairwise path, measured XLA flops at N=4k (the acceptance gate:
    # the new path must beat the old asymptotically, not just on wall time)
    N4, S4 = 4096, 8
    ids4 = jnp.asarray(rng.integers(0, S4, N4), jnp.int32)
    tk4 = jnp.arange(N4, dtype=jnp.uint32)
    al4 = jnp.asarray(rng.random(N4) > 0.2)
    fl_new = _flops(lambda i, t, a: live_fifo_rank(i, t, a, S4), ids4, tk4, al4)
    fl_old = _flops(live_fifo_rank_pairwise, ids4, tk4, al4)
    if fl_old > 0:  # some backends report no cost analysis — skip, don't fail
        assert fl_new < fl_old / 10, (
            f"blocked-prefix rank not asymptotically better: {fl_new:.3g} vs "
            f"pairwise {fl_old:.3g} flops at N={N4}")
    lines.append(
        f"live_fifo_rank N={N4} S={S4}: blocked-prefix {fl_new:.3g} flops "
        f"vs pairwise {fl_old:.3g} ({fl_old / max(fl_new, 1):.0f}× fewer; "
        f"O(N·S/block) vs O(N²))")
    if metrics is not None:
        metrics["qos_rank_flops"] = {
            "n": N4, "s": S4, "blocked": fl_new, "pairwise": fl_old,
            "ratio": fl_old / max(fl_new, 1.0)}

    # per-kernel cost-analysis profile: XLA's own flops / bytes-accessed
    # view of each serving kernel's compiled module (interpret mode lowers
    # to plain HLO, so the numbers are the reference-path cost — the
    # groundwork for the ROADMAP item-2 TPU roofline validation).  Some
    # backends report no cost model: rows degrade to zeros, never fail.
    from repro.kernels.paged_decode import paged_decode

    def _profile(name, fn, *args):
        try:
            ca = compat.cost_analysis(jax.jit(fn).lower(*args).compile())
        except Exception as e:  # pragma: no cover - backend-specific
            lines.append(f"profile {name}: cost analysis unavailable ({e})")
            ca = {}
        flops = float(ca.get("flops", 0.0))
        byt = float(ca.get("bytes accessed", 0.0))
        ai = flops / byt if byt else float("nan")
        lines.append(f"profile {name}: {flops:.3g} flops, {byt:.3g} B "
                     f"accessed, AI={ai:.2f} flop/B")
        if metrics is not None:
            metrics.setdefault("kernel_profile", {})[name] = {
                "flops": flops, "bytes": byt}

    _profile("qos_round_fused",
             lambda st, i, t, a, d: qos_round_fused(
                 st, i, t, a, d, 1.0, 24, max_units=MU, block_n=BN,
                 interpret=True),
             qs, ids, tk, alive, dls)
    pd_q = jax.random.normal(key, (tbl.shape[0], H, hd), jnp.float32)
    pd_lens = jnp.asarray([32, 17, 9, 0], jnp.int32)
    _profile("paged_decode",
             lambda q_, kp_, vp_, t_, l_: paged_decode(
                 q_, kp_, vp_, t_, l_, interpret=True),
             pd_q, kpool, vpool, tbl, pd_lens)
    _profile("paged_prefill",
             lambda *a: paged_prefill(*a, interpret=True),
             qp_, kc, vc, kpool, vpool, tbl, offs, lens)
    return "\n".join(lines)


if __name__ == "__main__":
    print(run())
