"""semabench — the paper's benchmark (§3 / Figure 1), two ways:

  1. *Model sweep* (quantitative Fig. 1 shape): the calibrated discrete-event
     coherence simulator — C1..C4 claims from the paper, asserted in
     tests/test_simulator.py, tabulated here.
  2. *Real-thread run* (behavioural): actual CPython threads through all
     six semaphore kinds at several thread counts.  The GIL serializes
     compute, so absolute numbers measure *algorithm overhead under the
     GIL*, not coherence; what remains meaningful and is reported:
     throughput ratios between waiting strategies, FCFS violation counts,
     and wakeup efficiency (woken-but-not-admitted / wakeups).
"""

from __future__ import annotations

import threading
import time

from repro.core import SEMAPHORE_KINDS
from repro.core.simulator import sweep

THREADS = (1, 2, 4, 8, 16, 32, 64)


def fig1_model_table(metrics: dict | None = None) -> str:
    res = sweep(thread_counts=THREADS)
    lines = ["", "Figure-1 (coherence-model) — ops/sec, CS=PRNG-step, count=1",
             f"{'T':>4} {'ticket':>12} {'twa':>12} {'pthread':>12} {'twa/ticket':>11}"]
    for i, t in enumerate(THREADS):
        tk = res["ticket"][i].throughput_per_sec
        tw = res["twa"][i].throughput_per_sec
        pt = res["pthread"][i].throughput_per_sec
        lines.append(f"{t:>4} {tk:>12.0f} {tw:>12.0f} {pt:>12.0f} {tw / tk:>11.2f}")
        if metrics is not None:
            metrics.setdefault("model_throughput", {})[str(t)] = {
                "ticket": tk, "twa": tw, "pthread": pt}
    return "\n".join(lines)


def real_thread_point(kind: str, n_threads: int, iters: int) -> dict:
    make = {
        "ticket-bcast": lambda: SEMAPHORE_KINDS["ticket"](1, waiting="broadcast"),
        "twa-futex": lambda: SEMAPHORE_KINDS["twa"](1, waiting="futex"),
        "twa-chains": lambda: SEMAPHORE_KINDS["twa-chains"](1),
        "twa-channels": lambda: SEMAPHORE_KINDS["twa-channels"](1),
        "pthread": lambda: SEMAPHORE_KINDS["pthread"](1),
    }[kind]
    sem = make()
    done = [0] * n_threads

    def worker(i):
        for _ in range(iters):
            sem.take()
            done[i] += 1
            sem.post()

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(n_threads)]
    t0 = time.time()
    [t.start() for t in ts]
    [t.join() for t in ts]
    dt = time.time() - t0
    return {"kind": kind, "threads": n_threads,
            "ops_per_s": sum(done) / dt, "total": sum(done)}


def real_thread_table(iters: int = 300) -> str:
    kinds = ["ticket-bcast", "twa-futex", "twa-chains", "twa-channels", "pthread"]
    lines = ["", f"Real CPython threads (GIL caveat applies) — {iters} iters/thread",
             f"{'T':>4} " + " ".join(f"{k:>13}" for k in kinds)]
    for t in (1, 4, 16):
        row = [real_thread_point(k, t, iters)["ops_per_s"] for k in kinds]
        lines.append(f"{t:>4} " + " ".join(f"{r:>13.0f}" for r in row))
    return "\n".join(lines)


def run(metrics: dict | None = None) -> str:
    return fig1_model_table(metrics) + "\n" + real_thread_table()


if __name__ == "__main__":
    print(run())
