"""Bench-trajectory regression gate.

Every PR commits a full-size ``BENCH_PR<k>.json`` snapshot (see
``benchmarks/run.py --json``).  This module walks that committed
trajectory and fails if any throughput metric in the NEWEST snapshot
regressed by more than ``--threshold`` (default 20%) against the most
recent earlier snapshot that reports the same metric.

    PYTHONPATH=src python -m benchmarks.regression            # newest vs rest
    PYTHONPATH=src python -m benchmarks.regression BENCH_PR10.json
    PYTHONPATH=src python -m benchmarks.regression --threshold 0.3

Throughput metrics are discovered structurally: any numeric leaf whose
key is ``tok_s`` or ``tok_per_vs`` (cluster tokens per virtual second),
anywhere under ``sections``.  Metrics that appear for the first time in
the newest snapshot are reported as new, never failed.

Snapshots are produced on whatever machine ran that PR's session, so
absolute tokens/s is only comparable over SHORT spans of the
trajectory: the gate compares against the ``--window`` most recent
earlier snapshots (default 1 — one hardware hop), taking each metric's
most recent prior value inside the window.  Older history still prints
(``--window 0`` = whole trajectory) but reading a 20% "regression"
across a machine change is noise, not signal.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

THROUGHPUT_KEYS = ("tok_s", "tok_per_vs")


def _snapshot_order(path: str) -> int:
    m = re.search(r"BENCH_PR(\d+)\.json$", os.path.basename(path))
    return int(m.group(1)) if m else -1


def discover(root: str = ".") -> list[str]:
    """Committed trajectory snapshots, oldest first."""
    paths = [p for p in glob.glob(os.path.join(root, "BENCH_PR*.json"))
             if _snapshot_order(p) >= 0]
    return sorted(paths, key=_snapshot_order)


def throughput_metrics(report: dict) -> dict[str, float]:
    """Flatten ``sections`` to {'section/.../tok_s': value}."""
    out: dict[str, float] = {}

    def walk(node, prefix):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{prefix}/{k}" if prefix else str(k))
        elif isinstance(node, (int, float)) and not isinstance(node, bool):
            if prefix.rsplit("/", 1)[-1] in THROUGHPUT_KEYS:
                out[prefix] = float(node)

    walk(report.get("sections", {}), "")
    return out


def compare(new_path: str, baseline_paths: list[str], threshold: float):
    """Return (failures, lines) for new vs the trajectory baselines."""
    with open(new_path) as f:
        new = throughput_metrics(json.load(f))
    # Most recent earlier value per metric: apply baselines oldest→newest.
    base: dict[str, tuple[float, str]] = {}
    for p in baseline_paths:
        with open(p) as f:
            for k, v in throughput_metrics(json.load(f)).items():
                base[k] = (v, os.path.basename(p))
    failures, lines = [], []
    for k in sorted(new):
        if k not in base:
            lines.append(f"  NEW    {k} = {new[k]:.1f}")
            continue
        old, src = base[k]
        if old <= 0:
            continue
        ratio = new[k] / old
        tag = "ok"
        if ratio < 1.0 - threshold:
            tag = "FAIL"
            failures.append(k)
        lines.append(f"  {tag:<6} {k}: {old:.1f} ({src}) -> "
                     f"{new[k]:.1f}  ({(ratio - 1.0) * 100:+.1f}%)")
    for k in sorted(set(base) - set(new)):
        lines.append(f"  GONE   {k} (was in {base[k][1]}) — not failed, "
                     f"but trajectory lost a metric")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", nargs="?", default=None,
                    help="snapshot to gate (default: newest BENCH_PR*.json)")
    ap.add_argument("--root", default=".",
                    help="directory holding BENCH_PR*.json (default: .)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed fractional tokens/s drop (default 0.20)")
    ap.add_argument("--window", type=int, default=1,
                    help="gate vs the N most recent earlier snapshots "
                         "(0 = whole trajectory; default 1)")
    args = ap.parse_args(argv)

    traj = discover(args.root)
    if args.new is None:
        if not traj:
            print("[bench-regression] no BENCH_PR*.json trajectory found")
            return 1
        new_path, baselines = traj[-1], traj[:-1]
    else:
        new_path = args.new
        baselines = [p for p in traj
                     if os.path.abspath(p) != os.path.abspath(new_path)]
        order = _snapshot_order(new_path)
        if order >= 0:
            baselines = [p for p in baselines if _snapshot_order(p) < order]
    if not baselines:
        print(f"[bench-regression] {new_path}: no earlier snapshots — pass")
        return 0
    if args.window > 0:
        baselines = baselines[-args.window:]

    failures, lines = compare(new_path, baselines, args.threshold)
    print(f"[bench-regression] {os.path.basename(new_path)} vs "
          f"{len(baselines)} earlier snapshot(s), "
          f"threshold -{args.threshold * 100:.0f}%")
    print("\n".join(lines))
    if failures:
        print(f"[bench-regression] FAIL: {len(failures)} metric(s) regressed "
              f"more than {args.threshold * 100:.0f}%:")
        for k in failures:
            print(f"  {k}")
        return 1
    print("[bench-regression] pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
