"""Aggregate the dry-run JSONs into the §Dry-run / §Roofline tables of
EXPERIMENTS.md.  Reads experiments/dryrun/*.json (produced by
repro.launch.dryrun), writes markdown to stdout."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load(mode: str, mesh: str | None = None) -> dict:
    out = {}
    for p in sorted(DRYRUN_DIR.glob(f"*_{mode}.json")):
        r = json.loads(p.read_text())
        if not r.get("ok"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_b(x) -> str:
    return f"{(x or 0) / 1e9:.2f}"


def dryrun_table() -> str:
    rows = load("production")
    lines = [
        "| arch | shape | mesh | A | remat | raw GB/dev | proj GB/dev | fits 16G | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), r in sorted(rows.items()):
        m = r["memory"]
        scfg = r.get("step_config", {})
        proj = m.get("tpu_projected_bytes") or 0
        lines.append(
            f"| {arch} | {shape} | {mesh} | {scfg.get('accum_steps')} "
            f"| {scfg.get('remat')} | {fmt_b(m.get('per_device_total_bytes'))} "
            f"| {fmt_b(proj)} | {'✓' if proj < 16e9 else '✗'} "
            f"| {r.get('t_compile_s', r.get('t_total_s'))} |")
    return "\n".join(lines)


def roofline_table() -> str:
    rows = load("analysis", mesh="pod")
    lines = [
        "| arch | shape | T_comp ms | T_mem ms | T_coll ms | bound | roofline-frac"
        " | 6ND/HLO | (+attn)/HLO |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), r in sorted(rows.items()):
        t = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {t['T_comp'] * 1e3:.1f} | {t['T_mem'] * 1e3:.1f} "
            f"| {t['T_coll'] * 1e3:.1f} | {t['bottleneck'][2:]} "
            f"| {t['roofline_fraction']:.2f} | {t['useful_ratio']:.2f} "
            f"| {t.get('useful_ratio_with_attn', 0):.2f} |")
    return "\n".join(lines)


def collective_summary() -> str:
    rows = load("analysis", mesh="pod")
    lines = ["| arch | shape | coll ops | wire GB/chip | dominant axis | dominant kind |",
             "|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(rows.items()):
        c = r.get("collectives", {})
        ax = max(c.get("by_axis", {"-": 0}).items(), key=lambda kv: kv[1])[0]
        kd = max(c.get("by_kind", {"-": 0}).items(), key=lambda kv: kv[1])[0]
        lines.append(f"| {arch} | {shape} | {c.get('ops')} "
                     f"| {fmt_b(c.get('wire_bytes_per_chip'))} | {ax} | {kd} |")
    return "\n".join(lines)


def run(metrics: dict | None = None) -> str:
    prod = load("production")
    ana = load("analysis")
    if metrics is not None:
        metrics["production_cells"] = len(prod)
        metrics["analysis_cells"] = len(ana)
    return (
        f"== Dry-run: {len(prod)} production cells "
        f"({len([1 for k in prod if k[2] == 'multipod'])} multipod), "
        f"{len(ana)} analysis cells ==\n\n"
        "### Production (memory proof)\n" + dryrun_table() +
        "\n\n### Roofline (single-pod analysis lowering)\n" + roofline_table() +
        "\n\n### Collectives\n" + collective_summary()
    )


if __name__ == "__main__":
    print(run())
