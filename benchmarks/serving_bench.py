"""Serving-scheduler benchmark: TWA admission vs naive-rescan baseline,
the multi-tenant QoS section, and the device-resident megastep section
(tokens/s + host-sync count vs K — the scan-fused engine loop).

The paper's Figure-1 quantity transplanted to the engine: scheduler work per
iteration as the backlog deepens.  The TWA scheduler re-examines only poked
buckets (O(slots freed)); the baseline re-scans the whole backlog
(O(backlog)) — the global-spinning analogue.  Measured with the toy model so
the numbers isolate SCHEDULER cost, not model compute.

The QoS section saturates the engine with ≥3 tenants of unequal weights and
reports per-tenant admission shares measured while every tenant still has
backlog (the saturation window); shares must land within 10% of the
configured weights (weighted stride replenishment of the admission
subsystem).
"""

from __future__ import annotations

import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.admission.functional_qos import make_qos, qos_round, qos_take
from repro.serving.scheduler import ContinuousBatchingEngine, Request


def _quick() -> bool:
    """CI wall-time guard (``benchmarks.run --quick`` / REPRO_BENCH_QUICK=1):
    skip the K=128 megastep sweep and shrink the mixed-length workload."""
    return os.environ.get("REPRO_BENCH_QUICK", "0") == "1"


def run_engine(n_requests: int, n_slots: int, twa: bool):
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots)
    if not twa:
        # baseline: force every backlog entry to be re-examined each step
        orig = eng._admit_ready

        def rescan_all():
            for r in eng.backlog:
                r.fast = True  # "woken" every iteration — global rescan
            return orig()

        eng._admit_ready = rescan_all
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=4) for i in range(n_requests)]
    eng.submit_batch(reqs)
    t0 = time.time()
    steps = 0
    while eng.stats.finished < n_requests and steps < 10 * n_requests:
        eng.step(lambda lg: np.zeros(len(lg), np.int64))
        steps += 1
    dt = time.time() - t0
    s = eng.stats
    return {"checks": s.backlog_scans + s.backlog_skipped * 0,  # examined rows
            "skipped": s.backlog_skipped, "steps": steps, "wall_s": dt,
            "finished": s.finished}


def run_multitenant(weights: dict[str, float], n_per_tenant: int = 150,
                    n_slots: int = 6) -> dict:
    """Saturate the engine with equal per-tenant arrival streams; measure
    admission shares while EVERY tenant still has backlog."""
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots,
        tenants=weights)
    reqs, rid = [], 0
    for _ in range(n_per_tenant):
        for t in weights:
            reqs.append(Request(rid=rid, prompt=[1], max_new_tokens=3,
                                tenant_id=t))
            rid += 1
    eng.submit_batch(reqs)
    steps = 0
    while all(d > 0 for d in eng._tenant_live) and steps < 100 * len(reqs):
        eng.step(lambda lg: np.zeros(len(lg), np.int64))
        steps += 1
    total = sum(eng.tenant_admitted.values())
    wsum = sum(weights.values())
    return {
        "steps": steps,
        "admitted": dict(eng.tenant_admitted),
        "shares": {t: eng.tenant_admitted[t] / total for t in weights},
        "target": {t: w / wsum for t, w in weights.items()},
        "scans": eng.stats.backlog_scans,
        "skipped": eng.stats.backlog_skipped,
    }


def run_qos_scaling(metrics: dict | None = None) -> list[str]:
    """qos_round throughput vs backlog depth N: the new blocked-prefix
    reference path vs the retained O(N²) pairwise-rank baseline (jitted,
    CPU wall time).  The crossover the ISSUE asks to demonstrate: at
    N ≥ 1k the O(N·S/block) path must win and the gap must widen with N."""
    lines = ["", "== QoS admission round: blocked-prefix vs O(N²) rank =="]
    lines.append(f"{'N':>6} {'blocked ms':>11} {'pairwise ms':>12} {'speedup':>8}")
    S, MU = 8, 64
    rng = np.random.default_rng(1)
    for n in (256, 1024, 4096):
        state = make_qos(np.linspace(1, 4, S).astype(np.float32),
                         table_size=1024)
        ids = jnp.asarray(rng.integers(0, S, n), jnp.int32)
        state, tk, _, _ = qos_take(state, ids, jnp.ones(n, bool))
        alive = jnp.asarray(rng.random(n) > 0.2)
        dls = jnp.asarray(np.where(rng.random(n) > 0.5,
                                   rng.uniform(0, 2, n), np.inf), jnp.float32)

        def bench(pairwise: bool) -> float:
            fn = jax.jit(lambda s, i, t, a, d, pw=pairwise: qos_round(
                s, i, t, a, d, 1.0, 32, MU, pairwise_rank=pw))
            out = fn(state, ids, tk, alive, dls)  # compile + warm
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            iters = 5
            for _ in range(iters):
                jax.block_until_ready(fn(state, ids, tk, alive, dls))
            return (time.perf_counter() - t0) / iters * 1e3

        ms_new, ms_old = bench(False), bench(True)
        lines.append(f"{n:>6} {ms_new:>11.2f} {ms_old:>12.2f} "
                     f"{ms_old / ms_new:>7.1f}×")
        if metrics is not None:
            metrics.setdefault("qos_round_scaling", {})[str(n)] = {
                "blocked_ms": round(ms_new, 3), "pairwise_ms": round(ms_old, 3),
                "speedup": round(ms_old / ms_new, 2)}
    lines.append("→ the pairwise path grows O(N²) while the blocked-prefix "
                 "path stays O(N·S/block); same admissions (oracle-equal)")
    return lines


def run_megastep(metrics: dict | None = None) -> list[str]:
    """Device-resident megastep vs the per-step host loop: tokens/s and
    host-sync count vs K ∈ {1, 8, 32, 128}.  The per-step path pays one
    host round-trip per decoded token (queue bookkeeping + dispatch +
    host sampling); megastep(K) pays one launch + one drain per K tokens.
    The ISSUE acceptance: ≥5× tokens/s at K=32, host syncs K → 1 per
    round."""
    from repro.serving.engine_state import zero_token_fn

    weights = {"gold": 3.0, "bronze": 1.0}
    n_req, n_slots, max_new = 192, 8, 8

    def make():
        eng = ContinuousBatchingEngine(
            lambda active: np.zeros(len(active)), lambda r: None, n_slots,
            tenants=weights)
        reqs = [Request(rid=i, prompt=[1], max_new_tokens=max_new,
                        tenant_id=("gold", "bronze")[i % 2])
                for i in range(n_req)]
        eng.submit_batch(reqs)
        return eng, reqs

    def drain_steps():
        eng, reqs = make()
        t0 = time.perf_counter()
        while eng.stats.finished < n_req:
            eng.step(lambda lg: np.zeros(len(lg), np.int64))
        dt = time.perf_counter() - t0
        return eng, reqs, dt

    def drain_mega(K):
        eng, reqs = make()
        t0 = time.perf_counter()
        while eng.stats.finished < n_req:
            eng.megastep(K, token_fn=zero_token_fn)
        dt = time.perf_counter() - t0
        return eng, reqs, dt

    lines = ["", "== Device-resident megastep vs per-step host loop =="]
    lines.append(f"{'path':>10} {'tokens/s':>10} {'host syncs':>11} "
                 f"{'wall s':>8} {'speedup':>8}")
    eng, reqs, dt = drain_steps()
    tokens = sum(len(r.out_tokens) for r in reqs)
    base_tps = tokens / dt
    lines.append(f"{'per-step':>10} {base_tps:>10.0f} "
                 f"{eng.stats.host_syncs:>11} {dt:>8.2f} {'1.0×':>8}")
    if metrics is not None:
        metrics["megastep"] = {"per_step": {
            "tok_s": round(base_tps, 1), "host_syncs": eng.stats.host_syncs,
            "wall_s": round(dt, 4), "tokens": tokens}}
    speedup32 = 0.0
    for K in ((1, 8, 32) if _quick() else (1, 8, 32, 128)):
        drain_mega(K)  # warm the (B, K) executables out of the timing
        eng, reqs, dt = drain_mega(K)
        tokens = sum(len(r.out_tokens) for r in reqs)
        assert eng.stats.finished == n_req
        tps = tokens / dt
        sp = tps / base_tps
        if K == 32:
            speedup32 = sp
        lines.append(f"{'K=' + str(K):>10} {tps:>10.0f} "
                     f"{eng.stats.host_syncs:>11} {dt:>8.2f} {sp:>7.1f}×")
        if metrics is not None:
            metrics["megastep"][f"K{K}"] = {
                "tok_s": round(tps, 1), "host_syncs": eng.stats.host_syncs,
                "wall_s": round(dt, 4), "speedup": round(sp, 2)}
        # host syncs drop from one per round to one per K rounds
        assert eng.stats.host_syncs <= eng.stats.steps // K + 2, (
            K, eng.stats.host_syncs, eng.stats.steps)
    assert speedup32 >= 5.0, \
        f"megastep K=32 only {speedup32:.1f}× over per-step (<5×)"
    lines.append("→ the scan-fused engine stops being host-bound: K host "
                 "round-trips per K tokens become 1; the crossover vs the "
                 "per-step path sits at small K")
    return lines


def run_paged_pool(metrics: dict | None = None) -> list[str]:
    """Mixed-length workload at EQUAL HBM budget: dense per-slot ring
    caches (S_d slots × C tokens reserved up front, full-C attention every
    step) vs the block-paged pool (the SAME S_d·C pooled tokens as NB×BS
    blocks behind the TWA block semaphore, multi-resource admission).

    The mixed-length mix is short-dominated with a rare near-capacity
    tail (lengths log-uniform in [8, C/4], plus a few drawn log-uniform
    in [3C/4, C]): the engine must SUPPORT the tail, so every dense ring
    is provisioned at C, while the pool's worst-case reservations follow
    the realized lengths — ~C/mean_reservation× more concurrent
    sequences per HBM byte.  The ISSUE acceptance: ≥2× tokens/s for the
    pool at the full size, and streamed-KV bytes that scale with LIVE
    blocks (∝ live tokens) instead of ∝ S·C."""
    from repro.serving.engine_state import (
        make_paged_attn_model,
        make_paged_pool_model,
        paged_attn_admit_fn,
        paged_attn_token_fn,
        paged_pool_admit_fn,
        paged_pool_token_fn,
    )

    C, BS = 128, 8
    S_dense, S_paged = 4, 20
    NB = S_dense * C // BS                      # equal HBM: NB·BS = S_d·C
    d, vocab, plen = 8, 50, 4
    K = 16
    n_req, n_long = (128, 2) if _quick() else (256, 4)
    rng = np.random.default_rng(4)
    lens = np.concatenate([
        np.exp(rng.uniform(math.log(8), math.log(32), n_req - n_long)),
        np.exp(rng.uniform(math.log(96), math.log(C), n_long))])
    lens = np.clip(np.round(lens).astype(int), 8, C)
    rng.shuffle(lens)

    def make_reqs():
        rng_p = np.random.default_rng(7)
        return [Request(rid=i, prompt=list(rng_p.integers(1, vocab, plen)),
                        max_new_tokens=int(L - plen), tenant_id="a")
                for i, L in enumerate(lens)]

    def drain(paged: bool):
        if paged:
            eng = ContinuousBatchingEngine(
                lambda a: None, lambda r: None, S_paged, tenants={"a": 1.0},
                kv_pool=(NB, BS, C // BS))
            eng.megastep_model = make_paged_pool_model(
                jax.random.PRNGKey(0), vocab=vocab, d=d, num_blocks=NB,
                block_size=BS)
            tok_fn, adm_fn = paged_pool_token_fn, paged_pool_admit_fn
        else:
            eng = ContinuousBatchingEngine(
                lambda a: None, lambda r: None, S_dense, tenants={"a": 1.0})
            eng.megastep_model = make_paged_attn_model(
                jax.random.PRNGKey(0), vocab=vocab, d=d, n_slots=S_dense,
                capacity=C)
            tok_fn, adm_fn = paged_attn_token_fn, paged_attn_admit_fn
        reqs = make_reqs()
        eng.submit_batch(reqs)
        t0 = time.perf_counter()
        while eng.stats.finished < n_req:
            eng.megastep(K, token_fn=tok_fn, admit_fn=adm_fn)
        return eng, reqs, time.perf_counter() - t0

    drain(False)  # warm the executables out of the timing
    runs_d = [drain(False) for _ in range(3)]
    drain(True)
    runs_p = [drain(True) for _ in range(3)]
    eng_d, reqs_d, dt_d = min(runs_d, key=lambda t: t[2])  # least-noise wall
    eng_p, reqs_p, dt_p = min(runs_p, key=lambda t: t[2])
    tokens = int(sum(len(r.out_tokens) for r in reqs_d))
    assert tokens == sum(len(r.out_tokens) for r in reqs_p)
    tps_d, tps_p = tokens / dt_d, tokens / dt_p
    speedup = tps_p / tps_d

    # streamed-KV tokens per decoded token.  Dense: the full C-token
    # reservation every step — what the CPU toy (and any dense path)
    # executes.  Paged: ceil(live/BS) blocks — the RAGGED KERNEL's HBM
    # access pattern (`kernels/paged_decode`, pl.when tail-block skip),
    # reported analytically; the CPU toy's vectorized in-scan gather
    # reads the worst-case table width instead (XLA gathers are dense),
    # so this column models the TPU path, not the timed CPU attention.
    str_d = str_p = 0
    for L in lens:
        for e in range(int(L) - plen):
            str_d += C
            str_p += -(-(plen + e + 1) // BS) * BS
    lines = ["", "== Block-paged KV pool vs dense rings (equal HBM budget) ==",
             f"   C={C} BS={BS}: {S_dense} dense slots × {C} vs "
             f"{NB} pooled blocks (≤{S_paged} slots), K={K}; lengths ~ "
             f"logU[8, {C // 4}] + {n_long}×logU[{3 * C // 4}, {C}], "
             f"mean {lens.mean():.0f}"]
    lines.append(f"{'path':>12} {'tokens/s':>10} {'rounds':>7} "
                 f"{'KV tok/decode':>14} {'speedup':>8}")
    lines.append(f"{'dense ring':>12} {tps_d:>10.0f} {eng_d.stats.steps:>7} "
                 f"{str_d / tokens:>14.0f} {'1.0×':>8}")
    lines.append(f"{'paged pool':>12} {tps_p:>10.0f} {eng_p.stats.steps:>7} "
                 f"{str_p / tokens:>14.0f} {speedup:>7.1f}×")
    lines.append(f"→ same HBM, {speedup:.1f}× tokens/s "
                 f"({eng_d.stats.steps / eng_p.stats.steps:.1f}× fewer engine "
                 f"rounds): short sequences stop paying long-sequence "
                 f"reservation; streamed KV {str_d / str_p:.1f}× smaller "
                 f"(∝ live blocks — the ragged kernel's HBM model)")
    floor = 1.5 if _quick() else 2.0  # reduced-size CI smoke tolerates noise
    assert speedup >= floor, \
        f"paged pool only {speedup:.2f}× over dense ring (<{floor}×)"
    if metrics is not None:
        metrics["paged_pool"] = {
            "dense": {"tok_s": round(tps_d, 1), "rounds": eng_d.stats.steps,
                      "kv_tokens_per_decode": round(str_d / tokens, 1)},
            "paged": {"tok_s": round(tps_p, 1), "rounds": eng_p.stats.steps,
                      "kv_tokens_per_decode": round(str_p / tokens, 1)},
            "speedup": round(speedup, 2),
            "rounds_ratio": round(eng_d.stats.steps / eng_p.stats.steps, 2),
            "streamed_kv_ratio": round(str_d / str_p, 2),
            "mean_len": round(float(lens.mean()), 1),
            "hbm_tokens": S_dense * C,
        }
    return lines


def run_longprompt(metrics: dict | None = None) -> list[str]:
    """Long-prompt mixed workload at EQUAL HBM: worst-case up-front block
    admission (PR 4) vs continuous chunked prefill with incremental
    allocation (PR 5) over the SAME pool.

    The up-front mode must reserve ``⌈(plen+max_new)/BS⌉`` blocks before a
    sequence may start, so long prompts + long decodes cap concurrency at
    ``NB / worst_case`` and park the reservation's decode tail unwritten
    for the whole sequence lifetime.  The chunked mode admits on
    first-chunk demand, takes blocks exactly at block-boundary crossings
    (parking on the block semaphore's waiting array when the pool runs
    dry), so live blocks track WRITTEN tokens — more concurrent sequences
    per HBM byte, fewer engine rounds, higher pool utilization.  The
    ISSUE acceptance: chunked ≥ up-front tokens/s AND higher mean pool
    utilization at equal HBM (asserted)."""
    from repro.serving.engine_state import (
        make_chunked_prefill_token_fn,
        make_paged_pool_model,
        paged_pool_admit_fn,
        paged_pool_token_fn,
    )

    NB, BS, MB = 64, 8, 26
    S, K, CHUNK, BUDGET = 8, 16, 24, 96
    d, vocab = 8, 50
    n_req = 16 if _quick() else 24
    rng = np.random.default_rng(9)
    plens = rng.integers(40, 80, n_req)  # 5-10 blocks of prompt
    mxs = rng.integers(64, 128, n_req)   # + a LONG decode tail (≤ MB·BS)
    chunked_tok_fn = make_chunked_prefill_token_fn(CHUNK)

    def make_reqs():
        rng_p = np.random.default_rng(11)
        return [Request(rid=i, prompt=list(rng_p.integers(1, vocab, plens[i])),
                        max_new_tokens=int(mxs[i]), tenant_id="a")
                for i in range(n_req)]

    def drain(chunked: bool):
        eng = ContinuousBatchingEngine(
            lambda a: None, lambda r: None, S, tenants={"a": 1.0},
            kv_pool=(NB, BS, MB), prompt_cap=128,
            chunked_prefill=(CHUNK, BUDGET) if chunked else None)
        eng.megastep_model = make_paged_pool_model(
            jax.random.PRNGKey(0), vocab=vocab, d=d, num_blocks=NB,
            block_size=BS)
        tok_fn = chunked_tok_fn if chunked else paged_pool_token_fn
        adm_fn = None if chunked else paged_pool_admit_fn
        reqs = make_reqs()
        eng.submit_batch(reqs)
        utils = []
        t0 = time.perf_counter()
        while eng.stats.finished < n_req:
            eng.megastep(K, token_fn=tok_fn, admit_fn=adm_fn)
            utils.append(eng.telemetry()["pool_utilization"])
        dt = time.perf_counter() - t0
        # drop the drain tail (emptying pool) from the utilization mean
        live = [u for u in utils if u > 0] or [0.0]
        return eng, reqs, dt, sum(live) / len(live)

    drain(False)  # warm the executables out of the timing
    runs_u = [drain(False) for _ in range(3)]
    drain(True)
    runs_c = [drain(True) for _ in range(3)]
    eng_u, reqs_u, dt_u, util_u = min(runs_u, key=lambda t: t[2])
    eng_c, reqs_c, dt_c, util_c = min(runs_c, key=lambda t: t[2])
    tokens = int(sum(len(r.out_tokens) for r in reqs_u))
    assert tokens == sum(len(r.out_tokens) for r in reqs_c)
    tps_u, tps_c = tokens / dt_u, tokens / dt_c
    speedup = tps_c / tps_u
    lines = ["", "== Continuous chunked prefill vs worst-case up-front "
                 "(equal HBM) ==",
             f"   pool {NB}×{BS} ({NB * BS} tokens), {S} slots, K={K}; "
             f"prompts {plens.min()}–{plens.max()} tok + decode "
             f"{mxs.min()}–{mxs.max()} tok; chunk={CHUNK}, "
             f"budget={BUDGET}/round"]
    lines.append(f"{'path':>10} {'tokens/s':>9} {'rounds':>7} "
                 f"{'pool util':>10} {'stalls':>7} {'speedup':>8}")
    lines.append(f"{'up-front':>10} {tps_u:>9.0f} {eng_u.stats.steps:>7} "
                 f"{util_u:>9.1%} {'—':>7} {'1.0×':>8}")
    lines.append(f"{'chunked':>10} {tps_c:>9.0f} {eng_c.stats.steps:>7} "
                 f"{util_c:>9.1%} {eng_c.stats.kv_block_stalls:>7} "
                 f"{speedup:>7.1f}×")
    lines.append(f"→ incremental allocation keeps live blocks ∝ written "
                 f"tokens: {util_c / max(util_u, 1e-9):.1f}× higher pool "
                 f"utilization and {speedup:.1f}× tokens/s at equal HBM; "
                 f"mid-sequence block stalls park on the waiting array "
                 f"({eng_c.stats.kv_block_stalls} slot-rounds) instead of "
                 f"deadlocking or over-reserving")
    assert speedup >= (1.05 if _quick() else 1.15), \
        f"chunked prefill only {speedup:.2f}× over up-front"
    assert util_c > util_u, (util_c, util_u)
    if metrics is not None:
        metrics["chunked_prefill"] = {
            "upfront": {"tok_s": round(tps_u, 1),
                        "rounds": eng_u.stats.steps,
                        "pool_util": round(util_u, 4)},
            "chunked": {"tok_s": round(tps_c, 1),
                        "rounds": eng_c.stats.steps,
                        "pool_util": round(util_c, 4),
                        "stalls": eng_c.stats.kv_block_stalls,
                        "prefill_chunks": eng_c.stats.prefill_chunks},
            "speedup": round(speedup, 2),
            "util_ratio": round(util_c / max(util_u, 1e-9), 2),
            "hbm_tokens": NB * BS,
        }
    return lines


def run_prefix_cache(metrics: dict | None = None) -> list[str]:
    """Repeated-prefix workload (PR 9): every request opens with the SAME
    224-token system prompt; the second half repeats earlier prompts
    verbatim (retry/regenerate traffic).  Sharing OFF prefills all 232
    tokens per request; sharing ON attaches the cached prefix by incref
    (zero prefill flops, zero new HBM for the covered blocks) and
    prefills only the 9-token divergent tail — full-prompt repeats skip
    prefill entirely (`prefix_hits`).  Decode lengths are staggered so
    lifetimes overlap (weak cache entries live exactly as long as their
    blocks) — the steady-state shape of real shared-prefix traffic.
    Same pool both ways (equal HBM).  The ISSUE acceptance: ≥2×
    tokens/s, lower TTFT, and a lower live-block footprint (shared
    blocks counted once) at equal HBM."""
    from repro.obs import EngineObs
    from repro.serving.engine_state import (
        make_chunked_prefill_token_fn,
        make_paged_pool_model,
    )

    NB, BS, MB = 256, 8, 32
    S, K, CHUNK, BUDGET = 8, 16, 24, 48
    d, vocab, PRE, TAIL = 8, 50, 224, 9
    DT = 0.25
    n_req = 16 if _quick() else 48
    # seed chosen so the shared chain's 28 direct-mapped homes are
    # pairwise distinct (a same-sweep collision would permanently cut
    # the chain at the colliding depth — misses, not corruption, but
    # this bench measures the sharing win, not the collision rate)
    sysp = list(np.random.default_rng(4).integers(1, vocab, PRE))
    rng = np.random.default_rng(3)
    mxs = [int(m) for m in rng.integers(3, 8, n_req)]  # staggered decodes
    prompts = []
    for i in range(n_req):
        if i >= S and i % 2 == 1:
            # verbatim repeat of a recently-admitted prompt: its holder
            # is still decoding, so the full-prompt entry is live
            prompts.append(list(prompts[i - 2]))
        else:
            prompts.append(sysp + list(rng.integers(1, vocab, TAIL)))
    tok_fn = make_chunked_prefill_token_fn(CHUNK)

    def drain(prefix: int):
        clk = [0.0]
        obs = EngineObs(ttft_target=24 * DT)
        eng = ContinuousBatchingEngine(
            lambda a: None, lambda r: None, S, tenants={"a": 1.0},
            clock=lambda: clk[0], kv_pool=(NB, BS, MB), prompt_cap=256,
            chunked_prefill=(CHUNK, BUDGET), prefix_cache=prefix, obs=obs)
        eng.megastep_model = make_paged_pool_model(
            jax.random.PRNGKey(0), vocab=vocab, d=d, num_blocks=NB,
            block_size=BS)
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=mxs[i],
                        tenant_id="a") for i, p in enumerate(prompts)]
        eng.submit_batch(reqs)
        utils, pf_tok = [], 0
        t0 = time.perf_counter()
        while eng.stats.finished < n_req:
            base = eng._round_no
            nows = np.asarray([(base + k) * DT for k in range(K)],
                              np.float32)
            clk[0] = 0.0
            eng.megastep(K, token_fn=tok_fn, nows=nows)
            clk[0] = float(nows[-1]) + DT
            utils.append(eng.telemetry()["pool_utilization"])
            pf_tok += sum(s["prefill_tokens"] for s in eng._last_samples)
        dt = time.perf_counter() - t0
        live = [u for u in utils if u > 0] or [0.0]
        s = obs.summary()["tenants"]["a"]
        return eng, reqs, dt, sum(live) / len(live), s["ttft"]["p50"], pf_tok

    drain(0)  # warm the executables out of the timing
    runs_b = [drain(0) for _ in range(5)]
    drain(1024)
    runs_s = [drain(1024) for _ in range(5)]
    eng_b, reqs_b, dt_b, util_b, ttft_b, pf_b = min(runs_b,
                                                    key=lambda t: t[2])
    eng_s, reqs_s, dt_s, util_s, ttft_s, pf_s = min(runs_s,
                                                    key=lambda t: t[2])
    tokens = int(sum(len(r.out_tokens) for r in reqs_b))
    assert tokens == sum(len(r.out_tokens) for r in reqs_s)
    tps_b, tps_s = tokens / dt_b, tokens / dt_s
    speedup = tps_s / tps_b
    lines = ["", "== Refcounted prefix cache: shared system prompt "
                 "(equal HBM) ==",
             f"   pool {NB}×{BS}, {S} slots, K={K}; {n_req} requests = "
             f"{PRE}-tok shared prefix + {TAIL}-tok tail (+3–7 decode), "
             f"verbatim repeats after the first wave; chunk={CHUNK}, "
             f"budget={BUDGET}/round"]
    lines.append(f"{'path':>10} {'tokens/s':>9} {'rounds':>7} "
                 f"{'prefill tok':>12} {'ttft p50':>9} {'pool util':>10} "
                 f"{'speedup':>8}")
    lines.append(f"{'no share':>10} {tps_b:>9.0f} {eng_b.stats.steps:>7} "
                 f"{pf_b:>12} {ttft_b:>9.2f} {util_b:>9.1%} {'1.0×':>8}")
    lines.append(f"{'sharing':>10} {tps_s:>9.0f} {eng_s.stats.steps:>7} "
                 f"{pf_s:>12} {ttft_s:>9.2f} {util_s:>9.1%} "
                 f"{speedup:>7.1f}×")
    lines.append(f"→ {eng_s.stats.prefix_hits} full-prompt hits prefilled "
                 f"ZERO tokens; chained attaches cut prefill flops "
                 f"{pf_b / max(pf_s, 1):.1f}× and rounds "
                 f"{eng_b.stats.steps / eng_s.stats.steps:.1f}×; "
                 f"{eng_s.stats.cow_copies} copy-on-write takes kept "
                 f"shared blocks immutable; shared blocks count once, so "
                 f"the same pool sustains more concurrent requests "
                 f"(util {util_s:.1%} vs {util_b:.1%})")
    floor = 1.4 if _quick() else 2.0
    assert speedup >= floor, \
        f"prefix sharing only {speedup:.2f}× over no-sharing (<{floor}×)"
    assert eng_s.stats.prefix_hits > 0, "no full-prompt cache hit engaged"
    assert pf_s < pf_b / 2, (pf_s, pf_b)
    assert eng_b.stats.prefix_hits == 0
    if metrics is not None:
        metrics["prefix_cache"] = {
            "no_share": {"tok_s": round(tps_b, 1),
                         "rounds": eng_b.stats.steps,
                         "prefill_tokens": int(pf_b),
                         "ttft_p50": round(float(ttft_b), 4),
                         "pool_util": round(util_b, 4)},
            "sharing": {"tok_s": round(tps_s, 1),
                        "rounds": eng_s.stats.steps,
                        "prefill_tokens": int(pf_s),
                        "ttft_p50": round(float(ttft_s), 4),
                        "pool_util": round(util_s, 4),
                        "prefix_hits": int(eng_s.stats.prefix_hits),
                        "cow_copies": int(eng_s.stats.cow_copies)},
            "speedup": round(speedup, 2),
            "prefill_flop_ratio": round(pf_b / max(pf_s, 1), 2),
            "hbm_tokens": NB * BS,
        }
    return lines


def run_slo(metrics: dict | None = None) -> list[str]:
    """Per-tenant SLO report off the PR-6 observability layer: a
    deterministic virtual-clock workload decodes through megastep with an
    `repro.obs.EngineObs` attached; TTFT/TPOT quantiles come from the
    in-scan TelemetryRing-clocked request lifecycle (zero added host
    syncs) and land in the JSON report."""
    from repro.obs import EngineObs
    from repro.serving.engine_state import rid_token_fn

    DT = 0.25
    weights = {"gold": 4.0, "silver": 2.0, "bronze": 1.0}
    clk = [0.0]
    obs = EngineObs(ttft_target=8 * DT)
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, 6,
        tenants=weights, use_kernel=True, clock=lambda: clk[0], obs=obs)
    rng = np.random.default_rng(0)
    names = list(weights)
    n_req = 60 if _quick() else 180
    reqs = [Request(rid=i, prompt=[1 + int(rng.integers(0, 9))],
                    max_new_tokens=1 + int(rng.integers(0, 6)),
                    tenant_id=names[int(rng.integers(0, len(names)))],
                    deadline=(DT * int(rng.integers(4, 40))
                              if rng.random() < 0.3 else None))
            for i in range(n_req)]
    eng.submit_batch(reqs)
    K = 16
    rounds = 0
    while (eng.active or int(eng._tenant_live.sum())) and rounds < 30 * K:
        nows = np.asarray([(rounds + k) * DT for k in range(K)], np.float32)
        clk[0] = 0.0
        eng.megastep(K, token_fn=rid_token_fn, nows=nows)
        clk[0] = (rounds + K) * DT
        rounds += K
    s = obs.summary()
    lines = ["", "== Per-tenant SLO (obs layer over the telemetry ring) ==",
             f"   {n_req} requests, {len(weights)} tenants, virtual clock "
             f"DT={DT}, TTFT target {8 * DT} — {eng.stats.host_syncs} host "
             f"syncs for {rounds} rounds"]
    lines.append(obs.render_table())
    resolved = sum(t["submitted"] for t in s["tenants"].values())
    assert resolved == n_req, (resolved, n_req)
    # megastep observability stayed one-sync-per-launch
    assert eng.stats.host_syncs == rounds // K
    lines.append(f"→ p50/p99 TTFT/TPOT per tenant from log-bucketed "
                 f"streaming histograms; deadline misses count against "
                 f"attainment ({sum(t['expired'] for t in s['tenants'].values())}"
                 f" expired)")
    if metrics is not None:
        def _r(x):  # NaN (no samples) → None: keep the report strict JSON
            return None if math.isnan(x) else round(x, 4)

        metrics["slo"] = {
            "ttft_target": 8 * DT,
            "rounds": rounds,
            "host_syncs": eng.stats.host_syncs,
            "tenants": {
                t: {"attainment": _r(r["attainment"]),
                    "finished": r["finished"], "expired": r["expired"],
                    "ttft_p50": _r(r["ttft"]["p50"]),
                    "ttft_p99": _r(r["ttft"]["p99"]),
                    "tpot_p50": _r(r["tpot"]["p50"]),
                    "tpot_p99": _r(r["tpot"]["p99"])}
                for t, r in s["tenants"].items()},
        }
    return lines


def run_resilience(metrics: dict | None = None) -> list[str]:
    """PR-7 robustness section: (a) sentinel overhead — the in-scan
    health bitmask + stuck-slot watchdog ride the megastep scan, so
    megastep(K=32) tokens/s with the watchdog armed must stay within a
    few percent of the sentinel-free drain (ISSUE acceptance: ≤5% vs
    the PR-6 baseline — compare `megastep.K32.tok_s` across BENCH_PR
    snapshots for the cross-PR view); (b) a seeded chaos drain whose
    recovery-event counters land in the JSON trajectory."""
    from repro.serving.engine_state import zero_token_fn

    weights = {"gold": 3.0, "bronze": 1.0}
    n_req, n_slots, max_new, K = 192, 8, 8, 32

    def drain(watchdog):
        eng = ContinuousBatchingEngine(
            lambda active: np.zeros(len(active)), lambda r: None, n_slots,
            tenants=weights, watchdog=watchdog)
        reqs = [Request(rid=i, prompt=[1], max_new_tokens=max_new,
                        tenant_id=("gold", "bronze")[i % 2])
                for i in range(n_req)]
        eng.submit_batch(reqs)
        t0 = time.perf_counter()
        while eng.stats.finished < n_req:
            eng.megastep(K, token_fn=zero_token_fn)
        dt = time.perf_counter() - t0
        return sum(len(r.out_tokens) for r in reqs) / dt

    lines = ["", "== Self-healing: sentinel overhead + chaos recovery =="]
    trials = 2 if _quick() else 3
    drain(0), drain(8)  # warm both executables out of the timing
    tps_off = max(drain(0) for _ in range(trials))
    tps_on = max(drain(8) for _ in range(trials))
    ratio = tps_on / tps_off
    lines.append(f"{'sentinels':>12} {'tok/s':>10} {'vs off':>8}")
    lines.append(f"{'off':>12} {tps_off:>10.0f} {'1.000':>8}")
    lines.append(f"{'watchdog=8':>12} {tps_on:>10.0f} {ratio:>8.3f}")
    assert ratio >= 0.85, \
        f"in-scan sentinels cost {(1 - ratio):.1%} megastep throughput"
    lines.append("→ the health bitmask folds into the scan's existing "
                 "telemetry pass: no extra host syncs, overhead within "
                 "measurement noise")

    from repro.resilience import CAPACITY_KINDS, FaultPlan, ResilientEngine
    from repro.serving.engine_state import rid_token_fn

    clk = [0.0]
    eng = ContinuousBatchingEngine(
        lambda a: np.array([r.rid * 1000 + len(r.out_tokens) for r in a],
                           np.int64),
        lambda r: None, 4, tenants={"gold": 2.0, "bronze": 1.0},
        clock=lambda: clk[0], kv_pool=(16, 4), chunked_prefill=(5, 9, 16),
        prompt_cap=32, watchdog=4)
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=[1 + i % 7] * int(rng.integers(1, 19)),
                    max_new_tokens=1 + int(rng.integers(0, 10)),
                    tenant_id=("gold", "bronze")[int(rng.integers(0, 2))])
            for i in range(12)]
    plan = FaultPlan.random(7, rounds=24, n_faults=4, kinds=CAPACITY_KINDS)
    rz = ResilientEngine(eng, plan=plan, react_every=2, retry_budget=2,
                         seed=7)
    eng.submit_batch(reqs)
    spent = 0
    while spent < 240 and not (all(r.done_event.is_set() for r in reqs)
                               and not rz._retryq and not eng.active):
        base = eng._round_no
        rz.megastep(8, token_fn=rid_token_fn,
                    nows=np.asarray([(base + k) * 0.25 for k in range(8)],
                                    np.float32))
        spent += 8
    audit = rz.audit()
    rec = rz.telemetry()["recovery"]
    assert all(r.done_event.is_set() for r in reqs) and audit["ok"]
    injected = sum(1 for e in rz.events
                   if e["action"] == "inject" and e["applied"])
    lines.append(f"→ chaos drain (seed 7): {len(reqs)} requests through "
                 f"{injected} injected faults in {spent} rounds; recovery: "
                 + ", ".join(f"{k}={v}" for k, v in rec.items() if v))
    if metrics is not None:
        metrics["resilience"] = {
            "sentinel_overhead": {
                "tok_s_off": round(tps_off, 1),
                "tok_s_watchdog": round(tps_on, 1),
                "ratio": round(ratio, 4)},
            "chaos": {"requests": len(reqs), "injected": injected,
                      "rounds": spent, "audit_ok": audit["ok"],
                      "recovery": rec},
        }
    return lines


def run_cluster(metrics: dict | None = None) -> list[str]:
    """Cluster fabric (PR 8): tokens/s and p99 TTFT over 4 replica
    engines behind the replica router, fault-free vs one replica KILLED
    mid-megastep — the cost of detection + exactly-once migration is a
    TTFT tail and a modest throughput dip, never a lost or doubled
    request."""
    from repro.obs import EngineObs, FlightRecorder, aggregate
    from repro.resilience.faults import REPLICA_KILL, FaultEvent, FaultPlan
    from repro.serving.router import toy_cluster, toy_workload

    n_req = 24 if _quick() else 48
    lines = ["", "== Cluster fabric: replica kill vs fault-free (4 replicas) ==",
             f"{'scenario':>12} {'done':>5} {'shed':>5} {'rounds':>7} "
             f"{'tok/s':>9} {'p99 ttft':>9} {'migr':>5} {'wall s':>7}"]
    out = {}
    for name, plan in (
            ("fault-free", None),
            ("1 killed", FaultPlan(seed=0, events=(
                FaultEvent(round=2, kind=REPLICA_KILL, arg=1, delta=2),))),
    ):
        # one recorder + flight window per replica: the fleet aggregator
        # and the dead replica's post-mortem bundle both need them
        r = toy_cluster(4, seed=0, plan=plan, capacity=4,
                        obs=lambda: EngineObs(
                            flight=FlightRecorder(capacity=16)))
        r.submit_batch(toy_workload(n_req, seed=9))
        t0 = time.perf_counter()
        rep = r.run(max_rounds=300)
        wall = time.perf_counter() - t0
        toks = sum(len(t) for t in r.completed.values())
        ttfts = sorted(cr.ttft for cr in r.requests.values()
                       if cr.ttft is not None)
        p99 = float(np.percentile(ttfts, 99)) if ttfts else float("nan")
        vt = rep["rounds"] * 1.0  # virtual seconds (inner_k·dt per round)
        st = rep["stats"]
        assert rep["lease_audit"]["ok"], rep["lease_audit"]["violations"]
        assert st["completed"] + len(rep["shed"]) == n_req
        lines.append(f"{name:>12} {st['completed']:>5} {len(rep['shed']):>5} "
                     f"{rep['rounds']:>7} {toks / vt:>9.1f} {p99:>9.2f} "
                     f"{st['migrated']:>5} {wall:>7.2f}")
        key = name.replace(" ", "_").replace("-", "_")
        out[key] = {
            "completed": st["completed"], "shed": len(rep["shed"]),
            "rounds": rep["rounds"], "tok_per_vs": round(toks / vt, 2),
            "p99_ttft": round(p99, 3), "migrated": st["migrated"],
            "wall_s": round(wall, 3)}

        # PR 10: fleet SLO aggregation + per-replica lease headroom +
        # migration latency + stitched-span accounting off the trace
        fab = r.fabric_telemetry()
        fleet = aggregate([rp.eng._obs for rp in r.replicas], router=fab)
        spans = r.cluster_spans()
        migrated_spans = sum(1 for s in spans.values()
                             if s["migrations"] > 0)
        bundles = sum(len(rp.eng._obs.flight.bundles)
                      for rp in r.replicas)
        c = fleet["cluster"]
        mlat = fab["migration_latency"]
        lines.append(
            f"{'':>12} fleet ttft p50/p99={c['ttft']['p50']:.2f}/"
            f"{c['ttft']['p99']:.2f} tpot p50={c['tpot']['p50']:.2f} "
            f"spans={len(spans)} migrated_spans={migrated_spans} "
            f"mig_lat p50={mlat['p50'] if mlat['count'] else 0:.2f} "
            f"flight_bundles={bundles}")
        out[key]["fleet"] = {
            "ttft_p50": c["ttft"]["p50"], "ttft_p99": c["ttft"]["p99"],
            "tpot_p50": c["tpot"]["p50"],
            "attainment": c["attainment"],
            "lease_headroom": {str(i): v["headroom"]
                               for i, v in fab["leases"].items()},
            "migration_latency_p50": (mlat["p50"] if mlat["count"]
                                      else None),
            "spans": len(spans), "migrated_spans": migrated_spans,
            "flight_bundles": bundles}
    lines.append("→ virtual-time tokens/s and the TTFT tail absorb the "
                 "detection TTL + migration backoff; the lease audit stays "
                 "clean in both scenarios (no unit lost with the replica); "
                 "every request leaves ONE stitched span and a dead "
                 "replica leaves a flight bundle")
    if metrics is not None:
        metrics["cluster"] = out
    return lines


def run(metrics: dict | None = None) -> str:
    lines = ["== Serving scheduler: TWA buckets vs global rescan ==",
             f"{'backlog':>8} {'mode':>8} {'examined':>10} {'skipped':>10} {'wall s':>8}"]
    for n in (64, 256, 1024):
        for twa in (True, False):
            r = run_engine(n, 8, twa)
            assert r["finished"] == n
            lines.append(f"{n:>8} {'twa' if twa else 'rescan':>8} "
                         f"{r['checks']:>10} {r['skipped']:>10} {r['wall_s']:>8.2f}")
            if metrics is not None:
                metrics.setdefault("scheduler", {})[
                    f"{'twa' if twa else 'rescan'}_{n}"] = {
                        "examined": r["checks"], "skipped": r["skipped"],
                        "wall_s": round(r["wall_s"], 4)}
    lines.append("→ examined rows stay ~O(completions) under TWA; the rescan "
                 "baseline grows O(backlog × steps) — the paper's global-"
                 "spinning pathology at the scheduler level")

    weights = {"gold": 4.0, "silver": 2.0, "bronze": 1.0}
    q = run_multitenant(weights)
    lines.append("")
    lines.append("== Multi-tenant QoS admission (saturation window) ==")
    lines.append(f"{'tenant':>8} {'weight':>7} {'admitted':>9} {'share':>7} "
                 f"{'target':>7} {'Δ':>7}")
    worst = 0.0
    for t, w in weights.items():
        share, target = q["shares"][t], q["target"][t]
        rel = abs(share - target) / target
        worst = max(worst, rel)
        lines.append(f"{t:>8} {w:>7.1f} {q['admitted'][t]:>9} {share:>7.3f} "
                     f"{target:>7.3f} {rel:>6.1%}")
    assert worst < 0.10, f"admission shares off weights by {worst:.1%} (>10%)"
    lines.append(f"→ shares within 10% of weights (worst Δ {worst:.1%}); "
                 f"scheduler examined {q['scans']} rows, skipped {q['skipped']} "
                 "(per-tenant TWA bucket gating)")
    if metrics is not None:
        metrics["multitenant"] = q

    lines.extend(run_qos_scaling(metrics))
    lines.extend(run_megastep(metrics))
    lines.extend(run_paged_pool(metrics))
    lines.extend(run_longprompt(metrics))
    lines.extend(run_prefix_cache(metrics))
    lines.extend(run_slo(metrics))
    lines.extend(run_resilience(metrics))
    lines.extend(run_cluster(metrics))
    return "\n".join(lines)


if __name__ == "__main__":
    print(run())
