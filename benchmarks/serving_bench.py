"""Serving-scheduler benchmark: TWA admission vs naive-rescan baseline.

The paper's Figure-1 quantity transplanted to the engine: scheduler work per
iteration as the backlog deepens.  The TWA scheduler re-examines only poked
buckets (O(slots freed)); the baseline re-scans the whole backlog
(O(backlog)) — the global-spinning analogue.  Measured with the toy model so
the numbers isolate SCHEDULER cost, not model compute.
"""

from __future__ import annotations

import time

import numpy as np

from repro.serving.scheduler import ContinuousBatchingEngine, Request


def run_engine(n_requests: int, n_slots: int, twa: bool):
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots)
    if not twa:
        # baseline: force every backlog entry to be re-examined each step
        orig = eng._admit_ready

        def rescan_all():
            for r in eng.backlog:
                r.fast = True  # "woken" every iteration — global rescan
            return orig()

        eng._admit_ready = rescan_all
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=4) for i in range(n_requests)]
    eng.submit_batch(reqs)
    t0 = time.time()
    steps = 0
    while eng.stats.finished < n_requests and steps < 10 * n_requests:
        eng.step(lambda lg: np.zeros(len(lg), np.int64))
        steps += 1
    dt = time.time() - t0
    s = eng.stats
    return {"checks": s.backlog_scans + s.backlog_skipped * 0,  # examined rows
            "skipped": s.backlog_skipped, "steps": steps, "wall_s": dt,
            "finished": s.finished}


def run() -> str:
    lines = ["== Serving scheduler: TWA buckets vs global rescan ==",
             f"{'backlog':>8} {'mode':>8} {'examined':>10} {'skipped':>10} {'wall s':>8}"]
    for n in (64, 256, 1024):
        for twa in (True, False):
            r = run_engine(n, 8, twa)
            assert r["finished"] == n
            lines.append(f"{n:>8} {'twa' if twa else 'rescan':>8} "
                         f"{r['checks']:>10} {r['skipped']:>10} {r['wall_s']:>8.2f}")
    lines.append("→ examined rows stay ~O(completions) under TWA; the rescan "
                 "baseline grows O(backlog × steps) — the paper's global-"
                 "spinning pathology at the scheduler level")
    return "\n".join(lines)


if __name__ == "__main__":
    print(run())
