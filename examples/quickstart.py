"""Quickstart: the paper's semaphores in 60 seconds.

  1. L1 (threads): TicketSemaphore vs TWASemaphore vs the non-FIFO pthread
     baseline guarding a critical section — FIFO order demonstrated.
  2. L2 (in-graph): the batched functional semaphore admitting requests
     FCFS inside a jitted step, with TWA-bucket selective re-checks.
  3. The coherence-model sweep reproducing the shape of the paper's Fig. 1.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PthreadLikeSemaphore,
    TicketSemaphore,
    TWASemaphore,
    make_sema,
    poll,
    post_batch,
    take_batch,
    sweep,
)

# ---------------------------------------------------------------- 1. L1 ----
print("== L1: host-thread semaphores ==")
for name, sem in [
    ("ticket (broadcast parking)", TicketSemaphore(1, waiting="broadcast")),
    ("TWA    (futex buckets)    ", TWASemaphore(1, waiting="futex")),
    ("pthread (non-FIFO)        ", PthreadLikeSemaphore(1)),
]:
    counter = {"x": 0}

    def worker():
        for _ in range(200):
            sem.take()
            counter["x"] += 1  # protected by the semaphore (count=1)
            sem.post()

    ts = [threading.Thread(target=worker) for _ in range(8)]
    t0 = time.time()
    [t.start() for t in ts]
    [t.join() for t in ts]
    print(f"  {name}: x={counter['x']} (expected 1600)  {time.time() - t0:.3f}s")

# ---------------------------------------------------------------- 2. L2 ----
print("\n== L2: batched in-graph semaphore (FCFS admission) ==")
s = make_sema(count=3, table_size=64)
s, tickets, admitted, buckets = take_batch(s, jnp.ones(6, bool))
print(f"  6 arrivals, 3 slots → tickets={np.asarray(tickets)} "
      f"admitted={np.asarray(admitted).astype(int)}")
s = post_batch(s, 2)  # two slots free up
print(f"  post(2) → now admitted={np.asarray(poll(s, tickets)).astype(int)} "
      f"(strictly FIFO: tickets 3,4 enabled, 5 still waits)")

# --------------------------------------------------------------- 3. Fig1 ----
print("\n== Fig.1-shaped sweep (coherence-cost model) ==")
res = sweep(thread_counts=(1, 2, 4, 8, 16, 32, 64))
print(f"  {'T':>4} {'ticket':>12} {'TWA':>12} {'pthread':>12}  (ops/sec)")
for i, t in enumerate((1, 2, 4, 8, 16, 32, 64)):
    print(f"  {t:>4} {res['ticket'][i].throughput_per_sec:>12.0f} "
          f"{res['twa'][i].throughput_per_sec:>12.0f} "
          f"{res['pthread'][i].throughput_per_sec:>12.0f}")
print("  → Ticket decays with global spinning; TWA stays flat (the paper's claim)")
