"""Multi-tenant QoS serving example: three tenants with 4:2:1 weights share
one continuous-batching engine, demonstrating

  * weighted-FCFS admission — under saturation, admission shares track the
    configured weights (stride-scheduled grant replenishment over per-tenant
    functional TWA semaphores);
  * the tombstone protocol — a batch of requests carrying an admission
    deadline that passes while queued is expired (tickets tombstoned) and
    never blocks later live requests;
  * the waiting-array effect at tenant granularity — the scheduler
    re-examines only tenant queues whose buckets were poked (skip ratio
    printed).

Run:  PYTHONPATH=src python examples/serve_multitenant.py [--kernel]
                                                          [--megastep]

``--kernel`` (or ``ContinuousBatchingEngine(..., use_kernel=True)``) routes
the whole tenant round — expire → weighted replenish → FCFS admit →
reclaim — through the fused Pallas pass (`kernels.qos_admission`,
interpret mode off-TPU) instead of the host queue walk: same admission
semantics (bit-exact vs `functional_qos.qos_round`), one vectorized
in-graph sweep per engine step.

Device-resident engine (``--megastep``): the whole engine LOOP moves
in-graph — ``eng.megastep(K)`` runs K fused rounds (deadline preemption →
QoS admission → TWA slot assignment → decode+sample → completion) as one
jitted `lax.scan` over a donated on-device EngineState pytree
(`serving.engine_state`), so the host syncs once per K decoded tokens
instead of once per token.  Semantics are property-tested identical to K
sequential ``step()`` calls (tests/test_megastep.py); throughput vs K is
measured in `benchmarks/serving_bench.py` (≥5× at K=32 on CPU).  Custom
in-graph models plug in via ``token_fn``/``admit_fn`` — see
`engine_state.paged_attn_token_fn` for paged decode attention with
in-graph prompt prefill.
"""

import sys
import time

import numpy as np

from repro.serving.scheduler import ContinuousBatchingEngine, Request

WEIGHTS = {"gold": 4.0, "silver": 2.0, "bronze": 1.0}


def main(use_kernel: bool = False, use_megastep: bool = False, K: int = 16):
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots=6,
        tenants=WEIGHTS, use_kernel=use_kernel)
    reqs, rid = [], 0
    for _ in range(120):
        for t in WEIGHTS:
            reqs.append(Request(rid=rid, prompt=[1], max_new_tokens=3,
                                tenant_id=t))
            rid += 1
    # one bronze burst with a deadline that will expire in the queue
    doomed = [Request(rid=rid + i, prompt=[1], max_new_tokens=3,
                      tenant_id="bronze", deadline=time.monotonic() + 0.02)
              for i in range(8)]
    eng.submit_batch(reqs + doomed)
    time.sleep(0.05)  # the doomed deadlines pass while queued

    sat_admitted = None
    steps = 0
    total = len(reqs) + len(doomed)
    while eng.stats.finished + eng.stats.expired < total and steps < 50 * total:
        if sat_admitted is None and not all(d > 0 for d in eng._tenant_live):
            sat_admitted = dict(eng.tenant_admitted)  # saturation window ends
        if use_megastep:
            eng.megastep(K)  # one host sync per K decode rounds
            steps += K
        else:
            eng.step(lambda lg: np.zeros(len(lg), np.int64))
            steps += 1

    tel = eng.telemetry()
    wsum = sum(WEIGHTS.values())
    stot = sum(sat_admitted.values())
    print(f"served {eng.stats.finished} requests in {steps} engine rounds "
          f"({eng.stats.host_syncs} host syncs); "
          f"{eng.stats.expired} deadline-expired (tombstoned)")
    print(f"{'tenant':>8} {'weight':>7} {'sat-share':>10} {'target':>7} "
          f"{'expired':>8}")
    for t, w in WEIGHTS.items():
        share = sat_admitted[t] / stot
        print(f"{t:>8} {w:>7.1f} {share:>10.3f} {w / wsum:>7.3f} "
              f"{tel['tenants'][t]['expired']:>8}")
        assert abs(share - w / wsum) / (w / wsum) < 0.15
    s = eng.stats
    print(f"scheduler examined {s.backlog_scans} rows, skipped "
          f"{s.backlog_skipped} (TWA bucket gating at tenant granularity)")
    assert eng.stats.expired == 8 and eng.stats.finished == len(reqs)
    assert tel["queue_depth"] == 0
    return eng


if __name__ == "__main__":
    main(use_kernel="--kernel" in sys.argv[1:],
         use_megastep="--megastep" in sys.argv[1:])
    print("[example] weighted-FCFS admission + tombstoned deadlines OK")
