"""Multi-tenant QoS serving example: three tenants with 4:2:1 weights share
one continuous-batching engine, demonstrating

  * weighted-FCFS admission — under saturation, admission shares track the
    configured weights (stride-scheduled grant replenishment over per-tenant
    functional TWA semaphores);
  * the tombstone protocol — a batch of requests carrying an admission
    deadline that passes while queued is expired (tickets tombstoned) and
    never blocks later live requests;
  * the waiting-array effect at tenant granularity — the scheduler
    re-examines only tenant queues whose buckets were poked (skip ratio
    printed).

Run:  PYTHONPATH=src python examples/serve_multitenant.py [--kernel]
                                                          [--megastep]
                                                          [--paged]
                                                          [--shared-prefix]
                                                          [--chaos [seed]]
                                                          [--cluster [seed]]
                                                          [--trace]

Prefix caching (``--shared-prefix``): the three tenants serve
retry/regenerate traffic over one long shared system prompt through a
refcounted block pool with a weak prefix index (``prefix_cache=E``):
after the first wave prefills and registers, later admissions attach the
covered blocks by incref — zero prefill flops, zero new HBM — and
verbatim full-prompt repeats skip prefill entirely (``prefix_hits``);
divergence inside a shared tail block triggers a copy-on-write take
(``cow_copies``).  The run prints the sharing gauges and proves the
refcounted conservation identity drains back to a full pool; with
``--trace`` the per-round ``blocks_shared`` gauge and the accumulated
prefix counters land in the SLO table footer.

Cluster fabric (``--cluster [seed]``): four replica engines behind
`repro.serving.router.ReplicaRouter` — per-replica in-flight capacity as
a cluster-level TWA lease, a heartbeat reaper, exactly-once request
migration off dead replicas (warm takeover from the last checkpoint
snapshot where available), a per-replica circuit breaker — driven
through a seeded cluster FaultPlan (replica kill mid-megastep, KV
partition with zombie fencing, straggler, leaked lease) and verified
bit-identical against a fault-free twin.

Self-healing (``--chaos [seed]``): drives a chunked block-paged engine
through a seeded `repro.resilience.FaultPlan` (dropped pokes, counter
corruption, wedged slots, one mid-run crash) under the recovery ladder —
watchdog quarantine + backoff requeue, block-table audit, snapshot
restore + deterministic replay — printing every ladder action and the
exit conservation audit.  See src/repro/resilience/README.md.

Observability (``--trace``): attaches a `repro.obs.EngineObs` with a
streaming `JsonlSink` — every engine round (host ``step()`` or megastep
ring drain, identical records either way) appends one JSON line to
``trace_multitenant.jsonl`` with the per-round gauges and the TWA
waiting-array probes (bucket-occupancy histogram, per-tenant credit,
poke-window slack), and resolved requests feed per-tenant TTFT/TPOT
distributions.  At exit the rendered SLO-attainment table prints.
Attaching the observer adds zero host syncs (see src/repro/obs/README.md).

``--kernel`` (or ``ContinuousBatchingEngine(..., use_kernel=True)``) routes
the whole tenant round — expire → weighted replenish → FCFS admit →
reclaim — through the fused Pallas pass (`kernels.qos_admission`,
interpret mode off-TPU) instead of the host queue walk: same admission
semantics (bit-exact vs `functional_qos.qos_round`), one vectorized
in-graph sweep per engine step.

Device-resident engine (``--megastep``): the whole engine LOOP moves
in-graph — ``eng.megastep(K)`` runs K fused rounds (deadline preemption →
QoS admission → TWA slot assignment → decode+sample → completion) as one
jitted `lax.scan` over a donated on-device EngineState pytree
(`serving.engine_state`), so the host syncs once per K decoded tokens
instead of once per token.  Semantics are property-tested identical to K
sequential ``step()`` calls (tests/test_megastep.py); throughput vs K is
measured in `benchmarks/serving_bench.py` (≥5× at K=32 on CPU).  Custom
in-graph models plug in via ``token_fn``/``admit_fn`` — see
`engine_state.paged_attn_token_fn` for paged decode attention with
in-graph prompt prefill.

Continuous chunked prefill (``chunked_prefill=(chunk, budget)``): long
prompts stream through the engine in per-round chunks with INCREMENTAL
block allocation (admission on first-chunk demand, waiting-array parks on
pool exhaustion) — see examples/serve_longprompt.py for the dedicated
demo and serving/engine_state.py for the stall/park policy.

Block-paged KV pool (``--paged``): the engine additionally owns a shared
pool of KV blocks behind a TWA **block** semaphore
(``kv_pool=(num_blocks, block_size)``): admission gates on BOTH a free
slot and each request's worst-case block demand in strict FCFS order
(multi-resource admission), decode attention streams only the blocks a
sequence actually holds (`engine_state.paged_pool_token_fn`;
`kernels/paged_decode` on TPU), preemption/completion post the blocks
back, and `telemetry()` exposes the kv_blocks_free / kv_blocks_live
gauges.  Mixed-length throughput vs the dense rings at equal HBM is
measured in `benchmarks/serving_bench.py` (≥2× tokens/s on the CPU toy).
"""

import sys
import time

import numpy as np

from repro.serving.scheduler import ContinuousBatchingEngine, Request

WEIGHTS = {"gold": 4.0, "silver": 2.0, "bronze": 1.0}


def _make_obs(trace: bool, path: str, ttft_target: float):
    """Build the ``--trace`` observer (or None): streaming JSONL sink +
    per-tenant SLO accumulators with a rolling-median companion trace."""
    if not trace:
        return None
    from repro.obs import EngineObs, JsonlSink

    return EngineObs([JsonlSink(path)], ttft_target=ttft_target,
                     smooth_window=9)


def _finish_trace(obs, path: str, recovery: dict | None = None) -> None:
    if obs is None:
        return
    n = obs.sinks[0].emitted
    obs.close()
    print(f"[trace] {n} per-round records streamed to {path}")
    print(obs.render_table(recovery=recovery))


def main_paged(K: int = 16, trace: bool = False) -> None:
    """Mixed-length multi-tenant serving over the block-paged pool: 64
    blocks × 8 tokens serve up to 12 slots (vs 4 dense rings at the same
    HBM), short requests pay short-sequence cost, and the block gauges
    drain back to full."""
    import jax

    from repro.serving.engine_state import (
        make_paged_pool_model,
        paged_pool_admit_fn,
        paged_pool_token_fn,
    )

    NB, BS, vocab = 64, 8, 50
    trace_path = "trace_multitenant.jsonl"
    obs = _make_obs(trace, trace_path, ttft_target=30.0)
    eng = ContinuousBatchingEngine(
        lambda a: None, lambda r: None, n_slots=12, tenants=WEIGHTS,
        kv_pool=(NB, BS, 16), obs=obs)
    eng.megastep_model = make_paged_pool_model(
        jax.random.PRNGKey(0), vocab=vocab, d=16, num_blocks=NB,
        block_size=BS)
    rng = np.random.default_rng(0)
    reqs, rid = [], 0
    for _ in range(30):
        for t in WEIGHTS:
            reqs.append(Request(
                rid=rid, prompt=list(rng.integers(1, vocab, 4)),
                max_new_tokens=int(rng.integers(4, 28)), tenant_id=t))
            rid += 1
    eng.submit_batch(reqs)
    peak_live = 0
    while eng.stats.finished < len(reqs):
        eng.megastep(K, token_fn=paged_pool_token_fn,
                     admit_fn=paged_pool_admit_fn)
        peak_live = max(peak_live, eng.telemetry()["kv_blocks_live"])
    tel = eng.telemetry()
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[paged] served {eng.stats.finished} requests / {toks} tokens in "
          f"{eng.stats.host_syncs} host syncs; peak {peak_live}/{NB} blocks "
          f"reserved, now free={tel['kv_blocks_free']} "
          f"live={tel['kv_blocks_live']}")
    # chunked-prefill gauges ride along on every paged engine (all zero in
    # worst-case up-front mode; see examples/serve_longprompt.py for them
    # moving): pool_utilization = blocks actually holding tokens / pool,
    # kv_block_stalls / parked_slots = waiting-array block parks,
    # prefill_chunks = chunk writes
    print(f"[paged] gauges: pool_utilization={tel['pool_utilization']:.0%} "
          f"kv_block_stalls={tel['kv_block_stalls']} "
          f"parked_slots={tel['parked_slots']} "
          f"prefill_chunks={tel['prefill_chunks']}")
    assert tel["kv_blocks_free"] == NB and tel["kv_blocks_live"] == 0
    assert tel["parked_slots"] == 0 and tel["pool_utilization"] == 0.0
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    _finish_trace(obs, trace_path)
    print("[example] block-paged KV pool admission + decode OK")


def main_shared_prefix(K: int = 16, trace: bool = False) -> None:
    """Prefix-cache demo (``--shared-prefix``): retry/regenerate traffic
    over one 96-token shared system prompt.  The first wave prefills and
    registers the prefix chain; every later admission attaches the
    covered blocks by incref (zero prefill flops, zero new HBM) and
    prefills only its divergent tail — verbatim repeats skip prefill
    entirely.  Exit asserts: sharing engaged (hits/COW observed), every
    stream completed, and the refcounted conservation identity
    ``free + live(refcnt>0) = NB`` drained back to a full pool."""
    import jax

    from repro.serving.engine_state import (
        make_chunked_prefill_token_fn,
        make_paged_pool_model,
    )

    NB, BS, MB, vocab = 128, 8, 32, 50
    CHUNK, BUDGET = 24, 48
    trace_path = "trace_multitenant.jsonl"
    obs = _make_obs(trace, trace_path, ttft_target=30.0)
    eng = ContinuousBatchingEngine(
        lambda a: None, lambda r: None, n_slots=8, tenants=WEIGHTS,
        kv_pool=(NB, BS, MB), prompt_cap=256,
        chunked_prefill=(CHUNK, BUDGET), prefix_cache=1024, obs=obs)
    eng.megastep_model = make_paged_pool_model(
        jax.random.PRNGKey(0), vocab=vocab, d=16, num_blocks=NB,
        block_size=BS)
    rng = np.random.default_rng(7)
    sysp = list(rng.integers(1, vocab, 96))  # the shared system prompt
    names = list(WEIGHTS)
    reqs = []
    for i in range(30):
        if i >= 8 and i % 2 == 1:
            prompt = list(reqs[i - 2].prompt)  # verbatim regenerate
        else:
            prompt = sysp + list(rng.integers(1, vocab, 5))
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=3 + int(rng.integers(0, 5)),
                            tenant_id=names[i % len(names)]))
    eng.submit_batch(reqs)
    shared_peak = 0
    tok_fn = make_chunked_prefill_token_fn(CHUNK)
    while eng.stats.finished < len(reqs):
        eng.megastep(K, token_fn=tok_fn)
        shared_peak = max(shared_peak, eng.telemetry()["blocks_shared"])
    tel = eng.telemetry()
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[prefix] served {len(reqs)} requests / {toks} tokens over a "
          f"{len(sysp)}-token shared prefix in {eng.stats.host_syncs} "
          f"host syncs")
    print(f"[prefix] gauges: prefix_hits={tel['prefix_hits']} "
          f"cow_copies={tel['cow_copies']} peak blocks_shared={shared_peak} "
          f"prefill_chunks={tel['prefill_chunks']}")
    assert eng.stats.prefix_hits + eng.stats.cow_copies > 0, \
        "prefix sharing never engaged"
    assert shared_peak > 0
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    # refcounted conservation drained: every shared block decref'd to 0
    assert tel["kv_blocks_free"] == NB and tel["blocks_shared"] == 0
    _finish_trace(obs, trace_path)
    print("[example] refcounted prefix cache + copy-on-write sharing OK")


def main_chaos(seed: int = 0, K: int = 8, trace: bool = False) -> None:
    """Self-healing demo (``--chaos [seed]``): a chunked block-paged
    engine with the in-scan sentinels armed is driven through a seeded
    `repro.resilience.FaultPlan` — dropped wake pokes, counter
    corruption, wedged slots, plus one mid-run crash — by the
    `ResilientEngine` recovery ladder: watchdog quarantine + jittered
    requeue, block-table audit-and-rebuild, snapshot/restore with
    deterministic replay.  Every request still drains and the exit
    audit proves conservation at all three semaphore granularities."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    from repro.resilience import CAPACITY_KINDS, FaultPlan, ResilientEngine
    from repro.serving.engine_state import rid_token_fn

    clk = [0.0]
    trace_path = "trace_multitenant.jsonl"
    obs = _make_obs(trace, trace_path, ttft_target=30.0)
    eng = ContinuousBatchingEngine(
        lambda a: np.array([r.rid * 1000 + len(r.out_tokens)
                            for r in a], np.int64),
        lambda r: None, n_slots=4, tenants={"gold": 2.0, "bronze": 1.0},
        clock=lambda: clk[0], kv_pool=(16, 4), chunked_prefill=(5, 9, 16),
        prompt_cap=32, watchdog=4, obs=obs)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, prompt=[1 + i % 7] * int(rng.integers(1, 19)),
                    max_new_tokens=1 + int(rng.integers(0, 10)),
                    tenant_id=("gold", "bronze")[int(rng.integers(0, 2))])
            for i in range(12)]
    plan = FaultPlan.random(seed, rounds=24, n_faults=4,
                            kinds=CAPACITY_KINDS).with_crash(11)
    with tempfile.TemporaryDirectory() as ckdir:
        rz = ResilientEngine(eng, plan=plan, react_every=2, retry_budget=2,
                             seed=seed, ckpt=CheckpointManager(ckdir),
                             snapshot_every=8)
        eng.submit_batch(reqs)
        spent = 0
        while spent < 240 and not (
                all(r.done_event.is_set() for r in reqs)
                and not rz._retryq and not eng.active):
            base = eng._round_no
            rz.megastep(K, token_fn=rid_token_fn,
                        nows=np.asarray([(base + k) * 0.25
                                         for k in range(K)], np.float32))
            spent += K
        print(f"[chaos] plan seed={seed}: "
              + ", ".join(f"r{e.round}:{e.kind}" for e in plan.events))
        for e in rz.events:
            extra = {k: v for k, v in e.items()
                     if k not in ("round", "action")}
            print(f"[chaos]   round {e['round']:>3} {e['action']:<12} "
                  f"{extra if extra else ''}")
        rec = rz.telemetry()["recovery"]
        print(f"[chaos] recovery counters: {rec}")
        audit = rz.audit()
        assert all(r.done_event.is_set() for r in reqs), \
            "chaos run failed to drain"
        assert audit["ok"], audit["violations"]
        _finish_trace(obs, trace_path, rec)
        print("[example] fault injection + recovery ladder OK "
              f"(drained {len(reqs)} requests under {len(plan.events)} "
              "injected faults, exit audit clean)")


def main_cluster(seed: int = 0, trace: bool = False,
                 perfetto: str | None = None) -> None:
    """Fault-tolerant multi-engine fabric (``--cluster [seed]``): four
    replica engines behind `repro.serving.router.ReplicaRouter` — each
    replica's in-flight capacity a cluster-level `DistributedTicketLease`
    (grant − ticket = headroom routes the bind), a `LeaseReaper` freeing
    what dead holders leak — driven through a seeded CLUSTER FaultPlan:
    one replica killed mid-megastep, one stalled behind a KV partition
    (declared dead, keeps running as a zombie, fenced when the partition
    heals), one straggler, one leaked lease ticket.  Every accepted
    request completes exactly once or is shed with a recorded reason;
    surviving token streams are bit-identical to a fault-free run; the
    final grant sequence of every lease is clean."""
    from repro.resilience import FaultPlan
    from repro.serving.router import toy_cluster, toy_workload

    trace_path = "trace_multitenant.jsonl"
    plan = FaultPlan.cluster(seed + 3, rounds=10, n_replicas=4)
    work = toy_workload(12, seed=seed + 2)

    baseline = toy_cluster(4, seed=seed)
    baseline.submit_batch(toy_workload(12, seed=seed + 2))
    baseline.run(max_rounds=150)

    obs = _make_obs(trace, trace_path, ttft_target=30.0)
    router = toy_cluster(4, seed=seed, plan=plan, standby=True,
                         snapshot_every=4, obs=obs)
    router.submit_batch(work)
    report = router.run(max_rounds=150)

    print(f"[cluster] plan seed={seed + 3}: "
          + ", ".join(f"r{e.round}:{e.kind}@{e.arg}" for e in plan.events))
    for e in router.events:
        if e["action"] in ("inject", "replica_killed", "replica_dead",
                           "warm_takeover", "fenced", "shed", "reap",
                           "duplicate_suppressed"):
            extra = {k: v for k, v in e.items()
                     if k not in ("round", "action")}
            print(f"[cluster]   round {e['round']:>3} {e['action']:<20} "
                  f"{extra}")
    st = report["stats"]
    print(f"[cluster] completed={st['completed']} shed={report['shed']} "
          f"migrated={st['migrated']} adopted={st['adopted']} "
          f"dupes_suppressed={st['duplicates_suppressed']} "
          f"orphans_reaped={st['orphans_reaped']}")
    done = set(router.completed)
    shed = set(report["shed"])
    assert done | shed == {cr.rid for cr in work} and not (done & shed), \
        "exactly-once violated"
    for rid in done & set(baseline.completed):
        assert router.completed[rid] == baseline.completed[rid], \
            f"rid {rid} stream diverged from fault-free run"
    assert report["lease_audit"]["ok"], report["lease_audit"]["violations"]
    assert all(a["ok"] for a in report["engine_audits"].values())
    recovery = None
    if obs is not None:
        recovery = {}
        for rep in router.replicas:
            for k, v in rep.eng.telemetry()["recovery"].items():
                recovery[k] = recovery.get(k, 0) + v
    _finish_trace(obs, trace_path, recovery)
    # PR 10: stitched cluster spans + fleet aggregation + Perfetto export
    from repro.obs import aggregate, render_cluster_table, write_perfetto

    spans = router.cluster_spans()
    migrated = sum(1 for s in spans.values() if s["migrations"] > 0)
    print(f"[trace] {len(spans)} stitched spans "
          f"({migrated} with a migration segment, "
          f"{sum(s['duplicates_suppressed'] for s in spans.values())} "
          f"duplicate terminals suppressed)")
    # toy_cluster may share ONE recorder across replicas — dedupe so the
    # fleet reduction doesn't count the same accumulator four times
    seen: set[int] = set()
    per_rep = []
    for rep in router.replicas:
        o = rep.eng._obs
        if o is not None and id(o) not in seen:
            seen.add(id(o))
            per_rep.append(o)
    if per_rep:
        print(render_cluster_table(
            aggregate(per_rep, router=router.fabric_telemetry())))
    if perfetto:
        write_perfetto(perfetto, spans)
        print(f"[trace] Chrome-trace JSON written to {perfetto} "
              f"(load in chrome://tracing or ui.perfetto.dev)")
    print("[example] replica router + reaper + exactly-once migration OK "
          f"({st['replicas_dead']} replicas died, "
          f"{st['successors']} warm successors, streams bit-identical)")


def main(use_kernel: bool = False, use_megastep: bool = False, K: int = 16,
         trace: bool = False):
    trace_path = "trace_multitenant.jsonl"
    obs = _make_obs(trace, trace_path, ttft_target=30.0)
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots=6,
        tenants=WEIGHTS, use_kernel=use_kernel, obs=obs)
    reqs, rid = [], 0
    for _ in range(120):
        for t in WEIGHTS:
            reqs.append(Request(rid=rid, prompt=[1], max_new_tokens=3,
                                tenant_id=t))
            rid += 1
    # one bronze burst with a deadline that will expire in the queue
    doomed = [Request(rid=rid + i, prompt=[1], max_new_tokens=3,
                      tenant_id="bronze", deadline=time.monotonic() + 0.02)
              for i in range(8)]
    eng.submit_batch(reqs + doomed)
    time.sleep(0.05)  # the doomed deadlines pass while queued

    sat_admitted = None
    steps = 0
    total = len(reqs) + len(doomed)
    while eng.stats.finished + eng.stats.expired < total and steps < 50 * total:
        if sat_admitted is None and not all(d > 0 for d in eng._tenant_live):
            sat_admitted = dict(eng.tenant_admitted)  # saturation window ends
        if use_megastep:
            eng.megastep(K)  # one host sync per K decode rounds
            steps += K
        else:
            eng.step(lambda lg: np.zeros(len(lg), np.int64))
            steps += 1

    tel = eng.telemetry()
    wsum = sum(WEIGHTS.values())
    stot = sum(sat_admitted.values())
    print(f"served {eng.stats.finished} requests in {steps} engine rounds "
          f"({eng.stats.host_syncs} host syncs); "
          f"{eng.stats.expired} deadline-expired (tombstoned)")
    print(f"{'tenant':>8} {'weight':>7} {'sat-share':>10} {'target':>7} "
          f"{'expired':>8}")
    for t, w in WEIGHTS.items():
        share = sat_admitted[t] / stot
        print(f"{t:>8} {w:>7.1f} {share:>10.3f} {w / wsum:>7.3f} "
              f"{tel['tenants'][t]['expired']:>8}")
        assert abs(share - w / wsum) / (w / wsum) < 0.15
    s = eng.stats
    print(f"scheduler examined {s.backlog_scans} rows, skipped "
          f"{s.backlog_skipped} (TWA bucket gating at tenant granularity)")
    assert eng.stats.expired == 8 and eng.stats.finished == len(reqs)
    assert tel["queue_depth"] == 0
    if obs is not None:
        # one record per engine round regardless of host-step vs megastep
        assert obs.rounds == steps, (obs.rounds, steps)
        assert tel["slo"]["tenants"]["bronze"]["expired"] == 8
    _finish_trace(obs, trace_path)
    return eng


if __name__ == "__main__":
    trace = "--trace" in sys.argv[1:]
    if "--chaos" in sys.argv[1:]:
        rest = sys.argv[sys.argv.index("--chaos") + 1:]
        main_chaos(seed=int(rest[0]) if rest and rest[0].isdigit() else 0,
                   trace=trace)
    elif "--cluster" in sys.argv[1:]:
        rest = sys.argv[sys.argv.index("--cluster") + 1:]
        pf = None
        if "--perfetto" in sys.argv[1:]:
            after = sys.argv[sys.argv.index("--perfetto") + 1:]
            pf = (after[0] if after and not after[0].startswith("--")
                  else "trace_cluster.json")
        main_cluster(seed=int(rest[0]) if rest and rest[0].isdigit() else 0,
                     trace=trace, perfetto=pf)
    elif "--paged" in sys.argv[1:]:
        main_paged(trace=trace)
    elif "--shared-prefix" in sys.argv[1:]:
        main_shared_prefix(trace=trace)
    else:
        main(use_kernel="--kernel" in sys.argv[1:],
             use_megastep="--megastep" in sys.argv[1:], trace=trace)
        print("[example] weighted-FCFS admission + tombstoned deadlines OK")
