"""End-to-end training example: ~100M-param model, a few hundred steps on CPU
through the full stack (TWA-buffered data pipeline, AdamW, async checkpoints,
coordinator heartbeats), with a mid-run checkpoint-restore to prove
fault-tolerant resume.

Run:  PYTHONPATH=src python examples/train_lm.py  [--steps 300]
(A ~100M model on 1 CPU core takes a while; --small trains the reduced config
used by CI instead.)
"""

import argparse
import dataclasses
import sys
import tempfile

from repro.configs.registry import get_smoke_config
from repro.launch.train import main as train_main


def run(steps: int, small: bool):
    with tempfile.TemporaryDirectory() as ckdir:
        argv = [
            "--arch", "qwen2-0.5b", "--smoke",
            "--steps", str(steps), "--batch", "8", "--seq", "128",
            "--ckpt-dir", ckdir, "--ckpt-every", str(max(10, steps // 4)),
        ]
        if not small:
            # scale the reduced config up to ~100M params via the registry's
            # overrides: wider model, deeper stack
            import repro.configs.base as base
            import repro.configs.registry as registry

            orig = registry.get_smoke_config

            def bigger(arch):
                return dataclasses.replace(
                    orig(arch), d_model=512, n_heads=8, n_kv_heads=2,
                    head_dim=64, d_ff=2048, num_units=12, vocab=32768,
                    name=arch + "-100m",
                )

            registry.get_smoke_config = bigger
        losses = train_main(argv)
        # resume from the checkpoint and train a few more steps
        print("\n[example] simulating restart: --resume from checkpoint")
        more = train_main(argv + ["--resume", "--steps", str(steps + 10)])
        assert more[-1] < losses[0], "resumed training regressed"
        print("[example] resume OK — loss continued from checkpointed state")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--small", action="store_true")
    args = ap.parse_args()
    run(args.steps, args.small)
