"""Continuous chunked-prefill example: long prompts through the scanned
megastep with incremental block allocation.

Prompts here are 4–8× longer than the one-shot in-graph prefill previously
handled (PR 4 truncated at the default ``prompt_cap=32`` because the whole
prompt had to land in one admission-round scatter AND its worst-case block
demand had to be free up front).  With ``chunked_prefill=(chunk, budget)``:

  * admission gates on FIRST-CHUNK demand only (behind the no-deadlock
    reserved headroom + the pipelined commitment watermark), so a 256-token
    prompt is admitted the moment 1–2 blocks fit — not when 40 do;
  * every scanned engine round co-schedules prompt chunks with decode,
    Sarathi-style, under the per-round prefill token budget — long prompts
    stream through ``megastep(K)`` with ZERO extra host syncs;
  * blocks are taken from the TWA block semaphore exactly at block-boundary
    crossings; on pool exhaustion the slot PARKS on the semaphore's waiting
    array and resumes FCFS when releases poke its bucket (the stall/park
    policy documented in serving/engine_state.py);
  * `telemetry()` shows the incremental lifecycle: pool_utilization tracks
    WRITTEN blocks (vs the up-front mode's reserved-but-unwritten tails),
    kv_block_stalls / parked_slots count the waiting-array parks, and
    prefill_chunks counts the chunk writes.

Run:  PYTHONPATH=src python examples/serve_longprompt.py

Throughput/utilization vs the worst-case up-front mode at equal HBM is
measured in `benchmarks/serving_bench.py` (chunked-prefill section;
BENCH_PR5.json).  The Pallas kernel for real models (ragged blockwise
flash-prefill, causal chunk attention + in-pass pool writeback) is
`kernels/paged_prefill` — oracle-bit-exact, see tests/test_paged_prefill.py.
"""

import numpy as np


def main(K: int = 24) -> None:
    import jax

    from repro.serving.engine_state import (
        make_chunked_prefill_token_fn,
        make_paged_pool_model,
    )
    from repro.serving.scheduler import ContinuousBatchingEngine, Request

    NB, BS, MB = 128, 8, 40          # 1024 pooled tokens
    CHUNK, BUDGET = 32, 96
    vocab = 50
    eng = ContinuousBatchingEngine(
        lambda a: None, lambda r: None, n_slots=8,
        tenants={"gold": 2.0, "bronze": 1.0},
        kv_pool=(NB, BS, MB), prompt_cap=256,
        chunked_prefill=(CHUNK, BUDGET))
    eng.megastep_model = make_paged_pool_model(
        jax.random.PRNGKey(0), vocab=vocab, d=16, num_blocks=NB,
        block_size=BS)
    tok_fn = make_chunked_prefill_token_fn(CHUNK)

    rng = np.random.default_rng(0)
    reqs, rid = [], 0
    for _ in range(12):
        for t in ("gold", "bronze"):
            plen = int(rng.integers(128, 257))   # 4–8× the old 32-cap table
            reqs.append(Request(
                rid=rid, prompt=list(rng.integers(1, vocab, plen)),
                max_new_tokens=int(rng.integers(8, 24)), tenant_id=t))
            rid += 1
    eng.submit_batch(reqs)

    peak_util, peak_parked = 0.0, 0
    while eng.stats.finished < len(reqs):
        eng.megastep(K, token_fn=tok_fn)
        tel = eng.telemetry()
        peak_util = max(peak_util, tel["pool_utilization"])
        peak_parked = max(peak_parked, tel["parked_slots"])
    tel = eng.telemetry()
    toks = sum(len(r.out_tokens) for r in reqs)
    ptoks = sum(len(r.prompt) for r in reqs)
    print(f"[chunked] served {eng.stats.finished} requests "
          f"({ptoks} prompt + {toks} decode tokens) in "
          f"{eng.stats.host_syncs} host syncs / {eng.stats.steps} rounds")
    print(f"[chunked] prompts up to {max(len(r.prompt) for r in reqs)} tok "
          f"streamed through megastep in {eng.stats.prefill_chunks} chunks "
          f"(≤{CHUNK} tok each, ≤{BUDGET}/round)")
    print(f"[chunked] peak pool utilization {peak_util:.0%} of {NB} blocks; "
          f"{eng.stats.kv_block_stalls} block-stall slot-rounds "
          f"(peak {peak_parked} parked) — resumed FCFS off the waiting "
          f"array; now free={tel['kv_blocks_free']} "
          f"parked={tel['parked_slots']}")
    assert tel["kv_blocks_free"] == NB and tel["parked_slots"] == 0
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    print("[example] continuous chunked prefill + incremental block "
          "allocation OK")


if __name__ == "__main__":
    main()
