"""Serving example: continuous batching with TWA FCFS admission over a real
reduced model, demonstrating
  * strict first-come-first-enabled request admission,
  * the waiting-array effect: the scheduler re-examines only poked backlog
    entries (skip ratio printed),
  * slot telemetry (queue depth = ticket − grant).

Run:  PYTHONPATH=src python examples/serve_continuous_batching.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    engine = main(["--arch", "qwen2-0.5b", "--requests", "24", "--slots", "4",
                   "--prompt-len", "8", "--max-new", "12"])
    tel = engine.telemetry()
    assert tel["stats"]["finished"] == 24
    print("[example] all requests served, FCFS preserved")
