"""Registry of assigned architectures + input shapes.

Every entry matches the assignment block verbatim (layer counts, dims, GQA,
vocab, MoE arrangement); provenance in each config's `source`.  Family
notes / simplifications are in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from .base import ModelConfig, reduced

ARCH_IDS = [
    "qwen2-72b",
    "qwen2-0.5b",
    "codeqwen1.5-7b",
    "gemma3-1b",
    "xlstm-350m",
    "recurrentgemma-9b",
    "deepseek-moe-16b",
    "granite-moe-3b-a800m",
    "musicgen-medium",
    "internvl2-1b",
]

_MODULES = {
    "qwen2-72b": "qwen2_72b",
    "qwen2-0.5b": "qwen2_0_5b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "gemma3-1b": "gemma3_1b",
    "xlstm-350m": "xlstm_350m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "musicgen-medium": "musicgen_medium",
    "internvl2-1b": "internvl2_1b",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


# ------------------------------------------------------------- shapes -------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic archs (SSM / hybrid / mostly-local
# attention); pure full-attention archs skip it (assignment note).
SUBQUADRATIC = {"xlstm-350m", "recurrentgemma-9b", "gemma3-1b"}


def shapes_for(arch: str):
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


def all_cells():
    return [(a, s) for a in ARCH_IDS for s in shapes_for(a)]
