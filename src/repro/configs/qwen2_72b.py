"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias. [arXiv:2407.10671; hf]"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    unit=(Block("attn"),),
    num_units=80,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_kind="swiglu",
    max_seq_len=32768,
    source="arXiv:2407.10671; hf:Qwen/Qwen2-72B",
)
