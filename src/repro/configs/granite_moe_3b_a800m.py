"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, MoE 40 experts top-8, no shared experts.
[hf:ibm-granite (granite-3.0 family)]
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=0,  # all layers MoE
    vocab=49155,
    unit=(Block("moe"),),
    num_units=32,
    n_experts=40,
    n_experts_pad=48,  # EP: 48 divides the 16-way model axis (40 does not)
    top_k=8,
    n_shared=0,
    d_expert=512,
    capacity_factor=1.25,
    rope_theta=10_000.0,
    mlp_kind="swiglu",
    tie_embeddings=True,
    max_seq_len=4096,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (scaled)",
)
