"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) vocab=102400,
fine-grained MoE: 2 shared + 64 routed experts top-6, expert d_ff=1408.
Layer 0 is a dense SwiGLU (d_ff=10944); layers 1..27 are MoE.
[arXiv:2401.06066; hf]
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense layer 0 only
    vocab=102400,
    prefix=(Block("attn"),),
    unit=(Block("moe"),),
    num_units=27,
    n_experts=64,
    top_k=6,
    n_shared=2,
    d_expert=1408,
    d_shared=2816,  # 2 shared experts fused (2 × 1408)
    capacity_factor=1.25,
    rope_theta=10_000.0,
    mlp_kind="swiglu",
    max_seq_len=16384,
    source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
)
