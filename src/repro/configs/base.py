"""Config schema: a model is a (prefix, repeating-unit × N, suffix) stack of
heterogeneous blocks.  The repeating unit is lax.scan'ed (HLO size stays O(1)
in depth — compile-time critical for the 512-device dry-runs); prefix/suffix
hold non-repeating layers (e.g. DeepSeekMoE's dense layer 0, RecurrentGemma's
ragged tail).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Block:
    kind: str  # "attn" | "moe" | "mlstm" | "slstm" | "rglru"
    window: int = 0  # attn: sliding window (0 = full causal)
    rope_theta: float = 0.0  # attn: per-block rope base override (0 = cfg default)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    unit: tuple[Block, ...]
    num_units: int
    prefix: tuple[Block, ...] = ()
    suffix: tuple[Block, ...] = ()
    head_dim: int = 0  # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    mlp_kind: str = "swiglu"  # "swiglu" | "gelu"
    norm_plus_one: bool = False  # gemma (1+w) RMSNorm
    sandwich_norms: bool = False  # gemma3 post-attn / post-ffn norms
    embed_scale: bool = False  # gemma x *= sqrt(d)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    d_shared: int = 0
    capacity_factor: float = 1.25
    # EP padding: expert tensors padded to this count so the expert dim
    # divides the model axis (granite: 40 → 48); router stays at n_experts,
    # padded experts are dead weight (counted in the HLO-vs-model FLOPs
    # ratio, see EXPERIMENTS.md).
    n_experts_pad: int = 0
    # recurrent
    lru_width: int = 0
    xlstm_heads: int = 4
    # modality frontend stub: None | "audio" | "vision"
    frontend: str | None = None
    n_patches: int = 256  # vision stub: patch-embedding count
    max_seq_len: int = 32768
    # loss
    z_loss_weight: float = 0.0
    # notes for DESIGN.md §Arch-applicability / provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + self.num_units * len(self.unit) + len(self.suffix)

    @property
    def blocks(self) -> list[Block]:
        return list(self.prefix) + list(self.unit) * self.num_units + list(self.suffix)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head); used for
        MODEL_FLOPS = 6·N·D in the roofline."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab * d  # head
        for b in self.blocks:
            if b.kind in ("attn", "moe"):
                attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
                if self.qkv_bias:
                    attn += (self.n_heads + 2 * self.n_kv_heads) * hd
                n += attn + 2 * d  # + norms
                if b.kind == "attn":
                    mult = 3 if self.mlp_kind == "swiglu" else 2
                    n += mult * d * self.d_ff
                else:
                    n += d * self.n_experts  # router
                    n += self.n_experts * 3 * d * self.d_expert
                    if self.n_shared:
                        n += 3 * d * self.d_shared
            elif b.kind == "mlstm":
                di = 2 * d
                n += d + 2 * d * di + 4 * di + di * (3 * di + 2 * self.xlstm_heads) + di * d + di
            elif b.kind == "slstm":
                hd_s = d // self.xlstm_heads
                n += d + d * 4 * d + self.xlstm_heads * hd_s * 4 * hd_s + 4 * d + d * d
                n += d + 3 * d * int(d * 4 / 3)
            elif b.kind == "rglru":
                w = self.lru_width
                n += d + 2 * d * w + 4 * w + 2 * w * w + 2 * w + w * d
                mult = 3 if self.mlp_kind == "swiglu" else 2
                n += d + mult * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        n_moe_blocks = sum(1 for b in self.blocks if b.kind == "moe")
        inactive = n_moe_blocks * (self.n_experts - self.top_k) * 3 * self.d_model * self.d_expert
        return full - inactive


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests: same block pattern,
    same kinds, small dims."""
    hd = 16
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    small = dict(
        d_model=n_heads * hd,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=96 if cfg.d_ff else 0,
        vocab=512,
        num_units=min(2, cfg.num_units),
        n_experts=min(8, cfg.n_experts) if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        d_expert=32 if cfg.d_expert else 0,
        d_shared=64 if cfg.d_shared else 0,
        lru_width=n_heads * hd if cfg.lru_width else 0,
        xlstm_heads=2,
        n_patches=8,
        max_seq_len=128,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


field  # (re-export guard)
