"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, 1 attention per 2 recurrent.
[arXiv:2402.19427; unverified tier]

38 layers = (rglru, rglru, local-attn-2048) × 12 units + 2 rglru suffix.
Griffin conventions: GeGLU MLP, (1+w) RMSNorm, √d embed scale, tied
embeddings, lru_width = d_model.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    unit=(Block("rglru"), Block("rglru"), Block("attn", window=2048)),
    num_units=12,
    suffix=(Block("rglru"), Block("rglru")),
    lru_width=4096,
    rope_theta=10_000.0,
    mlp_kind="geglu",
    norm_plus_one=True,
    embed_scale=True,
    tie_embeddings=True,
    max_seq_len=1_048_576,  # local attention bounds the KV; state is O(1)
    source="arXiv:2402.19427 (unverified)",
)
