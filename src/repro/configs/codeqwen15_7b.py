"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32 ⇒ MHA) d_ff=13440
vocab=92416 — qwen1.5 arch (QKV bias). [hf:Qwen/CodeQwen1.5-7B]"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    unit=(Block("attn"),),
    num_units=32,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_kind="swiglu",
    max_seq_len=65536,
    source="hf:Qwen/CodeQwen1.5-7B",
)
