"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24 ⇒ MHA) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only (per assignment): the EnCodec frontend is a STUB —
input_specs() provides precomputed frame embeddings (B,S,d_model); the head
predicts the next EnCodec token (vocab 2048).  Adaptation note: MusicGen
uses sinusoidal positions; we use RoPE (TPU-idiomatic, documented in
DESIGN.md).  Classic (non-gated) GELU MLP per the original transformer LM.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    unit=(Block("attn"),),
    num_units=48,
    rope_theta=10_000.0,
    mlp_kind="gelu",
    frontend="audio",
    max_seq_len=32768,
    source="arXiv:2306.05284; hf:facebook/musicgen-medium",
)
