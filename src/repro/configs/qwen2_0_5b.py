"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151936,
    unit=(Block("attn"),),
    num_units=24,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_kind="swiglu",
    tie_embeddings=True,
    max_seq_len=32768,
    source="arXiv:2407.10671; hf:Qwen/Qwen2-0.5B",
)
