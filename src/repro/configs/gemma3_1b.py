"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144
— 5:1 local:global sliding-window pattern, 128k-class context.
[hf:google/gemma-3-1b-pt; unverified tier]

26 layers = (5 local + 1 global) × 4 units + 2 local suffix. Local layers:
512-token sliding window, rope θ=10k; global layers rope θ=1M.  Gemma
conventions: (1+w) RMSNorm, sandwich norms, √d embedding scale, tied
embeddings.
"""

from .base import Block, ModelConfig

_LOCAL = Block("attn", window=512, rope_theta=10_000.0)
_GLOBAL = Block("attn", window=0, rope_theta=1_000_000.0)

CONFIG = ModelConfig(
    name="gemma3-1b",
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    unit=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    num_units=4,
    suffix=(_LOCAL, _LOCAL),
    qkv_bias=False,
    mlp_kind="geglu",
    norm_plus_one=True,
    sandwich_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    max_seq_len=131072,
    source="hf:google/gemma-3-1b-pt (unverified)",
)
