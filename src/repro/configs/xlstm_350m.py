"""xlstm-350m [ssm] — 24L d_model=1024 4 heads, d_ff=0 vocab=50304 —
alternating sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified tier]

Block ratio choice (config tier is unverified): 1:1 alternating
(mLSTM, sLSTM) × 12 — see DESIGN.md §Arch-applicability for the
simplifications vs the reference CUDA kernels.  d_ff=0: xLSTM blocks carry
their own up/down projections (mLSTM pf=2, sLSTM pf=4/3).
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    unit=(Block("mlstm"), Block("slstm")),
    num_units=12,
    xlstm_heads=4,
    mlp_kind="gelu",
    max_seq_len=1_048_576,  # recurrent: O(1) state in sequence length
    source="arXiv:2405.04517 (unverified)",
)
