"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT (stub) + Qwen2-0.5B-family LM backbone.
[arXiv:2404.16821; hf]

Backbone only (per assignment): the InternViT frontend is a STUB —
input_specs() provides precomputed patch embeddings (B, n_patches, d_model)
which are prepended to the token embeddings; loss is masked over the patch
region.  No decode over patches (encoder-side), so decode shapes exercise
the LM only.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    unit=(Block("attn"),),
    num_units=24,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_kind="swiglu",
    tie_embeddings=True,
    frontend="vision",
    n_patches=256,
    max_seq_len=32768,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B",
)
