"""Shared neural building blocks: RMSNorm, RoPE, GQA attention (full /
sliding-window, bias-optional), SwiGLU & classic MLPs.

Attention is implemented **blockwise** (online-softmax over KV blocks via
lax.scan) so activation memory is O(S·d) instead of O(S²) — this is both the
production path for 32k prefill and the pure-jnp oracle mirrored by
`kernels/flash_attention`.  A naive O(S²) reference lives in
`kernels/flash_attention/ref.py` for cross-checking.

Conventions: activations (B, S, D); params are plain dicts of jnp arrays;
compute dtype bf16 with fp32 softmax statistics; weights stored in the dtype
given at init (bf16 for large configs, fp32 for smoke tests).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain

# ---------------------------------------------------------------- norms ----


def rms_norm(x, weight, eps: float = 1e-6, plus_one: bool = False):
    """RMSNorm; `plus_one` selects the Gemma convention ((1+w)·x̂)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (x32 * inv * w).astype(dtype)


def init_rms_norm(d, dtype, plus_one: bool = False):
    return jnp.zeros((d,), dtype) if plus_one else jnp.ones((d,), dtype)


# ----------------------------------------------------------------- rope ----


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----


def _online_softmax_block(carry, qk_scale, q, k, v, mask):
    """One KV-block step of the online-softmax recurrence.

    carry: (acc (B,H,Sq,hd) f32, m (B,H,Sq) f32, l (B,H,Sq) f32)
    q: (B,H,Sq,hd)  k,v: (B,H,Sk,hd)  mask: (B,1|H,Sq,Sk) bool (True=keep)
    """
    acc, m, l = carry
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * qk_scale
    s = jnp.where(mask, s, -jnp.inf)
    m_blk = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m, m_blk)
    # guard fully-masked rows (m_new == -inf)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return (acc_new, m_new, l_new)


def blockwise_attention(q, k, v, q_positions, kv_positions, *, window: int = 0,
                        kv_block: int = 1024, causal: bool = True):
    """Flash-style attention with O(S) memory.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); positions give the absolute
    index of each row (so decode passes Sq=1 with its position).
    GQA: H is grouped onto KV heads by repetition (H % KV == 0).
    window > 0 ⇒ sliding-window (key kept iff 0 ≤ qpos-kpos < window).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    group = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = jnp.transpose(q, (0, 2, 1, 3))  # (B,H,Sq,hd)
    kh = jnp.transpose(k, (0, 2, 1, 3))  # (B,KV,Sk,hd)
    vh = jnp.transpose(v, (0, 2, 1, 3))
    kh = jnp.repeat(kh, group, axis=1)  # (B,H,Sk,hd) — GQA repeat
    vh = jnp.repeat(vh, group, axis=1)
    # pin the repeated KV to the head sharding of q: without this the
    # partitioner resolves the q(heads-sharded) × k(kv-replicated) einsum by
    # replicating whichever side it fancies — at 32k context that is the
    # whole KV stream per chip.
    kh = constrain(kh, "batch", "heads", "seq")
    vh = constrain(vh, "batch", "heads", "seq")
    qh = constrain(qh, "batch", "heads", "seq")

    kv_block = min(kv_block, Sk)
    nblk = (Sk + kv_block - 1) // kv_block
    pad = nblk * kv_block - Sk
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=-1)

    def mask_for(kp):
        dpos = q_positions[:, None, :, None] - kp[:, None, None, :]
        mask = kp[:, None, None, :] >= 0
        if causal:
            mask &= dpos >= 0
        if window > 0:
            mask &= dpos < window
        return mask

    acc0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)

    if nblk == 1:
        # single block — direct call (no while loop; keeps cost_analysis
        # exact for the dry-run analysis lowering)
        acc, m, l = _online_softmax_block((acc0, m0, l0), scale, qh, kh, vh, mask_for(kv_positions))
    else:
        kh = kh.reshape(B, H, nblk, kv_block, hd).transpose(2, 0, 1, 3, 4)
        vh = vh.reshape(B, H, nblk, kv_block, hd).transpose(2, 0, 1, 3, 4)
        kpos = kv_positions.reshape(B, nblk, kv_block).transpose(1, 0, 2)  # (nblk,B,blk)

        # checkpoint the block body: without it the scan saves the per-block
        # probability matrices for backward — O(S²) memory, exactly what
        # flash attention exists to avoid. With it, backward recomputes each
        # block's s/p from the (already stored) k/v blocks.
        @jax.checkpoint
        def body(carry, blk):
            kb, vb, kp = blk
            return _online_softmax_block(carry, scale, qh, kb, vb, mask_for(kp)), None

        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kh, vh, kpos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (B,Sq,H,hd)


def single_query_attention(q, k, v, q_positions, kv_positions, *, window: int = 0):
    """Decode-path attention (Sq == 1) against a (possibly sequence-sharded)
    KV cache, computed densely — no scan, no GQA head materialization.

    q: (B, 1, H, hd); k/v: (B, C, KV, hd); kv_positions: (B, C) with -1 for
    empty slots.  With the cache sequence dim sharded over the `model` axis
    (parallel/sharding.py "seq_kv" rule) the SPMD partitioner turns the
    softmax max/sum reductions and the PV contraction into exactly the
    flash-decode log-sum-exp merge: each shard attends to its sequence slice
    and partial results are combined with small all-reduces.
    """
    B, Sq, H, hd = q.shape
    _, C, KV, _ = k.shape
    assert Sq == 1 and H % KV == 0
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)  # (B,KV,G,hd) — GQA without repeating KV
    # flash-decode sharding contract: q is tiny — replicate it across the
    # model axis so the big KV keeps its *sequence* sharding; the partial
    # softmax stats and PV products then merge with small all-reduces (the
    # LSE merge).  Without the pin, GSPMD may instead reshard the cache to
    # match q's head sharding — replicating TBs of KV.
    qg = constrain(qg, "batch", None, None, None)
    # §Perf iteration 2 (KV streaming): keep K/V in their storage dtype and
    # accumulate in f32 via preferred_element_type — an explicit
    # .astype(f32) materializes a full-width copy of the WHOLE cache slice
    # (decode is memory-bound; this doubles its dominant traffic term).
    # Matches the Pallas decode kernel's numerics (bf16 operands, f32 acc).
    s = jnp.einsum("bkgd,bckd->bkgc", qg.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) * scale
    s = constrain(s, "batch", None, None, "seq_kv")
    dpos = q_positions[:, None, None, :] - kv_positions[:, None, None, :]  # (B,1,1,C)
    mask = (kv_positions[:, None, None, :] >= 0) & (dpos >= 0)
    if window > 0:
        mask &= dpos < window
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    ctx = jnp.einsum("bkgc,bckd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32) / jnp.maximum(l, 1e-30)
    out = ctx.reshape(B, 1, H, hd).astype(q.dtype)
    # hand the o-projection a head-sharded ctx (reduce-scatter, not all-reduce)
    return constrain(out, "batch", "seq", "heads")


# -------------------------------------------------------- attention block ---


def init_attention(key, cfg_layer, d_model, dtype):
    """cfg_layer: dict with n_heads, n_kv_heads, head_dim, qkv_bias."""
    H, KV, hd = cfg_layer["n_heads"], cfg_layer["n_kv_heads"], cfg_layer["head_dim"]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d_model**-0.5
    p = {
        "wq": (jax.random.normal(k1, (d_model, H, hd)) * std).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, KV, hd)) * std).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, KV, hd)) * std).astype(dtype),
        "wo": (jax.random.normal(k4, (H, hd, d_model)) * (H * hd) ** -0.5).astype(dtype),
    }
    if cfg_layer.get("qkv_bias", False):
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def qkv_project(p, x, positions, rope_theta=10000.0):
    """x (B,S,D) → q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attn_output(p, ctx):
    """ctx (B,S,H,hd) → (B,S,D) via the o-projection."""
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


# ------------------------------------------------------------------ mlps ----


def init_swiglu(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": (jax.random.normal(k1, (d_model, d_ff)) * d_model**-0.5).astype(dtype),
        "wg": (jax.random.normal(k2, (d_model, d_ff)) * d_model**-0.5).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * d_ff**-0.5).astype(dtype),
    }


def swiglu_forward(p, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * jnp.einsum("bsd,df->bsf", x, p["wi"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def init_gelu_mlp(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key, 2)
    return {
        "wi": (jax.random.normal(k1, (d_model, d_ff)) * d_model**-0.5).astype(dtype),
        "wo": (jax.random.normal(k2, (d_ff, d_model)) * d_ff**-0.5).astype(dtype),
    }


def gelu_mlp_forward(p, x):
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"])), p["wo"])


def geglu_forward(p, x):
    """GeGLU (Griffin/Gemma MLP): gelu-gated — same params as swiglu."""
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * jnp.einsum("bsd,df->bsf", x, p["wi"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


MLP_INIT = {"swiglu": init_swiglu, "geglu": init_swiglu, "gelu": init_gelu_mlp}
MLP_FWD = {"swiglu": swiglu_forward, "geglu": geglu_forward, "gelu": gelu_mlp_forward}


# --------------------------------------------------------------- helpers ----


def init_embedding(key, vocab, d_model, dtype):
    return (jax.random.normal(key, (vocab, d_model)) * d_model**-0.5).astype(dtype)


partial  # re-export guard (silence linters for unused import style)
