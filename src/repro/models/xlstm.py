"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, exponential gating with stabilizer).

Both cells are expressed as a single-step transition plus a lax.scan over
time for train/prefill; decode reuses the single step with the carried
state.  Simplifications vs the reference CUDA implementation (noted per the
"unverified" config tier): causal-conv pre-activation on the q/k branch is a
width-4 depthwise conv; block up/down projections follow the paper's factors
(mLSTM pf=2, sLSTM pf=4/3); recurrent gate contributions in sLSTM are
block-diagonal per head.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cache import make_mlstm_state, make_slstm_state
from .layers import rms_norm


def _causal_conv1d(x, w, tail=None):
    """Depthwise causal conv. x: (B,S,D), w: (K,D); ``tail`` carries the
    last K-1 inputs from previous chunks/steps (zeros at sequence start).
    Returns (out, new_tail) — the tail makes chunked prefill and one-token
    decode produce exactly the full-sequence result."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out, xp[:, xp.shape[1] - (K - 1) :, :]


# ------------------------------------------------------------------ mLSTM ---


def init_mlstm(key, d_model, n_heads, dtype):
    """mLSTM block: pre-LN → up-proj (pf=2) → (conv branch → q,k; v) →
    mLSTM cell → gated skip → down-proj."""
    d_in = 2 * d_model  # up-projected width
    hd = d_in // n_heads
    ks = jax.random.split(key, 8)
    std = d_model**-0.5
    stdi = d_in**-0.5
    return {
        "ln": jnp.ones((d_model,), dtype),
        "w_up": (jax.random.normal(ks[0], (d_model, d_in)) * std).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (d_model, d_in)) * std).astype(dtype),
        "conv": (jax.random.normal(ks[2], (4, d_in)) * 0.1).astype(dtype),
        "wq": (jax.random.normal(ks[3], (d_in, n_heads, hd)) * stdi).astype(dtype),
        "wk": (jax.random.normal(ks[4], (d_in, n_heads, hd)) * stdi).astype(dtype),
        "wv": (jax.random.normal(ks[5], (d_in, n_heads, hd)) * stdi).astype(dtype),
        "w_if": (jax.random.normal(ks[6], (d_in, n_heads, 2)) * stdi).astype(dtype),
        "b_if": jnp.tile(jnp.asarray([0.0, 3.0], dtype), (n_heads, 1)),  # forget bias>0
        "w_down": (jax.random.normal(ks[7], (d_in, d_model)) * stdi).astype(dtype),
        "out_ln": jnp.ones((d_in,), dtype),
    }


def _mlstm_step(state, q, k, v, i_gate, f_gate):
    """One time step. q/k/v: (B,H,hd); i/f gates: (B,H) pre-activations."""
    logf = -jax.nn.softplus(-f_gate)  # log sigmoid(f)
    m_new = jnp.maximum(logf + state["m"], i_gate)
    i_ = jnp.exp(i_gate - m_new)
    f_ = jnp.exp(logf + state["m"] - m_new)
    C = f_[..., None, None] * state["C"] + i_[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = f_[..., None] * state["n"] + i_[..., None] * k
    h_num = jnp.einsum("bhk,bhkv->bhv", q, C)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    h = h_num / h_den[..., None]
    return {"C": C, "n": n, "m": m_new}, h


def mlstm_forward(p, x, n_heads, state=None):
    """x: (B,S,D). Returns (out (B,S,D), new_state)."""
    B, S, D = x.shape
    xn = rms_norm(x, p["ln"])
    up = jnp.einsum("bsd,de->bse", xn, p["w_up"])
    gate = jnp.einsum("bsd,de->bse", xn, p["w_gate"])
    d_in = up.shape[-1]
    if state is None:
        state = make_mlstm_state(B, n_heads, d_in // n_heads, d_in // n_heads, d_in)
    conv, conv_tail = _causal_conv1d(up, p["conv"], state["conv"])
    conv = jax.nn.silu(conv)
    q = jnp.einsum("bse,ehk->bshk", conv, p["wq"])
    k = jnp.einsum("bse,ehk->bshk", conv, p["wk"]) * (p["wq"].shape[-1] ** -0.5)
    v = jnp.einsum("bse,ehk->bshk", up, p["wv"])
    gates = jnp.einsum("bse,ehg->bshg", up, p["w_if"]) + p["b_if"].astype(jnp.float32)
    i_g, f_g = gates[..., 0].astype(jnp.float32), gates[..., 1].astype(jnp.float32)

    def body(st, inp):
        qt, kt, vt, it, ft = inp
        st, h = _mlstm_step(st, qt.astype(jnp.float32), kt.astype(jnp.float32), vt.astype(jnp.float32), it, ft)
        return st, h

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_g.transpose(1, 0, 2),
        f_g.transpose(1, 0, 2),
    )
    cell_state = {k_: state[k_] for k_ in ("C", "n", "m")}
    cell_state, hs = jax.lax.scan(body, cell_state, xs)
    state = dict(cell_state, conv=conv_tail)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, -1).astype(x.dtype)  # (B,S,d_in)
    h = rms_norm(h, p["out_ln"]) * jax.nn.silu(gate)
    return x + jnp.einsum("bse,ed->bsd", h, p["w_down"]), state  # residual inside


# ------------------------------------------------------------------ sLSTM ---


def init_slstm(key, d_model, n_heads, dtype):
    hd = d_model // n_heads
    ks = jax.random.split(key, 7)
    std = d_model**-0.5
    d_ff = int(d_model * 4 / 3)
    return {
        "ln": jnp.ones((d_model,), dtype),
        "w_zifo": (jax.random.normal(ks[0], (d_model, n_heads, 4 * hd)) * std).astype(dtype),
        "r_zifo": (jax.random.normal(ks[1], (n_heads, hd, 4 * hd)) * hd**-0.5).astype(dtype),
        "b_zifo": jnp.zeros((n_heads, 4 * hd), dtype),
        "w_out": (jax.random.normal(ks[2], (d_model, d_model)) * std).astype(dtype),
        "ffn_ln": jnp.ones((d_model,), dtype),
        "ffn_wi": (jax.random.normal(ks[3], (d_model, d_ff)) * std).astype(dtype),
        "ffn_wg": (jax.random.normal(ks[4], (d_model, d_ff)) * std).astype(dtype),
        "ffn_wo": (jax.random.normal(ks[5], (d_ff, d_model)) * d_ff**-0.5).astype(dtype),
    }


def _slstm_step(p, state, zifo_x):
    """zifo_x: (B,H,4*hd) input pre-activations; recurrent term added here."""
    hd = state["h"].shape[-1]
    rec = jnp.einsum("bhk,hkg->bhg", state["h"].astype(zifo_x.dtype), p["r_zifo"].astype(zifo_x.dtype))
    z, i, f, o = jnp.split((zifo_x + rec).astype(jnp.float32), 4, axis=-1)
    logf = -jax.nn.softplus(-f)
    m_new = jnp.maximum(logf + state["m"], i)
    i_ = jnp.exp(i - m_new)
    f_ = jnp.exp(logf + state["m"] - m_new)
    c = f_ * state["c"] + i_ * jnp.tanh(z)
    n = f_ * state["n"] + i_
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(p, x, n_heads, state=None):
    B, S, D = x.shape
    hd = D // n_heads
    xn = rms_norm(x, p["ln"])
    zifo = jnp.einsum("bsd,dhg->bshg", xn, p["w_zifo"]) + p["b_zifo"]
    if state is None:
        state = make_slstm_state(B, n_heads, hd)

    def body(st, inp):
        st = _slstm_step(p, st, inp)
        return st, st["h"]

    state, hs = jax.lax.scan(body, state, zifo.transpose(1, 0, 2, 3))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h, p["w_out"])
    y = x + out  # residual inside
    # post FFN (pf = 4/3 SwiGLU), part of the sLSTM block per the paper
    yn = rms_norm(y, p["ffn_ln"])
    ff = jax.nn.silu(jnp.einsum("bsd,df->bsf", yn, p["ffn_wg"])) * jnp.einsum("bsd,df->bsf", yn, p["ffn_wi"])
    return y + jnp.einsum("bsf,fd->bsd", ff, p["ffn_wo"]), state
