"""Unified decoder stack over heterogeneous blocks.

A model is ``prefix + unit×num_units + suffix`` of blocks (configs/base.py).
The repeating unit is lax.scan'ed with stacked parameters → HLO size is O(1)
in depth (essential for 80-layer models on the 512-device dry-run) and the
scan body is remat'ed (selective activation checkpointing).

Modes (static):
  train    — full-sequence forward, no caches, chunked-CE loss
  prefill  — full-sequence forward, writes KV caches / recurrent states
  decode   — one token per row against the caches

Frontend stubs (per assignment): "audio" consumes precomputed frame
embeddings (B,S,D); "vision" prepends precomputed patch embeddings (B,P,D)
to the token embeddings.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import Block, ModelConfig
from ..parallel.sharding import constrain
from .cache import (
    kv_cache_append,
    kv_cache_write_prefill,
    make_kv_cache,
    make_mlstm_state,
    make_rglru_state,
    make_slstm_state,
)
from .layers import (
    MLP_FWD,
    MLP_INIT,
    attn_output,
    blockwise_attention,
    init_attention,
    init_embedding,
    init_rms_norm,
    qkv_project,
    rms_norm,
    single_query_attention,
)
from .moe import init_moe, moe_forward
from .rglru import init_rglru_block, rglru_block_forward, rglru_block_step
from .xlstm import init_mlstm, init_slstm, mlstm_forward, slstm_forward

ZERO_AUX = {"lb_loss": 0.0, "z_loss": 0.0, "overflow_frac": 0.0}


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in a}


# ------------------------------------------------------------------ init ----


def init_block(key, spec: Block, cfg: ModelConfig, dtype):
    d = cfg.d_model
    if spec.kind in ("attn", "moe"):
        ks = jax.random.split(key, 2)
        p = {
            "ln1": init_rms_norm(d, dtype, cfg.norm_plus_one),
            "attn": init_attention(
                ks[0],
                dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, qkv_bias=cfg.qkv_bias),
                d,
                dtype,
            ),
            "ln2": init_rms_norm(d, dtype, cfg.norm_plus_one),
        }
        if cfg.sandwich_norms:
            p["ln1b"] = init_rms_norm(d, dtype, cfg.norm_plus_one)
            p["ln2b"] = init_rms_norm(d, dtype, cfg.norm_plus_one)
        if spec.kind == "attn":
            p["mlp"] = MLP_INIT[cfg.mlp_kind](ks[1], d, cfg.d_ff, dtype)
        else:
            p["moe"] = init_moe(
                ks[1], d, cfg.n_experts, cfg.d_expert, cfg.top_k, cfg.n_shared, cfg.d_shared,
                dtype, n_experts_pad=cfg.n_experts_pad,
            )
        return p
    if spec.kind == "mlstm":
        return init_mlstm(key, d, cfg.xlstm_heads, dtype)
    if spec.kind == "slstm":
        return init_slstm(key, d, cfg.xlstm_heads, dtype)
    if spec.kind == "rglru":
        k1, k2 = jax.random.split(key)
        return {
            "temporal": init_rglru_block(k1, d, cfg.lru_width, dtype),
            "ln2": init_rms_norm(d, dtype, cfg.norm_plus_one),
            "mlp": MLP_INIT[cfg.mlp_kind](k2, d, cfg.d_ff, dtype),
        }
    raise ValueError(f"unknown block kind {spec.kind}")


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 6)
    params = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_ln": init_rms_norm(cfg.d_model, dtype, cfg.norm_plus_one),
    }
    if not cfg.tie_embeddings:
        params["head"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab)) * cfg.d_model**-0.5
        ).astype(dtype)

    def init_unit(k):
        ks = jax.random.split(k, max(1, len(cfg.unit)))
        return {f"b{i}": init_block(ks[i], spec, cfg, dtype) for i, spec in enumerate(cfg.unit)}

    if cfg.num_units > 0:
        params["units"] = jax.vmap(init_unit)(jax.random.split(keys[2], cfg.num_units))
    for name, blocks, k in (("prefix", cfg.prefix, keys[3]), ("suffix", cfg.suffix, keys[4])):
        if blocks:
            ks = jax.random.split(k, len(blocks))
            params[name] = [init_block(ks[i], spec, cfg, dtype) for i, spec in enumerate(blocks)]
    return params


# ---------------------------------------------------------------- caches ----


def init_block_cache(spec: Block, cfg: ModelConfig, batch: int, capacity: int, dtype):
    if spec.kind in ("attn", "moe"):
        cap = min(capacity, spec.window) if spec.window > 0 else capacity
        return make_kv_cache(batch, cap, cfg.n_kv_heads, cfg.hd, dtype)
    if spec.kind == "mlstm":
        d_in = 2 * cfg.d_model
        hd = d_in // cfg.xlstm_heads
        return make_mlstm_state(batch, cfg.xlstm_heads, hd, hd, d_in)
    if spec.kind == "slstm":
        return make_slstm_state(batch, cfg.xlstm_heads, cfg.d_model // cfg.xlstm_heads)
    if spec.kind == "rglru":
        return make_rglru_state(batch, cfg.lru_width)
    raise ValueError(spec.kind)


def init_caches(cfg: ModelConfig, batch: int, capacity: int, dtype):
    caches = {}
    if cfg.num_units > 0:

        def one(spec):
            return init_block_cache(spec, cfg, batch, capacity, dtype)

        unit_cache = {f"b{i}": one(spec) for i, spec in enumerate(cfg.unit)}
        caches["units"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_units,) + x.shape), unit_cache
        )
    for name, blocks in (("prefix", cfg.prefix), ("suffix", cfg.suffix)):
        if blocks:
            caches[name] = [init_block_cache(s, cfg, batch, capacity, dtype) for s in blocks]
    return caches


# --------------------------------------------------------------- forward ----


def block_forward(spec: Block, cfg: ModelConfig, p, x, positions, cache, mode: str,
                  kv_block: int = 1024):
    """Returns (x', new_cache, aux). Residuals are applied inside."""
    aux = dict(ZERO_AUX)
    if spec.kind in ("attn", "moe"):
        theta = spec.rope_theta or cfg.rope_theta
        xn = rms_norm(x, p["ln1"], plus_one=cfg.norm_plus_one)
        q, k, v = qkv_project(p["attn"], xn, positions, theta)
        q = constrain(q, "batch", "seq", "heads")
        new_cache = cache
        if mode == "train":
            ctx = blockwise_attention(q, k, v, positions, positions, window=spec.window,
                                      kv_block=kv_block)
        elif mode == "prefill":
            ctx = blockwise_attention(q, k, v, positions, positions, window=spec.window,
                                      kv_block=kv_block)
            new_cache = kv_cache_write_prefill(cache, k, v, positions)
        else:  # decode — dense single-query path (scan-free; with the cache
            # sequence sharded over `model` the partitioner emits the
            # flash-decode LSE-merge all-reduces)
            new_cache = kv_cache_append(cache, k, v, positions)
            ctx = single_query_attention(
                q, new_cache["k"], new_cache["v"], positions, new_cache["pos"], window=spec.window
            )
        attn_out = attn_output(p["attn"], ctx)
        if cfg.sandwich_norms:
            attn_out = rms_norm(attn_out, p["ln1b"], plus_one=cfg.norm_plus_one)
        x = x + attn_out
        xn2 = rms_norm(x, p["ln2"], plus_one=cfg.norm_plus_one)
        if spec.kind == "attn":
            ff = MLP_FWD[cfg.mlp_kind](p["mlp"], xn2)
        else:
            ff, aux = moe_forward(
                p["moe"], xn2, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor
            )
            aux = dict(ZERO_AUX, **aux)
        if cfg.sandwich_norms:
            ff = rms_norm(ff, p["ln2b"], plus_one=cfg.norm_plus_one)
        return x + ff, new_cache, aux
    if spec.kind == "mlstm":
        x, st = mlstm_forward(p, x, cfg.xlstm_heads, cache)
        return x, st, aux
    if spec.kind == "slstm":
        x, st = slstm_forward(p, x, cfg.xlstm_heads, cache)
        return x, st, aux
    if spec.kind == "rglru":
        if mode == "decode":
            x, st = rglru_block_step(p["temporal"], x, cache)
        else:
            x, st = rglru_block_forward(p["temporal"], x, cache)
        xn = rms_norm(x, p["ln2"], plus_one=cfg.norm_plus_one)
        return x + MLP_FWD[cfg.mlp_kind](p["mlp"], xn), st, aux
    raise ValueError(spec.kind)


def _apply_unit(cfg, unit_params, x, positions, unit_cache, mode, kv_block=1024):
    aux_sum = dict(ZERO_AUX)
    new_caches = {}
    for i, spec in enumerate(cfg.unit):
        cache_i = None if unit_cache is None else unit_cache[f"b{i}"]
        x, nc, aux = block_forward(spec, cfg, unit_params[f"b{i}"], x, positions, cache_i, mode,
                                   kv_block=kv_block)
        new_caches[f"b{i}"] = nc
        aux_sum = _add_aux(aux_sum, aux)
    return x, new_caches, aux_sum


_REMAT_POLICIES = {
    "full": None,  # save only per-unit inputs (max recompute, min memory)
    "dots": "dots_with_no_batch_dims_saveable",
}


def _group_size(u: int) -> int:
    """Largest divisor of u that is ≤ ceil(sqrt(u)) (√L checkpointing)."""
    import math as _m

    target = _m.isqrt(u) + (0 if _m.isqrt(u) ** 2 == u else 1)
    for g in range(target, 0, -1):
        if u % g == 0:
            return g
    return 1


def forward_hidden(params, cfg: ModelConfig, x, positions, caches=None, mode="train",
                   remat="dots", unroll_units: bool = False, kv_block: int = 1024):
    """x: (B,S,D) input embeddings → (h, new_caches, aux).

    ``unroll_units`` unrolls the layer scan (dry-run analysis lowering only:
    while-loop bodies are counted once by XLA cost analysis, so the roofline
    lowering unrolls every static-trip-count loop)."""
    aux_total = dict(ZERO_AUX)
    new_caches = {"prefix": [], "suffix": []} if caches is not None else None

    for i, spec in enumerate(cfg.prefix):
        c = caches["prefix"][i] if caches is not None else None
        x, nc, aux = block_forward(spec, cfg, params["prefix"][i], x, positions, c, mode,
                                   kv_block=kv_block)
        aux_total = _add_aux(aux_total, aux)
        if caches is not None:
            new_caches["prefix"].append(nc)

    if cfg.num_units > 0:
        unroll = cfg.num_units if unroll_units else 1
        if caches is None:

            def unit_fn(xc, up):
                xo, _, aux = _apply_unit(cfg, up, xc, positions, None, mode, kv_block)
                return xo, aux

            if remat == "2level" and mode == "train" and not unroll_units:
                # nested (√L) activation checkpointing: scan over unit GROUPS
                # (outer checkpoint saves one carry per group) with a
                # checkpointed per-unit scan inside (backward re-runs one
                # group, then re-runs one unit at a time).  Saved activations
                # drop from U·|x| to (U/g + g)·|x| for ~2 extra fwd passes.
                U = cfg.num_units
                g = _group_size(U)
                inner = jax.checkpoint(unit_fn)

                @jax.checkpoint
                def group_fn(xc, gp):
                    return jax.lax.scan(inner, xc, gp)

                grouped = jax.tree.map(
                    lambda a: a.reshape((U // g, g) + a.shape[1:]), params["units"]
                )
                x, auxs = jax.lax.scan(group_fn, x, grouped)
            else:
                if remat in _REMAT_POLICIES and mode == "train":
                    pol = _REMAT_POLICIES[remat]
                    unit_fn = jax.checkpoint(
                        unit_fn, policy=getattr(jax.checkpoint_policies, pol) if pol else None
                    )
                x, auxs = jax.lax.scan(unit_fn, x, params["units"], unroll=unroll)
            aux_total = _add_aux(aux_total, jax.tree.map(jnp.sum, auxs))
        else:

            def unit_fn_c(xc, inp):
                up, uc = inp
                xo, ncs, aux = _apply_unit(cfg, up, xc, positions, uc, mode, kv_block)
                return xo, (ncs, aux)

            x, (ncs, auxs) = jax.lax.scan(unit_fn_c, x, (params["units"], caches["units"]),
                                          unroll=unroll)
            new_caches["units"] = ncs
            aux_total = _add_aux(aux_total, jax.tree.map(jnp.sum, auxs))

    for i, spec in enumerate(cfg.suffix):
        c = caches["suffix"][i] if caches is not None else None
        x, nc, aux = block_forward(spec, cfg, params["suffix"][i], x, positions, c, mode,
                                   kv_block=kv_block)
        aux_total = _add_aux(aux_total, aux)
        if caches is not None:
            new_caches["suffix"].append(nc)

    h = rms_norm(x, params["final_ln"], plus_one=cfg.norm_plus_one)
    if new_caches is not None:
        new_caches = {k: v for k, v in new_caches.items() if v != []}
    return h, new_caches, aux_total


# ------------------------------------------------------------ embeddings ----


def embed_inputs(params, cfg: ModelConfig, batch):
    """Assemble input embeddings + positions + loss mask from a batch dict."""
    if cfg.frontend == "audio":
        x = batch["frame_embeds"]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        mask = jnp.ones((B, S), jnp.float32)
    elif cfg.frontend == "vision":
        tok = params["embed"][batch["tokens"]]
        x = jnp.concatenate([batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        P = batch["patch_embeds"].shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((B, P), jnp.float32), jnp.ones((B, S - P), jnp.float32)], axis=1
        )
    else:
        x = params["embed"][batch["tokens"]]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        mask = jnp.ones((B, S), jnp.float32)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x, positions, mask


def lm_head(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


# ----------------------------------------------------------------- loss ----


def chunked_ce_loss(h, head, labels, mask, chunk: int = 512, z_weight: float = 0.0):
    """Cross-entropy without materializing (B,S,V) logits: scan over sequence
    chunks; fp32 statistics; vocab dim stays sharded (`vocab` → model axis)."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nch = S // chunk

    # checkpointed body: backward recomputes each chunk's logits from h
    # instead of storing (B, S, V) residuals across the scan.
    @jax.checkpoint
    def body(carry, i):
        loss_sum, z_sum, cnt = carry
        hs = jax.lax.dynamic_slice(h, (0, i * chunk, 0), (B, chunk, D))
        lab = jax.lax.dynamic_slice(labels, (0, i * chunk), (B, chunk))
        msk = jax.lax.dynamic_slice(mask, (0, i * chunk), (B, chunk))
        logits = jnp.einsum("bsd,dv->bsv", hs, head.astype(hs.dtype)).astype(jnp.float32)
        logits = constrain(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        loss_sum = loss_sum + jnp.sum((lse - ll) * msk)
        z_sum = z_sum + jnp.sum(lse**2 * msk)
        return (loss_sum, z_sum, cnt + jnp.sum(msk)), None

    if nch == 1:
        (loss_sum, z_sum, cnt), _ = body(
            (jnp.float32(0), jnp.float32(0), jnp.float32(0)), jnp.int32(0)
        )
    else:
        (loss_sum, z_sum, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), jnp.arange(nch)
        )
    cnt = jnp.maximum(cnt, 1.0)
    return loss_sum / cnt + z_weight * z_sum / cnt, cnt


def train_loss(params, cfg: ModelConfig, batch, remat="dots", unroll_units=False,
               kv_block: int = 1024, ce_chunk: int = 512):
    """Full training objective: chunked CE + MoE aux losses. Returns
    (loss, metrics)."""
    x, positions, mask = embed_inputs(params, cfg, batch)
    h, _, aux = forward_hidden(params, cfg, x, positions, None, "train", remat=remat,
                               unroll_units=unroll_units, kv_block=kv_block)
    ce, cnt = chunked_ce_loss(h, lm_head(params, cfg), batch["labels"], mask,
                              chunk=ce_chunk, z_weight=cfg.z_loss_weight)
    n_moe = max(1, sum(1 for b in cfg.blocks if b.kind == "moe"))
    lb = aux["lb_loss"] / n_moe
    loss = ce + 0.01 * lb + aux["z_loss"] / n_moe
    metrics = {
        "ce": ce,
        "lb_loss": lb,
        "router_z": aux["z_loss"] / n_moe,
        "overflow_frac": aux["overflow_frac"] / n_moe,
        "tokens": cnt,
    }
    return loss, metrics


# ---------------------------------------------------------------- serving ---


def prefill(params, cfg: ModelConfig, batch, caches, unroll_units=False, kv_block: int = 1024):
    """Full-context forward that fills caches; returns (last-pos logits, caches)."""
    x, positions, _ = embed_inputs(params, cfg, batch)
    h, caches, _ = forward_hidden(params, cfg, x, positions, caches, "prefill", remat="none",
                                  unroll_units=unroll_units, kv_block=kv_block)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :], lm_head(params, cfg).astype(h.dtype))
    return logits.astype(jnp.float32), caches


def decode_step(params, cfg: ModelConfig, tokens, positions, caches, unroll_units=False):
    """tokens (B,1) int32, positions (B,1) int32 → (logits (B,V) f32, caches)."""
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    h, caches, _ = forward_hidden(params, cfg, x, positions, caches, "decode", remat="none",
                                  unroll_units=unroll_units)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :], lm_head(params, cfg).astype(h.dtype))
    return logits.astype(jnp.float32), caches


partial  # (linter guard)
