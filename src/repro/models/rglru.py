"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Temporal mixing block: x → {gate branch, recurrent branch}; recurrent branch
passes through a width-4 causal conv then the Real-Gated Linear Recurrent
Unit:

    r_t = σ(W_a x_t + b_a)                (recurrence gate)
    i_t = σ(W_x x_t + b_x)                (input gate)
    a_t = exp(c · softplus(Λ) · (-r_t))   (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is *linear* in h ⇒ computed with `jax.lax.associative_scan`
(log-depth — the TPU-friendly form), unlike the nonlinear xLSTM cells which
must time-scan.  Decode is the single-step transition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cache import make_rglru_state
from .layers import rms_norm
from .xlstm import _causal_conv1d

_C = 8.0  # paper's fixed decay sharpening constant


def init_rglru_block(key, d_model, lru_width, dtype):
    ks = jax.random.split(key, 7)
    std = d_model**-0.5
    stdl = lru_width**-0.5
    # Λ init so that a^(1/c) ∈ [0.9, 0.999] (paper's init)
    u = jax.random.uniform(ks[0], (lru_width,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2 * _C)) - 1.0)  # softplus^-1
    return {
        "ln": jnp.ones((d_model,), dtype),
        "w_y": (jax.random.normal(ks[1], (d_model, lru_width)) * std).astype(dtype),  # gate branch
        "w_x": (jax.random.normal(ks[2], (d_model, lru_width)) * std).astype(dtype),  # recurrent branch
        "conv": (jax.random.normal(ks[3], (4, lru_width)) * 0.1).astype(dtype),
        "w_a": (jax.random.normal(ks[4], (lru_width, lru_width)) * stdl).astype(dtype),
        "b_a": jnp.zeros((lru_width,), dtype),
        "w_i": (jax.random.normal(ks[5], (lru_width, lru_width)) * stdl).astype(dtype),
        "b_i": jnp.zeros((lru_width,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": (jax.random.normal(ks[6], (lru_width, d_model)) * stdl).astype(dtype),
    }


def _rglru_coeffs(p, u):
    """u: (B,S,W) conv'd branch → (a, b) with h_t = a_t h_{t-1} + b_t."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["w_a"]).astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["w_i"]).astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1-a²) with a clamp for numerical safety at a→1
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    b = mult * (i * u.astype(jnp.float32))
    return a, b


def rglru_block_forward(p, x, state=None):
    """x: (B,S,D) → (x + out, new_state). Residual applied inside."""
    B, S, D = x.shape
    xn = rms_norm(x, p["ln"], plus_one=True)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn, p["w_y"]))
    u = jnp.einsum("bsd,dw->bsw", xn, p["w_x"])
    if state is None:
        state = make_rglru_state(B, u.shape[-1])
    u, conv_tail = _causal_conv1d(u, p["conv"], state["conv"])
    a, b = _rglru_coeffs(p, u)
    # prepend carried state: h_t = a_t h_{t-1} + b_t  via associative scan
    # over pairs (a, b): (a2,b2)∘(a1,b1) = (a1·a2, a2·b1 + b2)
    a0 = jnp.concatenate([jnp.ones((B, 1, a.shape[-1]), a.dtype), a], axis=1)
    b0 = jnp.concatenate([state["h"][:, None, :].astype(a.dtype), b], axis=1)

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br

    aa, hh = jax.lax.associative_scan(combine, (a0, b0), axis=1)
    h = hh[:, 1:, :]  # drop the injected initial state row
    new_state = {"h": h[:, -1, :], "conv": conv_tail}
    out = jnp.einsum("bsw,wd->bsd", (h * gate.astype(jnp.float32)).astype(x.dtype), p["w_out"])
    return x + out, new_state


def rglru_block_step(p, x, state):
    """Single decode step. x: (B,1,D). Exact (conv tail carried in state)."""
    xn = rms_norm(x, p["ln"], plus_one=True)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn, p["w_y"]))
    u = jnp.einsum("bsd,dw->bsw", xn, p["w_x"])
    u, conv_tail = _causal_conv1d(u, p["conv"], state["conv"])
    a, b = _rglru_coeffs(p, u)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = jnp.einsum("bw,wd->bd", (h * gate[:, 0].astype(jnp.float32)).astype(x.dtype), p["w_out"])
    return x + out[:, None, :], {"h": h, "conv": conv_tail}
