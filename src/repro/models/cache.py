"""Decode-time state: KV caches (full + rolling sliding-window buffers) and
recurrent states (mLSTM / sLSTM / RG-LRU).

All caches are functional pytrees. KV caches carry explicit per-slot
positions (``pos``, -1 = empty) so rolling buffers and continuous batching
(per-row lengths) need no implicit arithmetic, and attention masking is
uniform (see layers.attention_forward).
"""

from __future__ import annotations

import jax
from .. import compat
import jax.numpy as jnp


def make_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int, dtype):
    """ptr is a *scalar* write cursor: the serving engine is slot-synchronous
    (every active row writes its token into the same ring slot each step;
    per-row raggedness lives entirely in `pos`).  A scalar index keeps the
    decode-time cache update a dynamic-update-slice that the SPMD
    partitioner handles as a masked local write on the owning shard — a
    per-row scatter would force a full all-gather/rematerialization of the
    sequence-sharded cache (measured: 34 GB/chip on qwen2-72b decode_32k)."""
    return {
        "k": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "ptr": jnp.zeros((), jnp.int32),  # total tokens ever written (scalar)
    }


def kv_cache_write_prefill(cache, k, v, positions):
    """Bulk write a prefix: k/v (B,S,KV,hd), positions (B,S). S ≤ capacity.
    For rolling buffers with S > capacity the last `capacity` tokens land
    (standard sliding-window prefill).  All paths are static slices/pads —
    never a partial dynamic update of the (sequence-sharded) cache, which
    the SPMD partitioner would handle by replicating the cache."""
    B, S = positions.shape
    C = cache["k"].shape[1]
    if S == C:
        kc, vc, pc = k, v, positions
    elif S < C:
        pad = ((0, 0), (0, C - S), (0, 0), (0, 0))
        kc = jnp.pad(k, pad)
        vc = jnp.pad(v, pad)
        pc = jnp.pad(positions, ((0, 0), (0, C - S)), constant_values=-1)
    else:
        kc = jax.lax.slice_in_dim(k, S - C, S, axis=1)
        vc = jax.lax.slice_in_dim(v, S - C, S, axis=1)
        pc = jax.lax.slice_in_dim(positions, S - C, S, axis=1)
    return {"k": kc, "v": vc, "pos": pc, "ptr": cache["ptr"] + S}


def kv_cache_append(cache, k, v, positions, ring_write: bool = False):
    """Append one token per row (decode). k/v (B,1,KV,hd), positions (B,1).
    Rolling ring buffer: every row writes slot = ptr mod capacity (scalar —
    see make_kv_cache); per-row positions go into `pos` at that slot.

    Two write paths (a plain dynamic-update-slice on the sequence-sharded
    dim is NOT one of them — GSPMD "involuntarily rematerializes" the whole
    cache for it, 34 GB/chip measured):

      ring_write=True (§Perf iteration B2): shard_map manual over the
        sequence-sharding axes — each shard slices ONE slot, selects between
        the new token and the existing row depending on ownership, and
        writes ONE slot back: O(B·KV·hd) traffic instead of O(cache).
      ring_write=False: one-hot masked select over the whole cache — the
        baseline (correct everywhere, 1 extra full cache read+write).
    """
    C = cache["k"].shape[1]
    slot = cache["ptr"] % C  # scalar
    if ring_write:
        from ..parallel.sharding import current_rules, mesh_axes, spec_for

        axes = mesh_axes()
        rule = current_rules().get("seq_kv")
        rule = (rule,) if isinstance(rule, str) else (rule or ())
        shard_axes = [a for a in rule if a in axes]
        if shard_axes:
            return _ring_write_sharded(cache, k, v, positions, slot, shard_axes)
    z = jnp.zeros((), jnp.int32)
    hit = (jnp.arange(C, dtype=jnp.int32) == slot)  # (C,)
    kc = jnp.where(hit[None, :, None, None], k.astype(cache["k"].dtype), cache["k"])
    vc = jnp.where(hit[None, :, None, None], v.astype(cache["v"].dtype), cache["v"])
    pc = jnp.where(hit[None, :], positions, cache["pos"])
    return {"k": kc, "v": vc, "pos": pc, "ptr": cache["ptr"] + 1}


def _ring_write_sharded(cache, k, v, positions, slot, shard_axes):
    """Owning-shard single-slot write under shard_map (manual over the
    cache's sequence-sharding axes, auto elsewhere)."""
    from jax.sharding import PartitionSpec as P

    mesh = compat.get_abstract_mesh()
    seq_spec = tuple(shard_axes) if len(shard_axes) > 1 else shard_axes[0]

    def body(kc, vc, pc, kn, vn, pn, slot_):
        # local views: kc (B, C_local, KV, hd); compute the local slot
        idx = jax.lax.axis_index(shard_axes[0])
        for a in shard_axes[1:]:
            idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        c_local = kc.shape[1]
        local = slot_ - idx * c_local
        owned = (local >= 0) & (local < c_local)
        li = jnp.clip(local, 0, c_local - 1)
        z = jnp.zeros((), jnp.int32)
        # read one slot, select, write one slot — O(token) traffic
        cur_k = jax.lax.dynamic_slice(kc, (z, li, z, z), (kc.shape[0], 1) + kc.shape[2:])
        cur_v = jax.lax.dynamic_slice(vc, (z, li, z, z), (vc.shape[0], 1) + vc.shape[2:])
        cur_p = jax.lax.dynamic_slice(pc, (z, li), (pc.shape[0], 1))
        new_k = jnp.where(owned, kn.astype(kc.dtype), cur_k)
        new_v = jnp.where(owned, vn.astype(vc.dtype), cur_v)
        new_p = jnp.where(owned, pn, cur_p)
        return (
            jax.lax.dynamic_update_slice(kc, new_k, (z, li, z, z)),
            jax.lax.dynamic_update_slice(vc, new_v, (z, li, z, z)),
            jax.lax.dynamic_update_slice(pc, new_p, (z, li)),
        )

    kv_spec = P(None, seq_spec, None, None)
    pos_spec = P(None, seq_spec)
    rep4 = P(None, None, None, None)
    kc, vc, pc = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(kv_spec, kv_spec, pos_spec, rep4, rep4, P(None, None), P()),
        out_specs=(kv_spec, kv_spec, pos_spec),
        axis_names=set(shard_axes),
        check_vma=False,
    )(cache["k"], cache["v"], cache["pos"], k, v, positions, slot)
    return {"k": kc, "v": vc, "pos": pc, "ptr": cache["ptr"] + 1}


# ----------------------------------------------------- recurrent states ----


def make_mlstm_state(batch, n_heads, d_k, d_v, d_conv, conv_k=4, dtype=jnp.float32, conv_dtype=jnp.float32):
    return {
        "C": jnp.zeros((batch, n_heads, d_k, d_v), dtype),  # matrix memory
        "n": jnp.zeros((batch, n_heads, d_k), dtype),  # normalizer
        "m": jnp.zeros((batch, n_heads), dtype),  # stabilizer (log-space)
        "conv": jnp.zeros((batch, conv_k - 1, d_conv), conv_dtype),  # causal-conv tail
    }


def make_slstm_state(batch, n_heads, head_dim, dtype=jnp.float32):
    return {
        "c": jnp.zeros((batch, n_heads, head_dim), dtype),
        "n": jnp.zeros((batch, n_heads, head_dim), dtype),
        "h": jnp.zeros((batch, n_heads, head_dim), dtype),
        "m": jnp.zeros((batch, n_heads, head_dim), dtype),
    }


def make_rglru_state(batch, width, conv_k=4, dtype=jnp.float32, conv_dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, width), dtype),
        "conv": jnp.zeros((batch, conv_k - 1, width), conv_dtype),  # causal-conv tail
    }
