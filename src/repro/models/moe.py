"""Mixture-of-Experts FFN with semaphore-based capacity admission.

Fine-grained MoE (DeepSeekMoE style): optional shared experts (always-on)
plus E routed experts with top-k routing.  The expert-capacity mechanism IS
the paper's batched ticket semaphore (`core.functional.take_batch_multi`):

  * every (token, routed-expert) assignment `take`s from that expert's
    semaphore (grant preloaded to the expert capacity);
  * the ticket returned is the token's **slot in the expert buffer** — the
    FAA-rank dispatch used by Switch-style MoE is literally a batched
    wait-free ticket issuance, so FCFS (token order) decides overflow
    deterministically — the paper's first-come-first-enabled admission;
  * non-admitted assignments are the "long-term waiters"; in a train step
    there is no later grant, so they take the residual path (dropped), and
    their count is surfaced as an aux metric (the queue-depth telemetry the
    ticket/grant pair gives for free).

Dispatch/return are scatter/gather by (expert, slot) indices — no dense
(N, E, cap) one-hot tensors, so it scales to 64 experts × 32k tokens.
Experts are sharded over the `model` mesh axis (expert parallelism).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.functional import make_multi_sema, take_batch_multi
from ..parallel.sharding import constrain
from .layers import rms_norm


def init_moe(key, d_model, n_experts, d_expert, top_k, n_shared, d_shared, dtype,
             n_experts_pad: int = 0):
    ks = jax.random.split(key, 5)
    std = d_model**-0.5
    ep = max(n_experts, n_experts_pad)  # EP padding (see configs/base.py)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts)) * std).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (ep, d_model, d_expert)) * std).astype(dtype),
        "wg": (jax.random.normal(ks[2], (ep, d_model, d_expert)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (ep, d_expert, d_model)) * d_expert**-0.5).astype(dtype),
    }
    if n_shared > 0:
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": (jax.random.normal(k1, (d_model, d_shared)) * std).astype(dtype),
            "wg": (jax.random.normal(k2, (d_model, d_shared)) * std).astype(dtype),
            "wo": (jax.random.normal(k3, (d_shared, d_model)) * d_shared**-0.5).astype(dtype),
        }
    return p


def moe_forward(p, x, *, top_k: int, capacity_factor: float = 1.25, router_z_weight: float = 1e-3):
    """x: (B,S,D) → (out (B,S,D), aux dict with load-balance loss + overflow).

    Capacity admission via the batched multi-semaphore (FCFS token order).

    Dispatch is GROUP-wise (gshard-style, §Perf iteration 4): tokens are
    split into G = dp data-parallel groups with per-group expert buffers
    (G, E, cap/G, D) sharded (G→data, E→model).  Each group's scatter and
    FCFS semaphore admission stay local to its data shard — a single global
    buffer (E, cap, D) has no data axis, so GSPMD replicated it across the
    data axis and paid cross-data all-reduces of the whole dispatch buffer
    every layer (measured: dominant collective on deepseek/granite train).
    Per-group FCFS capacity (cap/G per expert per group) is the standard
    gshard semantics; G=1 (single device / tests) is bit-identical to the
    global form.
    """
    from ..parallel.sharding import mesh_axes

    B, S, D = x.shape
    E = p["router"].shape[-1]
    N = B * S
    xt = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (N,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- group split: G = data-parallel degree that divides N ---------------
    axes = mesh_axes()
    G = axes.get("pod", 1) * axes.get("data", 1)
    while N % G:
        G -= 1
    Ng = N // G
    capacity = int(max(top_k, round(Ng * top_k / E * capacity_factor)))

    # --- semaphore admission: one take per (token, expert) assignment, -----
    #     per group (vmapped batched multi-semaphore; FCFS within group)
    flat_e = gate_idx.reshape(G, Ng * top_k)  # row order == token order == FCFS
    sema = make_multi_sema(jnp.full((G, E), capacity, jnp.uint32))
    _, tickets, admitted = jax.vmap(take_batch_multi)(
        sema, flat_e, jnp.ones((G, Ng * top_k), bool))
    slots = tickets.astype(jnp.int32)  # ticket == buffer slot, by construction

    # --- dispatch: per-group scatter into (G, E_pad, cap, D) buffers --------
    E_pad = p["wi"].shape[0]  # ≥ E (EP padding)
    tok_idx = jnp.repeat(jnp.arange(Ng, dtype=jnp.int32), top_k)
    e_safe = jnp.where(admitted, flat_e, E_pad)  # out-of-range ⇒ dropped
    s_safe = jnp.where(admitted, slots, capacity)
    xg = xt.reshape(G, Ng, D)

    def scatter_group(xg_, e_, s_):
        return jnp.zeros((E_pad, capacity, D), x.dtype).at[e_, s_].set(
            xg_[tok_idx], mode="drop")

    buf = jax.vmap(scatter_group)(xg, e_safe, s_safe)
    buf = constrain(buf, "batch", "experts")

    # --- expert computation (G→data, E→model: DP × EP) ----------------------
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["wi"])
    h = constrain(h, "batch", "experts")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"])  # (G,E,cap,D)
    out_buf = constrain(out_buf, "batch", "experts")

    # --- return path: per-group gather by (expert, slot), weight, combine ---
    gv = (gate_vals.reshape(G, Ng * top_k)[..., None] * admitted[..., None])

    def combine_group(ob_, e_, s_, w_):
        per_assign = ob_[e_, s_] * w_.astype(x.dtype)
        return jnp.zeros((Ng, D), x.dtype).at[tok_idx].add(per_assign, mode="drop")

    out = jax.vmap(combine_group)(out_buf, e_safe, s_safe, gv).reshape(N, D)

    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(jnp.einsum("nd,df->nf", xt, sh["wg"])) * jnp.einsum("nd,df->nf", xt, sh["wi"])
        out = out + jnp.einsum("nf,fd->nd", hs, sh["wo"])

    # --- aux losses / telemetry ---------------------------------------------
    # Switch-style load balance: E · Σ_e f_e · P_e
    me = jnp.mean(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(1), axis=0)  # token fraction per e
    ce = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(me * ce) / top_k
    z_loss = router_z_weight * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    overflow = 1.0 - jnp.mean(admitted.astype(jnp.float32))  # semaphore queue telemetry
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "overflow_frac": overflow}
    return out.reshape(B, S, D), aux


def moe_block_forward(p, x, *, top_k, capacity_factor=1.25):
    """Full block: pre-norm MoE-FFN with residual (attention part handled by
    the generic attn machinery in transformer.py)."""
    xn = rms_norm(x, p["ln"])
    out, aux = moe_forward(p["moe"], xn, top_k=top_k, capacity_factor=capacity_factor)
    return x + out, aux
