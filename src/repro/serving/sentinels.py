"""In-scan invariant sentinels — the engine's health bitmask.

The whole serving stack rests on the paper's counter identities (grant −
ticket = available units at every one of the three semaphore
granularities) and on the block-pool partition invariant (free queue ∪
live block tables = {0..NB−1}, nothing lost, nothing aliased).  PRs 3–6
*trust* those invariants; this module **checks** them, every scanned
round, inside the megastep itself — a corrupted counter, leaked block,
dropped poke, or NaN'd KV block is visible in the SAME single host sync
that drains the telemetry ring, instead of surfacing rounds later as a
wedged slot or a silent deadlock.

Each round emits one ``uint32`` health bitmask (0 = healthy) carried in
:class:`~repro.serving.engine_state.TelemetrySample.health`.  The bits
split into two tiers:

**Mirrored bits** (low 16, ``HEALTH_MIRRORED_MASK``) — checks computable
identically from the host `step()` bookkeeping and from the scanned
device state, so the repo's bit-identity property (megastep ring ≡ K
host samples, tests/test_obs.py) extends to the health field:

  * ``H_SLOT_CONSERVE`` — the free-slot semaphore's counter identity
    broke: ``grant − ticket ≠ S − busy`` (a slot was lost or double
    granted);
  * ``H_CREDIT_NEG``   — some tenant's QoS credit ``grant − consumed``
    went negative (admission spent credit that was never granted);
  * ``H_KV_CONSERVE``  — the block semaphore's free count plus the
    blocks held by the slot tables no longer equals the pool size (a
    leaked or double-released block, a corrupted counter);
  * ``H_BANKER``       — the no-deadlock headroom invariant is violated:
    the Banker chain's required headroom exceeds the free pool (some
    parked slot may now never resume) — chunked mode only;
  * ``H_STUCK``        — stuck-slot watchdog: some busy slot has made no
    progress (no token emitted, no prefill chunk landed) for ≥ W
    consecutive rounds (``watchdog=W``; 0 disables).  A dropped poke or
    a silently wedged sequence trips this even when every counter still
    balances.

**Deep bits** (high 16) — device-side ground-truth checks the host
mirrors cannot reproduce without a sync (the host keeps counters, not
block *identities*); healthy runs emit 0 on both paths so bit-identity
is preserved, and the chaos equivalence property masks them with
``HEALTH_MIRRORED_MASK``:

  * ``H_KV_PARTITION`` — the full partition audit: the multiset
    {free-queue region} ∪ {live table entries} must be exactly
    {0..NB−1}.  Catches aliasing (one block in two tables, or live AND
    free) that a pure count can miss;
  * ``H_NAN``          — a non-finite value appeared in a float leaf of
    the model pytree (KV pools, weights): the classic silent-corruption
    mode of long-running decode.  The host `step()` path sets the same
    bit from its own logits.

The recovery ladder (`repro.resilience.recovery`) maps bits to rungs:
``H_STUCK``/``H_NAN`` → quarantine the sick slot; ``H_KV_CONSERVE`` /
``H_KV_PARTITION`` / ``H_CREDIT_NEG`` → audit-and-rebuild from
block-table ground truth; repeated divergence on the fused kernel path →
functional fallback; anything unrecoverable → snapshot restore.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..admission.functional_qos import block_headroom
from ..core.functional import _sdist, pool_free_count
from .prefill import banker_order

# ---- mirrored bits (host step() computes the identical value) --------------
H_SLOT_CONSERVE = 1 << 0   # free-slot sema: grant − ticket ≠ S − busy
H_CREDIT_NEG = 1 << 1      # some tenant credit grant − consumed < 0
H_KV_CONSERVE = 1 << 2     # block sema: free + held ≠ pool size
H_BANKER = 1 << 3          # Banker headroom > free pool (deadlock risk)
H_STUCK = 1 << 4           # watchdog: no progress for ≥ W rounds

# ---- deep bits (device ground truth; host emits 0 — masked in equivalence)
H_KV_PARTITION = 1 << 16   # free queue ∪ tables ≠ {0..NB−1} (aliasing)
H_NAN = 1 << 17            # non-finite value in a model float leaf

HEALTH_MIRRORED_MASK = 0xFFFF

HEALTH_BITS = {
    "slot_conserve": H_SLOT_CONSERVE,
    "credit_neg": H_CREDIT_NEG,
    "kv_conserve": H_KV_CONSERVE,
    "banker": H_BANKER,
    "stuck": H_STUCK,
    "kv_partition": H_KV_PARTITION,
    "nan": H_NAN,
}


def decode_health(mask: int) -> list[str]:
    """Human-readable view of a health bitmask (telemetry/log rendering)."""
    return [name for name, bit in HEALTH_BITS.items() if int(mask) & bit]


def _bit(cond, bit):
    return jnp.where(cond, jnp.uint32(bit), jnp.uint32(0))


def kv_partition_violated(kv) -> jax.Array:
    """Ground-truth partition audit of the block pool (bool scalar), in
    its refcounted generalization (PR 9):

        {free_q[ticket..grant)} ∪ {blocks with refcnt > 0} = {0..NB−1}
        per-block table references == refcnt

    With no sharing every refcount is 0 or 1 and table refs == refcnt
    pins each live block to exactly ONE table entry — the PR-4 one-owner
    partition as a special case; so the generalized audit replaces it
    unconditionally.  A double-release (refcnt untouched, id re-enqueued
    — `resilience.faults`) puts an id both free and live (sum 2); a
    decref of a never-held reference drives refcnt negative (≠ the
    non-negative table count); aliasing one private block into two
    tables breaks the reference equality.  O(NB + S·MB) — bincounts,
    cheap enough to run every scanned round."""
    NB = kv.pool.free_q.shape[0]
    free_n = pool_free_count(kv.pool)
    bad = (free_n < 0) | (free_n > NB)
    n = jnp.clip(free_n, 0, NB).astype(jnp.uint32)
    pos = jnp.arange(NB, dtype=jnp.uint32)
    in_free = pos < n
    qidx = ((kv.pool.sema.ticket + pos) & jnp.uint32(NB - 1)).astype(jnp.int32)
    fid = kv.pool.free_q[qidx]
    ok_f = in_free & (fid >= 0) & (fid < NB)
    bad |= jnp.any(in_free & ~ok_f)                 # free id out of range
    cnt = jnp.zeros((NB,), jnp.int32).at[
        jnp.where(ok_f, fid, 0)].add(ok_f.astype(jnp.int32))
    tid = kv.tbl.reshape(-1)
    ok_t = (tid >= 0) & (tid < NB)
    bad |= jnp.any(tid >= NB)                       # table id out of range
    refs = jnp.zeros((NB,), jnp.int32).at[
        jnp.where(ok_t, tid, 0)].add(ok_t.astype(jnp.int32))
    live = (kv.pool.refcnt > 0).astype(jnp.int32)
    return (bad | jnp.any(cnt + live != 1)          # partition broken
            | jnp.any(refs != kv.pool.refcnt))      # refs ≠ refcnt


def model_nonfinite(model) -> jax.Array:
    """True iff any float leaf of the model pytree holds a NaN/Inf."""
    bad = jnp.zeros((), bool)
    for leaf in jax.tree_util.tree_leaves(model):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                     jnp.floating):
            bad |= ~jnp.all(jnp.isfinite(leaf))
    return bad


def round_health(state, model, round_no, *, block_size: int = 0,
                 chunked: bool = False, watchdog: int = 0) -> jax.Array:
    """The per-round health bitmask, computed in-graph over the
    POST-round engine state (step 6 of `engine_state.engine_round`).
    ``round_no`` is the round being sampled (the watchdog's clock).
    Returns a ``uint32`` scalar; 0 = every invariant holds."""
    sl = state.slots
    S = sl.busy.shape[0]
    active = jnp.sum(sl.busy.astype(jnp.int32))
    h = _bit(_sdist(state.slot_sema.grant, state.slot_sema.ticket)
             != S - active, H_SLOT_CONSERVE)
    h |= _bit(jnp.any(_sdist(state.qos.grant, state.qos.consumed) < 0),
              H_CREDIT_NEG)
    if state.kv is not None:
        sharing = state.kv.cache is not None
        NB = state.kv.pool.free_q.shape[0]
        if sharing:
            # refcounted conservation: free + live (refcnt > 0) = NB —
            # held table entries over-count shared blocks, the refcount
            # support is the real allocated set
            held = jnp.sum((state.kv.pool.refcnt > 0).astype(jnp.int32))
        else:
            held = jnp.sum((state.kv.tbl >= 0).astype(jnp.int32))
        h |= _bit(pool_free_count(state.kv.pool) + held != NB,
                  H_KV_CONSERVE)
        h |= _bit(kv_partition_violated(state.kv), H_KV_PARTITION)
        if chunked:
            held_s = jnp.sum((state.kv.tbl >= 0).astype(jnp.int32), axis=1)
            from .engine_state import _share_flags, _slot_rem  # no cycle

            rem = _slot_rem(sl, held_s, block_size)
            cover = held_s
            if sharing:
                # a pending copy-on-write still owes one block; only
                # privately-held blocks fund the Banker cover
                cow, held_free = _share_flags(
                    state.kv.tbl, state.kv.pool.refcnt, sl.busy, sl.pos,
                    sl.plen, held_s, block_size)
                rem = rem + jnp.where(cow, 1, 0)
                cover = held_free
            need = block_headroom(
                rem, cover,
                banker_order(rem, sl.prio_r, sl.prio_k, sl.busy), sl.busy)
            h |= _bit(need > pool_free_count(state.kv.pool), H_BANKER)
    if watchdog > 0:
        h |= _bit(jnp.any(sl.busy
                          & (round_no - sl.last_adv >= watchdog)), H_STUCK)
    h |= _bit(model_nonfinite(model), H_NAN)
    return h


def host_round_health(*, n_slots: int, free_slots: int, active: int,
                      credit, paged: bool = False, kv_free: int = 0,
                      kv_held: int = 0, kv_blocks: int = 0,
                      chunked: bool = False, headroom: int = 0,
                      stuck: bool = False,
                      nonfinite: bool = False) -> int:
    """Host mirror of :func:`round_health`'s MIRRORED bits, computed from
    the scheduler's pure-host bookkeeping (`scheduler._host_sample`) —
    plus ``H_NAN`` from the host path's own logits.  Healthy rounds
    produce 0 on both paths, so the telemetry bit-identity property
    covers the health field; deep device-side bits are host-0 by
    definition (module docstring)."""
    h = 0
    if free_slots != n_slots - active:
        h |= H_SLOT_CONSERVE
    if any(int(c) < 0 for c in credit):
        h |= H_CREDIT_NEG
    if paged:
        if kv_free + kv_held != kv_blocks:
            h |= H_KV_CONSERVE
        if chunked and headroom > kv_free:
            h |= H_BANKER
    if stuck:
        h |= H_STUCK
    if nonfinite:
        h |= H_NAN
    return h
