"""Continuous chunked prefill — incremental block allocation on the TWA
block semaphore, planned by one fused priority scan.

PR 4's pool admits on *worst-case* block demand: a sequence reserves
``⌈(prompt_len + max_new)/BS⌉`` blocks up front, so long prompts lock out
the pool long before they have written a single token, and a prompt that
does not fit the per-slot table cannot be served at all.  This module is
the paper's move applied one more time: just as TWA turns the ticket
lock's global spin into bounded waiting-array waits, a mid-sequence block
shortage becomes a **parked slot on the block semaphore's waiting array**
(`core.functional.pool_try_alloc` / `park_state`) instead of an
admission-time over-reservation.  Blocks are acquired exactly when a
sequence crosses a block boundary:

  * admission gates on **first-chunk demand only**
    (:func:`first_chunk_demand` through `functional_qos.block_gate`);
  * every engine round co-schedules prompt chunks with decode,
    Sarathi-style, under a per-round prefill **token budget**
    (:func:`chunk_plan`) — long prompts stream through the engine without
    ever monopolizing a round;
  * on pool exhaustion a slot **parks**: it observes the TWAHash bucket of
    the future grant value that would make it runnable and is re-examined
    only when a release pokes that bucket (`core.functional.park_state`)
    — resumed FCFS, because releases enable tickets in cursor order.

No-deadlock invariant (the reserved-headroom check)
---------------------------------------------------

Incremental allocation can deadlock: if every running slot parks waiting
for blocks only other parked slots would release, nobody finishes.  The
planner prevents it with a Banker-style safety invariant over the slots
in **safety-chain order** (ascending remaining demand — nearest
completion first, admission order as tiebreak; :func:`banker_order`
derives why this order needs the least reserve):

    rem_i  ≤  free  +  Σ_{j<i} held_j       for every live slot i,   (I)

where ``rem_i`` is slot i's worst-case remaining block demand and
``held_j`` the blocks j already holds.  (I) says: even if no new blocks
ever appear, slot i can finish once its priority-predecessors finish and
release.  The priority-first slot can then always take (rem₁ ≤ free), so
it never parks; it finishes, releases, and hands the cover down — every
parked slot is eventually resumed, strictly FCFS.

(I) is maintained at both places blocks leave the pool:

  * **admission** — `functional_qos.block_gate` admits first chunks only
    into ``free − headroom`` where ``headroom = max(0, max_i(rem_i −
    Σ_{j<i} held_j))`` (`functional_qos.block_headroom`): a newcomer
    (appended last in priority order, its own (I) condition being
    ``demand ≤ NB`` — enforced at submit) can never eat the reserve;
  * **every incremental take** — :func:`chunk_plan` grants a take by slot
    s only while every earlier-priority slot's margin survives it; the
    margin recurrence (min over prefix of ``free + Σheld + Σtake + take_j
    − rem_j``) is exactly (I) rewritten so one `lax.scan` over the S
    sorted slots decides all takes, the budget split, and the park set in
    a single pass.

The planner is pure JAX and is THE single source of truth for all three
engine paths: `serving.engine_state.engine_round` calls it inside the
scanned megastep, and the host `ContinuousBatchingEngine.step()` (both
QoS modes) calls the same jitted function on its per-request state — the
paths stay bit-identical by construction (property-tested in
tests/test_chunked_prefill.py).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

INT32_MAX = jnp.iinfo(jnp.int32).max


def cdiv(a, b: int):
    return (a + b - 1) // b


def first_chunk_demand(prompt_len, chunk: int, block_size: int):
    """Blocks the FIRST prefill chunk of a prompt needs — what chunked
    admission gates on (vs the worst-case ``⌈(plen+max_new)/BS⌉`` of the
    up-front mode): ``⌈min(chunk, plen)/BS⌉``, at least one block."""
    return jnp.maximum(cdiv(jnp.minimum(jnp.asarray(prompt_len, jnp.int32),
                                        chunk), block_size), 1)


def total_block_demand(prompt_len, max_new, block_size: int):
    """Worst-case whole-lifetime block demand of a sequence (every token it
    can ever hold) — the ``rem + held`` bound the safety invariant tracks."""
    return jnp.maximum(cdiv(jnp.asarray(prompt_len, jnp.int32)
                            + jnp.asarray(max_new, jnp.int32), block_size), 1)


def shared_first_chunk_demand(prompt_len, cov, chunk: int, block_size: int):
    """Post-divergence first-chunk demand — what chunked admission gates
    on when a prompt's leading ``cov`` tokens are already resident in
    shared blocks (prefix cache hit; ``cov`` is block-aligned unless it
    covers the whole prompt).  Only the tokens past the divergence point
    need fresh blocks: ``⌈min(chunk, plen − cov)/BS⌉``.  A fully-covered
    prompt with a shared partial tail block needs ZERO blocks to start
    (its first decode writes land in the shared tail, copy-on-write); a
    fully-covered block-aligned prompt needs one (its first decode write
    opens a fresh block).  Reduces to :func:`first_chunk_demand` at
    ``cov = 0``."""
    plen = jnp.asarray(prompt_len, jnp.int32)
    cov = jnp.asarray(cov, jnp.int32)
    aligned = cov == (cov // block_size) * block_size
    return jnp.where(
        cov >= plen,
        jnp.where(aligned & (cov >= plen), jnp.int32(1), jnp.int32(0)),
        jnp.maximum(cdiv(jnp.minimum(plen - cov, chunk), block_size), 1))


def pending_prompt_tokens(pos: jax.Array, plen: jax.Array,
                          busy: jax.Array) -> jax.Array:
    """Prompt tokens still waiting to be prefilled across the busy slots —
    the chunked-prefill backpressure gauge (how far the per-round token
    budget is behind demand).  Decoding slots (``pos ≥ plen``) contribute
    zero, so the same formula is correct in the up-front modes (where it
    is identically 0).  i32 scalar."""
    return jnp.sum(jnp.where(busy, jnp.maximum(
        jnp.asarray(plen, jnp.int32) - jnp.asarray(pos, jnp.int32), 0), 0))


def banker_order(rem: jax.Array, prio_round: jax.Array, prio_key: jax.Array,
                 active: jax.Array) -> jax.Array:
    """The canonical safety-chain permutation: ascending (remaining
    worst-case demand, admission round, packed FCFS admission key, slot
    index), inactive rows last — **nearest-completion first**.

    For a single resource type this is Banker's optimal order: if ANY
    completion order satisfies the chain condition ``rem_i ≤ free +
    Σ_{j<i} held_j``, the ascending-remaining order does (exchange
    argument — swapping an out-of-order adjacent pair never shrinks a
    prefix's cover).  Checking and preserving the invariant against THIS
    order therefore reserves the least possible headroom: the slot
    closest to completion is the one the reserve protects, it finishes
    soonest, and its release funds the next link — whereas an
    admission-ordered chain would park the whole engine behind the
    oldest slot's outstanding tail.  Nearly-done (decoding) slots also
    take before hungry young prefills — the decode-prioritized schedule
    Sarathi-style co-scheduling wants.  FCFS is untouched where it is a
    fairness guarantee: ADMISSION order (the gate) and waiting-array
    WAKE order stay strictly ticket-FCFS; the chain only orders block
    takes by safety.

    Admission never breaks the chain regardless of where a newcomer's
    demand would insert: with the newcomer appended last the chain holds
    trivially (``demand ≤ NB`` — the submit-time check), so by the
    exchange argument the ascending order of the post-admission state
    holds too.

    Implemented as stable composed argsorts (a lexsort); pure function of
    ints, so host and device compute identical permutations."""
    key3 = jnp.where(active, jnp.asarray(prio_key, jnp.int32), INT32_MAX)
    key2 = jnp.where(active, jnp.asarray(prio_round, jnp.int32), INT32_MAX)
    key1 = jnp.where(active, jnp.asarray(rem, jnp.int32), INT32_MAX)
    o3 = jnp.argsort(key3, stable=True)
    o2 = jnp.argsort(key2[o3], stable=True)
    o = o3[o2]
    o1 = jnp.argsort(key1[o], stable=True)
    return o[o1]


class ChunkPlan(NamedTuple):
    """Per-slot outcome of one round's fused budget + Banker scan (all in
    UNSORTED slot order)."""

    take: jax.Array     # (S,) i32 — blocks granted this round
    tokens: jax.Array   # (S,) i32 — prefill tokens to write this round
    parked: jax.Array   # (S,) bool — block-stalled (park on the waiting array)
    deficit: jax.Array  # (S,) i32 — grant advance that makes a parked slot
    #                     runnable again (≥ 1 where parked; park_state input)
    emit: jax.Array     # (S,) bool — decode-ready this round (post-take)
    cow: jax.Array      # (S,) bool — granted a copy-on-write block this
    #                     round: the take REPLACES the slot's current write
    #                     block (copy shared portion, decref the original)


@functools.partial(jax.jit, static_argnames=("chunk", "budget", "block_size"))
def chunk_plan(order: jax.Array, busy: jax.Array, parked: jax.Array,
               woken: jax.Array, pos: jax.Array, plen: jax.Array,
               max_new: jax.Array, held: jax.Array, free, cow, held_free,
               *, chunk: int, budget: int, block_size: int) -> ChunkPlan:
    """Plan one engine round of continuous chunked prefill: split the
    per-round prefill token ``budget`` over the prefilling slots, decide
    every incremental block take (prefill chunks AND decode block-boundary
    crossings), and park the block-stalled slots — one `lax.scan` over the
    slots in priority ``order`` (see :func:`banker_order`).

    Per sorted slot the scan carries ``(T, minM, budget_left)`` — blocks
    taken so far, the running Banker margin, and the unspent token budget:

      * a *prefilling* slot (``pos < plen``) wants ``min(chunk, plen−pos,
        budget_left)`` tokens and the blocks to hold them; it accepts
        PARTIAL grants (fewer blocks ⇒ a shorter chunk — Sarathi-style
        degradation instead of all-or-nothing stalls);
      * a *decoding* slot needs one block exactly when its write cursor
        hits its capacity (``pos == held·BS``) — atomic (a token cannot be
        split);
      * a take by slot s is capped at ``min(free, min_{j<s} M_j) − T``
        where ``M_j = free + Σheld_{<j} + Σtake_{<j} + take_j − rem_j`` —
        the safety-invariant margin (module docstring): s may consume free
        blocks only while every earlier-priority slot could still finish
        on ``free + what its predecessors hold``;
      * a slot that needed progress and got NO tokens/blocks is **parked**
        with the grant deficit that would unblock it; parked slots whose
        waiting-array bucket has not moved (``~woken``) skip the attempt
        entirely — the no-global-spinning analogue (their demand still
        shapes the margin: parked ≠ forgotten by the invariant).

    ``woken`` is ignored for non-parked slots.  Budget is consumed by
    realized tokens only (work conservation: blocks denied ⇒ budget flows
    to the next slot).  Decode does not consume budget (the schedule is
    decode-maximal: every decode-ready slot decodes every round).

    Prefix sharing (PR 9) adds two inputs.  ``cow`` (S,) bool flags a
    decode-ready slot whose NEXT write block is shared (``refcnt > 1`` —
    a prefix-cache tail it attached to): before it may emit it needs one
    private block — an atomic 1-block take exactly like a boundary
    crossing, except the grant REPLACES the current write block (the
    engine copies the shared portion and decrefs the original).  A
    pending copy-on-write raises the slot's remaining demand by one (the
    swap consumes a free block without shrinking ``total − held``).
    ``held_free`` (S,) i32 is each slot's RELEASABLE held count — only
    privately-held blocks (``refcnt == 1``) return to the pool when the
    slot finishes, so the Banker chain's ``Σ held`` cover must count
    those alone (a shared block's free is funded by its LAST sharer,
    which the chain conservatively ignores).  With no sharing enabled
    (``cow`` all-False, ``held_free == held``) every formula reduces to
    the PR-5 plan bit-identically.  Returns a :class:`ChunkPlan` in
    unsorted slot order.
    """
    BS = block_size
    S = busy.shape[0]
    free = jnp.asarray(free, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    plen = jnp.asarray(plen, jnp.int32)
    held = jnp.asarray(held, jnp.int32)
    cow_in = jnp.asarray(cow, bool)
    held_free = jnp.asarray(held_free, jnp.int32)
    rem = (total_block_demand(plen, max_new, BS) - held
           + jnp.where(cow_in, 1, 0))
    trying = busy & (~parked | woken)
    prefilling = busy & (pos < plen)

    held_b = jnp.where(busy, held_free, 0)[order]
    cum_held = jnp.cumsum(held_b) - held_b  # A_j: Σ held of priority-preds
    xs = (cum_held,) + tuple(a[order] for a in (busy, trying, prefilling,
                                                pos, plen, held, rem,
                                                cow_in))

    def body(carry, x):
        T, minM, budget_left = carry
        A, b, t, pf, p, pl, h, r, cw = x
        want = jnp.where(pf & t, jnp.minimum(chunk, pl - p), 0)
        ctb = jnp.minimum(want, budget_left)
        need_pf = jnp.maximum(cdiv(p + ctb, BS) - h, 0)
        dec_try = b & ~pf & t & (p >= h * BS)
        cow_try = b & ~pf & t & cw & (p < h * BS)
        atomic = dec_try | cow_try              # one block, all-or-nothing
        need = jnp.where(pf, need_pf, jnp.where(atomic, 1, 0))
        cap = jnp.minimum(free, minM) - T
        take = jnp.where(pf, jnp.clip(cap, 0, need),
                         jnp.where(atomic & (need <= cap), need, 0))
        ct = jnp.where(pf, jnp.minimum(ctb, (h + take) * BS - p), 0)
        newly = t & ((pf & (ctb > 0) & (ct == 0))
                     | (atomic & (take == 0)))
        deficit = jnp.where(newly, 1 - jnp.minimum(cap, 0), 0)
        # this slot's margin for every LATER taker: M_j = free + A_j + T_j
        # + take_j − rem_j (invariant (I) rearranged; T is the exclusive
        # cumulative take carried in)
        M = jnp.where(b, free + A + T + take - r, INT32_MAX)
        carry = (T + take, jnp.minimum(minM, M), budget_left - ct)
        return carry, (take, ct, newly, deficit, cow_try & (take > 0))

    (_, _, _), (take_s, ct_s, park_s, def_s, cow_s) = jax.lax.scan(
        body, (jnp.int32(0), jnp.int32(INT32_MAX), jnp.int32(budget)), xs)

    inv = jnp.zeros((S,), jnp.int32).at[order].set(
        jnp.arange(S, dtype=jnp.int32))
    take = take_s[inv]
    tokens = ct_s[inv]
    deficit = def_s[inv]
    still_parked = busy & parked & ~woken
    parked_out = park_s[inv] | still_parked
    # a slot with a pending copy-on-write may not emit until granted (its
    # write would land in the shared block); all other decode-ready slots
    # emit exactly as before
    emit = (busy & ~prefilling & (pos < (held + take) * BS)
            & (~cow_in | (take > 0)))
    return ChunkPlan(take=take, tokens=tokens, parked=parked_out,
                     deficit=deficit, emit=emit, cow=cow_s[inv])
