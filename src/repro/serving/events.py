"""Trace-event kinds for the per-round in-scan event table (PR 10).

Pure Python on purpose — the ``obs`` package (span builder, flight
recorder, exporters) imports these constants without pulling in jax, and
`serving.engine_state` uses the same values inside the scanned round, so
the device table and every host consumer agree on the encoding.

Two families share one namespace:

* **engine events** (``EV_ADMIT`` … ``EV_QUARANTINE``) are emitted by the
  engine round — on device via the fixed-shape event table riding the
  :class:`~repro.serving.engine_state.TelemetryRing` (drained in the
  megastep's ONE host sync), and bit-identically by the host ``step()``
  bookkeeping (tests/test_obs.py);
* **fabric events** (``EV_SUBMIT`` … ``EV_EXPIRE``) only ever exist on
  the host — enqueue, routing, migration, and load-shed decisions the
  device never sees — and are appended straight to the host
  :class:`~repro.obs.trace.TraceBuffer` so spans stitch across replicas.

Each event is ``(kind, uid, slot, arg)``; the virtual clock is the
enclosing round's ``now`` (every event in a round shares it).  ``uid`` is
the request id (cluster-level rid across the router), ``slot`` the engine
slot (the admission lane index for ADMIT/PREFIX_ATTACH, the replica index
for fabric events, −1 when not applicable), and ``arg`` the per-kind
payload listed below.
"""

EV_NONE = 0           # padding in the fixed-shape table
EV_ADMIT = 1          # backlog row granted a slot      arg = prompt_len
EV_PREFILL_CHUNK = 2  # prompt chunk landed             arg = chunk tokens
EV_PARK = 3           # slot parked on the block TWA    arg = block deficit
EV_RESUME = 4         # parked slot woken + granted     arg = 0
EV_PREFIX_ATTACH = 5  # cache-covered prefix attached   arg = covered tokens
EV_COW = 6            # copy-on-write take              arg = replaced block id
EV_PREEMPT = 7        # running slot deadline-preempted arg = tokens emitted
EV_FINISH = 8         # slot completed (hit max_new)    arg = tokens emitted
EV_QUARANTINE = 9     # recovery rung 1 evicted a slot  arg = blocks released
EV_SUBMIT = 10        # request entered a queue         arg = 0
EV_ROUTE = 11         # router bound request → replica  arg = lease ticket
EV_MIGRATE = 12       # request requeued off a dead replica  arg = attempt #
EV_SHED = 13          # router dropped the request      arg = 0
EV_EXPIRE = 14        # backlog deadline tombstone      arg = 0

EVENT_NAMES = {
    EV_NONE: "NONE",
    EV_ADMIT: "ADMIT",
    EV_PREFILL_CHUNK: "PREFILL_CHUNK",
    EV_PARK: "PARK",
    EV_RESUME: "RESUME",
    EV_PREFIX_ATTACH: "PREFIX_ATTACH",
    EV_COW: "COW",
    EV_PREEMPT: "PREEMPT",
    EV_FINISH: "FINISH",
    EV_QUARANTINE: "QUARANTINE",
    EV_SUBMIT: "SUBMIT",
    EV_ROUTE: "ROUTE",
    EV_MIGRATE: "MIGRATE",
    EV_SHED: "SHED",
    EV_EXPIRE: "EXPIRE",
}

# The fixed per-round table is 8 lane-major segments of S entries each, in
# phase order (matching the engine round's phase numbering) — compaction
# in `engine_state.engine_round` preserves this order, and the host
# `step()` appends its per-kind event lists in the same order, so the two
# drained streams compare with ``==``.
SCAN_SEGMENTS = (EV_PREEMPT, EV_ADMIT, EV_PREFIX_ATTACH, EV_PARK,
                 EV_RESUME, EV_PREFILL_CHUNK, EV_COW, EV_FINISH)

# Terminal kinds: a well-formed span ends with exactly one of these.
TERMINAL_EVENTS = (EV_FINISH, EV_PREEMPT, EV_SHED, EV_EXPIRE)
