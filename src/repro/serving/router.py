"""Fault-tolerant replica router — the paper's semaphore at its fourth
granularity: **cluster admission**.

PRs 1–7 put the TWA (ticket, grant) pair under slots, tenant credit and
KV blocks inside ONE engine.  This module spreads requests across N
`ContinuousBatchingEngine` replicas and reuses the same construct one
level up: every replica's in-flight capacity is a
`runtime.coordinator.DistributedTicketLease` —

* **grant − ticket = replica headroom** is the routing signal (the
  router binds each request to the max-headroom live replica);
* a bound-but-unadmitted request IS a lease waiter: it holds a ticket,
  renews a heartbeat, and is admitted FCFS when completions advance the
  grant — the lease's hashed buckets gate the router's re-polls (one
  grant read only when the request's bucket was poked or it is near the
  head), so a thousand queued requests don't herd one KV key;
* a replica that dies leaks its tickets; the `runtime.reaper.LeaseReaper`
  tombstones stale waiters and force-releases stale holders, so the
  grant sequence is ALWAYS clean at exit.

Failure handling (the robustness contract):

* **detection** — missed coordinator heartbeats past the TTL, reaped
  lease tickets, or (for dispatch avoidance) a sick PR-7 sentinel
  bitmask feeding the per-replica circuit breaker;
* **exactly-once migration** — a dead replica's in-flight requests are
  re-cloned onto healthy replicas under the router's request-id dedupe:
  the first attempt to complete wins, later duplicates (e.g. a zombie
  replica on the far side of a KV partition) are suppressed, and a
  request is never delivered twice nor lost.  Requests the dead
  replica's last checkpoint snapshot captured can instead be adopted by
  a **warm-takeover successor** (`standby_factory`) that restores the
  snapshot and resumes them without a from-scratch replay;
* **retry discipline** — migrations consume a per-request retry budget
  with jittered exponential backoff (the same discipline the lease's
  acquire path and the engine-level quarantine requeue use); budget
  exhaustion, or a deadline that can no longer be met, sheds the request
  *explicitly* with a recorded reason instead of letting queues collapse;
* **circuit breaker** — consecutive sentinel-sick rounds trip a
  per-replica breaker (no new bindings); after a cool-off it half-opens
  for one probe binding and closes again only on a healthy round.

Determinism: the router runs on a virtual clock (``clk`` box shared with
every replica engine), cluster faults come from a seeded
`resilience.faults.FaultPlan` (kinds in ``CLUSTER_KINDS``), and request
token streams are functions of the request alone — so the chaos
acceptance property can assert *bit-identical* surviving streams against
a fault-free run.  See resilience/README.md ("the cluster plane").
"""

from __future__ import annotations

import heapq
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..resilience.faults import (
    CLUSTER_KINDS,
    KV_PARTITION,
    LEASE_LEAK,
    REPLICA_KILL,
    STRAGGLER,
    FaultPlan,
)
from ..obs.hist import LogHistogram
from ..obs.trace import TraceBuffer, build_spans
from ..runtime.coordinator import Coordinator, DistributedTicketLease, KVStore
from ..runtime.reaper import LeaseReaper, leases_clean
from .events import EV_MIGRATE, EV_ROUTE, EV_SHED, EV_SUBMIT
from .scheduler import Request

# ---------------------------------------------------------------------------


@dataclass
class ClusterRequest:
    """Client-facing record: ONE logical request, possibly many engine
    attempts.  ``done_event`` fires exactly once — on first delivery or
    on an explicit shed (``shed_reason`` records why)."""

    rid: int
    prompt: list[int]
    max_new_tokens: int
    tenant_id: str = "default"
    deadline: Optional[float] = None
    state: str = "queued"  # queued | inflight | done | shed
    tokens: list[int] = field(default_factory=list)
    shed_reason: Optional[str] = None
    retries: int = 0  # router-path migrations consumed
    attempts: int = 0  # engine clones created (≥1 duplicates ⇒ dedupe hit)
    completed_by: Optional[int] = None  # replica idx that won
    submit_clock: float = 0.0
    finish_clock: Optional[float] = None
    ttft: Optional[float] = None
    done_event: threading.Event = field(default_factory=threading.Event)


class CircuitBreaker:
    """Per-replica breaker over the sentinel health stream: ``trip_after``
    consecutive sick rounds open it (no new bindings); after ``cooloff``
    router rounds it half-opens for ONE probe binding; the next healthy
    round closes it, a sick one re-opens."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, trip_after: int = 3, cooloff: int = 6):
        self.trip_after = int(trip_after)
        self.cooloff = int(cooloff)
        self.state = self.CLOSED
        self.faults = 0  # consecutive sick rounds
        self.opened_at = -1
        self.trips = 0
        self._probe_used = False

    def record(self, healthy: bool, rnd: int) -> Optional[str]:
        """Feed one driven round's health; returns a transition name or
        None."""
        if healthy:
            self.faults = 0
            if self.state == self.HALF_OPEN:
                self.state = self.CLOSED
                return "close"
            return None
        self.faults += 1
        if self.state == self.HALF_OPEN:
            self.state = self.OPEN
            self.opened_at = rnd
            return "reopen"
        if self.state == self.CLOSED and self.faults >= self.trip_after:
            self.state = self.OPEN
            self.opened_at = rnd
            self.trips += 1
            return "open"
        return None

    def allow(self, rnd: int) -> bool:
        """May the router bind NEW work to this replica this round?
        (Peek only — a half-open probe is consumed by :meth:`bound`.)"""
        if self.state == self.OPEN:
            if rnd - self.opened_at < self.cooloff:
                return False
            self.state = self.HALF_OPEN
            self._probe_used = False
        if self.state == self.HALF_OPEN:
            return not self._probe_used
        return True

    def bound(self) -> None:
        """A binding was actually routed here — in half-open, that was
        the one probe."""
        if self.state == self.HALF_OPEN:
            self._probe_used = True


class Replica:
    """Router-side handle: engine (wrapped in a ResilientEngine), its
    capacity lease, and the liveness/dispatch state machine."""

    def __init__(self, idx: int, rz, lease: DistributedTicketLease,
                 breaker: CircuitBreaker):
        self.idx = idx
        self.rz = rz
        self.eng = rz.engine
        self.lease = lease
        self.breaker = breaker
        self.alive = True           # router's view (membership)
        self.process_alive = True   # simulation: is the process running
        self.dead_round: Optional[int] = None
        self.dead_reason: Optional[str] = None
        self.pending: dict[int, int] = {}    # rid → lease ticket (queued)
        self.inflight: dict[int, tuple[int, Request]] = {}  # rid → (t, att)
        self.zombie: dict[int, Request] = {}  # attempts a fenced corpse runs
        self.bucket_obs: dict[int, tuple[str, int]] = {}  # rid → (key, seq)
        self.grant_cache = lease.kv.get(f"{lease.name}/grant")
        self.straggle = 1
        self.straggle_from = 0
        self.partition_until = -1  # rnd < this ⇒ heartbeat writes lost
        self.kill_at: Optional[tuple[int, int]] = None  # (rnd, offset)
        self.driven_rounds = 0

    def partitioned(self, rnd: int) -> bool:
        return rnd < self.partition_until

    def tickets(self):
        for t in self.pending.values():
            yield t
        for t, _ in self.inflight.values():
            yield t


@dataclass
class RouterStats:
    accepted: int = 0
    completed: int = 0
    duplicates_suppressed: int = 0  # exactly-once dedupe hits
    migrated: int = 0      # in-flight requests requeued off a dead replica
    rebound: int = 0       # queued (never-admitted) bindings moved
    adopted: int = 0       # warm-takeover resumptions from a snapshot
    replicas_dead: int = 0
    successors: int = 0
    orphans_reaped: int = 0  # leaked tickets freed that mapped to no request
    grant_poll_skips: int = 0  # admission re-polls saved by bucket gating
    zombie_deliveries: int = 0  # completions delivered by a fenced replica


class ReplicaRouter:
    """Spread requests over N replicas; survive the replicas dying.

    ``replicas``: list of `resilience.recovery.ResilientEngine` (their
    engines must share ``clk`` as clock).  ``capacity``: per-replica
    in-flight cap (lease units).  ``ttl``: heartbeat TTL in clock
    seconds — both the reaper's and the coordinator's detection horizon.
    ``plan``: a seeded cluster `FaultPlan` (``CLUSTER_KINDS`` events,
    rounds = ROUTER rounds).  ``standby_factory``: zero-arg callable
    returning a fresh ResilientEngine for warm takeover.  ``inner_k``:
    engine rounds per router round (each replica drives one
    ``megastep(inner_k)``); a REPLICA_KILL's ``delta`` lands the death
    ``delta`` engine rounds INTO that window — mid-megastep."""

    def __init__(self, replicas, *, kv: KVStore, clk, token_fn,
                 capacity: int, ttl: float, dt: float = 0.25,
                 inner_k: int = 4, plan: Optional[FaultPlan] = None,
                 retry_budget: int = 3, backoff_base: int = 1,
                 backoff_jitter: int = 2, seed: int = 0,
                 shed_slack: float = 0.0, breaker_trip: int = 3,
                 breaker_cooloff: int = 6, max_queue_per_replica: int = 0,
                 standby_factory=None, obs=None):
        self.kv = kv
        self._clk = clk
        self.token_fn = token_fn
        self.capacity = int(capacity)
        self.ttl = float(ttl)
        self.dt = float(dt)
        self.inner_k = int(inner_k)
        self.plan = plan if plan is not None else FaultPlan(seed=0)
        self.retry_budget = int(retry_budget)
        self.backoff_base = max(1, int(backoff_base))
        self.backoff_jitter = max(0, int(backoff_jitter))
        self.shed_slack = float(shed_slack)
        self.max_queue = (int(max_queue_per_replica)
                          if max_queue_per_replica else self.capacity)
        self.standby_factory = standby_factory
        self.obs = obs
        self._rng = np.random.default_rng(seed)
        self._breaker_cfg = (int(breaker_trip), int(breaker_cooloff))
        clock = lambda: self._clk[0]  # noqa: E731
        self.coord = Coordinator(heartbeat_timeout=self.ttl, kv=kv,
                                 clock=clock)
        self.replicas: list[Replica] = []
        for i, rz in enumerate(replicas):
            lease = DistributedTicketLease(
                kv, f"replica/{i}", capacity=self.capacity, clock=clock)
            self.replicas.append(Replica(
                i, rz, lease, CircuitBreaker(*self._breaker_cfg)))
            self.coord.join(i)
        self.reaper = LeaseReaper([r.lease for r in self.replicas],
                                  ttl=self.ttl)
        self.queue: deque[ClusterRequest] = deque()  # unbound requests
        self.requests: dict[int, ClusterRequest] = {}  # rid → record
        self._retryq: list[tuple[int, int]] = []  # (due round, rid)
        self._reaped: set[tuple[str, int]] = set()  # freed (lease, ticket)
        self._leaks: list[tuple[int, int]] = []  # (replica idx, ticket)
        self._consumed: set[int] = set()  # plan event indices applied
        self.stats = RouterStats()
        self.shed: dict[int, str] = {}  # rid → recorded reason
        self.completed: dict[int, list[int]] = {}  # rid → delivered tokens
        self.events: list[dict] = []
        self.round_no = 0
        # fabric-side trace events (SUBMIT/ROUTE/MIGRATE/SHED) — merged
        # with every replica engine's buffer by cluster_spans(); tagging
        # each engine's buffer with its replica idx is what lets a span
        # that migrated show WHICH replica ran which segment
        self.trace = TraceBuffer()
        for rep in self.replicas:
            rep.eng._trace.replica = rep.idx
        self._migrate_at: dict[int, float] = {}  # rid → MIGRATE clock
        self.migration_hist = LogHistogram(resolution=0.05, min_value=1e-3)

    # ----------------------------------------------------------- client ----

    def submit(self, cr: ClusterRequest) -> ClusterRequest:
        """Idempotent admission: a rid seen before returns the EXISTING
        record (the exactly-once contract starts at the front door — a
        client retrying a timed-out submit must not enqueue a double)."""
        prev = self.requests.get(cr.rid)
        if prev is not None:
            return prev
        cr.submit_clock = self._clk[0]
        self.requests[cr.rid] = cr
        self.queue.append(cr)
        self.stats.accepted += 1
        self.trace.add(EV_SUBMIT, cr.rid, -1, 0, cr.submit_clock,
                       self.round_no)
        return cr

    def submit_batch(self, crs) -> None:
        for cr in crs:
            self.submit(cr)

    # -------------------------------------------------------------- log ----

    def _log(self, action: str, **kw) -> None:
        self.events.append({"round": self.round_no, "action": action, **kw})

    # ----------------------------------------------------- fault applies ----

    def _apply_cluster_faults(self, rnd: int) -> None:
        for i, ev in enumerate(self.plan.events):
            if ev.round != rnd or i in self._consumed:
                continue
            if ev.kind not in CLUSTER_KINDS:
                continue  # engine-level events belong to replica plans
            self._consumed.add(i)
            rep = self.replicas[ev.arg % len(self.replicas)]
            if ev.kind == REPLICA_KILL:
                off = max(1, min(int(ev.delta) or 1, self.inner_k))
                rep.kill_at = (rnd, off)
            elif ev.kind == KV_PARTITION:
                rep.partition_until = rnd + max(1, int(ev.delta))
            elif ev.kind == STRAGGLER:
                rep.straggle = max(2, int(ev.delta))
                rep.straggle_from = rnd
            elif ev.kind == LEASE_LEAK:
                # a client took a ticket on this replica's lease and then
                # vanished: one stale heartbeat stamp, never renewed —
                # exactly what the reaper exists to free
                t = rep.lease.take_ticket()
                self._leaks.append((rep.idx, t))
            self._log("inject", kind=ev.kind, replica=rep.idx,
                      delta=ev.delta)

    # -------------------------------------------------------- detection ----

    def _detect(self, rnd: int) -> None:
        for idx in self.coord.detect_failures():
            if idx < len(self.replicas):
                self._mark_dead(self.replicas[idx], rnd,
                                "heartbeat_timeout")
        for act in self.reaper.scan():
            self._reaped.add((act.lease, act.ticket))
            owner = None
            for rep in self.replicas:
                if rep.lease.name != act.lease:
                    continue
                if (act.ticket in rep.pending.values()
                        or any(t == act.ticket
                               for t, _ in rep.inflight.values())):
                    owner = rep
                break
            self._log("reap", lease=act.lease, ticket=act.ticket,
                      how=act.action, age=round(act.age, 3))
            if owner is not None:
                # a request's ticket went stale ⇒ its replica stopped
                # renewing ⇒ the replica is dead, not just one ticket
                self._mark_dead(owner, rnd, "lease_reaped")
            else:
                self.stats.orphans_reaped += 1

    # ---------------------------------------------------- death handling ----

    def _mark_dead(self, rep: Replica, rnd: int, reason: str) -> None:
        if not rep.alive:
            return
        rep.alive = False
        rep.dead_round = rnd
        rep.dead_reason = reason
        self.stats.replicas_dead += 1
        self.coord.leave(rep.idx)
        self._log("replica_dead", replica=rep.idx, reason=reason)
        # flight recorder: cut the dead replica's post-mortem bundle NOW,
        # while its last samples/events are still in the window
        fl = getattr(getattr(rep.eng, "_obs", None), "flight", None)
        if fl is not None:
            fl.dump("replica_reaped",
                    extra={"replica": rep.idx, "cause": reason,
                           "round": rnd})
        # free every lease ticket the corpse still owns: tombstone the
        # waiters FIRST (so the holder releases skip them in one walk),
        # then force-release the holders
        for rid, t in sorted(rep.pending.items()):
            if (rep.lease.name, t) not in self._reaped:
                rep.lease.cancel(t)
                self._reaped.add((rep.lease.name, t))
        for rid, (t, _) in sorted(rep.inflight.items()):
            if (rep.lease.name, t) not in self._reaped:
                if not rep.lease.cancel(t):
                    rep.lease.release(t)
                self._reaped.add((rep.lease.name, t))
        # queued bindings never started work: rebind at no retry cost
        for rid in sorted(rep.pending):
            cr = self.requests[rid]
            if cr.state == "queued":
                self.queue.append(cr)
                self.stats.rebound += 1
        rep.pending.clear()
        rep.bucket_obs.clear()
        # warm takeover: requests the last snapshot captured resume on a
        # successor replica instead of replaying from scratch
        adopted: set[int] = set()
        if (self.standby_factory is not None and rep.inflight
                and rep.rz._snap is not None):
            adopted = self._spawn_successor(rep, rnd)
        # everything else migrates: re-clone onto healthy replicas under
        # the retry budget (the dedupe registry guards the zombie race)
        for rid, (t, att) in sorted(rep.inflight.items()):
            if rid in adopted:
                continue
            if rep.process_alive:
                rep.zombie[rid] = att  # partition corpse keeps running
            cr = self.requests[rid]
            if cr.state == "inflight":
                self.stats.migrated += 1
                self._requeue(cr, rnd)
        rep.inflight.clear()

    def _requeue(self, cr: ClusterRequest, rnd: int) -> None:
        cr.retries += 1
        if cr.retries > self.retry_budget:
            self._shed(cr, "retry_budget")
            return
        delay = (self.backoff_base * (1 << (cr.retries - 1))
                 + int(self._rng.integers(0, self.backoff_jitter + 1)))
        cr.state = "queued"
        heapq.heappush(self._retryq, (rnd + delay, cr.rid))
        self._log("requeue", rid=cr.rid, attempt=cr.retries,
                  due=rnd + delay)
        self.trace.add(EV_MIGRATE, cr.rid, -1, cr.retries, self._clk[0],
                       rnd)
        # migration latency clock starts at the FIRST requeue; stops when
        # the request is re-admitted into a healthy engine (_admit)
        self._migrate_at.setdefault(cr.rid, self._clk[0])

    def _spawn_successor(self, dead: Replica, rnd: int) -> set[int]:
        """Warm takeover: a fresh replica adopts the dead one's last
        checkpoint snapshot (device tree from the shared FS, host capture
        standing in for its host-state shard) and resumes the captured
        requests mid-flight."""
        rz2 = self.standby_factory()
        eng2 = rz2.engine
        # one empty round materializes the device-state protos (block
        # pool, model) so the checkpoint restore has matching shapes
        eng2.megastep(1, token_fn=self.token_fn,
                      nows=np.asarray([self._clk[0]], np.float32))
        rz2.ckpt = dead.rz.ckpt
        rz2._snap = dead.rz._snap
        rz2._snaps = list(dead.rz._snaps)
        rz2._restore(rnd)
        if not any(e["action"] == "restore" for e in rz2.events):
            self._log("takeover_failed", replica=dead.idx)
            return set()
        idx2 = len(self.replicas)
        clock = lambda: self._clk[0]  # noqa: E731
        lease2 = DistributedTicketLease(
            self.kv, f"replica/{idx2}", capacity=self.capacity, clock=clock)
        rep2 = Replica(idx2, rz2, lease2, CircuitBreaker(*self._breaker_cfg))
        eng2._trace.replica = idx2
        self.replicas.append(rep2)
        self.coord.join(idx2)
        self.reaper.add(lease2)
        self.stats.successors += 1
        # adopt: every in-flight rid the snapshot captured is now live
        # inside eng2 (restored in place, same attempt objects)
        live_rids = {r.rid for r in eng2.active.values()}
        live_rids |= {r.rid for r in eng2.backlog}
        if eng2._tenants is not None:
            for q in eng2._tenant_queues:
                live_rids |= {r.rid for r in q}
        adopted: set[int] = set()
        for rid, (t, att) in sorted(dead.inflight.items()):
            if rid not in live_rids:
                continue
            t2 = lease2.try_acquire()
            if t2 is None:
                break  # capacity guard (snapshot bigger than a lease)
            rep2.inflight[rid] = (t2, att)
            cr = self.requests[rid]
            cr.attempts += 1
            adopted.add(rid)
            self.stats.adopted += 1
        self._log("warm_takeover", dead=dead.idx, successor=idx2,
                  adopted=sorted(adopted),
                  snapshot_round=dead.rz._snap[0])
        return adopted

    # --------------------------------------------------------- shedding ----

    def _shed(self, cr: ClusterRequest, reason: str) -> None:
        if cr.state in ("done", "shed"):
            return
        cr.state = "shed"
        cr.shed_reason = reason
        self.shed[cr.rid] = reason
        cr.done_event.set()
        self._log("shed", rid=cr.rid, reason=reason)
        self.trace.add(EV_SHED, cr.rid, -1, 0, self._clk[0],
                       self.round_no)

    def _shed_pass(self) -> None:
        """Deadline-aware overload relief: a queued request whose deadline
        is already (or is about to be) unmeetable is shed NOW with a
        recorded reason, instead of wasting a binding on it."""
        now = self._clk[0]
        keep = deque()
        for cr in self.queue:
            if (cr.deadline is not None
                    and cr.deadline - now <= self.shed_slack):
                self._shed(cr, "deadline")
            else:
                keep.append(cr)
        self.queue = keep

    # ---------------------------------------------------------- binding ----

    def _bind(self, rnd: int) -> None:
        while self.queue:
            cands = [rep for rep in self.replicas
                     if rep.alive and rep.lease.headroom() > -self.max_queue
                     and rep.breaker.allow(rnd)]
            if not cands:
                return
            # max headroom (least loaded), ties to the lowest index —
            # deterministic power-of-N routing
            rep = max(cands, key=lambda r: (r.lease.headroom(), -r.idx))
            rep.breaker.bound()
            cr = self.queue.popleft()
            t = rep.lease.take_ticket()
            rep.pending[cr.rid] = t
            rep.bucket_obs[cr.rid] = rep.lease.bucket_state(t)
            self._log("bind", rid=cr.rid, replica=rep.idx, ticket=t)
            self.trace.add(EV_ROUTE, cr.rid, rep.idx, t, self._clk[0],
                           rnd, replica=rep.idx)

    def _admit(self, rnd: int) -> None:
        """Promote granted bindings to engine submissions.  Re-polls are
        bucket-gated: far-from-head tickets re-read the grant only when
        their waiting-array bucket was poked."""
        for rep in self.replicas:
            if not rep.alive or not rep.pending:
                continue
            lease = rep.lease
            for rid in sorted(rep.pending, key=rep.pending.get):
                t = rep.pending[rid]
                if rep.grant_cache - t <= 0:
                    bkt, seq = rep.bucket_obs[rid]
                    cur = self.kv.get(bkt)
                    near = rep.grant_cache + lease.threshold - t > 0
                    if cur == seq and not near:
                        self.stats.grant_poll_skips += 1
                        continue
                    rep.bucket_obs[rid] = (bkt, cur)
                    rep.grant_cache = self.kv.get(f"{lease.name}/grant")
                    if rep.grant_cache - t <= 0:
                        continue
                cr = self.requests[rid]
                att = Request(rid=cr.rid, prompt=list(cr.prompt),
                              max_new_tokens=cr.max_new_tokens,
                              tenant_id=cr.tenant_id, deadline=cr.deadline)
                rep.eng.submit(att)
                rep.inflight[rid] = (t, att)
                del rep.pending[rid]
                del rep.bucket_obs[rid]
                cr.state = "inflight"
                cr.attempts += 1
                m = self._migrate_at.pop(rid, None)
                if m is not None:
                    self.migration_hist.add(max(self._clk[0] - m, 1e-3))

    # ------------------------------------------------------------ drive ----

    def _drive(self, rnd: int) -> None:
        now = self._clk[0]
        for rep in self.replicas:
            if not rep.process_alive:
                continue
            gated = (rep.straggle > 1
                     and (rnd - rep.straggle_from) % rep.straggle != 0)
            killed_now = rep.kill_at is not None and rep.kill_at[0] == rnd
            if not gated or killed_now:
                seg = self.inner_k
                if killed_now:
                    seg = rep.kill_at[1]  # dies mid-megastep: the rounds
                    #                       past the kill offset never run
                nows = np.asarray(now + np.arange(seg) * self.dt,
                                  np.float32)
                rep.rz.megastep(seg, token_fn=self.token_fn, nows=nows)
                rep.driven_rounds += seg
                health = 0
                for smp in rep.eng._last_samples:
                    health |= int(smp["health"])
                trans = rep.breaker.record(health == 0, rnd)
                if trans is not None:
                    self._log(f"breaker_{trans}", replica=rep.idx,
                              health=health)
            if killed_now:
                rep.process_alive = False
                self._log("replica_killed", replica=rep.idx,
                          offset=rep.kill_at[1])
                continue
            # liveness: heartbeats + lease renewals — suppressed inside a
            # KV partition window (the replica IS running; its writes are
            # lost — the zombie scenario the dedupe registry exists for)
            if rep.alive and not rep.partitioned(rnd):
                self.coord.heartbeat(
                    rep.idx, step=rep.eng._round_no,
                    step_time_s=self.dt * self.inner_k * rep.straggle)
            if not rep.partitioned(rnd):
                for t in rep.tickets():
                    if (rep.lease.name, t) not in self._reaped:
                        rep.lease.renew(t)

    # ---------------------------------------------------------- collect ----

    def _deliver(self, cr: ClusterRequest, att: Request, idx: int,
                 zombie: bool) -> None:
        if cr.state in ("done", "shed"):
            self.stats.duplicates_suppressed += 1
            self._log("duplicate_suppressed", rid=cr.rid, replica=idx)
            return
        if att.expired or att.preempted:
            self._shed(cr, "deadline")
            return
        cr.tokens = list(att.out_tokens)
        cr.state = "done"
        cr.completed_by = idx
        cr.finish_clock = self._clk[0]
        if att.first_tok_clock is not None:
            cr.ttft = att.first_tok_clock - cr.submit_clock
        self.completed[cr.rid] = cr.tokens
        cr.done_event.set()
        self.stats.completed += 1
        if zombie:
            self.stats.zombie_deliveries += 1

    def _collect(self, rnd: int) -> None:
        for rep in self.replicas:
            for rid in sorted(rep.inflight):
                t, att = rep.inflight[rid]
                if not att.done_event.is_set():
                    continue
                del rep.inflight[rid]
                if (rep.lease.name, t) not in self._reaped:
                    rep.lease.release(t)
                self._deliver(self.requests[rid], att, rep.idx,
                              zombie=False)
            for rid in sorted(rep.zombie):
                att = rep.zombie[rid]
                if att.done_event.is_set():
                    del rep.zombie[rid]
                    self._deliver(self.requests[rid], att, rep.idx,
                                  zombie=True)

    def _fence_rep(self, rep: Replica) -> None:
        for s in sorted(rep.eng.active):
            rep.eng.quarantine(s)
        rep.process_alive = False
        rep.zombie.clear()
        self._log("fenced", replica=rep.idx)

    def _fence(self, rnd: int) -> None:
        """A partitioned replica that was declared dead halts when the
        partition heals and it observes the membership epoch it lost —
        its slots are quarantined so ITS exit audit is clean too."""
        for rep in self.replicas:
            if (rep.process_alive and not rep.alive
                    and rep.partition_until != -1
                    and not rep.partitioned(rnd)):
                self._fence_rep(rep)

    # ------------------------------------------------------------- loop ----

    def _process_retries(self, rnd: int) -> None:
        while self._retryq and self._retryq[0][0] <= rnd:
            _, rid = heapq.heappop(self._retryq)
            cr = self.requests[rid]
            if cr.state == "queued":
                self.queue.append(cr)

    def round(self) -> None:
        rnd = self.round_no
        self._clk[0] = rnd * self.inner_k * self.dt
        self._apply_cluster_faults(rnd)
        self._detect(rnd)
        self._process_retries(rnd)
        self._shed_pass()
        self._bind(rnd)
        self._admit(rnd)
        self._drive(rnd)
        self._collect(rnd)
        self._fence(rnd)
        self.round_no += 1

    def pending_work(self) -> bool:
        if any(cr.state in ("queued", "inflight")
               for cr in self.requests.values()):
            return True
        if self._retryq:
            return True
        # losing duplicates still running on LIVE replicas must drain
        # normally (release their tickets, hit the dedupe registry) —
        # stopping here would strand their leases for the reaper
        return any(rep.alive and rep.inflight for rep in self.replicas)

    def run(self, max_rounds: int = 200) -> dict:
        """Drive to drain (or ``max_rounds``), then settle the leases:
        keep scanning with the clock advancing until every leaked ticket
        is reaped.  Returns the exit report."""
        while self.pending_work() and self.round_no < max_rounds:
            self.round()
        # shutdown fencing: any corpse still running (a partition window
        # that outlived the workload) halts now
        for rep in self.replicas:
            if rep.process_alive and not rep.alive:
                self._fence_rep(rep)
        # settle: orphan leaks may still be aging toward the TTL
        for _ in range(8):
            if self.lease_audit()["ok"]:
                break
            self._clk[0] += self.ttl + self.dt
            for act in self.reaper.scan():
                self._reaped.add((act.lease, act.ticket))
                self.stats.orphans_reaped += 1
                self._log("reap", lease=act.lease, ticket=act.ticket,
                          how=act.action, age=round(act.age, 3))
        return self.report()

    # -------------------------------------------------------- reporting ----

    def lease_audit(self) -> dict:
        return leases_clean([rep.lease for rep in self.replicas])

    def report(self) -> dict:
        from ..resilience.recovery import exit_audit

        audits = {rep.idx: exit_audit(rep.eng) for rep in self.replicas
                  if rep.process_alive}
        return {
            "rounds": self.round_no,
            "stats": self.stats.__dict__.copy(),
            "shed": dict(self.shed),
            "completed": len(self.completed),
            "lease_audit": self.lease_audit(),
            "engine_audits": audits,
            "reaper": self.reaper.telemetry(),
            "stragglers": self.coord.stragglers(),
        }

    def cluster_spans(self) -> dict:
        """Stitched per-request span trees across the whole fleet: the
        router's fabric events (SUBMIT/ROUTE/MIGRATE/SHED) merged with
        every replica engine's in-scan event stream.  A migrated request
        comes back as ONE span whose segments carry the replica index
        that ran them, with a ``migration`` segment bridging the gap."""
        return build_spans(self.trace,
                           *[rep.eng._trace for rep in self.replicas])

    def fabric_telemetry(self) -> dict:
        """The router sections `obs.cluster.aggregate(router=...)` folds
        into the fleet report."""
        return {
            "leases": {
                rep.idx: {"headroom": rep.lease.headroom(),
                          "capacity": self.capacity,
                          "alive": rep.alive}
                for rep in self.replicas
            },
            "migrations": self.stats.migrated,
            "migration_latency": self.migration_hist.percentiles(),
            "shed": len(self.shed),
            "deaths": self.stats.replicas_dead,
            "duplicates_suppressed": self.stats.duplicates_suppressed,
        }

    def telemetry(self) -> dict:
        return {
            "round": self.round_no,
            "stats": self.stats.__dict__.copy(),
            "epoch": self.coord.epoch,
            "queue": len(self.queue),
            "fabric": self.fabric_telemetry(),
            "trace": {"events": len(self.trace),
                      "dropped": self.trace.dropped},
            "replicas": {
                rep.idx: {
                    "alive": rep.alive,
                    "process_alive": rep.process_alive,
                    "dead_reason": rep.dead_reason,
                    "headroom": rep.lease.headroom(),
                    "queue_depth": rep.lease.queue_depth(),
                    "inflight": len(rep.inflight),
                    "pending": len(rep.pending),
                    "breaker": rep.breaker.state,
                    "straggle": rep.straggle,
                    "driven_rounds": rep.driven_rounds,
                    "recovery": rep.eng.telemetry()["recovery"],
                } for rep in self.replicas
            },
            "reaper": self.reaper.telemetry(),
        }


# ------------------------------------------------------------------ toy ----


def toy_cluster(n_replicas: int, *, seed: int = 0, plan=None,
                engine_plans=None, n_slots: int = 2, capacity: int = 4,
                inner_k: int = 4, dt: float = 0.25, ttl_rounds: float = 2.5,
                snapshot_every: int = 0, standby: bool = False,
                watchdog: int = 4, obs=None, **router_kw):
    """The chunked block-paged toy cluster the example, bench, and tests
    share: ``n_replicas`` rid-deterministic engines (each request's token
    stream is a pure function of its rid — the property that makes
    exactly-once migration *bit-identical*) on one virtual clock and one
    KV store.  ``engine_plans``: {replica idx → engine-level FaultPlan}
    for sentinel/breaker scenarios; ``ttl_rounds``: TTL in router rounds.
    Returns the router."""
    import tempfile

    from ..checkpoint.manager import CheckpointManager
    from ..resilience.recovery import ResilientEngine
    from .engine_state import rid_token_fn
    from .scheduler import ContinuousBatchingEngine

    kv = KVStore()
    clk = [0.0]
    engine_plans = engine_plans or {}

    def build_rz():
        # obs may be a shared EngineObs OR a zero-arg factory (one
        # recorder per replica — what per-replica flight bundles and the
        # fleet aggregator want)
        eng = ContinuousBatchingEngine(
            lambda a: np.array([r.rid * 1000 + len(r.out_tokens)
                                for r in a], np.int64),
            lambda r: None, n_slots=n_slots,
            tenants={"gold": 2.0, "bronze": 1.0}, clock=lambda: clk[0],
            kv_pool=(16, 4), chunked_prefill=(5, 9, 16), prompt_cap=32,
            use_kernel=True, watchdog=watchdog,
            obs=obs() if callable(obs) else obs)
        ck = CheckpointManager(tempfile.mkdtemp(prefix="repro-cluster-")) \
            if snapshot_every else None
        return ResilientEngine(eng, plan=None, react_every=2,
                               retry_budget=2, seed=seed, ckpt=ck,
                               snapshot_every=snapshot_every)

    replicas = []
    for i in range(n_replicas):
        rz = build_rz()
        if i in engine_plans:
            rz.plan = engine_plans[i]
        replicas.append(rz)
    return ReplicaRouter(
        replicas, kv=kv, clk=clk, token_fn=rid_token_fn,
        capacity=capacity, ttl=ttl_rounds * inner_k * dt, dt=dt,
        inner_k=inner_k, plan=plan, seed=seed,
        standby_factory=build_rz if standby else None,
        obs=None if callable(obs) else obs,
        **router_kw)


def toy_workload(n_req: int, seed: int = 0, *, deadline_frac: float = 0.0,
                 horizon: float = 40.0) -> list[ClusterRequest]:
    """Seeded mixed-tenant workload over the toy cluster's vocabulary."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_req):
        dl = None
        if deadline_frac and rng.random() < deadline_frac:
            dl = float(rng.uniform(2.0, horizon))
        out.append(ClusterRequest(
            rid=i, prompt=[1 + i % 7] * int(rng.integers(1, 19)),
            max_new_tokens=1 + int(rng.integers(0, 10)),
            tenant_id=("gold", "bronze")[int(rng.integers(0, 2))],
            deadline=dl))
    return out
