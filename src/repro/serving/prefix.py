"""Prompt-prefix cache — the hash index behind refcounted block sharing.

Heavy multi-tenant traffic is dominated by requests repeating a system
prompt.  With the `core.functional.BlockPool` refcounted (PR 9), a
request whose prompt prefix is already resident only needs the MAPPING
from prefix content to live block ids; this module is that mapping — a
small, fixed-shape, direct-mapped cache that lives inside the scanned
engine state (a pytree leaf of `engine_state.KVPool`), so lookups and
registrations happen in-graph at megastep speed and the host `step()`
path mirrors them bit-identically by calling the same jitted functions
on its replica.

Design constraints and the choices they force:

* **Weak entries.**  The cache holds NO refcount: an entry is a
  ``(key, block id, generation)`` triple, valid iff the pool's per-block
  ``gen`` stamp still equals the recorded one.  Freeing a block bumps
  its ``gen`` (`pool_release`), killing every entry that pointed at it —
  so the conservation invariant stays exactly ``Σ table references =
  Σ refcnt`` with the cache contributing nothing, and a dead entry can
  never resurrect a reused block.

* **Content is identified by hash only.**  Keys are two independent
  32-bit FNV-1a chains over the token sequence (64 bits of match), the
  same u32 arithmetic on host (`prompt_hashes`, at ``submit()``) and
  device (the hashes ride the backlog/slot state as data — nothing is
  re-hashed in-graph).  A 2⁻⁶⁴ collision shares a wrong block; real
  deployments would verify tokens, the reproduction accepts the odds.

* **Direct-mapped, deterministic.**  ``entries`` is a power of two;
  an entry's home slot is ``key & (E−1)``; a colliding registration
  overwrites (newest wins).  Registration happens when a slot FINISHES
  prefill: each fully-written block boundary publishes one entry, and a
  partially-filled tail block publishes a full-prompt entry carrying its
  ``filled`` count (the copy length for copy-on-write).  Same-round
  duplicate prompts therefore both miss and both prefill — sharing
  starts one completed prefill later (benches stagger arrivals).

Lookup returns the longest chain of matching *full* block entries
(blocks 0..c−1 attach by `pool_incref`, prefill resumes at ``c·BS``)
plus, when the whole prompt matches, the shared tail block — the
request then skips prefill entirely (zero flops, zero new HBM) and its
first diverging decode write goes copy-on-write (`prefill.chunk_plan`'s
``cow`` take).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

# two independent FNV-1a chains — 64 bits of content identity
_OFF1, _PRIME1 = 0x811C9DC5, 0x01000193
_OFF2, _PRIME2 = 0x9E3779B9, 0x85EBCA6B
_M32 = 0xFFFFFFFF


class PrefixCache(NamedTuple):
    """Direct-mapped weak prefix index (all fields length-E vectors).
    ``bid < 0`` marks an empty entry; a non-empty entry is live iff
    ``pool.gen[bid] == gen`` (weak reference).  ``filled`` is the number
    of valid tokens in the block: ``BS`` for a full-block entry, the
    tail length for a full-prompt (tail) entry."""

    key: jax.Array     # (E,) u32 — FNV chain 1 at the covered length
    key2: jax.Array    # (E,) u32 — FNV chain 2 (collision guard)
    bid: jax.Array     # (E,) i32 — block id (-1 = empty)
    gen: jax.Array     # (E,) u32 — pool.gen[bid] at registration
    filled: jax.Array  # (E,) i32 — valid tokens in the block


def make_prefix_cache(entries: int) -> PrefixCache:
    assert entries > 0 and (entries & (entries - 1)) == 0, \
        "prefix cache entries must be a power of two (key & (E-1) homes)"
    return PrefixCache(
        key=jnp.zeros((entries,), jnp.uint32),
        key2=jnp.zeros((entries,), jnp.uint32),
        bid=jnp.full((entries,), -1, jnp.int32),
        gen=jnp.zeros((entries,), jnp.uint32),
        filled=jnp.zeros((entries,), jnp.int32))


def prompt_hashes(prompt: Sequence[int], block_size: int,
                  width: int) -> list[list[int]]:
    """Host-side hashing at ``submit()`` — the ONLY place tokens are
    hashed; the resulting ``(2, width+1)`` u32 table rides the request
    into the backlog/slot state as plain data.  Column ``j < width``
    holds the chain value after ``(j+1)·BS`` tokens (the key of full
    block ``j``); column ``width`` holds the full-prompt value (the tail
    key).  Unreached boundaries stay 0 — harmless, lookup masks them by
    ``j < plen // BS``."""
    h1, h2 = _OFF1, _OFF2
    row1, row2 = [0] * (width + 1), [0] * (width + 1)
    for i, t in enumerate(prompt):
        t = int(t) & _M32
        h1 = ((h1 ^ t) * _PRIME1) & _M32
        h2 = ((h2 ^ t) * _PRIME2) & _M32
        if (i + 1) % block_size == 0 and (i + 1) // block_size <= width:
            row1[(i + 1) // block_size - 1] = h1
            row2[(i + 1) // block_size - 1] = h2
    row1[width], row2[width] = h1, h2
    return [row1, row2]


def cache_lookup(cache: PrefixCache, pool, ph: jax.Array, plen: jax.Array,
                 block_size: int):
    """Vectorized longest-prefix probe for a batch of prompts.

    ``ph``: (B, 2, W+1) u32 hash tables (`prompt_hashes` layout);
    ``plen``: (B,) i32 prompt lengths.  Returns

      ``c``        (B,)   i32 — matched full blocks (longest chain)
      ``bids``     (B, W) i32 — their block ids (-1 beyond ``c``)
      ``tail_bid`` (B,)   i32 — shared tail block (-1 = no tail hit)
      ``cov``      (B,)   i32 — covered prompt tokens (``c·BS`` or plen)

    A full-block entry matches only while the chain is unbroken (an
    evicted middle block cuts the usable prefix there); the tail entry
    matches only when every full block matched AND the recorded
    ``filled`` equals this prompt's tail length."""
    E = cache.key.shape[0]
    NB = pool.gen.shape[0]
    W = ph.shape[2] - 1
    plen = jnp.asarray(plen, jnp.int32)
    n_full = jnp.minimum(plen // block_size, W)
    tail_len = plen - n_full * block_size

    def probe(k1, k2):
        idx = (k1 & jnp.uint32(E - 1)).astype(jnp.int32)
        bid = cache.bid[idx]
        ok = ((bid >= 0) & (cache.key[idx] == k1) & (cache.key2[idx] == k2)
              & (pool.gen[jnp.clip(bid, 0, NB - 1)] == cache.gen[idx]))
        return ok, bid, cache.filled[idx]

    ok_j, bid_j, fill_j = probe(ph[:, 0, :W], ph[:, 1, :W])  # (B, W)
    j = jnp.arange(W, dtype=jnp.int32)[None, :]
    hit = ok_j & (fill_j == block_size) & (j < n_full[:, None])
    # longest unbroken chain from block 0
    c = jnp.sum(jnp.cumprod(hit.astype(jnp.int32), axis=1), axis=1)
    bids = jnp.where(j < c[:, None], bid_j, -1)
    ok_t, bid_t, fill_t = probe(ph[:, 0, W], ph[:, 1, W])
    tail_hit = ok_t & (c == n_full) & (tail_len > 0) & (fill_t == tail_len)
    tail_bid = jnp.where(tail_hit, bid_t, -1)
    cov = jnp.where(tail_hit, plen, c * block_size)
    return c, bids, tail_bid, cov


def cache_register(cache: PrefixCache, pool, ph: jax.Array,
                   plen: jax.Array, tbl: jax.Array, completed: jax.Array,
                   block_size: int) -> PrefixCache:
    """Publish the prefixes of slots that COMPLETED prefill this round.

    For each slot flagged in ``completed`` (S,): one entry per full
    block boundary (``filled = BS``) plus, when the prompt has a
    partial tail block, one full-prompt entry (``filled = tail``).
    Deterministic under collisions: conceptually entries apply in
    (slot, boundary) order and the LAST writer wins — computed as one
    vectorized pairwise sweep, so the scatter sees unique homes.
    Re-registering an already-shared prefix is idempotent (same key,
    same bid, unchanged gen)."""
    E = cache.key.shape[0]
    NB = pool.gen.shape[0]
    S = plen.shape[0]
    W = ph.shape[2] - 1
    plen = jnp.asarray(plen, jnp.int32)
    n_full = jnp.minimum(plen // block_size, W)        # (S,)
    tail_len = plen - n_full * block_size
    j = jnp.arange(W + 1, dtype=jnp.int32)[None, :]    # (1, W+1)
    is_tail = j == W
    valid = completed[:, None] & (
        (j < n_full[:, None]) | (is_tail & (tail_len[:, None] > 0)))
    # a tail entry points at block n_full (the partially-filled block)
    blk_ix = jnp.where(is_tail, jnp.minimum(n_full[:, None], tbl.shape[1] - 1),
                       jnp.minimum(j, tbl.shape[1] - 1))
    bid = jnp.take_along_axis(tbl, blk_ix, axis=1)     # (S, W+1)
    valid = valid & (bid >= 0)
    k1 = ph[:, 0, :].reshape(-1)
    k2 = ph[:, 1, :].reshape(-1)
    bid = bid.reshape(-1)
    valid = valid.reshape(-1)
    filled = jnp.where(is_tail, tail_len[:, None],
                       jnp.int32(block_size)).reshape(-1)
    gen = pool.gen[jnp.clip(bid, 0, NB - 1)]
    idx = (k1 & jnp.uint32(E - 1)).astype(jnp.int32)
    # last valid writer per home wins: N = S·(W+1) is small (slots ×
    # table width), so the pairwise "someone later hits my home" sweep
    # stays cheap and keeps the scatter unique → deterministic
    n = idx.shape[0]
    later = (jnp.arange(n)[None, :] > jnp.arange(n)[:, None])
    shadowed = jnp.any(later & valid[None, :] & (idx[None, :] == idx[:, None]),
                       axis=1)
    win = valid & ~shadowed
    tgt = jnp.where(win, idx, E)
    return PrefixCache(
        key=cache.key.at[tgt].set(k1, mode="drop"),
        key2=cache.key2.at[tgt].set(k2, mode="drop"),
        bid=cache.bid.at[tgt].set(bid, mode="drop"),
        gen=cache.gen.at[tgt].set(gen, mode="drop"),
        filled=cache.filled.at[tgt].set(filled, mode="drop"))


def cache_clear(cache: PrefixCache) -> PrefixCache:
    """Drop every entry (post-audit: block identities were rebuilt, so no
    weak reference can be trusted).  Cheaper and strictly safer than
    re-stamping generations."""
    return cache._replace(bid=jnp.full_like(cache.bid, -1))
