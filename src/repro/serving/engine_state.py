"""Device-resident decode megastep — the engine loop itself as a pure,
scanned JAX program.

`ContinuousBatchingEngine.step()` pays a full host round-trip per decoded
token: Python queue bookkeeping, one dispatch, host-side sampling, per-slot
loops.  The paper's whole point (TWA semaphores make admission latency
near-zero) is squandered if every admission round is bracketed by
milliseconds of host sync.  This module moves the engine in-graph: ONE
jitted `lax.scan` over K decode iterations where all per-slot engine state
lives in a donated on-device :class:`EngineState` pytree, and each scanned
round fuses

  (a) the in-graph multi-tenant QoS admission round (the
      `admission.functional_qos.qos_round` semantics; on TPU the fused
      Pallas pass `kernels.qos_admission.qos_round_fused` — bit-identical,
      see tests/test_qos_kernel.py);
  (b) slot assignment gated by the free-slot TWA semaphore
      (`core.functional` take/post — the reference semantics of the
      `kernels/sema_batch` fused pass): completions/preemptions `post`,
      admissions `take`, and ``grant − ticket`` is the physical free-slot
      count by the paper's counter identity;
  (c) decode + sampling through a caller-supplied jittable ``token_fn``
      (`make_paged_attn_token_fn` demonstrates paged single-token decode
      attention over a per-slot ring KV cache with in-graph prompt
      prefill);
  (d) completion AND deadline detection: sequences that hit ``max_new``
      or whose deadline passes mid-decode are tombstoned in-graph and
      their slots posted back into the SAME scanned round machinery —
      a preempted slot's unit re-enters the pool feeding this round's
      replenish, so the next live ticket is re-granted without any host
      involvement (the ROADMAP's deadline-aware decode preemption).

The host syncs once per K tokens — launch plus one drain of the
(K, S) token/event buffers — instead of once per token.

Round order (must mirror `ContinuousBatchingEngine.step()` exactly —
property-tested in tests/test_megastep.py):

  preempt expired running slots  →  QoS admission round (freed units feed
  the same round's replenish)  →  assign free slots to admitted rows in
  wrap-safe FCFS order  →  decode + sample every busy slot  →  retire
  completed slots (their units bank for the next round, exactly the
  host engine's ``_qos_free`` in kernel mode).

Block-paged KV pool (the TWA **block** semaphore ↔ paper mapping)
-----------------------------------------------------------------

With ``kv=`` configured, decode KV lives in a shared pool of fixed-size
blocks instead of per-slot rings, and the allocator is the paper's
semaphore a second time at block granularity (`core.functional.BlockPool`):

  * **units are blocks**: the semaphore's counter identity
    ``grant − ticket`` IS the free-block count, and the counters double as
    the cursors of the circular free queue holding block *identities* —
    an allocation is a wrap-safe `take` of ``demand`` units (ids leave the
    queue at the ticket cursor), a release is a `post` (ids re-enter at
    the grant cursor and the TWAHash buckets of the enabled ticket range
    are poked, staging block waiters for re-examination);
  * **sequences are waiters**: admission gates on BOTH resources — a free
    slot (the QoS round, unchanged) and the sequence's worst-case block
    demand ``⌈(prompt_len + max_new)/block_size⌉``
    (`admission.functional_qos.block_gate`): the longest FCFS prefix of
    QoS-admitted rows whose cumulative demand fits the pool is granted;
    the rest are *block-stalled* — their slot credit is refunded to their
    tenant and they stay live in the backlog, retrying every round (FCFS
    is strict: an unfit row blocks all later rows, so small sequences can
    never starve a large one);
  * **preemption is a tombstoned take**: a deadline-preempted slot's
    blocks are posted back BEFORE this round's admission (they feed the
    same round's gate, like its slot unit feeds the same round's
    replenish); completion posts blocks back after decode, banking them
    for the next round — exactly the slot-unit timing;
  * the per-slot **block tables** (``EngineState.kv.tbl``) map slot ×
    block-ordinal → pool block id; `kernels/paged_decode` streams
    attention over exactly the live blocks (bytes ∝ live tokens, not
    ∝ S·C as with the dense rings).

Continuous chunked prefill (incremental allocation + the stall/park policy)
---------------------------------------------------------------------------

With ``chunk > 0`` (engine: ``chunked_prefill=(chunk, budget)``), the
worst-case up-front reservation is replaced by **incremental** block
acquisition (`serving.prefill`):

  * admission gates on *first-chunk* demand only; each engine round
    co-schedules prompt chunks with decode under the per-round prefill
    token ``budget`` (Sarathi-style — long prompts stream through without
    monopolizing rounds, decode is never throttled);
  * a sequence takes blocks exactly when it crosses a block boundary —
    prefill chunks take ``⌈(pos+ct)/BS⌉ − held``, decode takes one block
    when its write cursor hits its capacity;
  * **stall policy**: on pool exhaustion the slot PARKS on the block
    semaphore's waiting array (`core.functional.pool_try_alloc`): it
    records the TWAHash bucket of the future grant value that would make
    it runnable and is re-examined only when a release pokes that bucket
    (`core.functional.park_state`) — block-parked slots cost no per-round
    rescan, and resume FCFS because releases enable tickets in cursor
    order.  A parked slot neither prefills nor decodes; preemption and
    completion release its blocks exactly like a running slot's.

  **Headroom invariant (no deadlock).**  For live slots in Banker
  priority order (admission round, FCFS key — `prefill.banker_order`),
  every take and every admission preserves

      rem_i  ≤  free  +  Σ_{j<i} held_j          for all live i,

  i.e. each slot's worst-case remaining demand is covered by the free
  pool plus what its priority-predecessors will release.  The
  priority-first slot therefore never parks; it finishes, releases, and
  hands the cover down — every parked slot is eventually resumed.
  Admission enforces it via the reserved-headroom check in
  `admission.functional_qos.block_gate` (+ `block_headroom`), takes via
  the margin scan in `serving.prefill.chunk_plan`, and the submit-time
  ``demand ≤ pool`` ValueError closes the induction for newcomers.

In-scan telemetry ring (observability without extra host syncs)
---------------------------------------------------------------

With ``ring_cap > 0`` the state carries a :class:`TelemetryRing`: every
scanned round appends one fixed-shape :class:`TelemetrySample` inside the
scan, and the host drains all K samples in the SAME single sync that
drains the token/event buffers — per-round observability is free of
round-trips by construction.  Each probe maps onto a paper construct:

  * ``slot_free`` / ``credit`` / ``kv_free`` — the semaphore **value** at
    each of the three granularities (free slots, per-tenant credit, KV
    blocks), always read through the paper's counter identity
    ``grant − ticket`` (wrap-safe signed distance), never a separate
    gauge that could drift from the counters;
  * ``kv_wait_hist`` — the **waiting-array occupancy** of the block
    semaphore: how many parked slots observe each TWAHash bucket
    (`core.functional.bucket_histogram`).  This is the paper's long-term
    wait made visible — a flat histogram means the salt disperses
    waiters (bounded re-checks per poke), a spike is hash aliasing;
  * ``poke_dead`` — the per-tenant tombstone slack: how far the QoS
    **poke window** over-covers live tickets (the skip-aware grant's
    conservative wake range; `functional_qos.QoSState.dead`);
  * ``kv_pokes`` — cumulative waiting-array pokes of the block semaphore
    (``Σ bucket_seq``): the wake traffic a release fan-out generates;
  * ``gate_stalls`` / ``parked`` — short-term (admission-time) vs
    long-term (mid-sequence) block waiting, the two wait classes the
    paper distinguishes;
  * ``health`` — the in-scan invariant-sentinel bitmask
    (`serving.sentinels`): counter conservation at all three semaphore
    granularities, the block-pool partition audit, Banker headroom, the
    stuck-slot watchdog, and NaN/Inf detection — 0 when every invariant
    holds.  The recovery ladder (`repro.resilience.recovery`) keys its
    escalation off these bits.

The central property extends the repo's spine invariant: the ring of
``megastep(K)`` is **bit-identical** to the concatenation of the K
per-step snapshots the host `ContinuousBatchingEngine.step()` assembles
from its mirrors (tests/test_obs.py — kernel-QoS, paged, and chunked
modes, incl. 2³² counter wrap).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..admission.functional_qos import (
    QoSState,
    block_gate,
    block_headroom,
    qos_scan_round,
)
from ..core.functional import (
    BlockPool,
    SemaState,
    _sdist,
    bucket_histogram,
    make_block_pool,
    make_sema,
    pool_alloc,
    pool_free_count,
    pool_incref,
    pool_release,
    pool_try_alloc,
    post_batch,
    segment_counts,
    take_batch,
)
from .prefill import (
    banker_order,
    cdiv,
    chunk_plan,
    first_chunk_demand,
    pending_prompt_tokens,
    shared_first_chunk_demand,
    total_block_demand,
)
from .prefix import (
    PrefixCache,
    cache_lookup,
    cache_register,
    make_prefix_cache,
)

# admission-order sort key packs (clamped ticket distance, tenant index)
# into one int32: distances beyond ±2²⁰ cannot occur for admitted rows
# (bounded by outstanding grant ≪ backlog capacity), tenant index < 256.
_D_CLAMP = 1 << 20
_T_BITS = 8

# waiting-array table width of the engine-owned semaphores (free-slot sema
# AND the block pool) — also the width of the telemetry ring's occupancy
# histogram.  The scheduler's host mirrors (`_kv_sema`, the host sample's
# bincount) must use the SAME width for the bit-identity property.
SLOT_TABLE = 64


class Backlog(NamedTuple):
    """Waiting requests, device-resident (static capacity B ≥ S)."""

    valid: jax.Array         # (B,) bool — ticketed, not yet admitted/expired
    tenant: jax.Array        # (B,) i32
    ticket: jax.Array        # (B,) u32
    deadline: jax.Array      # (B,) f32 — relative to the megastep epoch
    rid: jax.Array           # (B,) i32
    max_new: jax.Array       # (B,) i32
    prompt: jax.Array        # (B, P) i32 — padded prompt tokens
    prompt_len: jax.Array    # (B,) i32
    admit_round: jax.Array   # (B,) i32 — global round of admission (-1)
    expire_round: jax.Array  # (B,) i32 — global round of expiry (-1)
    slot: jax.Array          # (B,) i32 — slot assigned at admission (-1)
    # -- prompt-prefix sharing (serving.prefix; None when disabled) --
    ph: Optional[jax.Array] = None  # (B, 2, W+1) u32 — prompt hash table
    #                                 (prefix.prompt_hashes, computed on the
    #                                 host at submit — never re-hashed here)


class Slots(NamedTuple):
    """Per-slot decode state (S rows of the batched KV cache)."""

    busy: jax.Array      # (S,) bool
    row: jax.Array       # (S,) i32 — backlog row served (B+s ⇒ active at launch)
    rid: jax.Array       # (S,) i32
    tenant: jax.Array    # (S,) i32
    deadline: jax.Array  # (S,) f32 — decode deadline (preemption), epoch-relative
    max_new: jax.Array   # (S,) i32
    emitted: jax.Array   # (S,) i32 — tokens emitted so far
    token: jax.Array     # (S,) i32 — last token (next decode input)
    pos: jax.Array       # (S,) i32 — KV write cursor / absolute position
    # -- continuous chunked prefill (serving.prefill; inert when chunk=0) --
    plen: jax.Array      # (S,) i32 — prompt length (pos < plen ⇒ prefilling)
    prompt: jax.Array    # (S, P) i32 — the slot's prompt (chunk reads)
    prio_r: jax.Array    # (S,) i32 — admission round (Banker order, primary)
    prio_k: jax.Array    # (S,) i32 — packed FCFS admission key (secondary)
    parked: jax.Array    # (S,) bool — block-stalled on the waiting array
    park_bucket: jax.Array  # (S,) i32 — observed TWAHash bucket (park_state)
    park_seq: jax.Array     # (S,) u32 — bucket sequence at park time
    chunk: jax.Array     # (S,) i32 — prefill tokens scheduled THIS round
    last_adv: jax.Array  # (S,) i32 — last round this slot made progress
    #                      (token emitted / chunk landed / just assigned) —
    #                      the stuck-slot watchdog's clock (sentinels.py)
    # -- prompt-prefix sharing (serving.prefix; None when disabled) --
    ph: Optional[jax.Array] = None       # (S, 2, W+1) u32 — prompt hashes
    #                                      (copied from the backlog row at
    #                                      assignment; cache_register reads)
    cow_src: Optional[jax.Array] = None  # (S,) i32 — copy-on-write source
    #                                      block id staged THIS round (-1 =
    #                                      none): token_fn copies the shared
    #                                      block's contents into the fresh
    #                                      private block before decode writes


class KVPool(NamedTuple):
    """Block-paged KV state: the TWA block semaphore over the circular
    free queue (`core.functional.BlockPool`) plus the per-slot block
    tables the paged-decode kernel streams through.

    Refcounted sharing (PR 9) — the semaphore with a conditional `post`
    ----------------------------------------------------------------------
    With ``cache`` attached, a block may be referenced by SEVERAL slot
    tables at once (a shared prompt prefix).  The paper's semaphore keeps
    owning the block *lifecycle* — the free-queue cursors still satisfy
    ``grant − ticket = free`` and every free block id still lives in
    ``free_q[ticket..grant)`` — but `post` becomes **conditional on the
    refcount**: attaching a sharer (`core.functional.pool_incref`) moves
    no counter and pokes no bucket (sharing a live block is free at the
    semaphore level), and a release (`pool_release`) decrefs first, only
    re-enqueueing the id and poking the waiting array when the LAST
    sharer leaves.  The conservation invariant generalizes to

        {free_q[ticket..grant)} ∪ {blocks with refcnt > 0} = {0..NB−1}
        Σ table references = Σ refcnt

    (the PR-4 one-owner partition is the refcnt ∈ {0,1} special case).
    The ``cache`` itself holds NO references — it is a weak gen-stamped
    index (`serving.prefix.PrefixCache`), so it never delays a free and
    never resurrects a reused block."""

    pool: BlockPool      # free queue + block semaphore (grant−ticket = free)
    tbl: jax.Array       # (S, MB) i32 — per-slot block ids, -1 = unallocated
    cache: Optional[PrefixCache] = None  # weak prefix index (None = no
    #                                      sharing; presence enables the
    #                                      sharing paths — a STATIC pytree
    #                                      property, so both modes stay
    #                                      single-trace)


class TelemetrySample(NamedTuple):
    """One engine round's end-of-round probe set (module docstring maps
    each field to its paper construct).  Fixed-shape so a (R, …) ring of
    them rides the scanned carry; every field is the value AFTER the
    round's completion phase — exactly what the host `step()` path can
    mirror from its own bookkeeping, making megastep(K)'s ring
    bit-identical to K host snapshots."""

    round_no: jax.Array         # i32 — global engine round index
    now: jax.Array              # f32 — the round's clock (epoch-relative)
    admits: jax.Array           # i32 — backlog rows granted a slot
    expires: jax.Array          # i32 — backlog rows tombstoned (deadline)
    preempts: jax.Array         # i32 — running slots deadline-preempted
    tokens: jax.Array           # i32 — slots that emitted a token
    prefill_tokens: jax.Array   # i32 — prompt tokens written this round
    prefill_chunks: jax.Array   # i32 — slots that wrote a prompt chunk
    prefill_pending: jax.Array  # i32 — prompt tokens still unprefilled
    gate_stalls: jax.Array      # i32 — rows block-stalled at the gate
    parked: jax.Array           # i32 — slots parked on the waiting array
    backlog: jax.Array          # i32 — live backlog rows after the round
    active: jax.Array           # i32 — busy slots after the round
    slot_free: jax.Array        # i32 — free-slot sema grant − ticket
    kv_free: jax.Array          # i32 — block sema grant − ticket (0 dense)
    kv_pokes: jax.Array         # u32 — Σ block-sema bucket_seq (mod 2³²)
    prefix_hits: jax.Array      # i32 — fully-covered admits this round
    #                             (prefix cache served the WHOLE prompt:
    #                             zero prefill flops, zero new HBM)
    blocks_shared: jax.Array    # i32 — blocks with refcnt ≥ 2 (end of round)
    cow_copies: jax.Array       # i32 — copy-on-write takes this round
    health: jax.Array           # u32 — invariant-sentinel bitmask
    #                             (serving/sentinels.py; 0 = healthy.  Low
    #                             16 bits are host-mirrorable checks —
    #                             slot/credit/KV counter conservation,
    #                             Banker headroom, stuck-slot watchdog;
    #                             high bits are device-only ground truth:
    #                             block-pool partition audit, NaN/Inf in
    #                             the model.  See HEALTH_BITS.)
    credit: jax.Array           # (T,) i32 — per-tenant grant − consumed
    poke_dead: jax.Array        # (T,) u32 — per-tenant poke-window slack
    kv_wait_hist: jax.Array     # (H,) i32 — waiting-array occupancy
    # ---- per-round trace-event table (PR 10) --------------------------
    # Fixed-shape (E = 8·n_slots) compacted event list: the round's
    # ADMIT / PREFILL_CHUNK / PARK / RESUME / PREFIX_ATTACH / COW /
    # PREEMPT / FINISH records in canonical phase-major, lane-ascending
    # order (`serving.events.SCAN_SEGMENTS`); entries past ``ev_n`` are
    # EV_NONE padding.  The virtual clock of every event is the sample's
    # ``now``.  Host `step()` mirrors the list bit-exactly.
    ev_n: jax.Array             # i32 — number of valid events this round
    ev_kind: jax.Array          # (E,) i32 — serving.events.EV_* kind
    ev_uid: jax.Array           # (E,) i32 — request id (−1 padding)
    ev_slot: jax.Array          # (E,) i32 — engine slot (−1 padding)
    ev_arg: jax.Array           # (E,) i32 — per-kind payload (0 padding)


class TelemetryRing(NamedTuple):
    """Fixed-capacity ring of :class:`TelemetrySample` carried through the
    scan (capacity R = pow2 ≥ K, so one megastep never wraps and the pow2
    mask arithmetic stays exact if a longer-lived ring ever does)."""

    cursor: jax.Array      # i32 — next write index (monotonic)
    buf: TelemetrySample   # every leaf has leading dim R


def make_telemetry_ring(capacity: int, n_tenants: int,
                        hist: int = SLOT_TABLE,
                        ev_cap: int = 0) -> TelemetryRing:
    assert capacity > 0 and (capacity & (capacity - 1)) == 0, \
        "ring capacity must be a power of two (wrap-safe cursor mask)"
    R, T = capacity, n_tenants
    z = jnp.zeros((R,), jnp.int32)
    return TelemetryRing(
        cursor=jnp.zeros((), jnp.int32),
        buf=TelemetrySample(
            round_no=z, now=jnp.zeros((R,), jnp.float32), admits=z,
            expires=z, preempts=z, tokens=z, prefill_tokens=z,
            prefill_chunks=z, prefill_pending=z, gate_stalls=z, parked=z,
            backlog=z, active=z, slot_free=z, kv_free=z,
            kv_pokes=jnp.zeros((R,), jnp.uint32),
            prefix_hits=z, blocks_shared=z, cow_copies=z,
            health=jnp.zeros((R,), jnp.uint32),
            credit=jnp.zeros((R, T), jnp.int32),
            poke_dead=jnp.zeros((R, T), jnp.uint32),
            kv_wait_hist=jnp.zeros((R, hist), jnp.int32),
            ev_n=z,
            ev_kind=jnp.zeros((R, ev_cap), jnp.int32),
            ev_uid=jnp.full((R, ev_cap), -1, jnp.int32),
            ev_slot=jnp.full((R, ev_cap), -1, jnp.int32),
            ev_arg=jnp.zeros((R, ev_cap), jnp.int32)))


def ring_append(ring: TelemetryRing, sample: TelemetrySample) -> TelemetryRing:
    R = ring.buf.round_no.shape[0]
    idx = ring.cursor & (R - 1)
    return TelemetryRing(
        cursor=ring.cursor + 1,
        buf=jax.tree_util.tree_map(
            lambda b, s: b.at[idx].set(s), ring.buf, sample))


def ring_samples(ring, t0: float = 0.0) -> list:
    """Host-side drain: the ring (already device_get, as part of the ONE
    megastep sync) as a list of per-round dicts in round order, oldest
    first — the exact record shape `ContinuousBatchingEngine.step()`
    assembles per host round, so the two paths compare with ``==``.
    ``t0`` re-anchors the epoch-relative round clocks to the engine's
    absolute clock (``clock = t0 + now``)."""
    import numpy as np

    buf, n = ring.buf, int(ring.cursor)
    R = np.asarray(buf.round_no).shape[0]
    out = []
    for i in range(max(n - R, 0), n):
        k = i & (R - 1)
        out.append({
            "round": int(buf.round_no[k]),
            "clock": float(t0) + float(buf.now[k]),
            "admits": int(buf.admits[k]),
            "expires": int(buf.expires[k]),
            "preempts": int(buf.preempts[k]),
            "tokens": int(buf.tokens[k]),
            "prefill_tokens": int(buf.prefill_tokens[k]),
            "prefill_chunks": int(buf.prefill_chunks[k]),
            "prefill_pending": int(buf.prefill_pending[k]),
            "gate_stalls": int(buf.gate_stalls[k]),
            "parked": int(buf.parked[k]),
            "backlog": int(buf.backlog[k]),
            "active": int(buf.active[k]),
            "slot_free": int(buf.slot_free[k]),
            "kv_free": int(buf.kv_free[k]),
            "kv_pokes": int(buf.kv_pokes[k]),
            "prefix_hits": int(buf.prefix_hits[k]),
            "blocks_shared": int(buf.blocks_shared[k]),
            "cow_copies": int(buf.cow_copies[k]),
            "health": int(buf.health[k]),
            "credit": [int(c) for c in np.asarray(buf.credit[k])],
            "poke_dead": [int(d) for d in np.asarray(buf.poke_dead[k])],
            "kv_wait_hist": [int(h) for h in
                             np.asarray(buf.kv_wait_hist[k])],
            "events": [[int(ek), int(eu), int(es), int(ea)]
                       for ek, eu, es, ea in zip(
                           np.asarray(buf.ev_kind[k])[:int(buf.ev_n[k])],
                           np.asarray(buf.ev_uid[k])[:int(buf.ev_n[k])],
                           np.asarray(buf.ev_slot[k])[:int(buf.ev_n[k])],
                           np.asarray(buf.ev_arg[k])[:int(buf.ev_n[k])])],
        })
    return out


class EngineState(NamedTuple):
    """The donated on-device engine pytree carried through the scan."""

    qos: QoSState        # per-tenant semaphores + shared waiting array
    slot_sema: SemaState  # free-slot TWA semaphore (grant − ticket = free)
    free: jax.Array      # i32 scalar — undistributed global slot pool
    round_no: jax.Array  # i32 scalar — global engine round counter
    backlog: Backlog
    slots: Slots
    kv: Optional[KVPool] = None  # block-paged KV pool (None = dense rings)
    stalls: Optional[jax.Array] = None  # i32 — cumulative parked slot-rounds
    chunks: Optional[jax.Array] = None  # i32 — cumulative prefill chunks
    ring: Optional[TelemetryRing] = None  # in-scan telemetry (None = off)


class RoundOut(NamedTuple):
    """Per-iteration scan outputs drained by the host once per megastep."""

    tokens: jax.Array  # (S,) i32 — token emitted by each slot this round
    emit: jax.Array    # (S,) bool — slot decoded this round
    fin: jax.Array     # (S,) bool — slot completed (hit max_new) this round
    pre: jax.Array     # (S,) bool — slot deadline-preempted this round
    row: jax.Array     # (S,) i32 — backlog row at emit time
    prerow: jax.Array  # (S,) i32 — backlog row at preemption time
    n_live: jax.Array  # i32 — backlog rows examined by the admission round
    n_active: jax.Array  # i32 — busy slots at decode time


# TokenFn: (model, EngineState) -> (next_tokens (S,) i32, model')
TokenFn = Callable
# AdmitFn: (model, EngineState, rows (S,) i32, mask (S,) bool,
#           slots (S,) i32) -> model'   — in-graph prefill hook
AdmitFn = Optional[Callable]


def make_engine_state(qos: QoSState, n_slots: int, backlog_cap: int,
                      prompt_cap: int, *, free_units=0,
                      slot_table: int = SLOT_TABLE, kv_blocks: int = 0,
                      kv_slot_blocks: int = 0, ring_cap: int = 0,
                      prefix_entries: int = 0,
                      hash_width: int = 0) -> EngineState:
    """Fresh device state (empty backlog, idle slots).  The scheduler
    refreshes backlog/slot rows from its host queues at each launch; the
    QoS state is the one source of truth shared with the host path.
    ``kv_blocks`` > 0 attaches a block-paged KV pool of that many blocks
    (power of two) with ``kv_slot_blocks``-entry per-slot block tables.
    ``ring_cap`` > 0 (power of two ≥ the scan length) attaches the
    in-scan :class:`TelemetryRing` (module docstring).
    ``prefix_entries`` > 0 (power of two; requires the pool) attaches the
    weak prefix cache and the prompt-hash / copy-on-write slot state that
    enable refcounted block sharing; ``hash_width`` is the per-prompt
    hash-table width W (``prompt_cap // block_size`` — one entry per full
    block boundary plus the full-prompt column)."""
    assert backlog_cap >= n_slots, "backlog capacity must cover the slots"
    assert prefix_entries == 0 or kv_blocks > 0, \
        "prefix sharing needs the block-paged pool"
    S, B, P = n_slots, backlog_cap, prompt_cap
    W = hash_width
    zb = jnp.zeros((B,), jnp.int32)
    kv = None
    if kv_blocks:
        assert kv_slot_blocks > 0, "paged pool needs a per-slot table size"
        kv = KVPool(pool=make_block_pool(kv_blocks, table_size=slot_table),
                    tbl=jnp.full((S, kv_slot_blocks), -1, jnp.int32),
                    cache=(make_prefix_cache(prefix_entries)
                           if prefix_entries else None))
    ring = None
    if ring_cap:
        # event-table capacity: 8 phase segments of S lanes each — every
        # kind can fire on at most S lanes per round, so the compacted
        # table never overflows (serving.events.SCAN_SEGMENTS)
        ring = make_telemetry_ring(ring_cap, qos.ticket.shape[0],
                                   hist=slot_table, ev_cap=8 * n_slots)
    return EngineState(
        kv=kv,
        ring=ring,
        qos=qos,
        slot_sema=make_sema(count=n_slots, table_size=slot_table),
        free=jnp.asarray(free_units, jnp.int32),
        round_no=jnp.zeros((), jnp.int32),
        stalls=jnp.zeros((), jnp.int32),
        chunks=jnp.zeros((), jnp.int32),
        backlog=Backlog(
            valid=jnp.zeros((B,), bool), tenant=zb,
            ticket=jnp.zeros((B,), jnp.uint32),
            deadline=jnp.full((B,), jnp.inf, jnp.float32),
            rid=jnp.full((B,), -1, jnp.int32), max_new=zb,
            prompt=jnp.zeros((B, P), jnp.int32), prompt_len=zb,
            admit_round=jnp.full((B,), -1, jnp.int32),
            expire_round=jnp.full((B,), -1, jnp.int32),
            slot=jnp.full((B,), -1, jnp.int32),
            ph=(jnp.zeros((B, 2, W + 1), jnp.uint32)
                if prefix_entries else None)),
        slots=Slots(
            busy=jnp.zeros((S,), bool),
            row=jnp.full((S,), -1, jnp.int32),
            rid=jnp.full((S,), -1, jnp.int32),
            tenant=jnp.zeros((S,), jnp.int32),
            deadline=jnp.full((S,), jnp.inf, jnp.float32),
            max_new=jnp.zeros((S,), jnp.int32),
            emitted=jnp.zeros((S,), jnp.int32),
            token=jnp.zeros((S,), jnp.int32),
            pos=jnp.zeros((S,), jnp.int32),
            plen=jnp.zeros((S,), jnp.int32),
            prompt=jnp.zeros((S, P), jnp.int32),
            prio_r=jnp.zeros((S,), jnp.int32),
            prio_k=jnp.zeros((S,), jnp.int32),
            parked=jnp.zeros((S,), bool),
            park_bucket=jnp.zeros((S,), jnp.int32),
            park_seq=jnp.zeros((S,), jnp.uint32),
            chunk=jnp.zeros((S,), jnp.int32),
            last_adv=jnp.zeros((S,), jnp.int32),
            ph=(jnp.zeros((S, 2, W + 1), jnp.uint32)
                if prefix_entries else None),
            cow_src=(jnp.full((S,), -1, jnp.int32)
                     if prefix_entries else None)),
    )


def _fcfs_key(backlog: Backlog, grant: jax.Array, mask: jax.Array):
    """Packed global admission-order key (wrap-safe signed ticket distance
    from the post-round grant frontier, tenant-index tiebreak); rows
    outside ``mask`` get the INT32_MAX sentinel.  Shared by slot
    assignment and the block gate — host and device MUST sort by the same
    total order for the multi-resource prefix to be bit-identical
    (`ContinuousBatchingEngine._kv_gate` mirrors this in numpy)."""
    d = _sdist(backlog.ticket, grant[backlog.tenant])
    return jnp.where(
        mask,
        (jnp.clip(d, -_D_CLAMP, _D_CLAMP) << _T_BITS) + backlog.tenant,
        jnp.iinfo(jnp.int32).max)


def _block_demand(backlog: Backlog, block_size: int) -> jax.Array:
    """Worst-case block demand per backlog row: every token the sequence
    can ever hold (truncated prompt + max_new) — acquired in full at
    admission in up-front mode; the commitment watermark's per-row demand
    in chunked mode."""
    return total_block_demand(backlog.prompt_len, backlog.max_new,
                              block_size)


def _slot_rem(sl: Slots, held: jax.Array, block_size: int) -> jax.Array:
    """Worst-case REMAINING block demand per slot (the safety invariant's
    ``rem``): whole-lifetime demand minus the blocks already held; 0 for
    idle slots."""
    total = total_block_demand(sl.plen, sl.max_new, block_size)
    return jnp.where(sl.busy, total - held, 0)


def _share_flags(tbl: jax.Array, refcnt: jax.Array, busy: jax.Array,
                 pos: jax.Array, plen: jax.Array, held: jax.Array,
                 block_size: int):
    """The two per-slot sharing inputs of `serving.prefill.chunk_plan`,
    in ONE canonical formulation (host `_chunk_step` and the scanned
    round both call this — the formulas must never fork):

      ``cow``: the slot is decode-ready and its NEXT write would land in
      its current tail block while that block is still shared
      (``refcnt > 1``) — it must take a private copy first;
      ``held_free``: how many of the slot's held blocks it alone
      references (``refcnt == 1``) — the only ones whose release will
      actually free pool capacity (the Banker cover).

    Returns ``(cow (S,) bool, held_free (S,) i32)``.
    """
    S, MB = tbl.shape
    NB = refcnt.shape[0]
    rows_i = jnp.arange(S, dtype=jnp.int32)
    cur = tbl[rows_i, jnp.clip(held - 1, 0, MB - 1)]
    cow = (busy & (pos >= plen) & (pos < held * block_size) & (cur >= 0)
           & (refcnt[jnp.clip(cur, 0, NB - 1)] > 1))
    priv = (tbl >= 0) & (refcnt[jnp.clip(tbl, 0, NB - 1)] == 1)
    held_free = jnp.sum(priv.astype(jnp.int32), axis=1)
    return cow, held_free


def _chunk_phase(state: EngineState, chunk: int, budget: int,
                 block_size: int):
    """The chunked-prefill slice of one engine round: plan this round's
    chunks/takes/parks (`serving.prefill.chunk_plan` over the Banker
    order), take the granted blocks from the TWA block semaphore
    (`core.functional.pool_try_alloc` — parked slots register on the
    waiting array instead), scatter the fresh ids into the slot tables,
    and stage the per-slot chunk lengths for ``token_fn``.  With the
    prefix cache attached the plan additionally carries copy-on-write
    takes (`_share_flags`): a granted COW block REPLACES the slot's
    shared tail block in the table, the replaced id is decref'd in ONE
    batched `pool_release`, and ``slots.cow_src`` stages the source id
    for token_fn's in-pass block copy.  Returns ``(state', emit, n_cow,
    ev)`` — the decode mask, the round's copy-on-write count, and the
    trace-event masks/args (PARK transitions with their deficits, RESUME
    transitions, chunk token counts, COW takes with the replaced block
    ids) the caller folds into the in-scan event table."""
    sl, kv = state.slots, state.kv
    prev_parked = sl.parked  # pre-plan park state (PARK/RESUME transitions)
    sharing = kv.cache is not None
    S, MB = kv.tbl.shape
    held = jnp.sum((kv.tbl >= 0).astype(jnp.int32), axis=1)
    # TWA wake gate: parked slots re-attempt only when a release poked
    # their observed bucket (spurious wakes from hash aliasing are benign
    # re-checks; a missed state change is impossible — free−guard grows
    # only via releases, and every release pokes the enabled range).
    woken = kv.pool.sema.bucket_seq[sl.park_bucket] != sl.park_seq
    if sharing:
        cow, held_free = _share_flags(kv.tbl, kv.pool.refcnt, sl.busy,
                                      sl.pos, sl.plen, held, block_size)
    else:  # chunk_plan reduces bit-identically to the PR-5 plan
        cow, held_free = jnp.zeros((S,), bool), held
    rem = _slot_rem(sl, held, block_size) + jnp.where(cow, 1, 0)
    order = banker_order(rem, sl.prio_r, sl.prio_k, sl.busy)
    plan = chunk_plan(order, sl.busy, sl.parked, woken, sl.pos, sl.plen,
                      sl.max_new, held, pool_free_count(kv.pool), cow,
                      held_free, chunk=chunk, budget=budget,
                      block_size=block_size)
    newly = plan.parked & (plan.deficit > 0)
    max_take = cdiv(chunk, block_size) + 1  # a chunk can straddle a block
    pool, ids, bkt, seq = pool_try_alloc(kv.pool, plan.take, max_take,
                                         park=newly, deficit=plan.deficit)
    k = jnp.arange(max_take, dtype=jnp.int32)
    rowi = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[:, None],
                            (S, max_take))
    valid = k[None, :] < plan.take[:, None]
    # a COW grant replaces the shared tail (column held−1) instead of
    # extending the table; the replaced id is read BEFORE the scatter
    base = jnp.where(plan.cow, held - 1, held) if sharing else held
    old = kv.tbl[jnp.arange(S, dtype=jnp.int32),
                 jnp.clip(held - 1, 0, MB - 1)]
    tbl = kv.tbl.at[jnp.where(valid, rowi, S),
                    base[:, None] + k[None, :]].set(ids, mode="drop")
    n_cow = jnp.int32(0)
    if sharing:
        # ONE batched decref of every replaced shared block (identity on
        # an empty mask; cond-skipped at runtime — most rounds copy
        # nothing.  The host replica issues the SAME single batched call,
        # keeping free-queue id order identical.)
        pool = jax.lax.cond(
            jnp.any(plan.cow),
            lambda p: pool_release(p, old, plan.cow),
            lambda p: p, pool)
        n_cow = jnp.sum(plan.cow.astype(jnp.int32))
    sl = sl._replace(
        chunk=plan.tokens, parked=plan.parked,
        park_bucket=jnp.where(newly, bkt, sl.park_bucket),
        park_seq=jnp.where(newly, seq, sl.park_seq),
        cow_src=(jnp.where(plan.cow, old, -1) if sharing else sl.cow_src))
    state = state._replace(
        kv=KVPool(pool=pool, tbl=tbl, cache=kv.cache), slots=sl,
        stalls=state.stalls + jnp.sum(plan.parked.astype(jnp.int32)),
        chunks=state.chunks + jnp.sum((plan.tokens > 0).astype(jnp.int32)))
    ev = {
        "park": plan.parked & ~prev_parked,
        "park_arg": plan.deficit,
        "resume": prev_parked & ~plan.parked,
        "chunk_tok": plan.tokens,
        "cow": plan.cow if sharing else jnp.zeros_like(plan.parked),
        "cow_old": old,
    }
    return state, plan.emit, n_cow, ev


def _assign_slots(state: EngineState, admitted: jax.Array,
                  chunked: bool = False, cov=None):
    """Map admitted backlog rows to free slots: rows in wrap-safe per-tenant
    FCFS admission order (signed ticket distance from the post-round grant
    frontier, tenant index tiebreak — the in-graph `_fcfs_sort`) take
    ascending free slot indices, gated through the free-slot TWA semaphore
    (admissions `take`; the QoS invariant guarantees n_admitted ≤ free).

    Every slot records its Banker priority — (admission round, FCFS key) —
    at assignment; ``chunked`` starts the KV cursor at 0 (the prompt is
    prefilled chunk-by-chunk) instead of at ``prompt_len`` (instant
    prefill) and copies the prompt into the slot row so later chunks can
    read it after the backlog row is recycled.  With prefix sharing,
    ``cov`` (B,) i32 is each row's cache-covered token count — the KV
    cursor starts AT the divergence point (the covered tokens are
    already resident in the shared blocks the caller attaches)."""
    sl, bl = state.slots, state.backlog
    S = sl.busy.shape[0]
    B = bl.valid.shape[0]

    key = _fcfs_key(bl, state.qos.grant, admitted)
    order = jnp.argsort(key, stable=True)        # admitted rows first, FCFS
    n_adm = jnp.sum(admitted.astype(jnp.int32))

    j = jnp.arange(S, dtype=jnp.int32)
    rows = order[:S]                              # j-th admitted row (B ≥ S)
    assign = j < n_adm
    free_order = jnp.argsort(sl.busy, stable=True)  # free slots ascending
    tgt = jnp.where(assign, free_order[:S], S)      # S = out-of-range → drop

    slot_sema, _, _, _ = take_batch(state.slot_sema, assign)
    seed_tok = bl.prompt[rows, jnp.maximum(bl.prompt_len[rows] - 1, 0)]
    if cov is not None:  # sharing: resume past the cache-covered prefix
        pos0 = cov[rows]
    else:
        pos0 = jnp.zeros_like(rows) if chunked else bl.prompt_len[rows]
    slots = Slots(
        busy=sl.busy.at[tgt].set(True, mode="drop"),
        row=sl.row.at[tgt].set(rows, mode="drop"),
        rid=sl.rid.at[tgt].set(bl.rid[rows], mode="drop"),
        tenant=sl.tenant.at[tgt].set(bl.tenant[rows], mode="drop"),
        deadline=sl.deadline.at[tgt].set(bl.deadline[rows], mode="drop"),
        max_new=sl.max_new.at[tgt].set(bl.max_new[rows], mode="drop"),
        emitted=sl.emitted.at[tgt].set(0, mode="drop"),
        token=sl.token.at[tgt].set(seed_tok, mode="drop"),
        pos=sl.pos.at[tgt].set(pos0, mode="drop"),
        plen=sl.plen.at[tgt].set(bl.prompt_len[rows], mode="drop"),
        prompt=sl.prompt.at[tgt].set(bl.prompt[rows], mode="drop"),
        prio_r=sl.prio_r.at[tgt].set(state.round_no, mode="drop"),
        prio_k=sl.prio_k.at[tgt].set(key[rows], mode="drop"),
        parked=sl.parked.at[tgt].set(False, mode="drop"),
        park_bucket=sl.park_bucket.at[tgt].set(0, mode="drop"),
        park_seq=sl.park_seq.at[tgt].set(jnp.uint32(0), mode="drop"),
        chunk=sl.chunk.at[tgt].set(0, mode="drop"),
        last_adv=sl.last_adv.at[tgt].set(state.round_no, mode="drop"),
        ph=(sl.ph.at[tgt].set(bl.ph[rows], mode="drop")
            if sl.ph is not None else None),
        cow_src=(sl.cow_src.at[tgt].set(-1, mode="drop")
                 if sl.cow_src is not None else None))
    bslot = bl.slot.at[jnp.where(assign, rows, B)].set(tgt, mode="drop")
    return state._replace(slots=slots, slot_sema=slot_sema,
                          backlog=bl._replace(slot=bslot)), rows, assign, tgt


def engine_round(state: EngineState, model, now, *, token_fn: TokenFn,
                 admit_fn: AdmitFn = None, admit_impl=None,
                 block_size: int = 0, chunk: int = 0, budget: int = 0,
                 commit: int = 0, watchdog: int = 0):
    """One fused engine iteration — the pure-functional `step()`.

    ``admit_impl`` overrides the admission-round implementation (signature
    of `functional_qos.qos_round`); the default is the functional path, and
    the scheduler substitutes `kernels.qos_admission.qos_round_fused` on
    TPU (bit-identical — tests/test_qos_kernel.py).

    With ``state.kv`` set (block-paged KV pool), ``block_size`` must be the
    static pool block size: admission additionally gates on worst-case
    block demand (see the module docstring's block-semaphore mapping).

    ``chunk > 0`` selects **continuous chunked prefill** (requires the
    pool): admission gates on first-chunk demand behind the reserved
    headroom AND the ``commit``-block commitment watermark, prompts
    prefill ``chunk`` tokens per round under the per-round prefill token
    ``budget``, blocks are taken incrementally at block-boundary
    crossings, and block-stalled slots park on the block semaphore's
    waiting array (module docstring; `serving.prefill`).  ``token_fn``
    must then handle the prefill phase — see
    :func:`chunked_prefill_token_fn`.

    ``watchdog > 0`` arms the stuck-slot sentinel: a busy slot that makes
    no progress for ``watchdog`` consecutive rounds sets ``H_STUCK`` in
    the round's health bitmask (`serving.sentinels` — requires the
    telemetry ring to be observable).
    """
    paged = state.kv is not None
    assert not paged or block_size > 0, "paged pool needs block_size"
    chunked = chunk > 0
    assert not chunked or (paged and budget > 0), \
        "chunked prefill needs the block pool and a positive token budget"
    # prefix sharing is a STATIC pytree property (cache present or not) —
    # both modes trace once, and the no-sharing trace is unchanged
    sharing = paged and state.kv.cache is not None
    assert not sharing or chunked, \
        "prefix sharing requires continuous chunked prefill"
    sl, bl = state.slots, state.backlog
    S = sl.busy.shape[0]
    now = jnp.asarray(now, jnp.float32)

    # (1) deadline-aware decode preemption: expired RUNNING sequences are
    # tombstoned and their slots posted back into THIS round's pool.
    pre = sl.busy & (sl.deadline <= now)
    n_pre = jnp.sum(pre.astype(jnp.int32))
    prerow = jnp.where(pre, sl.row, -1)
    # trace: capture uid/progress at ENTRY — the slot may be re-assigned
    # to a new request later this same round (its unit feeds this round's
    # pool), which would overwrite rid/emitted before the phase-6 table
    pre_uid, pre_arg = sl.rid, sl.emitted
    sl = sl._replace(busy=sl.busy & ~pre,
                     row=jnp.where(pre, -1, sl.row),
                     parked=sl.parked & ~pre)
    state = state._replace(slots=sl, slot_sema=post_batch(state.slot_sema, n_pre))
    if paged:
        # preempted slots' blocks post back BEFORE admission — they feed
        # THIS round's block gate, mirroring the slot-unit feedback.  The
        # release is an identity on an empty mask, so it is cond-skipped
        # at runtime (most rounds preempt nothing — real wall-time inside
        # the compiled scan, bit-identical either way).
        state = state._replace(kv=jax.lax.cond(
            jnp.any(pre), lambda kv: KVPool(
                pool=pool_release(kv.pool, kv.tbl, pre),
                tbl=jnp.where(pre[:, None], -1, kv.tbl),
                cache=kv.cache),
            lambda kv: kv, state.kv))

    # (2) the QoS admission round, preemption-freed units feeding replenish.
    # The round only runs when live rows exist — the host path's early
    # return on an empty backlog (an unconditional round would still poke
    # the dead-slack window and drift bucket_seq off the host oracle).
    alive = bl.valid

    def _round(args):
        qos, free = args
        return qos_scan_round(qos, bl.tenant, bl.ticket, alive, bl.deadline,
                              now, free, n_pre, max_units=S,
                              round_impl=admit_impl)

    def _skip(args):
        qos, free = args
        no = jnp.zeros(alive.shape, bool)
        return qos, no, no, free + n_pre

    qos, admitted, expired, leftover = jax.lax.cond(
        jnp.any(alive), _round, _skip, (state.qos, state.free))

    # (2b) multi-resource gate: of the QoS-admitted rows, only the FCFS
    # prefix whose cumulative block demand fits the free pool is granted;
    # block-stalled rows refund their tenant's slot credit and stay live
    # in the backlog (they retry every round).  Cond-skipped when the QoS
    # round admitted nothing (gate/refund are identities on an empty mask
    # — the host path's ``admitted.any()`` early-out).  Chunked prefill
    # gates on FIRST-CHUNK demand only, behind the reserved headroom that
    # keeps the no-deadlock invariant (module docstring).
    doomed = None
    sh_c = sh_bids = sh_tail = sh_cov = None
    if paged:
        if chunked:
            held = jnp.sum((state.kv.tbl >= 0).astype(jnp.int32), axis=1)
            if sharing:
                # read-only longest-prefix probe (weak entries — the pool
                # is untouched until the attach below incref's the hits)
                sh_c, sh_bids, sh_tail, sh_cov = cache_lookup(
                    state.kv.cache, state.kv.pool, bl.ph, bl.prompt_len,
                    block_size)
                # POST-DIVERGENCE demand: the covered blocks are free to
                # attach (incref only) — admission pays for fresh blocks
                # past the divergence point alone
                demand = shared_first_chunk_demand(
                    bl.prompt_len, sh_cov, chunk, block_size)
                commit_demand = _block_demand(bl, block_size) - sh_c
                # a row whose private demand exceeds the whole pool can
                # NEVER be granted at the current coverage: skip it in the
                # FCFS prefix (it must not dam later rows) but keep it
                # live/stalled — future re-registration can resurrect it
                NB = state.kv.pool.gen.shape[0]
                doomed = commit_demand > NB
                cow_a, held_free_a = _share_flags(
                    state.kv.tbl, state.kv.pool.refcnt, state.slots.busy,
                    state.slots.pos, state.slots.plen, held, block_size)
                rem = (_slot_rem(state.slots, held, block_size)
                       + jnp.where(cow_a, 1, 0))
                held_cover = held_free_a
            else:
                demand = first_chunk_demand(bl.prompt_len, chunk,
                                            block_size)
                commit_demand = _block_demand(bl, block_size)
                rem = _slot_rem(state.slots, held, block_size)
                held_cover = held
            headroom = block_headroom(
                rem, held_cover,
                banker_order(rem, state.slots.prio_r, state.slots.prio_k,
                             state.slots.busy),
                state.slots.busy)
            # commitment watermark: lifetime demand admits only into the
            # UNCOMMITTED budget (pipelined, unlike up-front — see
            # block_gate); the bootstrap flag keeps over-watermark
            # requests servable (alone, strict FCFS)
            total_rem = jnp.sum(rem)
            commit_free = commit - total_rem
            bootstrap = total_rem == 0
        else:
            demand = _block_demand(bl, block_size)
            headroom = jnp.int32(0)
            commit_demand, commit_free, bootstrap = None, 0, False

        def _gate(args):
            qos, admitted, _ = args
            eligible = (admitted & ~doomed) if sharing else admitted
            granted = block_gate(eligible, demand,
                                 _fcfs_key(bl, qos.grant, eligible),
                                 pool_free_count(state.kv.pool), headroom,
                                 commit_demand, commit_free, bootstrap)
            stalled = admitted & ~granted
            return (qos._replace(consumed=qos.consumed - segment_counts(
                bl.tenant, stalled, qos.ticket.shape[0])), granted,
                jnp.sum(stalled.astype(jnp.int32)))

        qos, admitted, n_stall = jax.lax.cond(
            jnp.any(admitted), _gate, lambda a: a,
            (qos, admitted, jnp.int32(0)))
    else:
        n_stall = jnp.int32(0)
    rno = state.round_no
    bl = bl._replace(
        valid=alive & ~admitted & ~expired,
        admit_round=jnp.where(admitted, rno, bl.admit_round),
        expire_round=jnp.where(expired, rno, bl.expire_round))
    state = state._replace(qos=qos, backlog=bl)

    # (3) slot assignment (FCFS → ascending free slots)
    state, rows, assign, tgt = _assign_slots(
        state, admitted, chunked, cov=sh_cov if sharing else None)
    n_hits = jnp.int32(0)
    if sharing:
        # (3a) attach the cache-covered prefix: seed the matched block ids
        # into the fresh slots' tables and incref each — no counter moves,
        # no pokes, no prefill flops for the covered tokens (`pool_incref`
        # is the conditional-post mapping's free half)
        kv = state.kv
        MB = kv.tbl.shape[1]
        Wc = min(sh_bids.shape[1], MB)
        bids_r = sh_bids[rows][:, :Wc]             # (S, Wc) — -1 beyond c
        tail_r = sh_tail[rows]                     # (S,)
        c_r = sh_c[rows]
        jW = jnp.arange(Wc, dtype=jnp.int32)
        col_ok = assign[:, None] & (bids_r >= 0)
        tgt_rows = jnp.where(assign, tgt, S)
        tbl = kv.tbl.at[jnp.where(col_ok, tgt_rows[:, None], S),
                        jW[None, :]].set(bids_r, mode="drop")
        tail_ok = assign & (tail_r >= 0)
        tbl = tbl.at[jnp.where(tail_ok, tgt, S),
                     jnp.clip(c_r, 0, MB - 1)].set(tail_r, mode="drop")
        pool = pool_incref(
            kv.pool,
            jnp.concatenate([bids_r, tail_r[:, None]], axis=1),
            jnp.concatenate([col_ok, tail_ok[:, None]], axis=1))
        state = state._replace(kv=KVPool(pool=pool, tbl=tbl,
                                         cache=kv.cache))
        # a fully-covered admit starts decode-ready: zero prefill flops
        n_hits = jnp.sum((assign & (sh_cov[rows] >= bl.prompt_len[rows])
                          & (bl.prompt_len[rows] > 0)).astype(jnp.int32))
    if paged and not chunked:
        # wrap-safe semaphore take of each granted slot's demand: ids pop
        # off the circular free queue at the ticket cursor in slot order
        # (cond-skipped when nothing was assigned — alloc of 0 is identity)
        def _alloc(kv):
            counts = jnp.zeros((S,), jnp.int32).at[tgt].set(
                jnp.where(assign, demand[rows], 0), mode="drop")
            pool, ids = pool_alloc(kv.pool, counts, kv.tbl.shape[1])
            return KVPool(pool=pool,
                          tbl=jnp.where(counts[:, None] > 0, ids, kv.tbl),
                          cache=kv.cache)

        state = state._replace(kv=jax.lax.cond(
            jnp.any(assign), _alloc, lambda kv: kv, state.kv))

    # (3b) chunked prefill: plan chunks/budget, take blocks incrementally
    # (newly admitted slots request their FIRST chunk right here — the
    # blocks the gate's headroom check just promised), park the stalled.
    n_cow = jnp.int32(0)
    chunk_ev = None
    if chunked:
        state, emit, n_cow, chunk_ev = _chunk_phase(state, chunk, budget,
                                                    block_size)
    if admit_fn is not None:  # in-graph prefill for newly admitted slots
        model = admit_fn(model, state, rows, assign, tgt)

    # (4) decode + sample every decode-ready slot (including this round's
    # admits in up-front mode — the host engine prefills then decodes
    # admitted rows the same step; in chunked mode a slot decodes from the
    # round AFTER its prefill completes, and parked slots skip the round)
    sl = state.slots
    if not chunked:
        emit = sl.busy
    toks, model = token_fn(model, state)
    toks = jnp.where(emit, jnp.asarray(toks, jnp.int32), sl.token)
    adv = emit.astype(jnp.int32) + (sl.chunk if chunked else 0)
    pos_old = sl.pos
    sl = sl._replace(token=toks,
                     emitted=sl.emitted + emit.astype(jnp.int32),
                     pos=sl.pos + adv,
                     # watchdog clock: any forward motion (token emitted
                     # or prefill chunk landed) re-arms the slot
                     last_adv=jnp.where(adv > 0, rno, sl.last_adv))
    if sharing:
        # (4b) publish prefixes at prefill COMPLETION: a slot whose cursor
        # crossed plen this round registers one weak entry per full block
        # boundary plus its partial tail (serving.prefix.cache_register —
        # identity on an empty mask, cond-skipped at runtime; the host
        # mirrors the same jitted call on its replica)
        completed = sl.busy & (sl.pos >= sl.plen) & (pos_old < sl.plen)
        kvr = state.kv
        cache = jax.lax.cond(
            jnp.any(completed),
            lambda c: cache_register(c, kvr.pool, sl.ph, sl.plen, kvr.tbl,
                                     completed, block_size),
            lambda c: c, kvr.cache)
        state = state._replace(kv=KVPool(pool=kvr.pool, tbl=kvr.tbl,
                                         cache=cache))

    # (5) completion: done slots post back; their units bank for the NEXT
    # round (the host engine's `_qos_free` in kernel mode)
    n_busy = jnp.sum(sl.busy.astype(jnp.int32))
    fin = sl.busy & (sl.emitted >= sl.max_new)
    n_fin = jnp.sum(fin.astype(jnp.int32))
    finrow = sl.row
    sl = sl._replace(busy=sl.busy & ~fin, row=jnp.where(fin, -1, sl.row))
    state = state._replace(
        slots=sl, slot_sema=post_batch(state.slot_sema, n_fin),
        free=leftover + n_fin, round_no=rno + 1)
    if paged:
        # completed slots post their blocks back AFTER decode — banked for
        # the NEXT round's gate, exactly the slot-unit completion timing
        state = state._replace(kv=jax.lax.cond(
            jnp.any(fin), lambda kv: KVPool(
                pool=pool_release(kv.pool, kv.tbl, fin),
                tbl=jnp.where(fin[:, None], -1, kv.tbl),
                cache=kv.cache),
            lambda kv: kv, state.kv))
    # (6) telemetry: append this round's end-of-round probe set to the
    # in-scan ring — same donated carry, zero extra host syncs.  Every
    # field must stay mirrorable from the host `step()` bookkeeping (the
    # bit-identity property of tests/test_obs.py) — extend both or
    # neither.
    if state.ring is not None:
        from .events import (EV_ADMIT, EV_COW, EV_FINISH, EV_PARK,
                             EV_PREEMPT, EV_PREFILL_CHUNK,
                             EV_PREFIX_ATTACH, EV_RESUME)
        from .sentinels import round_health

        parked_mask = sl.busy & sl.parked
        E = state.ring.buf.ev_kind.shape[1]
        if E:
            assert E == 8 * S, "event table must be 8 segments of S lanes"
            # the fixed per-round event table: 8 phase-major segments of S
            # lane-ascending entries (serving.events.SCAN_SEGMENTS), then
            # ONE stable compaction (valid entries first, order kept) so
            # the drained list equals the host step()'s per-kind appends
            lane = jnp.arange(S, dtype=jnp.int32)
            zb, zi = jnp.zeros((S,), bool), jnp.zeros((S,), jnp.int32)
            admit_uid, admit_arg = bl.rid[rows], bl.prompt_len[rows]
            if sharing:
                att_mask = assign & (sh_cov[rows] > 0)
                att_arg = sh_cov[rows]
            else:
                att_mask, att_arg = zb, zi
            ck = chunk_ev if chunk_ev is not None else {
                "park": zb, "park_arg": zi, "resume": zb,
                "chunk_tok": zi, "cow": zb, "cow_old": zi}
            segs = (
                (EV_PREEMPT, pre, pre_uid, lane, pre_arg),
                (EV_ADMIT, assign, admit_uid, tgt, admit_arg),
                (EV_PREFIX_ATTACH, att_mask, admit_uid, tgt, att_arg),
                (EV_PARK, ck["park"], sl.rid, lane, ck["park_arg"]),
                (EV_RESUME, ck["resume"], sl.rid, lane, zi),
                (EV_PREFILL_CHUNK, ck["chunk_tok"] > 0, sl.rid, lane,
                 ck["chunk_tok"]),
                (EV_COW, ck["cow"], sl.rid, lane, ck["cow_old"]),
                (EV_FINISH, fin, sl.rid, lane, sl.emitted),
            )
            evm = jnp.concatenate([m for _, m, _, _, _ in segs])
            kinds = jnp.concatenate(
                [jnp.full((S,), k, jnp.int32) for k, _, _, _, _ in segs])
            uids = jnp.concatenate(
                [u.astype(jnp.int32) for _, _, u, _, _ in segs])
            eslots = jnp.concatenate(
                [t.astype(jnp.int32) for _, _, _, t, _ in segs])
            eargs = jnp.concatenate(
                [a.astype(jnp.int32) for _, _, _, _, a in segs])
            order = jnp.argsort(~evm, stable=True)
            ev_n = jnp.sum(evm.astype(jnp.int32))
            keep = jnp.arange(E, dtype=jnp.int32) < ev_n
            ev_kind = jnp.where(keep, kinds[order], 0)
            ev_uid = jnp.where(keep, uids[order], -1)
            ev_slot = jnp.where(keep, eslots[order], -1)
            ev_arg = jnp.where(keep, eargs[order], 0)
        else:  # ring built without an event table: empty columns
            ze = jnp.zeros((0,), jnp.int32)
            ev_n, ev_kind, ev_uid, ev_slot, ev_arg = (jnp.int32(0), ze,
                                                      ze, ze, ze)
        sample = TelemetrySample(
            round_no=rno,
            now=now,
            admits=jnp.sum(admitted.astype(jnp.int32)),
            expires=jnp.sum(expired.astype(jnp.int32)),
            preempts=n_pre,
            tokens=jnp.sum(emit.astype(jnp.int32)),
            prefill_tokens=jnp.sum(sl.chunk),
            prefill_chunks=jnp.sum((sl.chunk > 0).astype(jnp.int32)),
            prefill_pending=pending_prompt_tokens(sl.pos, sl.plen, sl.busy),
            gate_stalls=n_stall,
            parked=jnp.sum(parked_mask.astype(jnp.int32)),
            backlog=jnp.sum(state.backlog.valid.astype(jnp.int32)),
            active=jnp.sum(sl.busy.astype(jnp.int32)),
            slot_free=_sdist(state.slot_sema.grant, state.slot_sema.ticket),
            kv_free=(pool_free_count(state.kv.pool) if paged
                     else jnp.int32(0)),
            kv_pokes=(jnp.sum(state.kv.pool.sema.bucket_seq,
                              dtype=jnp.uint32) if paged
                      else jnp.uint32(0)),
            prefix_hits=n_hits if sharing else jnp.int32(0),
            blocks_shared=(jnp.sum((state.kv.pool.refcnt >= 2)
                                   .astype(jnp.int32)) if sharing
                           else jnp.int32(0)),
            cow_copies=n_cow if sharing else jnp.int32(0),
            health=round_health(state, model, rno, block_size=block_size,
                                chunked=chunked, watchdog=watchdog),
            credit=_sdist(state.qos.grant, state.qos.consumed),
            poke_dead=state.qos.dead,
            kv_wait_hist=bucket_histogram(
                sl.park_bucket, parked_mask,
                state.ring.buf.kv_wait_hist.shape[1]),
            ev_n=ev_n, ev_kind=ev_kind, ev_uid=ev_uid, ev_slot=ev_slot,
            ev_arg=ev_arg)
        state = state._replace(ring=ring_append(state.ring, sample))
    ys = RoundOut(tokens=toks, emit=emit, fin=fin, pre=pre, row=finrow,
                  prerow=prerow,
                  n_live=jnp.sum(alive.astype(jnp.int32)),
                  # busy (not emit): chunked rounds that only prefill or
                  # park still count as engine activity, mirroring the
                  # host loop's "active dict non-empty" accounting (in the
                  # up-front modes emit == busy, so nothing changes)
                  n_active=n_busy)
    return state, model, ys


def megastep_scan(state: EngineState, model, nows, *, token_fn: TokenFn,
                  admit_fn: AdmitFn = None, admit_impl=None,
                  block_size: int = 0, chunk: int = 0, budget: int = 0,
                  commit: int = 0, watchdog: int = 0):
    """K fused engine rounds as one `lax.scan` — K host round-trips become
    one launch + one drain.  ``nows``: (K,) f32 epoch-relative timestamps
    (the host projects them at launch; in-graph time never advances on its
    own).  With ``chunk > 0`` every scanned round co-schedules chunked
    prefill with decode (zero extra host syncs for long prompts).
    Returns ``(state', model', RoundOut-of-(K, S) arrays)``."""

    def body(carry, now):
        st, m = carry
        st, m, ys = engine_round(st, m, now, token_fn=token_fn,
                                 admit_fn=admit_fn, admit_impl=admit_impl,
                                 block_size=block_size, chunk=chunk,
                                 budget=budget, commit=commit,
                                 watchdog=watchdog)
        return (st, m), ys

    (state, model), ys = jax.lax.scan(body, (state, model), nows)
    return state, model, ys


@functools.partial(jax.jit, static_argnames=("token_fn", "admit_fn",
                                             "admit_impl", "block_size",
                                             "chunk", "budget", "commit",
                                             "watchdog"),
                   donate_argnums=(0, 1))
def megastep_jit(state: EngineState, model, nows, *, token_fn: TokenFn,
                 admit_fn: AdmitFn = None, admit_impl=None,
                 block_size: int = 0, chunk: int = 0, budget: int = 0,
                 commit: int = 0, watchdog: int = 0):
    """Donated-jit entry: the EngineState and model pytrees are donated, so
    steady-state serving re-uses their device buffers across megasteps
    instead of reallocating per launch."""
    return megastep_scan(state, model, nows, token_fn=token_fn,
                         admit_fn=admit_fn, admit_impl=admit_impl,
                         block_size=block_size, chunk=chunk, budget=budget,
                         commit=commit, watchdog=watchdog)


def fused_round_impl(state, tenant_ids, tickets, alive, deadlines, now,
                     free_units, max_units):
    """Admission-round impl routing through the fused Pallas pass
    (`kernels.qos_admission.qos_round_fused`) — bit-identical to the
    functional default; the scheduler selects it on TPU backends where
    the kernel compiles natively inside the scan."""
    from ..kernels.qos_admission import qos_round_fused

    return qos_round_fused(state, tenant_ids, tickets, alive, deadlines,
                           now, free_units, max_units=max_units,
                           interpret=jax.default_backend() != "tpu")


# --------------------------------------------------------------- models ----


def rid_token_fn(model, state: EngineState):
    """Deterministic request-identity token stream (oracle/testing): token
    = rid·1000 + #already-emitted — slot-assignment invariant, so the host
    loop and the megastep must produce byte-equal streams."""
    return state.slots.rid * 1000 + state.slots.emitted, model


def zero_token_fn(model, state: EngineState):
    """The serving-bench toy model (host path: zero logits, zero sample)."""
    return jnp.zeros_like(state.slots.token), model


def make_paged_attn_model(key, vocab: int, d: int, n_slots: int,
                          capacity: int):
    """Single-layer attention LM over a per-slot ring KV cache — the
    demonstration that real paged decode attention + sampling runs inside
    the scanned round (the `kernels/decode_attention` access pattern;
    ref-path attention keeps the scan CPU-lowerable)."""
    k1, k2 = jax.random.split(key)
    return {
        "emb": jax.random.normal(k1, (vocab, d), jnp.float32) * 0.05,
        "wo": jax.random.normal(k2, (d, d), jnp.float32) * 0.05,
        "k": jnp.zeros((n_slots, capacity, 1, d), jnp.float32),
        "v": jnp.zeros((n_slots, capacity, 1, d), jnp.float32),
        "pos": jnp.full((n_slots, capacity), -1, jnp.int32),
    }


def paged_attn_admit_fn(model, state: EngineState, rows, mask, slots):
    """In-graph prefill: write the admitted rows' prompt embeddings into
    their slots' KV rows (bulk masked write — one scatter per round for
    ALL admitted slots, the batched counterpart of the host engine's
    per-request `prefill_fn`)."""
    bl = state.backlog
    C = model["pos"].shape[1]
    P = bl.prompt.shape[1]
    S = slots.shape[0]
    ptoks = bl.prompt[rows]                       # (S, P)
    plens = bl.prompt_len[rows]                   # (S,)
    pe = model["emb"][ptoks][:, :, None, :]       # (S, P, 1, d)
    pad = ((0, 0), (0, C - P), (0, 0), (0, 0))
    kc = jnp.pad(pe, pad)
    vc = jnp.pad(pe, pad)                         # tied K/V embeddings
    posc = jnp.where(jnp.arange(C)[None, :] < plens[:, None],
                     jnp.arange(C, dtype=jnp.int32)[None, :], -1)
    tgt = jnp.where(mask, slots, S)               # out-of-range → dropped
    return {
        **model,
        "k": model["k"].at[tgt].set(kc, mode="drop"),
        "v": model["v"].at[tgt].set(vc, mode="drop"),
        "pos": model["pos"].at[tgt].set(posc, mode="drop"),
    }


def make_paged_pool_model(key, vocab: int, d: int, num_blocks: int,
                          block_size: int):
    """Single-layer attention LM over the SHARED block-paged KV pool — the
    successor of :func:`make_paged_attn_model`'s per-slot rings (kept as
    the dense baseline): KV lives in (NB, BS) pool blocks owned by the TWA
    block semaphore; which slot reads/writes which block is entirely the
    engine's block tables (`EngineState.kv.tbl`)."""
    k1, k2 = jax.random.split(key)
    return {
        "emb": jax.random.normal(k1, (vocab, d), jnp.float32) * 0.05,
        "wo": jax.random.normal(k2, (d, d), jnp.float32) * 0.05,
        "kp": jnp.zeros((num_blocks, block_size, 1, d), jnp.float32),
        "vp": jnp.zeros((num_blocks, block_size, 1, d), jnp.float32),
    }


def paged_pool_admit_fn(model, state: EngineState, rows, mask, slots):
    """In-graph prefill into the pool: the admitted rows' prompt embeddings
    scatter into the blocks their slots were just granted (token j of a
    slot lands in block ``tbl[slot, j // BS]`` offset ``j % BS``) — one
    bulk masked scatter per round for ALL admitted slots."""
    bl = state.backlog
    tbl = state.kv.tbl
    NB, BS = model["kp"].shape[:2]
    P = bl.prompt.shape[1]
    S = slots.shape[0]
    ptoks = bl.prompt[rows]                        # (S, P)
    plens = bl.prompt_len[rows]                    # (S,)
    pe = model["emb"][ptoks]                       # (S, P, d)
    j = jnp.arange(P, dtype=jnp.int32)
    stbl = tbl[jnp.where(mask, slots, 0)]          # (S, MB)
    bid = jnp.take_along_axis(
        stbl, jnp.broadcast_to((j // BS)[None, :], (S, P)), axis=1)
    valid = mask[:, None] & (j[None, :] < plens[:, None]) & (bid >= 0)
    bsel = jnp.where(valid, bid, NB)               # out-of-range → dropped
    off = jnp.broadcast_to((j % BS)[None, :], (S, P))
    return {
        **model,
        "kp": model["kp"].at[bsel, off, 0].set(pe, mode="drop"),
        "vp": model["vp"].at[bsel, off, 0].set(pe, mode="drop"),
    }


def paged_pool_token_fn(model, state: EngineState):
    """Pool-paged single-token decode: write the current token's KV into
    the slot's cursor block, attend over the slot's table-gathered blocks,
    and greedy-sample.  The in-graph attention is the VECTORIZED dense
    view of the table (`kernels.ref.paged_gather_kv` — the gathered width
    is the per-slot table, ∝ the slot's worst-case demand, never the pool
    or a global ring); the Pallas kernel `kernels/paged_decode` is the
    TPU path that additionally skips unwritten tail blocks in HBM (its
    sequential-row oracle `ref.paged_decode_ref` exists for bit-exactness,
    not for in-scan throughput)."""
    from ..kernels.ref import decode_attention_ref, paged_gather_kv

    sl = state.slots
    kv = state.kv
    NB, BS = model["kp"].shape[:2]
    S, MB = kv.tbl.shape
    cur = model["emb"][sl.token]                   # (S, d)
    rows_i = jnp.arange(S, dtype=jnp.int32)
    col = jnp.clip(sl.pos // BS, 0, MB - 1)
    bid = kv.tbl[rows_i, col]                      # current write block
    wr = sl.busy & (bid >= 0)
    bsel = jnp.where(wr, bid, NB)
    off = sl.pos % BS
    kp = model["kp"].at[bsel, off, 0].set(cur, mode="drop")
    vp = model["vp"].at[bsel, off, 0].set(cur, mode="drop")
    lens = jnp.where(sl.busy, sl.pos + 1, 0)       # attend incl. current
    kd, kpos = paged_gather_kv(kp, kv.tbl, lens)
    vd, _ = paged_gather_kv(vp, kv.tbl, lens)
    o = decode_attention_ref(cur[:, None, :], kd, vd, kpos,
                             jnp.maximum(lens - 1, 0))  # (S, 1, d)
    logits = (o[:, 0] @ model["wo"]) @ model["emb"].T
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return toks, {**model, "kp": kp, "vp": vp}


def make_chunked_prefill_token_fn(chunk: int):
    """Factory: a `chunked_prefill_token_fn` whose chunk-scatter window is
    the STATIC chunk size instead of the whole prompt width — at most
    ``chunk`` prompt tokens can move per round, so the masked gather/
    scatter shrinks from (S, P) to (S, chunk) (~P/chunk× less per-round
    prefill-write work for long prompts).  ``chunk`` must be ≥ the
    engine's configured chunk size (a narrower window would silently
    drop the tail of every scheduled chunk) — the scheduler validates
    this via the ``_chunk_window`` attribute stamped here.  Create ONCE
    per engine and reuse — the returned closure's identity keys the
    megastep jit cache."""
    def token_fn(model, state):
        return _chunked_prefill_step(model, state, chunk)
    token_fn._chunk_window = chunk
    return token_fn


def chunked_prefill_token_fn(model, state: EngineState):
    """Continuous chunked prefill over the SHARED block pool — the in-scan
    path that lets ``megastep(K)`` serve prompts far longer than the
    one-shot prefill table with ZERO extra host syncs: each scanned round
    writes this round's prompt chunks (``slots.chunk`` tokens starting at
    the slot's KV cursor, planned by `serving.prefill.chunk_plan` into the
    blocks the round just took) and decodes every decode-ready slot —
    prefill and decode co-scheduled in ONE model call per round.

    Uses `make_paged_pool_model` state.  The chunk scatter is masked over
    the slot prompt width (shape-stable for any chunk size; use
    :func:`make_chunked_prefill_token_fn` to shrink the window to the
    engine's static chunk); the Pallas path for real models — blockwise
    flash-prefill with causal chunk attention and in-pass KV writeback —
    is `kernels/paged_prefill` (oracle-bit-exact standalone; see
    tests/test_paged_prefill.py).  Decode math is identical to
    `paged_pool_token_fn`, so token streams are bit-identical to one-shot
    prefill for ANY chunk size (property-tested in
    tests/test_chunked_prefill.py)."""
    return _chunked_prefill_step(model, state, state.slots.prompt.shape[1])


def _chunked_prefill_step(model, state: EngineState, window: int):
    from ..kernels.ref import decode_attention_ref, paged_gather_kv

    sl = state.slots
    kv = state.kv
    NB, BS = model["kp"].shape[:2]
    S, MB = kv.tbl.shape
    P = sl.prompt.shape[1]
    W = min(window, P)
    # ---- prefill: scatter this round's chunk embeddings into the pool
    j = sl.pos[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]   # (S, W)
    valid = jnp.arange(W, dtype=jnp.int32)[None, :] < sl.chunk[:, None]
    ptok = jnp.take_along_axis(sl.prompt, jnp.clip(j, 0, P - 1), axis=1)
    pe = model["emb"][ptok]                                         # (S, W, d)
    bid = jnp.take_along_axis(kv.tbl, jnp.clip(j // BS, 0, MB - 1), axis=1)
    ok = valid & (bid >= 0)
    bsel = jnp.where(ok, bid, NB)                 # out-of-range → dropped
    kp = model["kp"].at[bsel, j % BS, 0].set(pe, mode="drop")
    vp = model["vp"].at[bsel, j % BS, 0].set(pe, mode="drop")
    # ---- decode: `paged_pool_token_fn` math, decode-ready slots only
    # (prefilling and block-parked slots are masked; the engine's emit
    # mask drops their garbage samples the same way)
    ready = sl.busy & (sl.pos >= sl.plen)
    cur = model["emb"][sl.token]                                    # (S, d)
    rows_i = jnp.arange(S, dtype=jnp.int32)
    dbid = kv.tbl[rows_i, jnp.clip(sl.pos // BS, 0, MB - 1)]
    wr = ready & (dbid >= 0)
    if sl.cow_src is not None:
        # sharing: NEVER write a block another slot can read — a slot
        # whose copy-on-write take was denied this round still points at
        # the shared tail (the engine's emit mask already drops its
        # sample; this drops its KV write too)
        wr = wr & (kv.pool.refcnt[jnp.clip(dbid, 0, NB - 1)] <= 1)
    dbsel = jnp.where(wr, dbid, NB)
    if sl.cow_src is not None:
        # copy-on-write: a slot granted a private replacement for its
        # shared tail this round copies the whole shared block into it
        # BEFORE its decode write lands (the source stays intact this
        # round even if its refcount just hit zero — freed ids cannot be
        # re-granted before the NEXT round's alloc).  dbid IS the fresh
        # private block: the write cursor sits inside the replaced column.
        do_cow = wr & (sl.cow_src >= 0)
        csel = jnp.where(do_cow, dbid, NB)
        src = jnp.clip(sl.cow_src, 0, NB - 1)
        kp = kp.at[csel].set(kp[src], mode="drop")
        vp = vp.at[csel].set(vp[src], mode="drop")
    kp = kp.at[dbsel, sl.pos % BS, 0].set(cur, mode="drop")
    vp = vp.at[dbsel, sl.pos % BS, 0].set(cur, mode="drop")
    lens = jnp.where(wr, sl.pos + 1, 0)
    kd, kpos = paged_gather_kv(kp, kv.tbl, lens)
    vd, _ = paged_gather_kv(vp, kv.tbl, lens)
    o = decode_attention_ref(cur[:, None, :], kd, vd, kpos,
                             jnp.maximum(lens - 1, 0))              # (S, 1, d)
    logits = (o[:, 0] @ model["wo"]) @ model["emb"].T
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return toks, {**model, "kp": kp, "vp": vp}


def paged_attn_token_fn(model, state: EngineState):
    """Paged single-token decode: write the current token's KV at the ring
    cursor, attend over the slot's cache (ref-path decode attention), and
    greedy-sample the next token."""
    from ..kernels.ref import decode_attention_ref

    sl = state.slots
    S, C = model["pos"].shape
    cur = model["emb"][sl.token]                  # (S, d)
    ring = sl.pos % C                             # per-slot write cursor
    rows_i = jnp.arange(S, dtype=jnp.int32)
    wr = sl.busy
    k = model["k"].at[rows_i, ring, 0].set(
        jnp.where(wr[:, None], cur, model["k"][rows_i, ring, 0]))
    v = model["v"].at[rows_i, ring, 0].set(
        jnp.where(wr[:, None], cur, model["v"][rows_i, ring, 0]))
    pos = model["pos"].at[rows_i, ring].set(
        jnp.where(wr, sl.pos, model["pos"][rows_i, ring]))
    o = decode_attention_ref(cur[:, None, :], k, v, pos, sl.pos)  # (S,1,d)
    logits = (o[:, 0] @ model["wo"]) @ model["emb"].T
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return toks, {**model, "k": k, "v": v, "pos": pos}
