"""FCFS continuous-batching serving scheduler — the paper's semaphore as the
admission-control core of an inference engine.

Resource model: the engine owns S decode slots (rows of the batched KV
cache).  Admission is a ticket semaphore with `grant` preloaded to S:

  * a new request `take`s → its ticket IS its global admission number; the
    FCFS guarantee of the paper becomes the engine's fairness guarantee
    (no request starves behind later arrivals — the pthread-baseline
    equivalent would let short prompts barge past long-queued ones);
  * when a sequence finishes, its slot frees → `post` advances grant, which
    enables exactly the next ticket(s) in line;
  * the TWA waiting array is what makes the *scheduler loop* scale: pending
    requests are dispersed over hashed buckets; each loop iteration
    re-examines ONLY requests whose bucket was poked by a post
    (`woken_mask`), instead of rescanning the whole backlog — the
    global-spinning analogue the paper eliminates.  With a 10k-deep backlog
    and 8 slots freed, the loop touches ~8 requests, not 10k.
  * host-side waiting uses the L1 TWA futex semaphore so request threads
    block politely (client-facing synchronous API), while the batched
    in-graph admission uses core.functional / kernels.sema_batch.

Multi-tenant QoS mode (``tenants={tenant_id: weight}``): admission routes
through `admission.functional_qos` — per-tenant functional TWA semaphores
replenished from the global slot pool by stride scheduling, one shared
bucket array gating which tenant queues the loop re-examines, and
deadline-expired backlog entries tombstoned so they never block later
live tickets (the skip-aware grant of the tombstone protocol).  FCFS holds
within a tenant; across tenants admission shares converge to the weights
under saturation.  With ``use_kernel=True`` the whole tenant round
(expire → replenish → admit → reclaim) runs as the fused Pallas pass
(`kernels.qos_admission`, interpret-mode off-TPU) instead of the host
queue walk — same admission semantics, one vectorized in-graph sweep.

Device-resident megastep (``megastep(K)``): the whole engine loop — deadline
preemption, the QoS admission round, TWA slot assignment, decode+sample,
completion — runs as ONE jitted `lax.scan` over a donated on-device
`serving.engine_state.EngineState` pytree, draining K decoded tokens per
host sync instead of one.  Round-for-round identical to K `step()` calls
(tests/test_megastep.py); `benchmarks/serving_bench.py` measures the
speedup vs K.

The engine below is deliberately model-agnostic: `step_fn` is any callable
(tokens, positions, caches) → (logits, caches); tests drive it with a tiny
transformer, examples/serve_continuous_batching.py with a reduced config.
"""

from __future__ import annotations

import heapq
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..admission.functional_qos import (
    make_qos,
    qos_reclaim,
    qos_replenish,
    qos_take,
)
from ..core.functional import (
    SemaState,
    make_block_pool,
    make_sema,
    next_pow2 as _next_pow2,
    pool_free_count,
    pool_incref,
    pool_release,
    pool_try_alloc,
    post_batch,
    take_batch,
    woken_mask,
)
from ..core.twa_semaphore import TWASemaphore
from .events import (
    EV_ADMIT,
    EV_COW,
    EV_EXPIRE,
    EV_FINISH,
    EV_PARK,
    EV_PREEMPT,
    EV_PREFILL_CHUNK,
    EV_PREFIX_ATTACH,
    EV_QUARANTINE,
    EV_RESUME,
    EV_SUBMIT,
)
from .prefix import (
    cache_clear,
    cache_lookup,
    cache_register,
    make_prefix_cache,
    prompt_hashes,
)


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    tenant_id: str = "default"
    deadline: Optional[float] = None  # absolute time.monotonic admission deadline
    ticket: Optional[int] = None
    bucket: Optional[int] = None
    observed_seq: Optional[int] = None
    fast: bool = False  # admitted at take time (paper's fast-path return)
    slot: Optional[int] = None
    expired: bool = False  # deadline passed before admission (tombstoned)
    preempted: bool = False  # deadline passed mid-decode (slot reclaimed)
    out_tokens: list[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    enqueue_t: float = 0.0
    admit_t: float = 0.0
    finish_t: float = 0.0
    # --- virtual-clock lifecycle stamps (repro.obs SLO tracking) ---
    # Keyed on the injectable ``clock=`` (NOT wall time), so TTFT/TPOT are
    # reproducible under virtual time and bit-identical between the host
    # loop and megastep drains (which stamp t0 + nows[k] per round).
    submit_clock: Optional[float] = None
    first_tok_clock: Optional[float] = None
    last_tok_clock: Optional[float] = None
    finish_clock: Optional[float] = None
    admit_round: int = -1  # global engine round of admission
    expire_round: int = -1  # global engine round of expiry/preemption
    # --- continuous chunked prefill (kv_pool + chunked_prefill engines) ---
    prefill_pos: int = 0  # prompt tokens prefilled so far
    kv_blocks: int = 0  # pool blocks currently held (incremental takes)
    prio_key: int = 0  # packed FCFS admission key (Banker order, secondary)
    parked: bool = False  # block-stalled on the block semaphore's waiting array
    park_bucket: int = 0  # observed TWAHash bucket (core.functional.park_state)
    park_seq: int = 0  # bucket sequence at park time
    # --- resilience (serving.sentinels / repro.resilience) ---
    last_adv_round: int = -1  # last engine round with forward progress
    #                           (host mirror of Slots.last_adv — the
    #                           stuck-slot watchdog's clock)
    retries: int = 0  # quarantine-requeue attempts consumed (recovery ladder)
    # --- prefix sharing (serving.prefix, prefix_cache= engines) ---
    ph: Optional[np.ndarray] = None  # (2, W+1) u32 prompt-hash table,
    #                                  computed ONCE at submit (the only
    #                                  place tokens are hashed — device
    #                                  and host both consume it as data)
    share: Optional[tuple] = None  # gate-time cache hit staged for the
    #                                attach: (c, bids, tail_bid, cov)


@dataclass
class EngineStats:
    admitted: int = 0
    finished: int = 0
    expired: int = 0  # deadline-missed (tombstoned tickets + preemptions)
    preempted: int = 0  # deadline-missed mid-decode (slot reclaimed)
    steps: int = 0
    backlog_scans: int = 0  # requests re-examined by the scheduler loop
    backlog_skipped: int = 0  # requests NOT re-examined thanks to TWA buckets
    wakeups: int = 0
    host_syncs: int = 0  # host↔device round-trips (1/step; 1/megastep)
    kv_block_stalls: int = 0  # cumulative parked slot-rounds (block waits)
    prefill_chunks: int = 0  # prompt chunks written (chunked prefill)
    prefix_hits: int = 0  # admissions whose whole prompt was cache-covered
    #                       (zero prefill flops — prefix_cache= engines)
    cow_copies: int = 0  # copy-on-write block copies (diverging sharers)
    # --- recovery ladder (repro.resilience.recovery / serving.sentinels) ---
    quarantined: int = 0  # rung 1: sick slots evicted (blocks released)
    requeued: int = 0  # quarantined requests re-submitted after backoff
    kv_audits: int = 0  # rung 2: free-queue rebuilds from table ground truth
    kernel_fallbacks: int = 0  # rung 3: fused kernel → functional path
    snapshots: int = 0  # rung 4: EngineState checkpoints taken
    restores: int = 0  # rung 4: EngineState checkpoints restored


class ContinuousBatchingEngine:
    """Slot-synchronous decode engine with TWA-semaphore admission."""

    def __init__(
        self,
        step_fn: Callable,
        prefill_fn: Callable,
        n_slots: int,
        *,
        table_size: int = 256,
        use_kernel: bool = False,
        tenants: Optional[dict[str, float]] = None,
        clock: Callable[[], float] = time.monotonic,
        backlog_cap: int = 4096,
        prompt_cap: int = 32,
        kv_pool: Optional[tuple] = None,
        chunked_prefill: Optional[tuple] = None,
        prefix_cache: int = 0,
        obs=None,
        watchdog: int = 0,
    ):
        self.step_fn = step_fn
        self.prefill_fn = prefill_fn
        self.n_slots = n_slots
        self.sema = make_sema(count=n_slots, table_size=table_size)
        self.backlog: list[Request] = []  # pending (ticketed, not admitted)
        self.active: dict[int, Request] = {}  # slot → request
        self.free_slots = list(range(n_slots))
        self.stats = EngineStats()
        self._lock = threading.Lock()
        self._client_sem = TWASemaphore(0, waiting="futex")  # completion wakeups
        self._use_kernel = use_kernel
        # injectable clock: deadlines compare against THIS time source, so
        # tests (megastep ≡ host-loop property) can drive virtual time
        self._clock = clock
        self._round_no = 0  # global engine round counter (step & megastep)
        # --- observability (repro.obs) ---
        # ``obs=`` accepts anything with record_round(sample: dict) /
        # record_request(req) / summary() — normally `repro.obs.EngineObs`.
        # Per-round telemetry samples are produced by BOTH serving paths
        # with identical keys and values: host `step()` mirrors every probe
        # from its own bookkeeping, megastep drains the in-scan
        # TelemetryRing in its ONE host sync (engine_state.py docstring).
        self._obs = obs
        self._last_samples: list[dict] = []  # most recent step/megastep
        # --- request tracing (repro.obs.trace, PR 10) ---
        # Always-on bounded host-side trace buffer.  Engine-phase events
        # (ADMIT/PARK/…/FINISH) arrive via the per-round samples — emitted
        # by the in-scan event table on the megastep path and mirrored
        # bit-exactly by step()'s bookkeeping below; host-only lifecycle
        # events (SUBMIT/EXPIRE/QUARANTINE) are appended directly.  Pure
        # host writes: tracing adds ZERO device syncs.
        from ..obs.trace import TraceBuffer

        self._trace = TraceBuffer()
        # host step()'s per-round trace-event scratch, one list per
        # in-scan kind, appended in the device round's phase order and
        # flattened by `_host_sample` in the canonical segment order
        # (serving.events.SCAN_SEGMENTS)
        self._ev_preempt: list[list[int]] = []
        self._ev_admit: list[list[int]] = []
        self._ev_attach: list[list[int]] = []
        self._ev_park: list[list[int]] = []
        self._ev_resume: list[list[int]] = []
        self._ev_chunk: list[list[int]] = []
        self._ev_cow: list[list[int]] = []
        self._ev_finish: list[list[int]] = []
        # --- invariant sentinels (serving.sentinels) ---
        # ``watchdog=W``: the stuck-slot sentinel trips (H_STUCK in the
        # per-round health bitmask) when any busy slot makes no progress
        # for ≥ W consecutive rounds; 0 disables.  Both serving paths
        # evaluate it from the same clock (Slots.last_adv on device,
        # Request.last_adv_round on host) so the telemetry bit-identity
        # property covers the health field.
        self._watchdog = int(watchdog)
        # host H_NAN input: set when the host decode's logits (already on
        # host — never a hidden device sync) carry a NaN/Inf, or directly
        # by the fault injector (repro.resilience.faults); reset per round
        self._round_nonfinite = False
        # sticky variant: an injected model poison persists until a rung-4
        # restore repairs it (the device model stays NaN'd the same way),
        # so every round re-raises H_NAN until recovery clears this
        self._nonfinite_sticky = False
        self._now_r = 0.0  # clock() at step start (lifecycle stamps)
        # pure-host mirrors of the global slot semaphore's counters, so
        # `telemetry()` never touches device arrays (a hidden host sync
        # that `stats.host_syncs` would miss): takes bump the ticket,
        # finish-posts bump the grant — queue_depth = ticket − grant.
        self._sema_ticket_h = 0
        self._sema_grant_h = n_slots
        # per-round scratch the host sample mirrors from (reset each step)
        self._round_gate_stalls = 0
        self._round_prefill_tokens = 0
        self._round_prefill_chunks = 0
        self._round_prefix_hits = 0
        self._round_cow_copies = 0
        self._backlog_cap = backlog_cap  # megastep device backlog ceiling
        self._prompt_cap = prompt_cap  # megastep padded prompt ceiling
        self.megastep_model = None  # device model pytree (megastep mode)
        # --- block-paged KV pool (core.functional.BlockPool) ---
        # ``kv_pool=(num_blocks, block_size[, max_blocks_per_seq])``:
        # admission gates on BOTH a free slot and the request's worst-case
        # block demand (multi-resource admission); the host keeps only the
        # free-block COUNTER (bit-identical to the device semaphore's
        # grant − ticket by construction) — block identities live in the
        # device pool, so paged engines must decode via megastep.
        self._kv_pool = kv_pool
        # --- continuous chunked prefill (serving.prefill) ---
        # ``chunked_prefill=(chunk_tokens, token_budget)``: admission gates
        # on FIRST-CHUNK demand behind the reserved headroom, prompts
        # prefill up to chunk_tokens per round under the per-round prefill
        # token budget (Sarathi-style co-scheduling with decode), blocks
        # are taken incrementally at block-boundary crossings, and
        # block-stalled slots PARK on the block semaphore's waiting array
        # (resumed FCFS when releases poke their bucket) — see
        # serving/engine_state.py for the stall/park policy and the
        # no-deadlock headroom invariant.
        self._chunk, self._budget, self._kv_commit = 0, 0, 0
        if chunked_prefill is not None:
            if kv_pool is None:
                raise ValueError(
                    "chunked_prefill requires the block-paged pool "
                    "(kv_pool=...): chunks allocate pool blocks "
                    "incrementally")
            ch, bu, *cw = chunked_prefill
            if int(ch) < 1 or int(bu) < 1:
                raise ValueError(
                    f"chunked_prefill needs a positive chunk size and "
                    f"token budget, got {chunked_prefill}")
            self._chunk, self._budget = int(ch), int(bu)
            # optional third element: the commitment watermark in BLOCKS
            # (aggregate outstanding worst-case demand admission may keep
            # in flight).  Default 9/16 of the pool: the measured sweet
            # spot between utilization (higher watermark ⇒ more resident
            # sequences ⇒ more written blocks) and safety-chain slack
            # (lower ⇒ fewer parks serializing the endgame) — see
            # benchmarks/serving_bench.run_longprompt.
            self._kv_commit = int(cw[0]) if cw \
                else max(1, int(kv_pool[0]) * 9 // 16)
        if kv_pool is not None:
            if tenants is None:
                raise ValueError("kv_pool requires QoS mode (tenants=...)")
            nb, bs, *rest = kv_pool
            nb, bs = int(nb), int(bs)
            if nb <= 0 or (nb & (nb - 1)) or bs <= 0:
                raise ValueError(
                    f"kv_pool needs a power-of-two block count and a "
                    f"positive block size, got {kv_pool}")
            self._kv_blocks, self._kv_bs = nb, bs
            self._kv_mb = int(rest[0]) if rest else nb  # table width
            self._kv_free_blocks = nb
            self._kv_state = None  # persisted device KVPool across megasteps
            # host mirror of the device block semaphore (chunked mode):
            # takes advance ticket, releases post+poke, and the park/wake
            # buckets come off THIS state — table_size/salt must match
            # `engine_state.make_engine_state`'s pool (slot_table=64) so
            # host-loop and megastep runs observe identical bucket moves
            self._kv_sema = make_sema(count=nb, table_size=64)
        # --- refcounted prefix sharing (serving.prefix, PR 9) ---
        # ``prefix_cache=E`` (power-of-two entries; requires chunked
        # prefill) attaches the weak prompt-prefix cache: admissions whose
        # prompt prefix is already pool-resident attach the shared blocks
        # by `pool_incref` (zero prefill flops, zero new HBM) and pay
        # admission only for post-divergence demand.  Counters are no
        # longer a sufficient host mirror — block IDENTITIES and refcounts
        # decide sharing — so the host keeps a full replica of the device
        # pool (`_kv_hpool`/`_kv_htbl`/`_kv_cache`) and mutates it through
        # the SAME jitted functions the scanned round uses, in the SAME
        # batched call pattern (one release per preempt/cow/finish phase —
        # sequential per-request releases would reorder the free queue).
        self._kv_share = int(prefix_cache) > 0
        if self._kv_share:
            if not self._chunk:
                raise ValueError(
                    "prefix_cache requires continuous chunked prefill "
                    "(chunked_prefill=...): shared prefixes resume at the "
                    "divergence point mid-prompt")
            e = int(prefix_cache)
            if e & (e - 1):
                raise ValueError(
                    f"prefix_cache needs a power-of-two entry count "
                    f"(direct-mapped homes are key & (E-1)), got {e}")
            self._kv_prefix = e
            self._hash_w = self._prompt_cap // self._kv_bs
            self._kv_hpool = make_block_pool(self._kv_blocks, table_size=64)
            self._kv_cache = make_prefix_cache(e)
            self._kv_htbl = np.full((n_slots, self._kv_mb), -1, np.int32)
            self._kv_sema = self._kv_hpool.sema
            self._kv_refcnt_h = np.zeros(self._kv_blocks, np.int32)
        # --- multi-tenant QoS admission (admission.functional_qos) ---
        self._tenants = tenants
        if tenants is not None:
            # weight 0 is meaningful at the functional layer (at most one
            # unit, then the virtual pass saturates to +inf) but in a
            # serving engine it means silent starvation — reject it here.
            bad = {t: w for t, w in tenants.items() if not w > 0}
            if bad:
                raise ValueError(
                    f"tenant weights must be > 0, got {bad}; zero-weight "
                    "tenants would starve after at most one admission")
            self._tenant_names = list(tenants)
            self._tindex = {t: i for i, t in enumerate(self._tenant_names)}
            self.qos = make_qos([tenants[t] for t in self._tenant_names],
                                table_size=table_size)
            self._qos_free = n_slots  # undistributed global slots
            self._tenant_queues: list[deque[Request]] = [
                deque() for _ in self._tenant_names]
            self._tenant_live = np.zeros(len(self._tenant_names), np.int64)
            self.tenant_admitted = {t: 0 for t in self._tenant_names}
            self.tenant_expired = {t: 0 for t in self._tenant_names}
            self._deadline_heap: list[tuple[float, int, Request]] = []

    # ------------------------------------------------------------ client ----

    def submit(self, req: Request) -> Request:
        """Take a ticket (FCFS position) and enqueue."""
        if self._tenants is not None:
            self._submit_qos([req])
            return req
        req.enqueue_t = time.time()
        with self._lock:
            req.submit_clock = self._clock()
            self._sema_ticket_h += 1
            state, tickets, admitted, buckets = take_batch(
                self.sema, jnp.ones((1,), bool)
            )
            self.sema = state
            req.ticket = int(tickets[0])
            req.bucket = int(buckets[0])
            req.fast = bool(admitted[0])
            req.observed_seq = int(self.sema.bucket_seq[req.bucket])
            self.backlog.append(req)
            self._trace.add(EV_SUBMIT, req.rid, -1, 0, req.submit_clock,
                            self._round_no)
        return req

    def submit_batch(self, reqs: list[Request]) -> None:
        """Vectorized ticket issuance — one fused pass for K arrivals (the
        sema_batch kernel path when enabled)."""
        if self._tenants is not None:
            self._submit_qos(reqs)
            return
        with self._lock:
            n = len(reqs)
            sclk = self._clock()
            self._sema_ticket_h += n
            if self._use_kernel:
                from ..kernels.ops import sema_batch as sema_kernel

                nt, ng, nseq, tk, adm, bkt, wok = sema_kernel(
                    self.sema.ticket, self.sema.grant, self.sema.bucket_seq,
                    jnp.ones((n,), bool), jnp.uint32(0), self.sema.salt,
                )
                self.sema = SemaState(nt, ng, nseq, self.sema.salt)
            else:
                self.sema, tk, adm, bkt = take_batch(self.sema, jnp.ones((n,), bool))
            for r, t, b, a in zip(reqs, np.asarray(tk), np.asarray(bkt), np.asarray(adm)):
                r.enqueue_t = time.time()
                r.submit_clock = sclk
                r.ticket = int(t)
                r.bucket = int(b)
                r.fast = bool(a)
                r.observed_seq = int(self.sema.bucket_seq[r.bucket])
                self.backlog.append(r)
                self._trace.add(EV_SUBMIT, r.rid, -1, 0, sclk,
                                self._round_no)

    # ------------------------------------------------- multi-tenant (QoS) ---

    def _submit_qos(self, reqs: list[Request]) -> None:
        """Batched ticket issuance against the per-tenant QoS semaphores.
        Arrivals whose deadline already passed are dead on arrival."""
        unknown = {r.tenant_id for r in reqs} - self._tindex.keys()
        if unknown:
            raise ValueError(
                f"unregistered tenant(s) {sorted(unknown)}; this engine "
                f"serves tenants {list(self._tenant_names)}")
        if self._kv_pool is not None:
            # submit-time capacity check: a request whose WHOLE-LIFETIME
            # demand exceeds what the pool (or its slot table) can ever
            # hold would stall forever — in chunked mode it would be
            # admitted on its small first chunk and then park with a
            # deficit no amount of releases can cover, so it is rejected
            # here with a clear error instead.  Chunked demand uses the
            # UNTRUNCATED prompt (chunked prompts are never truncated);
            # this also closes the no-deadlock induction for newcomers
            # (engine_state.py: headroom invariant needs demand ≤ pool).
            cap = min(self._kv_mb, self._kv_blocks)
            for r in reqs:
                if self._chunk:
                    plen = len(r.prompt) or 1
                    if plen > self._prompt_cap:
                        raise ValueError(
                            f"request rid={r.rid} prompt ({plen} tokens) "
                            f"exceeds prompt_cap={self._prompt_cap}; "
                            "chunked prefill never truncates prompts — "
                            "raise prompt_cap")
                # the prompt_cap check above makes _kv_demand's truncation
                # a no-op in chunked mode: ONE demand formula everywhere
                # (host gate/headroom/chunk phase and the device paths all
                # reduce to it — the bit-identity mirror depends on that)
                if self._kv_share and r.ph is None:
                    # hash ONCE, here: the (2, W+1) u32 table rides the
                    # request as plain data (device lookups re-use it)
                    r.ph = np.asarray(
                        prompt_hashes(r.prompt or [0], self._kv_bs,
                                      self._hash_w), np.uint32)
                dem = self._kv_demand(r)
                if dem > cap:
                    if self._kv_share and dem <= self._kv_mb:
                        # post-divergence demand: blocks covered by a
                        # cached prefix attach by incref (zero pool
                        # demand) — a request over the raw pool size is
                        # still servable while its prefix stays resident.
                        # Weak entries can die later; the gate then holds
                        # it stalled (doomed) until re-registration
                        # resurrects the coverage.
                        plen = min(len(r.prompt), self._prompt_cap) or 1
                        c, _, _, cv = cache_lookup(
                            self._kv_cache, self._kv_hpool,
                            jnp.asarray(r.ph)[None],
                            jnp.asarray([plen], jnp.int32), self._kv_bs)
                        if dem - int(c[0]) <= self._kv_blocks:
                            continue
                    raise ValueError(
                        f"request rid={r.rid} needs {dem} KV blocks over "
                        f"its lifetime (> {cap} = min(table, pool)): "
                        f"prompt_len + max_new must fit "
                        f"{cap * self._kv_bs} pooled tokens — it could "
                        "never be served and would stall forever")
        with self._lock:
            now = self._clock()
            ids = [self._tindex[r.tenant_id] for r in reqs]
            # Deadlines enter the graph RELATIVE to now: small deltas stay
            # exact in float32, whereas absolute monotonic stamps (~boot
            # seconds) lose sub-second precision after weeks of uptime and
            # would misclassify short-deadline arrivals as dead-on-arrival.
            dls = [np.inf if r.deadline is None else r.deadline - now
                   for r in reqs]
            self.qos, tickets, buckets, expired = qos_take(
                self.qos, jnp.asarray(ids, jnp.int32),
                jnp.ones(len(reqs), bool), jnp.asarray(dls), 0.0)
            seq = np.asarray(self.qos.bucket_seq)
            for r, i, t, b, e in zip(reqs, ids, np.asarray(tickets),
                                     np.asarray(buckets), np.asarray(expired)):
                r.enqueue_t = time.time()
                r.submit_clock = now
                self._trace.add(EV_SUBMIT, r.rid, -1, 0, now,
                                self._round_no)
                if e:
                    self._expire_req(r, i)
                    continue
                r.ticket = int(t)
                r.bucket = int(b)
                r.observed_seq = int(seq[r.bucket])
                r.fast = True  # fresh arrival: examine once on next pass
                self._tenant_queues[i].append(r)
                self._tenant_live[i] += 1
                # the kernel round re-evaluates every deadline in-graph each
                # step — the host expiry heap would only leak entries there
                if r.deadline is not None and not self._use_kernel:
                    heapq.heappush(self._deadline_heap, (r.deadline, r.rid, r))
            # Undistributed slots flow to the new demand immediately (the
            # work-conserving fast path of the hierarchy).
            self._replenish_qos(0)

    def _kv_demand(self, r: Request) -> int:
        """Worst-case block demand — MUST mirror the in-graph
        `engine_state._block_demand`: the device sees the prompt truncated
        to the padded cap, so the host clamps the same way."""
        plen = min(len(r.prompt), self._prompt_cap) or 1
        return max(1, -(-(plen + r.max_new_tokens) // self._kv_bs))

    def _kv_gate(self, cands: list[tuple[Request, int]]):
        """Host mirror of `admission.functional_qos.block_gate` + the
        in-graph `_fcfs_key`: of the QoS-admitted candidates, grant the
        longest FCFS prefix (wrap-safe clamped ticket distance from the
        post-round grant frontier, tenant-index tiebreak — byte-identical
        key arithmetic) whose cumulative block demand fits the free pool;
        strict FCFS, no bypass.  Up-front mode gates on worst-case demand
        and consumes it from the host counter; chunked mode gates on
        FIRST-CHUNK demand behind the reserved headroom
        (`functional_qos.block_headroom` — the no-deadlock invariant) and
        consumes nothing (blocks are taken incrementally by the chunk
        phase).  Returns (granted, stalled) index lists into ``cands``,
        both in gate order; granted requests get their Banker priority
        key stamped."""
        from .engine_state import _D_CLAMP, _T_BITS
        from .prefill import shared_first_chunk_demand

        grants = np.asarray(self.qos.grant)

        def key(i: int) -> int:
            r, tidx = cands[i]
            d = (r.ticket - int(grants[tidx])) & 0xFFFFFFFF
            d = d - (1 << 32) if d >= (1 << 31) else d
            return (max(-_D_CLAMP, min(_D_CLAMP, d)) << _T_BITS) + tidx

        order = sorted(range(len(cands)), key=key)
        free = self._kv_free_blocks
        commit_free = bootstrap = 0
        share = None
        if self._chunk:
            cow = hf = None
            if self._kv_share:
                cow, hf = self._kv_share_state()
                # read-only longest-prefix probe over the candidates (the
                # device gate's `cache_lookup` over the backlog) — demand
                # past the divergence point only
                pln = np.asarray(
                    [min(len(r.prompt), self._prompt_cap) or 1
                     for r, _ in cands], np.int32)
                ph = np.stack([np.asarray(r.ph, np.uint32)
                               for r, _ in cands]) if cands else \
                    np.zeros((0, 2, self._hash_w + 1), np.uint32)
                c_a, bids_a, tail_a, cov_a = cache_lookup(
                    self._kv_cache, self._kv_hpool, jnp.asarray(ph),
                    jnp.asarray(pln), self._kv_bs)
                dem_a = np.asarray(shared_first_chunk_demand(
                    jnp.asarray(pln), cov_a, self._chunk, self._kv_bs))
                c_a, bids_a = np.asarray(c_a), np.asarray(bids_a)
                tail_a, cov_a = np.asarray(tail_a), np.asarray(cov_a)
                commit_a = np.asarray(
                    [self._kv_demand(r) for r, _ in cands],
                    np.int64) - c_a
                # a row whose post-divergence demand exceeds the whole
                # pool can never be granted at current coverage: skip it
                # in the FCFS prefix (it must not dam later rows), keep
                # it stalled — re-registration can resurrect it
                doomed_a = commit_a > self._kv_blocks
                share = (c_a, bids_a, tail_a, cov_a, dem_a, commit_a,
                         doomed_a)
            free -= self._kv_headroom(share=(cow, hf))
            total_rem = sum(
                self._kv_rem(r) + (1 if cow is not None and cow[s] else 0)
                for s, r in self.active.items())
            commit_free = self._kv_commit - total_rem
            bootstrap = total_rem == 0
        granted, stalled = [], []
        dammed = False  # strict FCFS: first ELIGIBLE misfit blocks all
        for i in order:
            r = cands[i][0]
            if self._chunk:
                if share is not None:
                    if share[6][i]:  # doomed: skip, don't dam successors
                        stalled.append(i)
                        continue
                    dem = int(share[4][i])
                    commit = int(share[5][i])
                else:
                    dem = self._kv_first_chunk(r)
                    commit = self._kv_demand(r)
                ok = dem <= free and (commit <= commit_free
                                      or (bootstrap and not granted))
            else:
                dem = self._kv_demand(r)
                commit = 0
                ok = dem <= free
            if not dammed and ok:
                free -= dem
                commit_free -= commit
                r.prio_key = key(i)
                if share is not None:
                    r.share = (int(share[0][i]), share[1][i],
                               int(share[2][i]), int(share[3][i]))
                granted.append(i)
            else:
                dammed = True
                stalled.append(i)
        if not self._chunk:
            # up-front take: the host block-semaphore mirror's ticket
            # advances by the total granted demand — the exact counter move
            # the device `pool_alloc` makes at slot assignment, so
            # `telemetry`'s kv probes (and the megastep bit-identity
            # property) see the same semaphore state on both paths
            taken = self._kv_free_blocks - free
            self._kv_free_blocks = free
            if taken:
                self._kv_sema = self._kv_sema._replace(
                    ticket=self._kv_sema.ticket + jnp.uint32(taken))
        self._round_gate_stalls += len(stalled)
        return granted, stalled

    def _kv_first_chunk(self, r: Request) -> int:
        """First-chunk block demand — what chunked admission gates on
        (mirrors `serving.prefill.first_chunk_demand`)."""
        plen = min(len(r.prompt), self._prompt_cap) or 1
        return max(1, -(-min(self._chunk, plen) // self._kv_bs))

    def _kv_rem(self, r: Request) -> int:
        """Worst-case REMAINING block demand of an active request
        (`_kv_demand` minus the blocks already taken)."""
        return self._kv_demand(r) - r.kv_blocks

    def _kv_share_state(self):
        """Per-slot ``(cow, held_free)`` off the sharing replica — ONE
        call into the canonical `engine_state._share_flags` (host and
        device must never fork the formulas).  ``cow[s]``: the slot's
        next decode write lands in a still-shared tail block (it owes a
        private copy); ``held_free[s]``: blocks the slot alone references
        (the only Banker cover its release can fund)."""
        from .engine_state import _share_flags

        S = self.n_slots
        busy = np.zeros(S, bool)
        pos = np.zeros(S, np.int32)
        plen = np.zeros(S, np.int32)
        held = (self._kv_htbl >= 0).sum(axis=1).astype(np.int32)
        for s, r in self.active.items():
            pl = min(len(r.prompt), self._prompt_cap) or 1
            busy[s] = True
            pos[s] = (r.prefill_pos if r.prefill_pos < pl
                      else pl + len(r.out_tokens))
            plen[s] = pl
        cow, hf = _share_flags(
            jnp.asarray(self._kv_htbl), self._kv_hpool.refcnt,
            jnp.asarray(busy), jnp.asarray(pos), jnp.asarray(plen),
            jnp.asarray(held), self._kv_bs)
        return np.asarray(cow), np.asarray(hf)

    def _hshare_sync(self) -> None:
        """Re-derive every host counter mirror from the sharing replica
        after a pool mutation (the replica is the single source of truth
        in prefix_cache mode — `telemetry()` and `_host_sample` read
        these mirrors, never device arrays)."""
        self._kv_sema = self._kv_hpool.sema
        self._kv_free_blocks = int(pool_free_count(self._kv_hpool))
        self._kv_refcnt_h = np.asarray(self._kv_hpool.refcnt)

    def _hshare_release(self, slots: list[int]) -> None:
        """ONE batched decref of every block the given slots hold —
        mirroring the device round's single `pool_release` per phase
        (preempt / finish / quarantine).  Sequential per-slot releases
        would enqueue freed ids in a different free-queue order and
        diverge from the megastep path."""
        if not slots:
            return
        mask = np.zeros(self.n_slots, bool)
        mask[slots] = True
        self._kv_hpool = pool_release(
            self._kv_hpool, jnp.asarray(self._kv_htbl), jnp.asarray(mask))
        self._kv_htbl[mask] = -1
        self._hshare_sync()

    def _kv_headroom(self, share=None) -> int:
        """Host mirror of `functional_qos.block_headroom` over the
        nearest-completion safety chain (`prefill.banker_order`): the
        smallest free-pool level that keeps every active sequence's
        remaining worst-case demand covered by the pool plus what its
        chain-predecessors will release (see engine_state.py's
        headroom-invariant docs).  With prefix sharing, a pending
        copy-on-write still owes one block (rem + 1) and only
        privately-held blocks fund the cover (``held_free``, not the
        table count) — `serving.sentinels.round_health` applies the same
        generalization in-graph."""
        cow = hf = None
        if share is not None:
            cow, hf = share
        elif self._kv_share:
            cow, hf = self._kv_share_state()

        def rem_of(s: int, r: Request) -> int:
            return self._kv_rem(r) + (1 if cow is not None and cow[s]
                                      else 0)

        acts = sorted(self.active.items(),
                      key=lambda kv: (rem_of(*kv),
                                      kv[1].admit_round, kv[1].prio_key,
                                      kv[0]))
        cum = head = 0
        for s, r in acts:
            head = max(head, rem_of(s, r) - cum)
            cum += int(hf[s]) if hf is not None else r.kv_blocks
        return max(head, 0)

    def _fcfs_sort(self, reqs: list[Request]) -> None:
        """Sort admitted requests into wrap-safe admission order: signed
        ticket distance from the tenant's grant frontier (tickets are u32
        and may cross 2³²; raw comparison would order a post-wrap ticket
        before its predecessor).  Cross-tenant ordering is cosmetic — FCFS
        is a per-tenant invariant.  The grant snapshot is taken ONCE (one
        device→host transfer per round, not per request)."""
        grants = np.asarray(self.qos.grant)

        def key(r: Request):
            d = (r.ticket - int(grants[self._tindex[r.tenant_id]])) & 0xFFFFFFFF
            return (d - (1 << 32) if d >= (1 << 31) else d, r.tenant_id)

        reqs.sort(key=key)

    def _expire_req(self, r: Request, tidx: int) -> None:
        r.expired = True
        pre_rnd = r.expire_round  # megastep drain pre-stamps the in-scan
        r.expire_round = self._round_no  # round; host paths use this one
        self.stats.expired += 1
        self.tenant_expired[self._tenant_names[tidx]] += 1
        r.finish_t = time.time()
        if r.finish_clock is None:  # megastep drains pre-stamp per-round
            r.finish_clock = self._clock()
        # backlog expiry is host-resolved on BOTH serving paths (heap pop
        # order vs row order has no canonical in-scan mirror), so its
        # trace terminal is a host-side event, never an in-scan one
        self._trace.add(EV_EXPIRE, r.rid, -1, 0, r.finish_clock,
                        pre_rnd if pre_rnd >= 0 else r.expire_round)
        self._obs_done(r)
        r.done_event.set()

    def _expire_due_qos(self) -> None:
        """Tombstone backlog entries whose admission deadline passed.  The
        host-side skip: the next live same-tenant waiter is flagged for
        re-examination so the dead ticket never blocks it."""
        now = self._clock()
        dead_bump = np.zeros(len(self._tenant_names), np.uint32)
        while self._deadline_heap and self._deadline_heap[0][0] <= now:
            _, _, r = heapq.heappop(self._deadline_heap)
            if r.expired or r.slot is not None or r.done_event.is_set():
                continue  # admitted or already resolved — deadline is moot
            tidx = self._tindex[r.tenant_id]
            self._expire_req(r, tidx)
            self._tenant_live[tidx] -= 1
            dead_bump[tidx] += 1
            for nxt in self._tenant_queues[tidx]:
                if not nxt.expired:  # successor inherits the wake
                    nxt.fast = True
                    break
        if dead_bump.any():
            self.qos = self.qos._replace(
                dead=self.qos.dead + jnp.asarray(dead_bump))
            # Credit stranded on tombstoned tickets re-enters the pool and
            # is re-granted to live demand (skip-aware replenishment).
            self._replenish_qos(0)

    def _admit_ready_qos_kernel(self) -> list[Request]:
        """Fused in-graph admission round (``use_kernel=True``): expire,
        weighted replenish, tombstone-transparent FCFS admit and reclaim run
        as ONE `kernels.qos_admission` pass over the whole backlog —
        O(N·S/block) vectorized work instead of the host-side queue walk
        (every row is examined, but in-graph; the TWA bucket gating of the
        host path is subsumed by the kernel's blocked live-rank sweep)."""
        from ..kernels.ops import qos_round as qos_round_kernel

        rows = [r for q in self._tenant_queues for r in q if not r.expired]
        if not rows:
            return []
        now = self._clock()
        ids = np.asarray([self._tindex[r.tenant_id] for r in rows], np.int32)
        tks = np.asarray([r.ticket for r in rows], np.uint32)
        # relative deadlines: see _submit_qos on float32 precision
        dls = np.asarray([np.inf if r.deadline is None else r.deadline - now
                          for r in rows], np.float32)
        state, admitted, expired, leftover = qos_round_kernel(
            self.qos, ids, tks, np.ones(len(rows), bool), dls, 0.0,
            self._qos_free, max_units=self.n_slots)
        self.qos = state
        self._qos_free = int(leftover)
        self.stats.backlog_scans += len(rows)
        admitted = np.asarray(admitted).copy()
        expired = np.asarray(expired)
        if self._kv_pool is not None and admitted.any():
            # multi-resource gate: block-stalled rows lose their grant and
            # refund the tenant's slot credit (they stay queued and are
            # re-examined next round — the in-graph round does exactly
            # this via `block_gate` + the consumed refund)
            cidx = np.flatnonzero(admitted)
            _, stalled = self._kv_gate([(rows[i], int(ids[i])) for i in cidx])
            if stalled:
                bump = np.zeros(len(self._tenant_names), np.uint32)
                for i in stalled:
                    admitted[cidx[i]] = False
                    bump[ids[cidx[i]]] += 1
                    rows[cidx[i]].fast = True  # retry while blocks drain
                self.qos = self.qos._replace(
                    consumed=self.qos.consumed - jnp.asarray(bump))
        out: list[Request] = []
        for r, i, a, e in zip(rows, ids, admitted, expired):
            if e:
                self._expire_req(r, int(i))
                self._tenant_live[int(i)] -= 1
            elif a:
                self._tenant_live[int(i)] -= 1
                self.tenant_admitted[r.tenant_id] += 1
                out.append(r)
        if admitted.any() or expired.any():
            gone = {id(r) for r, a, e in zip(rows, admitted, expired) if a or e}
            for tidx, q in enumerate(self._tenant_queues):
                self._tenant_queues[tidx] = deque(
                    r for r in q if id(r) not in gone)
        self._fcfs_sort(out)
        return out

    def _admit_ready_qos(self) -> list[Request]:
        """Weighted-FCFS admission: per-tenant queues are re-examined only
        when their head's bucket was poked by a replenish (or flagged by an
        arrival/expiry) — the TWA gating at tenant granularity."""
        if self._use_kernel:
            return self._admit_ready_qos_kernel()
        self._expire_due_qos()
        # wrap-safe spendable credit: u32 difference reinterpreted signed
        # (mirrors functional_qos.avail's _sdist — a raw widened subtraction
        # would go hugely negative once grant crosses 2³²)
        avail = (np.asarray(self.qos.grant) - np.asarray(self.qos.consumed)
                 ).astype(np.int32).astype(np.int64)
        seq = np.asarray(self.qos.bucket_seq)
        admitted: list[Request] = []
        spent = np.zeros(len(self._tenant_names), np.uint32)
        for tidx, q in enumerate(self._tenant_queues):
            while q and q[0].expired:
                q.popleft()  # lazy removal of tombstoned heads
            if not q:
                continue
            head = q[0]
            if not (head.fast or seq[head.bucket] != head.observed_seq):
                self.stats.backlog_skipped += sum(not r.expired for r in q)
                continue
            head.fast = False
            head.observed_seq = int(seq[head.bucket])
            while q and avail[tidx] - int(spent[tidx]) > 0:
                r = q.popleft()
                if r.expired:
                    continue
                spent[tidx] += 1
                self._tenant_live[tidx] -= 1
                self.tenant_admitted[r.tenant_id] += 1
                admitted.append(r)
            # examined = the head + each admitted row; everything left in
            # the queue was never touched (the TWA skip).
            self.stats.backlog_scans += int(spent[tidx]) + (1 if q and q[0] is head else 0)
            self.stats.backlog_skipped += sum(not r.expired for r in q) \
                - (1 if q and q[0] is head else 0)
        if spent.any():
            self.qos = self.qos._replace(
                consumed=self.qos.consumed + jnp.asarray(spent))
        if self._kv_pool is not None and admitted:
            # multi-resource gate: roll the block-stalled suffix back onto
            # the queue heads (per tenant the stalled candidates are a
            # contiguous FIFO suffix — global FCFS preserves per-tenant
            # ticket order), refund their slot credit, and flag them for
            # re-examination once blocks drain
            cands = [(r, self._tindex[r.tenant_id]) for r in admitted]
            _, stalled = self._kv_gate(cands)
            if stalled:
                unbump = np.zeros(len(self._tenant_names), np.uint32)
                by_tenant: dict[int, list[Request]] = {}
                for i in stalled:
                    r, tidx = cands[i]
                    by_tenant.setdefault(tidx, []).append(r)
                for tidx, rs in by_tenant.items():
                    for r in reversed(rs):  # gate order = ticket order
                        self._tenant_queues[tidx].appendleft(r)
                        self._tenant_live[tidx] += 1
                        self.tenant_admitted[r.tenant_id] -= 1
                        unbump[tidx] += 1
                        r.fast = True
                self.qos = self.qos._replace(
                    consumed=self.qos.consumed - jnp.asarray(unbump))
                stall_ids = {id(cands[i][0]) for i in stalled}
                admitted = [r for r in admitted if id(r) not in stall_ids]
        self._fcfs_sort(admitted)
        return admitted

    def _replenish_qos(self, freed: int) -> None:
        """Slot(s) freed: reclaim credit stranded by tombstones, then
        distribute the pool to tenants with unmet live demand by stride
        scheduling (shares → weights under saturation); the replenish pokes
        the TWAHash buckets of the enabled ticket windows."""
        if self._use_kernel:
            # the fused kernel round replenishes in-graph each step — just
            # bank the freed slot(s) for the next round's pool
            self._qos_free += freed
            return
        depths = jnp.asarray(self._tenant_live, jnp.int32)
        self.qos, reclaimed = qos_reclaim(self.qos, depths)
        self._qos_free += freed + int(reclaimed)
        if self._qos_free > 0:
            self.qos, alloc, leftover = qos_replenish(
                self.qos, self._qos_free, depths, self.n_slots)
            self._qos_free = int(leftover)
            # Exact host-side wake on top of the bucket pokes: the engine
            # knows each replenished tenant's head, so flag it directly —
            # admission never depends on the conservative poke window alone.
            for tidx in np.flatnonzero(np.asarray(alloc)):
                for r in self._tenant_queues[tidx]:
                    if not r.expired:
                        r.fast = True
                        break

    # --------------------------------------------------------- scheduler ----

    def _admit_ready(self):
        """Admit backlog requests whose ticket < grant. TWA-style: only
        re-examine requests whose bucket moved since they last looked."""
        if self._tenants is not None:
            return self._admit_ready_qos()
        if not self.backlog:
            return []
        buckets = jnp.asarray([r.bucket for r in self.backlog], jnp.int32)
        observed = jnp.asarray([r.observed_seq for r in self.backlog], jnp.uint32)
        woken = np.asarray(woken_mask(self.sema, observed, buckets))
        admitted = []
        still = []
        grant = int(self.sema.grant)
        for r, w in zip(self.backlog, woken):
            if not (w or r.fast):
                # bucket untouched ⇒ grant can't have reached this ticket
                # (absent hash aliasing, which only causes extra checks);
                # `fast` rows were admitted at take time — the paper's
                # uncontended fast-path return.
                self.stats.backlog_skipped += 1
                still.append(r)
                continue
            self.stats.backlog_scans += 1
            r.observed_seq = int(self.sema.bucket_seq[r.bucket])
            if (grant - r.ticket) % (1 << 32) < (1 << 31) and r.ticket < grant:
                admitted.append(r)
            else:
                still.append(r)
        # FCFS safety: admission order == ticket order by construction
        admitted.sort(key=lambda r: r.ticket)
        self.backlog = still
        return admitted

    def _finish(self, slot: int, reason: str):
        """Retire a slot.  ``reason == "deadline"`` is decode preemption:
        the sequence is tombstoned (expired mid-decode), not completed —
        same slot-release path, different accounting."""
        req = self.active.pop(slot)
        req.finish_t = time.time()
        req.finish_clock = self._now_r
        self.free_slots.append(slot)
        if reason == "deadline":
            req.expired = True
            req.preempted = True
            req.expire_round = self._round_no
            self.stats.preempted += 1
            self.stats.expired += 1
            if self._tenants is not None:
                self.tenant_expired[req.tenant_id] += 1
        else:
            self.stats.finished += 1
        if self._kv_pool is not None:
            if self._chunk:
                # incremental mode: the blocks the sequence ACTUALLY took
                # post back, and the host block semaphore pokes the
                # waiting-array buckets of the enabled range — exactly the
                # device `pool_release`, so parked requests observe the
                # same wake sequence the megastep path would.  In sharing
                # mode the caller already decref'd the slot's table row in
                # ONE batched `_hshare_release` (kv_blocks was zeroed) —
                # only the last sharer's release moves the counter.
                if req.kv_blocks:
                    self._kv_free_blocks += req.kv_blocks
                    self._kv_sema = post_batch(self._kv_sema,
                                               req.kv_blocks)
                req.kv_blocks = 0
                req.parked = False
            else:
                # the sequence's worst-case block reservation posts back —
                # the host counter mirrors the device block semaphore's
                # `post`, and the semaphore mirror pokes the waiting-array
                # buckets of the enabled range (sequential per-slot posts
                # bump the same buckets as the device's one batched
                # `pool_release` — poke ranges tile [grant, grant+Σ), and
                # bucket bumps commute)
                self._kv_free_blocks += self._kv_demand(req)
                self._kv_sema = post_batch(self._kv_sema,
                                           self._kv_demand(req))
        # slot freed → post: advances grant AND pokes the bucket of the next
        # waiting ticket (successor staging — the paper's SemaPost).  In QoS
        # mode the freed slot instead re-enters the weighted replenishment.
        if self._tenants is not None:
            self._replenish_qos(1)
        else:
            self.sema = post_batch(self.sema, 1)
            self._sema_grant_h += 1
        self.stats.wakeups += 1
        self._obs_done(req)
        req.done_event.set()
        self._client_sem.post()

    def _preempt_expired(self):
        """Deadline-aware decode preemption (host path, both modes): a
        RUNNING sequence whose deadline passed is tombstoned and its slot
        freed BEFORE this step's admission, so the reclaimed unit feeds the
        same round's replenish and the next live ticket is re-granted in
        FCFS order (the megastep does the identical thing in-graph)."""
        now = self._clock()
        # ascending slot order — the device preempt mask is walked lane-
        # ascending, and the trace events below must list in that order
        due = sorted(slot for slot, req in self.active.items()
                     if req.deadline is not None and req.deadline <= now)
        for slot in due:
            r = self.active[slot]
            self._ev_preempt.append(
                [EV_PREEMPT, r.rid, slot, len(r.out_tokens)])
        if self._kv_share:
            # the device preempt phase decrefs every preempted slot's row
            # in ONE batched pool_release — mirror it on the replica, then
            # let _finish retire the slots with nothing left to release
            self._hshare_release(due)
            for slot in due:
                self.active[slot].kv_blocks = 0
        for slot in due:
            self._finish(slot, "deadline")

    def step(self, sample_fn: Callable[[np.ndarray], np.ndarray]) -> int:
        """One engine iteration: preempt expired → admit → prefill admitted
        → decode active.  Returns number of active rows."""
        if self._kv_pool is not None and self._kv_state is not None:
            # the device block pool already tracks reservations the host
            # counter can't see — a host admission here would double-book
            # blocks and decode against tables that don't exist on device
            raise RuntimeError(
                "paged engine is decoding via megastep; host step() would "
                "desync the device block pool (serve a kv_pool engine "
                "through ONE of the two paths)")
        with self._lock:
            rnd = self._round_no
            # ONE nominal host sync per step (the paired megastep counts 1
            # per K rounds); `telemetry()` and sample recording are pure
            # host-side reads and must never bump this
            self.stats.host_syncs += 1
            now_r = self._now_r = self._clock()
            self._round_gate_stalls = 0
            self._ev_preempt, self._ev_admit, self._ev_attach = [], [], []
            self._ev_park, self._ev_resume, self._ev_chunk = [], [], []
            self._ev_cow, self._ev_finish = [], []
            self._round_prefill_tokens = 0
            self._round_prefill_chunks = 0
            self._round_prefix_hits = 0
            self._round_cow_copies = 0
            self._round_nonfinite = self._nonfinite_sticky
            a0, e0, p0 = (self.stats.admitted, self.stats.expired,
                          self.stats.preempted)
            self._preempt_expired()
            admitted = self._admit_ready()
            if admitted:
                # mirror the device `_assign_slots` in EVERY mode: admits
                # in packed-FCFS-key order take ASCENDING free slots.
                # Under sharing this is load-bearing for block identities
                # (a slot's take pulls ids off the free queue in slot
                # order); in the counter-only modes it pins the ADMIT
                # trace events' slot column to the in-scan event table
                # bit-exactly (tests/test_obs.py).
                from .engine_state import _D_CLAMP, _T_BITS

                if self._tenants is not None:
                    grants = np.asarray(self.qos.grant)
                else:
                    grants = None
                    g0 = int(self.sema.grant)
                for r in admitted:
                    tidx = (self._tindex[r.tenant_id]
                            if grants is not None else 0)
                    d = (r.ticket
                         - (int(grants[tidx]) if grants is not None
                            else g0)) & 0xFFFFFFFF
                    d = d - (1 << 32) if d >= (1 << 31) else d
                    r.prio_key = (max(-_D_CLAMP, min(_D_CLAMP, d))
                                  << _T_BITS) + tidx
                admitted = sorted(admitted, key=lambda r: r.prio_key)
                self.free_slots.sort(reverse=True)
            for req in admitted:
                slot = self.free_slots.pop()
                req.slot = slot
                req.admit_t = time.time()
                req.admit_round = rnd
                req.last_adv_round = rnd  # assignment arms the watchdog
                self.active[slot] = req
                self.stats.admitted += 1
                self._ev_admit.append(
                    [EV_ADMIT, req.rid, slot,
                     min(len(req.prompt), self._prompt_cap) or 1])
                if self._chunk:
                    # chunked: no instant prefill — the chunk phase below
                    # streams the prompt in; prefill_fn fires on the round
                    # the last chunk lands (full KV available)
                    req.prefill_pos = 0
                    req.kv_blocks = 0
                    if self._kv_share and req.share is not None:
                        # attach the gate's cache hit: seed the shared
                        # block ids into the slot's table row and incref
                        # each (no counter moves, no pokes — the device
                        # round's phase 3a); the KV cursor resumes AT the
                        # divergence point, so the covered tokens cost
                        # zero prefill flops and zero new HBM
                        c_i, bids_i, tail_i, cov_i = req.share
                        req.share = None
                        ids = [int(b) for b in bids_i[:c_i]]
                        if tail_i >= 0:
                            ids.append(tail_i)
                        ids = ids[:self._kv_mb]
                        if ids:
                            self._kv_htbl[slot, :len(ids)] = ids
                            self._kv_hpool = pool_incref(
                                self._kv_hpool,
                                jnp.asarray(ids, jnp.int32),
                                jnp.ones(len(ids), bool))
                            self._hshare_sync()
                        req.prefill_pos = cov_i
                        req.kv_blocks = len(ids)
                        if cov_i > 0:
                            self._ev_attach.append(
                                [EV_PREFIX_ATTACH, req.rid, slot, cov_i])
                        pl = min(len(req.prompt), self._prompt_cap) or 1
                        if cov_i >= pl:  # fully covered: decode-ready now
                            self._round_prefix_hits += 1
                else:
                    self.prefill_fn(req)  # engine-owner fills the row's cache

            if not self.active:
                self._round_no = rnd + 1
                self._record_round(self._host_sample(rnd, now_r, a0, e0,
                                                     p0, 0))
                return 0
            self.stats.steps += 1
            if self._chunk:
                decode = [(int(s), self.active[int(s)])
                          for s in self._chunk_step()]
            else:
                decode = list(self.active.items())
            if decode:
                logits = self.step_fn([r for _, r in decode])
                if (isinstance(logits, np.ndarray)
                        and logits.dtype.kind == "f"
                        and not np.all(np.isfinite(logits))):
                    self._round_nonfinite = True  # H_NAN sentinel input
                next_tokens = sample_fn(logits)
                done_slots = []
                for (slot, req), tok in zip(decode, next_tokens):
                    req.out_tokens.append(int(tok))
                    req.last_adv_round = rnd  # progress re-arms watchdog
                    if req.first_tok_clock is None:
                        req.first_tok_clock = now_r
                    req.last_tok_clock = now_r
                    if len(req.out_tokens) >= req.max_new_tokens:
                        done_slots.append(slot)
                for slot in sorted(done_slots):  # device fin mask: lane-
                    r = self.active[slot]        # ascending event order
                    self._ev_finish.append(
                        [EV_FINISH, r.rid, slot, len(r.out_tokens)])
                if self._kv_share:
                    # ONE batched decref for the whole finish phase (the
                    # device round's completion release) before the
                    # per-slot retirement bookkeeping
                    self._hshare_release(done_slots)
                    for slot in done_slots:
                        self.active[slot].kv_blocks = 0
                for slot in done_slots:
                    self._finish(slot, "length")
            self.stats.prefix_hits += self._round_prefix_hits
            self.stats.cow_copies += self._round_cow_copies
            self._round_no = rnd + 1
            self._record_round(self._host_sample(rnd, now_r, a0, e0, p0,
                                                 len(decode)))
            return len(self.active)

    def _chunk_step(self) -> np.ndarray:
        """Host chunk phase — ONE call into the SAME jitted planner the
        scanned megastep uses (`serving.prefill.chunk_plan` over
        `banker_order`), applied to the per-request host state: split the
        prefill token budget, take blocks incrementally from the host
        block-semaphore mirror, park the block-stalled requests on its
        waiting array, and return the decode-ready slot indices.  Because
        planner, order, and semaphore arithmetic are shared with
        `engine_state._chunk_phase`, host-loop and megastep serving stay
        bit-identical round-for-round (tests/test_chunked_prefill.py)."""
        from ..core.functional import park_state
        from .prefill import banker_order, chunk_plan

        S = self.n_slots
        sharing = self._kv_share
        busy = np.zeros(S, bool)
        parked = np.zeros(S, bool)
        woken = np.zeros(S, bool)
        pos = np.zeros(S, np.int32)
        plen = np.zeros(S, np.int32)
        mxn = np.zeros(S, np.int32)
        held = np.zeros(S, np.int32)
        prio_r = np.zeros(S, np.int32)
        prio_k = np.zeros(S, np.int32)
        seq = np.asarray(self._kv_sema.bucket_seq)
        rem = np.zeros(S, np.int32)
        rids = np.full(S, -1, np.int32)
        for s, r in self.active.items():
            pl = min(len(r.prompt), self._prompt_cap) or 1
            rids[s] = r.rid
            busy[s] = True
            parked[s] = r.parked
            woken[s] = r.parked and seq[r.park_bucket] != r.park_seq
            pos[s] = (r.prefill_pos if r.prefill_pos < pl
                      else pl + len(r.out_tokens))
            plen[s] = pl
            mxn[s] = r.max_new_tokens
            held[s] = r.kv_blocks
            rem[s] = self._kv_rem(r)
            prio_r[s] = r.admit_round
            prio_k[s] = r.prio_key
        if sharing:
            cow_a, held_free = self._kv_share_state()
            rem = rem + cow_a.astype(np.int32)  # a pending COW owes 1 more
        else:
            cow_a, held_free = np.zeros(S, bool), held
        order = banker_order(rem, prio_r, prio_k, busy)
        plan = chunk_plan(order, busy, parked, woken, pos, plen, mxn, held,
                          self._kv_free_blocks, cow_a, held_free,
                          chunk=self._chunk, budget=self._budget,
                          block_size=self._kv_bs)
        take = np.asarray(plan.take)
        tokens = np.asarray(plan.tokens)
        parked_o = np.asarray(plan.parked)
        deficit = np.asarray(plan.deficit)
        newly = parked_o & (deficit > 0)
        # trace events — PARK/RESUME on park-state TRANSITIONS, one
        # PREFILL_CHUNK per slot that landed tokens; lane-ascending, the
        # same masks/args the device `_chunk_phase` folds into the table
        for s in np.flatnonzero(parked_o & ~parked):
            self._ev_park.append([EV_PARK, int(rids[s]), int(s),
                                  int(deficit[s])])
        for s in np.flatnonzero(parked & ~parked_o):
            self._ev_resume.append([EV_RESUME, int(rids[s]), int(s), 0])
        for s in np.flatnonzero(tokens > 0):
            self._ev_chunk.append([EV_PREFILL_CHUNK, int(rids[s]), int(s),
                                   int(tokens[s])])
        if sharing:
            # the replica takes the granted blocks through the SAME
            # `pool_try_alloc` the scanned round uses (free-queue cursor,
            # park registration), scatters the fresh ids into the table —
            # a COW grant REPLACES the shared tail at column held−1, whose
            # old id is decref'd in ONE batched release — and resyncs the
            # counter mirrors off the mutated pool
            cow_g = np.asarray(plan.cow)
            max_take = -(-self._chunk // self._kv_bs) + 1
            hp, ids, bkt_j, sq_j = pool_try_alloc(
                self._kv_hpool, plan.take, max_take,
                park=jnp.asarray(newly), deficit=plan.deficit)
            ids = np.asarray(ids)
            bkt, sq = np.asarray(bkt_j), np.asarray(sq_j)
            old = self._kv_htbl[np.arange(S),
                                np.clip(held - 1, 0, self._kv_mb - 1)]
            for s in np.flatnonzero(cow_g):  # arg = the replaced block id
                self._ev_cow.append([EV_COW, int(rids[s]), int(s),
                                     int(old[s])])
            base = np.where(cow_g, held - 1, held)
            for s in range(S):
                for k in range(int(take[s])):
                    if 0 <= base[s] + k < self._kv_mb:
                        self._kv_htbl[s, base[s] + k] = ids[s, k]
            if cow_g.any():
                hp = pool_release(hp, jnp.asarray(old),
                                  jnp.asarray(cow_g))
            self._kv_hpool = hp
            self._hshare_sync()
            self._round_cow_copies = int(cow_g.sum())
        else:
            if newly.any():
                bkt, sq = park_state(self._kv_sema,
                                     np.maximum(deficit, 1)
                                     .astype(np.uint32))
                bkt, sq = np.asarray(bkt), np.asarray(sq)
            total = int(take.sum())
            self._kv_free_blocks -= total
            self._kv_sema = self._kv_sema._replace(
                ticket=self._kv_sema.ticket + jnp.uint32(total))
        for s, r in self.active.items():
            pl = int(plen[s])
            if sharing:
                r.kv_blocks = int((self._kv_htbl[s] >= 0).sum())
            else:
                r.kv_blocks += int(take[s])
            r.parked = bool(parked_o[s])
            if newly[s]:
                r.park_bucket = int(bkt[s])
                r.park_seq = int(sq[s])
            if tokens[s]:
                r.prefill_pos += int(tokens[s])
                r.last_adv_round = self._round_no  # chunk landed: progress
                if r.prefill_pos >= pl:
                    self.prefill_fn(r)  # last chunk landed: full KV ready
        if sharing:
            # publish prefixes at prefill COMPLETION — the device round's
            # phase 4b, against the post-take post-COW pool/table (no pool
            # op intervenes between here and there on either path)
            comp = busy & (pos < plen) & (pos + tokens >= plen)
            if comp.any():
                sph = np.zeros((S, 2, self._hash_w + 1), np.uint32)
                for s, r in self.active.items():
                    sph[s] = np.asarray(r.ph, np.uint32)
                self._kv_cache = cache_register(
                    self._kv_cache, self._kv_hpool, jnp.asarray(sph),
                    jnp.asarray(plen), jnp.asarray(self._kv_htbl),
                    jnp.asarray(comp), self._kv_bs)
        self.stats.prefill_chunks += int((tokens > 0).sum())
        self.stats.kv_block_stalls += int(parked_o.sum())
        self._round_prefill_tokens = int(tokens.sum())
        self._round_prefill_chunks = int((tokens > 0).sum())
        return np.flatnonzero(np.asarray(plan.emit))

    # ----------------------------------------------------------- megastep ---

    def megastep(self, K: int, *, token_fn=None, admit_fn=None,
                 nows=None, admit_impl="auto") -> int:
        """Device-resident decode megastep: K fused engine rounds as ONE
        jitted `lax.scan` (`serving.engine_state.megastep_jit`) over a
        donated on-device :class:`~repro.serving.engine_state.EngineState`
        — the host syncs once per K decoded tokens (launch + one drain of
        the (K, S) token/event buffers) instead of once per token.

        Each scanned round fuses: deadline preemption of running slots →
        the QoS admission round (preemption-freed units feed the SAME
        round's replenish) → FCFS slot assignment through the free-slot
        TWA semaphore → ``token_fn`` decode+sample → completion
        retirement.  Round-for-round identical to K sequential `step()`
        calls (property-tested in tests/test_megastep.py).

        ``token_fn(model, EngineState) -> (tokens (S,) i32, model')`` and
        the optional in-graph prefill hook ``admit_fn(model, state, rows,
        mask, slots) -> model'`` must be jittable; the model pytree lives
        in ``self.megastep_model`` and is donated across launches.
        ``nows``: optional (K,) float timestamps RELATIVE to launch
        (default: all 0.0 — time frozen at launch for the whole
        megastep).  ``admit_impl`` overrides the in-graph admission-round
        implementation (``"auto"``: the fused Pallas pass on TPU when
        ``use_kernel``, else the functional path; tests pass
        `engine_state.fused_round_impl` explicitly to exercise the kernel
        in interpret mode — bit-identical either way).

        With ``kv_pool=`` the scanned round allocates from / releases to
        the block-paged KV pool; the device `KVPool` (block semaphore +
        tables) persists across launches alongside ``megastep_model``, so
        paged engines must decode through megastep (host `step()` keeps
        only the free-block counter).  With ``chunked_prefill=`` every
        scanned round additionally co-schedules prompt chunks with decode
        (incremental block takes, waiting-array parks — see
        `serving.engine_state`); ``token_fn`` must handle the prefill
        phase (`engine_state.chunked_prefill_token_fn` or the
        static-window factory), and per-request prefill/park state rides
        host↔device across launches.  Returns the number of busy slots
        after the last round.
        """
        from .engine_state import (
            KVPool,
            Slots,
            fused_round_impl,
            make_engine_state,
            megastep_jit,
            zero_token_fn,
        )

        if self._tenants is None:
            raise ValueError("megastep requires QoS mode (tenants=...)")
        if K < 1:
            raise ValueError("megastep needs K >= 1")
        token_fn = token_fn or zero_token_fn
        window = getattr(token_fn, "_chunk_window", None)
        if self._chunk and window is not None and window < self._chunk:
            # a narrower scatter window than the engine's chunk would
            # silently drop the tail of every scheduled chunk (pos still
            # advances by the full chunk) — corrupt KV, no error
            raise ValueError(
                f"token_fn chunk window ({window}) is smaller than the "
                f"engine's chunk size ({self._chunk}); build it with "
                f"make_chunked_prefill_token_fn({self._chunk})")
        with self._lock:
            self.stats.host_syncs += 1
            base = self._round_no
            t0 = self._clock()
            S = self.n_slots

            # Round-robin drain of the tenant queues up to the device
            # backlog capacity: truncation at the cap only ever cuts
            # per-tenant queue TAILS, so FCFS within a tenant is preserved
            # (dropped rows simply wait for a later megastep).
            qs = [[r for r in q if not r.expired]
                  for q in self._tenant_queues]
            heads = [0] * len(qs)
            rows: list[Request] = []
            while len(rows) < self._backlog_cap:
                moved = False
                for qi, q in enumerate(qs):
                    if heads[qi] < len(q) and len(rows) < self._backlog_cap:
                        rows.append(q[heads[qi]])
                        heads[qi] += 1
                        moved = True
                if not moved:
                    break
            n = len(rows)
            # power-of-two shape buckets: steady-state serving re-uses one
            # compiled executable per (B, P, K) bucket instead of
            # retracing per backlog length (cf. kernels.ops._pad_backlog)
            B = max(_next_pow2(max(n, S)), 8)
            maxp = max([len(r.prompt) for r in rows]
                       + [len(r.prompt) for r in self.active.values()] + [1])
            P = min(_next_pow2(maxp), self._prompt_cap)

            paged = self._kv_pool is not None
            if paged and self._kv_state is None and self.active:
                # slots admitted by host step() have no device block
                # tables — their KV does not exist in the pool
                raise RuntimeError(
                    "paged engine has host-admitted active slots; serve a "
                    "kv_pool engine exclusively via megastep")
            fresh_kv = paged and self._kv_state is None
            sharing = self._kv_share
            state = make_engine_state(
                self.qos, S, B, P, free_units=self._qos_free,
                kv_blocks=self._kv_blocks if fresh_kv and not sharing else 0,
                kv_slot_blocks=self._kv_mb if fresh_kv and not sharing
                else 0,
                # in-scan telemetry ring: pow2 ≥ K so one launch never
                # wraps (pow2 also buckets the compile cache with K)
                ring_cap=_next_pow2(K))
            if paged and not fresh_kv:
                # block semaphore + tables persist launch-to-launch (the
                # pool's identity mapping must survive with the model KV);
                # building a throwaway fresh pool first would waste an
                # (S, MB) table + NB-entry queue allocation per launch
                state = state._replace(kv=self._kv_state)
            elif paged and sharing:
                # first launch under sharing ADOPTS the host replica —
                # pool generations and cache entries accumulated by prior
                # host step() rounds stay authoritative, and the carried
                # pool below replaces the replica after the scan
                state = state._replace(kv=KVPool(
                    pool=self._kv_hpool, tbl=jnp.asarray(self._kv_htbl),
                    cache=self._kv_cache))
            valid = np.zeros(B, bool)
            ids = np.zeros(B, np.int32)
            tks = np.zeros(B, np.uint32)
            dls = np.full(B, np.inf, np.float32)
            rid = np.full(B, -1, np.int32)
            mx = np.zeros(B, np.int32)
            pl = np.zeros(B, np.int32)
            pr = np.zeros((B, P), np.int32)
            if sharing:
                bph = np.zeros((B, 2, self._hash_w + 1), np.uint32)
                sph = np.zeros((S, 2, self._hash_w + 1), np.uint32)
            for i, r in enumerate(rows):
                valid[i] = True
                ids[i] = self._tindex[r.tenant_id]
                tks[i] = r.ticket
                if r.deadline is not None:
                    dls[i] = r.deadline - t0
                rid[i] = r.rid
                mx[i] = r.max_new_tokens
                p = r.prompt[-P:] if r.prompt else [0]
                pl[i] = len(p)
                pr[i, :len(p)] = p
                if sharing:
                    bph[i] = np.asarray(r.ph, np.uint32)
            sb = np.zeros(S, bool)
            srow = np.full(S, -1, np.int32)
            srid = np.full(S, -1, np.int32)
            sten = np.zeros(S, np.int32)
            sdl = np.full(S, np.inf, np.float32)
            smx = np.zeros(S, np.int32)
            sem = np.zeros(S, np.int32)
            stok = np.zeros(S, np.int32)
            spos = np.zeros(S, np.int32)
            spl = np.zeros(S, np.int32)
            sprm = np.zeros((S, P), np.int32)
            spri_r = np.zeros(S, np.int32)
            spri_k = np.zeros(S, np.int32)
            sprk = np.zeros(S, bool)
            spb = np.zeros(S, np.int32)
            sps = np.zeros(S, np.uint32)
            sladv = np.zeros(S, np.int32)
            chunked = self._chunk > 0
            for slot, r in self.active.items():
                sb[slot] = True
                # watchdog clock rides host↔device with the slot (the
                # stuck-slot sentinel counts from the last progress round)
                sladv[slot] = r.last_adv_round
                srow[slot] = B + slot  # host-resolved: active at launch
                srid[slot] = r.rid
                sten[slot] = self._tindex[r.tenant_id]
                if r.deadline is not None:
                    sdl[slot] = r.deadline - t0
                smx[slot] = r.max_new_tokens
                sem[slot] = len(r.out_tokens)
                stok[slot] = (r.out_tokens[-1] if r.out_tokens
                              else (r.prompt[-1] if r.prompt else 0))
                # device position, NOT raw prompt length: prompts longer
                # than the cap were truncated at admission, and the paged
                # block tables / dense ring cursors index by the DEVICE
                # cursor — an untruncated re-seed would shift every later
                # KV write past the reservation
                plen_t = min(len(r.prompt), self._prompt_cap) or 1
                spl[slot] = plen_t
                if chunked:
                    # mid-prefill slots resume at their chunk cursor; the
                    # remaining prompt must ride along (the backlog row
                    # that held it was recycled at admission)
                    spos[slot] = (r.prefill_pos if r.prefill_pos < plen_t
                                  else plen_t + len(r.out_tokens))
                    p = r.prompt[-P:] if r.prompt else [0]
                    sprm[slot, :len(p)] = p
                    spri_r[slot] = r.admit_round
                    spri_k[slot] = r.prio_key
                    sprk[slot] = r.parked
                    spb[slot] = r.park_bucket
                    sps[slot] = r.park_seq
                    if sharing:
                        sph[slot] = np.asarray(r.ph, np.uint32)
                else:
                    spos[slot] = plen_t + len(r.out_tokens)
            state = state._replace(
                round_no=jnp.asarray(base, jnp.int32),
                stalls=jnp.asarray(self.stats.kv_block_stalls, jnp.int32),
                chunks=jnp.asarray(self.stats.prefill_chunks, jnp.int32),
                backlog=state.backlog._replace(
                    valid=jnp.asarray(valid), tenant=jnp.asarray(ids),
                    ticket=jnp.asarray(tks), deadline=jnp.asarray(dls),
                    rid=jnp.asarray(rid), max_new=jnp.asarray(mx),
                    prompt=jnp.asarray(pr), prompt_len=jnp.asarray(pl),
                    **({"ph": jnp.asarray(bph)} if sharing else {})),
                slots=Slots(
                    busy=jnp.asarray(sb), row=jnp.asarray(srow),
                    rid=jnp.asarray(srid), tenant=jnp.asarray(sten),
                    deadline=jnp.asarray(sdl), max_new=jnp.asarray(smx),
                    emitted=jnp.asarray(sem), token=jnp.asarray(stok),
                    pos=jnp.asarray(spos), plen=jnp.asarray(spl),
                    prompt=jnp.asarray(sprm), prio_r=jnp.asarray(spri_r),
                    prio_k=jnp.asarray(spri_k), parked=jnp.asarray(sprk),
                    park_bucket=jnp.asarray(spb), park_seq=jnp.asarray(sps),
                    chunk=jnp.zeros(S, jnp.int32),
                    last_adv=jnp.asarray(sladv),
                    **({"ph": jnp.asarray(sph),
                        "cow_src": jnp.full((S,), -1, jnp.int32)}
                       if sharing else {})),
                slot_sema=state.slot_sema._replace(
                    ticket=jnp.uint32(int(sb.sum()))))

            if nows is None:
                nows_a = np.zeros(K, np.float32)
            else:
                nows_a = np.asarray(nows, np.float32)
                if nows_a.shape != (K,):
                    raise ValueError(f"nows must be shape ({K},)")
            if admit_impl == "auto":
                admit_impl = (fused_round_impl
                              if self._use_kernel
                              and jax.default_backend() == "tpu" else None)

            # donation requires every leaf to own a distinct buffer: the
            # freshly-built state is small (copy unconditionally — fresh
            # QoS states alias one zeros buffer across fields); the model
            # (KV caches — the big pytree) is copied only on first
            # adoption, then flows donated launch-to-launch.
            state = jax.tree_util.tree_map(
                lambda x: jnp.array(x, copy=True), state)
            model = self.megastep_model if self.megastep_model is not None \
                else ()
            if model is not getattr(self, "_megastep_model_last", None):
                model = jax.tree_util.tree_map(
                    lambda x: jnp.array(x, copy=True), model)
            st, model, ys = megastep_jit(
                state, model, jnp.asarray(nows_a), token_fn=token_fn,
                admit_fn=admit_fn, admit_impl=admit_impl,
                block_size=self._kv_bs if paged else 0,
                chunk=self._chunk if paged else 0,
                budget=self._budget if paged else 0,
                commit=self._kv_commit if paged else 0,
                watchdog=self._watchdog)
            self.megastep_model = model
            self._megastep_model_last = model

            # ---- the ONE host sync: drain state + event buffers --------
            st_h, ys_h = jax.device_get((st, ys))
            prev_active = dict(self.active)

            def req_of(row: int) -> Request:
                return rows[row] if row < B else prev_active[row - B]

            gone = set()
            for i, r in enumerate(rows):
                tidx = self._tindex[r.tenant_id]
                if st_h.backlog.admit_round[i] >= 0:
                    r.admit_round = int(st_h.backlog.admit_round[i])
                    r.admit_t = time.time()
                    r.slot = int(st_h.backlog.slot[i])
                    self.stats.admitted += 1
                    self.tenant_admitted[r.tenant_id] += 1
                    self._tenant_live[tidx] -= 1
                    gone.add(id(r))
                elif st_h.backlog.expire_round[i] >= 0:
                    # stamp the tombstone's round clock BEFORE _expire_req
                    # so its obs event carries the in-scan expiry time, not
                    # the drain-time clock
                    r.expire_round = int(st_h.backlog.expire_round[i])
                    r.finish_clock = t0 + float(
                        nows_a[r.expire_round - base])
                    self._expire_req(r, tidx)
                    r.expire_round = int(st_h.backlog.expire_round[i])
                    self._tenant_live[tidx] -= 1
                    gone.add(id(r))
            if gone:
                for tidx, q in enumerate(self._tenant_queues):
                    self._tenant_queues[tidx] = deque(
                        r for r in q if id(r) not in gone)

            for k in range(K):
                tk = t0 + float(nows_a[k])  # round k's clock (absolute)
                for s in np.flatnonzero(ys_h.pre[k]):
                    r = req_of(int(ys_h.prerow[k][s]))
                    r.expired = True
                    r.preempted = True
                    r.expire_round = base + k
                    r.finish_t = time.time()
                    r.finish_clock = tk
                    self.stats.preempted += 1
                    self.stats.expired += 1
                    self.tenant_expired[r.tenant_id] += 1
                    self.stats.wakeups += 1
                    self._obs_done(r)
                    r.done_event.set()
                    self._client_sem.post()
                for s in np.flatnonzero(ys_h.emit[k]):
                    r = req_of(int(ys_h.row[k][s]))
                    r.out_tokens.append(int(ys_h.tokens[k][s]))
                    if r.first_tok_clock is None:
                        r.first_tok_clock = tk
                    r.last_tok_clock = tk
                for s in np.flatnonzero(ys_h.fin[k]):
                    r = req_of(int(ys_h.row[k][s]))
                    r.finish_t = time.time()
                    r.finish_clock = tk
                    self.stats.finished += 1
                    self.stats.wakeups += 1
                    self._obs_done(r)
                    r.done_event.set()
                    self._client_sem.post()
            self.stats.steps += int((ys_h.n_active > 0).sum())
            self.stats.backlog_scans += int(ys_h.n_live.sum())

            # drop resolved entries from the host expiry heap (only the
            # non-kernel step() path pops it — a megastep-only engine
            # would otherwise retain every deadline Request forever)
            if self._deadline_heap:
                self._deadline_heap = [
                    e for e in self._deadline_heap
                    if not (e[2].expired or e[2].slot is not None
                            or e[2].done_event.is_set())]
                heapq.heapify(self._deadline_heap)

            self.active = {int(s): req_of(int(st_h.slots.row[s]))
                           for s in np.flatnonzero(st_h.slots.busy)}
            self.free_slots = [s for s in range(S)
                               if not st_h.slots.busy[s]]
            for s, r in self.active.items():
                # the watchdog clock rides back to the host mirror so the
                # next launch (or a host step) resumes the same count
                r.last_adv_round = int(st_h.slots.last_adv[s])
            self._qos_free = int(st_h.free)
            self.qos = st.qos  # keep the (fresh) device arrays
            if paged:
                self._kv_state = st.kv
                # host counter ← the block semaphore's counter identity
                self._kv_free_blocks = int(np.int32(
                    np.uint32(st_h.kv.pool.sema.grant)
                    - np.uint32(st_h.kv.pool.sema.ticket)))
                # the host block-semaphore mirror resyncs to the device
                # counters/buckets in BOTH paged modes (it feeds the
                # kv_pokes telemetry probe) — mixed step()/megastep
                # serving raises above, but the mirror must never be
                # allowed to go stale against carried park state
                self._kv_sema = st.kv.pool.sema
                if sharing:
                    # replica ← post-scan device pool/table/cache (one
                    # object: the next host step() or launch continues
                    # from the scanned state); refcnt mirror off the SAME
                    # device_get so telemetry() stays sync-free
                    self._kv_hpool = st.kv.pool
                    self._kv_cache = st.kv.cache
                    self._kv_htbl = np.asarray(st_h.kv.tbl)
                    self._kv_refcnt_h = np.asarray(st_h.kv.pool.refcnt)
            if chunked:
                # carry each still-running request's prefill/park state to
                # the next launch (the device pool itself persists in
                # _kv_state; this is the per-request view of it)
                tbl_h = np.asarray(st_h.kv.tbl)
                for s, r in self.active.items():
                    r.prefill_pos = int(st_h.slots.pos[s])
                    r.prio_key = int(st_h.slots.prio_k[s])
                    r.parked = bool(st_h.slots.parked[s])
                    r.park_bucket = int(st_h.slots.park_bucket[s])
                    r.park_seq = int(st_h.slots.park_seq[s])
                    r.kv_blocks = int((tbl_h[s] >= 0).sum())
                self.stats.kv_block_stalls = int(st_h.stalls)
                self.stats.prefill_chunks = int(st_h.chunks)
            # drain the in-scan telemetry ring — part of the SAME device_get
            # above, so observability adds no host sync (host_syncs stays 1
            # per megastep; tests/test_obs.py pins this)
            from .engine_state import ring_samples

            self._last_samples = ring_samples(st_h.ring, t0=t0)
            for smp in self._last_samples:
                self._trace.ingest_sample(smp)
            if sharing:
                self.stats.prefix_hits += sum(
                    s["prefix_hits"] for s in self._last_samples)
                self.stats.cow_copies += sum(
                    s["cow_copies"] for s in self._last_samples)
            if self._obs is not None:
                for smp in self._last_samples:
                    self._obs.record_round(smp)
            self._round_no = base + K
            return int(st_h.slots.busy.sum())

    # ----------------------------------------------------------- recovery ---
    # The scheduler-owned rungs of the recovery ladder
    # (repro.resilience.recovery drives escalation policy; these methods
    # implement containment so EVERY serving mode — host loop, megastep,
    # paged, chunked — repairs through one audited path).

    def quarantine(self, slot: int) -> Request:
        """Rung 1 — evict a sick slot: release every block it holds (host
        mirror AND the persistent device pool, poking the waiting-array
        buckets exactly like a completion), return its slot unit to the
        replenishment pool, and hand the request back with its decode
        progress reset so the caller can re-submit it after a backoff
        (`Request.retries` carries the per-request budget).  The request's
        ``done_event`` is NOT set — it is still in flight."""
        from ..core.functional import pool_release

        with self._lock:
            req = self.active.pop(slot)
            self.free_slots.append(slot)
            self.stats.quarantined += 1
            # quarantine is a host-side recovery action BETWEEN rounds on
            # both serving paths — traced directly, never via the in-scan
            # table (arg = blocks the eviction hands back)
            self._trace.add(EV_QUARANTINE, req.rid, slot, req.kv_blocks,
                            self._clock(), self._round_no)
            if self._kv_pool is not None:
                if self._kv_state is not None:
                    # megastep-persistent pool: the device block table is
                    # ground truth — release ITS row (counter + free-queue
                    # + bucket pokes), clear it, and resync the host
                    # mirrors off the released pool's counter identity
                    kv = self._kv_state
                    onehot = jnp.arange(kv.tbl.shape[0]) == slot
                    pool = pool_release(kv.pool, kv.tbl, onehot)
                    self._kv_state = kv._replace(
                        pool=pool,
                        tbl=jnp.where(onehot[:, None], -1, kv.tbl))
                    self._kv_sema = pool.sema
                    self._kv_free_blocks = int(np.int32(
                        np.uint32(pool.sema.grant)
                        - np.uint32(pool.sema.ticket)))
                    if self._kv_share:
                        # replica follows the repaired device pool (the
                        # release above decref'd shared references — only
                        # last-sharer blocks actually re-entered the queue)
                        self._kv_hpool = pool
                        self._kv_htbl = np.asarray(self._kv_state.tbl)
                        self._kv_refcnt_h = np.asarray(pool.refcnt)
                elif self._kv_share:
                    # host-loop sharing: ONE batched decref-release of the
                    # slot's table row on the replica (frees + pokes only
                    # blocks whose last sharer this slot was)
                    self._hshare_release([slot])
                elif self._chunk:
                    self._kv_free_blocks += req.kv_blocks
                    self._kv_sema = post_batch(self._kv_sema, req.kv_blocks)
                else:
                    dem = self._kv_demand(req)
                    self._kv_free_blocks += dem
                    self._kv_sema = post_batch(self._kv_sema, dem)
            # reset decode progress: a requeued request replays from its
            # prompt (fresh ticket, fresh slot, fresh KV) — partial output
            # from the sick slot is untrusted by definition
            req.slot = None
            req.out_tokens.clear()
            req.prefill_pos = 0
            req.kv_blocks = 0
            req.parked = False
            req.admit_round = -1
            req.last_adv_round = -1
            req.first_tok_clock = None
            req.last_tok_clock = None
            req.fast = False
            # the freed unit re-enters admission like any completion
            if self._tenants is not None:
                self._replenish_qos(1)
            else:
                self.sema = post_batch(self.sema, 1)
                self._sema_grant_h += 1
            return req

    def audit_kv(self) -> dict:
        """Rung 2 — audit-and-rebuild the block pool from block-table
        ground truth.  The live tables are the only state a corrupted
        counter cannot forge (each busy slot's KV physically occupies its
        blocks): every id NOT owned by exactly one table cell is returned
        to the free queue, aliased duplicates are cleared from their later
        owners (reported as ``victims`` for the caller to quarantine), and
        the block semaphore's ticket is rewritten so ``grant − ticket``
        equals the true free count — ``grant`` itself is preserved, so the
        poke history parked slots observed stays valid.  All parked flags
        are cleared (stalled slots re-park against the repaired pool on
        their next round).  Returns a repair report."""
        if self._kv_pool is None:
            raise RuntimeError("audit_kv needs a block-paged pool "
                               "(kv_pool=...)")
        with self._lock:
            self.stats.kv_audits += 1
            NB = self._kv_blocks
            report = {"aliased": 0, "leaked": 0, "counter_drift": 0,
                      "victims": []}
            if self._kv_share:
                # refcounted rebuild: table REFERENCES are the ground
                # truth — refcnt := per-block reference count, free =
                # {refs == 0}, ticket = grant − free (grant preserved so
                # the poke history parked slots observed stays valid).
                # The weak prefix cache is dropped wholesale: no gen
                # stamp can be trusted about rebuilt identities
                # (`prefix.cache_clear`); future prefills re-register.
                # Note "aliased" loses its one-owner meaning here — a
                # block in two tables is a legitimate shared prefix —
                # so only out-of-range ids evict their cell.
                kv_dev = self._kv_state
                tbl = np.asarray(kv_dev.tbl if kv_dev is not None
                                 else self._kv_htbl).copy()
                pool = kv_dev.pool if kv_dev is not None else self._kv_hpool
                Sn, MB = tbl.shape
                refs = np.zeros(NB, np.int64)
                for s in range(Sn):
                    for j in range(MB):
                        b = tbl[s, j]
                        if b < 0:
                            continue
                        if b >= NB:
                            tbl[s, j] = -1
                            report["aliased"] += 1
                            if s not in report["victims"]:
                                report["victims"].append(s)
                        else:
                            refs[b] += 1
                free_ids = np.flatnonzero(refs == 0).astype(np.int32)
                n_free = len(free_ids)
                sema = pool.sema
                drift = n_free - int(np.int32(np.uint32(sema.grant)
                                              - np.uint32(sema.ticket)))
                report["counter_drift"] = int(drift)
                report["leaked"] = max(0, int(drift))
                report["refcnt_drift"] = int(
                    np.abs(refs - np.asarray(pool.refcnt)).sum())
                new_ticket = np.uint32(int(np.uint32(sema.grant)) - n_free)
                q = np.asarray(pool.free_q).copy()
                pos = (int(new_ticket) + np.arange(n_free)) & (NB - 1)
                q[pos] = free_ids
                new_pool = pool._replace(
                    sema=sema._replace(ticket=jnp.uint32(new_ticket)),
                    free_q=jnp.asarray(q),
                    refcnt=jnp.asarray(refs, jnp.int32))
                self._kv_cache = cache_clear(self._kv_cache)
                self._kv_hpool = new_pool
                self._kv_htbl = tbl
                if kv_dev is not None:
                    self._kv_state = kv_dev._replace(
                        pool=new_pool, tbl=jnp.asarray(tbl),
                        cache=self._kv_cache)
                self._hshare_sync()
                for s, r in self.active.items():
                    r.kv_blocks = int((tbl[s] >= 0).sum())
            elif self._kv_state is not None:
                kv = self._kv_state
                tbl = np.asarray(kv.tbl).copy()
                S, MB = tbl.shape
                owner = np.full(NB, -1, np.int64)
                for s in range(S):
                    for j in range(MB):
                        b = tbl[s, j]
                        if b < 0:
                            continue
                        if b >= NB or owner[b] >= 0:
                            # out-of-range or aliased: the LATER owner
                            # loses the cell (its KV is untrusted)
                            tbl[s, j] = -1
                            report["aliased"] += 1
                            if s not in report["victims"]:
                                report["victims"].append(s)
                        else:
                            owner[b] = s
                free_ids = np.flatnonzero(owner < 0).astype(np.int32)
                n_free = len(free_ids)
                sema = kv.pool.sema
                drift = n_free - int(np.int32(np.uint32(sema.grant)
                                              - np.uint32(sema.ticket)))
                report["counter_drift"] = int(drift)
                report["leaked"] = max(0, int(drift))
                # rebuild: free region occupies queue positions
                # [ticket, grant) — keep grant, set ticket = grant − free
                new_ticket = np.uint32(int(np.uint32(sema.grant)) - n_free)
                q = np.asarray(kv.pool.free_q).copy()
                pos = (int(new_ticket) + np.arange(n_free)) & (NB - 1)
                q[pos] = free_ids
                self._kv_state = kv._replace(
                    pool=kv.pool._replace(
                        sema=sema._replace(ticket=jnp.uint32(new_ticket)),
                        free_q=jnp.asarray(q)),
                    tbl=jnp.asarray(tbl))
                self._kv_sema = self._kv_state.pool.sema
                self._kv_free_blocks = n_free
                # host per-request held-block mirrors follow the table
                for s, r in self.active.items():
                    r.kv_blocks = int((tbl[s] >= 0).sum())
            else:
                # host-loop mode: the per-request counters are the ground
                # truth; reconcile the free counter and semaphore ticket
                if self._chunk:
                    held = sum(r.kv_blocks for r in self.active.values())
                else:
                    held = sum(self._kv_demand(r)
                               for r in self.active.values())
                n_free = NB - held
                drift = n_free - self._kv_free_blocks
                report["counter_drift"] = int(drift)
                report["leaked"] = max(0, int(drift))
                self._kv_free_blocks = n_free
                self._kv_sema = self._kv_sema._replace(
                    ticket=self._kv_sema.grant - jnp.uint32(n_free))
            for r in self.active.values():
                r.parked = False  # re-park (if still short) post-repair
            return report

    # ---------------------------------------------------------- telemetry ---

    def _obs_done(self, r: Request) -> None:
        """Feed a resolved request (finished, tombstoned, or preempted)
        into the attached observability layer — the per-request TTFT/TPOT
        event stream of `repro.obs.EngineObs.record_request`."""
        if self._obs is not None:
            self._obs.record_request(r)

    def _record_round(self, sample: dict) -> None:
        self._last_samples = [sample]
        self._trace.ingest_sample(sample)
        if self._obs is not None:
            self._obs.record_round(sample)

    def _host_sample(self, rnd: int, now_r: float, a0: int, e0: int,
                     p0: int, n_tok: int) -> dict:
        """Assemble the host `step()` round's telemetry sample — the SAME
        record (keys and values) `engine_state.ring_samples` drains from a
        megastep's in-scan :class:`TelemetryRing`, mirrored purely from the
        host bookkeeping.  The bit-identity property of tests/test_obs.py
        compares these with ``==`` across K rounds; extend both sides or
        neither (see `engine_state.TelemetrySample`)."""
        from . import sentinels
        from .engine_state import SLOT_TABLE

        if self._tenants is not None:
            # wrap-safe per-tenant credit: u32 difference re-read as i32
            # (the _sdist of core.functional — value survives 2³² wrap)
            credit = (np.asarray(self.qos.grant)
                      - np.asarray(self.qos.consumed)).view(np.int32)
            dead = np.asarray(self.qos.dead)
            backlog = int(self._tenant_live.sum())
        else:
            credit = np.zeros(0, np.int32)
            dead = np.zeros(0, np.uint32)
            backlog = len(self.backlog)
        paged = self._kv_pool is not None
        hist = np.zeros(SLOT_TABLE, np.int64)
        parked = pending = 0
        for r in self.active.values():
            if r.parked:
                parked += 1
                hist[r.park_bucket] += 1
            if self._chunk:
                plen = min(len(r.prompt), self._prompt_cap) or 1
                pending += max(plen - r.prefill_pos, 0)
        # per-round health bitmask — the host mirror of the in-scan
        # sentinel checks (serving.sentinels; megastep emits the same
        # field from `round_health` over the post-round device state)
        if self._chunk:
            if self._kv_share:
                # shared blocks are held ONCE — the refcount support is
                # the allocated set (conservation: free + live = NB), not
                # the per-slot table counts, which over-count sharers
                kv_held = int((self._kv_refcnt_h > 0).sum())
            else:
                kv_held = sum(r.kv_blocks for r in self.active.values())
        elif paged:
            kv_held = sum(self._kv_demand(r) for r in self.active.values())
        else:
            kv_held = 0
        health = sentinels.host_round_health(
            n_slots=self.n_slots, free_slots=len(self.free_slots),
            active=len(self.active), credit=credit, paged=paged,
            kv_free=int(self._kv_free_blocks) if paged else 0,
            kv_held=kv_held,
            kv_blocks=self._kv_blocks if paged else 0,
            chunked=self._chunk > 0,
            headroom=(self._kv_headroom(
                share=self._kv_share_state() if self._kv_share else None)
                if self._chunk else 0),
            stuck=(self._watchdog > 0 and any(
                rnd - r.last_adv_round >= self._watchdog
                for r in self.active.values())),
            nonfinite=self._round_nonfinite)
        return {
            "round": rnd,
            "clock": float(now_r),
            "admits": self.stats.admitted - a0,
            "expires": (self.stats.expired - e0)
            - (self.stats.preempted - p0),
            "preempts": self.stats.preempted - p0,
            "tokens": n_tok,
            "prefill_tokens": self._round_prefill_tokens,
            "prefill_chunks": self._round_prefill_chunks,
            "prefill_pending": pending,
            "gate_stalls": self._round_gate_stalls,
            "parked": parked,
            "backlog": backlog,
            "active": len(self.active),
            "slot_free": len(self.free_slots),
            "kv_free": int(self._kv_free_blocks) if paged else 0,
            "kv_pokes": (int(np.sum(np.asarray(self._kv_sema.bucket_seq),
                                    dtype=np.uint32)) if paged else 0),
            "prefix_hits": self._round_prefix_hits,
            "blocks_shared": (int((self._kv_refcnt_h >= 2).sum())
                              if self._kv_share else 0),
            "cow_copies": self._round_cow_copies,
            "health": int(health),
            "credit": [int(c) for c in credit],
            "poke_dead": [int(d) for d in dead],
            "kv_wait_hist": [int(h) for h in hist],
            # per-kind lists flattened in the canonical segment order
            # (serving.events.SCAN_SEGMENTS) — the exact list the device
            # event table drains after its stable compaction
            "events": (self._ev_preempt + self._ev_admit
                       + self._ev_attach + self._ev_park + self._ev_resume
                       + self._ev_chunk + self._ev_cow + self._ev_finish),
        }

    def telemetry(self) -> dict:
        """Gauge snapshot of the engine — pure host-side reads.

        Contract:

        * **No hidden host syncs.**  Every gauge comes off host bookkeeping
          (the counter mirrors) — calling ``telemetry()`` never transfers
          device arrays and never bumps ``stats.host_syncs``; the per-round
          sample streams (`last_samples`, the megastep TelemetryRing drain)
          ride the serving paths' own single sync.
        * **``pool_utilization`` is ALWAYS present**: a float in [0, 1]
          (blocks actually holding tokens / pool) for block-paged engines,
          and exactly ``None`` for dense engines — callers branch on the
          value, never on key presence.  The other block-pool gauges
          (``kv_blocks_free``, ``kv_blocks_live``, ``kv_block_stalls``,
          ``prefill_chunks``, ``parked_slots``) remain paged-only keys.
        * ``last_samples`` is the most recent serving call's per-round
          telemetry: ONE sample for a host ``step()``, K ring samples for a
          ``megastep(K)`` — identical record shape either way
          (`engine_state.ring_samples`).
        * With an ``obs=`` layer attached, ``slo`` carries its per-tenant
          TTFT/TPOT/attainment summary (`repro.obs.EngineObs.summary`).
        """
        tel = {
            "backlog": len(self.backlog),
            "active": len(self.active),
            "free_slots": len(self.free_slots),
            "queue_depth": max(0, self._sema_ticket_h - self._sema_grant_h),
            "stats": self.stats.__dict__.copy(),
            "pool_utilization": None,  # dense: no pool (see docstring)
            "last_samples": list(self._last_samples),
            # recovery-ladder action counters (repro.resilience) — every
            # containment/repair the engine performed, by rung
            "recovery": {
                "quarantined": self.stats.quarantined,
                "requeued": self.stats.requeued,
                "kv_audits": self.stats.kv_audits,
                "kernel_fallbacks": self.stats.kernel_fallbacks,
                "snapshots": self.stats.snapshots,
                "restores": self.stats.restores,
            },
            # per-request span trees + critical-path breakdown off the
            # host trace buffer (repro.obs.trace) — pure host reads, the
            # no-hidden-sync contract above covers this key too
            "trace": self._trace.summary(),
        }
        if self._kv_pool is not None:
            # block-pool gauges (the block semaphore's counter identity):
            # free = unreserved pool blocks, live = reserved blocks (whole
            # worst-case demand up-front; only the taken blocks in chunked
            # mode)
            tel["kv_blocks_free"] = int(self._kv_free_blocks)
            tel["kv_blocks_live"] = int(self._kv_blocks
                                        - self._kv_free_blocks)
            # pool_utilization = blocks actually HOLDING tokens / pool —
            # the gap to kv_blocks_live is the reservation waste the
            # chunked-incremental mode exists to reclaim
            written = 0
            for r in self.active.values():
                plen = min(len(r.prompt), self._prompt_cap) or 1
                cur = (r.prefill_pos if self._chunk and r.prefill_pos < plen
                       else plen + len(r.out_tokens))
                written += -(-cur // self._kv_bs) if cur else 0
            tel["pool_utilization"] = written / self._kv_blocks
            if self._kv_share:
                # under sharing the per-request sum above counts a shared
                # block once per SHARER — the refcount support is the
                # unique allocated set.  Read off the np refcnt mirror
                # (updated by _hshare_sync / the megastep carry), never
                # the device pool: the no-sync contract holds.
                live = int((self._kv_refcnt_h > 0).sum())
                tel["pool_utilization"] = live / self._kv_blocks
                tel["blocks_shared"] = int((self._kv_refcnt_h >= 2).sum())
                tel["prefix_hits"] = self.stats.prefix_hits
                tel["cow_copies"] = self.stats.cow_copies
            tel["kv_block_stalls"] = self.stats.kv_block_stalls
            tel["prefill_chunks"] = self.stats.prefill_chunks
            tel["parked_slots"] = sum(r.parked for r in self.active.values())
        if self._tenants is not None:
            total = sum(self.tenant_admitted.values())
            tel["backlog"] = int(self._tenant_live.sum())
            # the global `self.sema` is unused in QoS mode — queue depth is
            # the live per-tenant backlog, not the (frozen) ticket − grant
            tel["queue_depth"] = int(self._tenant_live.sum())
            tel["tenants"] = {
                t: {"weight": self._tenants[t],
                    "admitted": self.tenant_admitted[t],
                    "expired": self.tenant_expired[t],
                    "share": (self.tenant_admitted[t] / total) if total else 0.0,
                    "queue_depth": int(self._tenant_live[self._tindex[t]])}
                for t in self._tenant_names
            }
        if self._obs is not None:
            tel["slo"] = self._obs.summary()
        return tel
