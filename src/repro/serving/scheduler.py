"""FCFS continuous-batching serving scheduler — the paper's semaphore as the
admission-control core of an inference engine.

Resource model: the engine owns S decode slots (rows of the batched KV
cache).  Admission is a ticket semaphore with `grant` preloaded to S:

  * a new request `take`s → its ticket IS its global admission number; the
    FCFS guarantee of the paper becomes the engine's fairness guarantee
    (no request starves behind later arrivals — the pthread-baseline
    equivalent would let short prompts barge past long-queued ones);
  * when a sequence finishes, its slot frees → `post` advances grant, which
    enables exactly the next ticket(s) in line;
  * the TWA waiting array is what makes the *scheduler loop* scale: pending
    requests are dispersed over hashed buckets; each loop iteration
    re-examines ONLY requests whose bucket was poked by a post
    (`woken_mask`), instead of rescanning the whole backlog — the
    global-spinning analogue the paper eliminates.  With a 10k-deep backlog
    and 8 slots freed, the loop touches ~8 requests, not 10k.
  * host-side waiting uses the L1 TWA futex semaphore so request threads
    block politely (client-facing synchronous API), while the batched
    in-graph admission uses core.functional / kernels.sema_batch.

Multi-tenant QoS mode (``tenants={tenant_id: weight}``): admission routes
through `admission.functional_qos` — per-tenant functional TWA semaphores
replenished from the global slot pool by stride scheduling, one shared
bucket array gating which tenant queues the loop re-examines, and
deadline-expired backlog entries tombstoned so they never block later
live tickets (the skip-aware grant of the tombstone protocol).  FCFS holds
within a tenant; across tenants admission shares converge to the weights
under saturation.  With ``use_kernel=True`` the whole tenant round
(expire → replenish → admit → reclaim) runs as the fused Pallas pass
(`kernels.qos_admission`, interpret-mode off-TPU) instead of the host
queue walk — same admission semantics, one vectorized in-graph sweep.

The engine below is deliberately model-agnostic: `step_fn` is any callable
(tokens, positions, caches) → (logits, caches); tests drive it with a tiny
transformer, examples/serve_continuous_batching.py with a reduced config.
"""

from __future__ import annotations

import heapq
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..admission.functional_qos import (
    make_qos,
    qos_reclaim,
    qos_replenish,
    qos_take,
)
from ..core.functional import SemaState, make_sema, post_batch, take_batch, woken_mask
from ..core.twa_semaphore import TWASemaphore


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    tenant_id: str = "default"
    deadline: Optional[float] = None  # absolute time.monotonic admission deadline
    ticket: Optional[int] = None
    bucket: Optional[int] = None
    observed_seq: Optional[int] = None
    fast: bool = False  # admitted at take time (paper's fast-path return)
    slot: Optional[int] = None
    expired: bool = False  # deadline passed before admission (tombstoned)
    out_tokens: list[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    enqueue_t: float = 0.0
    admit_t: float = 0.0
    finish_t: float = 0.0


@dataclass
class EngineStats:
    admitted: int = 0
    finished: int = 0
    expired: int = 0  # deadline-missed before admission (tombstoned tickets)
    steps: int = 0
    backlog_scans: int = 0  # requests re-examined by the scheduler loop
    backlog_skipped: int = 0  # requests NOT re-examined thanks to TWA buckets
    wakeups: int = 0


class ContinuousBatchingEngine:
    """Slot-synchronous decode engine with TWA-semaphore admission."""

    def __init__(
        self,
        step_fn: Callable,
        prefill_fn: Callable,
        n_slots: int,
        *,
        table_size: int = 256,
        use_kernel: bool = False,
        tenants: Optional[dict[str, float]] = None,
    ):
        self.step_fn = step_fn
        self.prefill_fn = prefill_fn
        self.n_slots = n_slots
        self.sema = make_sema(count=n_slots, table_size=table_size)
        self.backlog: list[Request] = []  # pending (ticketed, not admitted)
        self.active: dict[int, Request] = {}  # slot → request
        self.free_slots = list(range(n_slots))
        self.stats = EngineStats()
        self._lock = threading.Lock()
        self._client_sem = TWASemaphore(0, waiting="futex")  # completion wakeups
        self._use_kernel = use_kernel
        # --- multi-tenant QoS admission (admission.functional_qos) ---
        self._tenants = tenants
        if tenants is not None:
            # weight 0 is meaningful at the functional layer (at most one
            # unit, then the virtual pass saturates to +inf) but in a
            # serving engine it means silent starvation — reject it here.
            bad = {t: w for t, w in tenants.items() if not w > 0}
            if bad:
                raise ValueError(
                    f"tenant weights must be > 0, got {bad}; zero-weight "
                    "tenants would starve after at most one admission")
            self._tenant_names = list(tenants)
            self._tindex = {t: i for i, t in enumerate(self._tenant_names)}
            self.qos = make_qos([tenants[t] for t in self._tenant_names],
                                table_size=table_size)
            self._qos_free = n_slots  # undistributed global slots
            self._tenant_queues: list[deque[Request]] = [
                deque() for _ in self._tenant_names]
            self._tenant_live = np.zeros(len(self._tenant_names), np.int64)
            self.tenant_admitted = {t: 0 for t in self._tenant_names}
            self.tenant_expired = {t: 0 for t in self._tenant_names}
            self._deadline_heap: list[tuple[float, int, Request]] = []

    # ------------------------------------------------------------ client ----

    def submit(self, req: Request) -> Request:
        """Take a ticket (FCFS position) and enqueue."""
        if self._tenants is not None:
            self._submit_qos([req])
            return req
        req.enqueue_t = time.time()
        with self._lock:
            state, tickets, admitted, buckets = take_batch(
                self.sema, jnp.ones((1,), bool)
            )
            self.sema = state
            req.ticket = int(tickets[0])
            req.bucket = int(buckets[0])
            req.fast = bool(admitted[0])
            req.observed_seq = int(self.sema.bucket_seq[req.bucket])
            self.backlog.append(req)
        return req

    def submit_batch(self, reqs: list[Request]) -> None:
        """Vectorized ticket issuance — one fused pass for K arrivals (the
        sema_batch kernel path when enabled)."""
        if self._tenants is not None:
            self._submit_qos(reqs)
            return
        with self._lock:
            n = len(reqs)
            if self._use_kernel:
                from ..kernels.ops import sema_batch as sema_kernel

                nt, ng, nseq, tk, adm, bkt, wok = sema_kernel(
                    self.sema.ticket, self.sema.grant, self.sema.bucket_seq,
                    jnp.ones((n,), bool), jnp.uint32(0), self.sema.salt,
                )
                self.sema = SemaState(nt, ng, nseq, self.sema.salt)
            else:
                self.sema, tk, adm, bkt = take_batch(self.sema, jnp.ones((n,), bool))
            for r, t, b, a in zip(reqs, np.asarray(tk), np.asarray(bkt), np.asarray(adm)):
                r.enqueue_t = time.time()
                r.ticket = int(t)
                r.bucket = int(b)
                r.fast = bool(a)
                r.observed_seq = int(self.sema.bucket_seq[r.bucket])
                self.backlog.append(r)

    # ------------------------------------------------- multi-tenant (QoS) ---

    def _submit_qos(self, reqs: list[Request]) -> None:
        """Batched ticket issuance against the per-tenant QoS semaphores.
        Arrivals whose deadline already passed are dead on arrival."""
        unknown = {r.tenant_id for r in reqs} - self._tindex.keys()
        if unknown:
            raise ValueError(
                f"unregistered tenant(s) {sorted(unknown)}; this engine "
                f"serves tenants {list(self._tenant_names)}")
        with self._lock:
            now = time.monotonic()
            ids = [self._tindex[r.tenant_id] for r in reqs]
            # Deadlines enter the graph RELATIVE to now: small deltas stay
            # exact in float32, whereas absolute monotonic stamps (~boot
            # seconds) lose sub-second precision after weeks of uptime and
            # would misclassify short-deadline arrivals as dead-on-arrival.
            dls = [np.inf if r.deadline is None else r.deadline - now
                   for r in reqs]
            self.qos, tickets, buckets, expired = qos_take(
                self.qos, jnp.asarray(ids, jnp.int32),
                jnp.ones(len(reqs), bool), jnp.asarray(dls), 0.0)
            seq = np.asarray(self.qos.bucket_seq)
            for r, i, t, b, e in zip(reqs, ids, np.asarray(tickets),
                                     np.asarray(buckets), np.asarray(expired)):
                r.enqueue_t = time.time()
                if e:
                    self._expire_req(r, i)
                    continue
                r.ticket = int(t)
                r.bucket = int(b)
                r.observed_seq = int(seq[r.bucket])
                r.fast = True  # fresh arrival: examine once on next pass
                self._tenant_queues[i].append(r)
                self._tenant_live[i] += 1
                # the kernel round re-evaluates every deadline in-graph each
                # step — the host expiry heap would only leak entries there
                if r.deadline is not None and not self._use_kernel:
                    heapq.heappush(self._deadline_heap, (r.deadline, r.rid, r))
            # Undistributed slots flow to the new demand immediately (the
            # work-conserving fast path of the hierarchy).
            self._replenish_qos(0)

    def _fcfs_sort(self, reqs: list[Request]) -> None:
        """Sort admitted requests into wrap-safe admission order: signed
        ticket distance from the tenant's grant frontier (tickets are u32
        and may cross 2³²; raw comparison would order a post-wrap ticket
        before its predecessor).  Cross-tenant ordering is cosmetic — FCFS
        is a per-tenant invariant.  The grant snapshot is taken ONCE (one
        device→host transfer per round, not per request)."""
        grants = np.asarray(self.qos.grant)

        def key(r: Request):
            d = (r.ticket - int(grants[self._tindex[r.tenant_id]])) & 0xFFFFFFFF
            return (d - (1 << 32) if d >= (1 << 31) else d, r.tenant_id)

        reqs.sort(key=key)

    def _expire_req(self, r: Request, tidx: int) -> None:
        r.expired = True
        self.stats.expired += 1
        self.tenant_expired[self._tenant_names[tidx]] += 1
        r.finish_t = time.time()
        r.done_event.set()

    def _expire_due_qos(self) -> None:
        """Tombstone backlog entries whose admission deadline passed.  The
        host-side skip: the next live same-tenant waiter is flagged for
        re-examination so the dead ticket never blocks it."""
        now = time.monotonic()
        dead_bump = np.zeros(len(self._tenant_names), np.uint32)
        while self._deadline_heap and self._deadline_heap[0][0] <= now:
            _, _, r = heapq.heappop(self._deadline_heap)
            if r.expired or r.slot is not None or r.done_event.is_set():
                continue  # admitted or already resolved — deadline is moot
            tidx = self._tindex[r.tenant_id]
            self._expire_req(r, tidx)
            self._tenant_live[tidx] -= 1
            dead_bump[tidx] += 1
            for nxt in self._tenant_queues[tidx]:
                if not nxt.expired:  # successor inherits the wake
                    nxt.fast = True
                    break
        if dead_bump.any():
            self.qos = self.qos._replace(
                dead=self.qos.dead + jnp.asarray(dead_bump))
            # Credit stranded on tombstoned tickets re-enters the pool and
            # is re-granted to live demand (skip-aware replenishment).
            self._replenish_qos(0)

    def _admit_ready_qos_kernel(self) -> list[Request]:
        """Fused in-graph admission round (``use_kernel=True``): expire,
        weighted replenish, tombstone-transparent FCFS admit and reclaim run
        as ONE `kernels.qos_admission` pass over the whole backlog —
        O(N·S/block) vectorized work instead of the host-side queue walk
        (every row is examined, but in-graph; the TWA bucket gating of the
        host path is subsumed by the kernel's blocked live-rank sweep)."""
        from ..kernels.ops import qos_round as qos_round_kernel

        rows = [r for q in self._tenant_queues for r in q if not r.expired]
        if not rows:
            return []
        now = time.monotonic()
        ids = np.asarray([self._tindex[r.tenant_id] for r in rows], np.int32)
        tks = np.asarray([r.ticket for r in rows], np.uint32)
        # relative deadlines: see _submit_qos on float32 precision
        dls = np.asarray([np.inf if r.deadline is None else r.deadline - now
                          for r in rows], np.float32)
        state, admitted, expired, leftover = qos_round_kernel(
            self.qos, ids, tks, np.ones(len(rows), bool), dls, 0.0,
            self._qos_free, max_units=self.n_slots)
        self.qos = state
        self._qos_free = int(leftover)
        self.stats.backlog_scans += len(rows)
        admitted = np.asarray(admitted)
        expired = np.asarray(expired)
        out: list[Request] = []
        for r, i, a, e in zip(rows, ids, admitted, expired):
            if e:
                self._expire_req(r, int(i))
                self._tenant_live[int(i)] -= 1
            elif a:
                self._tenant_live[int(i)] -= 1
                self.tenant_admitted[r.tenant_id] += 1
                out.append(r)
        if admitted.any() or expired.any():
            gone = {id(r) for r, a, e in zip(rows, admitted, expired) if a or e}
            for tidx, q in enumerate(self._tenant_queues):
                self._tenant_queues[tidx] = deque(
                    r for r in q if id(r) not in gone)
        self._fcfs_sort(out)
        return out

    def _admit_ready_qos(self) -> list[Request]:
        """Weighted-FCFS admission: per-tenant queues are re-examined only
        when their head's bucket was poked by a replenish (or flagged by an
        arrival/expiry) — the TWA gating at tenant granularity."""
        if self._use_kernel:
            return self._admit_ready_qos_kernel()
        self._expire_due_qos()
        # wrap-safe spendable credit: u32 difference reinterpreted signed
        # (mirrors functional_qos.avail's _sdist — a raw widened subtraction
        # would go hugely negative once grant crosses 2³²)
        avail = (np.asarray(self.qos.grant) - np.asarray(self.qos.consumed)
                 ).astype(np.int32).astype(np.int64)
        seq = np.asarray(self.qos.bucket_seq)
        admitted: list[Request] = []
        spent = np.zeros(len(self._tenant_names), np.uint32)
        for tidx, q in enumerate(self._tenant_queues):
            while q and q[0].expired:
                q.popleft()  # lazy removal of tombstoned heads
            if not q:
                continue
            head = q[0]
            if not (head.fast or seq[head.bucket] != head.observed_seq):
                self.stats.backlog_skipped += sum(not r.expired for r in q)
                continue
            head.fast = False
            head.observed_seq = int(seq[head.bucket])
            while q and avail[tidx] - int(spent[tidx]) > 0:
                r = q.popleft()
                if r.expired:
                    continue
                spent[tidx] += 1
                self._tenant_live[tidx] -= 1
                self.tenant_admitted[r.tenant_id] += 1
                admitted.append(r)
            # examined = the head + each admitted row; everything left in
            # the queue was never touched (the TWA skip).
            self.stats.backlog_scans += int(spent[tidx]) + (1 if q and q[0] is head else 0)
            self.stats.backlog_skipped += sum(not r.expired for r in q) \
                - (1 if q and q[0] is head else 0)
        if spent.any():
            self.qos = self.qos._replace(
                consumed=self.qos.consumed + jnp.asarray(spent))
        self._fcfs_sort(admitted)
        return admitted

    def _replenish_qos(self, freed: int) -> None:
        """Slot(s) freed: reclaim credit stranded by tombstones, then
        distribute the pool to tenants with unmet live demand by stride
        scheduling (shares → weights under saturation); the replenish pokes
        the TWAHash buckets of the enabled ticket windows."""
        if self._use_kernel:
            # the fused kernel round replenishes in-graph each step — just
            # bank the freed slot(s) for the next round's pool
            self._qos_free += freed
            return
        depths = jnp.asarray(self._tenant_live, jnp.int32)
        self.qos, reclaimed = qos_reclaim(self.qos, depths)
        self._qos_free += freed + int(reclaimed)
        if self._qos_free > 0:
            self.qos, alloc, leftover = qos_replenish(
                self.qos, self._qos_free, depths, self.n_slots)
            self._qos_free = int(leftover)
            # Exact host-side wake on top of the bucket pokes: the engine
            # knows each replenished tenant's head, so flag it directly —
            # admission never depends on the conservative poke window alone.
            for tidx in np.flatnonzero(np.asarray(alloc)):
                for r in self._tenant_queues[tidx]:
                    if not r.expired:
                        r.fast = True
                        break

    # --------------------------------------------------------- scheduler ----

    def _admit_ready(self):
        """Admit backlog requests whose ticket < grant. TWA-style: only
        re-examine requests whose bucket moved since they last looked."""
        if self._tenants is not None:
            return self._admit_ready_qos()
        if not self.backlog:
            return []
        buckets = jnp.asarray([r.bucket for r in self.backlog], jnp.int32)
        observed = jnp.asarray([r.observed_seq for r in self.backlog], jnp.uint32)
        woken = np.asarray(woken_mask(self.sema, observed, buckets))
        admitted = []
        still = []
        grant = int(self.sema.grant)
        for r, w in zip(self.backlog, woken):
            if not (w or r.fast):
                # bucket untouched ⇒ grant can't have reached this ticket
                # (absent hash aliasing, which only causes extra checks);
                # `fast` rows were admitted at take time — the paper's
                # uncontended fast-path return.
                self.stats.backlog_skipped += 1
                still.append(r)
                continue
            self.stats.backlog_scans += 1
            r.observed_seq = int(self.sema.bucket_seq[r.bucket])
            if (grant - r.ticket) % (1 << 32) < (1 << 31) and r.ticket < grant:
                admitted.append(r)
            else:
                still.append(r)
        # FCFS safety: admission order == ticket order by construction
        admitted.sort(key=lambda r: r.ticket)
        self.backlog = still
        return admitted

    def _finish(self, slot: int, reason: str):
        req = self.active.pop(slot)
        req.finish_t = time.time()
        self.free_slots.append(slot)
        self.stats.finished += 1
        # slot freed → post: advances grant AND pokes the bucket of the next
        # waiting ticket (successor staging — the paper's SemaPost).  In QoS
        # mode the freed slot instead re-enters the weighted replenishment.
        if self._tenants is not None:
            self._replenish_qos(1)
        else:
            self.sema = post_batch(self.sema, 1)
        self.stats.wakeups += 1
        req.done_event.set()
        self._client_sem.post()

    def step(self, sample_fn: Callable[[np.ndarray], np.ndarray]) -> int:
        """One engine iteration: admit → prefill admitted → decode active.
        Returns number of active rows."""
        with self._lock:
            for req in self._admit_ready():
                slot = self.free_slots.pop()
                req.slot = slot
                req.admit_t = time.time()
                self.active[slot] = req
                self.stats.admitted += 1
                self.prefill_fn(req)  # engine-owner fills the row's cache

            if not self.active:
                return 0
            self.stats.steps += 1
            logits = self.step_fn(list(self.active.values()))
            next_tokens = sample_fn(logits)
            done_slots = []
            for (slot, req), tok in zip(list(self.active.items()), next_tokens):
                req.out_tokens.append(int(tok))
                if len(req.out_tokens) >= req.max_new_tokens:
                    done_slots.append(slot)
            for slot in done_slots:
                self._finish(slot, "length")
            return len(self.active)

    # ---------------------------------------------------------- telemetry ---

    def telemetry(self) -> dict:
        tel = {
            "backlog": len(self.backlog),
            "active": len(self.active),
            "free_slots": len(self.free_slots),
            "queue_depth": max(0, int(self.sema.ticket) - int(self.sema.grant)),
            "stats": self.stats.__dict__.copy(),
        }
        if self._tenants is not None:
            total = sum(self.tenant_admitted.values())
            tel["backlog"] = int(self._tenant_live.sum())
            tel["tenants"] = {
                t: {"weight": self._tenants[t],
                    "admitted": self.tenant_admitted[t],
                    "expired": self.tenant_expired[t],
                    "share": (self.tenant_admitted[t] / total) if total else 0.0,
                    "queue_depth": int(self._tenant_live[self._tindex[t]])}
                for t in self._tenant_names
            }
        return tel
