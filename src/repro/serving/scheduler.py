"""FCFS continuous-batching serving scheduler — the paper's semaphore as the
admission-control core of an inference engine.

Resource model: the engine owns S decode slots (rows of the batched KV
cache).  Admission is a ticket semaphore with `grant` preloaded to S:

  * a new request `take`s → its ticket IS its global admission number; the
    FCFS guarantee of the paper becomes the engine's fairness guarantee
    (no request starves behind later arrivals — the pthread-baseline
    equivalent would let short prompts barge past long-queued ones);
  * when a sequence finishes, its slot frees → `post` advances grant, which
    enables exactly the next ticket(s) in line;
  * the TWA waiting array is what makes the *scheduler loop* scale: pending
    requests are dispersed over hashed buckets; each loop iteration
    re-examines ONLY requests whose bucket was poked by a post
    (`woken_mask`), instead of rescanning the whole backlog — the
    global-spinning analogue the paper eliminates.  With a 10k-deep backlog
    and 8 slots freed, the loop touches ~8 requests, not 10k.
  * host-side waiting uses the L1 TWA futex semaphore so request threads
    block politely (client-facing synchronous API), while the batched
    in-graph admission uses core.functional / kernels.sema_batch.

The engine below is deliberately model-agnostic: `step_fn` is any callable
(tokens, positions, caches) → (logits, caches); tests drive it with a tiny
transformer, examples/serve_continuous_batching.py with a reduced config.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functional import SemaState, make_sema, post_batch, take_batch, woken_mask
from ..core.twa_semaphore import TWASemaphore


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    ticket: Optional[int] = None
    bucket: Optional[int] = None
    observed_seq: Optional[int] = None
    fast: bool = False  # admitted at take time (paper's fast-path return)
    slot: Optional[int] = None
    out_tokens: list[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    enqueue_t: float = 0.0
    admit_t: float = 0.0
    finish_t: float = 0.0


@dataclass
class EngineStats:
    admitted: int = 0
    finished: int = 0
    steps: int = 0
    backlog_scans: int = 0  # requests re-examined by the scheduler loop
    backlog_skipped: int = 0  # requests NOT re-examined thanks to TWA buckets
    wakeups: int = 0


class ContinuousBatchingEngine:
    """Slot-synchronous decode engine with TWA-semaphore admission."""

    def __init__(
        self,
        step_fn: Callable,
        prefill_fn: Callable,
        n_slots: int,
        *,
        table_size: int = 256,
        use_kernel: bool = False,
    ):
        self.step_fn = step_fn
        self.prefill_fn = prefill_fn
        self.n_slots = n_slots
        self.sema = make_sema(count=n_slots, table_size=table_size)
        self.backlog: list[Request] = []  # pending (ticketed, not admitted)
        self.active: dict[int, Request] = {}  # slot → request
        self.free_slots = list(range(n_slots))
        self.stats = EngineStats()
        self._lock = threading.Lock()
        self._client_sem = TWASemaphore(0, waiting="futex")  # completion wakeups
        self._use_kernel = use_kernel

    # ------------------------------------------------------------ client ----

    def submit(self, req: Request) -> Request:
        """Take a ticket (FCFS position) and enqueue."""
        req.enqueue_t = time.time()
        with self._lock:
            state, tickets, admitted, buckets = take_batch(
                self.sema, jnp.ones((1,), bool)
            )
            self.sema = state
            req.ticket = int(tickets[0])
            req.bucket = int(buckets[0])
            req.fast = bool(admitted[0])
            req.observed_seq = int(self.sema.bucket_seq[req.bucket])
            self.backlog.append(req)
        return req

    def submit_batch(self, reqs: list[Request]) -> None:
        """Vectorized ticket issuance — one fused pass for K arrivals (the
        sema_batch kernel path when enabled)."""
        with self._lock:
            n = len(reqs)
            if self._use_kernel:
                from ..kernels.ops import sema_batch as sema_kernel

                nt, ng, nseq, tk, adm, bkt, wok = sema_kernel(
                    self.sema.ticket, self.sema.grant, self.sema.bucket_seq,
                    jnp.ones((n,), bool), jnp.uint32(0), self.sema.salt,
                )
                self.sema = SemaState(nt, ng, nseq, self.sema.salt)
            else:
                self.sema, tk, adm, bkt = take_batch(self.sema, jnp.ones((n,), bool))
            for r, t, b, a in zip(reqs, np.asarray(tk), np.asarray(bkt), np.asarray(adm)):
                r.enqueue_t = time.time()
                r.ticket = int(t)
                r.bucket = int(b)
                r.fast = bool(a)
                r.observed_seq = int(self.sema.bucket_seq[r.bucket])
                self.backlog.append(r)

    # --------------------------------------------------------- scheduler ----

    def _admit_ready(self):
        """Admit backlog requests whose ticket < grant. TWA-style: only
        re-examine requests whose bucket moved since they last looked."""
        if not self.backlog:
            return []
        buckets = jnp.asarray([r.bucket for r in self.backlog], jnp.int32)
        observed = jnp.asarray([r.observed_seq for r in self.backlog], jnp.uint32)
        woken = np.asarray(woken_mask(self.sema, observed, buckets))
        admitted = []
        still = []
        grant = int(self.sema.grant)
        for r, w in zip(self.backlog, woken):
            if not (w or r.fast):
                # bucket untouched ⇒ grant can't have reached this ticket
                # (absent hash aliasing, which only causes extra checks);
                # `fast` rows were admitted at take time — the paper's
                # uncontended fast-path return.
                self.stats.backlog_skipped += 1
                still.append(r)
                continue
            self.stats.backlog_scans += 1
            r.observed_seq = int(self.sema.bucket_seq[r.bucket])
            if (grant - r.ticket) % (1 << 32) < (1 << 31) and r.ticket < grant:
                admitted.append(r)
            else:
                still.append(r)
        # FCFS safety: admission order == ticket order by construction
        admitted.sort(key=lambda r: r.ticket)
        self.backlog = still
        return admitted

    def _finish(self, slot: int, reason: str):
        req = self.active.pop(slot)
        req.finish_t = time.time()
        self.free_slots.append(slot)
        self.stats.finished += 1
        # slot freed → post: advances grant AND pokes the bucket of the next
        # waiting ticket (successor staging — the paper's SemaPost)
        self.sema = post_batch(self.sema, 1)
        self.stats.wakeups += 1
        req.done_event.set()
        self._client_sem.post()

    def step(self, sample_fn: Callable[[np.ndarray], np.ndarray]) -> int:
        """One engine iteration: admit → prefill admitted → decode active.
        Returns number of active rows."""
        with self._lock:
            for req in self._admit_ready():
                slot = self.free_slots.pop()
                req.slot = slot
                req.admit_t = time.time()
                self.active[slot] = req
                self.stats.admitted += 1
                self.prefill_fn(req)  # engine-owner fills the row's cache

            if not self.active:
                return 0
            self.stats.steps += 1
            logits = self.step_fn(list(self.active.values()))
            next_tokens = sample_fn(logits)
            done_slots = []
            for (slot, req), tok in zip(list(self.active.items()), next_tokens):
                req.out_tokens.append(int(tok))
                if len(req.out_tokens) >= req.max_new_tokens:
                    done_slots.append(slot)
            for slot in done_slots:
                self._finish(slot, "length")
            return len(self.active)

    # ---------------------------------------------------------- telemetry ---

    def telemetry(self) -> dict:
        return {
            "backlog": len(self.backlog),
            "active": len(self.active),
            "free_slots": len(self.free_slots),
            "queue_depth": max(0, int(self.sema.ticket) - int(self.sema.grant)),
            "stats": self.stats.__dict__.copy(),
        }
