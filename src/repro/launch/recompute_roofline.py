"""Recompute roofline terms for already-measured analysis JSONs (idempotent
post-processor — lets the memory model / hardware constants evolve without
re-running the expensive lowerings)."""

from __future__ import annotations

import json
import sys
from pathlib import Path

from ..configs.registry import SHAPES, get_config
from .dryrun import attn_model_flops, model_flops, scan_flop_correction
from .hlo_analysis import analytic_hbm_bytes, roofline_terms


def recompute(path: Path) -> bool:
    r = json.loads(path.read_text())
    if not r.get("ok") or r.get("mode") != "analysis":
        return False
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    axes = ({"pod": 2, "data": 16, "model": 16} if r["mesh"] == "multipod"
            else {"data": 16, "model": 16})
    n_chips = r["chips"]

    class _C:
        by_axis = r["collectives"]["by_axis"]

    correction = scan_flop_correction(cfg, shape)
    flops_chip = r["cost"]["flops"] + correction / n_chips
    terms = roofline_terms(flops_chip, r["cost"]["bytes_accessed"], _C)
    mem_model = analytic_hbm_bytes(cfg, shape, axes, accum=1)
    terms["T_mem_hlo_upper"] = terms["T_mem"]
    terms["T_mem"] = mem_model / 819e9
    terms["hbm_model_bytes"] = mem_model
    bound = max(terms["T_comp"], terms["T_mem"], terms["T_coll"])
    terms["bottleneck"] = max(("T_comp", "T_mem", "T_coll"), key=lambda k: terms[k])
    terms["roofline_fraction"] = terms["T_comp"] / bound if bound else 0.0
    mf = model_flops(cfg, shape)
    terms["model_flops_total"] = mf
    terms["hlo_flops_total"] = flops_chip * n_chips
    terms["useful_ratio"] = mf / max(terms["hlo_flops_total"], 1.0)
    terms["attn_model_flops_total"] = attn_model_flops(cfg, shape)
    terms["useful_ratio_with_attn"] = (mf + terms["attn_model_flops_total"]) / max(
        terms["hlo_flops_total"], 1.0)
    r["roofline"] = terms
    path.write_text(json.dumps(r, indent=2))
    return True


def main():
    d = Path(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    n = sum(recompute(p) for p in sorted(d.glob("*_analysis.json")))
    print(f"[recompute] {n} analysis records updated")


if __name__ == "__main__":
    main()
