"""Serving driver: continuous batching with TWA-semaphore FCFS admission
over a real (reduced) model — the paper's technique as the first-class
scheduler of an inference engine.

    python -m repro.launch.serve --arch qwen2-0.5b --requests 24 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_smoke_config
from ..models.transformer import decode_step, init_caches, init_params, prefill
from ..serving.scheduler import ContinuousBatchingEngine, Request


class ModelServer:
    """Slot-synchronous batched decode over a reduced config."""

    def __init__(self, arch: str, n_slots: int, max_len: int = 128, seed: int = 0):
        self.cfg = get_smoke_config(arch)
        self.n_slots = n_slots
        self.max_len = max_len
        self.params = init_params(jax.random.PRNGKey(seed), self.cfg)
        self.caches = init_caches(self.cfg, n_slots, max_len, jnp.float32)
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self.positions = np.zeros((n_slots, 1), np.int32)
        self.row_pos = np.zeros((n_slots,), np.int32)
        self._decode = jax.jit(
            lambda p, t, pos, c: decode_step(p, self.cfg, t, pos, c)
        )

    def prefill_request(self, req: Request):
        """Row prefill: replay the prompt through decode steps (row-isolated
        caches make per-row prefill exact; a production engine would batch
        prefills — see DESIGN.md §serving)."""
        slot = req.slot
        for i, tok in enumerate(req.prompt):
            self.tokens[slot, 0] = tok
            self.positions[slot, 0] = i
            logits, self.caches = self._decode(
                self.params, jnp.asarray(self.tokens), jnp.asarray(self.positions),
                self.caches)
        self.row_pos[slot] = len(req.prompt)
        req._last_logits = np.asarray(logits[slot])

    def step_fn(self, active_reqs):
        for r in active_reqs:
            slot = r.slot
            self.tokens[slot, 0] = r.out_tokens[-1] if r.out_tokens else r.prompt[-1]
            self.positions[slot, 0] = self.row_pos[slot]
            self.row_pos[slot] += 1
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), jnp.asarray(self.positions), self.caches)
        return np.asarray(logits)[[r.slot for r in active_reqs]]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    server = ModelServer(args.arch, args.slots)
    engine = ContinuousBatchingEngine(
        server.step_fn, server.prefill_request, args.slots)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i, prompt=list(rng.integers(1, server.cfg.vocab, args.prompt_len)),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    engine.submit_batch(reqs)
    print(f"[serve] {args.requests} requests, {args.slots} slots, "
          f"queue_depth={engine.telemetry()['queue_depth']}")

    t0 = time.time()
    steps = 0
    sample = lambda lg: lg.argmax(-1)
    while engine.stats.finished < args.requests and steps < 10_000:
        engine.step(sample)
        steps += 1
    dt = time.time() - t0
    tel = engine.telemetry()
    tok = sum(len(r.out_tokens) for r in reqs)
    waits = [r.admit_t - r.enqueue_t for r in reqs]
    order_ok = all(
        reqs[i].admit_t <= reqs[j].admit_t + 1e-6
        for i in range(len(reqs)) for j in range(i + 1, len(reqs))
    )
    print(f"[serve] finished={engine.stats.finished} steps={steps} "
          f"tokens={tok} ({tok / dt:.1f} tok/s) fcfs={order_ok}")
    print(f"[serve] TWA scheduler: re-examined={tel['stats']['backlog_scans']} "
          f"skipped={tel['stats']['backlog_skipped']} "
          f"(skip ratio {tel['stats']['backlog_skipped'] / max(1, tel['stats']['backlog_skipped'] + tel['stats']['backlog_scans']):.2f})")
    return engine


if __name__ == "__main__":
    main()
