"""End-to-end training driver (the (b) deliverable's e2e entry point).

Wires every substrate together on whatever devices exist (1 CPU here; the
production mesh via the dry-run):

    data: SyntheticLM → DataLoader (TWA bounded buffer, FIFO, deterministic)
    model: any --arch config (reduced by default so CPU runs in minutes)
    step: parallel.steps.make_train_step (accum, remat, FSDP when meshed)
    checkpointing: async sharded writes, TWA writer-slot admission,
        atomic publish, resume
    control plane: Coordinator heartbeats + straggler telemetry + SIGTERM
        emergency checkpoint (preemption-safe)

Usage:
    python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 50
    python -m repro.launch.train --arch deepseek-moe-16b --smoke --steps 20 \
        --resume --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import signal
import time

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.registry import get_config, get_smoke_config
from ..configs.registry import ShapeSpec
from ..data.pipeline import DataLoader, SyntheticLM
from ..optim.adamw import AdamWConfig
from ..parallel import steps as steps_lib
from ..runtime.coordinator import Coordinator


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    sc = steps_lib.default_step_config(cfg, shape, dp=1, param_dtype=jax.numpy.float32)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)

    print(f"[train] arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"params≈{cfg.param_count() / 1e6:.1f}M accum={sc.accum_steps}")

    coord = Coordinator()
    coord.join(0)

    state = steps_lib.make_train_state(jax.random.PRNGKey(args.seed), cfg, sc)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        if args.resume and ckpt.latest_step() is not None:
            state, start_step = ckpt.restore(state)
            print(f"[train] resumed from step {start_step}")

    # preemption safety: SIGTERM → synchronous emergency checkpoint
    last_state = {"state": state, "step": start_step}
    if ckpt is not None:
        def _on_term(signum, frame):
            print("[train] SIGTERM — emergency checkpoint")
            ckpt.save_sync(last_state["step"], last_state["state"])
            raise SystemExit(143)

        signal.signal(signal.SIGTERM, _on_term)

    source = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed)
    loader = DataLoader(source, args.batch, n_workers=2, depth=4,
                        start_step=start_step)
    step_fn = jax.jit(steps_lib.make_train_step(cfg, shape, sc, opt_cfg))

    losses = []
    it = iter(loader)
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = next(it)
        if cfg.frontend == "vision":
            B = batch["tokens"].shape[0]
            batch["patch_embeds"] = np.zeros((B, cfg.n_patches, cfg.d_model), np.float32)
            batch["labels"] = np.concatenate(
                [np.zeros((B, cfg.n_patches), np.int32), batch["labels"]], axis=1)
        elif cfg.frontend == "audio":
            B = batch["tokens"].shape[0]
            emb = np.zeros((B, args.seq, cfg.d_model), np.float32)
            emb[..., 0] = batch["tokens"]  # token-dependent frames (stub)
            batch = {"frame_embeds": emb, "labels": batch["labels"]}
        state, metrics = step_fn(state, batch)
        last_state["state"], last_state["step"] = state, step + 1
        dt = time.time() - t0
        coord.heartbeat(0, step, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            tel = loader.telemetry()
            print(f"[train] step={step} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} dt={dt:.2f}s "
                  f"input_ready={tel['items_ready']}")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.save(args.steps, state, blocking=True)
        ckpt.wait()
    loader.stop()
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"[train] done. loss {first:.3f} → {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
