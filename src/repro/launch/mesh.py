"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization, while smoke tests want the plain
1-device CPU.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one v5e pod = 16×16 = 256 chips as
    (data=16, model=16); two pods = 512 chips as (pod=2, data=16, model=16).
    The `pod` axis carries only data parallelism (and the hierarchical /
    compressed gradient reduction) — it crosses DCI, not ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int = 2, n_model: int = 2, n_pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if n_pod:
        return jax.make_mesh((n_pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_device_count(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
