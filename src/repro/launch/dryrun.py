import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on the
production mesh built from 512 host placeholder devices, and extract the
roofline inputs.

Two lowerings per cell (see EXPERIMENTS.md §Method):

  production — scan-over-layers, microbatch accumulation, blockwise
               attention: the artifact that would ship.  Source of
               memory_analysis() (true per-device allocation).
  analysis   — identical math with every static-trip-count loop unrolled
               (units scan, KV-block scan, CE-chunk scan, accumulation
               collapsed to A=1).  Source of cost_analysis() FLOPs/bytes and
               the HLO collective parse — XLA counts a while-loop body ONCE,
               so the production artifact *undercounts* by the trip counts;
               the analysis artifact does not.  Residual undercount: the
               xLSTM time-step scans (nonlinear recurrences cannot be
               unrolled at S=4k/32k); corrected analytically via
               scan_flop_correction() and flagged in the output.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --out experiments/dryrun
"""

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from .. import compat  # noqa: E402
from ..configs.registry import SHAPES, get_config, shapes_for  # noqa: E402
from ..optim.adamw import AdamWConfig  # noqa: E402
from ..parallel import steps as steps_lib  # noqa: E402
from ..parallel.sharding import batch_pspecs, cache_pspecs, param_pspecs  # noqa: E402
from .hlo_analysis import analyze_collectives, analytic_hbm_bytes, roofline_terms  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


# ------------------------------------------------------- analytic helpers ---


def scan_flop_correction(cfg, shape) -> float:
    """(trips−1) × body-FLOPs for the unavoidable nonlinear time-step scans
    (mLSTM / sLSTM).  Train counts fwd+recompute+bwd ≈ 4× fwd body."""
    kinds = [b.kind for b in cfg.blocks]
    n_ml = kinds.count("mlstm")
    n_sl = kinds.count("slstm")
    if n_ml + n_sl == 0 or shape.kind == "decode":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    d_in = 2 * cfg.d_model
    H = cfg.xlstm_heads
    hd = d_in // H
    ml_body = B * (5 * H * hd * hd + 6 * H * hd)  # C/n update + qC readout
    hd_s = cfg.d_model // H
    sl_body = B * (2 * H * hd_s * 4 * hd_s + 30 * H * hd_s)  # recurrent mm + gates
    per_step = n_ml * ml_body + n_sl * sl_body
    mult = 4.0 if shape.kind == "train" else 1.0
    return (S - 1) * per_step * mult


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens/step."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens  # inference: fwd only


def attn_model_flops(cfg, shape) -> float:
    """Attention-score/PV FLOPs not counted by 6·N·D (reported separately so
    the useful-compute ratio can be read against the right denominator).
    Causal/windowed structure credited (factor ½ or window/S)."""
    n_attn = sum(1 for b in cfg.blocks if b.kind in ("attn", "moe"))
    if n_attn == 0:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.n_heads, cfg.hd
    total = 0.0
    for b in cfg.blocks:
        if b.kind not in ("attn", "moe"):
            continue
        if shape.kind == "decode":
            ctx = min(S, b.window) if b.window else S
            total += 4.0 * B * H * ctx * hd  # one query vs cache
        else:
            frac = min(1.0, b.window / S) if b.window else 0.5  # causal half
            fb = 3.0 if shape.kind == "train" else 1.0  # fwd(+bwd≈2×)
            total += 4.0 * B * H * S * S * frac * hd * fb
    return total


# ----------------------------------------------------------- cell dry-run ---


def build_step(cfg, shape, sc):
    if shape.kind == "train":
        step = steps_lib.make_train_step(cfg, shape, sc, AdamWConfig())
        state = steps_lib.abstract_train_state(cfg, sc)
        specs = steps_lib.train_state_pspecs(state, sc)
        ins = steps_lib.input_specs(cfg, shape)
        args = (state, ins["batch"])
        in_specs = (specs, batch_pspecs(ins["batch"]))
        donate = (0,)
    elif shape.kind == "prefill":
        step = steps_lib.make_prefill_step(cfg, shape, sc)
        params = jax.eval_shape(
            lambda: steps_lib.init_params(jax.random.PRNGKey(0), cfg, sc.param_dtype)
        )
        ins = steps_lib.input_specs(cfg, shape)
        args = (params, ins["batch"], ins["caches"])
        in_specs = (param_pspecs(params, fsdp=sc.fsdp), batch_pspecs(ins["batch"]),
                    cache_pspecs(ins["caches"]))
        donate = (2,)
    else:
        step = steps_lib.make_decode_step(cfg, shape, sc)
        params = jax.eval_shape(
            lambda: steps_lib.init_params(jax.random.PRNGKey(0), cfg, sc.param_dtype)
        )
        ins = steps_lib.input_specs(cfg, shape)
        args = (params, ins["tokens"], ins["positions"], ins["caches"])
        in_specs = (
            param_pspecs(params, fsdp=sc.fsdp),
            batch_pspecs({"t": ins["tokens"]})["t"],
            batch_pspecs({"p": ins["positions"]})["p"],
            cache_pspecs(ins["caches"]),
        )
        donate = (3,)
    return step, args, in_specs, donate


def _analytic_args_bytes(in_specs, args, mesh) -> dict:
    """Exact per-device bytes of every input tree, from ShapeDtypeStructs ×
    sharding divisors.  This is the TPU ground truth for weights/opt/caches —
    the CPU host backend *widens every bf16 buffer to f32* (HLO shows
    wrapped_convert fusions of whole parameter/cache stacks), inflating
    memory_analysis() temps by up to 2×; see EXPERIMENTS.md §Dry-run."""
    axis_size = dict(mesh.shape_tuple)

    def spec_div(spec):
        n = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                n *= axis_size.get(ax, 1)
        return n

    total = 0.0
    flat_a = jax.tree_util.tree_leaves(args)
    flat_s = jax.tree_util.tree_leaves(
        in_specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    for a, s in zip(flat_a, flat_s):
        import numpy as _np

        n = float(_np.prod(a.shape)) if a.shape else 1.0
        total += n * a.dtype.itemsize / spec_div(s)
    return total


def run_cell(arch: str, shape_name: str, mesh_kind: str, mode: str,
             hlo_dir: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": mode,
        "chips": n_chips, "ok": False,
    }
    t0 = time.time()
    HBM_BUDGET = 14.5e9  # v5e 16 GB minus runtime reserve
    with compat.set_mesh(mesh):
        dp = steps_lib.dp_size()
        sc = steps_lib.default_step_config(cfg, shape, dp, analysis=(mode == "analysis"))
        max_accum = max(1, shape.global_batch // max(dp, 1))
        while True:
            step, args, in_specs, donate = build_step(cfg, shape, sc)
            to_sharding = lambda spec: jax.sharding.NamedSharding(mesh, spec)
            in_shardings = jax.tree.map(
                to_sharding, in_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            jitted = jax.jit(step, in_shardings=in_shardings, donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["t_lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["t_compile_s"] = round(time.time() - t1, 1)

            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: getattr(mem, k, None)
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
            }
            args_b = rec["memory"]["argument_size_in_bytes"] or 0
            alias_b = rec["memory"]["alias_size_in_bytes"] or 0
            out_b = rec["memory"]["output_size_in_bytes"] or 0
            tmp_b = rec["memory"]["temp_size_in_bytes"] or 0
            rec["memory"]["per_device_total_bytes"] = args_b + tmp_b + max(0, out_b - alias_b)
            rec["step_config"] = {"accum_steps": sc.accum_steps, "remat": sc.remat,
                                  "fsdp": sc.fsdp}
            # TPU-projected steady-state memory: exact sharded input bytes +
            # activation estimate (CPU memory_analysis widens bf16 → f32).
            args_exact = _analytic_args_bytes(in_specs, args, mesh)
            if shape.kind == "train":
                per_chip_tokens = shape.global_batch * shape.seq_len / max(dp, 1)
                act = steps_lib.est_train_act_bytes(
                    cfg, per_chip_tokens / sc.accum_steps,
                    dict(mesh.shape_tuple).get("model", 1))
                if sc.remat == "2level":
                    import math as _m

                    g = _m.isqrt(cfg.num_units) or 1
                    act *= (cfg.num_units // g + g) / max(cfg.num_units, 1)
            else:
                act = 4 * shape.global_batch * max(1, shape.seq_len if shape.kind == "prefill" else 1) \
                    * cfg.d_model * 2 / max(dp, 1)
            rec["memory"]["tpu_projected_bytes"] = args_exact + act
            rec["memory"]["analytic_args_bytes"] = args_exact
            # memory auto-tuner: production train cells double accumulation
            # until the artifact fits the per-chip HBM budget, then fall back
            # to nested (√L) remat (analysis lowerings are never executed
            # and always use A=1).
            if (mode == "production" and shape.kind == "train"
                    and rec["memory"]["per_device_total_bytes"] > HBM_BUDGET):
                if sc.accum_steps < max_accum:
                    sc = sc._replace(accum_steps=sc.accum_steps * 2)
                    rec["retuned"] = True
                    continue
                if sc.remat != "2level":
                    sc = sc._replace(remat="2level")
                    rec["retuned"] = True
                    continue
            break

        ca = compat.cost_analysis(compiled)
        rec["cost"] = {"flops": ca.get("flops", 0.0),
                       "bytes_accessed": ca.get("bytes accessed", 0.0),
                       "transcendentals": ca.get("transcendentals", 0.0)}

    rec["ok"] = True
    rec["t_total_s"] = round(time.time() - t0, 1)
    return rec


def _measure_analysis(cfg, shape, mesh, hlo_path=None, sc_over=None) -> dict:
    """One analysis lowering (all loops unrolled) → flops/bytes/collectives."""
    dp = steps_lib.dp_size()
    sc = steps_lib.default_step_config(cfg, shape, dp, analysis=True, **(sc_over or {}))
    step, args, in_specs, donate = build_step(cfg, shape, sc)
    to_sharding = lambda spec: jax.sharding.NamedSharding(mesh, spec)
    in_shardings = jax.tree.map(to_sharding, in_specs,
                                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    compiled = jax.jit(step, in_shardings=in_shardings,
                       donate_argnums=donate).lower(*args).compile()
    ca = compat.cost_analysis(compiled)
    text = compiled.as_text()
    if hlo_path:
        Path(hlo_path).parent.mkdir(parents=True, exist_ok=True)
        Path(hlo_path).write_text(text)
    axis_sizes = {name: size for name, size in mesh.shape_tuple}
    coll = analyze_collectives(text, axis_sizes)
    return {
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "wire": coll.wire_bytes_per_chip,
        "ops": coll.ops,
        "by_kind": coll.by_kind,
        "by_axis": coll.by_axis,
        "top": sorted(coll.details, key=lambda d: -d["wire"])[:12],
    }


def _lin(base: dict, delta: dict, extra_units: float) -> dict:
    """base + extra_units × delta, linearly over all numeric fields/dicts."""
    out = {}
    for k, b in base.items():
        d = delta.get(k, 0)
        if isinstance(b, dict):
            keys = set(b) | set(d if isinstance(d, dict) else {})
            out[k] = {kk: b.get(kk, 0.0) + extra_units * (d.get(kk, 0.0) if isinstance(d, dict) else 0.0)
                      for kk in keys}
        elif isinstance(b, (int, float)):
            out[k] = b + extra_units * d
        else:
            out[k] = b
    return out


def run_analysis(arch: str, shape_name: str, mesh_kind: str,
                 hlo_dir: str | None = None, sc_over: dict | None = None) -> dict:
    """Roofline measurement.  XLA counts a while-loop body once, so every
    static loop is unrolled; for deep stacks (U > 4) compiling the unrolled
    program is infeasible on this host, so we exploit exact per-unit
    linearity: measure U'∈{2,4} fully unrolled and reconstruct
    f(U) = f(4) + (U−4)·(f(4)−f(2))/2 — identical repeated units make
    flops/bytes/collective traffic affine in U (verified by the U=4 direct
    measurements for small archs)."""
    import dataclasses

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": "analysis",
           "chips": n_chips, "ok": False}
    t0 = time.time()
    with compat.set_mesh(mesh):
        U = cfg.num_units
        hlo = (Path(hlo_dir) / f"{arch}_{shape_name}_{mesh_kind}.hlo") if hlo_dir else None
        if U <= 4:
            meas = _measure_analysis(cfg, shape, mesh, hlo, sc_over)
            rec["reconstruction"] = "direct"
        else:
            m2 = _measure_analysis(dataclasses.replace(cfg, num_units=2), shape, mesh, None, sc_over)
            m4 = _measure_analysis(dataclasses.replace(cfg, num_units=4), shape, mesh, hlo, sc_over)
            delta = {k: ({kk: (m4[k].get(kk, 0.0) - m2[k].get(kk, 0.0)) / 2 for kk in set(m4[k]) | set(m2[k])}
                         if isinstance(m4[k], dict) else
                         ((m4[k] - m2[k]) / 2 if isinstance(m4[k], (int, float)) else m4[k]))
                     for k in m4}
            meas = _lin(m4, delta, U - 4)
            meas["top"] = m4["top"]
            rec["reconstruction"] = {"u_points": [2, 4], "per_unit_flops": delta["flops"]}
        rec["cost"] = {"flops": meas["flops"], "bytes_accessed": meas["bytes_accessed"]}
        rec["collectives"] = {"ops": meas["ops"], "wire_bytes_per_chip": meas["wire"],
                              "by_kind": meas["by_kind"], "by_axis": meas["by_axis"],
                              "top": meas["top"]}
        correction = scan_flop_correction(cfg, shape)
        flops_chip = meas["flops"] + correction / n_chips
        rec["flops_correction_total"] = correction

        class _C:  # tiny adapter for roofline_terms
            by_axis = meas["by_axis"]

        terms = roofline_terms(flops_chip, meas["bytes_accessed"], _C)
        # analytic (TPU-projected) memory term; HLO bytes stay as upper bound
        axes = dict(mesh.shape_tuple)
        mem_model = analytic_hbm_bytes(cfg, shape, axes, accum=1)
        terms["T_mem_hlo_upper"] = terms["T_mem"]
        terms["T_mem"] = mem_model / 819e9
        terms["hbm_model_bytes"] = mem_model
        bound = max(terms["T_comp"], terms["T_mem"], terms["T_coll"])
        terms["bottleneck"] = max(
            ("T_comp", "T_mem", "T_coll"), key=lambda k: terms[k])
        terms["roofline_fraction"] = terms["T_comp"] / bound if bound else 0.0
        mf = model_flops(cfg, shape)
        terms["model_flops_total"] = mf
        terms["hlo_flops_total"] = flops_chip * n_chips
        terms["useful_ratio"] = mf / max(terms["hlo_flops_total"], 1.0)
        terms["attn_model_flops_total"] = attn_model_flops(cfg, shape)
        terms["useful_ratio_with_attn"] = (mf + terms["attn_model_flops_total"]) / max(
            terms["hlo_flops_total"], 1.0)
        rec["roofline"] = terms
    rec["ok"] = True
    rec["t_total_s"] = round(time.time() - t0, 1)
    return rec


# ------------------------------------------------------------------ main ----


def cell_list():
    cells = []
    from ..configs.registry import ARCH_IDS

    for arch in ARCH_IDS:
        for shape in shapes_for(arch):
            cells.append((arch, shape.name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--mode", default="production", choices=["production", "analysis"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None, help="dump optimized HLO text here")
    ap.add_argument("--all", action="store_true", help="run every cell as subprocesses")
    ap.add_argument("--modes", default="production,analysis")
    ap.add_argument("--meshes", default="pod,multipod")
    ap.add_argument("--remat", default=None, help="override remat policy (hillclimb)")
    ap.add_argument("--tag", default=None, help="output-name suffix (hillclimb variants)")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        jobs = []
        for arch, shape in cell_list():
            for mesh in args.meshes.split(","):
                for mode in args.modes.split(","):
                    if mode == "analysis" and mesh == "multipod":
                        continue  # roofline table is single-pod (assignment)
                    jobs.append((arch, shape, mesh, mode))
        print(f"[dryrun] {len(jobs)} jobs")
        failures = 0
        for i, (arch, shape, mesh, mode) in enumerate(jobs):
            tag = f"{arch}_{shape}_{mesh}_{mode}"
            out_json = outdir / f"{tag}.json"
            if out_json.exists() and json.loads(out_json.read_text()).get("ok"):
                print(f"[{i + 1}/{len(jobs)}] {tag}: cached")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--mesh", mesh, "--mode", mode, "--out", str(outdir)]
            if args.hlo_dir:
                cmd += ["--hlo-dir", args.hlo_dir]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            ok = out_json.exists() and json.loads(out_json.read_text()).get("ok")
            print(f"[{i + 1}/{len(jobs)}] {tag}: {'ok' if ok else 'FAIL'} "
                  f"({time.time() - t0:.0f}s)")
            if not ok:
                failures += 1
                (outdir / f"{tag}.err").write_text(r.stdout[-4000:] + "\n" + r.stderr[-8000:])
        print(f"[dryrun] done, {failures} failures")
        sys.exit(1 if failures else 0)

    tag = f"{args.arch}_{args.shape}_{args.mesh}_{args.mode}"
    if args.tag:
        tag += f"_{args.tag}"
    try:
        sc_over = {"remat": args.remat} if args.remat else None
        if args.mode == "analysis":
            rec = run_analysis(args.arch, args.shape, args.mesh, args.hlo_dir, sc_over)
        else:
            rec = run_cell(args.arch, args.shape, args.mesh, args.mode, args.hlo_dir)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "mode": args.mode, "ok": False, "error": traceback.format_exc()}
        (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
        print(rec["error"], file=sys.stderr)
        sys.exit(1)
    (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
    mem = rec.get("memory", {})
    print(f"[dryrun] {tag}: ok mem/device="
          f"{(mem.get('per_device_total_bytes') or 0) / 1e9:.2f} GB raw / "
          f"{(mem.get('tpu_projected_bytes') or 0) / 1e9:.2f} GB projected "
          f"flops={rec['cost']['flops']:.3e} t={rec.get('t_total_s')}s")
    if "roofline" in rec:
        r = rec["roofline"]
        print(f"  T_comp={r['T_comp'] * 1e3:.2f}ms T_mem={r['T_mem'] * 1e3:.2f}ms "
              f"T_coll={r['T_coll'] * 1e3:.2f}ms bottleneck={r['bottleneck']} "
              f"frac={r['roofline_fraction']:.2f} useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
