"""Collective-traffic analysis of lowered/compiled HLO text.

`compiled.cost_analysis()` has no collective accounting, so we parse the
optimized HLO: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute contributes ring-model wire bytes per chip:

    all-gather         : out_bytes · (n-1)/n
    reduce-scatter     : in_bytes  · (n-1)/n        (= out_bytes·(n-1))
    all-reduce         : 2 · bytes · (n-1)/n
    all-to-all         : bytes · (n-1)/n
    collective-permute : bytes

`n` = replica-group size, parsed from the `replica_groups` attribute (both
explicit `{{0,1,..}}` and iota `[g,n]<=[N]...` forms).  Each op is classified
onto a mesh axis by the *stride pattern* of its first replica group against
the device order of the (pod, data, model) mesh: contiguous → "model",
stride model_size → "data", stride data·model → "pod"; mixed groups are
labelled by the outermost axis they span (their bytes cross the slowest
link involved).

CAVEAT (while loops): XLA prints a while-loop body once, so collectives
inside scans are counted once per body.  The dry-run therefore parses the
*analysis lowering* (all static-trip loops unrolled); the production
lowering is used for memory numbers only.  See EXPERIMENTS.md §Method.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[[\dx,]+\]<=\[[\d,]+\](?:T\([\d,]+\))?)")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def _tuple_bytes(inner: str) -> int:
    return sum(_shape_bytes(s) for s in re.findall(r"\w+\[[\d,]*\]", inner))


def _parse_first_group(attr: str) -> list[int]:
    """First replica group as a device list."""
    if attr.startswith("{{"):
        first = attr[2 : attr.index("}")]
        return [int(x) for x in first.split(",") if x.strip()]
    # iota form: [G,g]<=[dims...](T(perm))?  — groups are rows of a reshaped
    # (possibly transposed) iota over N devices.
    m = re.match(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", attr)
    if not m:
        return []
    out_dims = [int(x) for x in m.group(1).split(",")]
    in_dims = [int(x) for x in m.group(2).split(",")]
    perm = [int(x) for x in m.group(3).split(",")] if m.group(3) else list(range(len(in_dims)))
    n = 1
    for d in in_dims:
        n *= d
    # devices = iota(N).reshape(in_dims).transpose(perm).reshape(out_dims)
    import numpy as np

    dev = np.arange(n).reshape(in_dims).transpose(perm).reshape(out_dims)
    return list(map(int, dev[0].ravel())) if dev.ndim > 1 else [int(dev[0])]


def _classify_axis(group: list[int], axis_sizes: dict[str, int]) -> str:
    """Mesh axis (or composite) whose devices this group spans.  Device id =
    ((pod·data)+d)·model + m for mesh order (pod, data, model)."""
    if len(group) < 2:
        return "self"
    model = axis_sizes.get("model", 1)
    data = axis_sizes.get("data", 1)
    stride = group[1] - group[0]
    size = len(group)
    if stride == 1:
        if size <= model:
            return "model"
        return "model+" if size <= model * data else "all"
    if stride == model:
        return "data" if size <= data else "data+pod"
    if stride == model * data:
        return "pod"
    return "mixed"


@dataclass
class CollectiveStats:
    ops: int = 0
    wire_bytes_per_chip: float = 0.0
    by_kind: dict = field(default_factory=lambda: defaultdict(float))
    by_axis: dict = field(default_factory=lambda: defaultdict(float))
    details: list = field(default_factory=list)


def analyze_collectives(hlo_text: str, axis_sizes: dict[str, int],
                        keep_details: int = 40) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_inner, single_shape, kind = m.groups()
        out_bytes = _tuple_bytes(tuple_inner) if tuple_inner else _shape_bytes(single_shape)
        gm = _GROUPS_RE.search(line)
        group = _parse_first_group(gm.group(1)) if gm else []
        n = max(len(group), 1)
        if n == 1:
            continue  # degenerate
        frac = (n - 1) / n
        if kind == "all-gather":
            wire = out_bytes * frac
        elif kind == "reduce-scatter":
            wire = out_bytes * (n - 1)  # out is the scattered shard
        elif kind == "all-reduce":
            wire = 2 * out_bytes * frac
        elif kind == "all-to-all":
            wire = out_bytes * frac
        else:  # collective-permute
            wire = out_bytes
        axis = _classify_axis(group, axis_sizes)
        stats.ops += 1
        stats.wire_bytes_per_chip += wire
        stats.by_kind[kind] += wire
        stats.by_axis[axis] += wire
        if len(stats.details) < keep_details:
            stats.details.append(
                {"kind": kind, "bytes": out_bytes, "group_n": n, "axis": axis, "wire": wire}
            )
    stats.by_kind = dict(stats.by_kind)
    stats.by_axis = dict(stats.by_axis)
    return stats


# ------------------------------------------------------------- roofline -----

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (intra-pod)
DCI_BW = 9e9  # B/s per chip across pods (assumed; sensitivity in EXPERIMENTS)


def analytic_hbm_bytes(cfg, shape, axes: dict, accum: int = 1) -> float:
    """Per-chip HBM traffic model for one step (the TPU-projected memory
    term; `bytes accessed` from the CPU backend is an upper bound that
    double-counts fused intermediates and f32-widens bf16 — see
    EXPERIMENTS.md §Roofline note 5).

    Components (all bytes, per chip, per step):
      weights   train: 2·(P/tp)·A      (fwd+bwd weight reads per microbatch;
                                         FSDP gathers land in HBM once each)
                serve: P/tp
      optimizer train: 8·(P·4B)/(tp·dp)  (master+mu+nu read/write + fp32 grad)
      carries   train: 6·L·tokens_chip·d·2B  (save + bwd read + recompute rw)
                prefill: 2·L·tokens_chip·d·2B
      attention KV stream: n_q_blocks × local KV bytes per layer (flash
                kernel semantics: scores/probs stay in VMEM)
      kv cache  decode: 3×local cache (attention read + ring-write rw)
                prefill: +1 write
      moe       expert buffer rw: 4·tokens·k·(d+d_e)·2B / ep_shards
    """
    tp = axes.get("model", 1)
    dp = axes.get("data", 1) * axes.get("pod", 1)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    P = cfg.param_count() * 2  # bf16
    total = 0.0
    if shape.kind == "train":
        tokens_chip = B * S / dp
        total += 2 * (P / tp) * accum
        total += 8 * (P * 2) / (tp * dp)
        total += 6 * L * tokens_chip * d * 2
    elif shape.kind == "prefill":
        tokens_chip = B * S / dp
        total += P / tp
        total += 2 * L * tokens_chip * d * 2
    else:  # decode
        total += P / tp
        tokens_chip = B / dp

    # attention KV streaming / cache traffic
    n_attn = sum(1 for b in cfg.blocks if b.kind in ("attn", "moe"))
    kv_row_bytes = 2 * cfg.n_kv_heads * cfg.hd * 2  # k+v bf16 per token
    if n_attn:
        if shape.kind == "decode":
            # cache sharded over seq (tp) and batch (dp): local slice per layer
            for b in cfg.blocks:
                if b.kind not in ("attn", "moe"):
                    continue
                ctx = min(S, b.window) if b.window else S
                local = (B / dp) * (ctx / tp) * kv_row_bytes
                total += 3 * local  # attn read + one-hot ring write (rw)
        else:
            nq = max(1, S // 512)  # flash q-block revisits of the KV stream
            mult = 3.0 if shape.kind == "train" else 1.0  # fwd+recompute+bwd
            for b in cfg.blocks:
                if b.kind not in ("attn", "moe"):
                    continue
                ctx = min(S, b.window) if b.window else S
                total += mult * (B / dp) * nq * ctx * kv_row_bytes
            if shape.kind == "prefill":
                total += (B / dp) * S * kv_row_bytes * n_attn / tp  # cache write
    if cfg.n_experts:
        n_moe = sum(1 for b in cfg.blocks if b.kind == "moe")
        ep = max(cfg.n_experts, cfg.n_experts_pad)
        eshard = tp if ep % tp == 0 else 1
        tokens_chip_all = (B * (S if shape.kind != "decode" else 1)) / dp
        total += (4 * tokens_chip_all * cfg.top_k * (d + cfg.d_expert) * 2
                  * n_moe / eshard)
    return total


def roofline_terms(flops_per_chip: float, hbm_bytes_per_chip: float,
                   coll: CollectiveStats) -> dict:
    ici_bytes = sum(v for k, v in coll.by_axis.items() if k != "pod")
    dci_bytes = coll.by_axis.get("pod", 0.0)
    t_comp = flops_per_chip / PEAK_FLOPS_BF16
    t_mem = hbm_bytes_per_chip / HBM_BW
    t_coll = ici_bytes / ICI_BW + dci_bytes / DCI_BW
    terms = {"T_comp": t_comp, "T_mem": t_mem, "T_coll": t_coll,
             "ici_bytes": ici_bytes, "dci_bytes": dci_bytes}
    terms["bottleneck"] = max(("T_comp", "T_mem", "T_coll"), key=lambda k: terms[k])
    # roofline fraction: useful compute time over the max term (overlap-ideal)
    bound = max(t_comp, t_mem, t_coll)
    terms["roofline_fraction"] = (t_comp / bound) if bound > 0 else 0.0
    return terms
