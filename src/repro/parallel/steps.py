"""Step factories: jit-ready train / prefill / decode steps with the
distribution features wired in:

  * gradient accumulation via lax.scan over microbatches (activation memory
    control for the 16 GB/v5e budget; XLA overlaps each microbatch's
    gradient reduce with the next microbatch's compute);
  * configurable remat ('full' recompute per repeated unit for deep/wide
    models, 'dots' selective policy for small ones);
  * FSDP(+TP) parameter sharding and ZeRO'd optimizer state (specs from
    parallel/sharding.py);
  * optional hierarchical int8 error-feedback gradient compression across
    the *pod* axis (shard_map manual over "pod", auto over data/model —
    intra-pod reduction stays fp32 on fast ICI, inter-pod crosses DCI
    quantized; see optim/compression.py);
  * decode steps use KV-sequence ("flash-decode") sharding — rules set via
    axis_rules per shape (batch=1 long-context spreads seq over data+model).

Mode/shape-specific rule overrides keep one model code path for all 40
(arch × shape) dry-run cells.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple

import jax
from .. import compat
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..configs.registry import ShapeSpec
from ..models.transformer import (
    decode_step as model_decode_step,
    init_caches,
    init_params,
    prefill as model_prefill,
    train_loss,
)
from ..optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state
from ..optim.compression import compress_psum, init_residuals
from .sharding import (
    axis_rules,
    batch_pspecs,
    cache_pspecs,
    constrain,
    constrain_tree,
    mesh_axes,
    param_pspecs,
    spec_for,
)


class StepConfig(NamedTuple):
    accum_steps: int = 1
    remat: str = "full"  # "full" | "dots" | "none"
    param_dtype: Any = jnp.bfloat16
    fsdp: bool = True
    compress_pods: bool = False
    act_budget_bytes: float = 6e9
    kv_block: int = 1024  # flash-attention KV block (train/prefill)
    ce_chunk: int = 512  # chunked-CE sequence chunk
    analysis: bool = False  # dry-run analysis lowering: unroll every
    #   static-trip loop (units scan, attention KV scan, CE chunk scan,
    #   accumulation) so cost_analysis counts true FLOPs/bytes/collectives.


# --------------------------------------------------------------- helpers ----


def dp_size() -> int:
    axes = mesh_axes()
    return axes.get("pod", 1) * axes.get("data", 1)


def est_train_act_bytes(cfg: ModelConfig, tokens_micro: float, tp: int) -> float:
    """Rough per-chip activation bytes for one microbatch under 'full' remat:
    scan carries (never model-sharded) + the TP-sharded transient working set
    of one rematerialized unit (qkv/ffn/moe buffers, fp32 attention acc)."""
    div = lambda n: n / tp if (n and n % tp == 0) else n
    D, hd = cfg.d_model, cfg.hd
    heads_eff = div(cfg.n_heads) * hd
    carries = cfg.n_layers * tokens_micro * D * 2
    trans = tokens_micro * 2 * (4 * D + 6 * heads_eff)
    trans += tokens_micro * 4 * 2 * heads_eff  # fp32 online-softmax acc+stats
    if cfg.d_ff:
        trans += tokens_micro * 2 * 3 * div(cfg.d_ff)
    if cfg.n_experts:
        ep = max(cfg.n_experts, cfg.n_experts_pad)
        e_div = tp if ep % tp == 0 else 1
        trans += tokens_micro * cfg.top_k * cfg.capacity_factor * 2 * (
            2 * D + 2 * cfg.d_expert
        ) / e_div
        if cfg.d_shared:
            trans += tokens_micro * 2 * 2 * cfg.d_shared
    if cfg.lru_width:
        trans += tokens_micro * 2 * 8 * div(cfg.lru_width)
    return carries + trans


def default_step_config(cfg: ModelConfig, shape: ShapeSpec, dp: int, **over) -> StepConfig:
    """Pick accumulation and remat for the v5e 16 GB budget (the dry-run
    additionally auto-doubles accum_steps if memory_analysis disagrees)."""
    sc = StepConfig()
    if shape.kind == "train":
        axes = mesh_axes()
        tp = axes.get("model", 1)
        per_chip_tokens = shape.global_batch * shape.seq_len / max(dp, 1)
        max_accum = max(1, shape.global_batch // max(dp, 1))
        accum = 1
        while (accum < max_accum
               and est_train_act_bytes(cfg, per_chip_tokens / accum, tp) > sc.act_budget_bytes):
            accum *= 2
        sc = sc._replace(accum_steps=accum, remat="full")
    else:
        sc = sc._replace(fsdp=False, accum_steps=1, remat="none")
    return sc._replace(**over)


def _mode_rules(cfg: ModelConfig, shape: ShapeSpec):
    """Logical-rule overrides per shape: batch axes must divide global_batch;
    long-context (batch=1) spreads the KV sequence over data+model."""
    axes = mesh_axes()
    batch_rule: Any = ("pod", "data")
    prod = 1
    picked = []
    for a in ("pod", "data"):
        if a in axes and shape.global_batch % (prod * axes[a]) == 0:
            picked.append(a)
            prod *= axes[a]
    batch_rule = tuple(picked) if picked else None
    seq_kv = ("data", "model") if (shape.kind != "train" and "data" in axes and prod == 1) else ("model",)
    return dict(batch=batch_rule, seq_kv=seq_kv)


# ---------------------------------------------------------- train step ------


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residuals: Any  # compression error-feedback (None-like empty dict if off)


def make_train_state(key, cfg: ModelConfig, sc: StepConfig) -> TrainState:
    params = init_params(key, cfg, sc.param_dtype)
    opt = init_opt_state(params)
    res = init_residuals(params) if sc.compress_pods else {}
    return TrainState(params, opt, res)


def abstract_train_state(cfg: ModelConfig, sc: StepConfig) -> TrainState:
    return jax.eval_shape(lambda: make_train_state(jax.random.PRNGKey(0), cfg, sc))


def train_state_pspecs(state: TrainState, sc: StepConfig):
    pspec = param_pspecs(state.params, fsdp=sc.fsdp)
    res_spec = param_pspecs(state.residuals, fsdp=sc.fsdp) if state.residuals else {}
    return TrainState(
        params=pspec,
        opt=OptState(step=spec_for(), mu=pspec, nu=pspec, master=pspec),
        residuals=res_spec,
    )


def make_train_step(cfg: ModelConfig, shape: ShapeSpec, sc: StepConfig,
                    opt_cfg: AdamWConfig = AdamWConfig()):
    """Returns train_step(state, batch) → (state', metrics). Jit/pjit-ready;
    call under an active mesh (jax.set_mesh) or on a single device."""
    rules = _mode_rules(cfg, shape)
    A = 1 if sc.analysis else sc.accum_steps
    kv_block = 10**9 if sc.analysis else sc.kv_block
    ce_chunk = 10**9 if sc.analysis else sc.ce_chunk

    def loss_fn(params, mb):
        return train_loss(params, cfg, mb, remat=sc.remat, unroll_units=sc.analysis,
                          kv_block=kv_block, ce_chunk=ce_chunk)

    def grads_and_metrics(params, batch):
        """Microbatch-accumulated fp32 grads (scan when A > 1)."""
        if A == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return grads, dict(metrics, loss=loss)

        split = lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:])
        batch_r = jax.tree.map(split, batch)

        def micro(carry, mb):
            gacc, lacc, macc = carry
            mb = jax.tree.map(lambda x: constrain(x, "batch"), mb)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            macc = jax.tree.map(lambda a, b: a + b, macc, metrics)
            return (gacc, lacc + loss, macc), None

        gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {k: jnp.zeros((), jnp.float32) for k in
              ("ce", "lb_loss", "router_z", "overflow_frac", "tokens")}
        (gacc, loss, macc), _ = jax.lax.scan(micro, (gacc0, jnp.float32(0), m0), batch_r)
        grads = jax.tree.map(lambda g: g / A, gacc)
        metrics = {k: v / A for k, v in macc.items()}
        metrics["tokens"] = macc["tokens"]
        return grads, dict(metrics, loss=loss / A)

    def apply_updates(state: TrainState, grads, metrics):
        new_params, new_opt, opt_m = adamw_update(opt_cfg, grads, state.opt, sc.param_dtype)
        metrics.update(opt_m)
        return new_params, new_opt, metrics

    if not sc.compress_pods:

        def train_step(state: TrainState, batch):
            with axis_rules(**rules):
                grads, metrics = grads_and_metrics(state.params, batch)
                new_params, new_opt, metrics = apply_updates(state, grads, metrics)
                if mesh_axes():
                    specs = train_state_pspecs(state, sc)
                    new_params = constrain_tree(new_params, specs.params)
                return TrainState(new_params, new_opt, state.residuals), metrics

        return train_step

    # ---- hierarchical compressed variant: manual over "pod", auto inside ----
    def train_step_compressed(state: TrainState, batch):
        axes = mesh_axes()
        n_pods = axes.get("pod", 1)
        mesh = compat.get_abstract_mesh()
        with axis_rules(**rules):
            # grads within each pod: data+model handled automatically (auto
            # axes), pod manual. Batch enters split over pod (dim 0).
            pspec = train_state_pspecs(state, sc).params

            def per_pod(params, pod_batch):
                grads, metrics = grads_and_metrics(params, pod_batch)
                return grads, metrics

            in_batch_specs = jax.tree.map(lambda _: jax.sharding.PartitionSpec("pod"), batch)
            rep = jax.tree.map(lambda _: jax.sharding.PartitionSpec(), state.params)

            def body(params, pod_batch, residuals):
                grads, metrics = per_pod(params, pod_batch)
                # hierarchical exchange: fp32 within pod already done by auto
                # sharding; across pods → int8-range EF compression.
                if n_pods > 1:
                    flat_g, tdef = jax.tree_util.tree_flatten(grads)
                    flat_r = tdef.flatten_up_to(residuals)
                    out = [compress_psum(g, r, "pod", n_pods) for g, r in zip(flat_g, flat_r)]
                    grads = tdef.unflatten([o[0] for o in out])
                    residuals = tdef.unflatten([o[1] for o in out])
                metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
                return grads, residuals, metrics

            grads, new_res, metrics = compat.shard_map(
                body,
                mesh=mesh,
                in_specs=(rep, in_batch_specs, rep),
                out_specs=(rep, rep, jax.sharding.PartitionSpec()),
                check_vma=False,
                axis_names={"pod"},
            )(state.params, batch, state.residuals)
            new_params, new_opt, metrics = apply_updates(state, grads, metrics)
            return TrainState(new_params, new_opt, new_res), metrics

    return train_step_compressed


# ------------------------------------------------------------ serve steps ---


def make_prefill_step(cfg: ModelConfig, shape: ShapeSpec, sc: StepConfig = StepConfig()):
    rules = _mode_rules(cfg, shape)
    kv_block = 10**9 if sc.analysis else sc.kv_block

    def prefill_step(params, batch, caches):
        with axis_rules(**rules):
            logits, caches = model_prefill(params, cfg, batch, caches,
                                           unroll_units=sc.analysis, kv_block=kv_block)
            if mesh_axes():
                caches = constrain_tree(caches, cache_pspecs(caches))
            return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: ShapeSpec, sc: StepConfig = StepConfig()):
    rules = _mode_rules(cfg, shape)

    def decode_step(params, tokens, positions, caches):
        with axis_rules(**rules):
            logits, caches = model_decode_step(params, cfg, tokens, positions, caches,
                                               unroll_units=sc.analysis)
            if mesh_axes():
                caches = constrain_tree(caches, cache_pspecs(caches))
            return logits, caches

    return decode_step


# ------------------------------------------------------------ input specs ---


def input_specs(cfg: ModelConfig, shape: ShapeSpec, cache_dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape)
    cell — weak-type-correct, shardable, no device allocation.

    train   → {"batch": {tokens, labels, [patch/frame embeds]}}
    prefill → {"batch": …, "caches": zero-initialized cache tree}
    decode  → {"tokens", "positions", "caches"}
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    emb_dtype = jnp.bfloat16

    def batch_struct(seq_len):
        b = {"tokens": sds((B, seq_len), jnp.int32), "labels": sds((B, seq_len), jnp.int32)}
        if cfg.frontend == "vision":
            b["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model), emb_dtype)
            b["labels"] = sds((B, seq_len + cfg.n_patches), jnp.int32)
        elif cfg.frontend == "audio":
            b = {
                "frame_embeds": sds((B, seq_len, cfg.d_model), emb_dtype),
                "labels": sds((B, seq_len), jnp.int32),
            }
        return b

    if shape.kind == "train":
        return {"batch": batch_struct(S)}

    capacity = S + (cfg.n_patches if cfg.frontend == "vision" else 0)
    caches = jax.eval_shape(partial(init_caches, cfg, B, capacity, cache_dtype))
    if shape.kind == "prefill":
        b = batch_struct(S)
        b.pop("labels", None)  # prefill consumes no labels
        return {"batch": b, "caches": caches}
    return {
        "tokens": sds((B, 1), jnp.int32),
        "positions": sds((B, 1), jnp.int32),
        "caches": caches,
    }
