"""Sharding rules: logical axis names → mesh axes, plus param/optimizer/cache
PartitionSpec derivation for every architecture.

Logical activation axes: ("batch", "seq", "embed", "heads", "kv_heads",
"ffn", "experts", "vocab", "lru", "seq_kv").  Default mapping (single-pod
(data, model) mesh; multi-pod prepends "pod" onto the batch axis):

  batch   → ("pod","data")      ffn/heads/experts/vocab/lru → "model"
  embed   → None (replicated)   seq → None
  seq_kv  → "model"             (decode: flash-decode-style KV-sequence
                                 sharding — the SPMD partitioner turns the
                                 softmax max/sum and the PV einsum into the
                                 log-sum-exp merge all-reduces)

Step factories override rules per mode via `axis_rules(...)` (e.g. batch=1
long-context decode replicates batch and spreads seq_kv over data+model).

`constrain(x, *logical_axes)` inserts with_sharding_constraint when a mesh
context is active (jax.sharding.use_mesh / `with mesh:`), else no-op — model
code stays runnable on a single CPU device for smoke tests.

Parameter sharding is derived from leaf *path names* (wq/wk/wo/wi/...), with
optional FSDP: the first replicated dimension divisible by the data-axis size
is sharded over "data" (params+grads+optimizer state — ZeRO-3-style for the
working copy; the optimizer state reuses the same spec, which is what makes
it ZeRO and not mere TP).
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from .. import compat

# logical name → mesh axis (or tuple), for the canonical 2D/3D meshes
_DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_kv": "model",  # decode KV-sequence sharding (flash-decode analogue)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "vocab": "model",
    "lru": "model",
    "conv": None,
    "unit": None,  # scan/stack axis — never sharded
}

_rules_stack: list[dict] = [dict(_DEFAULT_RULES)]


@contextlib.contextmanager
def axis_rules(**overrides):
    """Temporarily override logical-axis rules (step factories use this to
    retarget `batch`/`seq_kv` per mode/shape)."""
    top = dict(_rules_stack[-1])
    top.update(overrides)
    _rules_stack.append(top)
    try:
        yield
    finally:
        _rules_stack.pop()


def current_rules() -> dict:
    return _rules_stack[-1]


def mesh_axes() -> dict[str, int]:
    m = compat.get_abstract_mesh()
    if m is None or not m.axis_names:
        return {}
    return {name: size for name, size in m.shape_tuple}


def _resolve(name: str, avail: dict[str, int], dim_size: int | None = None):
    """Map one logical name to a mesh-axis entry, dropping axes that are
    missing from the mesh or that do not divide `dim_size`."""
    rule = current_rules().get(name)
    if rule is None:
        return None
    if isinstance(rule, str):
        rule = (rule,)
    picked = []
    prod = 1
    for a in rule:
        if a not in avail:
            continue
        if dim_size is not None and dim_size % (prod * avail[a]):
            continue
        picked.append(a)
        prod *= avail[a]
    if not picked:
        return None
    return tuple(picked) if len(picked) > 1 else picked[0]


def spec_for(*logical, dim_sizes=None) -> P:
    """PartitionSpec for the current mesh (unknown logical names replicate;
    mesh axes not present are dropped; axes that don't divide the dim are
    dropped when dim_sizes is given). The same rules serve 1-device,
    single-pod and multi-pod meshes."""
    avail = mesh_axes()
    out = []
    for i, name in enumerate(logical):
        ds = dim_sizes[i] if dim_sizes is not None else None
        out.append(_resolve(name, avail, ds) if name else None)
    return P(*out)


def constrain_tree(tree, spec_tree):
    """with_sharding_constraint over matching pytrees (PartitionSpec is a
    pytree node, so plain tree_map would descend into it)."""
    flat, tdef = jax.tree_util.tree_flatten(tree)
    specs = tdef.flatten_up_to(spec_tree)
    return tdef.unflatten(
        [jax.lax.with_sharding_constraint(x, s) for x, s in zip(flat, specs)]
    )


def constrain(x, *logical):
    """with_sharding_constraint under an active mesh; identity otherwise."""
    if not mesh_axes():
        return x
    return jax.lax.with_sharding_constraint(x, spec_for(*logical, dim_sizes=x.shape[: len(logical)]))


# ------------------------------------------------------------------------
# parameter specs (path-name based)
# ------------------------------------------------------------------------

# leaf name → logical axes per dimension (excluding any leading stacked-unit
# axis, which is added automatically for leaves under "units").
_PARAM_AXES: dict[str, tuple[str | None, ...]] = {
    # embeddings / head
    "embed": ("vocab", "embed"),
    "head": ("embed", "vocab"),
    "final_ln": (None,),
    # attention
    "wq": ("embed", "heads", None),
    "wk": ("embed", "kv_heads", None),
    "wv": ("embed", "kv_heads", None),
    "wo_attn": ("heads", None, "embed"),
    "bq": ("heads", None),
    "bk": ("kv_heads", None),
    "bv": ("kv_heads", None),
    # dense mlp
    "wi": ("embed", "ffn"),
    "wg": ("embed", "ffn"),
    "wo_mlp": ("ffn", "embed"),
    # moe (leading experts dim)
    "router": ("embed", None),
    "wi_moe": ("experts", "embed", None),
    "wg_moe": ("experts", "embed", None),
    "wo_moe": ("experts", None, "embed"),
    # rg-lru temporal block
    "w_y": ("embed", "lru"),
    "w_x": ("embed", "lru"),
    "conv": (None, "lru"),
    "w_a": (None, "lru"),
    "w_i": (None, "lru"),
    "b_a": ("lru",),
    "b_i": ("lru",),
    "lam": ("lru",),
    "w_out": ("lru", "embed"),
    # xlstm (names from models/xlstm.py; d_in plays the "lru" role)
    "w_up": ("embed", "lru"),
    "w_gate": ("embed", "lru"),
    "w_down": ("lru", "embed"),
    "wq_rnn": ("lru", None, None),
    "wk_rnn": ("lru", None, None),
    "wv_rnn": ("lru", None, None),
    "w_if": ("lru", None, None),
    "ln": (None,),
}


def _leaf_logical(path) -> tuple[str, ...] | None:
    """Resolve the logical axes for a param leaf from its tree path."""
    names = [getattr(k, "key", getattr(k, "name", None)) or str(getattr(k, "idx", k)) for k in path]
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    if leaf == "wo":
        if parent == "attn":
            key = "wo_attn"
        elif parent == "moe":
            key = "wo_moe"
        else:
            key = "wo_mlp"  # mlp / shared
    elif leaf in ("wi", "wg") and parent == "moe":
        key = leaf + "_moe"
    elif leaf in ("wq", "wk", "wv") and parent != "attn":
        key = leaf + "_rnn"  # mLSTM q/k/v live on the up-projected width
    elif leaf.startswith("ln") or leaf.endswith("ln"):
        key = "final_ln"
    else:
        key = leaf
    return _PARAM_AXES.get(key)


def param_pspecs(params, *, fsdp: bool = False, fsdp_axis: str = "data"):
    """PartitionSpecs for a parameter pytree. Leaves under 'units' get a
    leading replicated (stack) dim. With fsdp=True, the first replicated dim
    divisible by the fsdp axis is sharded over it."""
    avail = mesh_axes()

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        logical = _leaf_logical(path)
        stacked = "units" in names
        shape = leaf.shape[1:] if stacked else leaf.shape
        if logical is None:
            logical = (None,) * len(shape)
        entries = [
            _resolve(ax, avail, shape[i]) if ax else None
            for i, ax in enumerate(logical[: len(shape)])
        ]
        entries += [None] * (len(shape) - len(entries))
        # Never FSDP the embedding/head: with tied embeddings the head is the
        # transpose, so a data-sharded d_model axis would make the CE einsum
        # contract over `data` — the partitioner then materializes and
        # all-reduces FULL-batch logits (measured: 40 GB/chip on qwen2-0.5b).
        # Vocab sharding already divides these tables 16-way.
        if fsdp and fsdp_axis in avail and "vocab" not in logical:
            n = avail[fsdp_axis]
            for i, e in enumerate(entries):
                if e is None and shape[i] % n == 0 and shape[i] >= 2 * n:
                    entries[i] = fsdp_axis
                    break
        if stacked:
            entries = [None] + entries
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_pspecs(param_specs, opt_state_proto):
    """Optimizer-state specs: step replicated; mu/nu/master shaped like params
    (ZeRO — they inherit the param specs, including the fsdp axis)."""
    from ..optim.adamw import OptState

    return OptState(
        step=P(),
        mu=param_specs,
        nu=param_specs,
        master=param_specs,
    )


# ------------------------------------------------------------------------
# cache / state specs
# ------------------------------------------------------------------------


def cache_pspecs(caches: Any):
    """Specs for decode caches/states by leaf name:
    k/v (B,C,KV,hd) → (batch, seq_kv, kv_heads?, None); pos (B,C);
    ptr (B,); recurrent h (B,W) → (batch, lru); conv tails (B,k,W);
    mlstm C/n/m per shape. Leaves under 'units' carry a leading stack dim."""
    avail = mesh_axes()

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        stacked = "units" in names
        shape = leaf.shape[1:] if stacked else leaf.shape
        leafname = names[-1]
        if leafname in ("k", "v"):
            entries = [
                _resolve("batch", avail, shape[0]),
                _resolve("seq_kv", avail, shape[1]),
                _resolve("kv_heads", avail, shape[2]),
                None,
            ]
            # never double-assign: if seq took 'model', kv_heads rule would
            # conflict — seq_kv and kv_heads share 'model'; prefer seq_kv.
            if entries[1] is not None:
                entries[2] = None
        elif leafname == "pos":
            entries = [_resolve("batch", avail, shape[0]), _resolve("seq_kv", avail, shape[1])]
        elif leafname == "ptr":
            entries = []  # scalar cursor — replicated
        elif leafname == "conv":
            entries = [_resolve("batch", avail, shape[0]), None, _resolve("lru", avail, shape[2])]
        elif leafname == "h" and len(shape) == 2:
            entries = [_resolve("batch", avail, shape[0]), _resolve("lru", avail, shape[1])]
        else:
            # mlstm C/n/m, slstm c/n/h/m: batch-sharded, rest replicated
            entries = [_resolve("batch", avail, shape[0])] + [None] * (len(shape) - 1)
        if stacked:
            entries = [None] + entries
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, caches)


def batch_pspecs(batch: Any):
    """Input-batch specs: leading dim is batch; everything else replicated,
    except trailing embedding dims of frontend stubs."""

    def one(path, leaf):
        entries = [_resolve("batch", mesh_axes(), leaf.shape[0])] + [None] * (leaf.ndim - 1)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, batch)
