"""Request tracing: event buffer, span builder, Perfetto export (PR 10).

The device emits a fixed-shape per-round event table inside the scanned
engine round (`serving.engine_state`); the host ``step()`` mirrors the
identical records.  Either way the drained stream is a flat list of
``(kind, uid, slot, arg)`` tuples stamped with the round's virtual clock.
This module turns that stream into something a human (or chrome://tracing)
can read:

* :class:`TraceBuffer` — a bounded event log fed by the scheduler and the
  router.  ``ingest_sample`` drains one telemetry sample's event list;
  ``add`` appends a single host-side (fabric) event.  Plain Python, no
  jax: attaching a buffer adds ZERO host syncs.
* :func:`build_spans` — per-request span trees keyed by uid.  A span
  survives migration (several ADMIT episodes on different replicas) and
  first-completion-wins dedupe (later duplicate terminals are counted,
  not double-built).
* :func:`to_perfetto` — Chrome-trace JSON (``traceEvents`` with ``ph:"X"``
  slices, pid = replica, tid = uid) loadable in chrome://tracing and
  ui.perfetto.dev.

Critical-path breakdown per request::

    queue      SUBMIT → first ADMIT  (minus any migration gap)
    prefill    ADMIT → last PREFILL_CHUNK of the episode
    park       Σ PARK → RESUME   (block-TWA wait inside prefill)
    decode     prefill end → terminal
    migration  Σ MIGRATE → re-ADMIT  (dead-replica requeue latency)

All times are on the engine's virtual clock, so breakdowns are exactly
reproducible and identical between the host loop and megastep paths.
"""

from __future__ import annotations

import json
from collections import deque
from typing import Any, Iterable, Optional

from ..serving.events import (EVENT_NAMES, EV_ADMIT, EV_EXPIRE, EV_FINISH,
                              EV_MIGRATE, EV_PARK, EV_PREEMPT,
                              EV_PREFILL_CHUNK, EV_RESUME, EV_SHED,
                              EV_SUBMIT, TERMINAL_EVENTS)

__all__ = ["TraceBuffer", "build_spans", "to_perfetto", "write_perfetto"]


class TraceBuffer:
    """Bounded append-only trace-event log.

    Events are dicts ``{kind, uid, slot, arg, clock, round, replica}``.
    ``capacity`` bounds memory; once full the OLDEST events are dropped
    (and counted in ``dropped``) — a flight-recorder-style tail window.
    Insertion order is preserved, which (with Python's stable sort) keeps
    same-clock events in emission order when streams are merged.
    """

    def __init__(self, capacity: int = 65536,
                 replica: Optional[int] = None):
        self.capacity = capacity
        self.replica = replica  # default replica tag (router sets this on
        #                         each engine's buffer for span stitching)
        self._events: deque[dict] = deque(maxlen=capacity)
        self.total = 0          # events ever added (incl. dropped)
        self._seq = 0           # global tie-break for cross-buffer merges

    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        return self.total - len(self._events)

    def add(self, kind: int, uid: int, slot: int, arg: int,
            clock: float, rnd: int, replica: Optional[int] = None) -> None:
        self._events.append({
            "kind": int(kind), "uid": int(uid), "slot": int(slot),
            "arg": int(arg), "clock": float(clock), "round": int(rnd),
            "replica": self.replica if replica is None else replica,
            "seq": self._seq,
        })
        self._seq += 1
        self.total += 1

    def ingest_sample(self, sample: dict,
                      replica: Optional[int] = None) -> None:
        """Drain one telemetry sample's event list (host or ring-drained)."""
        clock = float(sample.get("clock", 0.0))
        rnd = int(sample.get("round", 0))
        for kind, uid, slot, arg in sample.get("events", ()):
            self.add(kind, uid, slot, arg, clock, rnd, replica=replica)

    def events(self) -> list[dict]:
        return list(self._events)

    def summary(self, max_requests: int = 256) -> dict:
        """Compact report for ``telemetry()["trace"]``: counts, aggregate
        critical path, and per-request breakdowns (capped)."""
        spans = build_spans(self._events)
        agg = {"queue": 0.0, "prefill": 0.0, "park": 0.0, "decode": 0.0,
               "migration": 0.0}
        requests = {}
        complete = 0
        for uid, span in spans.items():
            if span["terminal"] is not None:
                complete += 1
            for k in agg:
                agg[k] += span["breakdown"][k]
            if len(requests) < max_requests:
                requests[uid] = {
                    "terminal": span["terminal"],
                    "breakdown": span["breakdown"],
                    "replicas": span["replicas"],
                    "migrations": span["migrations"],
                    "duplicates_suppressed": span["duplicates_suppressed"],
                }
        return {
            "events": len(self._events),
            "dropped": self.dropped,
            "spans": len(spans),
            "complete": complete,
            "critical_path": agg,
            "requests": requests,
        }


def _merged(sources: Iterable[Any]) -> list[dict]:
    """Flatten TraceBuffers / event lists into one clock-ordered stream.

    Stable sort on (clock, round): same-round events keep their emission
    order (the canonical segment order), which the span builder relies on
    for PARK/RESUME pairing within a round.
    """
    evs: list[dict] = []
    for src in sources:
        evs.extend(src.events() if isinstance(src, TraceBuffer) else src)
    evs.sort(key=lambda e: (e["clock"], e["round"]))
    return evs


def build_spans(*sources: Any) -> dict[int, dict]:
    """Assemble per-request span trees from one or more event streams.

    Accepts TraceBuffers and/or iterables of event dicts — pass the
    router's buffer plus every replica engine's buffer to stitch cluster
    spans.  Returns ``{uid: span}`` where each span is::

        {"uid", "start", "end", "terminal",          # name or None (open)
         "replicas": [...],                          # in visit order
         "migrations": n, "duplicates_suppressed": n,
         "segments": [{"name", "t0", "t1", "replica"}, ...],
         "breakdown": {"queue","prefill","park","decode",
                       "migration","total"},
         "events": [...]}                            # the raw records

    First-completion-wins: the FIRST terminal event (by clock) closes the
    span; later terminal records for the same uid — e.g. a duplicate
    FINISH from a zombie replica racing its migrated copy — increment
    ``duplicates_suppressed`` and change nothing else.
    """
    spans: dict[int, dict] = {}
    for ev in _merged(sources):
        uid = ev["uid"]
        if uid < 0:
            continue
        sp = spans.get(uid)
        if sp is None:
            sp = spans[uid] = {
                "uid": uid, "start": ev["clock"], "end": None,
                "terminal": None, "replicas": [], "migrations": 0,
                "duplicates_suppressed": 0, "segments": [], "events": [],
                # builder scratch (stripped below)
                "_admit": None, "_chunk_end": None, "_park": None,
                "_park_sum": 0.0, "_migrate": None, "_submit": None,
            }
        k = ev["kind"]
        if sp["terminal"] is not None:
            if k in TERMINAL_EVENTS:
                sp["duplicates_suppressed"] += 1
            continue
        sp["events"].append(ev)
        rep = ev.get("replica")
        if rep is not None and (not sp["replicas"]
                                or sp["replicas"][-1] != rep):
            sp["replicas"].append(rep)
        t = ev["clock"]
        if k == EV_SUBMIT and sp["_submit"] is None:
            sp["_submit"] = t
        elif k == EV_ADMIT:
            src = sp["_migrate"] if sp["_migrate"] is not None else \
                (sp["_submit"] if sp["_submit"] is not None else sp["start"])
            name = "migration" if sp["_migrate"] is not None else "queue"
            sp["segments"].append(
                {"name": name, "t0": src, "t1": t, "replica": rep})
            sp["_migrate"] = None
            sp["_admit"] = t
            sp["_chunk_end"] = t
        elif k == EV_PREFILL_CHUNK:
            sp["_chunk_end"] = t
        elif k == EV_PARK:
            sp["_park"] = t
        elif k == EV_RESUME:
            if sp["_park"] is not None:
                sp["segments"].append(
                    {"name": "park", "t0": sp["_park"], "t1": t,
                     "replica": rep})
                sp["_park_sum"] += t - sp["_park"]
                sp["_park"] = None
        elif k == EV_MIGRATE:
            sp["migrations"] += 1
            sp["_migrate"] = t
            sp["_admit"] = None        # episode on the dead replica is void
        elif k in TERMINAL_EVENTS:
            sp["terminal"] = EVENT_NAMES[k]
            sp["end"] = t
            if sp["_park"] is not None:       # parked at death
                sp["_park_sum"] += t - sp["_park"]
                sp["segments"].append(
                    {"name": "park", "t0": sp["_park"], "t1": t,
                     "replica": rep})
                sp["_park"] = None
            if sp["_admit"] is not None:
                ce = sp["_chunk_end"]
                if ce is not None and ce > sp["_admit"]:
                    sp["segments"].append(
                        {"name": "prefill", "t0": sp["_admit"], "t1": ce,
                         "replica": rep})
                sp["segments"].append(
                    {"name": "decode",
                     "t0": ce if ce is not None else sp["_admit"], "t1": t,
                     "replica": rep})
            elif k in (EV_SHED, EV_EXPIRE):
                src = sp["_submit"] if sp["_submit"] is not None \
                    else sp["start"]
                sp["segments"].append(
                    {"name": "queue", "t0": src, "t1": t, "replica": rep})

    for sp in spans.values():
        segs = sp["segments"]
        bd = {"queue": 0.0, "prefill": 0.0, "park": 0.0, "decode": 0.0,
              "migration": 0.0}
        for s in segs:
            bd[s["name"]] += s["t1"] - s["t0"]
        # park happens INSIDE the prefill/decode windows (block-gate parks
        # fire during chunked prefill) — deduct it from prefill first,
        # remainder from decode, so the categories tile the span without
        # double counting
        spill = bd["park"]
        take = min(spill, bd["prefill"])
        bd["prefill"] -= take
        bd["decode"] = max(0.0, bd["decode"] - (spill - take))
        end = sp["end"] if sp["end"] is not None else \
            (sp["events"][-1]["clock"] if sp["events"] else sp["start"])
        bd["total"] = end - sp["start"]
        sp["breakdown"] = bd
        for key in ("_admit", "_chunk_end", "_park", "_park_sum",
                    "_migrate", "_submit"):
            del sp[key]
    return spans


def to_perfetto(spans: dict[int, dict], *,
                time_scale: float = 1e6) -> dict:
    """Chrome-trace ("JSON Array"/"JSON Object") export of built spans.

    One ``ph:"X"`` complete slice per span segment; pid = replica index
    (0 when single-engine), tid = request uid.  ``time_scale`` converts
    virtual-clock units to microseconds (Perfetto's ``ts`` unit) — the
    default treats the virtual clock as seconds.  Instant (``ph:"i"``)
    markers flag terminals so preemptions stand out on the timeline.
    """
    out: list[dict] = []
    for uid, sp in sorted(spans.items()):
        pid0 = sp["replicas"][0] if sp["replicas"] else 0
        out.append({"name": "process_name", "ph": "M", "pid": pid0,
                    "args": {"name": f"replica {pid0}"}})
        out.append({"name": "thread_name", "ph": "M", "pid": pid0,
                    "tid": uid, "args": {"name": f"req {uid}"}})
        for seg in sp["segments"]:
            pid = seg["replica"] if seg["replica"] is not None else pid0
            out.append({
                "name": seg["name"], "cat": "request", "ph": "X",
                "ts": seg["t0"] * time_scale,
                "dur": max(0.0, (seg["t1"] - seg["t0"]) * time_scale),
                "pid": pid, "tid": uid,
                "args": {"uid": uid},
            })
        if sp["terminal"] is not None:
            pid = (sp["replicas"][-1] if sp["replicas"] else pid0)
            out.append({
                "name": sp["terminal"], "cat": "request", "ph": "i",
                "ts": sp["end"] * time_scale, "pid": pid, "tid": uid,
                "s": "t", "args": {"uid": uid},
            })
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_perfetto(path: str, spans: dict[int, dict], *,
                   time_scale: float = 1e6) -> str:
    """Serialize :func:`to_perfetto` output to ``path``; returns ``path``."""
    with open(path, "w") as f:
        json.dump(to_perfetto(spans, time_scale=time_scale), f)
    return path
