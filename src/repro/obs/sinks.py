"""Pluggable record sinks for the per-round telemetry stream.

A sink is anything with ``emit(record: dict)`` (and optionally
``close()``).  `EngineObs` fans every per-round sample — host ``step()``
mirror or megastep ring drain alike — out to its sinks; the engine itself
never knows where telemetry goes.
"""

from __future__ import annotations

import json
import sys
from typing import Callable, Optional


class JsonlSink:
    """Append records to a JSONL file, one JSON object per line — the
    interchange format everything downstream (pandas, jq, the bench
    harness) already reads.  Opens lazily on first emit, flushes per
    record (a crash loses at most the in-flight line)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None
        self.emitted = 0

    def emit(self, record: dict) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()
        self.emitted += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class StdoutSink:
    """Print records as JSON lines (default stdout) — the ``--trace``
    follow-along view."""

    def __init__(self, prefix: str = "", stream=None):
        self.prefix = prefix
        self._stream = stream
        self.emitted = 0

    def emit(self, record: dict) -> None:
        stream = self._stream if self._stream is not None else sys.stdout
        stream.write(self.prefix + json.dumps(record) + "\n")
        self.emitted += 1

    def close(self) -> None:  # streams are borrowed, never closed
        pass


class CallbackSink:
    """Hand each record to a callable — the escape hatch for tests and
    embedders (metrics pushers, live plots).  ``filter`` optionally drops
    records before the callback."""

    def __init__(self, fn: Callable[[dict], None],
                 filter: Optional[Callable[[dict], bool]] = None):
        self._fn = fn
        self._filter = filter
        self.emitted = 0

    def emit(self, record: dict) -> None:
        if self._filter is not None and not self._filter(record):
            return
        self._fn(record)
        self.emitted += 1

    def close(self) -> None:
        pass
