"""Rolling-median trace smoothing (à la HomebrewNLP's ``wandblog.py``).

Per-round engine gauges are noisy step functions — tokens/round jumps as
slots retire, kv_free sawtooths at every alloc/release.  A rolling MEDIAN
(not mean) keeps the smoothed trace on actually-observed values and is
robust to the single-round spikes that make mean-smoothed dashboards lie
(one preemption burst drags a mean for the whole window; the median
shrugs it off).
"""

from __future__ import annotations

import statistics
from collections import deque


class RollingMedian:
    """Median over a sliding window of the last ``window`` observations.

    ``push(x)`` returns the median INCLUDING ``x`` — a fresh tracker echoes
    its first value, so traces need no warm-up special-casing.  O(window)
    per push via ``statistics.median`` over a deque; windows here are
    dashboard-sized (≤ a few hundred), far below where a two-heap
    implementation would earn its complexity.
    """

    def __init__(self, window: int = 9):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = window
        self._buf: deque = deque(maxlen=window)

    def push(self, x: float) -> float:
        self._buf.append(x)
        return statistics.median(self._buf)

    @property
    def value(self) -> float:
        """Current median (nan before any push)."""
        return statistics.median(self._buf) if self._buf else float("nan")

    def reset(self) -> None:
        self._buf.clear()


class TraceSmoother:
    """Rolling medians over named fields of a record stream: feed per-round
    sample dicts, get back ``{field: median}`` for the selected fields —
    the smoothed companion trace `EngineObs` attaches to sink records."""

    def __init__(self, fields: tuple, window: int = 9):
        self._trackers = {f: RollingMedian(window) for f in fields}

    def push(self, record: dict) -> dict:
        return {f: t.push(record[f]) for f, t in self._trackers.items()
                if f in record}
