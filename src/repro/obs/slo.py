"""Per-tenant SLO tracking: TTFT / TPOT distributions and attainment.

Definitions (the serving-standard ones, on the engine's virtual clock):

* **TTFT** — time to first token: ``first_tok_clock − submit_clock``.
* **TPOT** — time per output token after the first:
  ``(last_tok_clock − first_tok_clock) / (n_tokens − 1)`` for ``n ≥ 2``
  (a one-token response has no inter-token gap and contributes no TPOT
  sample).
* **Attainment** — the fraction of RESOLVED requests that completed
  (deadline tombstones and mid-decode preemptions are misses by
  definition — the engine's own deadline IS the SLO) and, when targets
  are configured, met ``ttft ≤ ttft_target`` / ``tpot ≤ tpot_target``.

All stamps come off the injectable engine ``clock=`` (never wall time),
so reports are reproducible under virtual time and identical between the
host loop and megastep serving paths.
"""

from __future__ import annotations

import math
from typing import Optional

from .hist import LogHistogram


class TenantSLO:
    """Event accumulator for one tenant."""

    def __init__(self, ttft_target: Optional[float] = None,
                 tpot_target: Optional[float] = None,
                 resolution: float = 0.01):
        self.ttft_target = ttft_target
        self.tpot_target = tpot_target
        self.ttft = LogHistogram(resolution=resolution)
        self.tpot = LogHistogram(resolution=resolution)
        self.submitted = 0
        self.finished = 0
        self.expired = 0
        self.preempted = 0
        self.attained = 0
        self.tokens = 0

    def record(self, *, n_tokens: int, expired: bool, preempted: bool,
               submit_clock: Optional[float],
               first_tok_clock: Optional[float],
               last_tok_clock: Optional[float]) -> None:
        """One resolved request.  ``expired`` covers both backlog
        tombstones and preemptions (mirroring ``EngineStats``); clocks may
        be ``None`` when the request never reached that lifecycle point."""
        self.submitted += 1
        self.tokens += n_tokens
        ttft = tpot = None
        if submit_clock is not None and first_tok_clock is not None:
            ttft = first_tok_clock - submit_clock
            self.ttft.add(ttft)
        if (first_tok_clock is not None and last_tok_clock is not None
                and n_tokens >= 2):
            tpot = (last_tok_clock - first_tok_clock) / (n_tokens - 1)
            self.tpot.add(tpot)
        if preempted:
            self.preempted += 1
            self.expired += 1
        elif expired:
            self.expired += 1
        else:
            self.finished += 1
            ok = True
            if self.ttft_target is not None:
                ok = ok and ttft is not None and ttft <= self.ttft_target
            if self.tpot_target is not None and tpot is not None:
                ok = ok and tpot <= self.tpot_target
            if ok:
                self.attained += 1

    def merge(self, other: "TenantSLO") -> None:
        """Fold another accumulator in (cluster aggregation: the SAME
        tenant served by several replicas).  Targets must agree — a
        cluster-level attainment number is meaningless across different
        SLOs — and histogram resolutions are checked by
        :meth:`LogHistogram.merge`."""
        if (other.ttft_target != self.ttft_target
                or other.tpot_target != self.tpot_target):
            raise ValueError("can only merge TenantSLOs with identical "
                             "targets")
        self.ttft.merge(other.ttft)
        self.tpot.merge(other.tpot)
        self.submitted += other.submitted
        self.finished += other.finished
        self.expired += other.expired
        self.preempted += other.preempted
        self.attained += other.attained
        self.tokens += other.tokens

    @property
    def attainment(self) -> float:
        return self.attained / self.submitted if self.submitted \
            else math.nan

    def summary(self) -> dict:
        return {
            "submitted": self.submitted,
            "finished": self.finished,
            "expired": self.expired,
            "preempted": self.preempted,
            "tokens": self.tokens,
            "attainment": self.attainment,
            "ttft": self.ttft.percentiles(),
            "tpot": self.tpot.percentiles(),
        }
