"""Log-bucketed streaming histogram — p50/p99/p999 in O(1) memory.

Latency distributions in a serving engine are heavy-tailed: storing every
TTFT/TPOT event to sort later is unbounded, and a linear-bucket histogram
either wastes its range on the tail or loses the head.  The standard fix
(HdrHistogram and friends) is geometric buckets: bucket ``i`` covers
``[min_value·g^i, min_value·g^(i+1))`` with growth ``g = 1 + resolution``,
so EVERY quantile is recovered with bounded relative error ≤ ``resolution``
regardless of scale — the property the tests pin against a full-sample
``np.percentile`` oracle.
"""

from __future__ import annotations

import math


class LogHistogram:
    """Streaming histogram over geometric buckets.

    ``resolution`` bounds the relative error of any reported quantile
    (default 5%); ``min_value`` is the left edge of bucket 0 — smaller
    observations clamp into it (a sub-nanosecond latency is noise).
    Buckets are a sparse dict: memory is O(occupied buckets), ~hundreds
    for a 9-decade range at 5%.
    """

    def __init__(self, resolution: float = 0.05, min_value: float = 1e-9):
        if not 0 < resolution < 1:
            raise ValueError(f"resolution must be in (0, 1), got {resolution}")
        if not min_value > 0:
            raise ValueError(f"min_value must be > 0, got {min_value}")
        self.resolution = resolution
        self.min_value = min_value
        self._log_g = math.log1p(resolution)
        self._counts: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.max = -math.inf
        self.min = math.inf

    def _bucket(self, x: float) -> int:
        if x <= self.min_value:
            return 0
        return int(math.log(x / self.min_value) / self._log_g)

    def _edge(self, i: int) -> float:
        """Upper edge of bucket ``i`` — the conservative (≤ +resolution
        relative error) quantile estimate."""
        return self.min_value * math.exp((i + 1) * self._log_g)

    def add(self, x: float, n: int = 1) -> None:
        b = self._bucket(x)
        self._counts[b] = self._counts.get(b, 0) + n
        self.count += n
        self.sum += x * n
        self.max = max(self.max, x)
        self.min = min(self.min, x)

    def merge(self, other: "LogHistogram") -> None:
        if (other.resolution != self.resolution
                or other.min_value != self.min_value):
            raise ValueError("can only merge histograms with identical "
                             "bucketing")
        for b, n in other._counts.items():
            self._counts[b] = self._counts.get(b, 0) + n
        self.count += other.count
        self.sum += other.sum
        self.max = max(self.max, other.max)
        self.min = min(self.min, other.min)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` ∈ [0, 1], within ±resolution relative
        error (exact at the recorded extremes: q=0 → min, q=1 → max)."""
        if not 0 <= q <= 1:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return math.nan
        if q == 0:
            return self.min
        if q == 1:
            return self.max
        target = q * self.count
        acc = 0
        for b in sorted(self._counts):
            acc += self._counts[b]
            if acc >= target:
                # clamp into the observed range: the bucket EDGE can
                # overshoot the true maximum by up to +resolution
                return min(self._edge(b), self.max)
        return self.max

    def percentiles(self) -> dict:
        """The standard serving report: p50 / p99 / p999 (+ mean, count)."""
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.quantile(0.50),
            "p99": self.quantile(0.99),
            "p999": self.quantile(0.999),
            "max": self.max if self.count else math.nan,
        }
