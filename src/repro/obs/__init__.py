"""Host-side observability for the TWA serving engine (PR 6).

The device side of the observability story lives in
`serving.engine_state`: an in-scan :class:`TelemetryRing` appended to by
every scanned engine round and drained in the megastep's ONE host sync.
This package is the host side — everything downstream of the per-round
sample stream:

* :class:`LogHistogram` — log-bucketed streaming histograms for
  p50/p99/p999 quantiles in O(1) memory (latency-style heavy tails);
* :class:`RollingMedian` — rolling-median trace smoothing for noisy
  per-round gauges (à la HomebrewNLP's ``wandblog.py``);
* sinks — :class:`JsonlSink`, :class:`StdoutSink`, :class:`CallbackSink`:
  pluggable per-round record consumers;
* :class:`TenantSLO` / :class:`EngineObs` — per-tenant TTFT/TPOT event
  tracking keyed on the engine's virtual ``clock=`` and SLO-attainment
  reporting, consumed by ``scheduler.telemetry()`` (the ``slo`` key),
  ``benchmarks/serving_bench.run_slo``, and
  ``examples/serve_multitenant.py --trace``.

Everything here is plain Python/numpy — no jax imports, no device work:
attaching an ``EngineObs`` never adds a host sync to either serving path.
"""

from .hist import LogHistogram
from .recorder import EngineObs
from .sinks import CallbackSink, JsonlSink, StdoutSink
from .slo import TenantSLO
from .smooth import RollingMedian

__all__ = [
    "LogHistogram",
    "RollingMedian",
    "JsonlSink",
    "StdoutSink",
    "CallbackSink",
    "TenantSLO",
    "EngineObs",
]
