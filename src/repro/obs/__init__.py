"""Host-side observability for the TWA serving engine (PR 6).

The device side of the observability story lives in
`serving.engine_state`: an in-scan :class:`TelemetryRing` appended to by
every scanned engine round and drained in the megastep's ONE host sync.
This package is the host side — everything downstream of the per-round
sample stream:

* :class:`LogHistogram` — log-bucketed streaming histograms for
  p50/p99/p999 quantiles in O(1) memory (latency-style heavy tails);
* :class:`RollingMedian` — rolling-median trace smoothing for noisy
  per-round gauges (à la HomebrewNLP's ``wandblog.py``);
* sinks — :class:`JsonlSink`, :class:`StdoutSink`, :class:`CallbackSink`:
  pluggable per-round record consumers;
* :class:`TenantSLO` / :class:`EngineObs` — per-tenant TTFT/TPOT event
  tracking keyed on the engine's virtual ``clock=`` and SLO-attainment
  reporting, consumed by ``scheduler.telemetry()`` (the ``slo`` key),
  ``benchmarks/serving_bench.run_slo``, and
  ``examples/serve_multitenant.py --trace``;
* :class:`TraceBuffer` / :func:`build_spans` / :func:`to_perfetto`
  (PR 10) — per-request span trees from the in-scan event table, with
  Chrome-trace export and critical-path breakdowns;
* :class:`FlightRecorder` (PR 10) — bounded pre-crash window that cuts a
  post-mortem bundle on sentinel trips, recovery-ladder engagement, or a
  replica reap;
* :func:`aggregate` (PR 10) — cross-replica ``EngineObs`` reduction to
  fleet-level p50/p99/p999 TTFT/TPOT and per-replica health.

Everything here is plain Python/numpy — no jax imports, no device work:
attaching an ``EngineObs`` never adds a host sync to either serving path.
"""

from .cluster import aggregate, render_cluster_table
from .flight import FlightRecorder
from .hist import LogHistogram
from .recorder import EngineObs
from .sinks import CallbackSink, JsonlSink, StdoutSink
from .slo import TenantSLO
from .smooth import RollingMedian
from .trace import TraceBuffer, build_spans, to_perfetto, write_perfetto

__all__ = [
    "LogHistogram",
    "RollingMedian",
    "JsonlSink",
    "StdoutSink",
    "CallbackSink",
    "TenantSLO",
    "EngineObs",
    "TraceBuffer",
    "build_spans",
    "to_perfetto",
    "write_perfetto",
    "FlightRecorder",
    "aggregate",
    "render_cluster_table",
]
