"""Crash flight recorder: bounded pre-crash window + post-mortem bundles.

An aircraft flight recorder keeps the last N seconds of everything; when
something goes wrong you read the tape backwards.  Same idea here: a
:class:`FlightRecorder` rides an :class:`~repro.obs.recorder.EngineObs`
(``EngineObs(flight=...)``) keeping a bounded deque of recent round
samples, and on a trigger freezes a **bundle** — samples + recent trace
events + the decoded health bitmask — for post-mortem inspection.

Triggers (all host-side, zero extra syncs):

* a PR-7 sentinel bit newly trips (``observe_round`` sees health bits the
  previous round didn't have);
* the PR-7 recovery ladder engages (``ResilientEngine._react`` calls
  ``dump("recovery:<rung>")``);
* the PR-8 router reaps a replica (``ReplicaRouter._mark_dead`` calls
  ``dump("replica_reaped")`` on the dead replica's recorder).

Bundles are plain dicts (JSON-serializable); pass ``sink=JsonlSink(...)``
to persist them as they happen, or read ``recorder.bundles`` after a run.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Optional

__all__ = ["FlightRecorder"]


def _health_flags(mask: int) -> list[str]:
    """Decode a health bitmask to named flags via the single authoritative
    table in ``serving.sentinels`` (lazy: sentinels imports jax)."""
    if not mask:
        return []
    try:
        from ..serving.sentinels import decode_health
        return decode_health(mask)
    except Exception:  # pragma: no cover - jax-free envs
        return [f"bit{i}" for i in range(32) if mask >> i & 1]


class FlightRecorder:
    """Bounded window of recent rounds + triggered post-mortem bundles.

    ``capacity`` is the number of round samples retained; ``trace`` is an
    optional :class:`~repro.obs.trace.TraceBuffer` whose most recent
    ``trace_tail`` events are frozen into each bundle; ``max_bundles``
    caps memory under a flapping sentinel (oldest bundles are dropped);
    ``sink`` is an optional obs sink (``JsonlSink`` etc.) that receives
    each bundle as it is cut.
    """

    def __init__(self, capacity: int = 64, *, trace: Any = None,
                 trace_tail: int = 256, max_bundles: int = 16,
                 sink: Any = None):
        self.capacity = capacity
        self.trace = trace
        self.trace_tail = trace_tail
        self._samples: deque[dict] = deque(maxlen=capacity)
        self._bundles: deque[dict] = deque(maxlen=max_bundles)
        self._sink = sink
        self._last_mask = 0
        self.rounds = 0

    # ------------------------------------------------------------ feed ---

    def observe_round(self, sample: dict) -> None:
        """Append one round sample; auto-dump when a NEW sentinel bit
        appears (edge-triggered — a persistently sick engine cuts one
        bundle per distinct symptom, not one per round)."""
        self._samples.append(sample)
        self.rounds += 1
        mask = int(sample.get("health", 0))
        fresh = mask & ~self._last_mask
        self._last_mask = mask
        if fresh:
            self.dump("sentinel", extra={
                "new_bits": fresh, "new_flags": _health_flags(fresh)})

    # ------------------------------------------------------------ dump ---

    def dump(self, reason: str, extra: Optional[dict] = None) -> dict:
        """Cut a post-mortem bundle NOW and return it."""
        last = self._samples[-1] if self._samples else {}
        mask = int(last.get("health", 0))
        bundle = {
            "reason": reason,
            "round": int(last.get("round", -1)),
            "clock": float(last.get("clock", 0.0)),
            "health": {"mask": mask, "flags": _health_flags(mask)},
            "samples": [dict(s) for s in self._samples],
            "events": [],
            "extra": dict(extra or {}),
        }
        if self.trace is not None:
            evs = self.trace.events()
            bundle["events"] = evs[-self.trace_tail:]
        self._bundles.append(bundle)
        if self._sink is not None:
            try:
                self._sink({"flight_bundle": {k: v for k, v in
                                              bundle.items()
                                              if k != "samples"},
                            "reason": reason})
            except Exception:  # pragma: no cover - sink failures are
                pass           # never allowed to take down the engine
        return bundle

    # ---------------------------------------------------------- report ---

    @property
    def bundles(self) -> list[dict]:
        return list(self._bundles)

    def summary(self) -> dict:
        return {
            "rounds": self.rounds,
            "window": len(self._samples),
            "bundles": len(self._bundles),
            "reasons": [b["reason"] for b in self._bundles],
            "health": {"mask": self._last_mask,
                       "flags": _health_flags(self._last_mask)},
        }
