"""Fleet SLO aggregation: reduce per-replica `EngineObs` to cluster view.

A tenant spread across replicas by the PR-8 router has no single
`TenantSLO` — each replica accumulated its own histograms.  Because
:class:`~repro.obs.hist.LogHistogram` buckets are position-independent
counts, merging is exact bucket-wise addition (`LogHistogram.merge`), so
cluster p50/p99/p999 are identical to what a single engine observing the
combined event stream would report (within the same ±resolution bound —
property-tested in tests/test_obs.py).

:func:`aggregate` is the one entry point: give it the per-replica
``EngineObs`` objects (plus optional router telemetry for lease-headroom
and migration-latency sections) and get the fleet report consumed by
``benchmarks/serving_bench.run_cluster`` and
``examples/serve_multitenant.py --cluster``.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

from .hist import LogHistogram
from .slo import TenantSLO

__all__ = ["aggregate", "render_cluster_table"]


def _merge_into(dst: dict[str, TenantSLO], src: dict[str, TenantSLO],
                resolution: float) -> None:
    for t, slo in src.items():
        mine = dst.get(t)
        if mine is None:
            mine = dst[t] = TenantSLO(ttft_target=slo.ttft_target,
                                      tpot_target=slo.tpot_target,
                                      resolution=resolution)
        mine.merge(slo)


def aggregate(replicas: Sequence, *, router: Optional[dict] = None,
              names: Optional[Sequence[str]] = None) -> dict:
    """Reduce per-replica ``EngineObs`` into one fleet report.

    ``replicas``: EngineObs instances (dead replicas' recorders included —
    their requests still count).  ``router``: optionally the
    ``ReplicaRouter.telemetry()`` dict; contributes lease-headroom,
    migration, and shed sections.  ``names``: display names per replica
    (defaults to indices).

    Returns::

        {"replicas": n,
         "per_replica": [{"name", "rounds", "health", "finished",
                          "tokens"}, ...],
         "health": {"mask", "flags", "sick_rounds"},     # fleet OR / sum
         "tenants": {t: TenantSLO summary over ALL replicas},
         "cluster": {"ttft": {...}, "tpot": {...},       # fleet-wide
                     "submitted", "finished", "expired", "preempted",
                     "tokens", "attainment"},
         "fabric": {...}}                                # router sections
    """
    resolution = (replicas[0]._resolution if replicas else 0.01)
    tenants: dict[str, TenantSLO] = {}
    fleet_mask = 0
    sick = 0
    per_replica = []
    for i, obs in enumerate(replicas):
        _merge_into(tenants, obs.tenants, resolution)
        fleet_mask |= obs.health_mask
        sick += obs.sick_rounds
        per_replica.append({
            "name": (names[i] if names is not None else str(i)),
            "rounds": obs.rounds,
            "health": obs.health_mask,
            "finished": sum(s.finished for s in obs.tenants.values()),
            "tokens": sum(s.tokens for s in obs.tenants.values()),
        })

    # fleet-wide latency: one more exact bucket-wise reduce across tenants
    ttft = LogHistogram(resolution=resolution)
    tpot = LogHistogram(resolution=resolution)
    tot = {"submitted": 0, "finished": 0, "expired": 0, "preempted": 0,
           "tokens": 0, "attained": 0}
    for slo in tenants.values():
        ttft.merge(slo.ttft)
        tpot.merge(slo.tpot)
        tot["submitted"] += slo.submitted
        tot["finished"] += slo.finished
        tot["expired"] += slo.expired
        tot["preempted"] += slo.preempted
        tot["tokens"] += slo.tokens
        tot["attained"] += slo.attained

    try:
        from ..serving.sentinels import decode_health
        flags = decode_health(fleet_mask)
    except Exception:  # pragma: no cover - jax-free envs
        flags = [f"bit{i}" for i in range(32) if fleet_mask >> i & 1]

    out = {
        "replicas": len(replicas),
        "per_replica": per_replica,
        "health": {"mask": fleet_mask, "flags": flags,
                   "sick_rounds": sick},
        "tenants": {t: s.summary() for t, s in sorted(tenants.items())},
        "cluster": {
            "ttft": ttft.percentiles(),
            "tpot": tpot.percentiles(),
            "submitted": tot["submitted"],
            "finished": tot["finished"],
            "expired": tot["expired"],
            "preempted": tot["preempted"],
            "tokens": tot["tokens"],
            "attainment": (tot["attained"] / tot["submitted"]
                           if tot["submitted"] else math.nan),
        },
    }

    if router is not None:
        leases = router.get("leases", {})
        out["fabric"] = {
            # lease headroom: how close each replica ran to its cap
            "lease_headroom": {
                str(k): v for k, v in sorted(leases.items())
            } if isinstance(leases, dict) else leases,
            "migrations": router.get("migrations", 0),
            "migration_latency": router.get("migration_latency", {}),
            "shed": router.get("shed", 0),
            "deaths": router.get("deaths", 0),
            "duplicates_suppressed": router.get("duplicates_suppressed", 0),
        }
    return out


def render_cluster_table(report: dict) -> str:
    """Fixed-width fleet view: per-replica rows + cluster tail latencies."""
    def fmt(x) -> str:
        return "-" if x is None or (isinstance(x, float) and math.isnan(x)) \
            else (f"{x:.3f}" if isinstance(x, float) else str(x))

    hdr = (f"{'replica':<10} {'rounds':>7} {'done':>6} {'tokens':>8} "
           f"{'health':>18}")
    lines = [hdr, "-" * len(hdr)]
    for row in report["per_replica"]:
        h = row["health"]
        lines.append(f"{row['name']:<10} {row['rounds']:>7} "
                     f"{row['finished']:>6} {row['tokens']:>8} "
                     f"{('0x%x' % h) if h else 'ok':>18}")
    c = report["cluster"]
    lines.append(f"cluster: submitted={c['submitted']} "
                 f"finished={c['finished']} expired={c['expired']} "
                 f"preempted={c['preempted']} "
                 f"attainment={fmt(c['attainment'])}")
    lines.append(f"  ttft p50={fmt(c['ttft']['p50'])} "
                 f"p99={fmt(c['ttft']['p99'])} "
                 f"p999={fmt(c['ttft']['p999'])}")
    lines.append(f"  tpot p50={fmt(c['tpot']['p50'])} "
                 f"p99={fmt(c['tpot']['p99'])} "
                 f"p999={fmt(c['tpot']['p999'])}")
    if report["health"]["mask"]:
        lines.append("health: "
                     + ",".join(report["health"]["flags"])
                     + f" (0x{report['health']['mask']:x})")
    fab = report.get("fabric")
    if fab:
        lines.append(f"fabric: migrations={fab['migrations']} "
                     f"shed={fab['shed']} deaths={fab['deaths']} "
                     f"dup_suppressed={fab['duplicates_suppressed']}")
    return "\n".join(lines)
