"""`EngineObs` — the object the engine's ``obs=`` parameter accepts.

Glues the pieces together: per-round samples (host mirror or megastep
ring drain — identical records either way) fan out to the sinks with an
optional rolling-median companion trace; resolved requests feed the
per-tenant :class:`TenantSLO` accumulators; ``summary()`` is what
``scheduler.telemetry()`` exposes under the ``slo`` key and
``render_table()`` is the human view ``--trace`` prints at exit.
"""

from __future__ import annotations

import math
from typing import Optional

from .flight import _health_flags
from .slo import TenantSLO
from .smooth import TraceSmoother

# per-round gauges worth a smoothed companion trace (noisy sawtooths)
_SMOOTH_FIELDS = ("tokens", "active", "kv_free", "prefill_tokens",
                  "blocks_shared")


class EngineObs:
    """Observability layer for `ContinuousBatchingEngine`.

    ``sinks``: iterable of objects with ``emit(record)`` (see
    `repro.obs.sinks`).  ``ttft_target``/``tpot_target``: optional SLO
    targets in clock units, applied to every tenant.  ``smooth_window``:
    when > 1, each sink record carries a ``"smoothed"`` sub-dict of
    rolling medians over the noisy per-round gauges.

    Duck-typed against the engine: `record_round` takes the per-round
    sample dict, `record_request` the resolved ``Request`` (reads its
    lifecycle clock stamps) — no scheduler import, no jax, no device work.
    """

    def __init__(self, sinks=(), *, ttft_target: Optional[float] = None,
                 tpot_target: Optional[float] = None,
                 smooth_window: int = 1, resolution: float = 0.01,
                 flight=None):
        self.sinks = list(sinks)
        self.flight = flight        # optional obs.flight.FlightRecorder
        self.ttft_target = ttft_target
        self.tpot_target = tpot_target
        self._resolution = resolution
        self.tenants: dict[str, TenantSLO] = {}
        self.rounds = 0
        self.health_mask = 0        # OR of every round's sentinel bitmask
        self.sick_rounds = 0        # rounds with any sentinel bit set
        self.tenant_retries: dict[str, int] = {}  # recovery requeues seen
        self.prefix_hits = 0        # zero-prefill cached-prefix admissions
        self.cow_copies = 0         # copy-on-write takes of shared tails
        self.blocks_shared_peak = 0  # max blocks referenced by >1 table
        self._smoother = (TraceSmoother(_SMOOTH_FIELDS, smooth_window)
                          if smooth_window > 1 else None)

    # ------------------------------------------------------- engine feed ----

    def record_round(self, sample: dict) -> None:
        self.rounds += 1
        h = int(sample.get("health", 0))
        if h:
            self.health_mask |= h
            self.sick_rounds += 1
        self.prefix_hits += int(sample.get("prefix_hits", 0))
        self.cow_copies += int(sample.get("cow_copies", 0))
        self.blocks_shared_peak = max(self.blocks_shared_peak,
                                      int(sample.get("blocks_shared", 0)))
        if self.flight is not None:
            self.flight.observe_round(sample)
        record = sample
        if self._smoother is not None or h:
            record = dict(sample)
            if self._smoother is not None:
                record["smoothed"] = self._smoother.push(sample)
            if h:
                # named flags next to the raw mask wherever it surfaces
                record["health_flags"] = _health_flags(h)
        for sink in self.sinks:
            sink.emit(record)

    def record_request(self, req) -> None:
        """A resolved request (finished / tombstoned / preempted)."""
        t = getattr(req, "tenant_id", "default")
        retries = int(getattr(req, "retries", 0))
        if retries:
            self.tenant_retries[t] = self.tenant_retries.get(t, 0) + retries
        slo = self.tenants.get(t)
        if slo is None:
            slo = self.tenants[t] = TenantSLO(
                ttft_target=self.ttft_target, tpot_target=self.tpot_target,
                resolution=self._resolution)
        slo.record(
            n_tokens=len(getattr(req, "out_tokens", ())),
            expired=bool(getattr(req, "expired", False)),
            preempted=bool(getattr(req, "preempted", False)),
            submit_clock=getattr(req, "submit_clock", None),
            first_tok_clock=getattr(req, "first_tok_clock", None),
            last_tok_clock=getattr(req, "last_tok_clock", None))

    # ---------------------------------------------------------- reporting ---

    def summary(self) -> dict:
        return {
            "rounds": self.rounds,
            "health": {"mask": self.health_mask,
                       "flags": _health_flags(self.health_mask),
                       "sick_rounds": self.sick_rounds},
            "retries": dict(sorted(self.tenant_retries.items())),
            "prefix": {"hits": self.prefix_hits,
                       "cow_copies": self.cow_copies,
                       "blocks_shared_peak": self.blocks_shared_peak},
            "tenants": {t: s.summary() for t, s in sorted(self.tenants.items())},
        }

    def render_table(self, recovery: Optional[dict] = None) -> str:
        """Fixed-width per-tenant SLO table (the ``--trace`` exit view).
        ``recovery``: the engine's ``telemetry()["recovery"]`` counters —
        rendered as a footer with the accumulated health bitmask, so one
        glance shows WHICH tenants paid for WHICH faults."""
        hdr = (f"{'tenant':<10} {'done':>5} {'exp':>4} {'pre':>4} "
               f"{'rty':>4} {'attain':>7} {'ttft p50':>9} {'ttft p99':>9} "
               f"{'tpot p50':>9} {'tpot p99':>9}")
        lines = [hdr, "-" * len(hdr)]

        def fmt(x: float) -> str:
            return "-" if x is None or math.isnan(x) else f"{x:.3f}"

        for t, s in sorted(self.tenants.items()):
            r = s.summary()
            lines.append(
                f"{t:<10} {r['finished']:>5} {r['expired']:>4} "
                f"{r['preempted']:>4} {self.tenant_retries.get(t, 0):>4} "
                f"{fmt(r['attainment']):>7} "
                f"{fmt(r['ttft']['p50']):>9} {fmt(r['ttft']['p99']):>9} "
                f"{fmt(r['tpot']['p50']):>9} {fmt(r['tpot']['p99']):>9}")
        if self.health_mask:
            try:
                from ..serving.sentinels import decode_health
                names = ",".join(decode_health(self.health_mask))
            except Exception:
                names = hex(self.health_mask)
            lines.append(f"health: 0x{self.health_mask:x} ({names}) over "
                         f"{self.sick_rounds}/{self.rounds} rounds")
        if self.prefix_hits or self.cow_copies or self.blocks_shared_peak:
            lines.append(f"prefix: hits={self.prefix_hits} "
                         f"cow={self.cow_copies} "
                         f"shared_peak={self.blocks_shared_peak}")
        if recovery:
            lines.append("recovery: " + " ".join(
                f"{k}={v}" for k, v in sorted(recovery.items()) if v))
        return "\n".join(lines)

    def close(self) -> None:
        for sink in self.sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()
