"""Cluster control plane (L3 of the paper's adaptation).

At thousands of hosts, the coordination hot-spots are the distributed
analogue of the paper's contended `Grant` word: a checkpoint-write token, a
barrier generation counter, an elastic membership epoch.  We structure every
one of them as a (ticket, grant) pair on the coordinator KV store and have
hosts wait on *hashed bucket keys* instead of the grant key:

  * polling hosts disperse across buckets (no thundering-herd reads of one
    key — the KV-store equivalent of coherence storms);
  * the releaser pokes exactly the successor's bucket (plus the benaphore
    fast-path skip when nobody can be waiting);
  * `ticket − grant` per resource is the built-in queue-depth telemetry that
    feeds straggler detection.

The KV store here is in-process (this box is single-host); the interface is
the same one an etcd/redis deployment would implement — tests simulate many
hosts as threads against it, which exercises every code path except network
latency.

Fault-tolerance machinery:
  * heartbeats with configurable timeout → failure detection;
  * barrier with failure awareness (dead hosts are excluded from the count
    rather than hanging the barrier);
  * straggler detection from per-step duration EWMA + semaphore queue depth;
  * elastic epochs: join/leave bumps the membership epoch; the training
    driver re-builds its mesh and re-shards from the last checkpoint
    (runtime/elastic.py).
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

from ..core.atomics import AtomicU64
from ..core.hashfn import index_for, twa_hash
from ..core.twa_semaphore import TWASemaphore


class KVStore:
    """In-process stand-in for the coordinator store (etcd-like watch API)."""

    def __init__(self):
        self._data: dict[str, int] = {}
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)

    def incr(self, key: str, by: int = 1) -> int:
        with self._cond:
            old = self._data.get(key, 0)
            self._data[key] = old + by
            self._cond.notify_all()
            return old

    def get(self, key: str) -> int:
        with self._lock:
            return self._data.get(key, 0)

    def keys(self, prefix: str = "") -> list[str]:
        """Range read (etcd prefix get) — the reaper's scan primitive."""
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def delete(self, key: str) -> None:
        with self._cond:
            self._data.pop(key, None)
            self._cond.notify_all()

    def wait_change(self, key: str, observed: int, timeout: float = 5.0) -> int:
        with self._cond:
            deadline = time.time() + timeout
            while self._data.get(key, 0) == observed:
                left = deadline - time.time()
                if left <= 0:
                    break
                self._cond.wait(left)
            return self._data.get(key, 0)

    def txn(self, fn):
        """Atomic read-modify-write over the store — the etcd ``Txn``
        analogue.  The tombstone protocol needs it: cancel's (grant check,
        mark-dead) and release's (advance, dead-check) must not interleave,
        or a slot could be granted to a dead ticket AND reported cancelled."""
        with self._cond:
            out = fn(self._data)
            self._cond.notify_all()
            return out


class DistributedTicketLease:
    """Ticket/grant resource on the KV store with TWA bucket waiting.

    acquire(): take a ticket; wait until grant reaches it — polling ONLY our
    hashed bucket key (kv:`bucket/<i>`), which the releaser pokes.
    release(): advance grant, poke the successor's bucket (benaphore skip
    when the distance shows no waiters).

    Cancellable waits (the tombstone protocol, distributed): a waiter that
    gives up marks its ticket dead (`<name>/dead/<ticket>`); release()
    skips dead tickets when advancing grant, so a dying host that leaked a
    ticket can never wedge the cluster grant sequence — the slot flows to
    the next *live* ticket and FCFS among live hosts is preserved.  On
    timeout, acquire() tombstones its own ticket; if the tombstone loses
    the race (grant arrived first) the lease is held and returned instead
    of raising.

    Wait discipline: re-checks use **jittered exponential backoff**
    (``backoff_base·2^attempt``, capped at ``backoff_cap``, scaled by a
    seeded uniform jitter in [0.5, 1.5)) instead of a fixed poll period —
    an observed grant advance resets the backoff, so near-head waiters
    stay snappy while a stalled far queue decays to the cap and the store
    sees O(hosts / cap) re-reads per second instead of a synchronized
    herd.  While waiting (and on acquisition) the ticket renews a
    **lease heartbeat** key (``<name>/hb/<ticket>``, epoch-ms) every
    ``heartbeat_interval`` seconds; holders keep renewing via
    :meth:`renew`, and :meth:`heartbeat_age` lets a reaper decide a
    holder is dead and :meth:`cancel` its ticket.  Per-lease retry
    counters are surfaced by :meth:`wait_telemetry`.
    """

    BUCKETS = 64

    def __init__(self, kv: KVStore, name: str, capacity: int = 1,
                 long_term_threshold: int = 1, backoff_base: float = 0.005,
                 backoff_cap: float = 0.25, backoff_seed: int | None = None,
                 heartbeat_interval: float = 0.5, clock=time.time):
        self.kv = kv
        self.name = name
        self.capacity = int(capacity)
        self.threshold = long_term_threshold
        # heartbeat TIME SOURCE only (stamps + ages): injectable so reaper
        # TTL logic is testable under a virtual clock.  The blocking waits
        # in acquire() stay on wall time — they gate real threads.
        self._clock = clock
        self._salt = index_for(hash(name), 1 << 31)
        self.dead_skipped = 0  # grant advances that bypassed a tombstone
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.heartbeat_interval = float(heartbeat_interval)
        # seeded jitter: deterministic tests, decorrelated hosts (the
        # default seed differs per lease name / process)
        self._jitter = random.Random(
            backoff_seed if backoff_seed is not None else hash((name, id(self))))
        self.retry_counts = {
            "acquires": 0,    # acquire() calls
            "near": 0,        # short waits on the grant key (head of queue)
            "far": 0,         # backoff waits on the hashed bucket key
            "timeouts": 0,    # acquires that gave up (tombstoned)
            "heartbeats": 0,  # lease-heartbeat renewals written
        }
        if kv.incr(f"{name}/init", 0) == 0 and kv.incr(f"{name}/init") == 0:
            kv.incr(f"{name}/grant", capacity)

    def _bucket_key(self, ticket: int) -> str:
        return f"{self.name}/bucket/{index_for(twa_hash(self._salt, ticket), self.BUCKETS)}"

    def cancel(self, ticket: int) -> bool:
        """Tombstone ``ticket``.  True: dead, will be skipped by release().
        False: grant already covers it — the caller holds the lease and
        must release() it.  Runs as one KV transaction (etcd Txn)."""
        gk, dk = f"{self.name}/grant", f"{self.name}/dead/{ticket}"

        def do(d):
            if d.get(gk, 0) - ticket > 0:
                return False
            d[dk] = 1
            d.pop(f"{self.name}/hb/{ticket}", None)  # tombstoned ≠ leaked
            return True

        return self.kv.txn(do)

    def _renew_heartbeat(self, ticket: int) -> None:
        key = f"{self.name}/hb/{ticket}"
        # +1 so a stamp at virtual t=0 is distinguishable from "never"
        now_ms = int(self._clock() * 1000) + 1
        self.kv.txn(lambda d: d.__setitem__(key, now_ms))
        self.retry_counts["heartbeats"] += 1

    def renew(self, ticket: int) -> None:
        """Holder-side lease-heartbeat renewal — call periodically while
        holding the lease so reapers can tell held from leaked."""
        self._renew_heartbeat(ticket)

    def heartbeat_age(self, ticket: int) -> float | None:
        """Seconds since ``ticket`` last renewed its heartbeat (None if it
        never has).  A reaper that sees an age past its TTL can
        :meth:`cancel` the ticket to unwedge the grant sequence."""
        ms = self.kv.get(f"{self.name}/hb/{ticket}")
        return None if ms == 0 else max(0.0, self._clock() - (ms - 1) / 1000.0)

    def outstanding(self) -> list[int]:
        """Tickets with a live heartbeat key — the reaper's scan set
        (release/reap delete the key; a vanished holder leaves it stale)."""
        pre = f"{self.name}/hb/"
        return sorted(int(k[len(pre):]) for k in self.kv.keys(pre))

    def wait_telemetry(self) -> dict:
        """Retry/heartbeat counters (cumulative, this process's view)."""
        return dict(self.retry_counts, queue_depth=self.queue_depth())

    # ---- non-blocking admission (router path) ---------------------------
    #
    # A request router cannot park an OS thread per queued request; it
    # takes the ticket up front (FCFS position now) and polls `granted`
    # from its control loop — the queued requests ARE the lease's TWA
    # waiting array, and `headroom()` (grant − ticket) is the routing
    # signal.

    def try_acquire(self) -> int | None:
        """Benaphore fast path as one KV txn: take a ticket only when the
        grant already covers it (immediate admission).  None = full."""
        tk, gk = f"{self.name}/ticket", f"{self.name}/grant"

        def do(d):
            nxt = d.get(tk, 0)
            if d.get(gk, 0) - nxt > 0:
                d[tk] = nxt + 1
                return nxt
            return None

        t = self.kv.txn(do)
        if t is not None:
            self.retry_counts["acquires"] += 1
            self._renew_heartbeat(t)
        return t

    def take_ticket(self) -> int:
        """Unconditional ticket take — queue admission without blocking.
        The caller polls :meth:`granted` (ideally gated on its TWA bucket
        key) and MUST keep renewing the heartbeat while queued, or a
        reaper will tombstone the position."""
        t = self.kv.incr(f"{self.name}/ticket")
        self.retry_counts["acquires"] += 1
        self._renew_heartbeat(t)
        return t

    def granted(self, ticket: int) -> bool:
        return self.kv.get(f"{self.name}/grant") - ticket > 0

    def headroom(self) -> int:
        """grant − ticket: free units when positive, waiters when negative
        — the per-replica routing signal (capacity − in-flight − queued)."""
        return (self.kv.get(f"{self.name}/grant")
                - self.kv.get(f"{self.name}/ticket"))

    def bucket_state(self, ticket: int) -> tuple[str, int]:
        """(bucket key, current sequence) for a queued ticket — lets a
        polling router re-check `granted` only when the bucket was poked
        (the waiting-array read-dispersal discipline, clusterized)."""
        k = self._bucket_key(ticket)
        return k, self.kv.get(k)

    def acquire(self, timeout: float = 30.0) -> int:
        ticket = self.kv.incr(f"{self.name}/ticket")
        deadline = time.time() + timeout
        bucket = self._bucket_key(ticket)
        observed = self.kv.get(bucket)
        self.retry_counts["acquires"] += 1
        attempt = 0
        last_grant = None
        next_hb = 0.0  # first loop pass writes the heartbeat immediately
        while True:
            grant = self.kv.get(f"{self.name}/grant")
            if grant - ticket > 0:
                self._renew_heartbeat(ticket)  # holder baseline
                return ticket
            now = time.time()
            if now > deadline:
                if self.cancel(ticket):
                    self.retry_counts["timeouts"] += 1
                    raise TimeoutError(
                        f"lease {self.name}: ticket {ticket} vs grant {grant} "
                        "(ticket tombstoned — grant sequence not wedged)")
                return ticket  # lost race: the lease arrived at expiry
            if now >= next_hb:
                # waiting is also alive: renew the lease heartbeat so a
                # reaper never tombstones a slow-but-live waiter
                self._renew_heartbeat(ticket)
                next_hb = now + self.heartbeat_interval
            if grant != last_grant:
                attempt = 0  # observed progress → re-arm fast polling
            last_grant = grant
            # jittered exponential backoff, clipped to the deadline and
            # the next heartbeat due time
            wait = min(self.backoff_cap,
                       self.backoff_base * (1 << min(attempt, 16)))
            wait *= 0.5 + self._jitter.random()
            wait = max(1e-4, min(wait, deadline - now, next_hb - now + 1e-3))
            attempt += 1
            if grant + self.threshold - ticket > 0:
                # near the head: short-term wait directly on grant
                self.retry_counts["near"] += 1
                self.kv.wait_change(f"{self.name}/grant", grant, timeout=wait)
            else:
                # far: semi-local wait on our hashed bucket
                self.retry_counts["far"] += 1
                observed = self.kv.wait_change(bucket, observed, timeout=wait)

    def release(self, ticket: int | None = None) -> None:
        """Advance grant by one unit (skip-aware over tombstones) and poke
        the successor buckets.  When the releasing ``ticket`` is known its
        heartbeat key is deleted — a released ticket must never look like
        a leak to the reaper."""
        if ticket is not None:
            self.kv.delete(f"{self.name}/hb/{ticket}")
        gk = f"{self.name}/grant"

        def advance(d):
            """Skip-aware grant: keep advancing while the enabled ticket is
            tombstoned (one unit may hop several dead tickets)."""
            skipped = 0
            while True:
                enabled = d.get(gk, 0)
                d[gk] = enabled + 1
                if d.pop(f"{self.name}/dead/{enabled}", None) is None:
                    return enabled + 1, skipped
                skipped += 1

        grant, skipped = self.kv.txn(advance)
        self.dead_skipped += skipped
        ticket = self.kv.get(f"{self.name}/ticket")
        # Poke every bucket staged by this advance (the skip may have moved
        # grant several steps; each step has its own successor's successor).
        for v in range(grant - skipped, grant + 1):
            g = v + self.threshold
            if g - ticket >= 0:
                break  # benaphore fast path: nobody long-term waiting past g
            self.kv.incr(self._bucket_key(g))

    def queue_depth(self) -> int:
        return max(0, self.kv.get(f"{self.name}/ticket") - self.kv.get(f"{self.name}/grant"))


# ------------------------------------------------------------ coordinator ---


@dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step: int = 0
    step_ewma_s: float = 0.0
    alive: bool = True


@dataclass
class Coordinator:
    """Failure detection + barriers + straggler accounting + elastic epochs.

    ``clock`` is the failure-detection time source (heartbeat stamps, the
    heartbeat-timeout comparison, the barrier deadline) — injectable so
    dead/rejoining-host scenarios run deterministically under a virtual
    clock while worker threads still block on the KV store's real
    condition variables."""

    heartbeat_timeout: float = 2.0
    straggler_factor: float = 2.0
    kv: KVStore = field(default_factory=KVStore)
    clock: object = time.time

    def __post_init__(self):
        self.hosts: dict[int, HostState] = {}
        self._lock = threading.Lock()
        self.epoch = 0  # membership epoch — bumped on join/leave/failure
        self.ckpt_lease = DistributedTicketLease(self.kv, "ckpt-writers",
                                                 capacity=2, clock=self.clock)

    # ---- membership -------------------------------------------------------
    def join(self, host_id: int) -> int:
        """Join or REJOIN: a host that was declared dead re-enters with a
        fresh heartbeat and a bumped epoch (the elastic-epoch contract —
        the driver rebuilds its mesh; stale state from the old
        incarnation is fenced by the epoch it carries)."""
        with self._lock:
            self.hosts[host_id] = HostState(host_id, self.clock())
            self.epoch += 1
            return self.epoch

    def leave(self, host_id: int) -> int:
        with self._lock:
            if host_id in self.hosts:
                self.hosts[host_id].alive = False
                self.epoch += 1
            return self.epoch

    def alive_hosts(self) -> list[int]:
        with self._lock:
            return sorted(h.host_id for h in self.hosts.values() if h.alive)

    # ---- heartbeats / failure detection -----------------------------------
    def heartbeat(self, host_id: int, step: int, step_time_s: float) -> dict:
        now = self.clock()
        with self._lock:
            h = self.hosts.get(host_id)
            if h is None or not h.alive:
                raise RuntimeError(f"host {host_id} not a member (epoch {self.epoch})")
            h.last_heartbeat = now
            h.step = step
            h.step_ewma_s = (0.7 * h.step_ewma_s + 0.3 * step_time_s
                             if h.step_ewma_s else step_time_s)
            return {"epoch": self.epoch}

    def detect_failures(self) -> list[int]:
        now = self.clock()
        dead = []
        with self._lock:
            for h in self.hosts.values():
                if h.alive and now - h.last_heartbeat > self.heartbeat_timeout:
                    h.alive = False
                    dead.append(h.host_id)
            if dead:
                self.epoch += 1
        return dead

    # ---- stragglers --------------------------------------------------------
    def stragglers(self) -> list[int]:
        """Hosts whose EWMA step time exceeds straggler_factor × median."""
        with self._lock:
            alive = [h for h in self.hosts.values() if h.alive and h.step_ewma_s > 0]
            if len(alive) < 3:
                return []
            times = sorted(h.step_ewma_s for h in alive)
            med = times[len(times) // 2]
            return [h.host_id for h in alive if h.step_ewma_s > self.straggler_factor * med]

    # ---- failure-aware barrier ---------------------------------------------
    def barrier(self, host_id: int, gen: str, timeout: float = 10.0) -> bool:
        """Generation barrier: waits until every *alive* host arrived.  A
        host dying mid-barrier shrinks the required count instead of hanging
        everyone (the arrived-count is compared against the live membership
        each poll)."""
        key = f"barrier/{gen}"
        self.kv.incr(key)
        deadline = self.clock() + timeout
        observed = -1
        while self.clock() < deadline:
            arrived = self.kv.get(key)
            if arrived >= len(self.alive_hosts()):
                return True
            self.detect_failures()
            observed = self.kv.wait_change(key, arrived, timeout=0.05)
        return False
