"""Lease-heartbeat reaper — frees what dead holders leaked.

`DistributedTicketLease` gives every outstanding ticket a heartbeat key
(waiters renew it from their wait loop, holders via ``renew()``).  A
process that vanishes — crash, partition, live-lock — stops renewing but
its ticket still occupies the grant sequence: a leaked *waiter* ticket
will wedge FCFS hand-off when grant reaches it, a leaked *holder* ticket
is a capacity unit lost forever.  The reaper closes both leaks with the
tombstone protocol the lease already implements:

* stale **waiter** (grant has not reached the ticket) → ``cancel()``:
  the ticket is tombstoned and release()'s skip-aware advance hops it,
  so the unit flows to the next live ticket;
* stale **holder** (grant covers the ticket) → cancel() returns False,
  meaning the lease is held — the reaper force-``release()``\\ s it on
  the dead holder's behalf, returning the unit to the pool and poking
  the successor's waiting-array bucket.

Either way the heartbeat key is deleted, so one leak is reaped exactly
once.  TTL tuning is a detection-latency / false-positive trade: the TTL
must exceed the longest renewal gap a *live* client can have (a slow
megastep, a GC pause, a tolerable KV blip), and every TTL second is a
second of capacity held by a corpse — see resilience/README.md for the
cluster failure model.

The reaper is deliberately dumb: it frees tickets and reports what it
did.  *Policy* — declaring a replica dead because its tickets went
stale, migrating its in-flight work — belongs to the caller
(`serving.router.ReplicaRouter` consumes the report); a reaper that
made policy decisions would need the membership view, and then two
components would own it.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass


@dataclass(frozen=True)
class ReapAction:
    lease: str     # lease name
    ticket: int
    action: str    # "cancelled" (waiter tombstoned) | "released" (holder freed)
    age: float     # heartbeat age at reap time (seconds past TTL implied)


class LeaseReaper:
    """TTL scanner over a set of leases (one per replica, typically).

    ``scan()`` is the deterministic single-shot pass a control loop calls
    once per round (virtual-clock friendly); ``run()`` wraps it in a
    daemon thread for wall-clock deployments.  ``on_reap`` (if given) is
    called with each :class:`ReapAction` as it happens.
    """

    def __init__(self, leases, *, ttl: float, on_reap=None):
        self.leases = list(leases)
        self.ttl = float(ttl)
        self.on_reap = on_reap
        self.actions: list[ReapAction] = []  # full reap history
        self._stop = threading.Event()
        self._thread = None

    def add(self, lease) -> None:
        """Track another lease (e.g. a warm-takeover successor replica)."""
        self.leases.append(lease)

    # ------------------------------------------------------------- scan ----

    def scan(self) -> list[ReapAction]:
        """One pass: reap every outstanding ticket whose heartbeat age
        exceeds the TTL.  Returns this pass's actions (also appended to
        :attr:`actions`)."""
        out: list[ReapAction] = []
        for lease in self.leases:
            for t in lease.outstanding():
                age = lease.heartbeat_age(t)
                if age is None or age <= self.ttl:
                    continue
                if lease.cancel(t):
                    act = ReapAction(lease.name, t, "cancelled", age)
                else:
                    # grant already covers it: a leaked HOLDER — free the
                    # unit on the corpse's behalf (deletes the hb key)
                    lease.release(t)
                    act = ReapAction(lease.name, t, "released", age)
                out.append(act)
                if self.on_reap is not None:
                    self.on_reap(act)
        self.actions.extend(out)
        return out

    # -------------------------------------------------- wall-clock loop ----

    def run(self, interval: float = 0.25) -> "LeaseReaper":
        """Start the daemon scan loop (wall-clock deployments)."""
        def loop():
            while not self._stop.wait(interval):
                self.scan()

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # -------------------------------------------------------- telemetry ----

    def telemetry(self) -> dict:
        cancelled = sum(1 for a in self.actions if a.action == "cancelled")
        released = sum(1 for a in self.actions if a.action == "released")
        return {"reaped": len(self.actions), "cancelled": cancelled,
                "released": released, "leases": len(self.leases)}


def leases_clean(leases) -> dict:
    """Exit-time lease audit: after a drained run + reaper passes, every
    lease's grant sequence must be CLEAN — no queued tickets, full
    headroom (grant − ticket == capacity), no outstanding heartbeat keys.
    Any leaked ticket the reaper missed shows up here."""
    violations = []
    for lease in leases:
        hr = lease.headroom()
        if hr != lease.capacity:
            violations.append(
                f"{lease.name}: headroom {hr} != capacity {lease.capacity} "
                "(leaked or double-released ticket)")
        if lease.queue_depth() > 0:
            violations.append(
                f"{lease.name}: {lease.queue_depth()} tickets still queued")
        stale = lease.outstanding()
        if stale:
            violations.append(f"{lease.name}: heartbeat keys left for "
                              f"tickets {stale}")
    return {"ok": not violations, "violations": violations}


__all__ = ["LeaseReaper", "ReapAction", "leases_clean"]
