"""Elastic scaling: re-mesh + re-shard on membership change.

Protocol (driven by launch/train.py):
  1. coordinator epoch bumps (join/leave/failure detected);
  2. every surviving host finishes (or abandons) its in-flight step, enters
     the failure-aware barrier for the new epoch;
  3. the training driver rebuilds the mesh over the surviving device set
     (dp shrinks/grows; tp is fixed by the model), re-derives shardings, and
     restores the last complete checkpoint with the new sharding layout —
     checkpoints are stored logically unsharded, so re-sharding is a
     device_put with new NamedShardings;
  4. the data loader re-shards its index space to (host_id', n_hosts') — the
     deterministic per-index corpus makes the stream exact-continued.

The container is single-process, so "hosts" here are logical dp groups; the
mesh is rebuilt over the same physical CPU devices with a different dp
extent.  All state-carrying logic (checkpoint round-trip, spec re-derivation,
bit-exact resume) is the real thing and is covered by tests.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

from .. import compat

from ..checkpoint.manager import CheckpointManager
from ..parallel import steps as steps_lib


@dataclass
class ElasticPlan:
    epoch: int
    n_data: int
    n_model: int
    global_batch: int


def plan_for_membership(n_alive_hosts: int, devices_per_host: int,
                        n_model: int, global_batch: int, epoch: int) -> ElasticPlan:
    """dp extent = alive devices / tp; batch stays constant (grad-accum picks
    up the slack) as long as dp divides it."""
    total = n_alive_hosts * devices_per_host
    n_data = max(1, total // n_model)
    while global_batch % n_data:
        n_data -= 1
    return ElasticPlan(epoch=epoch, n_data=n_data, n_model=n_model,
                       global_batch=global_batch)


def build_mesh(plan: ElasticPlan):
    devs = jax.devices()[: plan.n_data * plan.n_model]
    import numpy as np

    arr = np.array(devs).reshape(plan.n_data, plan.n_model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def reshard_state(state, sc, mesh):
    """Re-device-put a (restored, host-resident) train state with the specs
    of the new mesh."""
    with compat.set_mesh(mesh):
        specs = steps_lib.train_state_pspecs(state, sc)
        flat_s, tdef = jax.tree_util.tree_flatten(state)
        flat_p = tdef.flatten_up_to(specs)
        out = [
            jax.device_put(x, jax.sharding.NamedSharding(mesh, p))
            for x, p in zip(flat_s, flat_p)
        ]
        return tdef.unflatten(out)


def resume_elastic(ckpt: CheckpointManager, proto_state, sc, plan: ElasticPlan):
    """Restore latest complete checkpoint and reshard onto the new mesh.
    Returns (state, step). Bit-exactness is tested (same step → same loss
    trajectory across a dp 4→2→4 resize)."""
    mesh = build_mesh(plan)
    restored, step = ckpt.restore(proto_state)
    return reshard_state(restored, sc, mesh), step, mesh
