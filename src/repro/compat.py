"""jax version compatibility — the repo targets the current public mesh /
shard_map API (jax ≥ 0.5-style `jax.sharding.get_abstract_mesh`,
`jax.set_mesh`, `jax.shard_map(..., axis_names=..., check_vma=...)`) while
still running on jax 0.4.x (this container ships 0.4.37).  Everything here
resolves to the native API when it exists and otherwise adapts:

  get_abstract_mesh() — public accessor, else the 0.4.x thread-resources
      physical mesh (`with mesh:` / set_mesh context); returns None when no
      mesh context is active.
  set_mesh(mesh)      — `jax.set_mesh` when present; on 0.4.x the Mesh
      object itself is the context manager.
  shard_map(...)      — `jax.shard_map` when present; on 0.4.x wraps
      `jax.experimental.shard_map.shard_map`, translating
      `axis_names={manual}` → `auto=frozenset(mesh.axis_names) - manual`
      and `check_vma` → `check_rep`.
"""

from __future__ import annotations

import jax


def get_abstract_mesh():
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        m = getter()
        return None if (m is None or not m.axis_names) else m
    from jax._src import mesh as _mesh

    m = _mesh.get_abstract_mesh()
    if isinstance(m, tuple):  # 0.4.x: bare context tuple, not a Mesh
        m = _mesh.thread_resources.env.physical_mesh
        return None if m.empty else m
    return None if (m is None or not m.axis_names) else m


def set_mesh(mesh):
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh  # 0.4.x: Mesh is itself the context manager


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=True):
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return native(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
    if axis_names is not None:  # manual axes → complement is auto
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _sm(f, **kwargs)


def cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on current jax, a
    one-element list of dicts on 0.4.x."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
