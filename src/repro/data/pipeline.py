"""Data pipeline: deterministic synthetic LM corpus + multi-worker prefetch
through a bounded buffer built from TWO of the paper's semaphores — the
classic producer/consumer construction (Downey, The Little Book of
Semaphores), with the TWA semaphore supplying FIFO admission:

    free  = TWASemaphore(depth)   # producers take a free slot
    ready = TWASemaphore(0)       # consumers take a ready item

FIFO matters here: with N producer threads, ticket order = production order,
so batch order is *deterministic* given worker count — reproducible input
pipelines for free (tested in test_data_pipeline.py), which a pthread-style
barging semaphore cannot guarantee.

The `queue_depth()` telemetry of the ready semaphore is the pipeline's
backpressure signal, exported to the runtime coordinator (straggler
detection: a host whose ready-depth stays 0 is input-starved).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np

from ..core.twa_semaphore import TWASemaphore


# ------------------------------------------------------- synthetic corpus ---


@dataclass
class SyntheticLM:
    """Deterministic synthetic token stream: a mixture of Zipfian unigrams
    and short repeated motifs (so models have learnable structure)."""

    vocab: int
    seq_len: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._motifs = rng.integers(
            0, self.vocab, size=(self.n_motifs, self.motif_len), dtype=np.int64
        )

    def sample(self, index: int) -> dict:
        """Sample `index` is the global sequence id — same id, same sequence,
        regardless of worker count or arrival order (elastic-restart safe)."""
        rng = np.random.default_rng((self.seed << 20) ^ index)
        toks = rng.choice(self.vocab, size=self.seq_len + 1, p=self._probs)
        # splice motifs to create predictable n-gram structure
        mlen = min(self.motif_len, max(1, self.seq_len // 2))
        for _ in range(max(1, self.seq_len // (4 * mlen))):
            m = rng.integers(0, self.n_motifs)
            at = rng.integers(0, max(1, self.seq_len - mlen))
            toks[at : at + mlen] = self._motifs[m][:mlen]
        return {
            "tokens": toks[:-1].astype(np.int32),
            "labels": toks[1:].astype(np.int32),
        }


# --------------------------------------------------------- bounded buffer ---


class BoundedBuffer:
    """Classic 2-semaphore bounded buffer; FIFO on both sides via TWA."""

    def __init__(self, depth: int, waiting: str = "futex"):
        self.depth = depth
        self._free = TWASemaphore(depth, waiting=waiting)
        self._ready = TWASemaphore(0, waiting=waiting)
        self._slots = [None] * depth
        self._wcur = 0
        self._rcur = 0
        self._wlock = threading.Lock()
        self._rlock = threading.Lock()

    def put(self, item) -> None:
        self._free.take()
        with self._wlock:
            slot = self._wcur % self.depth
            self._wcur += 1
            self._slots[slot] = item
        self._ready.post()

    def get(self):
        self._ready.take()
        with self._rlock:
            slot = self._rcur % self.depth
            self._rcur += 1
            item = self._slots[slot]
            self._slots[slot] = None
        self._free.post()
        return item

    def backpressure(self) -> dict:
        """Semaphore telemetry: producers blocked (free queue depth) and
        consumers starved (ready queue depth)."""
        return {
            "producers_blocked": self._free.queue_depth(),
            "consumers_starved": self._ready.queue_depth(),
            "items_ready": self._ready.available(),
        }


# ---------------------------------------------------------------- loader ----


class DataLoader:
    """Multi-worker prefetching loader over a sharded index space.

    Host `host_id` of `n_hosts` owns indices {i : i ≡ host_id (mod n_hosts)}
    — elastic re-sharding just changes (host_id, n_hosts) and the index
    cursor restarts from the checkpointed step (deterministic samples make
    this exact).
    """

    def __init__(
        self,
        source: SyntheticLM,
        batch_size: int,
        *,
        n_workers: int = 2,
        depth: int = 8,
        host_id: int = 0,
        n_hosts: int = 1,
        start_step: int = 0,
        collate: Callable | None = None,
    ):
        self.source = source
        self.batch_size = batch_size
        self.host_id, self.n_hosts = host_id, n_hosts
        self.buffer = BoundedBuffer(depth)
        self._start_step = start_step
        self._cursor = start_step * batch_size
        self._cursor_lock = threading.Lock()
        self._stop = threading.Event()
        self._collate = collate or (lambda items: {
            k: np.stack([it[k] for it in items]) for k in items[0]
        })
        self._workers = [
            threading.Thread(target=self._work, daemon=True) for _ in range(n_workers)
        ]
        for w in self._workers:
            w.start()

    def _next_indices(self):
        with self._cursor_lock:
            base = self._cursor
            self._cursor += self.batch_size
        step = base // self.batch_size
        return step, [
            (base + j) * self.n_hosts + self.host_id for j in range(self.batch_size)
        ]

    def _work(self):
        while not self._stop.is_set():
            step, idxs = self._next_indices()
            try:
                batch = self._collate([self.source.sample(i) for i in idxs])
            except Exception as e:  # surface producer faults to the consumer
                self.buffer.put((step, e))
                return
            # buffer.put blocks on the `free` TWA semaphore when the trainer
            # is behind — bounded memory, FIFO handoff.
            self.buffer.put((step, batch))

    def __iter__(self) -> Iterator[dict]:
        """Step-ordered stream: with N workers, batches may complete out of
        order; a bounded reorder stage (≤ n_workers entries) restores the
        deterministic step order so worker count never changes the stream."""
        pending: dict[int, dict] = {}
        expect = self._start_step
        while True:
            while expect not in pending:
                step, item = self.buffer.get()
                if isinstance(item, Exception):
                    raise item
                pending[step] = item
            yield pending.pop(expect)
            expect += 1

    def stop(self):
        self._stop.set()
        # unblock any producer stuck in put()
        while self.buffer.backpressure()["items_ready"] > 0:
            try:
                self.buffer.get()
            except Exception:
                break

    def telemetry(self) -> dict:
        return self.buffer.backpressure()
