"""Int8 error-feedback gradient compression for the scarce inter-pod links.

The pod axis crosses data-center interconnect (~9 GB/s/chip assumed) while
intra-pod ICI runs ~50 GB/s/link, so the multi-pod gradient reduction is the
dominant collective.  We therefore reduce gradients hierarchically:

    g_local   = reduce(g, axis="data")           # fast ICI, full precision
    absmax    = pmax(blockmax(g_local + e), "pod")   # tiny fp32 collective
    q         = round((g_local + e)/scale)       # int8-range values
    q_sum     = psum(q as int16, "pod")          # 2-byte wire (4-byte fp32 → 2x;
                                                 # real HW reduces the int8
                                                 # payload → 4x, noted in
                                                 # EXPERIMENTS.md)
    g_global  = q_sum * scale / n_pods
    e'        = (g_local + e) - q*scale          # error feedback (stays local)

The *shared* (pmax'ed) scale makes the integer psum exact: Σ qᵢ·s == (Σ qᵢ)·s.
Error feedback makes quantization unbiased over time — the residual `e` lives
in the optimizer state and is re-injected next step, so compression noise
does not accumulate into the trajectory (standard EF-SGD result; validated in
tests/test_compression.py).

Used when the mesh has a "pod" axis and the run config enables
`compress_pod_grads`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256  # per-block scale granularity (flattened)


def _blocked(x):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, BLOCK), flat.size


def compress_psum(g, residual, axis_name: str, n_shards: int):
    """Error-feedback int8-range psum over `axis_name` for one leaf.

    Returns (g_mean fp32 (mean over shards), new_residual fp32).
    """
    x = g.astype(jnp.float32) + residual
    blocks, n = _blocked(x)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    absmax = jax.lax.pmax(absmax, axis_name)  # shared scale → exact int sum
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127)
    local_dq = (q * scale).reshape(-1)[:n].reshape(g.shape)
    new_residual = x - local_dq
    # int16 accumulator: exact for ≤256 shards (127·256 < 2^15); 2-byte wire.
    q_sum = jax.lax.psum(q.astype(jnp.int16), axis_name)
    g_sum = (q_sum.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(g.shape)
    return g_sum / n_shards, new_residual


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
