"""AdamW with the distributed-training substrate features:

  * fp32 master weights + moments, bf16 working params (mixed precision);
  * ZeRO-1: the *optimizer state* shardings add a "zero" (data/pod) dimension
    on top of the parameter TP sharding — derived in parallel/sharding.py,
    applied by the step factory via with_sharding_constraint;
  * global-norm clipping computed in fp32;
  * linear-warmup cosine schedule;
  * optional int8 error-feedback gradient compression (for the scarce
    inter-pod links — see compression.py).

Pure functional: state is a pytree, `update` is jit-safe.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array  # int32
    mu: dict  # first moment,  fp32, like params
    nu: dict  # second moment, fp32, like params
    master: dict  # fp32 master copy of params


def init_opt_state(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
    )


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, opt: OptState, param_dtype=jnp.bfloat16):
    """Returns (new_params, new_opt_state, metrics). grads in any dtype
    (accumulated fp32 upstream); decoupled weight decay on master weights."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = opt.step + 1
    lr = schedule(cfg, opt.step)
    b1, b2 = cfg.beta1, cfg.beta2
    # bias correction
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        # decoupled weight decay — skipped for norms/biases (ndim ≤ 1)
        wd = cfg.weight_decay if w.ndim > 1 else 0.0
        w = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + wd * w)
        return m, v, w

    # explicit flatten (tuples are pytree nodes — tree.map with a
    # tuple-returning fn would splice them into the tree)
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree.leaves(opt.mu)
    flat_v = jax.tree.leaves(opt.nu)
    flat_w = jax.tree.leaves(opt.master)
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    mu = tdef.unflatten([o[0] for o in out])
    nu = tdef.unflatten([o[1] for o in out])
    master = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda w: w.astype(param_dtype), master)
    new_opt = OptState(step=step, mu=mu, nu=nu, master=master)
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
