"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels are
allclose-validated against, shape/dtype-swept in tests/test_kernels.py)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

MASK32 = (1 << 32) - 1
TICKET_STRIDE = 17


# ------------------------------------------------------------ sema_batch ----


def sema_batch_ref(ticket, grant, bucket_seq, requests, post_n, salt):
    """Oracle for the fused batched semaphore pass (the paper's take+post+
    notify adapted to a vector of K requests — see core/functional.py).

    Inputs (all jnp):
      ticket, grant: uint32 scalars     bucket_seq: (T,) uint32
      requests: (N,) bool               post_n: uint32 scalar
      salt: uint32 scalar (semaphore identity — uintptr_t(L) of TWAHash)

    Returns dict with new ticket/grant/bucket_seq, per-row tickets, admitted
    mask, bucket index, and woken mask (bucket moved this pass).
    """
    T = bucket_seq.shape[0]
    req = requests.astype(jnp.uint32)
    ranks = jnp.cumsum(req) - req
    tickets = ticket + ranks
    admitted = requests & ((grant - tickets).astype(jnp.int32) > 0)
    new_ticket = ticket + jnp.sum(req)

    idx = ((salt + tickets * jnp.uint32(TICKET_STRIDE)) & jnp.uint32(T - 1)).astype(jnp.int32)

    # post: grant advances by post_n; the enabled ticket range's buckets bump
    offs = jnp.arange(T, dtype=jnp.uint32)
    enabled = offs < post_n
    post_idx = ((salt + (grant + offs) * jnp.uint32(TICKET_STRIDE)) & jnp.uint32(T - 1)).astype(jnp.int32)
    bump = jnp.zeros((T,), jnp.uint32).at[post_idx].add(enabled.astype(jnp.uint32))
    new_seq = bucket_seq + bump
    woken = requests & (new_seq[idx] != bucket_seq[idx])
    return {
        "ticket": new_ticket,
        "grant": grant + post_n,
        "bucket_seq": new_seq,
        "tickets": tickets,
        "admitted": admitted,
        "bucket": idx,
        "woken": woken,
    }


# ------------------------------------------------------------- qos_round ----


def qos_round_ref(state, tenant_ids, tickets, alive, deadlines, now,
                  free_units, max_units: int):
    """Oracle for the fused multi-tenant QoS admission round — delegates to
    `admission.functional_qos.qos_round` (the reference semantics the
    `kernels/qos_admission` Pallas kernel must match bit-exactly: expire →
    weighted stride replenish → tombstone-transparent FCFS admit → reclaim).

    Returns dict with the new QoSState and per-row admitted/expired masks
    plus the leftover (work-conserving) unit count.
    """
    from ..admission.functional_qos import qos_round

    state2, admitted, expired, leftover = qos_round(
        state, tenant_ids, tickets, alive, deadlines, now, free_units,
        max_units)
    return {
        "state": state2,
        "admitted": admitted,
        "expired": expired,
        "leftover": leftover,
    }


def qos_round_scan_ref(state, tenant_ids, tickets, alive, deadlines, nows,
                       free_units, released, max_units: int):
    """Oracle for the batch-of-rounds scan (`kernels.qos_admission.
    qos_round_scan`): K sequential `functional_qos.qos_round` calls — each
    round's admitted/expired rows leave the alive set, each round's
    released units join the pool before its replenish, and the leftover
    pool carries.  Returns dict with the final state, per-row
    admit/expire round indices (-1 = never), and the final free pool."""
    from ..admission.functional_qos import qos_scan_round

    n = tickets.shape[0]
    alive = jnp.asarray(alive, bool)
    free = jnp.asarray(free_units, jnp.int32)
    admit_round = np.full(n, -1, np.int32)
    expire_round = np.full(n, -1, np.int32)
    for k in range(len(nows)):
        state, adm, exp, free = qos_scan_round(
            state, tenant_ids, tickets, alive, deadlines, nows[k], free,
            released[k], max_units)
        adm_np, exp_np = np.asarray(adm), np.asarray(exp)
        admit_round[adm_np] = k
        expire_round[exp_np] = k
        alive = alive & ~adm & ~exp
    return {
        "state": state,
        "admit_round": jnp.asarray(admit_round),
        "expire_round": jnp.asarray(expire_round),
        "free": free,
    }


# -------------------------------------------------------- flash attention ---


def mha_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """Naive O(S²) attention oracle. q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd).
    GQA by head repetition; fp32 softmax; q_offset = absolute position of
    q row 0 (so a decode/step query can attend to a longer prefix)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    group = H // KV
    kh = jnp.repeat(k, group, axis=2)
    vh = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kh.astype(jnp.float32))
    s *= 1.0 / math.sqrt(hd)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    d = qpos[:, None] - kpos[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= d >= 0
    if window > 0:
        mask &= d < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------- paged decode ----


NEG_INF = float("-inf")


def flash_decode_block(q, k, v, mask, m_prev, l_prev, acc_prev, *, scale):
    """One online-softmax block step of flash-decode — shared VERBATIM by
    the Pallas kernel (`kernels/paged_decode._paged_kernel`) and the
    blockwise oracle below, so interpret-mode bit-exactness tests the
    kernel's *paging* logic (table-driven DMA, ragged skip, init/finalize)
    rather than fp reassociation noise.

    q: (G, hd); k/v: (BS, hd); mask: (BS,) bool (valid tokens);
    m/l: (G, 1) f32 carries; acc: (G, hd) f32.  Returns (m', l', acc')."""
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * scale  # (G, BS)
    s = jnp.where(mask[None, :], s, NEG_INF)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask[None, :], jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def paged_decode_ref(q, k_pool, v_pool, block_tbl, lens):
    """Blockwise oracle for the ragged paged-decode kernel
    (`kernels/paged_decode.paged_decode` — bit-exact in interpret mode).

    q: (S, H, hd); k_pool/v_pool: (NB, BS, KV, hd) — the shared block-paged
    KV pool; block_tbl: (S, MB) int32 block ids (-1 ⇒ unallocated);
    lens: (S,) int32 — valid tokens of each slot (tokens 0..len-1 live at
    block ``block_tbl[s, t // BS]`` offset ``t % BS``).  Returns (S, H, hd).

    The recurrence mirrors the kernel exactly (same `flash_decode_block`,
    same -1→0 table clamp, same ``i·BS < len`` ragged skip), and rows run
    under `lax.map` so every dot keeps the kernel's UNBATCHED (G, hd) ×
    (BS, hd) shape — a vmapped/batched dot reduces in a different order on
    CPU at G=1 (1-ulp drift) and would break the bit-exact contract.
    Semantic equivalence to the naive dense softmax is checked separately
    against `decode_attention_ref` over the gathered cache
    (tests/test_paged_decode.py) — the fp delta between blockwise and
    dense softmax is tiny but nonzero, so *bit*-exactness is defined
    against this blockwise form.
    """
    S, H, hd = q.shape
    NB, BS, KV, _ = k_pool.shape
    MB = block_tbl.shape[1]
    G = H // KV
    R = S * KV
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(S, KV, G, hd).reshape(R, G, hd)
    kp = k_pool.transpose(2, 0, 1, 3)  # (KV, NB, BS, hd)
    vp = v_pool.transpose(2, 0, 1, 3)
    tbl_r = jnp.repeat(jnp.asarray(block_tbl, jnp.int32), KV, axis=0)
    lens_r = jnp.repeat(jnp.asarray(lens, jnp.int32), KV)
    head = jnp.tile(jnp.arange(KV, dtype=jnp.int32), S)  # r = s·KV + h

    def row(args):
        qrow, trow, ln, h = args
        m = jnp.full((G, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((G, 1), jnp.float32)
        acc = jnp.zeros((G, hd), jnp.float32)

        def body(carry, i):
            m, l, acc = carry
            b = jnp.maximum(trow[i], 0)          # the kernel's index-map clamp
            tpos = i * BS + jnp.arange(BS, dtype=jnp.int32)
            m2, l2, acc2 = flash_decode_block(
                qrow, kp[h, b], vp[h, b], tpos < ln, m, l, acc, scale=scale)
            upd = i * BS < ln                    # the kernel's pl.when skip
            return (jnp.where(upd, m2, m), jnp.where(upd, l2, l),
                    jnp.where(upd, acc2, acc)), None

        (m, l, acc), _ = jax.lax.scan(body, (m, l, acc),
                                      jnp.arange(MB, dtype=jnp.int32))
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    o = jax.lax.map(row, (qr, tbl_r, lens_r, head))
    return o.reshape(S, KV, G, hd).reshape(S, H, hd)


def paged_prefill_merge(chunk, tpos, off, length):
    """Merge the chunk rows that land in one pool block — shared VERBATIM
    by the Pallas kernel (`kernels/paged_prefill._prefill_kernel`) and the
    blockwise oracle `paged_prefill_ref`, so bit-exactness pins the
    writeback logic, not fp noise.

    ``chunk``: (CT, hd) this slot's chunk K or V rows; ``tpos``: (BS,) i32
    absolute token positions of the block rows; ``off``/``length``: chunk
    start position / token count.  Block row t receives chunk row
    ``tpos[t] − off`` iff it falls inside the chunk window.  The gather is
    a 0/1 one-hot matmul — MXU-friendly on TPU and EXACT in f32 (each
    output row is a single product with a 1.0) — instead of a dynamic
    in-kernel gather.  Returns ``(sel (BS,) bool, upd (BS, hd))``."""
    CT = chunk.shape[0]
    sel = (tpos >= off) & (tpos < off + length)
    c = tpos - off
    onehot = ((c[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, CT), 1))
              & sel[:, None]).astype(jnp.float32)
    upd = jax.lax.dot_general(
        onehot, chunk.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return sel, upd.astype(chunk.dtype)


def flash_prefill_block(q, k, v, mask, m_prev, l_prev, acc_prev, *, scale):
    """One online-softmax block step of blockwise flash-PREFILL — the 2-D
    masked sibling of :func:`flash_decode_block` (per-query-row masks:
    causal within the chunk, full attention to prior pool blocks), shared
    VERBATIM by `kernels/paged_prefill` and `paged_prefill_ref`.

    q: (Q, hd) chunk queries (GQA groups stacked row-major); k/v: (BS, hd);
    mask: (Q, BS) bool; m/l: (Q, 1) f32 carries; acc: (Q, hd) f32."""
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * scale  # (Q, BS)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def paged_prefill_ref(q, k_chunk, v_chunk, k_pool, v_pool, block_tbl, off,
                      lens):
    """Blockwise oracle for the ragged chunked-prefill kernel
    (`kernels/paged_prefill.paged_prefill` — bit-exact in interpret mode).

    q: (S, CT, H, hd) chunk queries; k_chunk/v_chunk: (S, CT, KV, hd) the
    chunk's new KV rows; k_pool/v_pool: (NB, BS, KV, hd); block_tbl:
    (S, MB) i32 (-1 ⇒ unallocated); off: (S,) i32 chunk start positions
    (= tokens already in the pool); lens: (S,) i32 chunk lengths (0 ⇒ slot
    idle this round).  Token t of slot s lives at block
    ``block_tbl[s, t // BS]`` offset ``t % BS``; blocks covering
    ``[0, off+len)`` must be allocated (the incremental allocator's
    invariant).

    Returns ``(out (S, CT, H, hd), k_pool', v_pool')`` — chunk KV merged
    into its freshly-taken blocks (`paged_prefill_merge`), and each chunk
    query attending causally within the chunk and fully to all prior
    tokens (`flash_prefill_block` over the block tables, same -1→0 clamp
    and ``i·BS < off+len`` ragged skip as the kernel; `lax.map` rows keep
    the kernel's unbatched dot shapes — see `paged_decode_ref` on why)."""
    S, CT, H, hd = q.shape
    NB, BS, KV, _ = k_pool.shape
    MB = block_tbl.shape[1]
    G = H // KV
    R = S * KV
    scale = 1.0 / math.sqrt(hd)
    qr = (q.reshape(S, CT, KV, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(R, G * CT, hd))
    kc = k_chunk.transpose(0, 2, 1, 3).reshape(R, CT, hd)
    vc = v_chunk.transpose(0, 2, 1, 3).reshape(R, CT, hd)
    kp = k_pool.transpose(2, 0, 1, 3)  # (KV, NB, BS, hd)
    vp = v_pool.transpose(2, 0, 1, 3)
    tbl_r = jnp.repeat(jnp.asarray(block_tbl, jnp.int32), KV, axis=0)
    off_r = jnp.repeat(jnp.asarray(off, jnp.int32), KV)
    len_r = jnp.repeat(jnp.asarray(lens, jnp.int32), KV)
    head = jnp.tile(jnp.arange(KV, dtype=jnp.int32), S)  # r = s·KV + h

    rows_q = jax.lax.broadcasted_iota(jnp.int32, (G * CT, 1), 0) % CT

    def row(args):
        qrow, kcrow, vcrow, trow, o, ln, h = args
        m = jnp.full((G * CT, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((G * CT, 1), jnp.float32)
        acc = jnp.zeros((G * CT, hd), jnp.float32)
        qpos = o + rows_q
        qvalid = rows_q < ln

        def body(carry, i):
            m, l, acc, mk, mv = carry
            b = jnp.maximum(trow[i], 0)          # the kernel's index-map clamp
            tpos = i * BS + jnp.arange(BS, dtype=jnp.int32)
            sel, ku = paged_prefill_merge(kcrow, tpos, o, ln)
            _, vu = paged_prefill_merge(vcrow, tpos, o, ln)
            kblk = jnp.where(sel[:, None], ku, kp[h, b])
            vblk = jnp.where(sel[:, None], vu, vp[h, b])
            mask = qvalid & (tpos[None, :] <= qpos)
            m2, l2, acc2 = flash_prefill_block(
                qrow, kblk, vblk, mask, m, l, acc, scale=scale)
            upd = (i * BS < o + ln) & (ln > 0)   # the kernel's pl.when skip
            wr = upd & (i * BS + BS > o)         # block overlaps the chunk
            mk = mk.at[i].set(jnp.where(wr, kblk, mk[i]))
            mv = mv.at[i].set(jnp.where(wr, vblk, mv[i]))
            return (jnp.where(upd, m2, m), jnp.where(upd, l2, l),
                    jnp.where(upd, acc2, acc), mk, mv), None

        mk0 = jnp.zeros((MB, BS, hd), k_pool.dtype)
        mv0 = jnp.zeros((MB, BS, hd), v_pool.dtype)
        (m, l, acc, mk, mv), _ = jax.lax.scan(
            body, (m, l, acc, mk0, mv0), jnp.arange(MB, dtype=jnp.int32))
        wrote = ((jnp.arange(MB) * BS < o + ln) & (ln > 0)
                 & (jnp.arange(MB) * BS + BS > o))
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype), mk, mv, wrote

    o_r, mk_r, mv_r, wrote_r = jax.lax.map(
        row, (qr, kc, vc, tbl_r, off_r, len_r, head))
    out = (o_r.reshape(S, KV, G, CT, hd).transpose(0, 3, 1, 2, 4)
           .reshape(S, CT, H, hd))
    # scatter the merged chunk blocks back into the pools (the kernel's
    # aliased writeback): only overlapping blocks of live rows write
    bsel = jnp.where(wrote_r & (tbl_r >= 0), tbl_r, NB)  # (R, MB)
    hsel = jnp.broadcast_to(head[:, None], bsel.shape)
    kp2 = kp.at[hsel, bsel].set(mk_r, mode="drop")
    vp2 = vp.at[hsel, bsel].set(mv_r, mode="drop")
    return out, kp2.transpose(1, 2, 0, 3), vp2.transpose(1, 2, 0, 3)


def paged_gather_kv(pool, block_tbl, lens):
    """Dense view of a paged cache: gather ``(S, MB·BS, KV, hd)`` plus the
    per-token position array (`decode_attention_ref` conventions, -1 ⇒
    empty) — the bridge that lets the naive dense oracle cross-check the
    blockwise one."""
    NB, BS, KV, hd = pool.shape
    S, MB = block_tbl.shape
    b = jnp.maximum(jnp.asarray(block_tbl, jnp.int32), 0)
    dense = pool[b].reshape(S, MB * BS, KV, hd)
    t = jnp.arange(MB * BS, dtype=jnp.int32)[None, :]
    pos = jnp.where(t < jnp.asarray(lens, jnp.int32)[:, None], t, -1)
    return dense, pos


# -------------------------------------------------------- decode attention ---


def decode_attention_ref(q, k, v, kv_pos, q_pos, *, window=0):
    """Single-token decode oracle with explicit KV slot positions.
    q: (B,H,hd); k/v: (B,C,KV,hd); kv_pos: (B,C) int32 (-1 ⇒ empty);
    q_pos: (B,) int32. Returns (B,H,hd) in q.dtype."""
    B, H, hd = q.shape
    _, C, KV, _ = k.shape
    group = H // KV
    kh = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vh = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bchd->bhc", q.astype(jnp.float32), kh) / math.sqrt(hd)
    d = q_pos[:, None] - kv_pos  # (B,C)
    mask = (kv_pos >= 0) & (d >= 0)
    if window > 0:
        mask &= d < window
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhc,bchd->bhd", p, vh).astype(q.dtype)
