"""Pallas TPU kernel: ragged flash-decode over a block-paged KV pool.

The dense decode kernel (`kernels/decode_attention`) streams each slot's
whole ring cache — (S, C) tokens of HBM traffic per step regardless of how
many tokens the slot actually holds, so short sequences pay long-sequence
cost and the cache must be reserved up front.  This kernel decodes against
the **shared block pool** managed by the TWA block semaphore
(`core.functional.BlockPool` / `serving.engine_state`): each slot owns a
small table of block ids, and the kernel streams exactly the blocks the
slot has written — attention bytes ∝ live tokens, not ∝ S·C.

TPU adaptation notes:
  * grid = (S, KV, MB) with the block axis innermost-sequential; the block
    table and per-slot lengths ride in as **scalar prefetch** operands
    (`pltpu.PrefetchScalarGridSpec`), so each K/V BlockSpec index map
    dereferences ``tbl[s, i]`` to aim the next DMA at the right pool block
    — the table gather never materializes a dense (S, MB·BS) cache;
  * raggedness is data-driven: grid bound MB is the static per-slot
    maximum, and blocks at or past a slot's length (``i·BS ≥ len``) are
    skipped with `pl.when` — the online-softmax carry is untouched, so
    empty tail blocks and wholly-idle slots cost no flops (their DMA is
    aimed at the clamped block 0, a benign re-fetch);
  * unallocated table entries (-1) are clamped to block 0 in the index map
    — compute for them is always masked (a slot's length never reaches an
    unallocated block by the allocator's demand invariant);
  * the per-block math is `ref.flash_decode_block`, shared VERBATIM with
    the blockwise oracle `ref.paged_decode_ref` — interpret-mode
    bit-exactness therefore pins the paging logic (index maps, masks,
    init/finalize), not fp reassociation;
  * m/l/acc VMEM scratch carries the online softmax across the block axis,
    identical recurrence to `decode_attention`.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF, flash_decode_block


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, block_size):
    s = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[s]

    @pl.when(i * block_size < length)  # ragged bound: skip empty tail blocks
    def _block():
        q = q_ref[0, 0]  # (G, hd)
        k = k_ref[0, 0]  # (BS, hd) — pool block aimed by the index map
        v = v_ref[0, 0]
        tpos = i * block_size + jax.lax.iota(jnp.int32, block_size)
        mask = tpos < length
        m, l, acc = flash_decode_block(
            q, k, v, mask, m_ref[...], l_ref[...], acc_ref[...], scale=scale)
        m_ref[...] = m
        l_ref[...] = l
        acc_ref[...] = acc

    @pl.when(i == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode(q, k_pool, v_pool, block_tbl, lens, *, interpret=False):
    """q: (S, H, hd); k_pool/v_pool: (NB, BS, KV, hd); block_tbl: (S, MB)
    int32 (-1 ⇒ unallocated); lens: (S,) int32 valid tokens per slot.
    Returns (S, H, hd).  Oracle: `ref.paged_decode_ref` (bit-exact in
    interpret mode)."""
    S, H, hd = q.shape
    NB, BS, KV, _ = k_pool.shape
    MB = block_tbl.shape[1]
    assert H % KV == 0
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(S, KV, G, hd)
    kp = k_pool.transpose(2, 0, 1, 3)  # (KV, NB, BS, hd)
    vp = v_pool.transpose(2, 0, 1, 3)
    tbl = jnp.asarray(block_tbl, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)

    def kv_map(s, h, i, tbl_ref, len_ref):
        # table-driven DMA: the scalar-prefetched block id aims the fetch;
        # -1 (unallocated) clamps to pool block 0, compute stays masked
        return (h, jnp.maximum(tbl_ref[s, i], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, KV, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda s, h, i, tbl, ln: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, BS, hd), kv_map),
            pl.BlockSpec((1, 1, BS, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda s, h, i, tbl, ln: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, block_size=BS),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, lens, qr, kp, vp)
    return out.reshape(S, H, hd)
