"""Pallas TPU flash-decode kernel: one query token per sequence against a
long (possibly rolling) KV cache.

TPU adaptation notes:
  * decode is memory-bound (the whole KV cache streams HBM→VMEM once); the
    kernel's job is to keep that stream dense and fuse the softmax so no
    (B,H,C) score tensor ever exists in HBM;
  * grid = (B·KV, C/block_k) with the cache-block axis innermost-sequential;
    m/l/acc VMEM scratch carries the online softmax — identical recurrence
    to the prefill kernel but with all G q-heads of the kv-head resident
    (G·hd ≤ 64·256 → a few KiB);
  * explicit per-slot positions (pos_ref, -1 ⇒ empty) make the same kernel
    correct for rolling sliding-window buffers and ragged continuous-batching
    rows — masking is data-driven, matching models/cache.py semantics;
  * on a sequence-sharded cache (the production decode sharding), each model
    shard runs this kernel over its slice and the LSE merge happens in the
    surrounding jnp (psum) — kernel stays single-core-local, communication
    stays in XLA's hands.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale, window):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (G, hd)
    k = k_ref[0]  # (block_k, hd)
    v = v_ref[0]
    kpos = pos_ref[0]  # (block_k,) int32
    qpos = qpos_ref[0]  # scalar int32 per row

    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * scale  # (G, block_k)
    d = qpos - kpos[None, :]
    mask = (kpos[None, :] >= 0) & (d >= 0)
    if window > 0:
        mask &= d < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k, v, kv_pos, q_pos, *, window=0, block_k=512,
                     interpret=False):
    """q: (B, H, hd); k/v: (B, C, KV, hd); kv_pos: (B, C) int32 (-1 empty);
    q_pos: (B,) int32 → (B, H, hd)."""
    B, H, hd = q.shape
    _, C, KV, _ = k.shape
    assert H % KV == 0
    G = H // KV
    block_k = min(block_k, C)
    pad = (-C) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    Cp = C + pad
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Cp, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Cp, hd)
    pos = jnp.repeat(kv_pos, KV, axis=0)  # (B·KV, Cp)
    qp = jnp.repeat(q_pos, KV)  # (B·KV,)

    grid = (B * KV, Cp // block_k)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ik: (bh,)),
            pl.BlockSpec((1, G, hd), lambda bh, ik: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k), lambda bh, ik: (bh, ik)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, ik: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, qr, kr, vr, pos)
    return out.reshape(B, KV, G, hd).reshape(B, H, hd)
