"""Pallas TPU kernel for the batched TWA semaphore pass — the paper's
take + post + waiting-array notification, executed for a whole vector of
requests in one VMEM-resident sweep.

This is the L2 adaptation of the paper (DESIGN.md): TPUs have no in-graph
shared-memory atomics, so the wait-free fetch_add linearization becomes a
deterministic batch linearization:

  * `fetch_add` per request  →  base + exclusive prefix rank.  Computed on
    the MXU as `req · strict_lower_triangle(1)` — a (block_n × block_n)
    masked matmul is both exact (counts ≪ 2²⁴ in f32) and systolic-friendly,
    instead of a sequential scan;
  * ticket issuance order == row order == FCFS — the paper's
    first-come-first-enabled admission, preserved batchwise;
  * the waiting array is a (T,) sequence vector in VMEM; the post side bumps
    the TWAHash buckets of the enabled ticket range [grant, grant+post_n) —
    because the stride 17 is coprime with T, a window of consecutive tickets
    is a *permutation* of bucket indices, implemented as an iota-compare
    one-hot reduction (VPU) rather than a scatter;
  * `woken` = requests whose bucket moved — the scheduler re-examines ONLY
    those rows next step (the kernel-level analogue of not globally
    spinning: O(woken) instead of O(waiters) re-checks).

Multi-block grids carry the running request count (the ticket counter) in a
scratch accumulator across the sequential grid axis, mirroring the single
atomic counter the CPU algorithm maintains.

Oracle: ref.sema_batch_ref (== core.functional semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TICKET_STRIDE = 17


def _sema_kernel(scal_ref, req_ref, seq_ref, tickets_ref, admitted_ref,
                 bucket_ref, woken_ref, new_scal_ref, new_seq_ref,
                 base_ref, *, table, block_n):
    i = pl.program_id(0)
    ticket0 = scal_ref[0]
    grant = scal_ref[1]
    post_n = scal_ref[2]
    salt = scal_ref[3]

    @pl.when(i == 0)
    def _init():
        base_ref[0, 0] = ticket0

    req = req_ref[0].astype(jnp.float32)  # (block_n,)
    # exclusive prefix rank via strict-lower-triangular matmul (MXU):
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 1)
    tri = (cols < rows).astype(jnp.float32)
    ranks = jax.lax.dot_general(
        tri, req, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # ranks[r] = # requests before row r (within block)
    base = base_ref[0, 0]
    tickets = base + ranks.astype(jnp.uint32)
    reqb = req_ref[0] != 0
    admitted = reqb & ((grant - tickets).astype(jnp.int32) > 0)

    idx = ((salt + tickets * jnp.uint32(TICKET_STRIDE)) & jnp.uint32(table - 1)).astype(jnp.int32)

    # post side: bump buckets of the enabled ticket range [grant, grant+n)
    offs = jax.lax.broadcasted_iota(jnp.uint32, (1, table), 1)[0]
    enabled = (offs < post_n).astype(jnp.uint32)
    post_idx = ((salt + (grant + offs) * jnp.uint32(TICKET_STRIDE)) & jnp.uint32(table - 1))
    # permutation one-hot reduction: bump[j] = Σ_i enabled[i]·[post_idx_i == j]
    tcols = jax.lax.broadcasted_iota(jnp.uint32, (table, table), 1)
    onehot = (post_idx[:, None] == tcols).astype(jnp.uint32)
    bump = jnp.sum(onehot * enabled[:, None], axis=0)  # (table,)
    new_seq = seq_ref[0] + bump

    # gather bump at each waiter's bucket (compare-select, no scatter/gather)
    bcols = jax.lax.broadcasted_iota(jnp.int32, (block_n, table), 1)
    bump_at = jnp.sum(jnp.where(bcols == idx[:, None], bump[None, :], 0), axis=1)
    woken = reqb & (bump_at > 0)

    tickets_ref[0] = tickets
    admitted_ref[0] = admitted.astype(jnp.int32)
    bucket_ref[0] = idx
    woken_ref[0] = woken.astype(jnp.int32)
    new_seq_ref[0] = new_seq

    n_req = jnp.sum(req).astype(jnp.uint32)
    base_ref[0, 0] = base + n_req

    @pl.when(i == pl.num_programs(0) - 1)
    def _fin():
        new_scal_ref[0] = base + n_req  # final ticket counter
        new_scal_ref[1] = grant + post_n
        new_scal_ref[2] = jnp.uint32(0)
        new_scal_ref[3] = salt


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sema_batch(ticket, grant, bucket_seq, requests, post_n, salt,
               *, block_n: int = 512, interpret=False):
    """Fused batched semaphore pass.  requests: (N,) bool.
    Returns (new_ticket, new_grant, new_bucket_seq, tickets, admitted,
    bucket, woken)."""
    N = requests.shape[0]
    T = bucket_seq.shape[0]
    assert T & (T - 1) == 0
    block_n = min(block_n, max(N, 8))
    pad = (-N) % block_n
    reqp = jnp.pad(requests.astype(jnp.int32), (0, pad))
    nb = (N + pad) // block_n
    scal = jnp.stack([jnp.asarray(x, jnp.uint32) for x in (ticket, grant, post_n, salt)])

    outs = pl.pallas_call(
        functools.partial(_sema_kernel, table=T, block_n=block_n),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, T), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((1, T), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, N + pad), jnp.uint32),   # tickets
            jax.ShapeDtypeStruct((1, N + pad), jnp.int32),    # admitted
            jax.ShapeDtypeStruct((1, N + pad), jnp.int32),    # bucket
            jax.ShapeDtypeStruct((1, N + pad), jnp.int32),    # woken
            jax.ShapeDtypeStruct((4,), jnp.uint32),           # new scalars
            jax.ShapeDtypeStruct((1, T), jnp.uint32),         # new bucket_seq
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.uint32)],
        interpret=interpret,
    )(scal, reqp.reshape(1, -1), bucket_seq.reshape(1, -1))

    tickets, admitted, bucket, woken, new_scal, new_seq = outs
    return (
        new_scal[0],
        new_scal[1],
        new_seq[0],
        tickets[0, :N],
        admitted[0, :N].astype(bool),
        bucket[0, :N],
        woken[0, :N].astype(bool),
    )
