"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute via interpret=True; on TPU they
compile natively.  The model code keeps the pure-jnp path as default (the
512-device host dry-run cannot lower Pallas); serving/benchmarks opt in via
use_pallas=True or REPRO_USE_PALLAS=1.
"""

from __future__ import annotations

import os

import jax
import numpy as np

from ..core.functional import next_pow2 as _next_pow2
from .decode_attention import decode_attention as _decode_attention
from .flash_attention import flash_attention_fwd as _flash_attention_fwd
from .paged_decode import paged_decode as _paged_decode
from .paged_prefill import paged_prefill as _paged_prefill
from .qos_admission import qos_round_fused as _qos_round_fused
from .qos_admission import qos_round_scan as _qos_round_scan
from .sema_batch import sema_batch as _sema_batch


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=512, block_k=512):
    return _flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


def decode_attention(q, k, v, kv_pos, q_pos, *, window=0, block_k=512):
    return _decode_attention(
        q, k, v, kv_pos, q_pos, window=window, block_k=block_k,
        interpret=_interpret(),
    )


def paged_decode(q, k_pool, v_pool, block_tbl, lens):
    """Ragged flash-decode over the block-paged KV pool (oracle:
    `ref.paged_decode_ref`, bit-exact in interpret mode)."""
    return _paged_decode(q, k_pool, v_pool, block_tbl, lens,
                         interpret=_interpret())


def paged_prefill(q, k_chunk, v_chunk, k_pool, v_pool, block_tbl, off, lens):
    """Ragged blockwise flash-prefill of one chunked-prefill round: chunk
    KV written into the slots' freshly-taken pool blocks in the same pass
    (aliased pools), causal-within-chunk + full prior-block attention
    (oracle: `ref.paged_prefill_ref`, bit-exact in interpret mode)."""
    return _paged_prefill(q, k_chunk, v_chunk, k_pool, v_pool, block_tbl,
                          off, lens, interpret=_interpret())


def sema_batch(ticket, grant, bucket_seq, requests, post_n, salt, *, block_n=512):
    return _sema_batch(
        ticket, grant, bucket_seq, requests, post_n, salt,
        block_n=block_n, interpret=_interpret(),
    )


def _pad_backlog(tenant_ids, tickets, alive, deadlines, block_n: int):
    """Pad a backlog to the next power of two ≥ block_n, padded rows dead
    (alive=False ⇒ never admitted, expired, or counted).  Steady-state
    serving (backlog ≤ block_n) therefore hits ONE compiled executable for
    every distinct length, and a draining 10k-deep backlog touches
    log₂(N/block_n) shapes instead of one per multiple of block_n —
    compile-cache hits asserted in tests/test_megastep.py."""
    n = len(tenant_ids)
    pad = max(block_n, _next_pow2(n)) - n
    ids = np.pad(np.asarray(tenant_ids, np.int32), (0, pad))
    tks = np.pad(np.asarray(tickets, np.uint32), (0, pad))
    alv = np.pad(np.asarray(alive, bool), (0, pad))
    dls = np.pad(np.asarray(deadlines, np.float32), (0, pad),
                 constant_values=np.inf)
    return ids, tks, alv, dls


def qos_round(state, tenant_ids, tickets, alive, deadlines, now, free_units,
              *, max_units: int, block_n: int = 256):
    """Fused multi-tenant QoS admission round (expire → weighted replenish →
    FCFS admit → reclaim) — `kernels.qos_admission.qos_round_fused` with the
    backlog padded OUTSIDE the jit boundary (see `_pad_backlog`)."""
    n = len(tenant_ids)
    ids, tks, alv, dls = _pad_backlog(tenant_ids, tickets, alive, deadlines,
                                      block_n)
    state2, admitted, expired, leftover = _qos_round_fused(
        state, ids, tks, alv, dls, now, free_units,
        max_units=max_units, block_n=block_n, interpret=_interpret())
    return state2, admitted[:n], expired[:n], leftover


def qos_round_scan(state, tenant_ids, tickets, alive, deadlines, nows,
                   free_units, released, *, max_units: int,
                   block_n: int = 256):
    """Batch-of-K fused admission rounds (`kernels.qos_admission.
    qos_round_scan`) with the same power-of-two backlog padding — the
    megastep admission spine as a standalone entry point.  Returns
    ``(state', admit_round[:n], expire_round[:n], free')``."""
    n = len(tenant_ids)
    ids, tks, alv, dls = _pad_backlog(tenant_ids, tickets, alive, deadlines,
                                      block_n)
    state2, admit_round, expire_round, free = _qos_round_scan(
        state, ids, tks, alv, dls, nows, free_units, released,
        max_units=max_units, block_n=block_n, interpret=_interpret())
    return state2, admit_round[:n], expire_round[:n], free


def pallas_enabled() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1" or jax.default_backend() == "tpu"
