"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute via interpret=True; on TPU they
compile natively.  The model code keeps the pure-jnp path as default (the
512-device host dry-run cannot lower Pallas); serving/benchmarks opt in via
use_pallas=True or REPRO_USE_PALLAS=1.
"""

from __future__ import annotations

import os

import jax

from .decode_attention import decode_attention as _decode_attention
from .flash_attention import flash_attention_fwd as _flash_attention_fwd
from .sema_batch import sema_batch as _sema_batch


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=512, block_k=512):
    return _flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


def decode_attention(q, k, v, kv_pos, q_pos, *, window=0, block_k=512):
    return _decode_attention(
        q, k, v, kv_pos, q_pos, window=window, block_k=block_k,
        interpret=_interpret(),
    )


def sema_batch(ticket, grant, bucket_seq, requests, post_n, salt, *, block_n=512):
    return _sema_batch(
        ticket, grant, bucket_seq, requests, post_n, salt,
        block_n=block_n, interpret=_interpret(),
    )


def pallas_enabled() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1" or jax.default_backend() == "tpu"
