"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute via interpret=True; on TPU they
compile natively.  The model code keeps the pure-jnp path as default (the
512-device host dry-run cannot lower Pallas); serving/benchmarks opt in via
use_pallas=True or REPRO_USE_PALLAS=1.
"""

from __future__ import annotations

import os

import jax
import numpy as np

from .decode_attention import decode_attention as _decode_attention
from .flash_attention import flash_attention_fwd as _flash_attention_fwd
from .qos_admission import qos_round_fused as _qos_round_fused
from .sema_batch import sema_batch as _sema_batch


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=512, block_k=512):
    return _flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


def decode_attention(q, k, v, kv_pos, q_pos, *, window=0, block_k=512):
    return _decode_attention(
        q, k, v, kv_pos, q_pos, window=window, block_k=block_k,
        interpret=_interpret(),
    )


def sema_batch(ticket, grant, bucket_seq, requests, post_n, salt, *, block_n=512):
    return _sema_batch(
        ticket, grant, bucket_seq, requests, post_n, salt,
        block_n=block_n, interpret=_interpret(),
    )


def qos_round(state, tenant_ids, tickets, alive, deadlines, now, free_units,
              *, max_units: int, block_n: int = 256):
    """Fused multi-tenant QoS admission round (expire → weighted replenish →
    FCFS admit → reclaim) — `kernels.qos_admission.qos_round_fused` with the
    backlog padded to the block grid OUTSIDE the jit boundary, so an
    engine's shrinking backlog reuses a handful of compiled shapes instead
    of retracing per length.  Padded rows are dead (alive=False) and cannot
    be admitted, expired, or counted."""
    n = len(tenant_ids)
    npad = -(-max(n, 1) // block_n) * block_n
    pad = npad - n
    ids = np.pad(np.asarray(tenant_ids, np.int32), (0, pad))
    tks = np.pad(np.asarray(tickets, np.uint32), (0, pad))
    alv = np.pad(np.asarray(alive, bool), (0, pad))
    dls = np.pad(np.asarray(deadlines, np.float32), (0, pad),
                 constant_values=np.inf)
    state2, admitted, expired, leftover = _qos_round_fused(
        state, ids, tks, alv, dls, now, free_units,
        max_units=max_units, block_n=block_n, interpret=_interpret())
    return state2, admitted[:n], expired[:n], leftover


def pallas_enabled() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1" or jax.default_backend() == "tpu"
