"""Pallas TPU kernel for the fused multi-tenant QoS admission round — the
whole expire → weighted stride replenish → tombstone-transparent FCFS admit →
reclaim pass of `admission/functional_qos.qos_round`, executed as ONE
VMEM-resident kernel over the backlog.

Structure (mirrors `kernels/sema_batch`'s blocked discipline; oracle:
`ref.qos_round_ref` == `functional_qos.qos_round`):

  * the backlog rows arrive pre-sorted by wrap-safe per-tenant ticket order
    (the argsort is XLA data prep in the wrapper; ranks never need ticket
    values inside the kernel, only the order);
  * grid = (2, nb): phase 0 sweeps the row blocks accumulating per-tenant
    live depth and expiry counts in VMEM scratch (the sequential-grid
    carry, exactly `sema_batch`'s running ticket base);
  * between the sweeps (first step of phase 1) the weighted replenishment
    is solved in CLOSED FORM: tenant s's k-th grant crosses virtual time
    vpass_s + k/w_s, so the stride schedule is the merge of S arithmetic
    sequences.  The kernel selects the first `take` crossings without a
    sort: a 32-step bit-descend over f32-bitcast keys finds the take-th
    smallest crossing, ties resolved in tenant order — bit-identical to
    the reference's stable argsort;
  * the waiting-array poke inverts the coprime ticket stride (17⁻¹ mod T)
    to turn each tenant's enabled window into a permutation-offset compare
    (`bump[j] = Σ_s [((j − start_s)·17⁻¹ mod T) < width_s]`) — no scatter;
  * phase 1 re-sweeps the row blocks: per-block per-tenant live ranks come
    from the MXU strict-lower-triangular matmul (the tri-rank trick) plus
    the carried (S,) alive-count base; admit ⇔ rank < replenished avail;
  * the last step reclaims credit stranded past live demand and decays the
    dead-below-frontier poke slack — final state written once.

O(N·S/block + S·max_units + S·T) work — the O(N²) pairwise rank and the
max_units-length sequential argmin loop of the pre-PR-2 reference are gone
on both paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..admission.functional_qos import STRIDE_INV
from ..core.functional import ticket_order, twa_hash_u32
from ..core.hashfn import MIX32KA

_INF_BITS = 0x7F800000  # f32 +inf bit pattern (crossings are ≥ 0)


def _qos_kernel(scal_u_ref, scal_i_ref, nowf_ref, wf_ref, st_ref, seq_ref,
                ids_ref, alive_ref, dl_ref,
                adm_ref, exp_ref, out_u_ref, out_vp_ref, out_seq_ref,
                out_scal_ref,
                depth_ref, deadb_ref, alloc_ref, availr_ref, carry_ref,
                spent_ref, *, table, block_n, s_pad, max_units, u_pad):
    p = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    salt = scal_u_ref[0]
    free = scal_i_ref[0]
    now = nowf_ref[0]

    ids = ids_ref[0]  # (block_n,) i32, rows pre-sorted by ticket order
    alive_in = alive_ref[0] != 0
    newly = alive_in & (dl_ref[0] <= now)  # deadline-expired this round
    alive2 = alive_in & ~newly

    scols = jax.lax.broadcasted_iota(jnp.int32, (block_n, s_pad), 1)
    onehot = scols == ids[:, None]  # (block_n, Sp)
    oh_alive = onehot & alive2[:, None]
    cnt_alive = jnp.sum(oh_alive.astype(jnp.int32), axis=0)  # (Sp,)

    @pl.when((p == 0) & (j == 0))
    def _init():
        depth_ref[0] = jnp.zeros((s_pad,), jnp.int32)
        deadb_ref[0] = jnp.zeros((s_pad,), jnp.uint32)

    @pl.when(p == 0)
    def _sweep_depth():
        depth_ref[0] = depth_ref[0] + cnt_alive
        deadb_ref[0] = deadb_ref[0] + jnp.sum(
            (onehot & newly[:, None]).astype(jnp.uint32), axis=0)
        # every output block is fully written each visit (revisited at p=1)
        adm_ref[0] = jnp.zeros((block_n,), jnp.int32)
        exp_ref[0] = newly.astype(jnp.int32)

    @pl.when((p == 1) & (j == 0))
    def _replenish():
        weight = wf_ref[0]
        vpass = wf_ref[1]
        grant = st_ref[1]
        consumed = st_ref[2]
        avail0 = (grant - consumed).astype(jnp.int32)
        unmet = jnp.clip(depth_ref[0] - avail0, 0, max_units)

        # crossing matrix: value of tenant s's k-th grant in virtual time
        kf = jax.lax.broadcasted_iota(jnp.float32, (s_pad, u_pad), 1)
        step = jnp.where(weight[:, None] > 0, kf / weight[:, None], jnp.inf)
        step = jnp.where(kf == 0, 0.0, step)  # k=0 crossing is vpass itself
        cross = jnp.where(kf < unmet[:, None].astype(jnp.float32),
                          vpass[:, None] + step, jnp.inf)
        key = jax.lax.bitcast_convert_type(cross, jnp.uint32)
        finite = key < jnp.uint32(_INF_BITS)  # crossings ≥ 0 ⇒ bits monotone
        take = jnp.minimum(
            jnp.minimum(jnp.maximum(free, 0), jnp.int32(max_units)),
            jnp.sum(finite.astype(jnp.int32)))

        # bit-descend: largest θ with count_lt(θ) < take == take-th smallest
        def bit_body(b, theta):
            cand = theta | (jnp.uint32(1) << (jnp.uint32(31) - b.astype(jnp.uint32)))
            cnt_lt = jnp.sum((key < cand).astype(jnp.int32))
            return jnp.where(cnt_lt < take, cand, theta)

        theta = jax.lax.fori_loop(0, 32, bit_body, jnp.uint32(0))
        lt = key < theta
        eq = key == theta
        rem = take - jnp.sum(lt.astype(jnp.int32))
        lt_s = jnp.sum(lt.astype(jnp.int32), axis=1)
        eq_s = jnp.sum(eq.astype(jnp.int32), axis=1)
        # tie units flow in tenant-index order (== the reference's stable
        # argsort over the row-major crossing matrix): exclusive prefix of
        # eq via the strict-lower-triangular MXU matmul
        rows = jax.lax.broadcasted_iota(jnp.int32, (s_pad, s_pad), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (s_pad, s_pad), 1)
        tri = (cols < rows).astype(jnp.float32)
        exc = jax.lax.dot_general(
            tri, eq_s.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(jnp.int32)
        extra = jnp.clip(rem - exc, 0, eq_s)
        alloc = (lt_s + extra).astype(jnp.uint32)

        alloc_ref[0] = alloc
        availr_ref[0] = avail0 + alloc.astype(jnp.int32)
        af = alloc.astype(jnp.float32)
        out_vp_ref[0] = vpass + jnp.where(
            alloc > 0, jnp.where(weight > 0, af / weight, jnp.inf), 0.0)

        # waiting-array poke: enabled window [grant_s, grant_s + width_s),
        # width = alloc + not-yet-reclaimed dead slack, clamped to the
        # issued-ticket frontier; coprime-stride inversion instead of a
        # hash-index scatter
        dead0 = st_ref[3] + deadb_ref[0]
        outstanding = jnp.maximum((st_ref[0] - grant).astype(jnp.int32), 0)
        width = jnp.minimum((alloc + dead0).astype(jnp.int32),
                            outstanding).astype(jnp.uint32)
        jcols = jax.lax.broadcasted_iota(jnp.uint32, (s_pad, table), 1)
        srows = jax.lax.broadcasted_iota(jnp.uint32, (s_pad, table), 0)
        tsalt = salt + (srows + 1) * jnp.uint32(MIX32KA)  # == tenant_salt
        start = twa_hash_u32(tsalt, grant[:, None])
        offs = ((jcols - start) * jnp.uint32(STRIDE_INV)) & jnp.uint32(table - 1)
        out_seq_ref[0] = seq_ref[0] + jnp.sum(
            (offs < width[:, None]).astype(jnp.uint32), axis=0)

        carry_ref[0] = jnp.zeros((s_pad,), jnp.int32)
        spent_ref[0] = jnp.zeros((s_pad,), jnp.uint32)

    @pl.when(p == 1)
    def _sweep_admit():
        # per-tenant exclusive live rank within the block (tri-rank on MXU)
        rows_b = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 0)
        cols_b = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 1)
        trib = (cols_b < rows_b).astype(jnp.float32)
        pre = jax.lax.dot_general(
            trib, oh_alive.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (block_n, Sp)
        base = carry_ref[0]
        rank = jnp.sum(
            jnp.where(onehot, pre.astype(jnp.int32) + base[None, :], 0), axis=1)
        my_avail = jnp.sum(jnp.where(onehot, availr_ref[0][None, :], 0), axis=1)
        admitted = alive2 & (rank < my_avail)
        adm_ref[0] = admitted.astype(jnp.int32)
        exp_ref[0] = newly.astype(jnp.int32)
        carry_ref[0] = base + cnt_alive
        spent_ref[0] = spent_ref[0] + jnp.sum(
            (onehot & admitted[:, None]).astype(jnp.uint32), axis=0)

    @pl.when((p == 1) & (j == nb - 1))
    def _fin():
        grant = st_ref[1]
        alloc = alloc_ref[0]
        spent = spent_ref[0]
        dead0 = st_ref[3] + deadb_ref[0]
        depth_after = depth_ref[0] - spent.astype(jnp.int32)
        avail_after = availr_ref[0] - spent.astype(jnp.int32)
        surplus = jnp.maximum(avail_after - depth_after, 0).astype(jnp.uint32)
        out_u_ref[0] = grant + alloc
        out_u_ref[1] = st_ref[2] + spent + surplus
        out_u_ref[2] = dead0 - jnp.minimum(dead0, surplus)  # frontier decay
        out_u_ref[3] = alloc
        leftover = (free - jnp.sum(alloc.astype(jnp.int32))
                    + jnp.sum(surplus.astype(jnp.int32)))
        out_scal_ref[...] = jnp.zeros((8,), jnp.int32).at[0].set(leftover)


def _roundup(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit,
                   static_argnames=("max_units", "block_n", "interpret"))
def qos_round_fused(state, tenant_ids, tickets, alive, deadlines, now,
                    free_units, *, max_units: int, block_n: int = 256,
                    interpret: bool = False):
    """Fused multi-tenant admission round (kernel counterpart of
    `functional_qos.qos_round`).  Returns
    ``(state', admitted, expired, leftover)`` — bit-identical to the
    reference in interpret mode.

    The per-tenant ticket-order argsort (wrap-safe: keys are signed
    distances from each tenant's first-seen ticket) runs as XLA data prep;
    everything else — both row sweeps, the closed-form stride allocation,
    the permutation poke — is one `pallas_call` over a (2, nb) grid.
    """
    N = tenant_ids.shape[0]
    S = state.ticket.shape[0]
    T = state.bucket_seq.shape[-1]
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    tickets = jnp.asarray(tickets, jnp.uint32)
    alive = jnp.asarray(alive, bool)
    deadlines = jnp.asarray(deadlines, jnp.float32)

    # wrap-safe per-tenant ticket-order sort — MUST be the same permutation
    # the reference rank path uses (bit-exactness), hence the shared helper
    order = ticket_order(tenant_ids, tickets, S)

    block_n = min(block_n, _roundup(max(N, 8), 8))
    pad = max(_roundup(N, block_n), block_n) - N  # ≥ 1 block even for N=0
    nb = (N + pad) // block_n
    ids_p = jnp.pad(tenant_ids[order], (0, pad))
    alive_p = jnp.pad(alive[order], (0, pad))
    dl_p = jnp.pad(deadlines[order], (0, pad), constant_values=jnp.inf)

    s_pad = _roundup(S, 128)
    u_pad = _roundup(max_units, 128)
    zpad = (0, s_pad - S)
    wf = jnp.stack([jnp.pad(state.weight, zpad), jnp.pad(state.vpass, zpad)])
    st = jnp.stack([jnp.pad(x, zpad) for x in
                    (state.ticket, state.grant, state.consumed, state.dead)])
    scal_u = jnp.zeros((8,), jnp.uint32).at[0].set(
        jnp.asarray(state.salt, jnp.uint32))
    scal_i = jnp.zeros((8,), jnp.int32).at[0].set(
        jnp.asarray(free_units, jnp.int32))
    nowf = jnp.zeros((8,), jnp.float32).at[0].set(
        jnp.asarray(now, jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_qos_kernel, table=T, block_n=block_n, s_pad=s_pad,
                          max_units=max_units, u_pad=u_pad),
        grid=(2, nb),
        in_specs=[
            pl.BlockSpec((8,), lambda p, j: (0,)),
            pl.BlockSpec((8,), lambda p, j: (0,)),
            pl.BlockSpec((8,), lambda p, j: (0,)),
            pl.BlockSpec((2, s_pad), lambda p, j: (0, 0)),
            pl.BlockSpec((4, s_pad), lambda p, j: (0, 0)),
            pl.BlockSpec((1, T), lambda p, j: (0, 0)),
            pl.BlockSpec((1, block_n), lambda p, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda p, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda p, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda p, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda p, j: (0, j)),
            pl.BlockSpec((4, s_pad), lambda p, j: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda p, j: (0, 0)),
            pl.BlockSpec((1, T), lambda p, j: (0, 0)),
            pl.BlockSpec((8,), lambda p, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, N + pad), jnp.int32),   # admitted
            jax.ShapeDtypeStruct((1, N + pad), jnp.int32),   # expired
            jax.ShapeDtypeStruct((4, s_pad), jnp.uint32),    # grant/cons/dead/alloc
            jax.ShapeDtypeStruct((1, s_pad), jnp.float32),   # vpass
            jax.ShapeDtypeStruct((1, T), jnp.uint32),        # bucket_seq
            jax.ShapeDtypeStruct((8,), jnp.int32),           # leftover
        ],
        scratch_shapes=[
            pltpu.VMEM((1, s_pad), jnp.int32),    # depth
            pltpu.VMEM((1, s_pad), jnp.uint32),   # dead bump
            pltpu.VMEM((1, s_pad), jnp.uint32),   # alloc
            pltpu.VMEM((1, s_pad), jnp.int32),    # avail after replenish
            pltpu.VMEM((1, s_pad), jnp.int32),    # live-rank carry
            pltpu.VMEM((1, s_pad), jnp.uint32),   # admitted spend
        ],
        interpret=interpret,
    )(scal_u, scal_i, nowf, wf, st, state.bucket_seq.reshape(1, -1),
      ids_p.reshape(1, -1), alive_p.astype(jnp.int32).reshape(1, -1),
      dl_p.reshape(1, -1))

    adm_s, exp_s, out_u, out_vp, out_seq, out_scal = outs
    admitted = jnp.zeros((N,), bool).at[order].set(adm_s[0, :N] != 0)
    expired = jnp.zeros((N,), bool).at[order].set(exp_s[0, :N] != 0)
    new_state = state._replace(
        grant=out_u[0, :S], consumed=out_u[1, :S], dead=out_u[2, :S],
        vpass=out_vp[0, :S], bucket_seq=out_seq[0])
    return new_state, admitted, expired, out_scal[0]


@functools.partial(jax.jit,
                   static_argnames=("max_units", "block_n", "interpret"))
def qos_round_scan(state, tenant_ids, tickets, alive, deadlines, nows,
                   free_units, released, *, max_units: int,
                   block_n: int = 256, interpret: bool = False):
    """Batch-of-rounds entry point: K fused admission rounds as ONE jitted
    `lax.scan` over the kernel, with static padded shapes throughout — the
    megastep's admission spine (oracle: `ref.qos_round_scan_ref`, i.e. K
    sequential `functional_qos.qos_round` calls — bit-identical).

    Per round k: rows admitted/expired leave the alive set for round k+1;
    ``released[k]`` units (slot completions/preemptions fed back by the
    engine) join the carried free pool BEFORE the round's replenish (the
    `functional_qos.qos_scan_round` feedback contract); the leftover pool
    carries.  ``nows``: (K,) f32.  Returns ``(state', admit_round (N,)
    i32, expire_round (N,) i32, free')`` where round indices are -1 for
    rows never admitted/expired.
    """
    N = tenant_ids.shape[0]
    nows = jnp.asarray(nows, jnp.float32)
    released = jnp.asarray(released, jnp.int32)
    alive = jnp.asarray(alive, bool)
    free0 = jnp.asarray(free_units, jnp.int32)

    def body(carry, x):
        st, aliv, free = carry
        now, rel = x
        st, adm, exp, leftover = qos_round_fused(
            st, tenant_ids, tickets, aliv, deadlines, now, free + rel,
            max_units=max_units, block_n=block_n, interpret=interpret)
        return (st, aliv & ~adm & ~exp, leftover), (adm, exp)

    (state, _, free), (adm_k, exp_k) = jax.lax.scan(
        body, (state, alive, free0), (nows, released))
    # (K, N) event masks → first (only) round index per row, -1 if never
    admit_round = jnp.where(adm_k.any(0), jnp.argmax(adm_k, axis=0), -1)
    expire_round = jnp.where(exp_k.any(0), jnp.argmax(exp_k, axis=0), -1)
    return state, admit_round.astype(jnp.int32), \
        expire_round.astype(jnp.int32), free
