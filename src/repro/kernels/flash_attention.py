"""Pallas TPU flash-attention forward (prefill/train hot spot).

TPU-native adaptation (not a CUDA port): the kernel is organized around the
MXU/VMEM hierarchy —

  * grid = (batch·kv_heads, q_blocks, kv_blocks); the kv_blocks axis is the
    *innermost sequential* dimension on TPU, so the online-softmax state
    (m, l, acc) lives in VMEM scratch and is carried across kv iterations —
    the TPU analogue of a CUDA thread-block loop with smem accumulators;
  * BlockSpecs tile q/k/v into (block_q × head_dim) / (block_k × head_dim)
    VMEM slabs; head_dim (64–256) is MXU-lane aligned; block defaults
    (512, 512) keep the working set ≈ (2·bq·hd + 2·bk·hd + bq·bk)·4 B ≲ 4 MiB
    of the 16 MiB VMEM per core, leaving room for double buffering;
  * GQA is expressed in the grid (one program per kv head), with the q-head
    group folded into the q block rows — no repeated KV in HBM, the exact
    trick the pure-jnp path can't express;
  * causal/window masking is computed from iotas (VPU) — no mask tensors in
    HBM; fully-masked (q,k) grid cells are skipped via a cheap early exit on
    the block bounds.

Numerics match ref.mha_ref to bf16/f32 tolerance: fp32 m/l/acc, one rescale
per kv block (the standard 2-pass-free online softmax).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale, block_q, block_k, seq_len, causal, window, group):
    """One (bh, iq, ik) grid cell: fold KV block ik into the online softmax
    state for q block iq. q rows are (group × block_q) stacked GQA heads."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    def compute():
        q = q_ref[0]  # (group*block_q, hd)
        k = k_ref[0]  # (block_k, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (group*block_q, block_k)

        # positions: q rows are group-major [g0 rows.., g1 rows..] — same
        # sequence positions per group.
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % block_q + q_start
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + k_start
        mask = cols < seq_len
        d = rows - cols
        if causal:
            mask &= d >= 0
        if window > 0:
            mask &= d < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (group*block_q, 1)
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    if causal:
        # skip blocks entirely above the diagonal (and outside the window)
        live = k_start <= q_start + block_q - 1
        if window > 0:
            live &= (k_start + block_k - 1) >= (q_start - window + 1)
        pl.when(live)(compute)
    else:
        compute()

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_fwd(q, k, v, *, causal=True, window=0, block_q=512,
                        block_k=512, interpret=False):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) → (B, Sq, H, hd).

    GQA: H = G·KV; grid programs are per-(batch·kv_head); the G q-heads of a
    kv head are stacked into the q-block rows.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0, (Sq, block_q)
    pad_k = (-Sk) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(hd)

    # layout: (B·KV, G·Sq, hd) for q — G heads stacked per kv-head program
    qr = (q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(B * KV, G * Sq, hd))
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pad_k, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pad_k, hd)

    grid = (B * KV, Sq // block_q, (Sk + pad_k) // block_k)

    # q block: all G groups' rows for this q block, stacked group-major
    def q_index(bh, iq, ik):
        return (bh, iq, 0)

    def kv_index(bh, iq, ik):
        return (bh, ik, 0)

    # regroup q so that a q-block slice pulls the same block from each group:
    # (B·KV, G, Sq, hd) → blocks along Sq with G folded into rows
    qr = qr.reshape(B * KV, G, Sq, hd).transpose(0, 2, 1, 3)  # (bh, Sq, G, hd)
    qr = qr.reshape(B * KV, Sq // block_q, block_q, G, hd).transpose(0, 1, 3, 2, 4)
    qr = qr.reshape(B * KV, Sq // block_q * G * block_q, hd)
    # now rows of one q block = [g0:block_q, g1:block_q, ...] contiguous

    out = pl.pallas_call(
        functools.partial(
            _fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
            seq_len=Sk, causal=causal, window=window, group=G,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G * block_q, hd), q_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, G * block_q, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * KV, Sq // block_q * G * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, hd), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    # undo the block-group-major row layout
    out = out.reshape(B * KV, Sq // block_q, G, block_q, hd).transpose(0, 2, 1, 3, 4)
    out = out.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out
