"""Pallas TPU kernel: ragged blockwise flash-PREFILL over a block-paged KV
pool — the chunked-prefill counterpart of `kernels/paged_decode`.

One launch serves every slot's prompt chunk of the round: slot s has
``lens[s]`` new tokens starting at absolute position ``off[s]`` (= tokens
already in its pool blocks), and the kernel

  * **writes the chunk's K/V into the freshly-taken pool blocks in the
    same pass** — the pool arrays are aliased in/out
    (``input_output_aliases``), and each grid step that overlaps the
    chunk window merges the chunk rows into the block it just fetched
    (`ref.paged_prefill_merge` — a 0/1 one-hot matmul, exact in f32 and
    MXU-shaped, instead of an in-kernel dynamic gather) before writing it
    back through a table-driven output index map;
  * computes **causal-within-chunk + full attention to all prior pool
    blocks** for the chunk queries over exactly the blocks the slot holds
    — the online-softmax recurrence is `ref.flash_prefill_block`, shared
    VERBATIM with the oracle `ref.paged_prefill_ref`, so interpret-mode
    bit-exactness pins the paging/writeback logic, not fp reassociation.

TPU adaptation notes:
  * grid = (S, KV, MB), block axis innermost-sequential; block table,
    chunk offsets, and chunk lengths ride in as scalar prefetch
    (`pltpu.PrefetchScalarGridSpec`) so both the K/V **input** index maps
    (``tbl[s, i]``, −1 clamped to the trash block) and the **output**
    index maps (the merged block's id when the step overlaps the chunk,
    the trash block otherwise) are data-driven;
  * the pools are padded with one TRASH block (index NB): non-writing
    grid steps aim their mandatory output copy there, so aliased pool
    content is mutated only by each block's owning slot — the engine's
    no-aliasing invariant makes the writes race-free;
  * raggedness: blocks at or past a slot's written range
    (``i·BS ≥ off+len``) and idle slots (``len == 0``) are skipped with
    `pl.when` — empty rounds cost no flops;
  * GQA: the G q-heads of a kv head are stacked into the q block rows
    (group-major, `flash_attention`'s trick), each row masked by its own
    chunk position — one program per (slot, kv head).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF, flash_prefill_block, paged_prefill_merge


def _prefill_kernel(tbl_ref, off_ref, len_ref, q_ref, kc_ref, vc_ref,
                    kp_ref, vp_ref, o_ref, ko_ref, vo_ref,
                    acc_ref, m_ref, l_ref, *, scale, block_size, chunk_cap):
    s = pl.program_id(0)
    i = pl.program_id(2)
    BS = block_size

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    off = off_ref[s]
    ln = len_ref[s]

    @pl.when((i * BS < off + ln) & (ln > 0))  # ragged bound: skip dead blocks
    def _block():
        q = q_ref[0, 0]          # (G·CT, hd) — GQA groups stacked row-major
        tpos = i * BS + jax.lax.iota(jnp.int32, BS)
        # merge this block's slice of the chunk K/V (freshly-taken blocks
        # get their rows here — the in-pass writeback), then attend over
        # the MERGED content: the partially-filled boundary block serves
        # both its old rows and the chunk's new ones in one fetch
        sel, ku = paged_prefill_merge(kc_ref[0, 0], tpos, off, ln)
        _, vu = paged_prefill_merge(vc_ref[0, 0], tpos, off, ln)
        kblk = jnp.where(sel[:, None], ku, kp_ref[0, 0])
        vblk = jnp.where(sel[:, None], vu, vp_ref[0, 0])
        ko_ref[0, 0] = kblk
        vo_ref[0, 0] = vblk
        rows = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], 1), 0) \
            % chunk_cap
        qpos = off + rows
        mask = (rows < ln) & (tpos[None, :] <= qpos)  # causal + ragged
        m, l, acc = flash_prefill_block(
            q, kblk, vblk, mask, m_ref[...], l_ref[...], acc_ref[...],
            scale=scale)
        m_ref[...] = m
        l_ref[...] = l
        acc_ref[...] = acc

    @pl.when(i == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill(q, k_chunk, v_chunk, k_pool, v_pool, block_tbl, off, lens,
                  *, interpret=False):
    """q: (S, CT, H, hd) chunk queries; k_chunk/v_chunk: (S, CT, KV, hd);
    k_pool/v_pool: (NB, BS, KV, hd); block_tbl: (S, MB) int32 (-1 ⇒
    unallocated); off: (S,) int32 chunk start positions; lens: (S,) int32
    chunk lengths (0 ⇒ idle slot).  Returns ``(out (S, CT, H, hd),
    k_pool', v_pool')`` with the chunk KV written into the slots' blocks.
    Oracle: `ref.paged_prefill_ref` (bit-exact in interpret mode)."""
    S, CT, H, hd = q.shape
    NB, BS, KV, _ = k_pool.shape
    MB = block_tbl.shape[1]
    assert H % KV == 0
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    qr = (q.reshape(S, CT, KV, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(S, KV, G * CT, hd))
    kc = k_chunk.transpose(0, 2, 1, 3)     # (S, KV, CT, hd)
    vc = v_chunk.transpose(0, 2, 1, 3)
    pad = ((0, 1), (0, 0), (0, 0), (0, 0))  # + the trash block (index NB)
    kp = jnp.pad(k_pool, pad).transpose(2, 0, 1, 3)  # (KV, NB+1, BS, hd)
    vp = jnp.pad(v_pool, pad).transpose(2, 0, 1, 3)
    tbl = jnp.asarray(block_tbl, jnp.int32)
    off = jnp.asarray(off, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)

    def kv_map(s, h, i, tbl_ref, off_ref, len_ref):
        # table-driven DMA: -1 (unallocated) clamps to pool block 0, same
        # as the oracle — compute for it is always masked/skipped (a
        # slot's written range never reaches an unallocated block)
        return (h, jnp.maximum(tbl_ref[s, i], 0), 0, 0)

    def wr_map(s, h, i, tbl_ref, off_ref, len_ref):
        # the mandatory per-step output copy lands on the merged block
        # only when this step overlaps the chunk window; everything else
        # (skipped steps, pure-attention steps over old blocks) goes to
        # the trash block, keeping aliased pool content owner-written
        o, ln = off_ref[s], len_ref[s]
        wr = (ln > 0) & (i * BS < o + ln) & (i * BS + BS > o)
        return (h, jnp.where(wr, jnp.maximum(tbl_ref[s, i], 0), NB), 0, 0)

    def q_map(s, h, i, tbl_ref, off_ref, len_ref):
        return (s, h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, KV, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G * CT, hd), q_map),
            pl.BlockSpec((1, 1, CT, hd), q_map),
            pl.BlockSpec((1, 1, CT, hd), q_map),
            pl.BlockSpec((1, 1, BS, hd), kv_map),
            pl.BlockSpec((1, 1, BS, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G * CT, hd), q_map),
            pl.BlockSpec((1, 1, BS, hd), wr_map),
            pl.BlockSpec((1, 1, BS, hd), wr_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * CT, hd), jnp.float32),
            pltpu.VMEM((G * CT, 1), jnp.float32),
            pltpu.VMEM((G * CT, 1), jnp.float32),
        ],
    )
    out, kp2, vp2 = pl.pallas_call(
        functools.partial(_prefill_kernel, scale=scale, block_size=BS,
                          chunk_cap=CT),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((S, KV, G * CT, hd), q.dtype),
            jax.ShapeDtypeStruct((KV, NB + 1, BS, hd), k_pool.dtype),
            jax.ShapeDtypeStruct((KV, NB + 1, BS, hd), v_pool.dtype),
        ],
        input_output_aliases={6: 1, 7: 2},  # pools flow through, in-place
        interpret=interpret,
    )(tbl, off, lens, qr, kc, vc, kp, vp)
    out = (out.reshape(S, KV, G, CT, hd).transpose(0, 3, 1, 2, 4)
           .reshape(S, CT, H, hd))
    return (out, kp2.transpose(1, 2, 0, 3)[:NB],
            vp2.transpose(1, 2, 0, 3)[:NB])
