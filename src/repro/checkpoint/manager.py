"""Sharded, asynchronous, fault-tolerant checkpointing.

Layout (tensorstore-free, works on any POSIX FS / NFS):

    <dir>/step_000123.tmp/          # written first
        meta.json                   # step, tree structure, shard map, mesh
        shard_00000.npz             # this host's param/opt leaves (flat name → array)
    <dir>/step_000123/              # atomic rename when ALL shards committed

Production properties:
  * async: `save` snapshots to host RAM (device_get) and returns; a writer
    pool persists in the background — training never blocks on the FS;
  * writer-slot admission is a TWA semaphore (`max_concurrent_io`): with
    hundreds of hosts, unthrottled writers melt the shared FS; FIFO admission
    means checkpoint *order* is preserved under backlog (no newer-overtakes-
    older inversions) — queue_depth doubles as an "FS is slow" alarm;
  * atomicity: per-host shard files + a commit marker per host; the rename to
    the final name happens only when every expected host committed (restart
    ignores .tmp directories — a torn checkpoint is invisible);
  * emergency synchronous save on failure signals (SIGTERM from the cluster
    scheduler) — see runtime/coordinator.py;
  * restore: picks the newest COMPLETE step ≤ `at_step` (or the newest);
    elastic re-sharding is handled by saving every leaf unsharded-logical
    (host 0 of each replica group writes; restore reshards by the new mesh).

This container runs single-host, so host_id=0 writes everything; the
multi-host paths (expected_hosts > 1) are exercised by tests that simulate
several "hosts" writing into one directory.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

from ..core.twa_semaphore import TWASemaphore


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz round-trips f32; proto restores bf16
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def _unflatten_like(proto, flat: dict):
    import jax.numpy as jnp

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(proto)
    leaves = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = np.asarray(jnp.asarray(arr).astype(leaf.dtype))  # bf16-safe
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        *,
        host_id: int = 0,
        expected_hosts: int = 1,
        max_concurrent_io: int = 2,
        keep: int = 3,
        finalize_timeout: float = 300.0,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.expected_hosts = expected_hosts
        self.keep = keep
        # how long host 0 waits for the other hosts' commit markers before
        # giving up on publishing a step (the .tmp dir stays, invisible to
        # restore; a later save of the same step can still finalize it) —
        # tests drive this down to milliseconds to exercise the path
        self.finalize_timeout = float(finalize_timeout)
        # Writer-slot admission: the paper's semaphore as I/O throttle.
        self._io_slots = TWASemaphore(max_concurrent_io, waiting="futex")
        self._pending: list[threading.Thread] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------- save ----

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot to host memory, then persist asynchronously."""
        flat, _ = _flatten(jax.device_get(tree))
        t = threading.Thread(target=self._persist, args=(step, flat), daemon=True)
        with self._lock:
            self._pending.append(t)
        t.start()
        if blocking:
            t.join()

    def save_sync(self, step: int, tree) -> None:
        """Emergency path (failure signal): bypass the queue, write NOW."""
        flat, _ = _flatten(jax.device_get(tree))
        self._persist(step, flat, emergency=True)

    def _persist(self, step: int, flat: dict, emergency: bool = False) -> None:
        if not emergency:
            self._io_slots.take()  # FIFO writer slot
        try:
            tmp = self.dir / f"step_{step:09d}.tmp"
            tmp.mkdir(parents=True, exist_ok=True)
            shard = tmp / f"shard_{self.host_id:05d}.npz"
            partial = shard.with_suffix(f".{threading.get_ident()}.partial")
            try:
                with open(partial, "wb") as f:
                    np.savez(f, **flat)
                os.replace(partial, shard)  # atomic per shard
                (tmp / f"commit_{self.host_id:05d}").touch()
            except FileNotFoundError:
                # a concurrent duplicate save of the same step already
                # finalized (renamed) the tmp dir — nothing left to do
                if not (self.dir / f"step_{step:09d}").exists():
                    raise
                return
            if self.host_id == 0:
                self._try_finalize(step)
        finally:
            if not emergency:
                self._io_slots.post()

    def _try_finalize(self, step: int, timeout: float | None = None) -> bool:
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if timeout is None:
            timeout = self.finalize_timeout
        deadline = time.time() + timeout
        while time.time() < deadline:
            if final.exists():
                return True  # a concurrent saver of the same step published it
            commits = list(tmp.glob("commit_*"))
            if len(commits) >= self.expected_hosts:
                meta = {"step": step, "hosts": self.expected_hosts,
                        "time": time.time()}
                try:
                    (tmp / "meta.json").write_text(json.dumps(meta))
                    os.replace(tmp, final)  # atomic publish
                except FileNotFoundError:
                    # lost the publish race to a concurrent finalizer of the
                    # same step — the checkpoint exists either way
                    if not final.exists():
                        raise
                self._gc()
                return True
            time.sleep(0.01)
        return False

    def _gc(self):
        steps = sorted(self.complete_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    # ---------------------------------------------------------- restore ----

    def complete_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and not p.name.endswith(".tmp") and (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.complete_steps()
        return steps[-1] if steps else None

    def restore(self, proto, step: int | None = None):
        """Restore into the structure/dtypes of `proto` (works across mesh
        sizes: arrays are stored logically-unsharded; the caller re-device-
        puts with the current shardings). Returns (tree, step)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        flat: dict = {}
        for shard in sorted(d.glob("shard_*.npz")):
            with np.load(shard) as z:
                flat.update({k: z[k] for k in z.files})
        return _unflatten_like(proto, flat), step

    def io_telemetry(self) -> dict:
        return {"writers_queued": self._io_slots.queue_depth(),
                "slots_free": self._io_slots.available()}
