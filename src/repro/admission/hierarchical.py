"""Two-level weighted TWA-semaphore tree: global slots → per-tenant QoS.

The paper's flat TWA semaphore gives scalable FCFS over ONE queue; a
multi-tenant engine needs isolation: tenant A's burst must not starve
tenant B, and a paying tier should get a larger admission share.  The tree:

  root   — a conserved pool of S global slots (a counter guarded by the
           tree lock; slots only move, never duplicate);
  leaves — one TWA semaphore per tenant (``cancellation=True``), all
           sharing one process-global waiting array, so a release pokes
           O(freed-slots) buckets no matter how many thousands of tenants
           exist — the paper's dispersal argument applied across the tree.

Weighted replenishment is **stride scheduling**: every leaf carries a
virtual ``pass_``; granting a slot to a leaf advances its pass by
``1/weight``; a freed slot goes to the *waiting* leaf with the minimum
pass.  Under saturation the admission shares converge to the weights;
idle tenants are caught up to the global virtual time when they re-enter
so they cannot hoard credit (work-conserving: if nobody waits, the slot
parks in the root free pool and the next arrival, any tenant, takes it).

FCFS holds *within* a tenant (leaf ticket order, tombstone-skip for
abandoned waiters); *across* tenants the order is weighted-fair by
construction — exactly the "weighted grant replenishment" of the ISSUE.

Cancellation interplay: a tombstoned waiter whose slot was already posted
to its leaf leaves the unit parked at an idle leaf; ``_reclaim_idle``
pulls such stranded units back into the root pool (a take against one's
own idle leaf is non-blocking by the fast-path invariant) and re-runs the
weighted grant so the slot reaches whoever is actually waiting.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from ..core.twa_semaphore import TWASemaphore, WaitingArray
from .cancellable import CancelStats, CancellableTake


@dataclass
class _Leaf:
    tenant_id: str
    weight: float
    sem: TWASemaphore
    pass_: float = 0.0  # stride virtual time; +1/weight per granted slot
    granted: int = 0  # slots ever granted to this tenant (share telemetry)
    admitted: int = 0  # acquires that succeeded
    cancelled: int = 0  # acquires abandoned (timeout/deadline/explicit)
    stats: CancelStats = field(default_factory=CancelStats)


class HierarchicalTWASemaphore:
    """Root slot pool + per-tenant cancellable TWA leaves."""

    def __init__(self, total_slots: int, *, waiting: str = "futex",
                 array: WaitingArray | None = None,
                 long_term_threshold: int = 1):
        assert total_slots >= 0
        self.total_slots = total_slots
        self._free = total_slots  # unassigned slots at the root
        self._waiting = waiting
        self._threshold = long_term_threshold
        # One waiting array for the WHOLE tree (paper: process-global).
        self._array = array if array is not None else WaitingArray()
        self._leaves: dict[str, _Leaf] = {}
        self._lock = threading.Lock()
        self._vtime = 0.0

    # -- tenants -----------------------------------------------------------

    def register(self, tenant_id: str, weight: float = 1.0) -> None:
        assert weight > 0
        with self._lock:
            if tenant_id in self._leaves:
                self._leaves[tenant_id].weight = weight
                return
            sem = TWASemaphore(0, waiting=self._waiting,
                               long_term_threshold=self._threshold,
                               array=self._array, cancellation=True)
            self._leaves[tenant_id] = _Leaf(tenant_id, weight, sem,
                                            pass_=self._vtime)

    def _leaf(self, tenant_id: str) -> _Leaf:
        leaf = self._leaves.get(tenant_id)
        if leaf is None:
            raise KeyError(f"unregistered tenant {tenant_id!r}")
        return leaf

    # -- weighted grant (root → leaf) --------------------------------------

    def _charge_locked(self, leaf: _Leaf) -> None:
        # Idle catch-up then stride advance; _vtime tracks the granted pass
        # so re-entering tenants cannot replay banked idle time.
        leaf.pass_ = max(leaf.pass_, self._vtime)
        self._vtime = leaf.pass_
        leaf.pass_ += 1.0 / leaf.weight
        leaf.granted += 1

    def _grant_one_locked(self) -> None:
        """Route one free slot: min-pass waiting leaf, else the root pool."""
        waiting = [l for l in self._leaves.values()
                   if l.sem.live_queue_depth() > 0]
        if not waiting:
            self._free += 1
            return
        leaf = min(waiting, key=lambda l: (max(l.pass_, self._vtime),
                                           l.tenant_id))
        self._charge_locked(leaf)
        leaf.sem.post(1)

    def _reclaim_idle_locked(self) -> int:
        """Pull stranded units (tombstone-skipped past every live waiter of
        their leaf) back to the root and re-grant them."""
        reclaimed = 0
        for leaf in self._leaves.values():
            while leaf.sem.available() > 0 and leaf.sem.live_queue_depth() == 0:
                leaf.sem.take()  # non-blocking: available() > 0 fast path
                leaf.granted -= 1
                leaf.pass_ -= 1.0 / leaf.weight  # refund the stride charge
                reclaimed += 1
        for _ in range(reclaimed):
            self._grant_one_locked()
        return reclaimed

    # -- the semaphore surface ---------------------------------------------

    def acquire(self, tenant_id: str, *, timeout: float | None = None,
                deadline: float | None = None) -> bool:
        """Take one slot for ``tenant_id``.  Blocks FCFS within the tenant,
        weighted-fair across tenants.  Returns False iff abandoned at the
        timeout/deadline (the ticket is tombstoned, later live waiters are
        unaffected)."""
        if deadline is None and timeout is not None:
            deadline = time.monotonic() + timeout
        with self._lock:
            leaf = self._leaf(tenant_id)
            leaf.pass_ = max(leaf.pass_, self._vtime)  # idle catch-up
            if self._free > 0:
                # Work-conserving fast path: free slots mean nobody is
                # waiting anywhere — grant immediately, charged as usual.
                self._free -= 1
                self._charge_locked(leaf)
                leaf.sem.post(1)
            handle = CancellableTake(leaf.sem, leaf.stats)
        got = handle.wait(deadline)
        with self._lock:
            if got:
                leaf.admitted += 1
            else:
                leaf.cancelled += 1
                self._reclaim_idle_locked()
        return got

    def release(self, tenant_id: str | None = None) -> None:
        """Return one slot to the root; it flows to the min-pass waiting
        tenant (stride) or back to the free pool."""
        with self._lock:
            self._reclaim_idle_locked()
            self._grant_one_locked()

    # -- telemetry ----------------------------------------------------------

    def queue_depths(self) -> dict[str, int]:
        with self._lock:
            return {t: l.sem.live_queue_depth() for t, l in self._leaves.items()}

    def shares(self) -> dict[str, float]:
        """Fraction of all granted slots per tenant (→ weights under
        saturation)."""
        with self._lock:
            total = sum(l.granted for l in self._leaves.values())
            return {t: (l.granted / total if total else 0.0)
                    for t, l in self._leaves.items()}

    def telemetry(self) -> dict:
        with self._lock:
            return {
                "free": self._free,
                "vtime": self._vtime,
                "tenants": {
                    t: {"weight": l.weight, "granted": l.granted,
                        "admitted": l.admitted, "cancelled": l.cancelled,
                        "queue_depth": l.sem.live_queue_depth(),
                        "tombstones_skipped": l.sem.tombstones_skipped}
                    for t, l in self._leaves.items()
                },
            }
