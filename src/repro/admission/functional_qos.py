"""Batched multi-tenant QoS admission — the in-graph counterpart.

Extends `core.functional`'s MultiSemaState with per-tenant **weights**,
**deadline masks**, and a **tombstone-transparent admission rule**, so a
whole multi-tenant admission round (expire → admit → replenish → poke)
is one vectorized pass under jit — the reference semantics for a future
Pallas variant in `kernels/` (same role `core.functional` plays for
`kernels/sema_batch`).

State (all per-tenant vectors of length S, plus one shared waiting array):

  ticket / grant — the paper's counters, per tenant.  ``grant`` advances
      only via weighted replenishment from the global slot pool.
  consumed       — grant units actually used by admitted live rows;
      ``avail = grant − consumed`` is a tenant's spendable credit.
  dead           — tombstoned (deadline-expired / cancelled) tickets not
      yet absorbed by reclaim (dead-below-frontier slack); widens the
      conservative bucket-poke window, exactly generalizing `post_batch`'s
      ``[grant, grant+n)`` window (reduces to it when dead == 0), and
      decays as reclaim burns the credit those tombstones stranded — the
      poke cost no longer grows monotonically with total expirations.
  weight / vpass — stride scheduler: granting a unit advances the
      tenant's virtual pass by 1/weight; free units flow to the
      minimum-pass tenant with unmet live demand, so admission shares
      converge to the weights under saturation.
  bucket_seq     — ONE waiting array shared by all S tenant semaphores
      (paper §1: the array is process-global); tenants are dispersed by
      salting the TWA hash per tenant.

The admission rule is the batched tombstone-skip: a live row is admitted
iff its FCFS rank *among live rows of its tenant* is below the tenant's
avail — dead tickets anywhere in the queue (head, middle, or deep) are
transparent, so grant units always reach the earliest live waiters and
FCFS among live tickets is preserved (`core.functional.live_fifo_rank`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.functional import (
    _sdist,
    live_fifo_rank,
    live_fifo_rank_pairwise,
    segment_counts,
    twa_hash_u32,
)
from ..core.hashfn import TICKET_STRIDE, MIX32KA

DEFAULT_TABLE_SIZE = 1024

# 17⁻¹ mod 2³² — reduced mod any power-of-two table size it stays the
# inverse, so ((bucket − start)·STRIDE_INV) mod T recovers a ticket's offset
# within a poke window (the coprime-stride permutation, cf. kernels/sema_batch).
STRIDE_INV = pow(TICKET_STRIDE, -1, 1 << 32)


class QoSState(NamedTuple):
    ticket: jax.Array  # (S,) u32 — per-tenant tickets issued
    grant: jax.Array  # (S,) u32 — per-tenant units replenished
    consumed: jax.Array  # (S,) u32 — units spent on admitted live rows
    dead: jax.Array  # (S,) u32 — tombstoned tickets (poke-window slack)
    weight: jax.Array  # (S,) f32 — QoS weights
    vpass: jax.Array  # (S,) f32 — stride virtual pass
    bucket_seq: jax.Array  # (T,) u32 — shared waiting array
    salt: jax.Array  # u32


def make_qos(weights, table_size: int = DEFAULT_TABLE_SIZE,
             salt: int = 0x9E3779B9) -> QoSState:
    """Weights must be ≥ 0.  A zero-weight tenant is granted at most ONE
    unit ever (its first virtual-pass crossing), after which its pass
    saturates to +inf and it starves — an intentional floor semantics for
    best-effort tiers; serving engines should validate weights > 0 (the
    `ContinuousBatchingEngine` does)."""
    w = jnp.asarray(weights, jnp.float32)
    assert table_size > 0 and (table_size & (table_size - 1)) == 0
    z = jnp.zeros_like(w, dtype=jnp.uint32)
    return QoSState(ticket=z, grant=z, consumed=z, dead=z, weight=w,
                    vpass=jnp.zeros_like(w),
                    bucket_seq=jnp.zeros((table_size,), jnp.uint32),
                    salt=jnp.uint32(salt))


def tenant_salt(state: QoSState, tenant_ids) -> jax.Array:
    """Per-tenant TWAHash salt — disperses the S logical semaphores over
    the one shared array (the `uintptr_t(L)` component, per tenant)."""
    t = jnp.asarray(tenant_ids, jnp.uint32)
    return state.salt + (t + jnp.uint32(1)) * jnp.uint32(MIX32KA)


def qos_bucket_index(state: QoSState, tenant_ids, tickets) -> jax.Array:
    table = state.bucket_seq.shape[-1]
    h = twa_hash_u32(tenant_salt(state, tenant_ids),
                     jnp.asarray(tickets, jnp.uint32))
    return (h & jnp.uint32(table - 1)).astype(jnp.int32)


def avail(state: QoSState) -> jax.Array:
    """Spendable grant units per tenant (int32, ≥ 0 by invariant)."""
    return _sdist(state.grant, state.consumed)


# -- take ---------------------------------------------------------------------


def qos_take(state: QoSState, tenant_ids: jax.Array, mask: jax.Array,
             deadlines: jax.Array | None = None, now=0.0):
    """Batched ticket issuance for N arrivals against S tenants.

    Rows whose deadline already passed at arrival are *dead on arrival*:
    they receive no ticket and are reported in ``expired``.  Returns
    ``(state', tickets, buckets, expired)``; admission is decided by
    :func:`qos_admit` (rank among live waiters), not at take time.

    Precision note: deadlines/now compare in float32 under default jax —
    pass RELATIVE times (deltas from a caller-held epoch), not absolute
    wall/monotonic stamps, which lose sub-second resolution at ~1e6 s.
    """
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    if deadlines is None:
        expired = jnp.zeros(mask.shape, bool)
    else:
        expired = mask & (jnp.asarray(deadlines) <= now)
    eff = mask & ~expired
    S = state.ticket.shape[0]
    onehot = jax.nn.one_hot(tenant_ids, S, dtype=jnp.uint32) * \
        eff[:, None].astype(jnp.uint32)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot)  # exclusive, per tenant
    my_rank = jnp.take_along_axis(ranks, tenant_ids[:, None], axis=1)[:, 0]
    tickets = state.ticket[tenant_ids] + my_rank
    new_ticket = state.ticket + segment_counts(tenant_ids, eff, S)
    buckets = qos_bucket_index(state, tenant_ids, tickets)
    return state._replace(ticket=new_ticket), tickets, buckets, expired


# -- expire (tombstone) --------------------------------------------------------


def qos_expire(state: QoSState, tenant_ids: jax.Array, alive: jax.Array,
               deadlines: jax.Array, now):
    """Tombstone waiting rows whose deadline passed: they leave the live
    set (skip-transparent to later admissions) and widen the poke window.
    Returns ``(state', alive', newly_expired)``."""
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    newly = alive & (jnp.asarray(deadlines) <= now)
    per_tenant = segment_counts(tenant_ids, newly, state.ticket.shape[0])
    return state._replace(dead=state.dead + per_tenant), alive & ~newly, newly


# -- admit --------------------------------------------------------------------


def qos_admit(state: QoSState, tenant_ids: jax.Array, tickets: jax.Array,
              alive: jax.Array, *, pairwise_rank: bool = False):
    """Tombstone-transparent weighted-FCFS admission over the live backlog:
    row admitted ⇔ live_fifo_rank < avail[tenant].  Consumes the units.
    Returns ``(state', admitted)``.

    ``pairwise_rank=True`` routes through the retained O(N²) rank path —
    benchmark baseline only; the default is the O(N·S/block) blocked
    prefix (`core.functional.live_fifo_rank`)."""
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    S = state.ticket.shape[0]
    tickets = jnp.asarray(tickets, jnp.uint32)
    if pairwise_rank:
        rank = live_fifo_rank_pairwise(tenant_ids, tickets, alive)
    else:
        rank = live_fifo_rank(tenant_ids, tickets, alive, S)
    admitted = alive & (rank < avail(state)[tenant_ids])
    spent = segment_counts(tenant_ids, admitted, S)
    return state._replace(consumed=state.consumed + spent), admitted


# -- replenish (weighted grant from the global pool) ---------------------------


def stride_alloc(vpass: jax.Array, weight: jax.Array, unmet: jax.Array,
                 free_units, max_units: int):
    """Closed-form stride allocation (no ``max_units``-length sequential
    loop): tenant s's k-th grant crosses virtual time ``vpass_s + k/w_s``,
    so the sequential argmin schedule is exactly the merge of S arithmetic
    sequences — take the first ``take`` crossings of the flattened (value,
    tenant, k) sort.  A stable argsort over the (S, max_units) crossing
    matrix reproduces the argmin tie-break (lowest tenant index first).

    Non-finite crossings (zero-weight tenants past their first unit, or a
    vpass already saturated to +inf) are never granted.  Returns
    ``alloc (S,) u32``.
    """
    free_units = jnp.asarray(free_units, jnp.int32)
    S = vpass.shape[0]
    U = max_units
    k = jax.lax.broadcasted_iota(jnp.float32, (S, U), 1)
    step = jnp.where(weight[:, None] > 0, k / weight[:, None], jnp.inf)
    step = jnp.where(k == 0, 0.0, step)  # k=0 crossing is vpass itself (0/0 guard)
    cross = jnp.where(k < unmet[:, None].astype(jnp.float32),
                      vpass[:, None] + step, jnp.inf)
    finite = jnp.isfinite(cross)
    take = jnp.minimum(
        jnp.minimum(jnp.maximum(free_units, 0), jnp.int32(U)),
        jnp.sum(finite).astype(jnp.int32))
    order = jnp.argsort(cross.reshape(-1), stable=True)  # ties → (s, k) lex
    rank = jnp.zeros((S * U,), jnp.int32).at[order].set(
        jnp.arange(S * U, dtype=jnp.int32))
    granted = (rank < take).reshape(S, U)
    return jnp.sum(granted, axis=1).astype(jnp.uint32)


def poke_bump(state: QoSState, widths: jax.Array) -> jax.Array:
    """Waiting-array bump for per-tenant windows ``[grant_s, grant_s+w_s)``
    via the coprime-stride permutation (the `kernels/sema_batch` trick):
    ticket ``grant_s + k`` hashes to bucket ``(start_s + 17k) mod T``, and
    17 is coprime with the power-of-two table, so inverting the stride
    recovers each bucket's window offset — ``bump[j] = Σ_s [((j − start_s)
    · 17⁻¹ mod T) < w_s]``.  A dense compare instead of the former (S, T)
    hash-index matrix + scatter-add; windows ≥ T degrade to a full-table
    poke (never a missed poke), exactly as before."""
    table = state.bucket_seq.shape[-1]
    S = state.ticket.shape[0]
    start = twa_hash_u32(
        tenant_salt(state, jnp.arange(S, dtype=jnp.uint32)), state.grant)
    j = jnp.arange(table, dtype=jnp.uint32)[None, :]
    offs = ((j - start[:, None]) * jnp.uint32(STRIDE_INV)) & jnp.uint32(table - 1)
    return jnp.sum((offs < widths[:, None]).astype(jnp.uint32), axis=0)


def qos_replenish(state: QoSState, free_units, live_depth: jax.Array,
                  max_units: int):
    """Distribute up to ``free_units`` global slots by stride scheduling to
    tenants with unmet live demand; bump the TWAHash buckets of the
    conservatively-enabled ticket window (alloc + dead slack per tenant).

    ``max_units`` statically bounds the per-tenant grant count (engine:
    total slot count).  Returns ``(state', alloc, leftover)`` —
    ``leftover`` units stay in the caller's pool (work conservation).
    """
    free_units = jnp.asarray(free_units, jnp.int32)
    live_depth = jnp.asarray(live_depth, jnp.int32)
    unmet = jnp.clip(live_depth - avail(state), 0, max_units)
    alloc = stride_alloc(state.vpass, state.weight, unmet, free_units,
                         max_units)
    af = alloc.astype(jnp.float32)
    dv = jnp.where(alloc > 0,
                   jnp.where(state.weight > 0, af / state.weight, jnp.inf),
                   0.0)
    vpass = state.vpass + dv
    leftover = free_units - jnp.sum(alloc).astype(jnp.int32)

    # Conservative successor poke: newly enabled live tickets of tenant s
    # lie in [grant_s, grant_s + alloc_s + dead_s) — every not-yet-reclaimed
    # dead ticket can shift the live frontier up by one (``dead`` decays as
    # reclaim absorbs tombstone-stranded credit — see `qos_reclaim`).
    # Spurious pokes are benign (paper: collisions cause extra re-checks
    # only).  The window is clamped to the issued-ticket frontier: no
    # waiter holds a ticket ≥ `ticket`.
    outstanding = jnp.maximum(_sdist(state.ticket, state.grant), 0)
    width = jnp.minimum((alloc + state.dead).astype(jnp.int32),
                        outstanding).astype(jnp.uint32)
    bump = poke_bump(state, width)
    return state._replace(grant=state.grant + alloc, vpass=vpass,
                          bucket_seq=state.bucket_seq + bump), alloc, leftover


def qos_reclaim(state: QoSState, live_depth: jax.Array):
    """Burn surplus credit (granted past all live demand — stranded by
    tombstones) back to the caller's pool.  Returns ``(state', units)``.

    Each reclaimed unit is credit the grant frontier carried past a dead
    ticket, so that ticket can no longer displace a future enabled window:
    the poke slack ``dead`` shrinks by the reclaimed amount (saturating).
    This is the dead-below-frontier accounting — the window cost decays as
    the tombstone backlog drains instead of growing monotonically with
    total expirations."""
    live_depth = jnp.asarray(live_depth, jnp.int32)
    surplus = jnp.maximum(avail(state) - live_depth, 0).astype(jnp.uint32)
    return (state._replace(consumed=state.consumed + surplus,
                           dead=state.dead - jnp.minimum(state.dead, surplus)),
            jnp.sum(surplus).astype(jnp.int32))


# -- multi-resource gate (slots × KV blocks) -----------------------------------


def block_gate(admitted: jax.Array, demand: jax.Array, key: jax.Array,
               free_blocks, headroom=0, commit_demand=None, commit_free=0,
               commit_bootstrap=False):
    """Second-resource admission gate: of the rows the QoS round admitted
    (each holding one SLOT unit), keep the longest FCFS prefix whose
    cumulative **block** demand fits the free pool — the batched form of
    taking ``demand_i`` units from the TWA block semaphore in ticket
    order.  Strict FCFS: a row that does not fit blocks every later row
    (no bypass — a stream of small sequences can never starve a large
    one, exactly the paper's first-come-first-enabled order).

    ``key`` is the global admission order (the engine's packed
    (clamped ticket distance, tenant index) sort key — see
    `serving.engine_state._fcfs_key`); non-admitted rows must carry the
    sentinel INT32_MAX.  Returns the granted mask; the caller refunds the
    QoS slot credit of ``admitted & ~granted`` rows (they stay live in the
    backlog and retry next round — "block-stalled").

    ``headroom`` is the **reserved-headroom check** of the chunked-prefill
    subsystem (incremental allocation): demands are then FIRST-CHUNK
    demands, and the gate admits only into ``free − headroom``, where
    headroom = :func:`block_headroom` over the running slots — the blocks
    the safety-chain-earliest running sequences may still claim to
    finish.  Admission can therefore never eat into the reserve that
    keeps at least one runnable slot able to complete (the no-deadlock
    invariant documented in `serving.engine_state`); the worst-case
    up-front mode passes 0 (its demands are already whole-lifetime
    reservations).

    ``commit_demand``/``commit_free`` add the **commitment watermark**
    (chunked mode): each candidate's whole-lifetime demand must also fit
    the remaining commitment budget ``W − Σ rem(running)``.  Unlike the
    up-front gate this is PIPELINED — remaining demand drains as running
    sequences write, so reservations overlap in time — but it bounds
    aggregate outstanding demand: an overcommitted pool degenerates into
    the safety chain serializing the endgame (one funded slot at a time),
    which costs more rounds than the extra residency buys (measured in
    `benchmarks/serving_bench.run_longprompt`).  ``commit_bootstrap``
    (the "pool is uncommitted" flag) exempts the FCFS-FIRST candidate
    from the watermark so a request larger than W is still served — it
    waits, strict no-bypass, until the pool drains, then runs alone
    (no starvation; the submit-time check bounds it by the pool itself).
    """
    n = admitted.shape[0]
    demand = jnp.asarray(demand, jnp.int32)
    order = jnp.argsort(jnp.where(admitted, key, jnp.iinfo(jnp.int32).max),
                        stable=True)
    adm_s = admitted[order]
    cum = jnp.cumsum(jnp.where(adm_s, demand[order], 0))
    fits = cum <= (jnp.asarray(free_blocks, jnp.int32)
                   - jnp.asarray(headroom, jnp.int32))
    if commit_demand is not None:
        cum2 = jnp.cumsum(jnp.where(adm_s,
                                    jnp.asarray(commit_demand,
                                                jnp.int32)[order], 0))
        first = adm_s & (jnp.cumsum(adm_s.astype(jnp.int32)) == 1)
        fits &= ((cum2 <= jnp.asarray(commit_free, jnp.int32))
                 | (first & commit_bootstrap))
    blocked = jnp.cumsum((adm_s & ~fits).astype(jnp.int32)) > 0
    ok = adm_s & fits & ~blocked
    return jnp.zeros((n,), bool).at[order].set(ok)


def block_headroom(rem: jax.Array, held: jax.Array, order: jax.Array,
                   active: jax.Array) -> jax.Array:
    """Reserved headroom of the incremental block allocator — the Banker
    margin that makes mid-sequence stalls parks instead of deadlocks.

    The chunked-prefill subsystem maintains, for live slots in priority
    order (earliest admission first), the safety invariant

        rem_i  ≤  free  +  Σ_{j<i} held_j        for every live slot i,

    i.e. every slot's worst-case *remaining* block demand is covered by
    the free pool plus everything its priority-predecessors will
    eventually release.  Under it the priority-first slot can always take
    (rem_1 ≤ free), so it never parks, finishes, and releases — the next
    slot inherits the cover (rem_2 ≤ free + held_1), and by induction
    every parked slot is resumed: a strict no-deadlock guarantee.

    ``headroom = max(0, max_i(rem_i − Σ_{j<i} held_j))`` is the smallest
    free-pool level that keeps the invariant; admission (`block_gate`) and
    incremental takes (`serving.prefill.chunk_plan`) both refuse to let
    ``free`` drop below it.  ``rem``/``held``: per-slot remaining demand /
    blocks held; ``order``: the priority permutation (e.g.
    `serving.prefill.banker_order` — earliest admission first); inactive
    rows are ignored.  Returns an i32 scalar.
    """
    rem = jnp.asarray(rem, jnp.int32)
    held = jnp.asarray(held, jnp.int32)
    order = jnp.asarray(order, jnp.int32)
    act_s = active[order]
    held_s = jnp.where(act_s, held[order], 0)
    cum_held = jnp.cumsum(held_s) - held_s
    deficit = jnp.where(act_s, rem[order] - cum_held,
                        jnp.iinfo(jnp.int32).min)
    return jnp.maximum(jnp.max(deficit, initial=0), 0)


# -- one fused admission round -------------------------------------------------


def qos_scan_round(state: QoSState, tenant_ids: jax.Array,
                   tickets: jax.Array, alive: jax.Array,
                   deadlines: jax.Array, now, free_pool, released,
                   max_units: int, *, round_impl=None):
    """One admission round with **slot-release feedback**: ``released``
    units freed by decode completions/preemptions *this* round re-enter
    the pool consumed by the SAME round's weighted replenish, so a slot
    reclaimed mid-scan is re-granted to the next live ticket without a
    host round-trip (the megastep's in-graph counterpart of the engine's
    ``_replenish_qos(freed)``).

    ``round_impl`` selects the round implementation (default
    :func:`qos_round`; the scheduler substitutes the fused Pallas pass
    `kernels.qos_admission.qos_round_fused` on TPU — bit-identical).
    Returns ``(state', admitted, expired, leftover_units)``.
    """
    free = (jnp.asarray(free_pool, jnp.int32)
            + jnp.asarray(released, jnp.int32))
    impl = round_impl if round_impl is not None else qos_round
    return impl(state, tenant_ids, tickets, alive, deadlines, now, free,
                max_units)


def qos_round(state: QoSState, tenant_ids: jax.Array, tickets: jax.Array,
              alive: jax.Array, deadlines: jax.Array, now, free_units,
              max_units: int, *, pairwise_rank: bool = False):
    """One whole multi-tenant admission round as a single jit-able pass:
    expire → replenish (weighted) → admit (tombstone-transparent FCFS) →
    reclaim stranded credit.  Returns
    ``(state', admitted, expired, leftover_units)``.

    This is the oracle semantics for the fused Pallas kernel
    (`kernels.qos_admission.qos_round_fused`); ``pairwise_rank=True``
    selects the retained O(N²) rank baseline (benchmarks only)."""
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    state, alive, expired = qos_expire(state, tenant_ids, alive, deadlines, now)
    S = state.ticket.shape[0]
    depth = segment_counts(tenant_ids, alive, S, dtype=jnp.int32)
    state, _, leftover = qos_replenish(state, free_units, depth, max_units)
    state, admitted = qos_admit(state, tenant_ids, tickets, alive,
                                pairwise_rank=pairwise_rank)
    depth_after = depth - segment_counts(tenant_ids, admitted, S,
                                         dtype=jnp.int32)
    state, reclaimed = qos_reclaim(state, depth_after)
    return state, admitted, expired, leftover + reclaimed
