"""Batched multi-tenant QoS admission — the in-graph counterpart.

Extends `core.functional`'s MultiSemaState with per-tenant **weights**,
**deadline masks**, and a **tombstone-transparent admission rule**, so a
whole multi-tenant admission round (expire → admit → replenish → poke)
is one vectorized pass under jit — the reference semantics for a future
Pallas variant in `kernels/` (same role `core.functional` plays for
`kernels/sema_batch`).

State (all per-tenant vectors of length S, plus one shared waiting array):

  ticket / grant — the paper's counters, per tenant.  ``grant`` advances
      only via weighted replenishment from the global slot pool.
  consumed       — grant units actually used by admitted live rows;
      ``avail = grant − consumed`` is a tenant's spendable credit.
  dead           — cumulative tombstoned (deadline-expired / cancelled)
      tickets; used to widen the conservative bucket-poke window, exactly
      generalizing `post_batch`'s ``[grant, grant+n)`` window (reduces to
      it when dead == 0).
  weight / vpass — stride scheduler: granting a unit advances the
      tenant's virtual pass by 1/weight; free units flow to the
      minimum-pass tenant with unmet live demand, so admission shares
      converge to the weights under saturation.
  bucket_seq     — ONE waiting array shared by all S tenant semaphores
      (paper §1: the array is process-global); tenants are dispersed by
      salting the TWA hash per tenant.

The admission rule is the batched tombstone-skip: a live row is admitted
iff its FCFS rank *among live rows of its tenant* is below the tenant's
avail — dead tickets anywhere in the queue (head, middle, or deep) are
transparent, so grant units always reach the earliest live waiters and
FCFS among live tickets is preserved (`core.functional.live_fifo_rank`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.functional import _sdist, live_fifo_rank, twa_hash_u32
from ..core.hashfn import MIX32KA

DEFAULT_TABLE_SIZE = 1024


class QoSState(NamedTuple):
    ticket: jax.Array  # (S,) u32 — per-tenant tickets issued
    grant: jax.Array  # (S,) u32 — per-tenant units replenished
    consumed: jax.Array  # (S,) u32 — units spent on admitted live rows
    dead: jax.Array  # (S,) u32 — tombstoned tickets (poke-window slack)
    weight: jax.Array  # (S,) f32 — QoS weights
    vpass: jax.Array  # (S,) f32 — stride virtual pass
    bucket_seq: jax.Array  # (T,) u32 — shared waiting array
    salt: jax.Array  # u32


def make_qos(weights, table_size: int = DEFAULT_TABLE_SIZE,
             salt: int = 0x9E3779B9) -> QoSState:
    w = jnp.asarray(weights, jnp.float32)
    assert table_size > 0 and (table_size & (table_size - 1)) == 0
    z = jnp.zeros_like(w, dtype=jnp.uint32)
    return QoSState(ticket=z, grant=z, consumed=z, dead=z, weight=w,
                    vpass=jnp.zeros_like(w),
                    bucket_seq=jnp.zeros((table_size,), jnp.uint32),
                    salt=jnp.uint32(salt))


def tenant_salt(state: QoSState, tenant_ids) -> jax.Array:
    """Per-tenant TWAHash salt — disperses the S logical semaphores over
    the one shared array (the `uintptr_t(L)` component, per tenant)."""
    t = jnp.asarray(tenant_ids, jnp.uint32)
    return state.salt + (t + jnp.uint32(1)) * jnp.uint32(MIX32KA)


def qos_bucket_index(state: QoSState, tenant_ids, tickets) -> jax.Array:
    table = state.bucket_seq.shape[-1]
    h = twa_hash_u32(tenant_salt(state, tenant_ids),
                     jnp.asarray(tickets, jnp.uint32))
    return (h & jnp.uint32(table - 1)).astype(jnp.int32)


def avail(state: QoSState) -> jax.Array:
    """Spendable grant units per tenant (int32, ≥ 0 by invariant)."""
    return _sdist(state.grant, state.consumed)


# -- take ---------------------------------------------------------------------


def qos_take(state: QoSState, tenant_ids: jax.Array, mask: jax.Array,
             deadlines: jax.Array | None = None, now=0.0):
    """Batched ticket issuance for N arrivals against S tenants.

    Rows whose deadline already passed at arrival are *dead on arrival*:
    they receive no ticket and are reported in ``expired``.  Returns
    ``(state', tickets, buckets, expired)``; admission is decided by
    :func:`qos_admit` (rank among live waiters), not at take time.

    Precision note: deadlines/now compare in float32 under default jax —
    pass RELATIVE times (deltas from a caller-held epoch), not absolute
    wall/monotonic stamps, which lose sub-second resolution at ~1e6 s.
    """
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    if deadlines is None:
        expired = jnp.zeros(mask.shape, bool)
    else:
        expired = mask & (jnp.asarray(deadlines) <= now)
    eff = mask & ~expired
    S = state.ticket.shape[0]
    onehot = jax.nn.one_hot(tenant_ids, S, dtype=jnp.uint32) * \
        eff[:, None].astype(jnp.uint32)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot)  # exclusive, per tenant
    my_rank = jnp.take_along_axis(ranks, tenant_ids[:, None], axis=1)[:, 0]
    tickets = state.ticket[tenant_ids] + my_rank
    new_ticket = state.ticket + jnp.sum(onehot, axis=0)
    buckets = qos_bucket_index(state, tenant_ids, tickets)
    return state._replace(ticket=new_ticket), tickets, buckets, expired


# -- expire (tombstone) --------------------------------------------------------


def qos_expire(state: QoSState, tenant_ids: jax.Array, alive: jax.Array,
               deadlines: jax.Array, now):
    """Tombstone waiting rows whose deadline passed: they leave the live
    set (skip-transparent to later admissions) and widen the poke window.
    Returns ``(state', alive', newly_expired)``."""
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    newly = alive & (jnp.asarray(deadlines) <= now)
    S = state.ticket.shape[0]
    per_tenant = jnp.sum(
        jax.nn.one_hot(tenant_ids, S, dtype=jnp.uint32)
        * newly[:, None].astype(jnp.uint32), axis=0)
    return state._replace(dead=state.dead + per_tenant), alive & ~newly, newly


# -- admit --------------------------------------------------------------------


def qos_admit(state: QoSState, tenant_ids: jax.Array, tickets: jax.Array,
              alive: jax.Array):
    """Tombstone-transparent weighted-FCFS admission over the live backlog:
    row admitted ⇔ live_fifo_rank < avail[tenant].  Consumes the units.
    Returns ``(state', admitted)``."""
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    S = state.ticket.shape[0]
    rank = live_fifo_rank(tenant_ids, jnp.asarray(tickets, jnp.uint32), alive)
    admitted = alive & (rank < avail(state)[tenant_ids])
    spent = jnp.sum(jax.nn.one_hot(tenant_ids, S, dtype=jnp.uint32)
                    * admitted[:, None].astype(jnp.uint32), axis=0)
    return state._replace(consumed=state.consumed + spent), admitted


# -- replenish (weighted grant from the global pool) ---------------------------


def qos_replenish(state: QoSState, free_units, live_depth: jax.Array,
                  max_units: int):
    """Distribute up to ``free_units`` global slots by stride scheduling to
    tenants with unmet live demand; bump the TWAHash buckets of the
    conservatively-enabled ticket window (alloc + dead slack per tenant).

    ``max_units`` bounds the jit-static loop (engine: total slot count).
    Returns ``(state', alloc, leftover)`` — ``leftover`` units stay in the
    caller's pool (work conservation).
    """
    free_units = jnp.asarray(free_units, jnp.int32)
    live_depth = jnp.asarray(live_depth, jnp.int32)
    inf = jnp.float32(jnp.inf)

    def body(i, carry):
        vpass, alloc = carry
        unmet = live_depth - (avail(state) + alloc.astype(jnp.int32))
        active = (unmet > 0) & (i < free_units)
        eff = jnp.where(active, vpass, inf)
        j = jnp.argmin(eff)
        can = active[j]
        vpass = vpass.at[j].add(
            jnp.where(can, 1.0 / state.weight[j], 0.0))
        alloc = alloc.at[j].add(jnp.where(can, 1, 0).astype(jnp.uint32))
        return vpass, alloc

    vpass, alloc = jax.lax.fori_loop(
        0, max_units, body,
        (state.vpass, jnp.zeros_like(state.grant)))
    leftover = free_units - jnp.sum(alloc).astype(jnp.int32)

    # Conservative successor poke: newly enabled live tickets of tenant s
    # lie in [grant_s, grant_s + alloc_s + dead_s) — every dead ticket can
    # shift the live frontier up by one.  Spurious pokes are benign
    # (paper: collisions cause extra re-checks only).  The window is
    # clamped to the issued-ticket frontier: no waiter holds a ticket
    # ≥ `ticket`, so the cumulative dead slack stops inflating the poke
    # cost once it passes the outstanding queue (and decays as it drains).
    # No-lost-wakeup invariant even when the window exceeds the table:
    # `offs` spans one full table and TICKET_STRIDE (17) is coprime with
    # the power-of-two table size, so `table` consecutive tickets cover
    # every bucket exactly once — a ≥table window degrades to a full-table
    # poke (wakes everyone), never to a missed poke.
    table = state.bucket_seq.shape[-1]
    S = state.ticket.shape[0]
    offs = jnp.arange(table, dtype=jnp.uint32)[None, :]  # (1, T)
    outstanding = jnp.maximum(_sdist(state.ticket, state.grant), 0)
    width = jnp.minimum((alloc + state.dead).astype(jnp.int32),
                        outstanding).astype(jnp.uint32)[:, None]  # (S, 1)
    enabled = offs < width
    idx = qos_bucket_index(
        state, jnp.broadcast_to(jnp.arange(S)[:, None], (S, table)),
        state.grant[:, None] + offs)
    bump = jnp.zeros((table,), jnp.uint32).at[idx.reshape(-1)].add(
        enabled.reshape(-1).astype(jnp.uint32))
    return state._replace(grant=state.grant + alloc, vpass=vpass,
                          bucket_seq=state.bucket_seq + bump), alloc, leftover


def qos_reclaim(state: QoSState, live_depth: jax.Array):
    """Burn surplus credit (granted past all live demand — stranded by
    tombstones) back to the caller's pool.  Returns ``(state', units)``."""
    live_depth = jnp.asarray(live_depth, jnp.int32)
    surplus = jnp.maximum(avail(state) - live_depth, 0).astype(jnp.uint32)
    return (state._replace(consumed=state.consumed + surplus),
            jnp.sum(surplus).astype(jnp.int32))


# -- one fused admission round -------------------------------------------------


def qos_round(state: QoSState, tenant_ids: jax.Array, tickets: jax.Array,
              alive: jax.Array, deadlines: jax.Array, now, free_units,
              max_units: int):
    """One whole multi-tenant admission round as a single jit-able pass:
    expire → replenish (weighted) → admit (tombstone-transparent FCFS) →
    reclaim stranded credit.  Returns
    ``(state', admitted, expired, leftover_units)``."""
    tenant_ids = jnp.asarray(tenant_ids, jnp.int32)
    state, alive, expired = qos_expire(state, tenant_ids, alive, deadlines, now)
    S = state.ticket.shape[0]
    depth = jnp.sum(jax.nn.one_hot(tenant_ids, S, dtype=jnp.int32)
                    * alive[:, None].astype(jnp.int32), axis=0)
    state, _, leftover = qos_replenish(state, free_units, depth, max_units)
    state, admitted = qos_admit(state, tenant_ids, tickets, alive)
    depth_after = depth - jnp.sum(
        jax.nn.one_hot(tenant_ids, S, dtype=jnp.int32)
        * admitted[:, None].astype(jnp.int32), axis=0)
    state, reclaimed = qos_reclaim(state, depth_after)
    return state, admitted, expired, leftover + reclaimed
