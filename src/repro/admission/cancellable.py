"""Cancellable takes on a TWA semaphore — the tombstone protocol's host API.

Ticket semaphores are famously awkward to extend with timeout/cancellation:
an issued ticket holds a fixed position in the grant sequence and cannot
simply vanish (the same revocation problem Scalable Range Locks and the TWA
ticket-lock paper wrestle with).  `core.twa_semaphore` solves it with
tombstones + a skip-aware post; this module packages that into the two
shapes a production admission stack needs:

  * ``take_with_deadline`` / ``take_with_timeout`` — self-cancelling takes:
    the waiter itself abandons at its deadline, tombstoning its own ticket.
    A lost race (grant arrived exactly at expiry) reports *acquired* — the
    slot is never double-counted and never leaks.

  * ``CancellableTake`` — a handle whose ``cancel()`` may be called from a
    *different* thread (a reaper noticing a dead host, a client
    disconnect).  All resolution — waiter observing its grant, waiter
    timing out, external cancel — funnels through one handle lock, so
    exactly one outcome is decided even when a concurrent skip-aware post
    advances Grant past the ticket mid-cancel.

Stats (`CancelStats`) feed the serving telemetry: how many takes were
abandoned, and how many cancellations lost the race (a proxy for deadline
pressure sitting right at the admission latency).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from ..core.parking import pause
from ..core.ticket_semaphore import _dist
from ..core.twa_semaphore import TWASemaphore


@dataclass
class CancelStats:
    acquired: int = 0
    cancelled: int = 0
    lost_races: int = 0  # cancel attempts that found the slot already granted
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def bump(self, attr: str) -> None:
        with self._lock:
            setattr(self, attr, getattr(self, attr) + 1)


class CancellableTake:
    """One in-flight take whose cancellation may come from any thread.

    The waiter calls :meth:`wait`; anyone may call :meth:`cancel`.  The
    final outcome (acquired vs cancelled) is decided exactly once under
    ``_lock``; whichever side resolves first wins and the other observes.
    """

    def __init__(self, sema: TWASemaphore, stats: CancelStats | None = None):
        assert sema._cancellation, "semaphore must be built with cancellation=True"
        self.sema = sema
        self.stats = stats
        self.ticket = sema.ticket.fetch_add(1)
        self._lock = threading.Lock()
        self._outcome: bool | None = None  # True=acquired, False=cancelled
        self._resolved = threading.Event()

    # -- resolution (exactly-once) ----------------------------------------

    def _resolve_granted(self) -> bool:
        with self._lock:
            if self._outcome is None:
                self._outcome = True
                self._resolved.set()
            return self._outcome

    def _resolve_via_cancel(self) -> bool:
        """Tombstone the ticket unless the grant sequence already covered
        it.  Returns the final outcome (True means the cancel lost the race
        and the slot is held)."""
        with self._lock:
            if self._outcome is None:
                acquired = not self.sema.cancel(self.ticket)
                self._outcome = acquired
                self._resolved.set()
                # Wake a futex-parked waiter so it observes the outcome.
                self.sema.poke_ticket(self.ticket)
                if self.stats is not None:
                    if acquired:
                        self.stats.bump("lost_races")
                    else:
                        self.stats.bump("cancelled")
            return self._outcome

    def cancel(self) -> bool:
        """Abandon the take.  True: the ticket is tombstoned and will be
        skipped.  False: too late — the slot was already granted; the owner
        of the handle holds it and must release it normally."""
        return not self._resolve_via_cancel()

    # -- waiting -----------------------------------------------------------

    def wait(self, deadline: float | None = None) -> bool:
        """Block until granted, externally cancelled, or ``deadline``
        (absolute ``time.monotonic``).  Returns True iff the slot is held."""
        s = self.sema
        tx = self.ticket
        bucket = s.array.bucket_for(s._hash(s._addr, tx))
        mx = bucket.seq.load()
        while True:
            if self._resolved.is_set():
                return self._outcome
            dx = _dist(s.grant.load(), tx)
            if dx > 0:
                # Grant covers the ticket — but a concurrent cancel may have
                # tombstoned it first (the skip that advanced Grant past us
                # was *because* we were dead).  The handle lock arbitrates.
                got = self._resolve_granted()
                if got and self.stats is not None:
                    self.stats.bump("acquired")
                return got
            if deadline is not None and time.monotonic() >= deadline:
                return self._resolve_via_cancel()
            if (dx + s.threshold) > 0:
                pause()  # short-term: spin near Grant
                continue
            vx = mx
            bucket.wait_for_change(vx, s._spin_buckets, deadline)
            mx = bucket.seq.load()


def take_with_deadline(sema: TWASemaphore, deadline: float | None,
                       stats: CancelStats | None = None) -> bool:
    """Deadline-aware take (absolute ``time.monotonic`` deadline).  Only
    the waiter itself can abandon, so this rides the core ``take_until``
    directly — no handle machinery; use :class:`CancellableTake` when a
    *different* thread must be able to cancel."""
    got = sema.take_until(deadline)
    if stats is not None:
        stats.bump("acquired" if got else "cancelled")
    return got


def take_with_timeout(sema: TWASemaphore, timeout: float | None,
                      stats: CancelStats | None = None) -> bool:
    """Relative-timeout flavour of :func:`take_with_deadline`."""
    deadline = None if timeout is None else time.monotonic() + timeout
    return take_with_deadline(sema, deadline, stats)
