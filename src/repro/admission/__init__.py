"""Multi-tenant QoS admission — the paper's TWA semaphore as a production
admission stack.

Module map (file → paper construct → what it adds):

  ``cancellable.py``
      Paper construct: Listing 1/2's ticket+grant sequence, which cannot
      natively revoke an issued ticket.  Adds: the **tombstone protocol**
      host API — deadline/timeout takes and externally-cancellable take
      handles over ``core.twa_semaphore``'s skip-aware post (an abandoning
      waiter marks its ticket dead; posts re-advance Grant past dead
      tickets so FCFS among *live* waiters is exact).

  ``hierarchical.py``
      Paper construct: the process-global waiting array (§1) and the
      successor-poke of SemaPost (Listing 2).  Adds: a **two-level
      weighted semaphore tree** — root = conserved global slot pool,
      leaves = per-tenant TWA semaphores sharing ONE waiting array, so a
      release pokes O(freed-slots) buckets regardless of tenant count.
      Freed slots are replenished to leaves by stride scheduling
      (pass += 1/weight), converging admission shares to QoS weights
      under saturation while staying work-conserving.

  ``functional_qos.py``
      Paper construct: the batched in-graph adaptation begun by
      ``core.functional`` (MultiSemaState).  Adds: per-tenant **weights**,
      **deadline masks**, and the batched tombstone-skip
      (``live_fifo_rank``) so one jit-able pass runs a whole multi-tenant
      admission round (expire → weighted replenish → FCFS admit →
      reclaim) — the oracle semantics of the fused Pallas kernel
      ``kernels/qos_admission.qos_round_fused`` (bit-exact in interpret
      mode).  Both paths are O(N·S/block): blocked-prefix live ranks,
      closed-form stride allocation (``stride_alloc``), and the
      coprime-stride permutation poke (``poke_bump``).

Integration points: ``serving.scheduler.ContinuousBatchingEngine``
(``tenants=`` routes admission through the functional QoS state;
``Request`` carries ``tenant_id``/``deadline``) and
``runtime.coordinator.DistributedTicketLease`` (cancellable acquire with
KV tombstones, so a dying host never wedges the cluster grant sequence).
"""

from .cancellable import (
    CancellableTake,
    CancelStats,
    take_with_deadline,
    take_with_timeout,
)
from .functional_qos import (
    QoSState,
    make_qos,
    poke_bump,
    qos_admit,
    qos_bucket_index,
    qos_expire,
    qos_reclaim,
    qos_replenish,
    qos_round,
    qos_scan_round,
    qos_take,
    stride_alloc,
)
from .hierarchical import HierarchicalTWASemaphore

__all__ = [
    "CancellableTake",
    "CancelStats",
    "take_with_deadline",
    "take_with_timeout",
    "HierarchicalTWASemaphore",
    "QoSState",
    "make_qos",
    "qos_take",
    "qos_expire",
    "qos_admit",
    "qos_replenish",
    "qos_reclaim",
    "qos_round",
    "qos_scan_round",
    "qos_bucket_index",
    "stride_alloc",
    "poke_bump",
]
