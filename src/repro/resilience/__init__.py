"""Self-healing serving: deterministic fault injection + recovery ladder.

The serving engine's correctness rests on the paper's counter identities;
`serving.sentinels` checks them every scanned round and emits a per-round
health bitmask through the telemetry ring.  This package closes the loop:

* :mod:`repro.resilience.faults` — a seeded, deterministic
  :class:`FaultPlan` that injects dropped pokes, counter corruption,
  double block releases, NaN model poison, stuck slots, and mid-megastep
  crashes, identically on the host ``step()`` path and the scanned
  ``megastep`` path;
* :mod:`repro.resilience.recovery` — the :class:`ResilientEngine`
  wrapper that reads the health stream at deterministic reaction
  boundaries and escalates through the recovery ladder: quarantine →
  audit-and-rebuild → kernel fallback → snapshot/restore with replay.

See README.md in this directory for the architecture and the escalation
policy.
"""

from .faults import (  # noqa: F401
    CAPACITY_KINDS,
    CORRUPTION_KINDS,
    CRASH,
    DOUBLE_RELEASE,
    DROP_POKE,
    FaultEvent,
    FaultPlan,
    InjectedCrash,
    KV_COUNTER,
    NAN_LOGIT,
    STUCK_SLOT,
    apply_fault,
)
from .recovery import ResilientEngine, exit_audit  # noqa: F401
