"""Self-healing serving: deterministic fault injection + recovery ladder.

The serving engine's correctness rests on the paper's counter identities;
`serving.sentinels` checks them every scanned round and emits a per-round
health bitmask through the telemetry ring.  This package closes the loop:

* :mod:`repro.resilience.faults` — a seeded, deterministic
  :class:`FaultPlan` that injects dropped pokes, counter corruption,
  double block releases, NaN model poison, stuck slots, and mid-megastep
  crashes, identically on the host ``step()`` path and the scanned
  ``megastep`` path;
* :mod:`repro.resilience.recovery` — the :class:`ResilientEngine`
  wrapper that reads the health stream at deterministic reaction
  boundaries and escalates through the recovery ladder: quarantine →
  audit-and-rebuild → kernel fallback → snapshot/restore with replay.

One level up, the CLUSTER plane reuses the same pieces across replicas:
``CLUSTER_KINDS`` faults (replica kill, KV partition, lease leak,
straggler) drive `serving.router.ReplicaRouter` +
`runtime.reaper.LeaseReaper` — see README.md ("the cluster plane") for
the failure model and the exactly-once migration contract.
"""

from .faults import (  # noqa: F401
    BIT_FLIP,
    CAPACITY_KINDS,
    CLUSTER_KINDS,
    CORRUPTION_KINDS,
    CRASH,
    DOUBLE_RELEASE,
    DROP_POKE,
    FaultEvent,
    FaultPlan,
    InjectedCrash,
    KV_COUNTER,
    KV_PARTITION,
    LEASE_LEAK,
    NAN_LOGIT,
    REPLICA_KILL,
    STRAGGLER,
    STUCK_SLOT,
    TORN_SHARD,
    apply_fault,
    tear_checkpoint,
)
from .recovery import ResilientEngine, exit_audit  # noqa: F401
