"""Deterministic fault injection for the serving engine.

A :class:`FaultPlan` is a seeded, immutable schedule of
:class:`FaultEvent`\\ s — (round, kind, magnitude) triples drawn from a
``numpy`` PRNG, so the same seed yields byte-identical fault streams on
every run and on BOTH serving paths.  :func:`apply_fault` mutates the
engine's state at an engine boundary (between rounds): it always updates
the host mirrors, and additionally patches the persistent device state
(block pool, model pytree) when the engine carries one — which is exactly
what makes the repo's equivalence property (megastep(K) ≡ K·step())
extend to faulty runs: both paths see the same state mutation at the
same round boundary.

Fault kinds split into two classes:

**Capacity-loss faults** (``CAPACITY_KINDS``) — they destroy capacity or
progress but never forge state the two serving paths represent
differently, so host-loop and megastep runs stay bit-identical under
them (the chaos equivalence property in tests/test_resilience.py):

* ``DROP_POKE``   — a parked slot's observed bucket sequence is reset to
  the current value: the wake poke it was waiting on is lost (the
  TWA-protocol failure mode the paper's memo-based waiting prevents);
* ``KV_COUNTER``  with ``delta < 0`` — the block semaphore's grant is
  silently decremented: free blocks leak (trips ``H_KV_CONSERVE``);
* ``STUCK_SLOT``  — a busy MID-PREFILL slot is force-parked on an
  arbitrary bucket with a current sequence snapshot: it wedges until
  some release happens to poke that bucket, or the watchdog trips
  (chunked engines only — only the chunk phase honors parks, so a
  decode-phase slot would wedge on the host but keep emitting in-scan).

**Corruption faults** (``CORRUPTION_KINDS``) — they forge block
identities or poison the model, which only the device path physically
holds, so they are exercised as megastep-side detect-and-recover tests:

* ``KV_COUNTER`` with ``delta > 0`` — phantom free blocks: the free
  region grows over queue positions holding stale (possibly live) ids;
* ``DOUBLE_RELEASE`` — a live block id is appended to the free queue a
  second time (aliasing: ``H_KV_PARTITION``);
* ``NAN_LOGIT``   — the first float leaf of the device model pytree is
  poisoned with NaN (``H_NAN``); the host mirror sets the engine's
  sticky nonfinite flag, matching the poison's persistence;
* ``BIT_FLIP``    — one bit of a live device block-table entry flips
  (cosmic-ray / DMA corruption): the cell aliases another slot's block
  or points out of range (``H_KV_PARTITION``).

``CRASH`` raises :class:`InjectedCrash` at the boundary — the recovery
ladder's rung-4 trigger (snapshot restore + deterministic replay).
``TORN_SHARD`` is driver-level like CRASH: `recovery.ResilientEngine`
tears the newest on-disk checkpoint (:func:`tear_checkpoint`), so the
next rung-4 restore must fall back to an older snapshot.

**Cluster kinds** (``CLUSTER_KINDS``) target a replica index and are
consumed by `serving.router.ReplicaRouter` against ROUTER rounds:
replica kill mid-megastep, KV-store partition window (lost heartbeats +
zombie completions), leaked lease ticket, slow-host straggler —
:meth:`FaultPlan.cluster` draws the seeded ladder the acceptance
property drives.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.functional import post_batch

DROP_POKE = "drop_poke"
KV_COUNTER = "kv_counter"
DOUBLE_RELEASE = "double_release"
NAN_LOGIT = "nan_logit"
STUCK_SLOT = "stuck_slot"
BIT_FLIP = "bit_flip"
TORN_SHARD = "torn_shard"
CRASH = "crash"

CAPACITY_KINDS = (DROP_POKE, KV_COUNTER, STUCK_SLOT)
CORRUPTION_KINDS = (DOUBLE_RELEASE, NAN_LOGIT, BIT_FLIP)
ALL_KINDS = CAPACITY_KINDS + CORRUPTION_KINDS + (CRASH,)

# --- cluster-level fault kinds (serving.router consumes these) -----------
# A cluster FaultPlan schedules these against ROUTER rounds; ``arg`` is
# the target replica index.  They never reach `apply_fault` — the router
# applies them to its own control plane (see serving/router.py):
REPLICA_KILL = "replica_kill"    # delta: engine rounds INTO the megastep
#                                  at which the process dies (mid-launch)
KV_PARTITION = "kv_partition"    # delta: window length in router rounds —
#                                  heartbeat writes are lost; the replica
#                                  itself keeps running (zombie risk)
LEASE_LEAK = "lease_leak"        # an orphan ticket taken on the replica's
#                                  lease by a client that then vanished
STRAGGLER = "straggler"          # delta: slowdown factor f — the replica
#                                  advances one megastep every f rounds

CLUSTER_KINDS = (REPLICA_KILL, KV_PARTITION, LEASE_LEAK, STRAGGLER)


class InjectedCrash(RuntimeError):
    """Raised by a ``CRASH`` fault at the engine boundary; carries the
    event so the recovery driver can consume it (one-shot)."""

    def __init__(self, event: "FaultEvent"):
        super().__init__(f"injected crash at round {event.round}")
        self.event = event


@dataclass(frozen=True)
class FaultEvent:
    round: int       # engine round BEFORE which the fault fires
    kind: str        # one of the module's kind constants
    delta: int = 0   # KV_COUNTER: signed counter corruption magnitude
    arg: int = 0     # kind-specific (STUCK_SLOT: target park bucket)


@dataclass(frozen=True)
class FaultPlan:
    """Immutable, seeded fault schedule.  The plan itself is pure data —
    consumption bookkeeping (one-shot crashes, repaired corruption) lives
    in the driver (`recovery.ResilientEngine`), so ONE plan object can be
    shared verbatim by a host-loop run and a megastep run."""

    seed: int
    events: tuple = field(default_factory=tuple)

    @classmethod
    def random(cls, seed: int, *, rounds: int, n_faults: int = 3,
               kinds: tuple = CAPACITY_KINDS, max_delta: int = 4,
               first_round: int = 1) -> "FaultPlan":
        """Draw ``n_faults`` events uniformly over kinds and rounds in
        ``[first_round, rounds)``.  ``first_round`` defaults past round 0
        so faults land on a warmed-up engine (there is nothing to corrupt
        before the first admission).  Same seed → same plan, always."""
        rng = np.random.default_rng(seed)
        evs = []
        lo = min(first_round, max(rounds - 1, 0))
        for _ in range(n_faults):
            r = int(rng.integers(lo, max(rounds, lo + 1)))
            kind = kinds[int(rng.integers(0, len(kinds)))]
            delta = 0
            if kind == KV_COUNTER:
                delta = -int(rng.integers(1, max_delta + 1))
            evs.append(FaultEvent(round=r, kind=kind, delta=delta,
                                  arg=int(rng.integers(0, 64))))
        evs.sort(key=lambda e: (e.round, e.kind, e.delta, e.arg))
        return cls(seed=seed, events=tuple(evs))

    @classmethod
    def cluster(cls, seed: int, *, rounds: int, n_replicas: int,
                n_leaks: int = 1, partition_rounds: int = 3,
                straggle_factor: int = 3) -> "FaultPlan":
        """The cluster chaos ladder: one replica killed MID-megastep, one
        slow-host straggler, one KV-store partition window, plus
        ``n_leaks`` orphan lease tickets — on three DISTINCT seeded
        replicas, at seeded rounds in the first half of the run (so the
        detection/migration machinery has runway to drain).  Same seed →
        same plan; the router replays it identically."""
        if n_replicas < 3:
            raise ValueError("cluster plan needs ≥ 3 replicas (kill, "
                             "straggler and partition hit distinct ones)")
        rng = np.random.default_rng(seed)
        reps = rng.permutation(n_replicas)[:3]
        hi = max(2, rounds // 2)
        evs = [
            FaultEvent(round=int(rng.integers(1, hi)), kind=REPLICA_KILL,
                       delta=int(rng.integers(1, 4)), arg=int(reps[0])),
            FaultEvent(round=int(rng.integers(1, hi)), kind=STRAGGLER,
                       delta=int(straggle_factor), arg=int(reps[1])),
            FaultEvent(round=int(rng.integers(1, hi)), kind=KV_PARTITION,
                       delta=int(partition_rounds), arg=int(reps[2])),
        ]
        for _ in range(n_leaks):
            evs.append(FaultEvent(round=int(rng.integers(1, hi)),
                                  kind=LEASE_LEAK,
                                  arg=int(rng.integers(0, n_replicas))))
        evs.sort(key=lambda e: (e.round, e.kind, e.delta, e.arg))
        return cls(seed=seed, events=tuple(evs))

    def with_crash(self, rnd: int) -> "FaultPlan":
        evs = sorted(self.events + (FaultEvent(round=rnd, kind=CRASH),),
                     key=lambda e: (e.round, e.kind, e.delta, e.arg))
        return FaultPlan(seed=self.seed, events=tuple(evs))

    def rounds(self) -> list[int]:
        return sorted({e.round for e in self.events})


# ---------------------------------------------------------- injection ----


def tear_checkpoint(ckpt) -> int:
    """``TORN_SHARD``'s teeth: truncate the shard files of the NEWEST
    complete checkpoint step in ``ckpt`` (a `CheckpointManager`), leaving
    the directory and meta.json intact — the classic torn write a crashed
    writer leaves behind a rename barrier.  A later ``restore`` of that
    step raises, forcing the recovery ladder to fall back to an older
    snapshot.  Returns the number of shards torn (0: nothing to tear)."""
    step = ckpt.latest_step()
    if step is None:
        return 0
    d = ckpt.dir / f"step_{step:09d}"
    torn = 0
    for shard in sorted(d.glob("shard_*.npz")):
        size = shard.stat().st_size
        if size < 2:
            continue
        with open(shard, "r+b") as f:
            f.truncate(size // 2)
        torn += 1
    return torn


def _poison_model(model):
    """NaN the first float leaf of the model pytree (device poison)."""
    leaves, treedef = jax.tree_util.tree_flatten(model)
    for i, lf in enumerate(leaves):
        if hasattr(lf, "dtype") and jnp.issubdtype(lf.dtype, jnp.floating):
            leaves[i] = (lf.reshape(-1).at[0].set(jnp.nan)
                         .reshape(lf.shape))
            break
    return jax.tree_util.tree_unflatten(treedef, leaves)


def apply_fault(engine, ev: FaultEvent) -> bool:
    """Inject ``ev`` into ``engine`` at the current engine boundary.
    Mutates the host mirrors always, plus the persistent device state
    (block pool / model) when the engine carries one, so host-loop and
    megastep engines observe the identical state change.  Returns True
    if the fault found a target (e.g. DROP_POKE is a no-op when nothing
    is parked).  ``CRASH`` events raise :class:`InjectedCrash` — they
    are the driver's to handle, not this function's."""
    if ev.kind == CRASH:
        raise InjectedCrash(ev)
    if ev.kind == TORN_SHARD or ev.kind in CLUSTER_KINDS:
        raise ValueError(
            f"{ev.kind!r} is a driver-level fault (ResilientEngine tears "
            "checkpoints; serving.router applies cluster kinds) — it is "
            "not an engine-state mutation")

    with engine._lock:
        if ev.kind == DROP_POKE:
            seq = np.asarray(engine._kv_sema.bucket_seq) \
                if engine._kv_pool is not None else None
            for s in sorted(engine.active):
                r = engine.active[s]
                if r.parked and seq is not None:
                    # the park's memo is overwritten with the CURRENT
                    # sequence: any poke since park time is forgotten,
                    # and the slot waits for the NEXT poke on its bucket
                    r.park_seq = int(seq[r.park_bucket])
                    return True
            return False

        if ev.kind == KV_COUNTER:
            if engine._kv_pool is None or ev.delta == 0:
                return False
            d = int(ev.delta)
            engine._kv_free_blocks += d
            engine._kv_sema = engine._kv_sema._replace(
                grant=engine._kv_sema.grant + jnp.uint32(d & 0xFFFFFFFF))
            if getattr(engine, "_kv_state", None) is not None:
                kv = engine._kv_state
                sema = kv.pool.sema._replace(
                    grant=kv.pool.sema.grant + jnp.uint32(d & 0xFFFFFFFF))
                engine._kv_state = kv._replace(
                    pool=kv.pool._replace(sema=sema))
                # keep the host mirror EXACTLY the device semaphore (it
                # resyncs at every drain anyway)
                engine._kv_sema = sema
            return True

        if ev.kind == DOUBLE_RELEASE:
            if engine._kv_pool is None:
                return False
            engine._kv_free_blocks += 1
            if getattr(engine, "_kv_state", None) is not None:
                kv = engine._kv_state
                NB = kv.pool.free_q.shape[0]
                tbl = np.asarray(kv.tbl).reshape(-1)
                live = tbl[tbl >= 0]
                # re-free a LIVE block when one exists (true aliasing);
                # else re-free the head of the free region (double free)
                victim = int(live[0]) if live.size else int(
                    np.asarray(kv.pool.free_q)[
                        int(np.uint32(kv.pool.sema.ticket)) & (NB - 1)])
                g = int(np.uint32(kv.pool.sema.grant))
                free_q = kv.pool.free_q.at[g & (NB - 1)].set(victim)
                sema = post_batch(kv.pool.sema, 1)  # grant+1, bucket poke
                engine._kv_state = kv._replace(
                    pool=kv.pool._replace(sema=sema, free_q=free_q))
                engine._kv_sema = sema
            else:
                engine._kv_sema = post_batch(engine._kv_sema, 1)
            return True

        if ev.kind == NAN_LOGIT:
            engine._nonfinite_sticky = True  # host H_NAN until restored
            if engine.megastep_model is not None:
                engine.megastep_model = _poison_model(engine.megastep_model)
            return True

        if ev.kind == BIT_FLIP:
            # flip one bit of a LIVE device block-table entry: the cell
            # now names either an out-of-range id or another slot's block
            # (aliasing) — the deep partition sentinel (H_KV_PARTITION)
            # trips and rung 2's audit_kv must clear the forged cell and
            # quarantine the victim slot.  Device-path only: the forged
            # identity physically lives in the persistent pool.
            if (engine._kv_pool is None
                    or getattr(engine, "_kv_state", None) is None):
                return False
            kv = engine._kv_state
            tbl = np.asarray(kv.tbl)
            live = np.argwhere(tbl >= 0)
            if live.size == 0:
                return False
            s, j = (int(v) for v in live[ev.arg % len(live)])
            bit = 1 << (abs(int(ev.delta)) % 5)  # low bits: in/near range
            engine._kv_state = kv._replace(
                tbl=kv.tbl.at[s, j].set(int(tbl[s, j]) ^ bit))
            return True

        if ev.kind == STUCK_SLOT:
            if not engine._chunk:
                return False  # only the chunk phase honors parks
            seq = np.asarray(engine._kv_sema.bucket_seq)
            table = len(seq)
            for s in sorted(engine.active):
                r = engine.active[s]
                # only a MID-PREFILL slot wedges identically on both
                # paths (parks gate the chunk phase; a decode-phase slot
                # keeps emitting in-scan).  prefill_pos < plen holds in
                # both cursor encodings (host pins at plen, device
                # counts past it), so the victim choice is path-stable.
                if not r.parked and r.prefill_pos < len(r.prompt):
                    b = ev.arg % table
                    r.parked = True
                    r.park_bucket = b
                    r.park_seq = int(seq[b])
                    return True
            return False

    raise ValueError(f"unknown fault kind {ev.kind!r}")
