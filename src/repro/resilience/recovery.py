"""The recovery ladder — escalating responses to sentinel health bits.

:class:`ResilientEngine` wraps a `serving.scheduler
.ContinuousBatchingEngine` and drives it (host ``step`` or ``megastep``)
with three additions, all deterministic:

1. **Fault injection** — the events of a seeded `faults.FaultPlan` are
   applied at their scheduled round boundaries (a megastep is split at
   fault rounds so both serving paths see each fault at the identical
   boundary).
2. **Reaction boundaries** — the per-round health bitmask drained from
   the telemetry ring is OR-accumulated and examined every
   ``react_every`` rounds; BOTH drives react at the same multiples, so
   the equivalence property (megastep ≡ K·step) survives recovery too.
3. **The ladder** — sick boundaries escalate:

   * rung 1, ``H_STUCK`` → :meth:`scheduler.quarantine` each wedged
     slot; the evicted request re-enters admission after a jittered
     exponential backoff (seeded PRNG — deterministic), up to its
     per-request retry budget, then is tombstoned;
   * rung 2, conservation bits (``H_KV_CONSERVE``/``H_KV_PARTITION``/
     ``H_CREDIT_NEG``/``H_BANKER``/``H_SLOT_CONSERVE``) →
     :meth:`scheduler.audit_kv` rebuilds the free queue and reconciles
     the block semaphore from block-table ground truth; aliasing victims
     are quarantined;
   * rung 3, conservation STILL sick at the next boundary with the
     fused kernel path active → fall back to the functional reference
     path (``use_kernel=False``) — divergence between the two
     implementations is the remaining suspect;
   * rung 4, ``H_NAN`` or still-sick → restore the last device snapshot
     through `checkpoint.manager.CheckpointManager` and deterministically
     replay the rounds since (re-applying every fault except the ones
     being repaired).  ``CRASH`` faults take this rung directly.

Every action is appended to :attr:`events` and counted in the engine's
``stats`` / ``telemetry()["recovery"]``.

Snapshots capture the persistent DEVICE state (QoS semaphores, block
pool + tables, model) through the checkpoint manager — exercising its
dtype round-trip on the uint32 counters — plus a host-side field capture
of every in-flight request (``threading.Event`` forbids deepcopy, so
requests are captured per-field and restored in place, preserving
object identity with the client's handle).

Replay determinism requires a round-stable clock (the frozen/virtual
clocks every test uses): replayed rounds re-read the injected ``clock=``
/ sliced ``nows`` and re-fire the surviving plan events, so a crashed
run converges to the same final state as an uncrashed one.
"""

from __future__ import annotations

import heapq

import numpy as np

from ..serving import sentinels as sn
from .faults import (
    CORRUPTION_KINDS,
    CRASH,
    FaultPlan,
    InjectedCrash,
    KV_COUNTER,
    NAN_LOGIT,
    TORN_SHARD,
    apply_fault,
    tear_checkpoint,
)

# Request fields captured per snapshot (threading.Event bars deepcopy;
# out_tokens is list-copied separately, done_event becomes a bool flag)
_REQ_FIELDS = (
    "ticket", "bucket", "observed_seq", "fast", "slot", "expired",
    "preempted", "submit_clock", "first_tok_clock", "last_tok_clock",
    "finish_clock", "admit_round", "expire_round", "prefill_pos",
    "kv_blocks", "prio_key", "parked", "park_bucket", "park_seq",
    "last_adv_round", "retries",
)

_CONSERVE = (sn.H_KV_CONSERVE | sn.H_KV_PARTITION | sn.H_CREDIT_NEG
             | sn.H_BANKER | sn.H_SLOT_CONSERVE)


def exit_audit(engine) -> dict:
    """Exit-time conservation audit over host ground truth (plus the
    device block table when one persists).  Returns ``{"ok": bool,
    "violations": [...]}`` — the chaos property asserts ``ok`` after
    every drained run."""
    violations = []
    act = set(engine.active)
    free = set(engine.free_slots)
    if act & free or len(act) + len(free) != engine.n_slots:
        violations.append(
            f"slots: active {sorted(act)} ∪ free {sorted(free)} does not "
            f"partition {{0..{engine.n_slots - 1}}}")
    if engine._tenants is not None:
        credit = (np.asarray(engine.qos.grant)
                  - np.asarray(engine.qos.consumed)).view(np.int32)
        if (credit < 0).any():
            violations.append(f"negative tenant credit {credit.tolist()}")
    if engine._kv_pool is not None:
        NB = engine._kv_blocks
        sharing = getattr(engine, "_kv_share", False)
        if sharing:
            # refcounted conservation: shared blocks are held ONCE — the
            # refcount support is the allocated set (replica np mirror)
            held = int((engine._kv_refcnt_h > 0).sum())
        elif engine._chunk:
            held = sum(r.kv_blocks for r in engine.active.values())
        else:
            held = sum(engine._kv_demand(r)
                       for r in engine.active.values())
        if engine._kv_free_blocks + held != NB:
            violations.append(
                f"kv counter: free {engine._kv_free_blocks} + held "
                f"{held} != {NB}")
        kv = getattr(engine, "_kv_state", None)
        if kv is not None:
            pool, tbl = kv.pool, np.asarray(kv.tbl)
        elif sharing:
            # host-loop sharing: the replica pool/table IS the ground
            # truth — audit it exactly like a persisted device pool
            pool, tbl = engine._kv_hpool, np.asarray(engine._kv_htbl)
        else:
            pool = tbl = None
        if pool is not None:
            live = tbl[tbl >= 0]
            n_free = int(np.int32(np.uint32(pool.sema.grant)
                                  - np.uint32(pool.sema.ticket)))
            if n_free < 0 or n_free > NB:
                violations.append(f"kv sema free count {n_free} out of "
                                  f"[0, {NB}]")
            elif sharing:
                # generalized partition: {free ids} ∪ {refcnt > 0} must
                # tile {0..NB−1}, and per-block table references must
                # equal the refcount (Σ table refs = Σ refcnt)
                refcnt = np.asarray(pool.refcnt)
                tick = int(np.uint32(pool.sema.ticket))
                pos = (tick + np.arange(n_free)) & (NB - 1)
                fid = np.asarray(pool.free_q)[pos]
                ok_f = (fid >= 0) & (fid < NB)
                cnt = np.bincount(fid[ok_f], minlength=NB)
                refs = np.bincount(live[live < NB], minlength=NB)
                if (~ok_f).any() or (live >= NB).any() or \
                        (cnt + (refcnt > 0) != 1).any():
                    violations.append(
                        "kv partition: free queue ∪ {refcnt > 0} does "
                        f"not tile 0..{NB - 1}")
                if (refs != refcnt).any():
                    violations.append(
                        "kv refcnt: table references do not match the "
                        "pool refcounts")
            else:
                tick = int(np.uint32(pool.sema.ticket))
                pos = (tick + np.arange(n_free)) & (NB - 1)
                ids = np.concatenate(
                    [np.asarray(pool.free_q)[pos], live])
                cnt = np.bincount(ids[(ids >= 0) & (ids < NB)],
                                  minlength=NB)
                if (ids < 0).any() or (ids >= NB).any() or (cnt != 1).any():
                    violations.append(
                        "kv partition: free queue ∪ tables is not a "
                        f"permutation of 0..{NB - 1}")
    return {"ok": not violations, "violations": violations}


class ResilientEngine:
    """Fault-injecting, self-healing driver around a serving engine.

    Parameters: ``plan`` — the seeded fault schedule (default: none);
    ``react_every`` — reaction-boundary stride A (both drives react at
    round multiples of A); ``retry_budget`` / ``backoff_base`` /
    ``backoff_jitter`` — quarantine-requeue policy (delay rounds =
    ``base·2^retries + U[0, jitter]`` off the seeded PRNG); ``ckpt`` — a
    `CheckpointManager` enabling rung 4; ``snapshot_every`` — periodic
    snapshot stride in rounds (0: only the automatic pre-crash
    snapshot)."""

    def __init__(self, engine, *, plan: FaultPlan | None = None,
                 react_every: int = 1, retry_budget: int = 2,
                 backoff_base: int = 2, backoff_jitter: int = 2,
                 seed: int = 0, ckpt=None, snapshot_every: int = 0):
        self.engine = engine
        self.plan = plan if plan is not None else FaultPlan(seed=0)
        self.react_every = max(1, int(react_every))
        self.retry_budget = int(retry_budget)
        self.backoff_base = max(1, int(backoff_base))
        self.backoff_jitter = max(0, int(backoff_jitter))
        self._rng = np.random.default_rng(seed)
        self.ckpt = ckpt
        self.snapshot_every = int(snapshot_every)
        self.events: list[dict] = []  # chronological action/injection log
        # every telemetry sample the driven engine produced, in drive
        # order (replayed rounds append again — the log is the literal
        # execution history, not the logical round timeline)
        self.samples: list[dict] = []
        self._retryq: list[tuple[int, int, object]] = []  # (due, rid, req)
        self._consumed: set[int] = set()  # plan event indices repaired
        self._conserve_streak = 0
        self._health_acc = 0
        self._snap = None  # (round, host_capture, ckpt_step) — newest
        # short history of (round, host_capture, ckpt_step): restore walks
        # it newest→oldest when the newest checkpoint is unreadable (a
        # torn shard) — one bad write must not make rung 4 unrecoverable
        self._snaps: list[tuple] = []
        self._snap_keep = 3

    # ------------------------------------------------------------- log ----

    def _log(self, rnd: int, action: str, **kw) -> None:
        self.events.append({"round": rnd, "action": action, **kw})

    def telemetry(self) -> dict:
        tel = self.engine.telemetry()
        tel["ladder_events"] = list(self.events)
        return tel

    def audit(self) -> dict:
        return exit_audit(self.engine)

    # ---------------------------------------------------------- drives ----

    def step(self, sample_fn) -> int:
        """One resilient host round: due requeues → snapshot → faults →
        ``engine.step`` → (at boundaries) react."""
        eng = self.engine
        r = eng._round_no
        if r % self.react_every == 0:
            self._process_retries(r)
        self._maybe_snapshot(r)
        try:
            self._apply_faults(r)
        except InjectedCrash:
            self._log(r, "crash")
            self._restore(r)
            n = 0
            while eng._round_no <= r:  # deterministic replay (see module
                n = self.step(sample_fn)  # docstring on clock stability)
            return n
        n = eng.step(sample_fn)
        if eng._last_samples:
            self.samples.extend(eng._last_samples)
            self._health_acc |= int(eng._last_samples[-1]["health"])
        if (r + 1) % self.react_every == 0:
            self._react(r + 1)
        return n

    def megastep(self, K: int, *, token_fn=None, nows=None, **kw) -> int:
        """K resilient scanned rounds: the launch is SPLIT at fault
        rounds, snapshot rounds, and reaction boundaries, so every
        injection and reaction happens at the identical engine boundary
        the host drive would use."""
        eng = self.engine
        base = eng._round_no
        if nows is None:
            nows_a = np.zeros(K, np.float32)
        else:
            nows_a = np.asarray(nows, np.float32)
        n = len(eng.active)
        done = 0
        while done < K:
            r = base + done
            if r % self.react_every == 0:
                self._process_retries(r)
            self._maybe_snapshot(r)
            try:
                self._apply_faults(r)
            except InjectedCrash:
                self._log(r, "crash")
                rs = self._restore(r)
                done = rs - base  # replay from the snapshot round
                continue
            seg = self._segment_len(r, base + K)
            n = eng.megastep(seg, token_fn=token_fn,
                             nows=nows_a[done:done + seg], **kw)
            self.samples.extend(eng._last_samples)
            for smp in eng._last_samples:
                self._health_acc |= int(smp["health"])
            done += seg
            if (base + done) % self.react_every == 0:
                self._react(base + done)
                # a rung-4 reaction may have restored a snapshot and
                # rewound the engine — resync the cursor so the replay
                # re-runs the rewound rounds (snapshots from an earlier
                # megastep call cannot be replayed here: the caller's
                # nows window does not cover them)
                rno = eng._round_no
                if rno != base + done:
                    if rno < base:
                        raise RuntimeError(
                            "restore rewound past this megastep's launch "
                            f"round ({rno} < {base}); use snapshot_every "
                            "aligned inside the launch window")
                    done = rno - base
        return n

    def _segment_len(self, r: int, end: int) -> int:
        """Rounds until the next boundary the scan must stop at."""
        cut = end
        nb = r - r % self.react_every + self.react_every
        cut = min(cut, nb)
        if self.snapshot_every and self.ckpt is not None:
            ns = r - r % self.snapshot_every + self.snapshot_every
            cut = min(cut, ns)
        for i, ev in enumerate(self.plan.events):
            if ev.round > r and i not in self._consumed:
                cut = min(cut, ev.round)
        return max(1, cut - r)

    # ------------------------------------------------------- injection ----

    def _apply_faults(self, r: int) -> None:
        for i, ev in enumerate(self.plan.events):
            if ev.round != r or i in self._consumed:
                continue
            if ev.kind == CRASH:
                self._consumed.add(i)  # one-shot: replay must not re-crash
                raise InjectedCrash(ev)
            if ev.kind == TORN_SHARD:
                # driver-level like CRASH: corrupt the newest on-disk
                # checkpoint (one-shot — the torn file stays torn; replay
                # must not re-tear a freshly written snapshot)
                self._consumed.add(i)
                torn = tear_checkpoint(self.ckpt) if self.ckpt else 0
                self._log(r, "inject", kind=ev.kind, applied=bool(torn))
                continue
            applied = apply_fault(self.engine, ev)
            self._log(r, "inject", kind=ev.kind, delta=ev.delta,
                      applied=bool(applied))

    # ------------------------------------------------- retries (rung 1) ----

    def _process_retries(self, r: int) -> None:
        eng = self.engine
        while self._retryq and self._retryq[0][0] <= r:
            _, _, req = heapq.heappop(self._retryq)
            req.retries += 1
            eng.stats.requeued += 1
            eng.submit(req)  # fresh ticket, fresh FCFS position
            self._log(r, "requeue", rid=req.rid, attempt=req.retries)

    def _quarantine(self, slot: int, rnd: int) -> None:
        eng = self.engine
        req = eng.quarantine(slot)
        self._log(rnd, "quarantine", slot=slot, rid=req.rid)
        if req.retries < self.retry_budget:
            delay = (self.backoff_base * (1 << req.retries)
                     + int(self._rng.integers(0, self.backoff_jitter + 1)))
            heapq.heappush(self._retryq, (rnd + delay, req.rid, req))
        else:
            with eng._lock:  # budget exhausted: tombstone (still drains)
                eng._expire_req(req, eng._tindex[req.tenant_id])
            self._log(rnd, "give_up", rid=req.rid)

    # -------------------------------------------------------- reaction ----

    def _react(self, boundary: int) -> None:
        h = self._health_acc
        self._health_acc = 0
        if h == 0:
            self._conserve_streak = 0
            return
        eng = self.engine
        self._log(boundary, "health", bits=sn.decode_health(h))
        # flight recorder: the ladder engaging IS the post-mortem moment —
        # cut a bundle before any rung mutates engine state
        fl = getattr(getattr(eng, "_obs", None), "flight", None)
        if fl is not None:
            fl.dump("recovery_ladder",
                    extra={"boundary": boundary, "mask": h,
                           "flags": sn.decode_health(h)})
        if h & sn.H_STUCK:
            W = eng._watchdog
            last = boundary - 1  # the last executed round's watchdog view
            for s in sorted(eng.active):
                if last - eng.active[s].last_adv_round >= W > 0:
                    self._quarantine(s, boundary)
        if h & _CONSERVE:
            self._conserve_streak += 1
            if self._conserve_streak == 1 and eng._kv_pool is not None:
                rep = eng.audit_kv()  # rung 2
                self._log(boundary, "audit_kv",
                          **{k: v for k, v in rep.items()})
                for s in rep["victims"]:
                    if s in eng.active:
                        self._quarantine(s, boundary)
            elif eng._use_kernel:
                eng._use_kernel = False  # rung 3: functional reference
                eng.stats.kernel_fallbacks += 1
                self._log(boundary, "kernel_fallback")
            else:
                self._rung4(boundary)
        else:
            self._conserve_streak = 0
        if h & sn.H_NAN:
            self._rung4(boundary)  # nothing below rung 4 un-poisons

    # ------------------------------------------ snapshot/restore (rung 4) ----

    def _device_tree(self):
        eng = self.engine
        return {
            "qos": eng.qos,
            "kv": eng._kv_state
            if getattr(eng, "_kv_state", None) is not None else (),
            "model": eng.megastep_model
            if eng.megastep_model is not None else (),
        }

    def _maybe_snapshot(self, r: int) -> None:
        if self.ckpt is None:
            return
        due = self.snapshot_every and r % self.snapshot_every == 0
        first = self._snap is None and any(
            ev.kind == CRASH and i not in self._consumed
            for i, ev in enumerate(self.plan.events))
        if due or first:
            self._snapshot(r)

    def _snapshot(self, r: int) -> None:
        eng = self.engine
        self.ckpt.save_sync(r, self._device_tree())
        self._snap = (r, self._capture_host(), r)
        self._snaps = [s for s in self._snaps if s[0] != r]
        self._snaps.append(self._snap)
        del self._snaps[:-self._snap_keep]
        eng.stats.snapshots += 1
        self._log(r, "snapshot", step=r)

    def _capture_host(self) -> dict:
        eng = self.engine
        reqs = {}

        def cap(r):
            if id(r) not in reqs:
                reqs[id(r)] = (r, {f: getattr(r, f) for f in _REQ_FIELDS},
                               list(r.out_tokens), r.done_event.is_set())

        for r in eng.active.values():
            cap(r)
        for r in eng.backlog:
            cap(r)
        snap = {
            "round_no": eng._round_no,
            "free_slots": list(eng.free_slots),
            "active": dict(eng.active),
            "backlog": list(eng.backlog),
            "stats": dict(eng.stats.__dict__),
            "sema": eng.sema,
            "sema_t": eng._sema_ticket_h, "sema_g": eng._sema_grant_h,
            "sticky": eng._nonfinite_sticky,
            "ladder": {
                "retryq": list(self._retryq),
                "consumed": set(self._consumed),
                "streak": self._conserve_streak,
                "health": self._health_acc,
                "rng": self._rng.bit_generator.state,
            },
        }
        if eng._tenants is not None:
            for q in eng._tenant_queues:
                for r in q:
                    cap(r)
            snap.update(
                qos_free=eng._qos_free,
                tenant_queues=[list(q) for q in eng._tenant_queues],
                tenant_live=eng._tenant_live.copy(),
                tenant_admitted=dict(eng.tenant_admitted),
                tenant_expired=dict(eng.tenant_expired),
                deadline_heap=list(eng._deadline_heap))
        if eng._kv_pool is not None:
            snap.update(kv_free=eng._kv_free_blocks, kv_sema=eng._kv_sema)
        for _, _, r in self._retryq:
            cap(r)
        snap["requests"] = reqs
        return snap

    def _restore(self, at_round: int) -> int:
        """Rung 4 core: device tree ← checkpoint, host state ← capture.
        Walks the snapshot history newest→oldest past unreadable (torn)
        checkpoints.  Returns the snapshot round (replay resumes there)."""
        if self.ckpt is None or self._snap is None:
            self._log(at_round, "unrecoverable")
            return at_round
        eng = self.engine
        tree = None
        for snap in reversed(self._snaps or [self._snap]):
            rs, host, step = snap
            try:
                tree, _ = self.ckpt.restore(self._device_tree(), step=step)
                break
            except Exception as exc:  # torn shard / missing step: fall back
                self._log(at_round, "torn_shard_fallback", step=step,
                          error=type(exc).__name__)
        if tree is None:
            self._log(at_round, "unrecoverable")
            return at_round
        self._snap = (rs, host, step)  # the snapshot that actually loaded
        eng.qos = tree["qos"]
        if tree["kv"] != ():
            eng._kv_state = tree["kv"]
        if tree["model"] != ():
            eng.megastep_model = tree["model"]
            eng._megastep_model_last = None  # force a fresh donation copy
        from collections import deque

        eng._round_no = host["round_no"]
        eng.free_slots = list(host["free_slots"])
        eng.active = dict(host["active"])
        eng.backlog = list(host["backlog"])
        eng.stats.__dict__.update(host["stats"])
        eng.sema = host["sema"]
        eng._sema_ticket_h = host["sema_t"]
        eng._sema_grant_h = host["sema_g"]
        eng._nonfinite_sticky = host["sticky"]
        if eng._tenants is not None:
            eng._qos_free = host["qos_free"]
            eng._tenant_queues = [deque(q) for q in host["tenant_queues"]]
            eng._tenant_live = host["tenant_live"].copy()
            eng.tenant_admitted = dict(host["tenant_admitted"])
            eng.tenant_expired = dict(host["tenant_expired"])
            eng._deadline_heap = list(host["deadline_heap"])
            heapq.heapify(eng._deadline_heap)
        if eng._kv_pool is not None:
            eng._kv_free_blocks = host["kv_free"]
            eng._kv_sema = host["kv_sema"]
        for r, fields, toks, done in host["requests"].values():
            for f, v in fields.items():
                setattr(r, f, v)
            r.out_tokens[:] = list(toks)
            if done:
                r.done_event.set()
            else:
                r.done_event.clear()
        lad = host["ladder"]
        self._retryq = list(lad["retryq"])
        heapq.heapify(self._retryq)
        self._conserve_streak = lad["streak"]
        self._health_acc = lad["health"]
        self._rng.bit_generator.state = lad["rng"]
        # restore MUST NOT resurrect the repaired corruption: consumed is
        # the union of what was consumed at snapshot time and now
        self._consumed |= set(lad["consumed"])
        eng.stats.restores += 1
        self._log(rs, "restore", step=step, from_round=at_round)
        return rs

    def _rung4(self, boundary: int) -> None:
        """Sickness-triggered restore: mark every past corruption event
        (incl. model poison) repaired so the replay converges clean."""
        for i, ev in enumerate(self.plan.events):
            if ev.round < boundary and (ev.kind in CORRUPTION_KINDS
                                        or ev.kind == NAN_LOGIT
                                        or (ev.kind == KV_COUNTER
                                            and ev.delta > 0)):
                self._consumed.add(i)
        self._restore(boundary)
        self._conserve_streak = 0
