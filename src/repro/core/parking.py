"""park/unpark — identity-based waiting (java.util.concurrent LockSupport style).

The paper (§2, Waiting Chains) requires: ``If the unpark were to execute
before the corresponding park, the threading system maintains a per-thread
flag set accordingly, and the subsequent park operation clears the flag and
returns immediately`` — i.e. a bounded binary per-thread semaphore.

`Self()` returns the identity handle usable with `unpark`.  Handles are plain
objects registered per thread; `unpark` on a *stale* handle (thread gone) is
safe, matching the paper's "safe to unpark a stale thread reference".
"""

from __future__ import annotations

import threading
import time


class ParkToken:
    """Per-thread binary permit."""

    __slots__ = ("_cond", "_permit")

    def __init__(self):
        self._cond = threading.Condition()
        self._permit = False

    def park(self, timeout: float | None = None) -> None:
        with self._cond:
            if self._permit:
                self._permit = False
                return
            self._cond.wait(timeout)
            # Consume the permit if it arrived; spurious wakeups are allowed
            # (callers always re-check their condition, per the paper).
            self._permit = False

    def unpark(self) -> None:
        with self._cond:
            self._permit = True
            self._cond.notify()


_tls = threading.local()


def self_token() -> ParkToken:
    """The paper's ``Self()`` — identity of the calling thread for park/unpark."""
    tok = getattr(_tls, "token", None)
    if tok is None:
        tok = ParkToken()
        _tls.token = tok
    return tok


def park(timeout: float | None = None) -> None:
    self_token().park(timeout)


def unpark(who: ParkToken | None) -> None:
    if who is not None:
        who.unpark()


def pause() -> None:
    """The paper's ``Pause()`` (x86 ``rep;nop``).

    Under CPython, a zero sleep is the closest "polite spin" analogue: it
    releases the GIL so other runnable threads (including the eventual
    poster) can make progress — the same *intent* as PAUSE/sched_yield,
    with the caveats about sched_yield the paper itself discusses.
    """
    time.sleep(0)
