"""The paper's `pthread` reference point: the default system user-mode
semaphore — counting semaphore over a mutex + condition variable, with **no
FIFO admission guarantee** (wakeup order is whatever the threading system
does; barging is possible because a poster's increment can be consumed by a
late arriver before any blocked waiter runs).

Used as the third curve in semabench (Figure 1) and as the non-FIFO control
in fairness tests.
"""

from __future__ import annotations

import threading


class PthreadLikeSemaphore:
    def __init__(self, count: int = 0):
        assert count >= 0
        self._count = count
        self._cond = threading.Condition()
        # telemetry only:
        self._takes = 0
        self._posts = 0

    def take(self) -> None:
        with self._cond:
            while self._count == 0:
                self._cond.wait()
            self._count -= 1
            self._takes += 1

    def post(self, n: int = 1) -> None:
        with self._cond:
            self._count += n
            self._posts += n
            if n == 1:
                self._cond.notify()
            else:
                self._cond.notify_all()

    def available(self) -> int:
        with self._cond:
            return self._count
