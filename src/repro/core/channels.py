"""Listing 4 — TWA-Semaphore with MONITOR-MWAIT inspired waiting channels.

Each channel augments the waiting chain with an ``UpdateSequence`` counter:
``KeyMonitor`` samples the sequence ("arm the monitor"), ``KeyWait`` blocks
until the sequence moves — the sequence is a conservative *proxy* for the
condition of interest, exactly like MESI-state proxies under hardware
MONITOR-MWAIT / WFET.

    Indirection:  Location value → WaitChannel.Sequence → WaitElement.Gate
    Dekker pivot: Signal : ST Cond ; ST Sequence ; LD Chain
                  Wait   : LD Sequence ; LD Cond ; ST Chain ; LD Sequence

KeyMonitor is passive (no store, nothing emplaced) so no abort operator is
needed — the cost is one more level of indirection on the wait path.
"""

from __future__ import annotations

from .atomics import AtomicRef, AtomicU64
from .hashfn import index_for, mix32a, twa_hash
from .parking import self_token
from .ticket_semaphore import _dist
from .waiting_chains import WaitElement, _park_until_gate, poke

DEFAULT_TABLE_SIZE = 4096


class WaitChannel:
    __slots__ = ("chain", "sequence")

    def __init__(self):
        self.chain: AtomicRef[WaitElement] = AtomicRef(None)
        self.sequence = AtomicU64(0)


class ChannelTable:
    def __init__(self, table_size: int = DEFAULT_TABLE_SIZE):
        assert table_size > 0 and (table_size & (table_size - 1)) == 0
        self.table_size = table_size
        self.slots = [WaitChannel() for _ in range(table_size)]

    def key_to_channel(self, key: int) -> WaitChannel:
        return self.slots[index_for(key, self.table_size)]


_GLOBAL_CHANNELS = ChannelTable()


def key_monitor(ch: WaitChannel) -> int:
    return ch.sequence.load()


def key_signal(ch: WaitChannel) -> None:
    ch.sequence.fetch_add(1)
    poke(ch.chain.exchange(None))


def key_signal_polite(ch: WaitChannel) -> None:
    ch.sequence.fetch_add(1)
    if ch.chain.load() is not None:
        poke(ch.chain.exchange(None))


def key_wait(ch: WaitChannel, sequence: int) -> int:
    """Block until ch.sequence != sequence (proxy wait). Strict/persistent."""
    while True:
        # Optional optimization: reduces mis-queue rate / futile flushing.
        if ch.sequence.load() != sequence:
            return 0
        e = WaitElement()
        e.who = self_token()
        prv = ch.chain.exchange(e)
        assert prv is not e
        # Ratify — close the race against a concurrent key_signal.
        if ch.sequence.load() != sequence:
            # Mis-queued; recover. (The CAS-undo of Listing 3 is intentionally
            # omitted — the paper argues it saves nothing here because a
            # displaced prv must re-check its sequence anyway.)
            if e.gate.load() != 0:
                poke(prv)  # already flushed off-chain
                return 0
            prefix = ch.chain.exchange(None)
            assert (prv is not prefix) or (prv is None and prefix is None)
            poke(prv)
            poke(prefix)
            _park_until_gate(e)
            return 0
        # Properly enqueued — dominant case.
        _park_until_gate(e)
        poke(prv)  # systolic propagation
        # Loop: we may have been purged by a flush or hash collision.


def key_wait_lazy(ch: WaitChannel, sequence: int) -> tuple[int, int]:
    """Listing 4's KeyWaitLazy — passes the observed sequence back (Python:
    returned). First call with a stale guess returns immediately, arming the
    caller's loop; usage avoids explicit KeyMonitor calls entirely."""
    us = sequence
    sequence = ch.sequence.load()
    if us != sequence:
        return 0, sequence
    e = WaitElement()
    e.who = self_token()
    prv = ch.chain.exchange(e)
    assert prv is not e
    new_seq = ch.sequence.load()
    if us != new_seq:
        sequence = new_seq
        if e.gate.load() != 0:
            poke(prv)
            return 0, sequence
        prefix = ch.chain.exchange(None)
        poke(prv)
        poke(prefix)
        _park_until_gate(e)
        return 0, sequence
    _park_until_gate(e)
    poke(prv)
    return 0, ch.sequence.load()  # lazy & relaxed — caller re-evaluates


class TWASemaphoreChannels:
    """Listing 4's SemaTake/SemaPost over monitor/wait channels."""

    def __init__(self, count: int = 0, table: ChannelTable | None = None):
        assert count >= 0
        self.ticket = AtomicU64(0)
        self.grant = AtomicU64(count)
        self.table = table if table is not None else _GLOBAL_CHANNELS
        self._addr = mix32a(id(self) & 0xFFFFFFFF)

    def take(self) -> None:
        tx = self.ticket.fetch_add(1)
        if _dist(self.grant.load(), tx) > 0:
            return
        ch = self.table.key_to_channel(twa_hash(self._addr, tx))
        while True:
            seq = key_monitor(ch)
            if _dist(self.grant.load(), tx) > 0:
                break
            key_wait(ch, seq)

    def post(self, n: int = 1) -> None:
        for _ in range(n):
            g = self.grant.fetch_add(1)
            key_signal(self.table.key_to_channel(twa_hash(self._addr, g)))

    def queue_depth(self) -> int:
        return max(0, -_dist(self.grant.load(), self.ticket.load()))

    def available(self) -> int:
        return max(0, _dist(self.grant.load(), self.ticket.load()))
