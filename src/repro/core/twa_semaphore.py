"""Listing 2 — TWA-Semaphore: Ticket-Semaphore augmented with a waiting array.

Arriving threads whose distance to Grant exceeds ``LongTermThreshold`` leave
the hot Grant location and wait *semi-locally* on a hashed bucket of a shared
fixed-size waiting array (proxy ``UpdateSequence`` modification indicators).
``post`` increments Grant, then pokes the bucket for ticket value
``grant + LongTermThreshold`` — the *successor's successor* — shifting it
from long-term (bucket) to short-term (Grant) waiting while the immediate
successor is already entering the critical section: wakeup staging overlaps
useful work.

Global spinning is reduced to ≤ LongTermThreshold threads per semaphore at a
time; all other waiting is dispersed over the array by the ticket-aware hash.

The waiting array is **process-global and shared by all semaphores** (as in
the paper); collisions across unrelated semaphores are benign (spurious
re-checks), only a performance concern.

Bucket waiting modes:
  - "spin":  Listing 2 verbatim — poll the bucket's UpdateSequence.
  - "futex": block on the bucket (futex/WaitOnAddress analogue): waiters
             sleep on a per-bucket condition keyed by UpdateSequence value;
             the poke is a notify_all on that bucket only.  Because buckets
             are dispersed by TWAHash, futex-style waiting also disperses
             kernel hashtable traffic — the paper's noted side benefit.

``post`` implements the benaphore-style fast path: after the Grant
fetch_add, if ``grant + threshold - Ticket >= 0`` there can be no long-term
waiter needing notification and the bucket poke is skipped (racy but
conservative — never skips a *needed* poke, may rarely do a futile one).
"""

from __future__ import annotations

import threading

from .atomics import AtomicU64
from .hashfn import index_for, twa_hash
from .parking import pause
from .ticket_semaphore import _dist

DEFAULT_TABLE_SIZE = 2048
DEFAULT_LONG_TERM_THRESHOLD = 1


class WaitBucket:
    """One slot of the waiting array.

    ``seq`` is the paper's UpdateSequence. The condition variable exists only
    for "futex" mode; spin mode never touches it. (In C++ the bucket is a
    single aligned cache line; object-per-bucket is the Python analogue of
    the 128-byte sector alignment.)
    """

    __slots__ = ("seq", "_cond")

    def __init__(self):
        self.seq = AtomicU64(0)
        self._cond = threading.Condition()

    def wait_for_change(self, observed: int, spin: bool) -> None:
        if spin:
            while self.seq.load() == observed:
                pause()
        else:
            with self._cond:
                while self.seq.load() == observed:
                    self._cond.wait()

    def poke(self) -> None:
        self.seq.fetch_add(1)
        with self._cond:
            self._cond.notify_all()


class WaitingArray:
    """Process-wide waiting array (flat table of WaitBucket)."""

    def __init__(self, table_size: int = DEFAULT_TABLE_SIZE):
        assert table_size > 0 and (table_size & (table_size - 1)) == 0
        self.table_size = table_size
        self.buckets = [WaitBucket() for _ in range(table_size)]

    def bucket_for(self, key: int) -> WaitBucket:
        return self.buckets[index_for(key, self.table_size)]


# The process-global default array, shared by every TWASemaphore (paper §1:
# "The waiting array is shared by all threads in the process and is of fixed
# size.").
_GLOBAL_ARRAY = WaitingArray()


class TWASemaphore:
    def __init__(
        self,
        count: int = 0,
        waiting: str = "spin",
        long_term_threshold: int = DEFAULT_LONG_TERM_THRESHOLD,
        array: WaitingArray | None = None,
        post_fast_path: bool = True,
        hash_fn=twa_hash,
    ):
        assert count >= 0
        assert waiting in ("spin", "futex")
        self.ticket = AtomicU64(0)
        self.grant = AtomicU64(count)
        self.threshold = long_term_threshold
        self.array = array if array is not None else _GLOBAL_ARRAY
        self._spin_buckets = waiting == "spin"
        self._post_fast_path = post_fast_path
        self._hash = hash_fn
        self._addr = id(self)  # uintptr_t(L) component of TWAHash

    # -- take ----------------------------------------------------------------
    def take(self) -> None:
        tx = self.ticket.fetch_add(1)
        dx = _dist(self.grant.load(), tx)
        if dx > 0:  # fast-path uncontended return
            return
        # slow path: contended — need to wait.
        bucket = self.array.bucket_for(self._hash(self._addr, tx))
        mx = bucket.seq.load()
        while True:
            dx = _dist(self.grant.load(), tx)
            if dx > 0:
                return
            if (dx + self.threshold) > 0:
                # Short-term: near the head of the logical queue — global
                # polling directly on Grant for minimal handover latency.
                pause()
                continue
            # Long-term distal waiting — semi-local via the waiting array;
            # the bucket's UpdateSequence is a proxy change indicator.
            vx = mx
            bucket.wait_for_change(vx, self._spin_buckets)
            mx = bucket.seq.load()

    # -- post ----------------------------------------------------------------
    def post(self, n: int = 1) -> None:
        for _ in range(n):  # each unit may enable a distinct long-term waiter
            g = self.grant.fetch_add(1)
            g += self.threshold
            if self._post_fast_path:
                # Benaphore-style conservative fast path: if no thread can be
                # long-term waiting past g, skip the array access entirely —
                # avoids "marching" through the array on uncontended posts.
                dx = _dist(g, self.ticket.load())
                if dx >= 0:
                    continue
            # Poke successor-of-successor from long-term into short-term mode.
            self.array.bucket_for(self._hash(self._addr, g)).poke()

    # -- introspection ---------------------------------------------------------
    def queue_depth(self) -> int:
        return max(0, -_dist(self.grant.load(), self.ticket.load()))

    def available(self) -> int:
        return max(0, _dist(self.grant.load(), self.ticket.load()))
