"""Listing 2 — TWA-Semaphore: Ticket-Semaphore augmented with a waiting array.

Arriving threads whose distance to Grant exceeds ``LongTermThreshold`` leave
the hot Grant location and wait *semi-locally* on a hashed bucket of a shared
fixed-size waiting array (proxy ``UpdateSequence`` modification indicators).
``post`` increments Grant, then pokes the bucket for ticket value
``grant + LongTermThreshold`` — the *successor's successor* — shifting it
from long-term (bucket) to short-term (Grant) waiting while the immediate
successor is already entering the critical section: wakeup staging overlaps
useful work.

Global spinning is reduced to ≤ LongTermThreshold threads per semaphore at a
time; all other waiting is dispersed over the array by the ticket-aware hash.

The waiting array is **process-global and shared by all semaphores** (as in
the paper); collisions across unrelated semaphores are benign (spurious
re-checks), only a performance concern.

Bucket waiting modes:
  - "spin":  Listing 2 verbatim — poll the bucket's UpdateSequence.
  - "futex": block on the bucket (futex/WaitOnAddress analogue): waiters
             sleep on a per-bucket condition keyed by UpdateSequence value;
             the poke is a notify_all on that bucket only.  Because buckets
             are dispersed by TWAHash, futex-style waiting also disperses
             kernel hashtable traffic — the paper's noted side benefit.

``post`` implements the benaphore-style fast path: after the Grant
fetch_add, if ``grant + threshold - Ticket >= 0`` there can be no long-term
waiter needing notification and the bucket poke is skipped (racy but
conservative — never skips a *needed* poke, may rarely do a futile one).

Cancellation (the extension the admission subsystem builds on): ticket
designs are awkward to revoke because an issued ticket occupies a fixed
position in the grant sequence — it cannot simply vanish.  With
``cancellation=True`` the semaphore runs a **tombstone protocol**:

  * an abandoning waiter marks its ticket dead (``cancel``); the ticket
    keeps its place in the FCFS order but will never consume a slot;
  * ``post`` becomes *skip-aware*: after advancing Grant, if the ticket
    just enabled is tombstoned the unit is re-posted — Grant advances
    again — so the slot flows to the next *live* ticket.  FCFS among live
    waiters is preserved exactly (dead tickets are transparent);
  * the cancel/post race is resolved under one lock: ``cancel`` loses
    (returns False) iff Grant already covered the ticket, in which case
    the caller owns the slot after all and must release it normally.

``take_until`` is the deadline-aware take built on this: on expiry it
tombstones its own ticket; a lost race means the slot arrived concurrently
and the take reports success instead.
"""

from __future__ import annotations

import threading
import time

from .atomics import AtomicU64
from .hashfn import index_for, twa_hash
from .parking import pause
from .ticket_semaphore import _dist

DEFAULT_TABLE_SIZE = 2048
DEFAULT_LONG_TERM_THRESHOLD = 1


class WaitBucket:
    """One slot of the waiting array.

    ``seq`` is the paper's UpdateSequence. The condition variable exists only
    for "futex" mode; spin mode never touches it. (In C++ the bucket is a
    single aligned cache line; object-per-bucket is the Python analogue of
    the 128-byte sector alignment.)
    """

    __slots__ = ("seq", "_cond")

    def __init__(self):
        self.seq = AtomicU64(0)
        self._cond = threading.Condition()

    def wait_for_change(self, observed: int, spin: bool,
                        deadline: float | None = None) -> None:
        """Block until ``seq`` moves past ``observed`` or ``deadline`` (an
        absolute ``time.monotonic`` instant) passes.  Spurious returns are
        fine — callers re-check Grant in their outer loop."""
        if spin:
            checks = 0
            while self.seq.load() == observed:
                pause()
                checks += 1
                if deadline is not None and (checks & 0x3F) == 0 \
                        and time.monotonic() >= deadline:
                    return
        else:
            with self._cond:
                while self.seq.load() == observed:
                    if deadline is None:
                        self._cond.wait()
                    else:
                        left = deadline - time.monotonic()
                        if left <= 0:
                            return
                        self._cond.wait(left)

    def poke(self) -> None:
        self.seq.fetch_add(1)
        with self._cond:
            self._cond.notify_all()


class WaitingArray:
    """Process-wide waiting array (flat table of WaitBucket)."""

    def __init__(self, table_size: int = DEFAULT_TABLE_SIZE):
        assert table_size > 0 and (table_size & (table_size - 1)) == 0
        self.table_size = table_size
        self.buckets = [WaitBucket() for _ in range(table_size)]

    def bucket_for(self, key: int) -> WaitBucket:
        return self.buckets[index_for(key, self.table_size)]


# The process-global default array, shared by every TWASemaphore (paper §1:
# "The waiting array is shared by all threads in the process and is of fixed
# size.").
_GLOBAL_ARRAY = WaitingArray()


class TWASemaphore:
    def __init__(
        self,
        count: int = 0,
        waiting: str = "spin",
        long_term_threshold: int = DEFAULT_LONG_TERM_THRESHOLD,
        array: WaitingArray | None = None,
        post_fast_path: bool = True,
        hash_fn=twa_hash,
        cancellation: bool = False,
    ):
        assert count >= 0
        assert waiting in ("spin", "futex")
        self.ticket = AtomicU64(0)
        self.grant = AtomicU64(count)
        self.threshold = long_term_threshold
        self.array = array if array is not None else _GLOBAL_ARRAY
        self._spin_buckets = waiting == "spin"
        self._post_fast_path = post_fast_path
        self._hash = hash_fn
        self._addr = id(self)  # uintptr_t(L) component of TWAHash
        # Tombstone protocol state (cancellation=True only).  The lock orders
        # cancel's (grant check, mark-dead) against post's (advance,
        # dead-check) so a slot is never granted to a dead ticket NOR a
        # cancelled waiter left believing both outcomes at once.
        self._cancellation = cancellation
        self._tombstones: set[int] = set()
        self._tomb_lock = threading.Lock()
        self.tombstones_skipped = 0  # posts re-issued past dead tickets

    # -- take ----------------------------------------------------------------
    def take(self) -> None:
        tx = self.ticket.fetch_add(1)
        dx = _dist(self.grant.load(), tx)
        if dx > 0:  # fast-path uncontended return
            return
        # slow path: contended — need to wait.
        bucket = self.array.bucket_for(self._hash(self._addr, tx))
        mx = bucket.seq.load()
        while True:
            dx = _dist(self.grant.load(), tx)
            if dx > 0:
                return
            if (dx + self.threshold) > 0:
                # Short-term: near the head of the logical queue — global
                # polling directly on Grant for minimal handover latency.
                pause()
                continue
            # Long-term distal waiting — semi-local via the waiting array;
            # the bucket's UpdateSequence is a proxy change indicator.
            vx = mx
            bucket.wait_for_change(vx, self._spin_buckets)
            mx = bucket.seq.load()

    def take_until(self, deadline: float | None) -> bool:
        """Deadline-aware take (requires ``cancellation=True`` when a deadline
        is given).  ``deadline`` is an absolute ``time.monotonic`` instant;
        None degrades to a plain blocking ``take``.  Returns True iff the
        slot was acquired; on False the ticket has been tombstoned and will
        be skipped by future posts."""
        if deadline is None:
            self.take()
            return True
        assert self._cancellation, "take_until(deadline) needs cancellation=True"
        tx = self.ticket.fetch_add(1)
        if _dist(self.grant.load(), tx) > 0:
            return True
        bucket = self.array.bucket_for(self._hash(self._addr, tx))
        mx = bucket.seq.load()
        while True:
            dx = _dist(self.grant.load(), tx)
            if dx > 0:
                return True
            if time.monotonic() >= deadline:
                # Lost-race semantics: cancel fails iff grant already covered
                # the ticket — then the slot is ours despite the timeout.
                return not self.cancel(tx)
            if (dx + self.threshold) > 0:
                pause()
                continue
            vx = mx
            bucket.wait_for_change(vx, self._spin_buckets, deadline)
            mx = bucket.seq.load()

    # -- cancellation ---------------------------------------------------------
    def cancel(self, ticket: int) -> bool:
        """Tombstone ``ticket``.  True: the ticket is dead, it will never
        consume a slot and later live tickets keep FCFS order.  False: the
        grant sequence already reached the ticket — the caller holds the
        slot and must ``post`` it back when done."""
        assert self._cancellation, "constructed without cancellation support"
        with self._tomb_lock:
            if _dist(self.grant.load(), ticket) > 0:
                return False  # too late — already granted
            self._tombstones.add(ticket)
            return True

    # -- post ----------------------------------------------------------------
    def post(self, n: int = 1) -> None:
        pending = n
        while pending > 0:  # each unit may enable a distinct long-term waiter
            g = self.grant.fetch_add(1)
            enabled = g  # grant g→g+1 enables exactly ticket g
            if self._cancellation:
                # Skip-aware path: a unit landing on a tombstoned ticket is
                # re-posted so the slot flows to the next live waiter.  The
                # dead-check must happen under the lock AFTER the fetch_add
                # (see cancel) — the set is usually empty, and membership
                # costs O(1).
                with self._tomb_lock:
                    dead = enabled in self._tombstones
                    if dead:
                        self._tombstones.discard(enabled)
                if dead:
                    self.tombstones_skipped += 1
                else:
                    pending -= 1
            else:
                pending -= 1
            g += self.threshold
            if self._post_fast_path:
                # Benaphore-style conservative fast path: if no thread can be
                # long-term waiting past g, skip the array access entirely —
                # avoids "marching" through the array on uncontended posts.
                dx = _dist(g, self.ticket.load())
                if dx >= 0:
                    continue
            # Poke successor-of-successor from long-term into short-term mode.
            self.array.bucket_for(self._hash(self._addr, g)).poke()

    def poke_ticket(self, ticket: int) -> None:
        """Wake whatever is parked on ``ticket``'s bucket.  Used by external
        cancellers (admission.cancellable) so a futex-parked waiter observes
        its cancellation instead of sleeping on a bucket nobody will poke."""
        self.array.bucket_for(self._hash(self._addr, ticket)).poke()

    # -- introspection ---------------------------------------------------------
    def queue_depth(self) -> int:
        return max(0, -_dist(self.grant.load(), self.ticket.load()))

    def available(self) -> int:
        return max(0, _dist(self.grant.load(), self.ticket.load()))

    def tombstones_pending(self) -> int:
        with self._tomb_lock:
            return len(self._tombstones)

    def live_queue_depth(self) -> int:
        """Waiters in line excluding tombstoned (abandoned) tickets."""
        return max(0, self.queue_depth() - self.tombstones_pending())
