"""Listing 3 — TWA-Semaphore with address-based waiting chains.

The waiting-array elements become pointers to *chains* — lock-free concurrent
pop-stacks (push-one / detach-all) of on-stack ``WaitElement``s.  This converts
global spinning into local 1:1 waiting (at most one thread per Gate), which
makes waiting amenable to blocking primitives (park/unpark, futex).

Key properties transcribed from the paper:
  * arriving threads push themselves with an atomic exchange (SWAP);
  * linkage is implicit — each thread remembers ``prv`` (what it displaced),
    like CLH locks; no intrusive next pointers;
  * notification detaches the ENTIRE chain with exchange(None) and pokes the
    first element; each woken waiter pokes its ``prv`` — systolic propagation;
  * wake-one/wake-all policy ⇒ spurious wakeups are benign; callers must
    re-evaluate their condition (AddressWaitUntil is "strict and persistent");
  * hash collisions merely co-locate independent waiters on one chain;
  * the mis-queue recovery path (condition became true between push and
    ratify) attempts, in order: CAS-undo of the push; detecting an already-
    completed flush; detecting own Gate already set; full flush-and-wait.

Dekker duality pivot (the lost-wakeup proof obligation):
    Wait : ST Chain ; LD Condition
    Post : ST Condition ; LD Chain
"""

from __future__ import annotations

from .atomics import AtomicInt, AtomicRef, AtomicU64
from .hashfn import index_for, mix32a, twa_hash
from .parking import ParkToken, self_token, unpark
from .ticket_semaphore import _dist

DEFAULT_TABLE_SIZE = 4096

# Defensive bound on a single park() so that a *bug-induced* lost wakeup
# degrades to slow polling instead of a hang; the algorithm treats any early
# return as a spurious wakeup (allowed by design) and re-checks Gate.
_PARK_QUANTUM = 0.05


class WaitElement:
    """Per-waiting-episode element (``alignas(128)`` in C++ — here a plain
    object, naturally unshared). ``gate``: made-ready flag. ``who``: park
    identity."""

    __slots__ = ("gate", "who")

    def __init__(self):
        self.gate = AtomicInt(0)
        self.who: ParkToken | None = None


class WaitChain:
    __slots__ = ("chain",)

    def __init__(self):
        self.chain: AtomicRef[WaitElement] = AtomicRef(None)


class ChainTable:
    """Flat hashtable of WaitChain buckets (process-wide)."""

    def __init__(self, table_size: int = DEFAULT_TABLE_SIZE):
        assert table_size > 0 and (table_size & (table_size - 1)) == 0
        self.table_size = table_size
        self.slots = [WaitChain() for _ in range(table_size)]

    def key_to_chain(self, key: int) -> WaitChain:
        return self.slots[index_for(key, self.table_size)]


_GLOBAL_CHAINS = ChainTable()


def poke(e: WaitElement | None) -> None:
    if e is None:
        return
    who = e.who
    e.gate.store(1)
    # After gate=1 `e` may fall out of scope; unparking a stale token is safe.
    unpark(who)


def address_signal(key: int, table: ChainTable = _GLOBAL_CHAINS) -> None:
    slot = table.key_to_chain(key)
    poke(slot.chain.exchange(None))


def address_signal_polite(key: int, table: ChainTable = _GLOBAL_CHAINS) -> None:
    """Avoids mutating an already-empty chain pointer (less coherence traffic)."""
    slot = table.key_to_chain(key)
    if slot.chain.load() is not None:
        poke(slot.chain.exchange(None))


def _park_until_gate(e: WaitElement) -> None:
    tok = e.who
    while e.gate.load() == 0:
        tok.park(_PARK_QUANTUM)


def address_wait_until(key: int, satisfied, table: ChainTable = _GLOBAL_CHAINS):
    """Wait (parking) until ``satisfied()`` returns truthy; returns its value.

    Strict/persistent: re-pushes and resumes waiting after spurious wakeups
    (flushes, hash collisions) until the condition holds.
    """
    v = satisfied()
    if v:
        return v
    s = table.key_to_chain(key)
    while True:
        # Cheap re-check before becoming a visible waiter.
        v = satisfied()
        if v:
            return v
        e = WaitElement()
        e.who = self_token()
        prv = s.chain.exchange(e)
        assert prv is not e
        # Ratify: close the race against a concurrent address_signal.
        v = satisfied()
        if v:
            # Mis-queued — recover. We cannot return until E is off-chain
            # (privatized) and successors have been notified.
            k = s.chain.cas(e, prv)  # try to simply undo the push
            if k is e:
                assert e.gate.load() == 0
                return v
            if k is None:
                # A signaller flushed the chain (detaching E) in the window.
                poke(prv)
                _park_until_gate(e)
                return v
            if e.gate.load() != 0:
                # Already flushed & poked — skip the full flush.
                poke(prv)
                return v
            # Full chain flush: eject and wake everyone (suffix first — see
            # paper QoI note), then wait until our own Gate confirms that E
            # is detached and privatized.
            prefix = s.chain.exchange(None)
            assert (prv is not prefix) or (prv is None and prefix is None)
            poke(prv)
            poke(prefix)
            _park_until_gate(e)
            return v
        # Properly enqueued — wait politely (dominant case).
        _park_until_gate(e)
        # Systolic wakeup propagation through the rest of the stack.
        poke(prv)
        # We may have been woken by a flush or a hash collision — loop and
        # re-evaluate; if needed we re-push and resume waiting.


class TWASemaphoreChains:
    """Listing 3's SemaTake/SemaPost on waiting chains (threshold elided, as
    in the paper's listing)."""

    def __init__(self, count: int = 0, table: ChainTable | None = None):
        assert count >= 0
        self.ticket = AtomicU64(0)
        self.grant = AtomicU64(count)
        self.table = table if table is not None else _GLOBAL_CHAINS
        self._addr = mix32a(id(self) & 0xFFFFFFFF)

    def take(self) -> None:
        tx = self.ticket.fetch_add(1)
        if _dist(self.grant.load(), tx) > 0:
            return  # fast-path uncontended
        key = twa_hash(self._addr, tx)
        address_wait_until(
            key, lambda: 1 if _dist(self.grant.load(), tx) > 0 else 0, self.table
        )
        assert _dist(self.grant.load(), tx) > 0

    def post(self, n: int = 1) -> None:
        for _ in range(n):
            g = self.grant.fetch_add(1)
            address_signal(twa_hash(self._addr, g), self.table)

    def queue_depth(self) -> int:
        return max(0, -_dist(self.grant.load(), self.ticket.load()))

    def available(self) -> int:
        return max(0, _dist(self.grant.load(), self.ticket.load()))
