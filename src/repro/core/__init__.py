"""The paper's primary contribution: semaphores augmented with a waiting array.

L1 (host threads, faithful listings):
  TicketSemaphore            — Listing 1 (ticket/grant, global spinning)
  TWASemaphore               — Listing 2 (waiting array of UpdateSequence buckets)
  TWASemaphoreChains         — Listing 3 (lock-free pop-stack chains + park/unpark)
  TWASemaphoreChannels       — Listing 4 (MONITOR-MWAIT-style Key* channels)
  TWASemaphoreV3             — Listing 5 (LocationWait, TLS deferred elements)
  PthreadLikeSemaphore       — the paper's non-FIFO `pthread` baseline

L2 (in-graph functional adaptation): core.functional (SemaState, take_batch,
post_batch, MultiSemaState …) — see kernels/sema_batch for the Pallas form.

Validation of the paper's empirical claims on this 1-core box:
  core.simulator — discrete-event coherence-cost model (Figure 1).
"""

from .channels import TWASemaphoreChannels
from .eventcount import EventCount, Sequencer, TicketMutex
from .functional import (
    MultiSemaState,
    SemaState,
    make_multi_sema,
    make_sema,
    poll,
    post_batch,
    post_batch_multi,
    take_batch,
    take_batch_multi,
    woken_mask,
)
from .location_wait import TWASemaphoreV3, tls_cleanup
from .pthread_like import PthreadLikeSemaphore
from .simulator import SimParams, simulate, sweep
from .ticket_semaphore import TicketSemaphore
from .twa_semaphore import TWASemaphore, WaitingArray
from .waiting_chains import TWASemaphoreChains

SEMAPHORE_KINDS = {
    "ticket": TicketSemaphore,
    "twa": TWASemaphore,
    "twa-chains": TWASemaphoreChains,
    "twa-channels": TWASemaphoreChannels,
    "twa-v3": TWASemaphoreV3,
    "pthread": PthreadLikeSemaphore,
}

__all__ = [
    "EventCount",
    "Sequencer",
    "TicketMutex",
    "TicketSemaphore",
    "TWASemaphore",
    "WaitingArray",
    "TWASemaphoreChains",
    "TWASemaphoreChannels",
    "TWASemaphoreV3",
    "PthreadLikeSemaphore",
    "SEMAPHORE_KINDS",
    "SemaState",
    "MultiSemaState",
    "make_sema",
    "make_multi_sema",
    "take_batch",
    "post_batch",
    "poll",
    "woken_mask",
    "take_batch_multi",
    "post_batch_multi",
    "SimParams",
    "simulate",
    "sweep",
    "tls_cleanup",
]
