"""L2 — the paper's semaphore as a *functional, batched* JAX construct.

TPUs have no shared-memory atomics inside a jitted program, so the paper's
per-thread ``fetch_add`` linearization is adapted: a *batch* of K concurrent
``take`` requests is linearized deterministically by row order, and their
tickets are ``base + exclusive_prefix_rank`` — one vectorized cumsum replaces
K atomic RMWs while preserving wait-free FCFS admission **for the batch
order** (which we make deterministic: arrival order = row index, exactly the
"first-come-first-enabled" order of the paper).

The waiting array maps to a `bucket_seq` vector: `post_batch` bumps the
TWAHash buckets of the granted ticket range (the scatter is the analogue of
the successor-of-successor poke), and a scheduler needs to re-examine *only*
requests whose bucket moved — the global-spinning analogue (re-scanning every
waiting request each step) is what this avoids.  `kernels/sema_batch`
implements the fused take+post+wake pass as a Pallas TPU kernel; this module
is its reference semantics and the pure-JAX fallback.

All counters are uint32 with wrap-safe int32 signed distances (sufficient
for < 2^31 outstanding distance; the paper's 200-year uint64 argument holds
a fortiori for per-run schedulers).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashfn import TICKET_STRIDE

DEFAULT_TABLE_SIZE = 1024


def next_pow2(n: int) -> int:
    """Smallest power of two ≥ n (shape-bucketing helper: the kernel
    backlog padding and the megastep (B, P) buckets must round the same
    way so steady-state serving reuses compiled executables)."""
    return 1 << max(n - 1, 0).bit_length()


class SemaState(NamedTuple):
    """One functional semaphore (or a vector of them if leading dims agree)."""

    ticket: jax.Array  # uint32 scalar
    grant: jax.Array  # uint32 scalar
    bucket_seq: jax.Array  # (table_size,) uint32 — waiting-array UpdateSequence
    salt: jax.Array  # uint32 scalar — the uintptr_t(L) component of TWAHash


def make_sema(count: int, table_size: int = DEFAULT_TABLE_SIZE, salt: int = 0x9E3779B9) -> SemaState:
    assert table_size > 0 and (table_size & (table_size - 1)) == 0
    return SemaState(
        ticket=jnp.uint32(0),
        grant=jnp.uint32(count),
        bucket_seq=jnp.zeros((table_size,), jnp.uint32),
        salt=jnp.uint32(salt),
    )


def _sdist(grant, ticket):
    """Signed distance grant - ticket under uint32 wrap (paper's int64_t dx)."""
    return (grant - ticket).astype(jnp.int32)


def twa_hash_u32(salt, ticket):
    return (salt + ticket * jnp.uint32(TICKET_STRIDE)).astype(jnp.uint32)


def bucket_index(state: SemaState, ticket) -> jax.Array:
    table = state.bucket_seq.shape[-1]
    return (twa_hash_u32(state.salt, ticket) & jnp.uint32(table - 1)).astype(jnp.int32)


def take_batch(state: SemaState, requests: jax.Array):
    """Batched SemaTake.

    requests: (N,) bool — which rows are taking (batch arrival order = FIFO
    order).  Returns (state', tickets (N,) u32, admitted (N,) bool,
    buckets (N,) i32).  Non-admitted requesters are "long-term waiters": the
    caller holds their ticket and their TWAHash bucket, and should re-check
    them only when their bucket's sequence moves (see `woken_mask`).
    """
    req = requests.astype(jnp.uint32)
    ranks = jnp.cumsum(req) - req  # exclusive prefix rank
    tickets = state.ticket + ranks
    admitted = requests & (_sdist(state.grant, tickets) > 0)
    new_state = state._replace(ticket=state.ticket + jnp.sum(req).astype(jnp.uint32))
    return new_state, tickets, admitted, bucket_index(state, tickets)


def post_batch(state: SemaState, n) -> SemaState:
    """Batched SemaPost of `n` units: grant += n and poke the TWAHash buckets
    of the enabled ticket range [grant, grant+n) (successor staging)."""
    n = jnp.asarray(n, jnp.uint32)
    table = state.bucket_seq.shape[-1]
    # Enabled tickets grant..grant+n-1 → bucket scatter-add (masked iota over
    # a bounded window keeps this jit-static; window = table size is enough
    # because pokes beyond one table orbit alias anyway).
    offs = jnp.arange(table, dtype=jnp.uint32)
    enabled = offs < n
    idx = bucket_index(state, state.grant + offs)
    bump = jnp.zeros((table,), jnp.uint32).at[idx].add(enabled.astype(jnp.uint32))
    return state._replace(grant=state.grant + n, bucket_seq=state.bucket_seq + bump)


def woken_mask(state: SemaState, observed_seq: jax.Array, buckets: jax.Array) -> jax.Array:
    """TWA-style re-check gate: True for waiters whose bucket sequence moved
    since `observed_seq` (their KeyMonitor sample). Waiters with False need
    not be re-evaluated at all this step — the scheduler's analogue of NOT
    globally spinning."""
    return state.bucket_seq[buckets] != observed_seq


def poll(state: SemaState, tickets: jax.Array) -> jax.Array:
    """Grant check for specific tickets (the short-term 'spin on Grant')."""
    return _sdist(state.grant, tickets) > 0


# -- block-paged pool (TWA semaphore over a circular free queue) --------------


class BlockPool(NamedTuple):
    """Demand-paged block allocator gated by a TWA semaphore — the paper's
    counting semaphore where the *units are KV-cache blocks* and the
    semaphore counters double as the cursors of a circular free queue:

      * ``sema.ticket`` / ``sema.grant`` are the paper's counters; the
        physical free-block count is the counter identity
        ``grant − ticket`` (wrap-safe signed distance);
      * the free queue holds block *identities*: queue position ``p``
        (a u32 cursor value) stores its id at ``free_q[p mod NB]`` — an
        allocation at ticket ``t`` takes ids ``free_q[t..t+k)``, a release
        writes ids at ``free_q[grant..grant+k)`` and `post`s, poking the
        waiting-array buckets of the enabled ticket range exactly as any
        other post (a block release wakes block waiters).

    ``num_blocks`` must be a power of two so the queue-position arithmetic
    stays exact across the 2³² counter wrap (``(p mod 2³²) mod NB ==
    p mod NB`` iff NB | 2³² — same reasoning as the bucket table mask).

    Blocks are **refcounted** (prefix sharing, PR 9): one block may be
    referenced by several live tables at once — a shared prompt prefix is
    stored exactly once, each sharer holding one reference.  Allocation
    grants a block with refcount 1; `pool_incref` attaches an additional
    sharer; `pool_release` is decref-then-`post` — a released reference
    only re-enqueues the block id (and pokes the waiting array) when its
    refcount hits zero, i.e. the semaphore's `post` becomes CONDITIONAL
    on the last reference dying.  ``gen`` is a per-block generation
    stamp, bumped each time a block is freed, so weak references (the
    prefix cache) can detect reuse without holding a refcount.

    Conservation invariant (property-tested): the free-queue region
    ``{free_q[ticket..grant)}`` and the referenced set ``{b : refcnt[b] >
    0}`` partition ``{0..NB-1}``, and per block the number of live
    block-table references equals ``refcnt`` (``Σ table references =
    Σ refcnt``) — no block is ever lost, and aliasing is exactly the
    refcount, never accidental.
    """

    sema: SemaState    # ticket/grant u32 — free blocks = grant − ticket
    free_q: jax.Array  # (NB,) i32 — circular queue of free block ids
    refcnt: jax.Array  # (NB,) i32 — live references per block (0 = free)
    gen: jax.Array     # (NB,) u32 — bumped on free (weak-ref validity)


def make_block_pool(num_blocks: int, table_size: int = 64,
                    salt: int = 0x9E3779B9, start: int = 0) -> BlockPool:
    """Fresh pool: all blocks free.  ``start`` offsets both counters (and
    rotates the queue to match) so tests can park the cursors just below
    the 2³² wrap."""
    assert num_blocks > 0 and (num_blocks & (num_blocks - 1)) == 0, \
        "num_blocks must be a power of two (wrap-safe queue positions)"
    sema = make_sema(count=num_blocks, table_size=table_size, salt=salt)
    start = jnp.uint32(start)
    sema = sema._replace(ticket=sema.ticket + start, grant=sema.grant + start)
    ids = jnp.arange(num_blocks, dtype=jnp.int32)
    pos = ((start + jnp.arange(num_blocks, dtype=jnp.uint32))
           & jnp.uint32(num_blocks - 1)).astype(jnp.int32)
    return BlockPool(sema=sema,
                     free_q=jnp.zeros((num_blocks,), jnp.int32).at[pos].set(ids),
                     refcnt=jnp.zeros((num_blocks,), jnp.int32),
                     gen=jnp.zeros((num_blocks,), jnp.uint32))


def pool_free_count(pool: BlockPool) -> jax.Array:
    """Free blocks — the paper's counter identity, i32 scalar."""
    return _sdist(pool.sema.grant, pool.sema.ticket)


def pool_alloc(pool: BlockPool, counts: jax.Array, max_per: int):
    """Batched wrap-safe take: consumer ``s`` receives ``counts[s]`` block
    ids (its row of the returned ``(S, max_per)`` table, -1 padded), taken
    from the free queue in cursor order — consumers are linearized by row
    index, the batched FCFS of `take_batch`.  The caller must guarantee
    ``sum(counts) ≤ pool_free_count`` (the engine's admission gate does).
    Returns ``(pool', ids)``."""
    counts = jnp.asarray(counts, jnp.int32)
    NB = pool.free_q.shape[0]
    cum = jnp.cumsum(counts) - counts            # exclusive prefix offsets
    k = jnp.arange(max_per, dtype=jnp.int32)
    take = k[None, :] < counts[:, None]          # (S, max_per)
    pos = (pool.sema.ticket + cum[:, None].astype(jnp.uint32)
           + k[None, :].astype(jnp.uint32)) & jnp.uint32(NB - 1)
    ids = jnp.where(take, pool.free_q[pos.astype(jnp.int32)], -1)
    total = jnp.sum(counts).astype(jnp.uint32)
    sema = pool.sema._replace(ticket=pool.sema.ticket + total)
    refcnt = pool.refcnt.at[jnp.where(take, ids, NB)].add(
        take.astype(jnp.int32), mode="drop")   # fresh grant: refcount 0 → 1
    return pool._replace(sema=sema, refcnt=refcnt), ids


def pool_release(pool: BlockPool, ids: jax.Array, mask: jax.Array) -> BlockPool:
    """Batched decref-then-`post`: every non-negative id in the rows
    selected by ``mask`` drops one reference; a block re-enters the free
    queue at the grant cursor — and the semaphore `post`s, advancing
    grant AND poking the TWAHash buckets of the newly enabled ticket
    range — only when its refcount hits zero (the last sharer leaving).
    With no sharing (every refcount 1) this degenerates to the PR-4
    unconditional post.  Freed ids enqueue in ascending-id order (any
    fixed order preserves the partition invariant; ascending keeps the
    scatter deterministic when one batch frees several blocks).  Each
    freed block's ``gen`` stamp bumps, invalidating weak references.
    Refcounts are NOT clamped at zero: releasing a reference that was
    never held drives ``refcnt`` negative, which the partition sentinel
    (`serving.sentinels.kv_partition_violated`) reports as corruption —
    the double-release fault stays detectable."""
    NB = pool.free_q.shape[0]
    valid = mask[:, None] & (ids >= 0) if ids.ndim == 2 else mask & (ids >= 0)
    flat = ids.reshape(-1)
    tgt = jnp.where(valid.reshape(-1), flat, NB)  # out-of-range → dropped
    cnt = jnp.zeros((NB,), jnp.int32).at[tgt].add(1, mode="drop")
    refcnt = pool.refcnt - cnt
    freed = (cnt > 0) & (refcnt == 0)            # decref hit exactly zero
    fu = freed.astype(jnp.uint32)
    rank = jnp.cumsum(fu) - fu
    pos = ((pool.sema.grant + rank) & jnp.uint32(NB - 1)).astype(jnp.int32)
    qtgt = jnp.where(freed, pos, NB)
    free_q = pool.free_q.at[qtgt].set(jnp.arange(NB, dtype=jnp.int32),
                                      mode="drop")
    return BlockPool(sema=post_batch(pool.sema, jnp.sum(fu)), free_q=free_q,
                     refcnt=refcnt, gen=pool.gen + fu)


def pool_incref(pool: BlockPool, ids: jax.Array, mask: jax.Array) -> BlockPool:
    """Attach additional references (prefix sharing): every non-negative id
    selected by ``mask`` gains one reference.  No counter moves, no queue
    traffic, no poke — sharing an already-live block is free at the
    semaphore level; only the LAST `pool_release` of a block posts."""
    NB = pool.free_q.shape[0]
    valid = mask & (ids >= 0)
    tgt = jnp.where(valid, ids, NB).reshape(-1)
    refcnt = pool.refcnt.at[tgt].add(
        valid.reshape(-1).astype(jnp.int32), mode="drop")
    return pool._replace(refcnt=refcnt)


def park_state(sema: SemaState, deficit: jax.Array):
    """Waiting-array park registration for block waiters (the long-term wait
    of the paper, at pool granularity): a waiter short ``deficit`` units
    becomes runnable exactly when the grant cursor has advanced ``deficit``
    more places, i.e. when ticket ``grant + deficit − 1`` is enabled.
    Releases `post` and poke the buckets of the enabled range in order, so
    that ticket's TWAHash bucket moves precisely when cumulative releases
    reach the deficit — the waiter observes ``(bucket, seq)`` here and
    re-examines only when the bucket's sequence moves (`woken_mask`).
    Hash aliasing can wake early (the paper's benign spurious re-check);
    a woken waiter whose re-check fails re-parks with a fresh deficit.
    Returns ``(bucket (…,) i32, observed_seq (…,) u32)``."""
    wake = sema.grant + jnp.asarray(deficit, jnp.uint32) - jnp.uint32(1)
    bucket = bucket_index(sema, wake)
    return bucket, sema.bucket_seq[bucket]


def pool_try_alloc(pool: BlockPool, counts: jax.Array, max_per: int, *,
                   park: jax.Array, deficit: jax.Array):
    """Guarded batched take + waiting-array park — the incremental-allocation
    entry point (`serving.prefill.chunk_plan` decides the counts).

    ``counts`` rows take their blocks (a plain wrap-safe `pool_alloc`; the
    caller's no-deadlock plan guarantees they fit), while rows flagged in
    ``park`` register as block waiters instead of spinning on the free
    count: each parked row records the `park_state` of its ``deficit`` —
    the TWA bucket whose poke signals that enough releases have landed for
    a re-check.  This is the paper's long-term wait transplanted to block
    grants: a mid-sequence block stall costs one bucket observation, not a
    per-round rescan of every stalled slot, and resumes flow FCFS because
    releases enable tickets (and poke their buckets) strictly in cursor
    order.  Returns ``(pool', ids (S, max_per), bucket (S,), seq (S,))``
    — bucket/seq are meaningful only where ``park`` is set (0 elsewhere).
    """
    park = jnp.asarray(park, bool)
    new_pool, ids = pool_alloc(pool, counts, max_per)
    bucket, seq = park_state(pool.sema, jnp.maximum(jnp.asarray(deficit,
                                                                jnp.int32), 1))
    return (new_pool, ids, jnp.where(park, bucket, 0),
            jnp.where(park, seq, jnp.uint32(0)))


# -- vectorized multi-semaphore (one per expert / per resource class) ---------


class MultiSemaState(NamedTuple):
    ticket: jax.Array  # (S,) uint32
    grant: jax.Array  # (S,) uint32


def make_multi_sema(counts: jax.Array) -> MultiSemaState:
    counts = jnp.asarray(counts, jnp.uint32)
    return MultiSemaState(ticket=jnp.zeros_like(counts), grant=counts)


def take_batch_multi(state: MultiSemaState, sema_ids: jax.Array, mask: jax.Array,
                     block: int = 1024):
    """K requests against S semaphores in one pass (MoE capacity admission).

    sema_ids: (N,) int32 in [0,S); mask: (N,) bool.  Returns
    (state', tickets, admitted) where admitted[i] ⇔ rank within its
    semaphore's remaining grant.  Deterministic FCFS per semaphore ⇒ the
    paper's first-come-first-enabled order decides which tokens overflow.

    Per-semaphore FIFO ranks use a TWO-LEVEL blocked prefix (§Perf iteration
    3): rank = intra-block exclusive rank + carried per-block base — exactly
    the kernels/sema_batch structure (per-block tri-rank + carry).  A flat
    global `cumsum(one_hot)` lowers catastrophically under SPMD: measured
    1.58e14 flops/chip (≈4·N²) on deepseek train_4k — 20× the expert matmul
    cost; the blocked form is O(N·S).
    """
    S = state.ticket.shape[0]
    N = sema_ids.shape[0]
    pad = (-N) % block
    ids_p = jnp.pad(sema_ids, (0, pad))
    mask_p = jnp.pad(mask, (0, pad))
    nb = (N + pad) // block
    onehot = (jax.nn.one_hot(ids_p, S, dtype=jnp.uint32)
              * mask_p[:, None].astype(jnp.uint32)).reshape(nb, block, S)
    intra = jnp.cumsum(onehot, axis=1)  # (nb, block, S) inclusive within block
    block_tot = intra[:, -1, :]  # (nb, S)
    base = jnp.cumsum(block_tot, axis=0) - block_tot  # exclusive block base
    ranks = (base[:, None, :] + intra - onehot).reshape(-1, S)[:N]  # exclusive
    my_rank = jnp.take_along_axis(ranks, sema_ids[:, None], axis=1)[:, 0]
    tickets = state.ticket[sema_ids] + my_rank
    admitted = mask & (_sdist(state.grant[sema_ids], tickets) > 0)
    new_ticket = state.ticket + jnp.sum(block_tot, axis=0)
    return state._replace(ticket=new_ticket), tickets, admitted


def post_batch_multi(state: MultiSemaState, counts: jax.Array) -> MultiSemaState:
    return state._replace(grant=state.grant + jnp.asarray(counts, jnp.uint32))


def segment_counts(ids: jax.Array, mask: jax.Array, num_segments: int,
                   dtype=jnp.uint32) -> jax.Array:
    """Per-segment count of mask-true rows — the shared per-tenant reduction
    used throughout `admission.functional_qos` (take/expire/admit/round all
    need "how many flagged rows per tenant").  A segment-sum instead of the
    former ``sum(one_hot(ids) * mask)`` idiom: no (N, S) materialization."""
    return jax.ops.segment_sum(
        jnp.asarray(mask).astype(dtype), jnp.asarray(ids, jnp.int32),
        num_segments=num_segments)


def bucket_histogram(buckets: jax.Array, mask: jax.Array,
                     table_size: int) -> jax.Array:
    """Waiting-array occupancy histogram — the paper's observable: how many
    long-term waiters currently observe each TWAHash bucket.  ``buckets``
    are the waiters' observed bucket indices (e.g. ``Slots.park_bucket``),
    ``mask`` selects the rows that are actually parked.  A flat histogram
    means the salt disperses waiters well (bounded re-checks per poke); a
    spike is the hash-aliasing pathology the paper's salt term exists to
    avoid.  Returns (table_size,) i32."""
    return segment_counts(jnp.asarray(buckets, jnp.int32), mask, table_size,
                          dtype=jnp.int32)


def ticket_order(sema_ids: jax.Array, tickets: jax.Array,
                 num_semas: int) -> jax.Array:
    """Stable permutation putting every semaphore's rows in wrap-safe ticket
    order (cross-semaphore interleaving is arbitrary — per-semaphore prefix
    counts don't care).  The key is the signed ticket distance from the
    semaphore's first-seen ticket, valid while a semaphore's outstanding
    tickets span < 2³¹ (the module-wide counter invariant).  Shared by
    `live_fifo_rank` and the `kernels.qos_admission` wrapper — the two must
    sort identically for the kernel's bit-exactness."""
    n = tickets.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    sema_ids = jnp.asarray(sema_ids, jnp.int32)
    tickets = jnp.asarray(tickets, jnp.uint32)
    first_row = jnp.full((num_semas,), n, jnp.int32).at[sema_ids].min(
        jnp.arange(n, dtype=jnp.int32))
    ref = tickets[jnp.clip(first_row, 0, n - 1)]  # (S,) u32
    key = _sdist(tickets, ref[sema_ids])
    return jnp.argsort(key, stable=True)


def live_fifo_rank(sema_ids: jax.Array, tickets: jax.Array,
                   alive: jax.Array, num_semas: int,
                   block: int = 512) -> jax.Array:
    """Rank of each row among the *alive* rows of its semaphore, in ticket
    order — the batched form of the tombstone-skip: dead (cancelled /
    deadline-expired) tickets are transparent, so grant units flow to the
    earliest live waiters and FCFS among live tickets is preserved exactly.
    Dead rows get rank N (never admitted by a `< avail` test).

    O(N·S/block) two-level blocked prefix (the `take_batch_multi`
    structure) over a per-tenant ticket-order argsort:

      1. wrap-safe sort key: signed ticket distance from the tenant's
         first-seen ticket (valid while a tenant's outstanding tickets span
         < 2³¹ — the module-wide counter invariant);
      2. stable argsort puts every tenant's rows in ticket order (ties
         across tenants are irrelevant — counts are per tenant);
      3. alive-masked (nb, block, S) one-hot two-level prefix gives each
         sorted row its exclusive count of earlier live same-tenant rows;
      4. scatter back through the inverse permutation.

    The former O(N²) pairwise comparison is kept as
    :func:`live_fifo_rank_pairwise` (equivalence tests + benchmarks).
    Tickets are assumed unique within a tenant (they are consecutive
    counter values by construction).
    """
    n = tickets.shape[0]
    sema_ids = jnp.asarray(sema_ids, jnp.int32)
    tickets = jnp.asarray(tickets, jnp.uint32)
    S = num_semas
    order = ticket_order(sema_ids, tickets, S)

    ids_s = sema_ids[order]
    alive_s = alive[order]
    pad = (-n) % block
    ids_p = jnp.pad(ids_s, (0, pad))
    alive_p = jnp.pad(alive_s, (0, pad))
    nb = (n + pad) // block
    onehot = (jax.nn.one_hot(ids_p, S, dtype=jnp.uint32)
              * alive_p[:, None].astype(jnp.uint32)).reshape(nb, block, S)
    intra = jnp.cumsum(onehot, axis=1)  # inclusive within block
    block_tot = intra[:, -1, :]  # (nb, S)
    base = jnp.cumsum(block_tot, axis=0) - block_tot  # exclusive block base
    ranks = (base[:, None, :] + intra - onehot).reshape(-1, S)[:n]
    my = jnp.take_along_axis(ranks, ids_s[:, None], axis=1)[:, 0]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(my.astype(jnp.int32))
    return jnp.where(alive, rank, jnp.int32(n))


def live_fifo_rank_pairwise(sema_ids: jax.Array, tickets: jax.Array,
                            alive: jax.Array) -> jax.Array:
    """O(N²) pairwise-comparison form of :func:`live_fifo_rank` — retained
    as the equivalence oracle and the benchmark baseline the blocked-prefix
    path is measured against (BENCH trajectory: qos_round scaling)."""
    n = tickets.shape[0]
    same = sema_ids[:, None] == sema_ids[None, :]
    before = _sdist(tickets[:, None], tickets[None, :]) > 0  # ticket_j < ticket_i
    rank = jnp.sum(same & before & alive[None, :], axis=1).astype(jnp.int32)
    return jnp.where(alive, rank, jnp.int32(n))
