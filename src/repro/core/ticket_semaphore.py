"""Listing 1 — Ticket-Semaphore.

A semaphore built from the ticket-lock idea: 64-bit unsigned ``Ticket`` and
``Grant`` counters; ``take`` performs an atomic fetch_add on Ticket and waits
until ``Grant - ticket > 0`` (magnitude comparison — multiple posters may
increment Grant concurrently, so equality checks are insufficient); ``post``
atomically increments Grant.  64-bit counters make roll-over a non-issue
(<200 years at 1 increment/ns).

Strict first-come-first-served admission, assuming fetch_add is wait-free.
Simple, compact, extremely low latency uncontended — but *global spinning*
on Grant causes coherence storms as thread counts grow (the problem TWA
solves).

Waiting modes:
  - "spin":      the paper's Listing 1 verbatim (Pause() decorated polling).
  - "broadcast": parking variant — every waiter blocks on one shared event
                 and *every* post wakes *all* waiters (thundering herd).
                 This is the natural futex-on-Grant port and is the honest
                 parking counterpart for comparing against TWA's selective
                 wakeup in semabench.
"""

from __future__ import annotations

import threading

from .atomics import AtomicU64
from .parking import pause

_U64_HALF = 1 << 63


def _dist(grant: int, ticket: int) -> int:
    """Signed 64-bit distance grant - ticket (wrap-safe)."""
    d = (grant - ticket) & ((1 << 64) - 1)
    return d - (1 << 64) if d >= _U64_HALF else d


class TicketSemaphore:
    def __init__(self, count: int = 0, waiting: str = "spin"):
        assert count >= 0
        assert waiting in ("spin", "broadcast")
        self.ticket = AtomicU64(0)
        self.grant = AtomicU64(count)
        self._waiting = waiting
        # broadcast mode: single condition shared by all waiters (herd).
        self._cond = threading.Condition()

    # -- the semaphore interface ------------------------------------------
    def take(self) -> None:
        tx = self.ticket.fetch_add(1)
        dx = _dist(self.grant.load(), tx)
        if dx > 0:  # fast-path uncontended return
            return
        if self._waiting == "spin":
            while True:
                dx = _dist(self.grant.load(), tx)
                if dx > 0:
                    return
                pause()
        else:  # broadcast parking: wait on the shared condition
            with self._cond:
                while _dist(self.grant.load(), tx) <= 0:
                    self._cond.wait()

    def post(self, n: int = 1) -> None:
        self.grant.fetch_add(n)
        if self._waiting == "broadcast":
            with self._cond:
                self._cond.notify_all()  # thundering herd — the point.

    # -- introspection ------------------------------------------------------
    def queue_depth(self) -> int:
        """Waiters in line = max(0, ticket - grant). The ticket/grant pair is
        free telemetry — the runtime uses this for backpressure/stragglers."""
        return max(0, -_dist(self.grant.load(), self.ticket.load()))

    def available(self) -> int:
        return max(0, _dist(self.grant.load(), self.ticket.load()))
