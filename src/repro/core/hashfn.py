"""Hash functions from the paper.

``TWAHash(L, Ticket) = uintptr_t(L) + Ticket * 17`` — the intentionally
*ticket-aware* hash: as the ticket advances by 1 the index strides by 17
(coprime with the power-of-two table), marching through the whole gamut of
buckets before repeating and keeping numerically-adjacent tickets on
different cache lines.  ``Mix32A`` is the general-purpose supplementary hash
for address-based (non-ticket) keys.
"""

from __future__ import annotations

MASK32 = (1 << 32) - 1

# Paper's multiplicative stride. Coprime with any power-of-two table size.
TICKET_STRIDE = 17

# Paper's Mix32A constant.
MIX32KA = 0x9ABE94E3


def twa_hash(obj_addr: int, ticket: int, stride: int = TICKET_STRIDE) -> int:
    """uint32 TWAHash — address + ticket*17 (mod 2^32)."""
    return (obj_addr + (ticket & MASK32) * stride) & MASK32


def twa_hash_paired(obj_addr: int, ticket: int) -> int:
    """Paper's ``Ticket >>= 1`` preconditioning variant: groups adjacent
    tickets into pairs → pipelined early-wakeup (more futile wakeups, but
    "near" successors warm up early)."""
    return twa_hash(obj_addr, (ticket & MASK32) >> 1)


def twa_hash_subpage(obj_addr: int, ticket: int, subpage_bits: int = 6) -> int:
    """Paper's sub-page variant: upper ticket bits select a logical sub-page,
    lower bits are hashed within it — sequential tickets "orbit" inside one
    sub-page before moving on (TLB-friendly, Z-order-like)."""
    t = ticket & MASK32
    page = t >> subpage_bits
    low = t & ((1 << subpage_bits) - 1)
    return (obj_addr + (page << subpage_bits) + (low * TICKET_STRIDE & ((1 << subpage_bits) - 1))) & MASK32


def mix32a(v: int) -> int:
    """Paper's Mix32A avalanche hash (for arbitrary address keys)."""
    v &= MASK32
    v = ((v ^ (v >> 16)) * MIX32KA) & MASK32
    v = ((v ^ (v >> 16)) * MIX32KA) & MASK32
    return (v ^ (v >> 16)) & MASK32


def index_for(key: int, table_size: int) -> int:
    assert table_size > 0 and (table_size & (table_size - 1)) == 0, "power of two"
    return key & (table_size - 1)
