"""Small atomic cells emulating the C++ std::atomic API used by the paper.

CPython's GIL makes many single-opcode operations *appear* atomic, but that is
an implementation detail (and is false on free-threaded builds).  We therefore
emulate `std::atomic<uint64_t>` / `std::atomic<T*>` with an explicit per-cell
mutex.  The mutex acquire/release also gives us the seq_cst ordering the
paper's listings assume (they deliberately avoid relaxed-memory-order
optimizations, and so do we).

The `fetch_add` here is the linearization point for ticket issuance, mirroring
the wait-free FAA the paper relies on for its FCFS guarantee.
"""

from __future__ import annotations

import threading
from typing import Generic, Optional, TypeVar

T = TypeVar("T")

MASK64 = (1 << 64) - 1


class AtomicU64:
    """std::atomic<uint64_t> with wrapping arithmetic."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: int = 0):
        self._lock = threading.Lock()
        self._value = value & MASK64

    def load(self) -> int:
        with self._lock:
            return self._value

    def store(self, value: int) -> None:
        with self._lock:
            self._value = value & MASK64

    def fetch_add(self, delta: int = 1) -> int:
        with self._lock:
            old = self._value
            self._value = (old + delta) & MASK64
            return old

    def exchange(self, value: int) -> int:
        with self._lock:
            old = self._value
            self._value = value & MASK64
            return old

    def cas(self, cmp: int, new: int) -> int:
        """compare_exchange_strong, returning the *witnessed* value (paper's
        `Atomic::cas` harmonized convention)."""
        with self._lock:
            old = self._value
            if old == cmp:
                self._value = new & MASK64
            return old


class AtomicRef(Generic[T]):
    """std::atomic<T*>: exchange / cas / load / store on object references."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: Optional[T] = None):
        self._lock = threading.Lock()
        self._value = value

    def load(self) -> Optional[T]:
        with self._lock:
            return self._value

    def store(self, value: Optional[T]) -> None:
        with self._lock:
            self._value = value

    def exchange(self, value: Optional[T]) -> Optional[T]:
        with self._lock:
            old = self._value
            self._value = value
            return old

    def cas(self, cmp: Optional[T], new: Optional[T]) -> Optional[T]:
        with self._lock:
            old = self._value
            if old is cmp:
                self._value = new
            return old


class AtomicInt:
    """std::atomic<int> (used for WaitElement.Gate)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: int = 0):
        self._lock = threading.Lock()
        self._value = value

    def load(self) -> int:
        with self._lock:
            return self._value

    def store(self, value: int) -> None:
        with self._lock:
            self._value = value

    def fetch_add(self, delta: int = 1) -> int:
        with self._lock:
            old = self._value
            self._value = old + delta
            return old
