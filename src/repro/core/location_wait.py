"""Listing 5 — TWA-Semaphore implemented with a LocationWait() primitive.

Differences from Listing 3's chains, per the paper:
  * the WaitElement lives in **TLS** (one per thread), not on-stack, because
    an element may be *abandoned* on a chain when the caller's condition
    becomes true while emplaced ("deferred lazy removal") — it is recovered
    on the next waiting episode (or at thread destruction);
  * therefore orphaned elements cannot propagate wakeups, so ``Poke`` must
    **walk** the chain via explicit ``Succ`` links (LD-CAS push publishes
    them) instead of relying on systolic waiter-to-waiter propagation;
  * ``LocationWait`` is an unrolled state machine alternating *emplace* and
    *wait* phases — the emplace call returns immediately (a deliberate
    "spurious" return) so the caller re-evaluates its condition between
    phases, closing the Dekker race:
        WakeAll : ST Cond ; SWAP Chain(None)
        Wait    : SWAP Chain(E) ; LD Cond
"""

from __future__ import annotations

import threading

from .atomics import AtomicInt, AtomicRef, AtomicU64
from .hashfn import index_for, mix32a, twa_hash
from .parking import pause
from .ticket_semaphore import _dist

DEFAULT_TABLE_SIZE = 4096
DEFAULT_LONG_TERM_THRESHOLD = 1


class WaitSlot:
    __slots__ = ("chain",)

    def __init__(self):
        self.chain: AtomicRef["TLSWaitElement"] = AtomicRef(None)


class TLSWaitElement:
    """Thread-local wait element. ``where`` is owner-private (which slot this
    element currently resides on, None if free-floating); ``succ`` is the
    published stack link."""

    __slots__ = ("gate", "where", "succ")

    def __init__(self):
        self.gate = AtomicInt(0)
        self.where: WaitSlot | None = None
        self.succ: AtomicRef[TLSWaitElement] = AtomicRef(None)

    def cleanup(self) -> None:
        """The C++ thread-exit DTOR: if we died while emplaced and not yet
        poked, flush that chain so our element cannot occlude successors."""
        if self.where is not None and self.gate.load() == 0:
            poke_walk(self.where.chain.exchange(None))
            while self.gate.load() == 0:
                pause()
        self.where = None
        self.succ.store(None)


_tls = threading.local()


def _tls_element() -> TLSWaitElement:
    e = getattr(_tls, "element", None)
    if e is None:
        e = TLSWaitElement()
        _tls.element = e
    return e


def tls_cleanup() -> None:
    """Explicit analogue of the TLS destructor registration
    (_cxa_thread_atexit); worker threads call this before exiting."""
    e = getattr(_tls, "element", None)
    if e is not None:
        e.cleanup()


def poke_walk(e: TLSWaitElement | None) -> None:
    """Poke that WALKS the chain: orphaned (abandoned) elements cannot be
    relied on to propagate, so the waker visits every element."""
    while e is not None:
        k = e
        e = k.succ.load()
        assert e is not k
        k.gate.store(1)


class SlotTable:
    def __init__(self, table_size: int = DEFAULT_TABLE_SIZE):
        assert table_size > 0 and (table_size & (table_size - 1)) == 0
        self.table_size = table_size
        self.slots = [WaitSlot() for _ in range(table_size)]

    def index_to_bucket(self, key: int) -> WaitSlot:
        return self.slots[index_for(key, self.table_size)]


_GLOBAL_SLOTS = SlotTable()


def location_wait(s: WaitSlot) -> None:
    """Advance the thread-local state machine (emplace phase / wait phase)."""
    assert s is not None
    e = _tls_element()
    where = e.where
    if where is s:
        # Previously emplaced on the correct chain — actually wait.
        while e.gate.load() == 0:
            pause()
        e.succ.store(None)  # hygiene
        e.where = None
        return
    if where is not None:
        # Residual residency on the WRONG chain (abandoned orphan) —
        # deferred recovery: extricate E before reusing it.
        if e.gate.load() == 0:
            poke_walk(where.chain.exchange(None))
            while e.gate.load() == 0:
                pause()
        e.where = None
        e.succ.store(None)
    # E is free-floating and privatized. Emplace on chain s.
    e.where = s
    e.gate.store(0)
    e.succ.store(None)
    succ = s.chain.cas(None, e)  # optimistic: slots are mostly empty
    if succ is None:
        return
    while True:
        assert succ is not e
        e.succ.store(succ)  # tentative, in anticipation of a successful CAS
        v = s.chain.cas(succ, e)
        if v is succ:
            break
        succ = v  # raced and lost; some other thread progressed — retry
    # Intentional immediate return: caller re-evaluates its condition, the
    # NEXT call actually waits.


def location_wake_all(s: WaitSlot) -> None:
    assert s is not None
    poke_walk(s.chain.exchange(None))


class TWASemaphoreV3:
    """Listing 5's semaphore over LocationWait/LocationWakeAll."""

    def __init__(
        self,
        count: int = 0,
        table: SlotTable | None = None,
        long_term_threshold: int = DEFAULT_LONG_TERM_THRESHOLD,
    ):
        assert count >= 0
        self.ticket = AtomicU64(0)
        self.grant = AtomicU64(count)
        self.table = table if table is not None else _GLOBAL_SLOTS
        self.threshold = long_term_threshold
        self._addr = mix32a(id(self) & 0xFFFFFFFF)

    def _twa_hash(self, ticket: int) -> int:
        return twa_hash(self._addr, ticket)

    def take(self) -> None:
        tx = self.ticket.fetch_add(1)
        if _dist(self.grant.load(), tx) > 0:
            return  # fast-path uncontended
        s = self.table.index_to_bucket(self._twa_hash(tx))
        while True:
            if _dist(self.grant.load(), tx) > 0:
                return
            location_wait(s)

    def post(self, n: int = 1) -> None:
        for _ in range(n):
            g = self.grant.fetch_add(1)
            # Benaphore-style racy-but-conservative fast path.
            dx = _dist(g, self.ticket.load())
            if dx >= 0:
                continue
            location_wake_all(self.table.index_to_bucket(self._twa_hash(g)))

    def post_conservative(self, n: int = 1) -> None:
        """SemaPostConservative — no fast path, wakes successor's successor
        (grant + threshold)."""
        for _ in range(n):
            g = self.grant.fetch_add(1)
            g += self.threshold
            location_wake_all(self.table.index_to_bucket(self._twa_hash(g)))

    def queue_depth(self) -> int:
        return max(0, -_dist(self.grant.load(), self.ticket.load()))

    def available(self) -> int:
        return max(0, _dist(self.grant.load(), self.ticket.load()))
