"""EventCount & Sequencer (Reed & Kanodia 1979) via the TWA transformation.

The paper (§1) notes the ticket→TWA transformation "is readily applicable to
other synchronization constructs, such as EventCount and Sequencers".  This
module carries that out:

  Sequencer  — `ticket()`: a wait-free fetch-add dispenser (the paper's
               Ticket word stand-alone).
  EventCount — `advance()` / `read()` / `await_(v)`: await blocks until the
               count reaches v.  The classic implementation has every waiter
               sleep on ONE location (broadcast herd on every advance);
               TWA-EventCount disperses waiters over the hashed waiting
               array by their *awaited value* — advance(n) pokes exactly the
               buckets of values (count, count+n], so only the waiters whose
               condition may now hold are woken.

Together they reconstruct the classic eventcount/sequencer mutual-exclusion
and producer/consumer patterns with the paper's scalability shape, and they
share the process-global waiting array with TWASemaphore (collisions benign).
"""

from __future__ import annotations

from .atomics import AtomicU64
from .hashfn import twa_hash
from .ticket_semaphore import _dist
from .twa_semaphore import DEFAULT_LONG_TERM_THRESHOLD, WaitingArray, _GLOBAL_ARRAY
from .parking import pause


class Sequencer:
    """Wait-free monotone ticket dispenser."""

    __slots__ = ("_ticket",)

    def __init__(self, start: int = 0):
        self._ticket = AtomicU64(start)

    def ticket(self) -> int:
        return self._ticket.fetch_add(1)

    def read(self) -> int:
        return self._ticket.load()


class EventCount:
    """TWA-augmented eventcount: value-hashed semi-local waiting."""

    def __init__(self, count: int = 0, waiting: str = "futex",
                 long_term_threshold: int = DEFAULT_LONG_TERM_THRESHOLD,
                 array: WaitingArray | None = None):
        assert waiting in ("spin", "futex")
        self.count = AtomicU64(count)
        self.array = array if array is not None else _GLOBAL_ARRAY
        self.threshold = long_term_threshold
        self._spin = waiting == "spin"
        self._addr = id(self)

    def read(self) -> int:
        return self.count.load()

    def await_(self, value: int) -> int:
        """Block until count ≥ value; returns the count seen."""
        c = self.count.load()
        if _dist(c, value) >= 0:
            return c
        bucket = self.array.bucket_for(twa_hash(self._addr, value))
        mx = bucket.seq.load()
        while True:
            c = self.count.load()
            if _dist(c, value) >= 0:
                return c
            if _dist(c, value) + self.threshold >= 0:
                pause()  # near: short-term wait on the count itself
                continue
            vx = mx
            bucket.wait_for_change(vx, self._spin)
            mx = bucket.seq.load()

    def advance(self, n: int = 1) -> int:
        """count += n; poke the buckets of every value the advance enabled
        (plus the staging threshold — successor-of-successor, as in the
        paper's SemaPost)."""
        old = self.count.fetch_add(n)
        for i in range(1, n + 1 + self.threshold):
            self.array.bucket_for(twa_hash(self._addr, old + i)).poke()
        return old + n


class TicketMutex:
    """The classic eventcount+sequencer mutual-exclusion construction —
    functionally a ticket lock whose waiters use the TWA waiting array."""

    def __init__(self):
        self.seq = Sequencer()
        self.ec = EventCount()

    def lock(self) -> None:
        my = self.seq.ticket()
        self.ec.await_(my)

    def unlock(self) -> None:
        self.ec.advance(1)
