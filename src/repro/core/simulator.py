"""Discrete-event simulator with an explicit cache-coherence cost model.

Why this exists: this container is a 1-core CPython box — the paper's central
empirical claim (global spinning's coherence storms make Ticket-Semaphore
fade with thread count while TWA stays flat, Figure 1) is about *parallel
hardware* and cannot be measured here.  We therefore reproduce it in a
calibrated discrete-event model and validate the *claims*, not just run the
code:

  C1  at 1 thread, Ticket ≈ TWA (identical fast paths);
  C2  throughput dips from 1 → 2 threads (communication costs precede
      parallelism benefits);
  C3  under contention, Ticket-Semaphore throughput decays ~1/T while
      TWA-Semaphore stays ~flat (global spinning vs ≤threshold spinners);
  C4  pthread-like pays wakeup latency but benefits from barging; its
      *admission order* is never FIFO (even though the kernel sleep queue
      itself wakes FIFO — the unfairness comes from bargers, not the
      wake discipline).

Model (times in ns; defaults roughly an Oracle X5-2-class 2-socket Xeon):
  * each thread loops: take → CS(c) → post → NCS(n)   (semabench, count=1)
  * handover cost at post time:
      ticket : h = base + coh·S        S = #threads spinning on Grant (= all
                                       waiters) — invalidation storm
      twa    : h = base + coh·S_short  S_short = min(waiters, threshold);
               the bucket poke (successor's successor staging) runs in
               parallel with the successor's CS — it adds to the critical
               path only if the staged thread is reached sooner than the
               poke+refetch completes (modelled via stage_lag)
      pthread: non-FIFO barging — post makes the permit available and (if
               sleepers exist) pays a futex-wake syscall; a thread finishing
               its NCS barges and grabs the permit long before the wakee
               arrives (wake_ns later), so wakeups are mostly futile and the
               semaphore is monopolized by few threads: throughput stays
               near the single-thread level but admission is unfair
               (max_queue / futile_wakeups expose the starvation).
  * hash collisions in a TableSize-bucket array add futile re-checks for TWA
    (coherence cost off the critical path; counted, reported).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field


@dataclass
class SimParams:
    cs_ns: float = 60.0  # CS: advance shared PRNG 1 step (cache-hot)
    ncs_ns: float = 60.0  # NCS: advance private PRNG 1 step
    base_ns: float = 40.0  # uncontended handover (one line transfer)
    coh_ns: float = 35.0  # per-spinner invalidation-storm cost
    wake_ns: float = 4000.0  # kernel wake latency (futex/park)
    futex_wake_syscall_ns: float = 400.0  # poster-side futex_wake entry cost
    stage_lag_ns: float = 150.0  # poke + bucket refetch + shift to Grant spin
    long_term_threshold: int = 1
    table_size: int = 2048
    numa_ns: float = 20.0  # extra per-spinner cost once threads span sockets
    numa_at: int = 16  # thread count where the scheduler spills sockets
    duration_ns: float = 2e7


@dataclass
class SimResult:
    policy: str
    threads: int
    iterations: int
    throughput_per_sec: float
    futile_wakeups: int = 0
    max_queue: int = 0
    # pthread only: park/wake orders of the kernel sleep queue.  The queue
    # discipline is FIFO (futex wait-queues wake oldest-first); the *admission*
    # unfairness of the pthread baseline comes from barging, not wake order.
    park_order: list = field(default_factory=list)
    wake_order: list = field(default_factory=list)


@dataclass(order=True)
class _Ev:
    t: float
    seq: int
    kind: str = field(compare=False)
    tid: int = field(compare=False)


def simulate(policy: str, threads: int, p: SimParams | None = None) -> SimResult:
    """Simulate semabench for one (policy, thread-count) point."""
    assert policy in ("ticket", "twa", "pthread")
    p = p or SimParams()
    heap: list[_Ev] = []
    seq = 0

    def push(t, kind, tid):
        nonlocal seq
        heapq.heappush(heap, _Ev(t, seq, kind, tid))
        seq += 1

    # Semaphore state: count=1 (used as a lock, per the paper's benchmark).
    available = 1
    fifo: list[int] = []  # waiting tickets in order (ticket/twa)
    # Parked threads (pthread): FIFO wake order — futex wait-queues hand out
    # wakeups oldest-first.  The baseline's unfairness is NOT here: it comes
    # from barging (a running thread grabs the permit before the wakee
    # arrives), which tests assert via max_queue / futile_wakeups.
    parked: list[int] = []
    park_order: list[int] = []
    wake_order: list[int] = []
    iterations = 0
    futile = 0
    max_queue = 0
    # staged[tid] = time at which tid finished shifting to short-term spin
    staged: dict[int, float] = {}

    def coh_cost(nspin: int) -> float:
        per = p.coh_ns + (p.numa_ns if threads >= p.numa_at else 0.0)
        return p.base_ns + per * nspin

    def handover(now: float) -> tuple[int, float] | None:
        """Pick the next owner and compute when it enters the CS (FIFO
        policies only; pthread uses availability + barging instead)."""
        nonlocal futile
        if not fifo:
            return None
        tid = fifo.pop(0)
        waiters = len(fifo) + 1
        if policy == "ticket":
            return tid, now + coh_cost(waiters)  # everyone spins on Grant
        # twa: ≤ threshold short-term spinners; successor must be staged.
        nspin = min(waiters, p.long_term_threshold)
        t_enter = now + coh_cost(nspin)
        st = staged.get(tid)
        if st is None or st > now:
            # Successor not yet staged (deep queue moved faster than pokes,
            # or a hash collision poked the wrong bucket first) — pay the
            # staging lag on the critical path.
            t_enter = max(t_enter, (st or now) + p.stage_lag_ns)
            futile += 1
        # Stage the *next* waiter now (successor's successor poke), in
        # parallel with the new owner's CS.
        if fifo:
            staged[fifo[0]] = now + p.stage_lag_ns
        return tid, t_enter

    # Threads all call take() at t≈0 (slight skew for determinism).
    for tid in range(threads):
        push(tid * 1.0, "take", tid)

    now = 0.0
    while heap:
        ev = heapq.heappop(heap)
        now = ev.t
        if now > p.duration_ns:
            break
        if ev.kind in ("take", "wakeup"):
            if policy == "pthread":
                if available > 0:
                    available -= 1
                    push(now + p.base_ns + p.cs_ns, "post", ev.tid)
                else:
                    if ev.kind == "wakeup":
                        futile += 1  # a barger beat the wakee to the permit
                    parked.append(ev.tid)
                    park_order.append(ev.tid)
                    max_queue = max(max_queue, len(parked))
            elif available > 0 and not fifo:
                available -= 1
                push(now + p.cs_ns, "post", ev.tid)  # straight into CS
            else:
                fifo.append(ev.tid)
                if policy == "twa" and len(fifo) <= p.long_term_threshold:
                    staged[ev.tid] = now  # arrives already short-term
                max_queue = max(max_queue, len(fifo))
        elif ev.kind == "post":
            iterations += 1
            if policy == "pthread":
                available += 1
                extra = 0.0
                if parked:
                    # futex_wake syscall on the poster's path; FIFO pop —
                    # the oldest sleeper is woken.  The wakee arrives
                    # wake_ns later (and usually loses to a barger).
                    wakee = parked.pop(0)
                    wake_order.append(wakee)
                    push(now + p.wake_ns, "wakeup", wakee)
                    extra = p.futex_wake_syscall_ns
                push(now + extra + p.ncs_ns, "take", ev.tid)
                continue
            nxt = handover(now)
            if nxt is None:
                available += 1
            else:
                tid, t_enter = nxt
                push(t_enter + p.cs_ns, "post", tid)
            push(now + p.ncs_ns, "take", ev.tid)  # poster does NCS then loops

    return SimResult(
        policy=policy,
        threads=threads,
        iterations=iterations,
        throughput_per_sec=iterations / (min(now, p.duration_ns) * 1e-9) if now > 0 else 0.0,
        futile_wakeups=futile,
        max_queue=max_queue,
        park_order=park_order,
        wake_order=wake_order,
    )


def sweep(policies=("ticket", "twa", "pthread"), thread_counts=(1, 2, 4, 8, 16, 32, 64), p: SimParams | None = None):
    return {pol: [simulate(pol, t, p) for t in thread_counts] for pol in policies}
