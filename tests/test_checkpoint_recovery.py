"""Checkpoint-manager fault paths (PR 7 satellite).

  * emergency ``save_sync`` bypasses the TWA writer-slot queue yet still
    produces a COMPLETE, restorable checkpoint;
  * ``_try_finalize`` times out (returns False, nothing published) while
    commit markers are missing, then finalizes the SAME step once the
    missing host commits — the torn ``.tmp`` dir is invisible to restore
    throughout;
  * uint32 semaphore counters round-trip bit-exact through the npz
    shard format, including values wrapped past 2³¹ (the regression this
    pins: a float or int32 cast would corrupt every TWA ticket/grant in
    a rung-4 snapshot).
"""

from __future__ import annotations

import jax
import numpy as np

import test_chunked_prefill as tcp

from repro.checkpoint.manager import CheckpointManager
from repro.serving.engine_state import rid_token_fn

DT = tcp.DT


def test_emergency_save_sync_restorable(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "ctr": np.asarray([7, 9], np.uint32)}
    m.save_sync(5, tree)
    assert m.complete_steps() == [5]
    got, step = m.restore({"w": np.zeros((3, 4), np.float32),
                           "ctr": np.zeros(2, np.uint32)})
    assert step == 5
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["ctr"], tree["ctr"])
    assert m.io_telemetry()["writers_queued"] == 0


def test_try_finalize_timeout_then_late_host_commits(tmp_path):
    """Host 0 of 2 saves alone: finalize must give up after its timeout
    without publishing; once host 1's shard+commit lands, an explicit
    re-finalize publishes and restore merges both shards."""
    h0 = CheckpointManager(str(tmp_path), host_id=0, expected_hosts=2,
                           finalize_timeout=0.05)
    h0.save_sync(3, {"a": np.asarray([1, 2], np.uint32)})
    assert h0.complete_steps() == []  # torn: invisible to restore
    assert not h0._try_finalize(3)  # still only one commit marker
    try:
        h0.restore({"a": np.zeros(2, np.uint32)})
        raise AssertionError("restore must not see a torn checkpoint")
    except FileNotFoundError:
        pass
    h1 = CheckpointManager(str(tmp_path), host_id=1, expected_hosts=2,
                           finalize_timeout=0.05)
    h1.save_sync(3, {"b": np.asarray([3.0], np.float32)})
    assert h0._try_finalize(3, timeout=5.0)
    assert h0.complete_steps() == [3]
    got, _ = h0.restore({"a": np.zeros(2, np.uint32),
                         "b": np.zeros(1, np.float32)})
    np.testing.assert_array_equal(got["a"], [1, 2])
    np.testing.assert_array_equal(got["b"], [3.0])


def test_uint32_counters_round_trip_bit_exact(tmp_path):
    """The rung-4 snapshot payload: a live engine's QoS + block-pool
    semaphores (uint32 tickets/grants WRAPPED past 2³²−K) restore with
    identical dtype and bits."""
    eng = tcp._mk_chunked([0.0], wrap=True)
    eng.submit_batch(tcp._workload(3, 8, 0.0))
    eng.megastep(6, token_fn=rid_token_fn,
                 nows=np.asarray([k * DT for k in range(6)], np.float32))
    tree = {"qos": eng.qos, "kv": eng._kv_state}
    m = CheckpointManager(str(tmp_path))
    m.save_sync(1, tree)
    got, _ = m.restore(tree)
    leaves_a = jax.tree_util.tree_leaves(tree)
    leaves_b = jax.tree_util.tree_leaves(got)
    assert len(leaves_a) == len(leaves_b) and leaves_a
    wrapped = False
    for a, b in zip(leaves_a, leaves_b):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
        if a.dtype == np.uint32 and (a > np.uint32(1 << 31)).any():
            wrapped = True
    assert wrapped  # the workload really exercised wrapped counters
