"""Coordinator + lease-reaper units (PR 8) — failure detection, barriers,
stragglers, and lease cleanliness under a VIRTUAL clock.

The Coordinator's ``clock`` field and the lease's ``clock=`` parameter
inject the time source for heartbeat stamps, the timeout comparison, and
the barrier deadline — so dead-host and rejoining-host scenarios run
deterministically — while worker THREADS still block on the KV store's
real condition variables (the barrier test drives both at once: threads
park on ``wait_change`` polls, the main thread advances virtual time).
"""

from __future__ import annotations

import threading

import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.runtime.coordinator import (
    Coordinator,
    DistributedTicketLease,
    KVStore,
)
from repro.runtime.reaper import LeaseReaper, leases_clean


def _coord(vc, timeout=2.0):
    return Coordinator(heartbeat_timeout=timeout, kv=KVStore(),
                       clock=lambda: vc[0])


# ---------------------------------------------------- failure detection ----


def test_detect_failures_and_rejoin_virtual_clock():
    """A host that stops heartbeating is declared dead exactly when the
    virtual clock passes the timeout; a REJOIN re-enters it with a fresh
    heartbeat and a bumped epoch (stale incarnations are fenced by the
    epoch they carry)."""
    vc = [0.0]
    c = _coord(vc, timeout=2.0)
    for h in (0, 1, 2):
        c.join(h)
    e0 = c.epoch
    vc[0] = 1.0
    for h in (0, 1):  # host 2 goes silent at t=0
        c.heartbeat(h, step=1, step_time_s=0.1)
    vc[0] = 1.9
    assert c.detect_failures() == []  # 1.9 − 0 < 2.0: still in budget
    vc[0] = 2.5
    assert c.detect_failures() == [2]
    assert c.epoch == e0 + 1
    assert c.alive_hosts() == [0, 1]
    # a dead host's heartbeat is rejected — the fencing contract
    try:
        c.heartbeat(2, step=9, step_time_s=0.1)
        raise AssertionError("dead host heartbeat accepted")
    except RuntimeError:
        pass
    # rejoin: fresh stamp at the CURRENT clock, epoch bumps again
    e2 = c.join(2)
    assert e2 == e0 + 2
    for h in (0, 1, 2):
        c.heartbeat(h, step=10, step_time_s=0.1)
    vc[0] = 3.5
    assert c.detect_failures() == []  # rejoined incarnation is fresh
    assert c.alive_hosts() == [0, 1, 2]


def test_stragglers_by_ewma():
    vc = [0.0]
    c = _coord(vc)
    for h in (0, 1, 2, 3):
        c.join(h)
    for _ in range(8):  # let the EWMA converge
        for h in (0, 1, 2):
            c.heartbeat(h, step=1, step_time_s=0.1)
        c.heartbeat(3, step=1, step_time_s=1.0)
    assert c.stragglers() == [3]


# ------------------------------------------------------------- barriers ----


def test_barrier_shrinks_when_a_host_dies():
    """Two live hosts arrive at the barrier; the third died silently.
    The arrived-count is compared against LIVE membership each poll, so
    once detect_failures() (driven by the advancing virtual clock)
    declares the corpse, the barrier completes instead of hanging."""
    vc = [0.0]
    c = _coord(vc, timeout=2.0)
    for h in (0, 1, 2):
        c.join(h)
    results = {}

    def arrive(h):
        results[h] = c.barrier(h, "gen-1", timeout=60.0)

    ts = [threading.Thread(target=arrive, args=(h,)) for h in (0, 1)]
    for t in ts:
        t.start()
    # host 2 never arrives; advance virtual time past its heartbeat
    # budget while keeping hosts 0/1 fresh — the barrier's inner
    # detect_failures() pass shrinks the required count from 3 to 2
    for _ in range(200):
        if all(not t.is_alive() for t in ts):
            break
        vc[0] += 0.5
        for h in (0, 1):
            if h in c.alive_hosts():
                c.heartbeat(h, step=1, step_time_s=0.1)
        import time
        time.sleep(0.01)
    for t in ts:
        t.join(timeout=10.0)
    assert results == {0: True, 1: True}
    assert c.alive_hosts() == [0, 1]


def test_barrier_times_out_on_virtual_deadline():
    """One of two HEALTHY hosts never arrives: the waiter gives up when
    the virtual clock passes its deadline (no wall-clock dependence)."""
    vc = [0.0]
    c = _coord(vc, timeout=1e9)  # nobody dies in this test
    c.join(0)
    c.join(1)
    out = {}
    t = threading.Thread(
        target=lambda: out.setdefault(0, c.barrier(0, "gen-2", timeout=5.0)))
    t.start()
    for _ in range(200):
        if not t.is_alive():
            break
        vc[0] += 0.5
        import time
        time.sleep(0.01)
    t.join(timeout=10.0)
    assert out[0] is False


# ------------------------------------------------------------ reaper ----


def test_reaper_frees_stale_holder_and_waiter():
    """One leaked holder and one leaked waiter on a capacity-1 lease:
    the reaper cancels the stale waiter (tombstone) and force-releases
    the stale holder — the final grant sequence is clean."""
    vc = [0.0]
    kv = KVStore()
    lease = DistributedTicketLease(kv, "cap", capacity=1,
                                   clock=lambda: vc[0])
    t0 = lease.try_acquire()     # holder
    assert t0 == 0
    t1 = lease.take_ticket()     # queued waiter behind it
    assert lease.granted(t1) is False
    reaper = LeaseReaper([lease], ttl=2.0)
    vc[0] = 1.0
    assert reaper.scan() == []   # inside TTL: nothing reaped
    vc[0] = 3.0
    acts = {a.ticket: a.action for a in reaper.scan()}
    assert acts == {t0: "released", t1: "released"} or \
        acts == {t0: "released", t1: "cancelled"}
    audit = leases_clean([lease])
    assert audit["ok"], audit["violations"]
    assert lease.outstanding() == []
    # reaped exactly once: a second sweep finds nothing
    assert reaper.scan() == []


def test_reaper_spares_renewing_holder():
    vc = [0.0]
    kv = KVStore()
    lease = DistributedTicketLease(kv, "cap", capacity=2,
                                   clock=lambda: vc[0])
    live = lease.try_acquire()
    leak = lease.take_ticket()
    reaper = LeaseReaper([lease], ttl=2.0)
    for step in range(1, 5):
        vc[0] = float(step)
        lease.renew(live)        # the live holder keeps its heartbeat
        reaper.scan()
    assert [a.ticket for a in reaper.actions] == [leak]
    assert lease.headroom() == 1  # live holder still holds its unit
    lease.release(live)
    audit = leases_clean([lease])
    assert audit["ok"], audit["violations"]


# ------------------------------------------------------ churn property ----


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_membership_churn_property(seed):
    """Random join/leave/silence/advance churn: the epoch only moves
    forward, detect_failures flags exactly the hosts whose last stamp is
    stale, and a rejoin always revives."""
    rng = np.random.default_rng(seed)
    vc = [0.0]
    c = _coord(vc, timeout=2.0)
    stamps: dict[int, float] = {}
    alive: set[int] = set()
    last_epoch = 0
    for _ in range(40):
        op = rng.integers(0, 4)
        h = int(rng.integers(0, 5))
        if op == 0:
            c.join(h)
            stamps[h] = vc[0]
            alive.add(h)
        elif op == 1 and h in alive:
            c.leave(h)
            alive.discard(h)
        elif op == 2 and h in alive:
            c.heartbeat(h, step=1, step_time_s=0.1)
            stamps[h] = vc[0]
        else:
            vc[0] += float(rng.uniform(0.0, 1.5))
            expect = sorted(x for x in alive
                            if vc[0] - stamps[x] > c.heartbeat_timeout)
            got = sorted(c.detect_failures())
            assert got == expect, (got, expect)
            alive -= set(expect)
        assert c.epoch >= last_epoch
        last_epoch = c.epoch
        assert c.alive_hosts() == sorted(alive)
