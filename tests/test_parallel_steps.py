"""Distribution-layer correctness: sharded execution must be numerically
equivalent to single-device execution, grad accumulation must match the
unaccumulated step, and elastic re-sharding must be bit-exact.

Uses a forced 8-device host platform in a SUBPROCESS so the main test
process keeps the default single CPU device (the dry-run flag rule)."""

from __future__ import annotations

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ShapeSpec, get_smoke_config
from repro.optim.adamw import AdamWConfig
from repro.parallel import steps as steps_lib


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }


def test_accum_equals_full_batch():
    """A=4 microbatch accumulation ≈ A=1 on the same global batch (fp32)."""
    cfg = get_smoke_config("qwen2-0.5b")
    shape = ShapeSpec("t", 32, 8, "train")
    batch = _batch(cfg, 8, 32)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    out = {}
    for A in (1, 4):
        sc = steps_lib.default_step_config(cfg, shape, dp=1, accum_steps=A,
                                           param_dtype=jnp.float32, fsdp=False)
        state = steps_lib.make_train_state(jax.random.PRNGKey(0), cfg, sc)
        step = jax.jit(steps_lib.make_train_step(cfg, shape, sc, opt))
        state, metrics = step(state, batch)
        out[A] = (float(metrics["loss"]),
                  np.asarray(jax.tree.leaves(state.params)[0], np.float32))
    assert abs(out[1][0] - out[4][0]) < 1e-4
    np.testing.assert_allclose(out[1][1], out[4][1], atol=1e-4, rtol=1e-4)


def test_remat_modes_same_loss():
    cfg = get_smoke_config("qwen2-72b")  # deep enough for 2level (num_units=2)
    shape = ShapeSpec("t", 16, 4, "train")
    batch = _batch(cfg, 4, 16)
    losses = {}
    for remat in ("none", "full", "dots", "2level"):
        sc = steps_lib.default_step_config(cfg, shape, dp=1, accum_steps=1,
                                           remat=remat, param_dtype=jnp.float32,
                                           fsdp=False)
        state = steps_lib.make_train_state(jax.random.PRNGKey(1), cfg, sc)
        step = jax.jit(steps_lib.make_train_step(cfg, shape, sc))
        _, metrics = step(state, batch)
        losses[remat] = float(metrics["loss"])
    base = losses["none"]
    for k, v in losses.items():
        assert abs(v - base) < 1e-3, (k, v, base)


_MESH_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import ShapeSpec, get_smoke_config
    from repro.optim.adamw import AdamWConfig
    from repro.parallel import steps as steps_lib
    from repro.parallel.sharding import batch_pspecs

    arch = sys.argv[1]
    cfg = get_smoke_config(arch)
    B, S = 8, 32
    shape = ShapeSpec("t", S, B, "train")
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S + cfg.n_patches)), jnp.int32)

    results = {}
    # single device
    sc = steps_lib.default_step_config(cfg, shape, dp=1, accum_steps=1,
                                       param_dtype=jnp.float32, fsdp=False)
    state = steps_lib.make_train_state(jax.random.PRNGKey(0), cfg, sc)
    step = jax.jit(steps_lib.make_train_step(cfg, shape, sc))
    _, m = step(state, batch)
    results["single"] = float(m["loss"])

    # 2x4 mesh (dp=2, tp=4) with FSDP
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    from repro import compat
    with compat.set_mesh(mesh):
        sc2 = steps_lib.default_step_config(cfg, shape, dp=2, accum_steps=2,
                                            param_dtype=jnp.float32, fsdp=True)
        state2 = steps_lib.make_train_state(jax.random.PRNGKey(0), cfg, sc2)
        specs = steps_lib.train_state_pspecs(state2, sc2)
        flat, tdef = jax.tree_util.tree_flatten(state2)
        fspecs = tdef.flatten_up_to(specs)
        state2 = tdef.unflatten([
            jax.device_put(x, jax.sharding.NamedSharding(mesh, s))
            for x, s in zip(flat, fspecs)])
        step2 = jax.jit(steps_lib.make_train_step(cfg, shape, sc2))
        _, m2 = step2(state2, batch)
        results["mesh"] = float(m2["loss"])
    print(json.dumps(results))
""")


# jax 0.4.x ships the old XLA whose FSDP all-gather + accumulation ordering
# drifts these two archs ~0.2% in fp32 loss (pre-existing seed reds; current
# jax passes) — version-gated so tier-1 stays green and REAL regressions on
# the other archs/newer jax remain visible.
_OLD_XLA = tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 5)
_OLD_XLA_DRIFT = pytest.mark.xfail(
    condition=_OLD_XLA, strict=False,
    reason="old-XLA (jax<0.5) FSDP accumulation numeric drift, pre-existing")


@pytest.mark.parametrize("arch", [
    pytest.param("qwen2-0.5b", marks=_OLD_XLA_DRIFT),
    pytest.param("deepseek-moe-16b", marks=_OLD_XLA_DRIFT),
    "recurrentgemma-9b",
    "gemma3-1b",
])
def test_mesh_equals_single_device(arch):
    """Same loss on 1 device vs a (2,4) FSDP+TP mesh with accumulation —
    the whole sharding/step stack is semantics-preserving."""
    r = subprocess.run([sys.executable, "-c", _MESH_EQUIV_SCRIPT, arch],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    # MoE accumulates the routed-expert combine in data-dependent scatter
    # order, so resharding legitimately perturbs fp32 rounding (~1e-3 on a
    # ~6.8 loss); dense archs must match tighter.
    tol = 1e-2 if "moe" in arch else 2e-3
    assert abs(res["single"] - res["mesh"]) < tol, res


def test_elastic_reshard_bit_exact(tmp_path):
    """Checkpoint → restore → (different logical dp) → same loss."""
    from repro.checkpoint.manager import CheckpointManager

    cfg = get_smoke_config("qwen2-0.5b")
    shape = ShapeSpec("t", 32, 8, "train")
    sc = steps_lib.default_step_config(cfg, shape, dp=1, accum_steps=1,
                                       param_dtype=jnp.float32, fsdp=False)
    state = steps_lib.make_train_state(jax.random.PRNGKey(0), cfg, sc)
    step = jax.jit(steps_lib.make_train_step(cfg, shape, sc))
    batch = _batch(cfg, 8, 32)
    state, m0 = step(state, batch)
    ck = CheckpointManager(str(tmp_path))
    ck.save(1, state, blocking=True)
    restored, at = ck.restore(state)
    assert at == 1
    # continue on the restored state: identical trajectory
    _, m1 = step(state, batch)
    _, m2 = step(jax.tree.map(jnp.asarray, restored), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-6)
