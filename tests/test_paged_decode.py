"""Ragged paged-decode kernel (`kernels/paged_decode`) vs its oracles:

  * **bit-exact** vs the blockwise oracle `ref.paged_decode_ref` in
    interpret mode — across ragged lengths (incl. wholly-empty slots and
    partially-filled tail blocks), block-table permutations, GQA head
    groupings, and dtypes.  The oracle shares the per-block math
    (`ref.flash_decode_block`) with the kernel, so equality pins the
    kernel's PAGING logic: scalar-prefetched table-driven DMA index maps,
    the -1→0 clamp, the ``i·BS < len`` `pl.when` skip, init/finalize;
  * **allclose** vs the naive dense softmax (`decode_attention_ref` over
    the gathered cache, `ref.paged_gather_kv`) — semantic equivalence of
    the blockwise recurrence itself.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.paged_decode import paged_decode
from repro.kernels.ref import (
    decode_attention_ref,
    paged_decode_ref,
    paged_gather_kv,
)


def _random_case(rng, S, H, KV, hd, NB, BS, MB, *, dtype=jnp.float32,
                 permute=True):
    q = jnp.asarray(rng.normal(size=(S, H, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(NB, BS, KV, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(NB, BS, KV, hd)), dtype)
    lens = rng.integers(0, MB * BS + 1, size=S).astype(np.int32)
    lens[rng.integers(0, S)] = 0            # always exercise an empty slot
    lens[rng.integers(0, S)] = MB * BS      # ... and a full table
    ids = rng.permutation(NB) if permute else np.arange(NB)
    tbl = np.full((S, MB), -1, np.int32)
    p = 0
    for s in range(S):
        nb = -(-int(lens[s]) // BS)
        if p + nb > NB:                     # pool exhausted: shorten the slot
            nb = NB - p
            lens[s] = nb * BS
        tbl[s, :nb] = ids[p:p + nb]
        p += nb
    return q, kp, vp, jnp.asarray(tbl), jnp.asarray(lens)


@pytest.mark.parametrize("H,KV,hd", [(4, 4, 32), (8, 2, 64), (2, 1, 16)])
def test_paged_decode_bit_exact_vs_blockwise_oracle(H, KV, hd):
    rng = np.random.default_rng(7 + H)
    for trial in range(3):
        q, kp, vp, tbl, lens = _random_case(
            rng, S=6, H=H, KV=KV, hd=hd, NB=32, BS=8, MB=5)
        ref = paged_decode_ref(q, kp, vp, tbl, lens)
        out = paged_decode(q, kp, vp, tbl, lens, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref),
            err_msg=f"H={H} KV={KV} trial={trial}: kernel != blockwise oracle")


def test_paged_decode_table_permutation_invariance():
    """The SAME logical sequences through two different physical block
    assignments must produce identical attention — the table fully decouples
    logical token order from pool layout."""
    rng = np.random.default_rng(3)
    S, H, KV, hd, NB, BS, MB = 4, 4, 2, 32, 32, 8, 4
    q = jnp.asarray(rng.normal(size=(S, H, hd)), jnp.float32)
    lens = jnp.asarray([0, 5, 16, 29], jnp.int32)
    outs = []
    for seed in (0, 1):
        prm = np.random.default_rng(seed).permutation(NB)
        tbl = np.full((S, MB), -1, np.int32)
        kp = np.zeros((NB, BS, KV, hd), np.float32)
        vp = np.zeros((NB, BS, KV, hd), np.float32)
        tok = np.asarray(rng.bit_generator.state["state"]["state"])  # unused
        content = np.random.default_rng(42).normal(
            size=(S, MB * BS, KV, hd)).astype(np.float32)
        p = 0
        for s in range(S):
            nb = -(-int(lens[s]) // BS)
            for j in range(nb):
                b = prm[p]
                tbl[s, j] = b
                kp[b] = content[s, j * BS:(j + 1) * BS]
                vp[b] = content[s, j * BS:(j + 1) * BS] * 0.5
                p += 1
        outs.append(np.asarray(paged_decode(
            q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tbl), lens,
            interpret=True)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_paged_decode_blockwise_matches_dense_softmax():
    """The blockwise oracle is semantically the dense masked softmax over
    the gathered cache (fp-tolerance — online vs full softmax)."""
    rng = np.random.default_rng(11)
    q, kp, vp, tbl, lens = _random_case(
        rng, S=5, H=4, KV=2, hd=32, NB=32, BS=8, MB=4)
    ref = paged_decode_ref(q, kp, vp, tbl, lens)
    kd, pos = paged_gather_kv(kp, tbl, lens)
    vd, _ = paged_gather_kv(vp, tbl, lens)
    dense = decode_attention_ref(q, kd, vd, pos, jnp.maximum(lens - 1, 0))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense),
                               atol=2e-6, rtol=2e-5)
    # empty slots (len 0) emit exactly zero on both paths
    empty = np.flatnonzero(np.asarray(lens) == 0)
    assert empty.size > 0 and not np.asarray(ref)[empty].any()


def test_paged_decode_streams_only_live_blocks():
    """Garbage in unallocated pool blocks must not perturb the output —
    the ragged skip + length mask confine the kernel to live blocks."""
    rng = np.random.default_rng(5)
    q, kp, vp, tbl, lens = _random_case(
        rng, S=4, H=2, KV=1, hd=16, NB=32, BS=4, MB=4)
    out1 = paged_decode(q, kp, vp, tbl, lens, interpret=True)
    live = np.unique(np.asarray(tbl)[np.asarray(tbl) >= 0])
    poison = np.asarray(kp).copy()
    mask = np.ones(32, bool)
    mask[live] = False
    poison[mask] = 1e9
    vpoison = np.asarray(vp).copy()
    vpoison[mask] = -1e9
    out2 = paged_decode(q, jnp.asarray(poison), jnp.asarray(vpoison), tbl,
                        lens, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_paged_decode_ops_wrapper():
    from repro.kernels import ops

    rng = np.random.default_rng(1)
    q, kp, vp, tbl, lens = _random_case(
        rng, S=3, H=2, KV=2, hd=16, NB=16, BS=4, MB=3)
    np.testing.assert_array_equal(
        np.asarray(ops.paged_decode(q, kp, vp, tbl, lens)),
        np.asarray(paged_decode_ref(q, kp, vp, tbl, lens)))
