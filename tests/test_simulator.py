"""Validation of the paper's empirical claims (Figure 1) via the calibrated
discrete-event coherence model — see core/simulator.py for why measurement
on a 1-core GIL box is impossible and what is modelled instead."""

from __future__ import annotations

from repro.core.simulator import SimParams, simulate, sweep


def test_c1_single_thread_parity():
    """C1: at 1 thread Ticket ≈ TWA (identical uncontended fast paths)."""
    t = simulate("ticket", 1)
    w = simulate("twa", 1)
    assert abs(t.throughput_per_sec - w.throughput_per_sec) / t.throughput_per_sec < 0.05


def test_c2_dip_one_to_two():
    """C2: 1→2 threads dips (communication precedes parallelism benefits)."""
    for policy in ("ticket", "twa"):
        t1 = simulate(policy, 1).throughput_per_sec
        t2 = simulate(policy, 2).throughput_per_sec
        assert t2 < t1, policy


def test_c3_twa_beats_ticket_under_contention():
    """C3: global spinning decays Ticket-Semaphore ~1/T; TWA stays ~flat.
    At 64 threads the gap must be large (paper: ~an order of magnitude)."""
    res = sweep(policies=("ticket", "twa"), thread_counts=(16, 32, 64))
    for i, t in enumerate((16, 32, 64)):
        tk = res["ticket"][i].throughput_per_sec
        tw = res["twa"][i].throughput_per_sec
        assert tw > tk, f"TWA should win at {t} threads"
    # decay shape: ticket halves (or worse) from 16→64; twa loses <25%
    assert res["ticket"][2].throughput_per_sec < 0.6 * res["ticket"][0].throughput_per_sec
    assert res["twa"][2].throughput_per_sec > 0.75 * res["twa"][0].throughput_per_sec
    # and the 64-thread gap is at least 3×
    assert res["twa"][2].throughput_per_sec > 3 * res["ticket"][2].throughput_per_sec


def test_c4_pthread_barging_tradeoff():
    """C4: the non-FIFO parking baseline keeps throughput via barging but
    starves waiters (deep queues / futile wakeups) — the unfairness the
    paper's FCFS design rules out."""
    p = simulate("pthread", 64)
    w = simulate("twa", 64)
    assert p.max_queue >= 32, "barging should starve the parked queue"
    # TWA bounds the queue by serving FIFO at hardware handover speed
    assert w.throughput_per_sec > 0.3 * p.throughput_per_sec


def test_threshold_zero_all_futex():
    """LongTermThreshold=0 ⇒ no global spinning at all (paper §2: 'if we
    desire that all threads wait by futex… set LongTermThreshold to 0') —
    the model must still make progress and stay fair."""
    p = SimParams(long_term_threshold=0)
    r = simulate("twa", 32, p)
    assert r.iterations > 0


def test_pthread_parked_queue_wakes_fifo():
    """The pthread model's kernel sleep queue is FIFO (futex wait-queues
    wake oldest-first): every wakeup pops the oldest parked thread.  The
    baseline's non-FIFO *admission* comes from barging, not wake order —
    this pins the code/doc agreement on the parked-queue discipline."""
    r = simulate("pthread", 16)
    assert r.wake_order, "contended run must produce wakeups"
    # Replay: maintaining the park log as a FIFO queue reproduces the wake
    # log exactly (each wake removes the current oldest sleeper).
    queue = []
    park_iter = iter(r.park_order)
    for wakee in r.wake_order:
        while not queue or queue[0] != wakee:
            queue.append(next(park_iter))
        assert queue.pop(0) == wakee
