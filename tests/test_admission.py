"""Multi-tenant QoS admission subsystem (src/repro/admission/).

Covers the three ISSUE-mandated properties:
  * weighted fairness — tenant admission counts converge to weights under
    saturation (hierarchical tree with real threads AND the batched
    functional QoS state);
  * tombstone cancellation — a cancelled/expired waiter never consumes a
    slot and never blocks later live tickets; FCFS among live waiters is
    preserved exactly (host skip-aware post, handle cancel, functional
    live-rank, distributed KV lease);
  * deadline misses through ContinuousBatchingEngine — an expired backlog
    entry is tombstoned, its client unblocked, later requests unaffected.
"""

from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is an optional test dependency (pyproject `test` extra)
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.admission import (
    CancellableTake,
    HierarchicalTWASemaphore,
    make_qos,
    qos_admit,
    qos_replenish,
    qos_round,
    qos_take,
    take_with_timeout,
)
from repro.core.twa_semaphore import TWASemaphore
from repro.runtime.coordinator import DistributedTicketLease, KVStore
from repro.serving.scheduler import ContinuousBatchingEngine, Request


# --------------------------------------------------------------- tombstones --


def test_tombstone_skip_preserves_live_fcfs():
    """Waiters A, B, C in FCFS order; B abandons.  Posts must reach A then
    C (skipping B's dead ticket) in ticket order — the skip-aware post."""
    sem = TWASemaphore(0, waiting="futex", cancellation=True)
    order: list[str] = []
    order_lock = threading.Lock()

    def waiter(name):
        sem.take()
        with order_lock:
            order.append(name)

    a = threading.Thread(target=waiter, args=("A",)); a.start()
    time.sleep(0.05)  # ticket order: A=0
    got_b = []
    b = threading.Thread(
        target=lambda: got_b.append(take_with_timeout(sem, 0.15)))
    b.start()  # B=1, will time out
    time.sleep(0.05)
    c = threading.Thread(target=waiter, args=("C",)); c.start()  # C=2
    b.join(3)
    assert got_b == [False]
    sem.post(1)  # → A
    a.join(3)
    sem.post(1)  # lands on B's tombstone → skipped → C
    c.join(3)
    assert order == ["A", "C"]
    assert sem.tombstones_skipped == 1
    assert sem.tombstones_pending() == 0


def test_cancel_lost_race_holds_slot():
    """A cancel that arrives after the grant reports 'acquired' — the slot
    is owned, never leaked, never double-granted."""
    sem = TWASemaphore(1, cancellation=True)
    assert sem.take_until(time.monotonic() - 1.0) is True  # grant pre-arrived
    assert sem.available() == 0
    sem.post()
    assert sem.available() == 1


def test_external_cancel_unblocks_futex_waiter():
    sem = TWASemaphore(0, waiting="futex", cancellation=True)
    handle = CancellableTake(sem)
    res = []
    t = threading.Thread(target=lambda: res.append(handle.wait(None)))
    t.start()
    time.sleep(0.1)
    assert handle.cancel() is True
    t.join(3)
    assert not t.is_alive() and res == [False]
    # the tombstone is transparent to the next waiter
    nxt = CancellableTake(sem)
    sem.post(1)
    assert nxt.wait(time.monotonic() + 3) is True


def test_cancel_exactly_one_outcome_under_race():
    """Hammer cancel-vs-post: for every handle exactly one of
    {acquired, cancelled} holds, and slots are conserved."""
    for trial in range(30):
        sem = TWASemaphore(0, cancellation=True)
        handles = [CancellableTake(sem) for _ in range(4)]
        results = [None] * 4

        def wait(i):
            results[i] = handles[i].wait(time.monotonic() + 0.01 * (i % 3))

        ts = [threading.Thread(target=wait, args=(i,)) for i in range(4)]
        [t.start() for t in ts]
        sem.post(2)
        [t.join(5) for t in ts]
        acquired = sum(bool(r) for r in results)
        # 2 units among 4 deadline-racing waiters: the acquired count plus
        # units still available (skipped past everyone) must equal 2.
        assert acquired + sem.available() == 2, (trial, results)


# --------------------------------------------------------- hierarchical tree --


def test_hierarchical_weighted_shares_under_saturation():
    """Tenant admission counts converge to weights while all tenants stay
    backlogged (stride replenishment)."""
    h = HierarchicalTWASemaphore(4, waiting="futex")
    weights = {"gold": 4.0, "silver": 2.0, "bronze": 1.0}
    for t, w in weights.items():
        h.register(t, w)
    stop = threading.Event()

    def worker(tenant):
        while not stop.is_set():
            if h.acquire(tenant, timeout=1.0):
                time.sleep(0.0005)
                h.release(tenant)

    ts = [threading.Thread(target=worker, args=(t,))
          for t in weights for _ in range(4)]
    [t.start() for t in ts]
    time.sleep(1.5)
    stop.set()
    [t.join(5) for t in ts]
    shares = h.shares()
    wsum = sum(weights.values())
    for t, w in weights.items():
        target = w / wsum
        assert abs(shares[t] - target) / target < 0.15, (shares, t)


def test_hierarchical_cancel_does_not_strand_slots():
    """A tenant whose only waiter abandons must not hoard the slot: it is
    reclaimed and flows to the other tenant (work conservation)."""
    h = HierarchicalTWASemaphore(1, waiting="futex")
    h.register("a", 1.0)
    h.register("b", 1.0)
    assert h.acquire("a", timeout=1.0)  # a holds the only slot
    res_b = []
    b = threading.Thread(target=lambda: res_b.append(
        h.acquire("b", timeout=0.15)))
    b.start()
    b.join(3)
    assert res_b == [False]  # b abandoned; its leaf may hold a stranded unit
    res_a2 = []
    a2 = threading.Thread(target=lambda: res_a2.append(
        h.acquire("a", timeout=5.0)))
    a2.start()
    time.sleep(0.05)
    h.release("a")  # must reach a2 despite b's tombstone
    a2.join(5)
    assert res_a2 == [True]
    h.release("a")
    tel = h.telemetry()
    assert tel["free"] == 1  # slot conserved back at the root


# ------------------------------------------------------------ functional QoS --


def test_qos_functional_weighted_split():
    s = make_qos([4.0, 2.0, 1.0], table_size=256)
    ids = jnp.asarray([0] * 8 + [1] * 8 + [2] * 8, jnp.int32)
    s, tickets, buckets, expired = qos_take(s, ids, jnp.ones(24, bool))
    assert not bool(expired.any())
    s, alloc, leftover = qos_replenish(
        s, 14, jnp.asarray([8, 8, 8], jnp.int32), max_units=16)
    np.testing.assert_array_equal(np.asarray(alloc), [8, 4, 2])
    assert int(leftover) == 0
    s, admitted = qos_admit(s, ids, tickets, jnp.ones(24, bool))
    counts = [int(admitted[np.asarray(ids) == i].sum()) for i in range(3)]
    assert counts == [8, 4, 2]


def test_qos_dead_ticket_transparent_fcfs():
    """A dead ticket in the MIDDLE of a tenant queue is skipped: grant
    units flow to the earliest live tickets, in ticket order."""
    s = make_qos([1.0], table_size=64)
    ids = jnp.zeros((4,), jnp.int32)
    s, tickets, _, _ = qos_take(s, ids, jnp.ones(4, bool))
    alive = jnp.asarray([True, False, True, True])  # ticket 1 tombstoned
    s = s._replace(dead=s.dead + jnp.asarray([1], jnp.uint32))
    s, alloc, _ = qos_replenish(s, 2, jnp.asarray([3], jnp.int32), max_units=4)
    assert int(alloc[0]) == 2
    s, admitted = qos_admit(s, ids, tickets, alive)
    # 2 units → tickets 0 and 2 (1 is dead, 3 waits) — live FCFS exact
    np.testing.assert_array_equal(np.asarray(admitted), [1, 0, 1, 0])


def test_qos_round_deadline_expiry():
    """qos_round: expired rows are tombstoned (reported, never admitted)
    and their would-be slots reach later live rows in the same pass."""
    s = make_qos([1.0, 1.0], table_size=64)
    ids = jnp.asarray([0, 0, 1, 1], jnp.int32)
    s, tickets, _, _ = qos_take(s, ids, jnp.ones(4, bool))
    deadlines = jnp.asarray([0.5, 10.0, 0.5, 10.0])
    s, admitted, expired, leftover = qos_round(
        s, ids, tickets, jnp.ones(4, bool), deadlines, now=1.0,
        free_units=2, max_units=4)
    np.testing.assert_array_equal(np.asarray(expired), [1, 0, 1, 0])
    np.testing.assert_array_equal(np.asarray(admitted), [0, 1, 0, 1])
    assert int(leftover) == 0


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(1, 8), min_size=2, max_size=5))
def test_qos_replenish_share_property(weights):
    """Property: distributing many units across always-backlogged tenants
    lands each tenant within one stride step of its weighted share."""
    S = len(weights)
    s = make_qos([float(w) for w in weights], table_size=64)
    units = 40
    depth = jnp.full((S,), units, jnp.int32)  # bottomless backlogs
    s, alloc, leftover = qos_replenish(s, units, depth, max_units=64)
    assert int(leftover) == 0
    total, wsum = int(jnp.sum(alloc)), sum(weights)
    assert total == units
    for i, w in enumerate(weights):
        target = units * w / wsum
        assert abs(int(alloc[i]) - target) <= wsum / min(weights) + 1, (
            np.asarray(alloc), weights)


# ------------------------------------------------------------------- engine --


def _run_engine(eng, reqs, max_steps=5000, until=None):
    steps = 0
    goal = until or (lambda: eng.stats.finished + eng.stats.expired >= len(reqs))
    while not goal() and steps < max_steps:
        eng.step(lambda lg: np.zeros(len(lg), np.int64))
        steps += 1
    return steps


def test_engine_weighted_fcfs_admission():
    """≥3 tenants of unequal weights: saturation-window admission shares
    within 10% of weights; FCFS within each tenant (admit time order ==
    submit order)."""
    weights = {"gold": 4.0, "silver": 2.0, "bronze": 1.0}
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots=6,
        tenants=weights)
    reqs, rid = [], 0
    for _ in range(100):
        for t in weights:
            reqs.append(Request(rid=rid, prompt=[1], max_new_tokens=3,
                                tenant_id=t))
            rid += 1
    eng.submit_batch(reqs)
    _run_engine(eng, reqs, until=lambda: not all(d > 0 for d in eng._tenant_live))
    total = sum(eng.tenant_admitted.values())
    wsum = sum(weights.values())
    for t, w in weights.items():
        target = w / wsum
        share = eng.tenant_admitted[t] / total
        assert abs(share - target) / target < 0.10, (t, share, target)
    # FCFS within tenant: admission timestamps follow ticket order
    for t in weights:
        admitted = [r for r in reqs if r.tenant_id == t and r.admit_t > 0]
        tks = [r.ticket for r in sorted(admitted, key=lambda r: r.admit_t)]
        assert tks == sorted(tks), t
    # TWA gating did real work: most backlog rows were never re-examined
    assert eng.stats.backlog_skipped > eng.stats.backlog_scans


def test_engine_deadline_miss_tombstoned():
    """A queued request whose deadline passes is expired (client unblocked,
    stats counted) and never blocks later live requests of its tenant."""
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots=1,
        tenants={"a": 1.0})
    blocker = Request(rid=0, prompt=[1], max_new_tokens=40, tenant_id="a")
    doomed = Request(rid=1, prompt=[1], max_new_tokens=2, tenant_id="a",
                     deadline=time.monotonic() + 0.05)
    later = Request(rid=2, prompt=[1], max_new_tokens=2, tenant_id="a")
    eng.submit_batch([blocker, doomed, later])
    time.sleep(0.1)  # the doomed deadline passes while queued
    _run_engine(eng, [blocker, doomed, later])
    assert doomed.expired and doomed.done_event.is_set()
    assert doomed.admit_t == 0.0 and not doomed.out_tokens
    assert len(later.out_tokens) >= 2  # the tombstone never blocked it
    assert eng.stats.expired == 1 and eng.stats.finished == 2
    assert eng.telemetry()["tenants"]["a"]["expired"] == 1


def test_engine_dead_on_arrival():
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots=2,
        tenants={"a": 1.0})
    doa = Request(rid=0, prompt=[1], max_new_tokens=2, tenant_id="a",
                  deadline=time.monotonic() - 1.0)
    live = Request(rid=1, prompt=[1], max_new_tokens=2, tenant_id="a")
    eng.submit_batch([doa, live])
    _run_engine(eng, [doa, live])
    assert doa.expired and doa.done_event.is_set()
    assert len(live.out_tokens) >= 2
    assert eng.stats.expired == 1 and eng.stats.finished == 1


def test_engine_single_tenant_path_unchanged():
    """Legacy (no tenants=) admission still FCFS over one flat queue."""
    eng = ContinuousBatchingEngine(
        lambda active: np.zeros(len(active)), lambda r: None, n_slots=4)
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=2) for i in range(32)]
    eng.submit_batch(reqs)
    _run_engine(eng, reqs)
    assert eng.stats.finished == 32
    tks = [r.ticket for r in sorted(reqs, key=lambda r: r.admit_t)]
    assert tks == sorted(tks)


# -------------------------------------------------------- distributed lease --


def test_lease_timeout_does_not_wedge_grant_sequence():
    """The ISSUE's cluster scenario: a dying host abandons its wait; the
    release path skips its KV tombstone so the next live host proceeds."""
    kv = KVStore()
    lease = DistributedTicketLease(kv, "ckpt", capacity=1)
    lease.acquire()
    with pytest.raises(TimeoutError):
        lease.acquire(timeout=0.15)  # dying host: tombstoned, not wedged
    got = []
    live = threading.Thread(target=lambda: got.append(lease.acquire(timeout=5.0)))
    live.start()
    time.sleep(0.05)
    lease.release()  # skips the dead ticket
    live.join(5)
    assert got and lease.dead_skipped == 1
    lease.release()
    assert lease.queue_depth() == 0
