"""Replica router + cluster chaos (PR 8) — the fourth semaphore
granularity and its failure contract.

  * routing rides the lease: bindings go to the max-headroom replica
    (grant − ticket), queued bindings are admitted FCFS when completions
    advance the grant, re-polls are bucket-gated;
  * reaper: a leaked ticket (client vanished after take) is freed at
    TTL and does NOT kill the replica it leaked on;
  * circuit breaker: consecutive sick rounds trip it, cool-off
    half-opens for one probe, a healthy round closes it;
  * exactly-once migration: a replica killed mid-megastep loses its
    in-flight requests to healthy replicas; a PARTITIONED replica keeps
    running as a zombie and races its own migrated clones — the first
    completion wins, duplicates are suppressed, nothing is lost or
    delivered twice;
  * warm takeover: requests captured by the dead replica's last
    checkpoint snapshot are adopted by a standby that restores the
    snapshot and resumes them mid-flight;
  * acceptance property: 4 replicas under a seeded cluster FaultPlan
    (kill + straggler + KV partition + lease leak) — every accepted
    request completes exactly once or is shed with a recorded reason,
    surviving token streams are bit-identical to a fault-free twin, the
    reaper frees every leaked ticket (final grant sequences clean), and
    every surviving engine's exit conservation audit passes.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.resilience import (
    CLUSTER_KINDS,
    FaultEvent,
    FaultPlan,
    KV_PARTITION,
    LEASE_LEAK,
    REPLICA_KILL,
    STRAGGLER,
)
from repro.serving.router import (
    CircuitBreaker,
    ClusterRequest,
    toy_cluster,
    toy_workload,
)


def _long_reqs(n, max_new=14):
    """Requests long enough to still be in flight when faults land."""
    return [ClusterRequest(rid=i, prompt=[1 + i % 5] * 3,
                           max_new_tokens=max_new,
                           tenant_id=("gold", "bronze")[i % 2])
            for i in range(n)]


def _check_exactly_once(router, report, rids, baseline=None):
    done, shed = set(router.completed), set(report["shed"])
    assert done | shed == set(rids), (done, shed, rids)
    assert not (done & shed)
    for rid in shed:
        assert report["shed"][rid] in ("deadline", "retry_budget")
    if baseline is not None:
        for rid in done & set(baseline.completed):
            assert router.completed[rid] == baseline.completed[rid], rid
    la = report["lease_audit"]
    assert la["ok"], la["violations"]
    assert all(a["ok"] for a in report["engine_audits"].values()), \
        report["engine_audits"]


# -------------------------------------------------------------- basics ----


def test_fault_free_cluster_drains_clean():
    r = toy_cluster(3, seed=0)
    work = toy_workload(9, seed=1)
    r.submit_batch(work)
    rep = r.run(max_rounds=100)
    _check_exactly_once(r, rep, [c.rid for c in work])
    assert rep["stats"]["completed"] == 9 and not rep["shed"]
    assert rep["stats"]["replicas_dead"] == 0
    # every replica got a share of the load (max-headroom spreading)
    assert all(x.driven_rounds > 0 for x in r.replicas)


def test_submit_is_idempotent():
    r = toy_cluster(2, seed=0)
    a = ClusterRequest(rid=7, prompt=[1], max_new_tokens=2,
                       tenant_id="gold")
    b = ClusterRequest(rid=7, prompt=[1], max_new_tokens=2,
                       tenant_id="gold")
    assert r.submit(a) is a
    assert r.submit(b) is a  # client retry folds into the same record
    assert r.stats.accepted == 1
    rep = r.run(max_rounds=50)
    assert rep["completed"] == 1


def test_cluster_plan_is_seed_deterministic():
    p1 = FaultPlan.cluster(5, rounds=10, n_replicas=4)
    p2 = FaultPlan.cluster(5, rounds=10, n_replicas=4)
    assert p1.events == p2.events
    kinds = {e.kind for e in p1.events}
    assert {REPLICA_KILL, KV_PARTITION, STRAGGLER, LEASE_LEAK} <= kinds
    assert kinds <= set(CLUSTER_KINDS)


# -------------------------------------------------------------- reaper ----


def test_leaked_ticket_reaped_without_killing_replica():
    plan = FaultPlan(seed=0, events=(
        FaultEvent(round=1, kind=LEASE_LEAK, arg=1),))
    r = toy_cluster(2, seed=0, plan=plan)
    work = toy_workload(6, seed=3)
    r.submit_batch(work)
    rep = r.run(max_rounds=100)
    _check_exactly_once(r, rep, [c.rid for c in work])
    assert rep["stats"]["orphans_reaped"] == 1
    assert rep["stats"]["replicas_dead"] == 0  # orphan ≠ dead replica
    assert all(x.alive for x in r.replicas)


# -------------------------------------------------------------- breaker ----


def test_circuit_breaker_state_machine():
    b = CircuitBreaker(trip_after=3, cooloff=4)
    assert b.allow(0)
    assert b.record(False, 0) is None
    assert b.record(False, 1) is None
    assert b.record(False, 2) == "open"      # third consecutive sick round
    assert b.state == CircuitBreaker.OPEN and b.trips == 1
    assert not b.allow(3) and not b.allow(5)
    assert b.allow(6)                         # cooloff over: the one probe
    assert b.state == CircuitBreaker.HALF_OPEN
    b.bound()
    assert not b.allow(6)                     # probe consumed
    assert b.record(False, 6) == "reopen"     # probe went badly
    assert not b.allow(7)
    assert b.allow(10)
    b.bound()
    assert b.record(True, 10) == "close"      # probe came back healthy
    assert b.state == CircuitBreaker.CLOSED and b.allow(11)
    # a single blip below the trip threshold never opens it
    b.record(False, 12)
    assert b.record(True, 13) is None and b.state == CircuitBreaker.CLOSED


# ------------------------------------------------- kill + migration ----


def test_replica_kill_migrates_exactly_once():
    """Replica 0 dies mid-megastep with work in flight: its tickets are
    freed, the requests re-clone onto the survivor under the retry
    budget, and every stream matches the fault-free twin bit for bit."""
    work = _long_reqs(6)
    base = toy_cluster(2, seed=0)
    base.submit_batch(_long_reqs(6))
    base.run(max_rounds=100)

    plan = FaultPlan(seed=0, events=(
        FaultEvent(round=1, kind=REPLICA_KILL, arg=0, delta=2),))
    r = toy_cluster(2, seed=0, plan=plan)
    r.submit_batch(work)
    rep = r.run(max_rounds=150)
    _check_exactly_once(r, rep, [c.rid for c in work], baseline=base)
    st_ = rep["stats"]
    assert st_["replicas_dead"] == 1 and st_["migrated"] >= 1
    assert not rep["shed"]  # budget was enough: nothing dropped
    assert any(e["action"] == "replica_killed" for e in r.events)
    # the dead replica's lease is clean even though it never released
    dead_lease = r.replicas[0].lease
    assert dead_lease.headroom() == dead_lease.capacity


def test_partition_zombie_races_migrated_clone_dedupe():
    """A KV partition makes replica 0 look dead (heartbeats lost) while
    it KEEPS RUNNING.  Its in-flight work is migrated; the zombie races
    the clones.  First completion wins, the loser is suppressed — each
    rid is delivered exactly once — and the corpse is fenced when the
    partition heals."""
    work = _long_reqs(6)
    plan = FaultPlan(seed=0, events=(
        FaultEvent(round=1, kind=KV_PARTITION, arg=0, delta=8),))
    r = toy_cluster(2, seed=0, plan=plan)
    r.submit_batch(work)
    rep = r.run(max_rounds=150)
    _check_exactly_once(r, rep, [c.rid for c in work])
    st_ = rep["stats"]
    assert st_["replicas_dead"] == 1
    assert r.replicas[0].dead_reason == "heartbeat_timeout"
    # the race really happened: the same rid finished on both sides at
    # least once, and exactly one side's result was delivered
    assert st_["duplicates_suppressed"] >= 1, st_
    assert st_["zombie_deliveries"] + st_["migrated"] >= 1
    assert any(e["action"] == "fenced" and e["replica"] == 0
               for e in r.events)
    assert not r.replicas[0].process_alive


def test_warm_takeover_adopts_snapshot_requests():
    """With a standby factory and snapshots on, a killed replica's
    captured in-flight requests resume on a successor mid-stream instead
    of replaying from scratch — and the streams still match the
    fault-free twin."""
    base = toy_cluster(2, seed=0)
    base.submit_batch(_long_reqs(6))
    base.run(max_rounds=100)

    work = _long_reqs(6)
    plan = FaultPlan(seed=0, events=(
        FaultEvent(round=2, kind=REPLICA_KILL, arg=0, delta=3),))
    r = toy_cluster(2, seed=0, plan=plan, standby=True, snapshot_every=4)
    r.submit_batch(work)
    rep = r.run(max_rounds=150)
    _check_exactly_once(r, rep, [c.rid for c in work], baseline=base)
    st_ = rep["stats"]
    assert st_["successors"] == 1 and st_["adopted"] >= 1, st_
    assert any(e["action"] == "warm_takeover" for e in r.events)
    # the successor joined membership and carried real work
    succ = r.replicas[-1]
    assert succ.idx == 2 and succ.alive and succ.driven_rounds > 0


# ------------------------------------------------ acceptance property ----


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 1_000))
def test_cluster_chaos_exactly_once_property(seed):
    """THE acceptance gate: 4 replicas under a seeded cluster FaultPlan —
    one replica killed mid-megastep, one straggler, one KV-partition
    window, plus a leaked lease ticket.  Every accepted request reaches
    `done` exactly once or `shed` with a recorded reason; surviving
    streams are bit-identical to the fault-free run; the reaper frees
    every leaked ticket (grant sequences clean); every surviving
    engine's conservation audit passes."""
    work = toy_workload(10, seed=seed + 1)
    base = toy_cluster(4, seed=seed)
    base.submit_batch(toy_workload(10, seed=seed + 1))
    base.run(max_rounds=150)

    plan = FaultPlan.cluster(seed, rounds=8, n_replicas=4)
    r = toy_cluster(4, seed=seed, plan=plan, standby=True,
                    snapshot_every=4)
    r.submit_batch(work)
    rep = r.run(max_rounds=150)
    _check_exactly_once(r, rep, [c.rid for c in work], baseline=base)
    # the reaper actually worked: the orphan leak was freed
    assert rep["reaper"]["reaped"] >= 1
    # detection happened through one of the two paths
    if rep["stats"]["replicas_dead"]:
        reasons = {x.dead_reason for x in r.replicas if not x.alive}
        assert reasons <= {"heartbeat_timeout", "lease_reaped"}
