"""Ragged blockwise flash-prefill kernel (`kernels/paged_prefill`) — the
chunked-prefill tentpole's kernel tests:

  * bit-exactness vs the blockwise oracle `ref.paged_prefill_ref`
    (interpret mode) across ragged per-slot chunk sizes and offsets
    (block-aligned AND mid-block), idle slots, GQA head groupings, and
    random block-table permutations — outputs AND both written-back
    pools;
  * the in-pass KV writeback: chunk rows land at exactly
    ``tbl[s, t//BS] · BS + t%BS``, blocks of OTHER slots and unallocated
    pool blocks are bit-untouched (the aliased trash-block routing);
  * chunked == one-shot semantics: driving a prompt through the kernel in
    arbitrary chunk splits reproduces the one-shot causal attention
    (`ref.mha_ref`) for every chunk's rows, and the final pool content is
    split-invariant bit-for-bit.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.paged_prefill import paged_prefill
from repro.kernels.ref import mha_ref, paged_prefill_ref


def _mk(seed, S, CT, H, KV, hd, NB, BS, MB, offs, lens):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((S, CT, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((S, CT, KV, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((S, CT, KV, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((NB, BS, KV, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NB, BS, KV, hd)), jnp.float32)
    perm = rng.permutation(NB)
    tbl = np.full((S, MB), -1, np.int32)
    n = 0
    for s in range(S):
        nb_s = -(-(int(offs[s]) + int(lens[s])) // BS)
        for i in range(nb_s):
            tbl[s, i] = perm[n]
            n += 1
    return q, kc, vc, kp, vp, jnp.asarray(tbl), \
        jnp.asarray(offs, jnp.int32), jnp.asarray(lens, jnp.int32)


def _assert_bitexact(args):
    out_k, kpk, vpk = paged_prefill(*args, interpret=True)
    out_r, kpr, vpr = paged_prefill_ref(*args)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(kpk), np.asarray(kpr))
    np.testing.assert_array_equal(np.asarray(vpk), np.asarray(vpr))
    return out_k, kpk, vpk


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_paged_prefill_bitexact_property(seed):
    """ISSUE acceptance: kernel ≡ oracle bit-for-bit over random ragged
    chunk offsets/lengths (unaligned starts included), idle slots, and
    permuted tables — attention outputs and both written-back pools."""
    rng = np.random.default_rng(seed)
    S, CT, KV, hd = 4, 8, 2, 8
    H = KV * int(rng.integers(1, 3))  # GQA group 1 or 2
    NB, BS, MB = 32, 4, 8
    offs = rng.integers(0, 16, S)
    lens = rng.integers(0, CT + 1, S)  # 0 ⇒ idle slot this round
    lens = np.where(offs + lens > MB * BS, 0, lens)
    args = _mk(seed, S, CT, H, KV, hd, NB, BS, MB, offs, lens)
    _assert_bitexact(args)


def test_paged_prefill_writeback_targets_and_isolation():
    """The in-pass writeback lands every chunk token at its table slot and
    touches NOTHING else: other slots' blocks and unallocated pool blocks
    are bit-identical before/after (aliased trash-block routing)."""
    S, CT, H, KV, hd = 2, 6, 2, 2, 4
    NB, BS, MB = 16, 4, 8
    offs, lens = np.asarray([3, 0]), np.asarray([5, 4])
    args = _mk(7, S, CT, H, KV, hd, NB, BS, MB, offs, lens)
    q, kc, vc, kp, vp, tbl, off_a, len_a = args
    _, kp2, vp2 = _assert_bitexact(args)
    tbl_np = np.asarray(tbl)
    touched = set()
    for s in range(S):
        for t in range(int(offs[s]), int(offs[s] + lens[s])):
            b, r = int(tbl_np[s, t // BS]), t % BS
            touched.add(b)
            np.testing.assert_array_equal(
                np.asarray(kp2)[b, r], np.asarray(kc)[s, t - int(offs[s])])
            np.testing.assert_array_equal(
                np.asarray(vp2)[b, r], np.asarray(vc)[s, t - int(offs[s])])
    for b in range(NB):
        if b not in touched:
            np.testing.assert_array_equal(np.asarray(kp2)[b],
                                          np.asarray(kp)[b])
            np.testing.assert_array_equal(np.asarray(vp2)[b],
                                          np.asarray(vp)[b])


@pytest.mark.parametrize("splits", [[11], [4, 4, 3], [1, 5, 2, 3],
                                    [8, 3], [2, 2, 2, 2, 2, 1]])
def test_chunked_equals_one_shot_prefill(splits):
    """Driving one prompt through the kernel in ANY chunk split reproduces
    the one-shot causal attention for every row, and the final pool is
    bit-identical across splits (the chunk-size-invariance contract the
    engine property tests rely on)."""
    assert sum(splits) == 11
    P, H, KV, hd = 11, 4, 2, 8
    NB, BS, MB = 16, 4, 4
    rng = np.random.default_rng(3)
    qf = jnp.asarray(rng.standard_normal((1, P, H, hd)), jnp.float32)
    kf = jnp.asarray(rng.standard_normal((1, P, KV, hd)), jnp.float32)
    vf = jnp.asarray(rng.standard_normal((1, P, KV, hd)), jnp.float32)
    dense = mha_ref(qf, kf, vf, causal=True)[0]  # (P, H, hd)
    tbl = jnp.asarray([[2, 7, 5, -1]], jnp.int32)
    kp = jnp.zeros((NB, BS, KV, hd), jnp.float32)
    vp = jnp.zeros((NB, BS, KV, hd), jnp.float32)
    CT = max(splits)
    off = 0
    outs = []
    for ln in splits:
        pad = ((0, 0), (0, CT - ln), (0, 0), (0, 0))
        out, kp, vp = paged_prefill(
            jnp.pad(qf[:, off:off + ln], pad),
            jnp.pad(kf[:, off:off + ln], pad),
            jnp.pad(vf[:, off:off + ln], pad),
            kp, vp, tbl, jnp.asarray([off]), jnp.asarray([ln]),
            interpret=True)
        outs.append(np.asarray(out)[0, :ln])
        off += ln
    got = np.concatenate(outs, axis=0)
    np.testing.assert_allclose(got, np.asarray(dense), atol=2e-5, rtol=2e-5)
    # final pool content is split-invariant bit-for-bit (one-shot pass)
    _, kp1, vp1 = paged_prefill(
        qf, kf, vf, jnp.zeros_like(kp), jnp.zeros_like(vp), tbl,
        jnp.asarray([0]), jnp.asarray([P]), interpret=True)
    np.testing.assert_array_equal(np.asarray(kp)[np.asarray(tbl)[0, :3]],
                                  np.asarray(kp1)[np.asarray(tbl)[0, :3]])
    np.testing.assert_array_equal(np.asarray(vp)[np.asarray(tbl)[0, :3]],
                                  np.asarray(vp1)[np.asarray(tbl)[0, :3]])
