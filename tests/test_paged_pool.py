"""Block-paged KV pool (TWA block semaphore) — the PR-4 tentpole tests:

  * property: with ``kv_pool=`` configured, ``megastep(K)`` stays
    round-for-round bit-identical to K sequential ``step()`` calls under
    mixed prompt/max_new lengths that force block-stall rounds (the
    multi-resource admission gate), incl. 2³² QoS ticket wrap;
  * property: **block conservation** — under random admit / complete /
    deadline-preempt sequences (incl. the block semaphore's own counters
    parked just below 2³²), ``allocated + free == num_blocks`` at every
    round, no block id ever aliases two live slots, and the free-queue ∪
    live-table multiset is exactly {0..NB-1};
  * strict-FCFS block gate: an oversized sequence at the head of the line
    blocks later small ones (no bypass → no starvation of large
    sequences), and admission resumes in ticket order as blocks drain;
  * the wired-but-untested ``admit_impl=engine_state.fused_round_impl``
    inside megastep, interpret mode (ROADMAP open item) — property-tested
    bit-identical to the functional admission path;
  * telemetry: ``kv_blocks_free`` / ``kv_blocks_live`` gauges track the
    reservation lifecycle;
  * `core.functional.BlockPool` unit behavior (alloc/release id flow
    across the counter wrap).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.admission.functional_qos import make_qos, qos_take
from repro.core.functional import (
    BlockPool,
    make_block_pool,
    pool_alloc,
    pool_free_count,
    pool_release,
)
from repro.serving.engine_state import (
    KVPool,
    engine_round,
    fused_round_impl,
    make_engine_state,
    rid_token_fn,
)
from repro.serving.scheduler import ContinuousBatchingEngine, Request

DT = 0.25  # f32-exact virtual-time grid (see tests/test_megastep.py)


def _rid_step_fn(active):
    return np.array([r.rid * 1000 + len(r.out_tokens) for r in active],
                    np.int64)


_IDENT = lambda lg: lg.astype(np.int64)  # noqa: E731


# ------------------------------------------------ BlockPool unit behavior ----


def test_block_pool_alloc_release_wrap():
    """Ids leave at the ticket cursor and re-enter at the grant cursor;
    the counter identity survives the 2³² wrap (pow2 queue positions)."""
    NB = 8
    pool = make_block_pool(NB, start=(1 << 32) - 3)  # counters straddle wrap
    assert int(pool_free_count(pool)) == NB
    pool, ids = pool_alloc(pool, jnp.asarray([3, 0, 2], jnp.int32), max_per=4)
    ids = np.asarray(ids)
    assert int(pool_free_count(pool)) == NB - 5
    got = ids[ids >= 0]
    assert len(got) == 5 and len(set(got.tolist())) == 5
    assert (ids[1] == -1).all() and (ids[0, 3] == -1) and (ids[2, 2:] == -1).all()
    # release consumer 0 only; its 3 ids come back in FIFO id order
    pool = pool_release(pool, jnp.asarray(ids), jnp.asarray([True, False, False]))
    assert int(pool_free_count(pool)) == NB - 2
    pool2, ids2 = pool_alloc(pool, jnp.asarray([6, 0, 0], jnp.int32), max_per=8)
    ids2 = np.asarray(ids2)[0, :6]
    live = set(np.asarray(ids)[2, :2].tolist())
    assert live.isdisjoint(ids2.tolist())          # never re-issue a live id
    assert int(pool_free_count(pool2)) == 0
    assert sorted(ids2.tolist() + sorted(live)) == list(range(NB))

    with pytest.raises(AssertionError):
        make_block_pool(12)  # non-pow2 queue positions break at wrap


# ------------------------------------------- paged megastep ≡ host loop ------


def _mk_engine(clk, *, kv_pool, n_slots=4, weights=None, wrap=False):
    weights = weights or {"gold": 2.0, "bronze": 1.0}
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, n_slots, tenants=dict(weights),
        use_kernel=True, clock=lambda: clk[0], kv_pool=kv_pool)
    if wrap:
        base = jnp.uint32((1 << 32) - 7)
        S = len(weights)
        eng.qos = eng.qos._replace(
            ticket=jnp.full((S,), base), grant=jnp.full((S,), base),
            consumed=jnp.full((S,), base))
    return eng


def _workload(seed, n_req, deadline_frac):
    rng = np.random.default_rng(seed)
    names = ["gold", "bronze"]
    reqs = []
    for i in range(n_req):
        dl = DT * int(rng.integers(0, 16)) if rng.random() < deadline_frac \
            else None
        reqs.append(Request(
            rid=i, prompt=[1] * int(rng.integers(1, 7)),
            max_new_tokens=1 + int(rng.integers(0, 12)),
            tenant_id=names[int(rng.integers(0, 2))], deadline=dl))
    return reqs


def _compare_paged_engines(seed, deadline_frac, wrap, K=14, n_req=16):
    """Mixed lengths against a 16-block pool of block size 4: worst-case
    demands of 1–5 blocks guarantee block-stall rounds; every observable
    must still match the host loop round-for-round."""
    clk = [0.0]
    eh = _mk_engine(clk, kv_pool=(16, 4), wrap=wrap)
    em = _mk_engine(clk, kv_pool=(16, 4), wrap=wrap)
    rh = _workload(seed, n_req, deadline_frac)
    rm = _workload(seed, n_req, deadline_frac)
    eh.submit_batch(rh)
    em.submit_batch(rm)
    times = [k * DT for k in range(K)]
    for t in times:
        clk[0] = t
        eh.step(_IDENT)
    clk[0] = 0.0
    em.megastep(K, token_fn=rid_token_fn, nows=np.asarray(times, np.float32))
    for a, b in zip(rh, rm):
        tag = f"seed={seed} rid={a.rid}"
        assert a.out_tokens == b.out_tokens, (tag, a.out_tokens, b.out_tokens)
        assert a.admit_round == b.admit_round, (tag, a.admit_round,
                                                b.admit_round)
        assert a.expired == b.expired and a.preempted == b.preempted, tag
        assert a.expire_round == b.expire_round, tag
    for f in eh.qos._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(eh.qos, f)), np.asarray(getattr(em.qos, f)),
            err_msg=f"seed={seed}:{f}")
    assert eh._qos_free == em._qos_free
    assert eh._kv_free_blocks == em._kv_free_blocks, seed
    assert eh.stats.admitted == em.stats.admitted
    assert eh.stats.preempted == em.stats.preempted


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.5]), st.booleans())
def test_paged_megastep_equals_host_loop_property(seed, deadline_frac, wrap):
    """ISSUE acceptance: megastep with the pool remains round-for-round
    bit-identical to K sequential step() calls — token streams, admission
    rounds (incl. block-stalled retries), expiry/preemption, the QoS
    state, and the free block counter."""
    _compare_paged_engines(seed, deadline_frac, wrap)


# ----------------------------------------------------- block conservation ----


def _fresh_paged_state(n_rows, *, S=3, NB=16, BS=4, MB=8, start=0, seed=0):
    """Engine-state-level fixture: a populated backlog against a pool whose
    semaphore counters can be parked just below the 2³² wrap."""
    rng = np.random.default_rng(seed)
    qos = make_qos([2.0, 1.0], table_size=64)
    ids = jnp.asarray(rng.integers(0, 2, n_rows), jnp.int32)
    qos, tks, _, _ = qos_take(qos, ids, jnp.ones(n_rows, bool))
    state = make_engine_state(qos, S, backlog_cap=max(16, n_rows), prompt_cap=8,
                              free_units=S, kv_blocks=NB, kv_slot_blocks=MB)
    if start:
        state = state._replace(kv=KVPool(
            pool=make_block_pool(NB, start=start), tbl=state.kv.tbl))
    B = state.backlog.valid.shape[0]
    pad = B - n_rows
    dl = np.where(rng.random(n_rows) < 0.35,
                  rng.integers(1, 10, n_rows) * DT, np.inf)
    bl = state.backlog._replace(
        valid=jnp.asarray(np.pad(np.ones(n_rows, bool), (0, pad))),
        tenant=jnp.asarray(np.pad(np.asarray(ids), (0, pad))),
        ticket=jnp.asarray(np.pad(np.asarray(tks), (0, pad))),
        deadline=jnp.asarray(np.pad(dl, (0, pad), constant_values=np.inf),
                             jnp.float32),
        rid=jnp.asarray(np.pad(np.arange(n_rows, dtype=np.int32), (0, pad),
                               constant_values=-1)),
        max_new=jnp.asarray(np.pad(rng.integers(1, 10, n_rows), (0, pad))
                            .astype(np.int32)),
        prompt=state.backlog.prompt,
        prompt_len=jnp.asarray(np.pad(rng.integers(1, 8, n_rows), (0, pad))
                               .astype(np.int32)))
    return state._replace(backlog=bl), NB, BS


def _check_conservation(kv, NB, tag=""):
    t = int(np.uint32(np.asarray(kv.pool.sema.ticket)))
    g = int(np.uint32(np.asarray(kv.pool.sema.grant)))
    free = ((g - t) + (1 << 32)) % (1 << 32)
    assert free <= NB, (tag, free)
    tbl = np.asarray(kv.tbl)
    live = tbl[tbl >= 0].tolist()
    assert len(live) == NB - free, (tag, len(live), NB - free)
    assert len(set(live)) == len(live), (tag, "block aliased by two slots")
    fq = np.asarray(kv.pool.free_q)
    free_ids = [int(fq[(t + j) % NB]) for j in range(free)]
    assert sorted(live + free_ids) == list(range(NB)), (tag, "ids lost")


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1), st.booleans())
def test_block_conservation_property(seed, wrap):
    """ISSUE satellite: under random admit / complete / deadline-preempt
    rounds (incl. the block semaphore's counters crossing 2³²),
    allocated + free block counts are invariant and no block table ever
    aliases two live slots."""
    start = (1 << 32) - 5 if wrap else 0
    state, NB, BS = _fresh_paged_state(12, start=start, seed=seed)
    step = jax.jit(lambda s, now: engine_round(
        s, (), now, token_fn=rid_token_fn, block_size=BS)[0])

    _check_conservation(state.kv, NB, "init")
    for k in range(64):
        state = step(state, k * DT)
        _check_conservation(state.kv, NB, f"round {k}")
    # fully drained: every sequence completed or was preempted/expired
    assert not bool(np.asarray(state.slots.busy).any())
    assert int(pool_free_count(state.kv.pool)) == NB


# ------------------------------------------------- strict-FCFS block gate ----


@pytest.mark.parametrize("use_kernel", [True, False])
def test_block_gate_strict_fcfs_no_bypass(use_kernel):
    """An oversized head-of-line sequence whose demand exceeds the free
    pool stalls, and LATER small sequences stall behind it (no bypass) —
    once running sequences complete and post their blocks back, admission
    resumes in ticket order, so the big request is never starved.  Both
    host admission paths (fused kernel round and the TWA queue walk with
    its stall rollback) enforce the same gate."""
    clk = [0.0]
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 4, tenants={"a": 1.0},
        use_kernel=use_kernel, clock=lambda: clk[0], kv_pool=(8, 4))
    # two running 2-block sequences occupy 4 of 8 blocks
    runners = [Request(rid=i, prompt=[1], max_new_tokens=5, tenant_id="a")
               for i in range(2)]
    eng.submit_batch(runners)
    eng.step(_IDENT)
    assert eng.telemetry()["kv_blocks_live"] == 4
    # big needs 5 blocks (> 4 free) — small (1 block) must NOT overtake it
    big = Request(rid=10, prompt=[1], max_new_tokens=18, tenant_id="a")
    small = Request(rid=11, prompt=[1], max_new_tokens=2, tenant_id="a")
    eng.submit_batch([big, small])
    eng.step(_IDENT)
    # 4 blocks are free and small's demand is 1 — yet small must NOT
    # overtake the unfit big request (strict FCFS, no bypass)
    assert eng.telemetry()["kv_blocks_free"] >= 1
    assert big.slot is None and small.slot is None  # both block-stalled
    for _ in range(10):
        eng.step(_IDENT)
    assert big.admit_round >= 0 and small.admit_round >= 0
    assert big.admit_round <= small.admit_round  # FCFS held under pressure
    while eng.stats.finished < 4:
        eng.step(_IDENT)
    assert eng.telemetry()["kv_blocks_free"] == 8


def _pool_attn_run(n_slots, K, *, prompt_len, prompt_cap=4, n_req=6,
                   vocab=40):
    import jax

    from repro.serving.engine_state import (
        make_paged_pool_model,
        paged_pool_admit_fn,
        paged_pool_token_fn,
    )

    NB, BS = 32, 4
    eng = ContinuousBatchingEngine(
        lambda a: None, lambda r: None, n_slots, tenants={"a": 1.0},
        clock=lambda: 0.0, kv_pool=(NB, BS, 8), prompt_cap=prompt_cap)
    eng.megastep_model = make_paged_pool_model(
        jax.random.PRNGKey(0), vocab=vocab, d=16, num_blocks=NB,
        block_size=BS)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(1, vocab, prompt_len)),
                    max_new_tokens=7, tenant_id="a") for i in range(n_req)]
    eng.submit_batch(reqs)
    launches = 0
    while eng.stats.finished < n_req and launches < 100:
        eng.megastep(K, token_fn=paged_pool_token_fn,
                     admit_fn=paged_pool_admit_fn)
        launches += 1
    assert eng.stats.finished == n_req
    assert eng.telemetry()["kv_blocks_free"] == NB
    return [r.out_tokens for r in reqs]


def test_pool_attention_truncated_prompt_launch_invariance():
    """Regression (review finding): a prompt LONGER than prompt_cap is
    truncated at admission, so the device KV cursor sits at the truncated
    length — the host must re-seed slot positions from the truncated
    length across launches, or every later block write lands past the
    reservation.  Streams must be invariant to K (launch splits) and slot
    count."""
    a = _pool_attn_run(n_slots=3, K=9, prompt_len=9)   # 9 > prompt_cap=4
    b = _pool_attn_run(n_slots=3, K=2, prompt_len=9)   # same work, 5 launches
    c = _pool_attn_run(n_slots=2, K=3, prompt_len=9)
    assert a == b == c
    assert all(len(t) == 7 for t in a)


def test_paged_engine_rejects_mixed_step_and_megastep():
    """Host step() and megastep() must not interleave on a paged engine:
    the device block pool cannot see host-gated reservations (and vice
    versa), so the engine refuses instead of silently double-booking."""

    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 2, tenants={"a": 1.0},
        use_kernel=True, clock=lambda: 0.0, kv_pool=(8, 4))
    eng.submit_batch([Request(rid=0, prompt=[1], max_new_tokens=6,
                              tenant_id="a")])
    eng.step(_IDENT)  # host admission: no device block tables exist
    with pytest.raises(RuntimeError):
        eng.megastep(2, token_fn=rid_token_fn)
    eng2 = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 2, tenants={"a": 1.0},
        use_kernel=True, clock=lambda: 0.0, kv_pool=(8, 4))
    eng2.submit_batch([Request(rid=0, prompt=[1], max_new_tokens=6,
                               tenant_id="a")])
    eng2.megastep(2, token_fn=rid_token_fn)  # device pool now live
    with pytest.raises(RuntimeError):
        eng2.step(_IDENT)


def test_kv_pool_requires_qos_and_fitting_requests():
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(_rid_step_fn, lambda r: None, 2,
                                 kv_pool=(8, 4))
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(_rid_step_fn, lambda r: None, 2,
                                 tenants={"a": 1.0}, kv_pool=(12, 4))
    eng = ContinuousBatchingEngine(_rid_step_fn, lambda r: None, 2,
                                   tenants={"a": 1.0}, kv_pool=(8, 4))
    with pytest.raises(ValueError):  # 40 tokens > 8 blocks × 4
        eng.submit_batch([Request(rid=0, prompt=[1] * 8, max_new_tokens=64,
                                  tenant_id="a")])


# -------------------------------------------------- telemetry gauges ---------


def test_telemetry_kv_block_gauges():
    """ISSUE satellite: `telemetry()` exposes kv_blocks_free/live next to
    queue_depth, tracking the worst-case reservation lifecycle."""
    clk = [0.0]
    eng = ContinuousBatchingEngine(
        _rid_step_fn, lambda r: None, 4, tenants={"a": 1.0},
        use_kernel=True, clock=lambda: clk[0], kv_pool=(16, 4))
    tel = eng.telemetry()
    assert tel["kv_blocks_free"] == 16 and tel["kv_blocks_live"] == 0
    assert "queue_depth" in tel
    reqs = [Request(rid=i, prompt=[1] * 4, max_new_tokens=4, tenant_id="a")
            for i in range(3)]  # 2 blocks each
    eng.submit_batch(reqs)
    eng.step(_IDENT)
    tel = eng.telemetry()
    assert tel["kv_blocks_live"] == 6 and tel["kv_blocks_free"] == 10
    while eng.stats.finished < 3:
        eng.step(_IDENT)
    tel = eng.telemetry()
    assert tel["kv_blocks_free"] == 16 and tel["kv_blocks_live"] == 0
    # dense engines don't grow the gauges
    dense = ContinuousBatchingEngine(_rid_step_fn, lambda r: None, 2)
    assert "kv_blocks_free" not in dense.telemetry()


# ------------------------------- fused kernel admission inside the scan ------


def _mega_run(seed, deadline_frac, impl, *, kv_pool=None, K=8, n_req=10):
    clk = [0.0]
    eng = _mk_engine(clk, kv_pool=kv_pool, n_slots=3)
    reqs = _workload(seed, n_req, deadline_frac)
    eng.submit_batch(reqs)
    times = np.asarray([k * DT for k in range(K)], np.float32)
    eng.megastep(K, token_fn=rid_token_fn, nows=times, admit_impl=impl)
    return eng, reqs


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([0.0, 0.5]))
def test_fused_round_impl_megastep_bit_identity(seed, deadline_frac):
    """ROADMAP open item (ISSUE satellite): the wired
    ``admit_impl=engine_state.fused_round_impl`` — the fused Pallas
    admission kernel INSIDE the scanned megastep, interpret mode — is
    bit-identical to the functional admission path: token streams,
    admit/expire rounds, QoS state, and the free pool."""
    ea, ra = _mega_run(seed, deadline_frac, None)
    eb, rb = _mega_run(seed, deadline_frac, fused_round_impl)
    for a, b in zip(ra, rb):
        assert a.out_tokens == b.out_tokens, (seed, a.rid)
        assert a.admit_round == b.admit_round, (seed, a.rid)
        assert a.expire_round == b.expire_round, (seed, a.rid)
    for f in ea.qos._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ea.qos, f)), np.asarray(getattr(eb.qos, f)),
            err_msg=f"seed={seed}:{f}")
    assert ea._qos_free == eb._qos_free


def test_fused_round_impl_megastep_paged():
    """The fused admission kernel composes with the block gate (the gate
    sits outside ``admit_impl``): one paged seed, bit-identical."""
    ea, ra = _mega_run(5, 0.4, None, kv_pool=(16, 4))
    eb, rb = _mega_run(5, 0.4, fused_round_impl, kv_pool=(16, 4))
    for a, b in zip(ra, rb):
        assert a.out_tokens == b.out_tokens and a.admit_round == b.admit_round
    assert ea._kv_free_blocks == eb._kv_free_blocks
